"""Golden-trace drivers shared by the regression tests and the regenerator.

Two locked traces live in ``tests/golden/``:

* ``baseline_traces.json`` — per-epoch tier/owner placements of the frozen
  seed baselines (``benchmarks/seed_baselines_frozen.py``) on a small
  scripted churn trace (arrive, depart, late arrive). The vectorized
  ``repro.core.baselines`` must replay it bit-for-bit: this is the parity
  lock that let the per-page reference implementations be deleted.
* ``policy_trace.json`` — telemetry + migration plans of 8 MaxMem policy
  epochs (64 pages, 3 tenants, exact sampling). ``policy.epoch_step`` AND
  ``policy.multi_epoch`` must both replay it bit-identically, so refactors
  cannot silently change migration decisions.
* ``fleet_trace.json`` — the same policy spec on a 3-machine
  ``core.fleet.FleetManager`` (per-machine seeds and migration budgets),
  telemetry per machine per epoch. The vmapped fleet scan must replay it
  bit-identically, and each machine's rows must equal a serial
  ``CentralManager.run_epochs`` run (locked by tests/test_fleet.py).

Regenerate (ONLY when the frozen reference or the trace spec changes):

    PYTHONPATH=src:. python tests/golden_regen.py

Drift check (the CI ``golden-drift`` job): regenerate into a temp dir and
diff against the committed traces — exits non-zero if they diverge, so the
goldens can never silently go stale relative to the generators:

    PYTHONPATH=src:. python tests/golden_regen.py --check
"""
from __future__ import annotations

import json
import os
import sys
import tempfile

import numpy as np

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "golden")
BASELINE_TRACE_PATH = os.path.join(GOLDEN_DIR, "baseline_traces.json")
POLICY_TRACE_PATH = os.path.join(GOLDEN_DIR, "policy_trace.json")
FLEET_TRACE_PATH = os.path.join(GOLDEN_DIR, "fleet_trace.json")

# ----------------------------------------------------------- baseline trace
P, FAST, BUDGET, THRESHOLD = 256, 64, 32, 6
EPOCHS = 12
COUNTS_SEED, BACKEND_SEED = 1234, 7


def trace_counts(epochs: int = EPOCHS, n_pages: int = P) -> np.ndarray:
    """Deterministic per-epoch access counts (mix straddling THRESHOLD)."""
    crng = np.random.default_rng(COUNTS_SEED)
    return crng.integers(0, 16, size=(epochs, n_pages)).astype(np.int64)


def backend_factories(mod):
    """The three baseline constructors from a baselines module (frozen seed
    or the live vectorized one) with identical knobs."""
    return {
        "hemem": lambda: mod.HeMemStatic(
            P, FAST, hot_threshold=THRESHOLD, migration_budget=BUDGET,
            seed=BACKEND_SEED,
        ),
        "autonuma": lambda: mod.AutoNUMALike(P, FAST, seed=BACKEND_SEED),
        "twolm": lambda: mod.TwoLM(P, FAST, seed=BACKEND_SEED),
    }


def drive_baseline(make_backend) -> list:
    """Scripted churn trace: two initial tenants, a mid-trace arrival, a
    departure, and a late arrival into the freed pages. Returns per-epoch
    serializable records (placements + migration counts + live-tenant FMMR).
    """
    b = make_backend()
    counts = trace_counts()

    def reg(n_pages: int, partition: int) -> tuple:
        h = b.register(0.5)
        if hasattr(b, "set_partition"):
            b.set_partition(h, partition)
        return h, b.allocate(h, n_pages)

    h0, _p0 = reg(80, 28)
    h1, p1 = reg(80, 20)
    live = [h0, h1]
    out = []
    for e in range(EPOCHS):
        if e == 4:
            h2, _ = reg(64, 12)
            live.append(h2)
        if e == 7:
            b.free(h1, p1)
            b.unregister(h1)
            live.remove(h1)
        if e == 9:
            h3, _ = reg(40, 16)
            live.append(h3)
        b.record_access(counts[e])
        res = b.run_epoch()
        out.append({
            "tier": np.asarray(b.tiers(), np.int8).tolist(),
            "owner": np.asarray(b.owners(), np.int32).tolist(),
            "promoted": int(res.plan.num_promote),
            "demoted": int(res.plan.num_demote),
            "fmmr": {str(int(h)): float(b.fmmr_of(h)) for h in live},
        })
    return out


# ------------------------------------------------------------- policy trace
POLICY_P, POLICY_FAST, POLICY_BUDGET = 64, 16, 16
POLICY_MAX_T, POLICY_EPOCHS, POLICY_SEED = 4, 8, 5
# First tenant allocates fast-first and holds the whole fast tier with a lax
# target (donor); the second is a hot needer (t=0.1): the trace exercises
# reallocation gives/takes AND per-tenant rebalance pairs every epoch.
POLICY_TENANTS = ((24, 1.0), (20, 0.1), (12, 0.5))  # (n_pages, t_miss)
POLICY_COUNTS_SEED = 99


def policy_counts() -> np.ndarray:
    crng = np.random.default_rng(POLICY_COUNTS_SEED)
    return crng.integers(0, 50, size=(POLICY_EPOCHS, POLICY_P)).astype(np.int64)


def make_policy_manager():
    from repro.core.manager import CentralManager

    m = CentralManager(
        num_pages=POLICY_P, fast_capacity=POLICY_FAST,
        migration_budget=POLICY_BUDGET, max_tenants=POLICY_MAX_T,
        sample_period=100, exact_sampling=True, seed=POLICY_SEED,
    )
    for n_pages, t_miss in POLICY_TENANTS:
        h = m.register(t_miss)
        m.allocate(h, n_pages)
    return m


def epoch_record(result, tier: np.ndarray) -> dict:
    s = result.stats
    return {
        "fmmr_now": np.asarray(s.fmmr_now, np.float32).astype(float).tolist(),
        "fmmr_ewma": np.asarray(s.fmmr_ewma, np.float32).astype(float).tolist(),
        "fast_pages": np.asarray(s.fast_pages, np.int32).tolist(),
        "slow_pages": np.asarray(s.slow_pages, np.int32).tolist(),
        "promoted": np.asarray(s.promoted, np.int32).tolist(),
        "demoted": np.asarray(s.demoted, np.int32).tolist(),
        "cooled": np.asarray(s.cooled, bool).tolist(),
        "promote_ids": np.asarray(result.plan.promote, np.int32).tolist(),
        "demote_ids": np.asarray(result.plan.demote, np.int32).tolist(),
        "tier": np.asarray(tier, np.int8).tolist(),
    }


def drive_policy_singlestep() -> list:
    m = make_policy_manager()
    counts = policy_counts()
    out = []
    for e in range(POLICY_EPOCHS):
        m.record_access(counts[e])
        res = m.run_epoch()
        out.append(epoch_record(res, m.tiers()))
    return out


# -------------------------------------------------------------- fleet trace
# 3 machines on the policy-trace geometry: per-machine seeds AND migration
# budgets differ (both traced), so the golden locks the vmapped program with
# genuinely heterogeneous PolicyParams leaves.
FLEET_MACHINES = ((5, 16), (6, 8), (7, 12))  # (seed, migration_budget)


def make_fleet():
    from repro.core.fleet import FleetManager
    from repro.core.manager import CentralManager

    machines = []
    for seed, budget in FLEET_MACHINES:
        m = CentralManager(
            num_pages=POLICY_P, fast_capacity=POLICY_FAST,
            migration_budget=budget, max_tenants=POLICY_MAX_T,
            sample_period=100, exact_sampling=True, seed=seed,
        )
        for n_pages, t_miss in POLICY_TENANTS:
            h = m.register(t_miss)
            m.allocate(h, n_pages)
        machines.append(m)
    return FleetManager(machines)


def drive_fleet() -> list:
    """Per-machine per-epoch telemetry of one fleet run (counts shared)."""
    fleet = make_fleet()
    counts = policy_counts()
    res = fleet.run_epochs(
        POLICY_EPOCHS, counts=np.broadcast_to(counts, (len(fleet),) + counts.shape),
        collect_plans=True,
    )
    out = []
    for m in range(len(fleet)):
        records = res.machine(m).unstack()
        tier = fleet.machines[m].tiers()
        epochs = [epoch_record(records[e], tier) for e in range(POLICY_EPOCHS)]
        # only the final placement is meaningful per machine (the fleet
        # takes one snapshot at the end, not one per epoch)
        for e in range(POLICY_EPOCHS - 1):
            epochs[e].pop("tier")
        out.append({"seed": FLEET_MACHINES[m][0],
                    "budget": FLEET_MACHINES[m][1], "epochs": epochs})
    return out


def regenerate(golden_dir: str) -> None:
    """Write both golden traces into ``golden_dir`` (same basenames as the
    committed ``BASELINE_TRACE_PATH``/``POLICY_TRACE_PATH``)."""
    import benchmarks.seed_baselines_frozen as frozen

    os.makedirs(golden_dir, exist_ok=True)
    base = {name: drive_baseline(mk) for name, mk in backend_factories(frozen).items()}
    with open(os.path.join(golden_dir, os.path.basename(BASELINE_TRACE_PATH)), "w") as f:
        json.dump({"spec": {"P": P, "FAST": FAST, "BUDGET": BUDGET,
                            "THRESHOLD": THRESHOLD, "EPOCHS": EPOCHS,
                            "COUNTS_SEED": COUNTS_SEED,
                            "BACKEND_SEED": BACKEND_SEED},
                   "traces": base}, f)
    with open(os.path.join(golden_dir, os.path.basename(POLICY_TRACE_PATH)), "w") as f:
        json.dump({"spec": {"P": POLICY_P, "FAST": POLICY_FAST,
                            "BUDGET": POLICY_BUDGET, "EPOCHS": POLICY_EPOCHS,
                            "SEED": POLICY_SEED,
                            "COUNTS_SEED": POLICY_COUNTS_SEED},
                   "epochs": drive_policy_singlestep()}, f)
    with open(os.path.join(golden_dir, os.path.basename(FLEET_TRACE_PATH)), "w") as f:
        json.dump({"spec": {"P": POLICY_P, "FAST": POLICY_FAST,
                            "EPOCHS": POLICY_EPOCHS,
                            "MACHINES": [list(m) for m in FLEET_MACHINES],
                            "COUNTS_SEED": POLICY_COUNTS_SEED},
                   "machines": drive_fleet()}, f)


def check() -> int:
    """Regenerate into a temp dir and diff against the committed traces.
    Returns the number of diverged files (0 = goldens are current)."""
    with tempfile.TemporaryDirectory() as tmp:
        regenerate(tmp)
        diverged = 0
        for path in (BASELINE_TRACE_PATH, POLICY_TRACE_PATH, FLEET_TRACE_PATH):
            name = os.path.basename(path)
            with open(path) as f:
                committed = json.load(f)
            with open(os.path.join(tmp, name)) as f:
                fresh = json.load(f)
            if committed == fresh:
                print(f"golden_drift_{name},0.000,ok")
                continue
            diverged += 1
            keys = [k for k in fresh if committed.get(k) != fresh.get(k)]
            print(f"golden_drift_{name},0.000,DIVERGED(sections={keys})")
    return diverged


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if "--check" in argv:
        n = check()
        if n:
            print(f"FAIL: {n} golden trace(s) no longer match their "
                  f"generators — regenerate deliberately or fix the drift")
        return 1 if n else 0
    regenerate(GOLDEN_DIR)
    print(f"wrote {BASELINE_TRACE_PATH}")
    print(f"wrote {POLICY_TRACE_PATH}")
    print(f"wrote {FLEET_TRACE_PATH}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
