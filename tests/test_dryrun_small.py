"""Launch-layer smoke: lower+compile representative cells on a small mesh.

Runs dryrun in a SUBPROCESS because the placeholder-device XLA flag must be
set before jax initializes (the main test process keeps 1 device).
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_cell(arch, shape, extra=()):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--test-mesh", *extra]
    return subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=ROOT, timeout=420)


@pytest.mark.parametrize("arch,shape", [
    ("qwen2.5-3b", "train_4k"),       # dense train
    ("qwen2-moe-a2.7b", "decode_32k"),  # MoE decode (padded experts)
    ("mamba2-130m", "long_500k"),     # SSM long-context decode (B=1)
])
def test_cell_compiles_on_test_mesh(arch, shape, tmp_path):
    r = _run_cell(arch, shape, ("--out-dir", str(tmp_path)))
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "1/1 cells compiled" in r.stdout
    out = tmp_path / "testmesh" / f"{arch}__{shape}.json"
    data = json.loads(out.read_text())
    assert data["flops_per_device"] > 0
    assert data["roofline"]["dominant"] in ("compute", "memory", "collective")


def test_multipod_cell_compiles(tmp_path):
    r = _run_cell("yi-6b", "train_4k", ("--multi-pod", "--out-dir", str(tmp_path)))
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "1/1 cells compiled" in r.stdout


def test_production_sweep_artifacts_exist():
    """The committed 32-cell sweeps (both meshes) are complete and coherent."""
    for sub in ("singlepod", "multipod"):
        d = os.path.join(ROOT, "results", "dryrun", sub)
        if not os.path.isdir(d):
            pytest.skip("production sweep not present")
        files = [f for f in os.listdir(d) if f.endswith(".json")]
        assert len(files) == 32, f"{sub}: {len(files)} cells"
        for f in files:
            data = json.load(open(os.path.join(d, f)))
            assert data["n_chips"] == (512 if sub == "multipod" else 256)
            assert data["flops_per_device"] > 0
