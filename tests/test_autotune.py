"""Policy autotuner (repro.launch.hillclimb, DESIGN.md §9).

Locks the tuner's reproducibility contract — same seed => bit-identical
search trajectory AND winner; a mid-generation kill + resume reproduces the
uninterrupted run exactly (PR 6 sweep checkpoints underneath) — plus the
committed-profile round trip (every profile under ``src/repro/configs/
tuned/`` must rebuild a working manager whose traced params match the
profile bit-exactly), the new SweepPoint per-point knob plumbing, the
online hot-swap mechanics (no recompile, no host-RNG perturbation), and
the docs contract: ``docs/PARAMS.md`` documents every ``PolicyParams``
field and the offline search space only tunes documented fields.

Runs with only ``src`` on the path: the search tests use the built-in
``skewshift`` family, never ``benchmarks/``.
"""
from __future__ import annotations

import os
import sys
from types import SimpleNamespace

import jax.numpy as jnp
import pytest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from repro.configs.tuned import (
    load_profile,
    manager_kwargs,
    params_from_profile,
    profile_names,
)
from repro.core.manager import CentralManager
from repro.core.scenario import ScenarioSweep, SkewChange, SweepPoint, run_sweep
from repro.core.simulator import OPTANE, ColocationSim
from repro.core.types import PolicyParams
from repro.launch.hillclimb import (
    SEARCH_SPACE,
    OnlineTuner,
    PolicyAutotuner,
    TunerGeometry,
    recovery_epochs,
    skewshift_scenario,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

GEOM = TunerGeometry(n_pages=512, n_epochs=12, fast=64, policy_chunk=4)


def _tuner(**kw):
    base = dict(population=4, generations=2, elites=1, seed=7)
    base.update(kw)
    return PolicyAutotuner("skewshift", GEOM, **base)


def _strip(traj):
    """Trajectory minus float-identity hazards — none expected, so keep all."""
    return [
        {
            "generation": t["generation"],
            "candidates": t["candidates"],
            "agg": t["agg"],
            "ls_p99": t["ls_p99"],
            "scores": t["scores"],
        }
        for t in traj
    ]


# ----------------------------------------------------------- reproducibility
def test_same_seed_same_trajectory_and_winner():
    r1 = _tuner().search()
    r2 = _tuner().search()
    assert not r1.interrupted and not r2.interrupted
    assert _strip(r1.trajectory) == _strip(r2.trajectory)
    assert r1.winner == r2.winner
    assert r1.ref == r2.ref
    # the default candidate is the floor: winner weakly dominates it
    assert r1.winner["agg"] >= r1.ref["agg"] * (1 - 1e-9)
    assert r1.winner["ls_p99"] <= r1.ref["ls_p99"] * (1 + 1e-9)


def test_different_seed_different_population():
    r1 = _tuner(seed=7).search()
    r2 = _tuner(seed=8).search()
    # generation 0 shares candidate 0 (the default) but the sampled rest
    # must differ
    assert r1.trajectory[0]["candidates"][0] == r2.trajectory[0]["candidates"][0]
    assert r1.trajectory[0]["candidates"][1:] != r2.trajectory[0]["candidates"][1:]


def test_kill_resume_reproduces_uninterrupted_run(tmp_path):
    ref = _tuner().search()

    out = str(tmp_path / "tuner")
    t1 = _tuner(out_dir=out, checkpoint_every=4)
    partial = t1.search(stop_after=5)  # killed inside generation 0
    assert partial.interrupted and partial.winner is None
    # the sweep checkpoint exists for generation 0
    assert os.path.isdir(os.path.join(out, "gen000"))

    t2 = _tuner(out_dir=out, checkpoint_every=4)
    resumed = t2.search(resume=True)
    assert not resumed.interrupted
    assert _strip(resumed.trajectory) == _strip(ref.trajectory)
    assert resumed.winner == ref.winner


def test_resume_state_mismatch_rejected(tmp_path):
    out = str(tmp_path / "tuner")
    # tuner state is written after each completed generation
    _tuner(out_dir=out, generations=1).search()
    with pytest.raises(ValueError, match="seed"):
        _tuner(out_dir=out, seed=8).search(resume=True)


# -------------------------------------------------------- committed profiles
def test_profiles_committed():
    # the bench references these names; deleting one must be loud (the perf
    # gate checks the same invariant against BENCH_autotune.json)
    assert {"colocation_4k", "thrash_4k", "skewshift_4k",
            "storm_64k"} <= set(profile_names())


@pytest.mark.parametrize("name", profile_names())
def test_profile_roundtrip_one_epoch(name):
    prof = load_profile(name)
    params = PolicyParams.from_profile(name)
    # bit-exact round trip through the host meta encoding
    for field in PolicyParams._fields:
        want = prof["params"][field]
        got = getattr(params, field)
        if field == "fair_mode":
            assert got is bool(want)
        else:
            assert float(got) == pytest.approx(float(want), abs=0), field
    # the profile rebuilds a working manager at its tuned geometry...
    mgr = CentralManager(**manager_kwargs(name))
    for f in ("migration_budget", "sample_period", "ewma_lambda",
              "hysteresis", "num_bins", "alloc_headroom",
              "promote_band", "demote_band", "promote_admission",
              "demote_cooldown"):
        assert float(getattr(mgr.params, f)) == pytest.approx(
            float(prof["params"][f]), abs=0), f
    # ...that survives one real epoch
    h = mgr.register(t_miss=0.5)
    mgr.allocate(h, min(64, prof["geometry"]["n_pages"] // 4))
    mgr.run_epoch()
    # the claim the profile commits to: tuned weakly dominates default
    m = prof["metrics"]
    assert m["tuned"]["agg_throughput"] >= m["default"]["agg_throughput"] * (1 - 1e-9)
    assert m["tuned"]["ls_p99_us"] <= m["default"]["ls_p99_us"] * (1 + 1e-9)


def test_profile_loader_errors():
    with pytest.raises(KeyError, match="no tuned profile"):
        load_profile("no_such_profile")
    with pytest.raises(TypeError, match="unknown PolicyParams"):
        params_from_profile(profile_names()[0], not_a_field=1)


def test_profile_override():
    name = profile_names()[0]
    p = params_from_profile(name, sample_period=77)
    assert int(p.sample_period) == 77


# ------------------------------------------------------- SweepPoint plumbing
def test_manager_hysteresis_kwarg():
    mgr = CentralManager(num_pages=256, fast_capacity=64, migration_budget=8,
                         hysteresis=0.19)
    assert float(mgr.params.hysteresis) == pytest.approx(0.19)


def test_sweep_point_policy_knobs_take_effect():
    scenario = skewshift_scenario(512, 8)
    points = (
        SweepPoint("default", seed=0),
        SweepPoint("tuned", seed=0, ewma_lambda=0.9, hysteresis=0.0,
                   num_bins=9, sample_period=31, alloc_headroom=8),
    )
    res = run_sweep(
        ScenarioSweep(scenario=scenario, points=points),
        num_pages=512, fast_capacity=64, migration_budget=8,
        max_tenants=8, policy_chunk=4,
    )
    hist_d = res.results["default"].history
    hist_t = res.results["tuned"].history
    assert len(hist_d) == len(hist_t) == 8
    # the overridden point must actually behave differently
    agg_d = [sum(r.throughput.values()) for r in hist_d]
    agg_t = [sum(r.throughput.values()) for r in hist_t]
    assert agg_d != agg_t


# ------------------------------------------------------------ recovery metric
def _hist(values, tenant="kvs"):
    return [SimpleNamespace(throughput={tenant: v}) for v in values]


def test_recovery_epochs_dip_then_recover():
    # baseline 100; event at epoch 4; dip appears 2 epochs later (chunked
    # telemetry lag), recovers at epoch index 4 after the event
    h = _hist([100, 100, 100, 100, 100, 100, 40, 60, 100, 100])
    epochs, base = recovery_epochs(h, 4, tenant="kvs")
    assert base == pytest.approx(100.0)
    assert epochs == 4


def test_recovery_epochs_no_dip_is_instant():
    h = _hist([100.0] * 10)
    epochs, _ = recovery_epochs(h, 4, tenant="kvs")
    assert epochs == 0


def test_recovery_epochs_never_recovers():
    h = _hist([100, 100, 100, 100, 100, 10, 10, 10])
    epochs, _ = recovery_epochs(h, 4, tenant="kvs")
    assert epochs == 4  # the whole post-event window (epoch 4 inclusive)


# ------------------------------------------------------------------- online
def _online_sim(n_pages=512, fast=64):
    mgr = CentralManager(num_pages=n_pages, fast_capacity=fast,
                         migration_budget=fast // 2, max_tenants=8)
    mgr.params = mgr.params._replace(migration_budget=jnp.int32(8))
    return ColocationSim(mgr, OPTANE, seed=3, policy_chunk=2)


def test_online_retune_no_host_rng_perturbation():
    sim = _online_sim()
    scenario = skewshift_scenario(512, 6, shift_epoch=3)
    tuner = OnlineTuner(sim, seed=0, triggers=(SkewChange,))
    res = sim.run_scenario(scenario, on_event=tuner.on_event)
    assert len(res.history) == 6
    assert len(tuner.retunes) == 1  # two same-epoch SkewChanges coalesce
    assert tuner.retunes[0]["trigger"].startswith("kvs")  # the event's label
    # the reference leg without the tuner must be identical BEFORE the
    # shift epoch: the burst draws from its own stream
    ref = _online_sim().run_scenario(skewshift_scenario(512, 6, shift_epoch=3))
    for a, b in zip(ref.history[:3], res.history[:3]):
        assert a.throughput == b.throughput


def test_online_swap_is_in_plan_budget():
    sim = _online_sim()
    tuner = OnlineTuner(sim, seed=0)
    # drive a couple of epochs so tenants exist, then retune manually
    scenario = skewshift_scenario(512, 4, shift_epoch=2)
    sim.run_scenario(scenario, on_event=tuner.on_event)
    assert tuner.retunes, "Arrive/SkewChange triggers must have fired"
    plan = sim.backend.plan_size
    for r in tuner.retunes:
        assert 1 <= r["budget"] <= plan  # runtime budget capped by the buffer
    # hot-swap left a working manager behind
    sim.run_epoch()


# --------------------------------------------------------------------- docs
def test_params_md_documents_every_field():
    path = os.path.join(REPO, "docs", "PARAMS.md")
    assert os.path.exists(path), "docs/PARAMS.md is the tuning-surface contract"
    with open(path) as f:
        text = f.read()
    for field in PolicyParams._fields:
        assert f"`{field}`" in text, f"PARAMS.md must document {field!r}"


def test_search_space_only_tunes_documented_params():
    assert set(SEARCH_SPACE) <= set(PolicyParams._fields)
    for k, s in SEARCH_SPACE.items():
        assert s["lo"] <= s["default"] <= s["hi"], k
