"""Direct numerical oracles for the two nontrivial pure-JAX algorithms:
the chunked SSD scan (vs the naive sequential recurrence) and the blocked
online-softmax attention (vs exact softmax attention)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # clean checkout: deterministic fallback sweep
    from _hypothesis_fallback import given, settings, st

from repro.kernels.ref import flash_attention_ref
from repro.models.layers import blocked_attention
from repro.models.ssm import ssd_scan


def _naive_ssd(xh, dt, A_log, Bm, Cm):
    """Sequential oracle: h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t^T;
    y_t = h_t C_t."""
    B, L, H, P = xh.shape
    N = Bm.shape[-1]
    A = -np.exp(np.asarray(A_log, np.float64))
    h = np.zeros((B, H, P, N), np.float64)
    ys = np.zeros((B, L, H, P), np.float64)
    x = np.asarray(xh, np.float64)
    d = np.asarray(dt, np.float64)
    Bn = np.asarray(Bm, np.float64)
    Cn = np.asarray(Cm, np.float64)
    for t in range(L):
        g = np.exp(d[:, t] * A)  # [B, H]
        delta = (
            d[:, t, :, None, None] * x[:, t, :, :, None] * Bn[:, t, None, None, :]
        )
        h = h * g[:, :, None, None] + delta
        ys[:, t] = np.einsum("bhpn,bn->bhp", h, Cn[:, t])
    return ys, h


class TestSSDOracle:
    @pytest.mark.parametrize("L,chunk", [(16, 4), (24, 8), (17, 8), (32, 32)])
    def test_chunked_matches_naive_recurrence(self, L, chunk):
        B, H, P, N = 2, 3, 4, 5
        ks = jax.random.split(jax.random.PRNGKey(L * 31 + chunk), 5)
        xh = jax.random.normal(ks[0], (B, L, H, P), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H), jnp.float32))
        A_log = jax.random.normal(ks[2], (H,), jnp.float32) * 0.5
        Bm = jax.random.normal(ks[3], (B, L, N), jnp.float32) * 0.5
        Cm = jax.random.normal(ks[4], (B, L, N), jnp.float32) * 0.5

        y, h_final = ssd_scan(xh, dt, A_log, Bm, Cm, chunk)
        y_ref, h_ref = _naive_ssd(xh, dt, A_log, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y, np.float64), y_ref,
                                   atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(h_final, np.float64), h_ref,
                                   atol=2e-4, rtol=2e-4)

    def test_initial_state_continuation(self):
        """ssd_scan(x[:half]) then ssd_scan(x[half:], initial_state) must
        equal one full scan — the prefill/decode state-handoff invariant."""
        B, L, H, P, N, Q = 1, 24, 2, 4, 3, 8
        ks = jax.random.split(jax.random.PRNGKey(7), 5)
        xh = jax.random.normal(ks[0], (B, L, H, P), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H), jnp.float32))
        A_log = jax.random.normal(ks[2], (H,), jnp.float32) * 0.5
        Bm = jax.random.normal(ks[3], (B, L, N), jnp.float32) * 0.5
        Cm = jax.random.normal(ks[4], (B, L, N), jnp.float32) * 0.5

        y_full, h_full = ssd_scan(xh, dt, A_log, Bm, Cm, Q)
        half = 16  # chunk-aligned split
        y1, h1 = ssd_scan(xh[:, :half], dt[:, :half], A_log,
                          Bm[:, :half], Cm[:, :half], Q)
        y2, h2 = ssd_scan(xh[:, half:], dt[:, half:], A_log,
                          Bm[:, half:], Cm[:, half:], Q, initial_state=h1)
        np.testing.assert_allclose(np.asarray(y_full[:, half:]), np.asarray(y2),
                                   atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2),
                                   atol=2e-4, rtol=2e-4)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000), chunk=st.sampled_from([2, 4, 8, 16]))
    def test_property_chunk_size_invariance(self, seed, chunk):
        """The result must not depend on the chunking (pure reformulation)."""
        B, L, H, P, N = 1, 16, 2, 3, 4
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        xh = jax.random.normal(ks[0], (B, L, H, P), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H), jnp.float32))
        A_log = jax.random.normal(ks[2], (H,), jnp.float32) * 0.5
        Bm = jax.random.normal(ks[3], (B, L, N), jnp.float32) * 0.5
        Cm = jax.random.normal(ks[4], (B, L, N), jnp.float32) * 0.5
        y_a, h_a = ssd_scan(xh, dt, A_log, Bm, Cm, chunk)
        y_b, h_b = ssd_scan(xh, dt, A_log, Bm, Cm, L)  # single chunk
        np.testing.assert_allclose(np.asarray(y_a), np.asarray(y_b),
                                   atol=3e-4, rtol=3e-4)


class TestBlockedAttentionOracle:
    @pytest.mark.parametrize("Sq,Skv,qb,kb,causal,win", [
        (64, 64, 16, 16, True, 0),
        (50, 50, 16, 32, True, 0),     # ragged padding
        (32, 96, 16, 32, True, 0),     # suffix alignment (Sq < Skv)
        (64, 64, 64, 64, False, 0),
        (128, 128, 32, 32, True, 24),  # sliding window
    ])
    def test_matches_exact_softmax(self, Sq, Skv, qb, kb, causal, win):
        B, nh, nkv, dh = 2, 4, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(Sq + Skv), 3)
        q = jax.random.normal(ks[0], (B, Sq, nh, dh), jnp.float32)
        k = jax.random.normal(ks[1], (B, Skv, nkv, dh), jnp.float32)
        v = jax.random.normal(ks[2], (B, Skv, nkv, dh), jnp.float32)
        out = blocked_attention(
            q, k, v, causal=causal, q_block=qb, kv_block=kb,
            sliding_window=win, q_offset=Skv - Sq,
        )
        # oracle operates in [B, h, S, dh] layout
        want = flash_attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, sliding_window=win,
        ).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)
