"""Unit + property tests for hotness bins and lazy cooling (paper §3.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # clean checkout: deterministic fallback sweep
    from _hypothesis_fallback import given, settings, st

from repro.core import bins
from repro.core.types import TIER_FAST, TIER_SLOW, PageState, TenantState


def test_bin_of_exponential_classes():
    counts = jnp.array([0, 1, 2, 3, 4, 7, 8, 15, 16, 31, 32, 1000], jnp.uint32)
    got = bins.bin_of(counts, 6)
    # bin k >= 1 holds [2^(k-1), 2^k): neighbor bins differ ~2x in heat
    expect = [0, 1, 2, 2, 3, 3, 4, 4, 5, 5, 5, 5]
    assert got.tolist() == expect


def test_cool_threshold_is_2_pow_5_for_6_bins():
    assert int(bins.cool_threshold(6)) == 32  # paper: 2^5 with 6 bins


def _mk_state(P=8, T=2):
    pages = PageState.create(P)
    pages = pages._replace(
        owner=jnp.zeros((P,), jnp.int32),
        tier=jnp.full((P,), TIER_SLOW, jnp.int8),
    )
    tenants = TenantState.create(T)
    tenants = tenants._replace(active=tenants.active.at[0].set(True))
    return pages, tenants


def test_cooling_fires_once_and_halves():
    pages, tenants = _mk_state()
    sampled = jnp.array([40, 2, 0, 0, 0, 0, 0, 0], jnp.uint32)  # page0 over 2^5
    pages2, tenants2, cooled = bins.accumulate_samples(pages, tenants, sampled, 6)
    assert bool(cooled[0])
    assert int(tenants2.cool_epoch[0]) == 1
    # page 0 and page 1 were touched -> materialized halving
    assert int(pages2.count[0]) == 20
    assert int(pages2.count[1]) == 1


def test_lazy_cooling_applies_on_next_read():
    pages, tenants = _mk_state()
    # page1 has stale count from before 2 cooling events
    pages = pages._replace(count=pages.count.at[1].set(12))
    tenants = tenants._replace(cool_epoch=tenants.cool_epoch.at[0].set(2))
    eff = bins.effective_count(pages, tenants)
    assert int(eff[1]) == 3  # 12 >> 2


def test_heat_histogram_groups_by_tenant_and_bin():
    pages, tenants = _mk_state(P=6, T=2)
    pages = pages._replace(
        owner=jnp.array([0, 0, 0, 1, 1, 1], jnp.int32),
        count=jnp.array([0, 1, 16, 2, 2, 31], jnp.uint32),
    )
    tenants = tenants._replace(active=jnp.array([True, True]))
    hist = bins.heat_histogram(pages, tenants, 6, 2)
    assert hist.shape == (2, 6)
    assert hist[0].tolist() == [1, 1, 0, 0, 0, 1]
    assert hist[1].tolist() == [0, 0, 2, 0, 0, 1]
    assert int(hist.sum()) == 6


@settings(max_examples=50, deadline=None)
@given(
    counts=st.lists(st.integers(0, 2**20), min_size=4, max_size=64),
    cools=st.integers(0, 10),
)
def test_property_effective_count_monotone_in_cooling(counts, cools):
    """More pending cooling events never increase effective counts."""
    P = len(counts)
    pages = PageState.create(P)._replace(
        owner=jnp.zeros((P,), jnp.int32),
        tier=jnp.full((P,), TIER_SLOW, jnp.int8),
        count=jnp.array(counts, jnp.uint32),
    )
    tenants = TenantState.create(1)._replace(active=jnp.array([True]))
    eff0 = bins.effective_count(pages, tenants)
    tenants2 = tenants._replace(cool_epoch=tenants.cool_epoch + cools)
    eff1 = bins.effective_count(pages, tenants2)
    assert np.all(np.asarray(eff1) <= np.asarray(eff0))
    # exact: count >> cools
    assert np.all(np.asarray(eff1) == (np.asarray(counts, np.uint32) >> min(cools, 31)))


@settings(max_examples=50, deadline=None)
@given(
    sampled=st.lists(st.integers(0, 100), min_size=8, max_size=32),
)
def test_property_bins_ordering_preserved(sampled):
    """Accumulation preserves heat ordering: hotter page -> bin >= colder's."""
    P = len(sampled)
    pages = PageState.create(P)._replace(
        owner=jnp.zeros((P,), jnp.int32), tier=jnp.full((P,), TIER_SLOW, jnp.int8)
    )
    tenants = TenantState.create(1)._replace(active=jnp.array([True]))
    pages2, tenants2, _ = bins.accumulate_samples(
        pages, tenants, jnp.array(sampled, jnp.uint32), 6
    )
    eff = np.asarray(bins.effective_count(pages2, tenants2))
    b = np.asarray(bins.bin_of(jnp.asarray(eff), 6))
    order = np.argsort(np.asarray(sampled))
    assert np.all(np.diff(b[order]) >= 0) or np.all(
        np.diff(eff[order].astype(np.int64)) >= 0
    )
