"""Chaos suite (DESIGN.md §7): fault injection, invariant sentinel,
checkpoint/resume, and dispatch-worker supervision.

The load-bearing locks:

  * kill-at-every-chunk-boundary resume parity — a sweep checkpointed and
    killed at ANY boundary, then resumed, replays to the BIT-IDENTICAL
    history of an uninterrupted run (including a kill while a machine is
    down);
  * fault-injected runs never violate the conservation invariants — the
    data plane degrades (moves fail, stay in source tier) but never
    corrupts (frame table + tier metadata stay consistent, contents
    survive);
  * the in-trace sentinel detects poisoned state, and detection triggers
    restore-from-checkpoint with the post-restore history matching a clean
    run;
  * randomized fault schedules (hypothesis, deterministic fallback sweep
    on clean checkouts) stay green for all four policies.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # clean checkout: deterministic fallback sweep
    from _hypothesis_fallback import given, settings, st

from repro.core.faults import (
    SENTINEL_NAN,
    SENTINEL_OWNERSHIP,
    FaultInjector,
    SentinelError,
    deep_validate,
)
from repro.core.manager import CentralManager
from repro.core.scenario import (
    Arrive,
    BandwidthDegrade,
    DataPlaneError,
    MachineFail,
    MachineRecover,
    Retarget,
    Scenario,
    ScenarioSweep,
    SweepPoint,
    TelemetryCorrupt,
    run_sweep,
)
from repro.core.simulator import OPTANE, ColocationSim, WorkloadSpec

P, FAST, BUDGET, CHUNK = 256, 96, 16, 4
SWEEP_KW = dict(num_pages=P, fast_capacity=FAST, migration_budget=BUDGET,
                policy_chunk=CHUNK)


def _scenario(events=(), n_epochs=16):
    return Scenario(name="chaos", n_epochs=n_epochs, events=(
        Arrive(0, WorkloadSpec("a", n_pages=80, t_miss=0.4, sets=((0.2, 0.8),))),
        Arrive(0, WorkloadSpec("b", n_pages=100, t_miss=0.5)),
        *events,
    ))


def _sweep(scn):
    return ScenarioSweep(scenario=scn, points=(
        SweepPoint(name="p0", seed=0), SweepPoint(name="p1", seed=1),
    ))


def _hist(res):
    return {k: [r.__dict__ for r in v.history] for k, v in res.results.items()}


def _assert_same_history(h1, h2, label=""):
    assert h1.keys() == h2.keys()
    for k in h1:
        assert len(h1[k]) == len(h2[k]), (label, k)
        for i, (a, b) in enumerate(zip(h1[k], h2[k])):
            for f in a:
                va, vb = a[f], b[f]
                same = (va == vb) or (
                    isinstance(va, float) and np.isnan(va) and np.isnan(vb)
                )
                assert same, (label, k, i, f, va, vb)


# ------------------------------------------------------------- sentinel
class TestSentinel:
    def test_sentinel_on_matches_off_and_stays_green(self):
        """The sentinel is observability, not behavior: identical histories
        with the flag on, zero trips on a clean run."""
        off = run_sweep(_sweep(_scenario()), **SWEEP_KW)
        on = run_sweep(_sweep(_scenario()), sentinel=True, **SWEEP_KW)
        _assert_same_history(_hist(off), _hist(on))
        assert on.restores == 0 and on.fallbacks == 0

    @pytest.mark.parametrize("kind,bit", [("tier", SENTINEL_OWNERSHIP),
                                          ("nan", SENTINEL_NAN)])
    def test_poisoned_telemetry_detected(self, kind, bit):
        evt = [TelemetryCorrupt(epoch=8, kind=kind, machine=0)]
        with pytest.raises(SentinelError) as ei:
            run_sweep(_sweep(_scenario(evt)), sentinel=True, **SWEEP_KW)
        assert str(bit) in str(ei.value)

    def test_sentinel_triggers_restore_and_finishes_clean(self, tmp_path):
        """Detection -> restore-from-checkpoint -> replay (the transient
        corruption is not re-fired) -> history identical to a clean run
        with the same chunk boundaries."""
        evt = [TelemetryCorrupt(epoch=8, kind="tier", machine=0)]
        res = run_sweep(_sweep(_scenario(evt)), sentinel=True,
                        checkpoint_every=CHUNK, checkpoint_dir=str(tmp_path),
                        **SWEEP_KW)
        assert res.restores >= 1
        noop = [BandwidthDegrade(epoch=8, factor=1.0, machine=1)]
        gold = run_sweep(_sweep(_scenario(noop)), **SWEEP_KW)
        _assert_same_history(_hist(gold), _hist(res), "restore == clean")

    def test_deep_validate_green_after_faulted_run(self):
        m = CentralManager(num_pages=128, fast_capacity=32, migration_budget=8,
                           max_tenants=3, sample_period=1, seed=0,
                           data_plane_elems=8)
        h = m.register(0.2)
        m.allocate(h, 100)
        rng = np.random.default_rng(0)
        m.set_fault_injector(FaultInjector(move_fail_rate=0.5, seed=1))
        for _ in range(8):
            c = np.zeros(128, np.int64)
            hot = rng.choice(128, 24, replace=False)
            c[hot] = rng.integers(20, 200, 24)
            m.record_access(c)
            m.run_epoch()
        deep_validate(m)


# -------------------------------------------------- data-plane fault model
class TestDataPlaneFaults:
    def _mgr(self, rate, seed, queue_size=0, bandwidth=None):
        m = CentralManager(
            num_pages=128, fast_capacity=32, migration_budget=16,
            max_tenants=3, sample_period=1, exact_sampling=True, seed=3,
            queue_size=queue_size, migration_bandwidth=bandwidth,
            data_plane_elems=16,
        )
        for n_pages, t_miss in ((60, 0.1), (40, 0.8)):
            m.allocate(m.register(t_miss), n_pages)
        if rate > 0:
            m.set_fault_injector(FaultInjector(move_fail_rate=rate, seed=seed))
        return m

    @pytest.mark.parametrize("queue_size,bandwidth",
                             [(0, None), (64, 3)], ids=["instant", "queue"])
    def test_degraded_never_corrupt(self, queue_size, bandwidth):
        """Failed moves stay in the source tier; the frame table, free
        lists and tier metadata remain mutually consistent after every
        epoch of a heavily-faulted schedule."""
        m = self._mgr(0.5, seed=7, queue_size=queue_size, bandwidth=bandwidth)
        rng = np.random.default_rng(10)
        for _ in range(12):
            c = np.zeros(128, np.int64)
            hot = rng.choice(128, 24, replace=False)
            c[hot] = rng.integers(20, 200, 24)
            m.record_access(c)
            m.run_epoch()
            m.pool.check(m.tiers())
        fi = m.pool.fault_injector
        assert fi.failures > 0, "fault schedule never fired"
        assert m.migration_failures > 0
        ctr = fi.counters()
        assert ctr["attempts"] >= ctr["failures"] >= ctr["gave_up"]
        assert ctr["retries"] >= ctr["gave_up"] * fi.max_retries

    def test_page_contents_survive_faults(self):
        m = self._mgr(0.4, seed=5)
        rng = np.random.default_rng(2)
        data = {}
        for h in (0, 1):
            pages = np.flatnonzero(np.asarray(m.owners()) == h)[:8]
            rows = rng.normal(size=(len(pages), m.pool.row_elems)).astype(np.float32)
            m.pool.write_pages(pages, rows)
            for p, r in zip(pages, rows):
                data[int(p)] = r
        for _ in range(10):
            c = np.zeros(128, np.int64)
            hot = rng.choice(128, 24, replace=False)
            c[hot] = rng.integers(20, 200, 24)
            m.record_access(c)
            m.run_epoch()
        m.pool.check(m.tiers())
        for p, want in data.items():
            np.testing.assert_array_equal(m.pool.read_page(p), want, str(p))

    @settings(max_examples=8, deadline=None)
    @given(rate=st.floats(min_value=0.05, max_value=0.95),
           seed=st.integers(min_value=0, max_value=2**16))
    def test_random_fault_rates_never_corrupt(self, rate, seed):
        m = self._mgr(rate, seed=seed)
        rng = np.random.default_rng(seed)
        for _ in range(6):
            c = np.zeros(128, np.int64)
            hot = rng.choice(128, 24, replace=False)
            c[hot] = rng.integers(20, 200, 24)
            m.record_access(c)
            m.run_epoch()
            m.pool.check(m.tiers())
        deep_validate(m)

    def test_zero_rate_injector_is_transparent(self):
        """rate=0 with an injector attached == no injector at all."""
        a, b = self._mgr(0.0, seed=0), self._mgr(0.0, seed=0)
        a.set_fault_injector(FaultInjector(move_fail_rate=0.0, seed=9))
        rng_a, rng_b = np.random.default_rng(4), np.random.default_rng(4)
        for _ in range(6):
            for m, rng in ((a, rng_a), (b, rng_b)):
                c = np.zeros(128, np.int64)
                hot = rng.choice(128, 24, replace=False)
                c[hot] = rng.integers(20, 200, 24)
                m.record_access(c)
                m.run_epoch()
        assert (a.tiers() == b.tiers()).all()
        assert a.migration_failures == 0


# --------------------------------------------------- checkpoint / resume
class TestCheckpointResume:
    def test_kill_at_every_chunk_boundary_resumes_bit_identically(self, tmp_path):
        gold = _hist(run_sweep(_sweep(_scenario()), **SWEEP_KW))
        for stop in range(CHUNK, 16, CHUNK):
            ckdir = str(tmp_path / f"stop{stop}")
            part = run_sweep(_sweep(_scenario()), checkpoint_every=CHUNK,
                             checkpoint_dir=ckdir, stop_after=stop, **SWEEP_KW)
            assert part.partial, stop
            full = run_sweep(_sweep(_scenario()), checkpoint_every=CHUNK,
                             checkpoint_dir=ckdir, resume=True, **SWEEP_KW)
            assert not full.partial
            _assert_same_history(gold, _hist(full), f"resume@{stop}")

    def test_kill_while_machine_down_resumes_bit_identically(self, tmp_path):
        """The checkpoint saves the PARKED real state of a failed machine
        and re-parks it on restore; a kill inside the down window still
        resumes to the uninterrupted history."""
        evs = [MachineFail(epoch=4, machine=1), MachineRecover(epoch=12, machine=1)]
        gold = _hist(run_sweep(_sweep(_scenario(evs)), **SWEEP_KW))
        ckdir = str(tmp_path / "down")
        part = run_sweep(_sweep(_scenario(evs)), checkpoint_every=CHUNK,
                         checkpoint_dir=ckdir, stop_after=8, **SWEEP_KW)
        assert part.partial
        full = run_sweep(_sweep(_scenario(evs)), checkpoint_every=CHUNK,
                         checkpoint_dir=ckdir, resume=True, **SWEEP_KW)
        _assert_same_history(gold, _hist(full), "resume-while-down")

    def test_resume_without_checkpoint_dir_rejected(self):
        with pytest.raises(ValueError):
            run_sweep(_sweep(_scenario()), resume=True, **SWEEP_KW)
        with pytest.raises(ValueError):
            run_sweep(_sweep(_scenario()), checkpoint_every=4, **SWEEP_KW)


# ------------------------------------------------- dispatch supervision
class TestDispatchSupervision:
    def test_worker_fault_falls_back_inline_bit_identically(self):
        """An injected dispatch-worker crash mid-sweep: the sweep recovers,
        re-runs the chunk serialized with the same drawn counts, degrades
        to pipeline=False, and the recorded history is unchanged."""
        gold = _hist(run_sweep(_sweep(_scenario()), **SWEEP_KW))
        seen = {}

        def arm(fleet):
            seen["fleet"] = fleet
            fleet._chaos_fail_n = 1

        res = run_sweep(_sweep(_scenario()), on_fleet=arm,
                        dispatch_timeout=60.0, **SWEEP_KW)
        assert res.fallbacks == 1
        assert res.pipeline is False
        _assert_same_history(gold, _hist(res), "fallback")

    def test_result_timeout_and_recovery(self):
        """A hung worker surfaces as DispatchError at result(timeout=), and
        recover_dispatch + inline retry reproduces the lost chunk."""
        from repro.core.fleet import DispatchError, FleetManager

        mgrs = [CentralManager(num_pages=128, fast_capacity=32,
                               migration_budget=8, max_tenants=3,
                               sample_period=1, seed=s) for s in (0, 1)]
        for m in mgrs:
            m.allocate(m.register(0.3), 100)
        fleet = FleetManager(mgrs)
        counts = np.zeros((2, 128), np.int64)
        counts[:, :24] = 50
        clean = fleet.run_epochs(2, counts=counts)
        fmmr_clean = np.asarray(clean.stats.fmmr_now)

        mgrs2 = [CentralManager(num_pages=128, fast_capacity=32,
                                migration_budget=8, max_tenants=3,
                                sample_period=1, seed=s) for s in (0, 1)]
        for m in mgrs2:
            m.allocate(m.register(0.3), 100)
        fleet2 = FleetManager(mgrs2)
        fleet2._chaos_delay_s = 30.0
        handle = fleet2.run_epochs_async(2, counts=counts)
        with pytest.raises(DispatchError):
            handle.result(timeout=0.05)
        fleet2.recover_dispatch()
        res = fleet2.run_epochs_async(2, counts=counts, inline=True).result()
        np.testing.assert_array_equal(np.asarray(res.stats.fmmr_now), fmmr_clean)

    def test_heartbeat_detects_silent_worker(self):
        from repro.runtime.fault_tolerance import HeartbeatTracker

        now = [0.0]
        hb = HeartbeatTracker([0], timeout=5.0, clock=lambda: now[0])
        hb.beat(0)
        now[0] = 3.0
        assert hb.check() == []
        now[0] = 9.0
        assert hb.check() == [0]
        hb.beat(0)  # liveness latches: a late beat does not resurrect
        assert hb.alive_hosts() == []


# -------------------------------------------------- machine fail/recover
class TestMachineFailures:
    def test_fail_recover_window_and_isolation(self):
        evs = [MachineFail(epoch=4, machine=1), MachineRecover(epoch=8, machine=1)]
        res = run_sweep(_sweep(_scenario(evs)), sentinel=True, **SWEEP_KW)
        h = _hist(res)
        for r in h["p1"][4:8]:
            assert sum(r["throughput"].values()) == 0.0
            assert r["migrated_pages"] == 0
        for r in h["p1"][8:]:
            assert sum(r["throughput"].values()) > 0.0
        # machine 0 bit-identical to the same schedule with machine-1
        # failures replaced by no-ops at the SAME epochs (chunk boundaries
        # derive from event epochs, so they must match for draw parity)
        noop = [BandwidthDegrade(epoch=4, factor=1.0, machine=1),
                BandwidthDegrade(epoch=8, factor=1.0, machine=1)]
        ref = run_sweep(_sweep(_scenario(noop)), **SWEEP_KW)
        _assert_same_history({"p0": h["p0"]}, {"p0": _hist(ref)["p0"]}, "isolation")

    def test_tenant_churn_while_down_rejected(self):
        evs = [MachineFail(epoch=4, machine=1),
               Arrive(6, WorkloadSpec("c", n_pages=10, t_miss=0.5)),
               MachineRecover(epoch=8, machine=1)]
        with pytest.raises(ValueError, match="schedule contract"):
            run_sweep(_sweep(_scenario(evs)), **SWEEP_KW)

    def test_machine_target_out_of_range_rejected(self):
        with pytest.raises(ValueError, match="targets machine"):
            run_sweep(_sweep(_scenario([MachineFail(epoch=4, machine=9)])),
                      **SWEEP_KW)


# --------------------------------------------------- input validation
class TestValidation:
    def test_workload_spec_rejects_bad_values(self):
        with pytest.raises(ValueError, match="t_miss"):
            WorkloadSpec("x", n_pages=10, t_miss=float("nan"))
        with pytest.raises(ValueError, match="n_pages"):
            WorkloadSpec("x", n_pages=-4, t_miss=0.5)
        with pytest.raises(ValueError, match="sets"):
            WorkloadSpec("x", n_pages=10, t_miss=0.5, sets=((float("nan"), 0.5),))

    def test_events_validate_at_scenario_construction(self):
        with pytest.raises(ValueError, match="t_miss"):
            _scenario([Retarget(epoch=4, name="a", t_miss=float("nan"))])
        with pytest.raises(ValueError, match="factor"):
            _scenario([BandwidthDegrade(epoch=4, factor=-0.5)])
        with pytest.raises(ValueError, match="rate"):
            _scenario([DataPlaneError(epoch=4, rate=1.5)])
        with pytest.raises(ValueError, match="kind"):
            _scenario([TelemetryCorrupt(epoch=4, kind="bogus")])


# ------------------------------------ randomized schedules, four policies
def _serial_backends(seed):
    from repro.core.baselines import AutoNUMALike, HeMemStatic, TwoLM

    fast = P // 4
    return {
        "maxmem": lambda: CentralManager(
            num_pages=P, fast_capacity=fast, migration_budget=BUDGET,
            max_tenants=8, sample_period=100, seed=seed),
        "hemem": lambda: HeMemStatic(
            P, fast, partitions={0: fast // 2, 1: fast // 2}, hot_threshold=8,
            migration_budget=BUDGET, seed=seed),
        "autonuma": lambda: AutoNUMALike(P, fast, seed=seed),
        "twolm": lambda: TwoLM(P, fast, seed=seed),
    }


class TestRandomizedChaosSchedules:
    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=1000),
           fail_at=st.integers(min_value=2, max_value=6),
           down_for=st.integers(min_value=1, max_value=4),
           factor=st.floats(min_value=0.25, max_value=1.0))
    def test_all_four_policies_survive_random_schedules(
            self, seed, fail_at, down_for, factor):
        """Randomized fail/recover + bandwidth-degrade schedules on the
        serial scenario path: every policy completes, the down window
        records zero throughput, no telemetry NaNs, and fast-tier
        occupancy never exceeds capacity (the sentinel's conservation
        invariant, checked host-side for the non-traced baselines)."""
        n_epochs = 12
        recover_at = min(fail_at + down_for, n_epochs - 2)
        sc = Scenario(name="rand_chaos", n_epochs=n_epochs, events=(
            Arrive(0, WorkloadSpec("a", n_pages=P // 2, t_miss=0.4,
                                   sets=((0.2, 0.8),))),
            Arrive(0, WorkloadSpec("b", n_pages=P // 4, t_miss=0.6)),
            MachineFail(epoch=fail_at),
            BandwidthDegrade(epoch=max(1, fail_at - 1), factor=factor),
            MachineRecover(epoch=recover_at),
        ))
        fast = P // 4
        for name, mk in _serial_backends(seed % 7).items():
            backend = mk()
            sim = ColocationSim(backend, OPTANE, seed=seed)
            res = sim.run_scenario(sc)
            assert len(res.history) == n_epochs, name
            for r in res.history[fail_at:recover_at]:
                assert sum(r.throughput.values()) == 0.0, name
            for r in res.history:
                vals = [*r.throughput.values(), *r.fmmr_true.values(),
                        *r.p99.values()]
                assert np.isfinite(vals).all(), (name, r.epoch)
                assert sum(r.fast_pages.values()) <= fast, (name, r.epoch)
            if name == "maxmem":
                deep_validate(backend)

    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=1000),
           fail_at=st.integers(min_value=2, max_value=8),
           factor=st.floats(min_value=0.25, max_value=0.9))
    def test_fleet_sweep_sentinel_green_under_random_faults(
            self, seed, fail_at, factor):
        """Randomized fault schedules through the FLEET path with the
        in-trace sentinel armed: no trips, clean completion."""
        fail_at = 2 * (fail_at // 2) or 2  # chunk-aligned-ish, any is legal
        evs = [MachineFail(epoch=fail_at, machine=1),
               BandwidthDegrade(epoch=fail_at, factor=factor),
               MachineRecover(epoch=min(fail_at + 4, 14), machine=1)]
        scn = _scenario(evs, n_epochs=16)
        sweep = ScenarioSweep(scenario=scn, points=(
            SweepPoint(name="p0", seed=seed % 11),
            SweepPoint(name="p1", seed=(seed + 1) % 11),
        ))
        res = run_sweep(sweep, sentinel=True, **SWEEP_KW)
        assert res.restores == 0
        for recs in _hist(res).values():
            assert len(recs) == 16
