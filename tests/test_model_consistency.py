"""Cross-path model consistency: prefill vs decode, shard_map MoE vs pjit
MoE, deferred vs eager cache commit, hybrid state handoff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.launch.partitioning import use_partitioning
from repro.launch.shardings import rules_for
from repro.models import tuning
from repro.models.model import get_model


def _greedy_rollout(api, params, prompt, n, max_len):
    """prefill + n decode steps, greedy."""
    logits, cache = api.prefill(params, prompt, max_len)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    toks = [tok]
    for _ in range(n - 1):
        logits, cache = api.decode(params, tok, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(tok)
    return jnp.stack(toks, axis=1)


@pytest.mark.parametrize("arch", ["yi-6b", "qwen2-moe-a2.7b"])
def test_prefill_decode_matches_teacher_forcing(arch):
    """Greedy decode continuation must match re-prefilling the full prefix."""
    cfg = get_config(arch).smoke()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    prompt = jnp.asarray(np.arange(1, 9)[None, :], jnp.int32)

    out = _greedy_rollout(api, params, prompt, 4, max_len=16)
    # teacher-forced check: prefill(prompt + out[:-1]) must predict out[-1]
    full = jnp.concatenate([prompt, out[:, :-1]], axis=1)
    logits2, _ = api.prefill(params, full, 16)
    pred = jnp.argmax(logits2[:, -1], axis=-1)
    assert int(pred[0]) == int(out[0, -1]), "decode path diverges from prefill"


def test_deferred_commit_multi_step_equivalence():
    cfg = get_config("qwen2.5-3b").smoke()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    toks = jnp.asarray([[2, 9, 4]], jnp.int32)

    def run():
        cache = api.init_cache(1, 8)
        outs = []
        for i in range(3):
            logits, cache = api.decode(params, toks[:, i], cache)
            outs.append(logits)
        return jnp.stack(outs), cache

    with tuning.tuned(decode_deferred_commit=True):
        o_def, c_def = run()
    with tuning.tuned(decode_deferred_commit=False):
        o_eager, c_eager = run()
    np.testing.assert_allclose(np.asarray(o_def), np.asarray(o_eager),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(c_def.k), np.asarray(c_eager.k),
                               atol=1e-5, rtol=1e-5)


def test_moe_shardmap_matches_pjit_path_on_unit_mesh():
    """On a 1x1 mesh the token-motion-free path must equal the pjit path
    (same routing, same capacity semantics at dp=1, m=1)."""
    cfg = get_config("qwen2-moe-a2.7b").smoke()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(2))
    batch = {
        "tokens": jnp.asarray(np.arange(1, 33)[None, :], jnp.int32),
        "labels": jnp.asarray(np.arange(2, 34)[None, :], jnp.int32),
    }
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = rules_for(cfg, mesh)

    with tuning.tuned(moe_shardmap=False):
        loss_a, _ = jax.jit(lambda p, b: api.loss(p, b))(params, batch)
    with tuning.tuned(moe_shardmap=True), use_partitioning(mesh, rules):
        loss_b, _ = jax.jit(lambda p, b: api.loss(p, b))(params, batch)
    assert float(loss_a) == pytest.approx(float(loss_b), rel=2e-3)


def test_hybrid_prefill_then_decode_state_handoff():
    """Zamba2: decode after prefill must match a pure-decode rollout."""
    cfg = get_config("zamba2-1.2b").smoke()
    from repro.models import hybrid

    params = hybrid.init_params(jax.random.PRNGKey(3), cfg)
    prompt = jnp.asarray(np.arange(1, 7)[None, :], jnp.int32)

    # path A: prefill prompt, decode 1
    logits_p, cache = hybrid.prefill(params, prompt, cfg, max_len=16)
    tok = jnp.argmax(logits_p, axis=-1).astype(jnp.int32)
    la, _ = hybrid.decode_step(params, tok, cache, cfg)

    # path B: feed prompt token-by-token through decode
    cache_b = hybrid.init_cache(cfg, 1, 16)
    for i in range(prompt.shape[1]):
        lb, cache_b = hybrid.decode_step(params, prompt[:, i], cache_b, cfg)
    # logits after consuming the prompt should match prefill's last logits
    np.testing.assert_allclose(
        np.asarray(lb), np.asarray(logits_p), atol=5e-3, rtol=5e-3
    )
    lb2, _ = hybrid.decode_step(params, tok, cache_b, cfg)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb2), atol=5e-3, rtol=5e-3)


def test_ssm_prefill_then_decode_state_handoff():
    cfg = get_config("mamba2-130m").smoke()
    from repro.models import ssm_lm

    params = ssm_lm.init_params(jax.random.PRNGKey(4), cfg)
    prompt = jnp.asarray(np.arange(1, 9)[None, :], jnp.int32)
    logits_p, cache = ssm_lm.prefill(params, prompt, cfg)

    cache_b = ssm_lm.init_cache(cfg, 1)
    for i in range(prompt.shape[1]):
        lb, cache_b = ssm_lm.decode_step(params, prompt[:, i], cache_b, cfg)
    np.testing.assert_allclose(
        np.asarray(lb), np.asarray(logits_p), atol=5e-3, rtol=5e-3
    )
