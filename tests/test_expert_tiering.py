"""MoE expert-weight tiering: routing skew drives hot experts into the fast
pool; migrations move real weight data; the pool-consuming forward stays
bit-identical across migrations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import get_model
from repro.serving.expert_tiering import ExpertTierManager, moe_layer_from_pools


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-moe-a2.7b").smoke()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, params


def _router_for_layer(params, l):
    return params["layers"]["moe"]["router"][l]


def test_pools_roundtrip_and_forward_consistency(setup):
    """Forward through pools == forward through pools after migrations."""
    cfg, params = setup
    E = cfg.num_experts
    tm = ExpertTierManager(cfg, n_fast_slots=4, migration_budget=6, epoch_steps=2)
    tm.build_pools(params)
    x = jax.random.normal(jax.random.PRNGKey(1), (6, cfg.d_model), jnp.float32)
    router = _router_for_layer(params, 0)
    slots0 = tm.slot_table()[0]
    out_before, counts = moe_layer_from_pools(tm.pools, slots0, router, x, cfg=cfg)
    assert int(counts.sum()) == 6 * cfg.moe_top_k

    # drive skewed routing for several epochs -> migrations happen
    rng = np.random.default_rng(0)
    L = cfg.num_layers
    moved_total = 0
    for step in range(12):
        ec = np.zeros((L, E), np.int64)
        ec[:, :2] = 50  # experts 0,1 hot in every layer
        ec[:, 2:] = rng.integers(0, 3, (L, E - 2))
        tm.record_routing(ec)
        moved_total += tm.maybe_epoch()
    assert moved_total > 0, "no expert migrations happened"

    # physical placement changed but the logical forward result must not
    slots1 = tm.slot_table()[0]
    assert not np.array_equal(np.asarray(slots0), np.asarray(slots1))
    out_after, _ = moe_layer_from_pools(tm.pools, slots1, router, x, cfg=cfg)
    np.testing.assert_allclose(
        np.asarray(out_before), np.asarray(out_after), atol=1e-5, rtol=1e-5
    )


def test_hot_experts_become_fast_resident(setup):
    cfg, params = setup
    E, L = cfg.num_experts, cfg.num_layers
    tm = ExpertTierManager(cfg, n_fast_slots=L * 2, migration_budget=8,
                           epoch_steps=1, t_miss=0.2)
    tm.build_pools(params)
    rng = np.random.default_rng(1)
    ec = np.zeros((L, E), np.int64)
    for _ in range(30):
        ec[:] = 0
        ec[:, 0] = 80  # expert 0 dominates in every layer
        ec[:, 1] = 40
        ec[:, 2:] = rng.integers(0, 2, (L, E - 2))
        tm.record_routing(ec)
        tm.maybe_epoch()
    hot_resident = np.mean([tm.fast_resident(l, 0) for l in range(L)])
    assert hot_resident > 0.8, f"hot expert fast-residency only {hot_resident:.0%}"
    assert tm.fast_share_of_traffic(ec) > 0.6
    assert tm.fmmr() < 0.5


def test_odd_plan_remainder_counted_not_dropped(setup):
    """A plan with unpaired promotions (1:1 slots can only swap) must count
    the remainder in telemetry instead of silently dropping it."""
    cfg, params = setup
    from repro.core.types import MigrationPlan

    tm = ExpertTierManager(cfg, n_fast_slots=4, migration_budget=8, epoch_steps=1)
    tm.build_pools(params)
    # identity slot_of at boot: pages 4,5,6 are slow-resident, page 0 fast
    plan = MigrationPlan(
        promote=jnp.asarray([4, 5, 6, -1], jnp.int32),
        demote=jnp.asarray([0, -1, -1, -1], jnp.int32),
    )
    before = {
        p: np.asarray(tm.pools.w_gate[tm.slot_of[p]]).copy() for p in (0, 4, 5, 6)
    }
    moved = tm._migrate(plan)
    assert moved == 2, "one executable pair = two page moves"
    assert tm.unpaired_promotes == 2
    assert tm.unpaired_demotes == 0
    # the paired swap really moved data; the unpaired remainder stayed put
    assert int(tm.slot_of[4]) == 0 and int(tm.slot_of[0]) == 4
    assert int(tm.slot_of[5]) == 5 and int(tm.slot_of[6]) == 6
    for p in (0, 4, 5, 6):
        np.testing.assert_array_equal(
            before[p], np.asarray(tm.pools.w_gate[tm.slot_of[p]])
        )


def test_real_router_skew_from_moe_model(setup):
    """End-to-end: counts produced by the REAL router on real activations."""
    cfg, params = setup
    E, L = cfg.num_experts, cfg.num_layers
    tm = ExpertTierManager(cfg, n_fast_slots=L * 3, migration_budget=8,
                           epoch_steps=2, t_miss=0.3)
    tm.build_pools(params)
    x = jax.random.normal(jax.random.PRNGKey(2), (16, cfg.d_model), jnp.float32)
    for step in range(10):
        counts = []
        for l in range(L):
            _, c = moe_layer_from_pools(
                tm.pools, tm.slot_table()[l], _router_for_layer(params, l), x, cfg=cfg
            )
            counts.append(np.asarray(c))
        tm.record_routing(np.stack(counts))
        tm.maybe_epoch()
    share = tm.fast_share_of_traffic(np.stack(counts))
    # the policy should capture at least the uniform share of traffic
    assert share >= 3 / E - 0.05, f"fast traffic share {share:.2f}"
