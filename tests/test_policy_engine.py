"""Tests for the fused on-device policy engine: exact bin-indexed victim
selection (no candidate window), the multi-epoch scan path, and the manager's
on-device state handling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import policy
from repro.core.manager import CentralManager
from repro.core.types import (
    TIER_FAST,
    TIER_SLOW,
    PageState,
    PolicyParams,
    PolicyState,
    TenantState,
)


def _single_tenant(P, tier, counts, F, R):
    pages = PageState.create(P)._replace(
        owner=jnp.zeros((P,), jnp.int32),
        tier=jnp.asarray(tier, jnp.int8),
        count=jnp.asarray(counts, jnp.uint32),
    )
    tenants = TenantState.create(1)._replace(
        active=jnp.ones((1,), bool),
        t_miss=jnp.asarray([0.05], jnp.float32),
        a_miss=jnp.asarray([0.9], jnp.float32),
        arrival=jnp.zeros((1,), jnp.int32),
    )
    params = PolicyParams(
        fast_capacity=jnp.int32(F),
        migration_budget=jnp.int32(R),
        sample_period=jnp.int32(1),
    )
    return pages, tenants, params


class TestExactSelection:
    def test_no_4096_candidate_window(self):
        """>4096 slow candidates per tenant: the true hottest pages win.

        The seed gathered sorted counts through a W=4096 window, silently
        truncating victim selection; the counting-rank engine is exact. Put
        the genuinely hot pages at ids beyond any window position so a
        truncating implementation cannot find them.
        """
        P, F, R = 10000, 256, 128
        tier = np.full(P, TIER_SLOW)
        tier[:64] = TIER_FAST  # a few cold fast pages
        counts = np.zeros(P, np.int64)
        # ~9900 warm slow candidates, then the true hot set at the very end
        counts[64:] = 2
        hot_ids = np.arange(P - 100, P)
        counts[hot_ids] = 30
        pages, tenants, params = _single_tenant(P, tier, counts, F, R)
        sampled = jnp.zeros((P,), jnp.uint32)
        _, _, plan, stats = policy.policy_epoch(
            pages, tenants, sampled, params, max_tenants=1, plan_size=R
        )
        promoted = np.asarray(plan.promote)
        promoted = set(promoted[promoted >= 0].tolist())
        assert len(promoted) >= 32, "expected a substantial promotion quota"
        # every promoted page must come from the true hottest candidates: all
        # 100 hot pages (count 30) rank strictly before any count-2 page, and
        # the quota here is < 100 — a windowed implementation would promote
        # warm low-id pages instead.
        assert promoted <= set(hot_ids.tolist()), (
            "window truncation: promoted warm pages while hotter pages exist"
        )

    def test_tie_break_is_lowest_page_id(self):
        """Within a count bucket the stable (seed lexsort) order holds."""
        P, F, R = 64, 8, 8
        tier = np.full(P, TIER_SLOW)
        tier[:4] = TIER_FAST
        counts = np.zeros(P, np.int64)
        counts[10:30] = 7  # 20 tied candidates, quota smaller
        pages, tenants, params = _single_tenant(P, tier, counts, F, R)
        _, _, plan, _ = policy.policy_epoch(
            pages, tenants, jnp.zeros((P,), jnp.uint32), params, max_tenants=1, plan_size=R
        )
        promoted = np.asarray(plan.promote)
        promoted = sorted(promoted[promoted >= 0].tolist())
        assert promoted == list(range(10, 10 + len(promoted)))

    def test_occ_packed_matches_twopass(self):
        """The packed 16+16-bit occupancy prefix sum equals the two-pass
        reference on random member sets."""
        rng = np.random.default_rng(0)
        for _ in range(5):
            P, T = 2048, 5
            owner = jnp.asarray(rng.integers(0, T, P), jnp.int32)
            mp = jnp.asarray(rng.random(P) < 0.3)
            md = jnp.asarray((rng.random(P) < 0.3)) & ~mp  # disjoint sides
            oh = owner[None, :] == jnp.arange(T, dtype=jnp.int32)[:, None]
            p1, d1 = policy._occ_packed(mp, md, owner, oh)
            p2, d2 = policy._occ_twopass(mp, md, owner, oh)
            np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
            np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))

    def test_selection_matches_lexsort_reference(self):
        """Promote/demote sets equal a numpy lexsort reference (exact ranks,
        stable tie-break) across random states."""
        rng = np.random.default_rng(3)
        for trial in range(10):
            P, T = int(rng.integers(50, 400)), int(rng.integers(1, 5))
            tier = np.where(rng.random(P) < 0.3, TIER_FAST, TIER_SLOW)
            owner = rng.integers(0, T, P)
            counts = rng.integers(0, 25, P)
            quota_p = rng.integers(0, 30, T)
            quota_d = rng.integers(0, 30, T)
            key = jnp.asarray(counts, jnp.int32)
            ownr = jnp.asarray(owner, jnp.int32)
            slow_cand = jnp.asarray(tier == TIER_SLOW)
            fast_cand = jnp.asarray(tier == TIER_FAST)
            C = 64
            from repro.core import bins

            hist_slow = bins.count_histogram(key, ownr, slow_cand, C, T)
            hist_fast = bins.count_histogram(key, ownr, fast_cand, C, T)
            oh = ownr[None, :] == jnp.arange(T, dtype=jnp.int32)[:, None]
            pm, dm = policy._select_victims(
                key, ownr, slow_cand, fast_cand, hist_slow, hist_fast,
                jnp.cumsum(hist_slow, axis=1), jnp.cumsum(hist_fast, axis=1),
                jnp.asarray(quota_p, jnp.int32), jnp.asarray(quota_d, jnp.int32), oh,
            )
            pm, dm = np.asarray(pm), np.asarray(dm)
            for t in range(T):
                s_ids = np.flatnonzero((owner == t) & (tier == TIER_SLOW))
                order = s_ids[np.lexsort((s_ids, -counts[s_ids]))]
                expect = set(order[: quota_p[t]].tolist())
                assert set(np.flatnonzero(pm & (owner == t)).tolist()) == expect
                f_ids = np.flatnonzero((owner == t) & (tier == TIER_FAST))
                order = f_ids[np.lexsort((f_ids, counts[f_ids]))]
                expect = set(order[: quota_d[t]].tolist())
                assert set(np.flatnonzero(dm & (owner == t)).tolist()) == expect


class TestMultiEpoch:
    def _state(self, P=256, T=4, seed=0):
        rng = np.random.default_rng(seed)
        pages = PageState.create(P)._replace(
            owner=jnp.asarray(rng.integers(0, T, P), jnp.int32),
            tier=jnp.asarray(
                np.where(np.arange(P) < P // 4, TIER_FAST, TIER_SLOW), jnp.int8
            ),
        )
        tenants = TenantState.create(T)._replace(
            active=jnp.ones((T,), bool),
            t_miss=jnp.asarray(rng.uniform(0.05, 1.0, T), jnp.float32),
            arrival=jnp.arange(T, dtype=jnp.int32),
        )
        params = PolicyParams(
            fast_capacity=jnp.int32(P // 4),
            migration_budget=jnp.int32(16),
            sample_period=jnp.int32(1),
        )
        return PolicyState(
            pages=pages, tenants=tenants,
            pending=jnp.zeros((P,), jnp.uint32), rng=jax.random.PRNGKey(1),
        ), params, rng

    def test_scan_equals_k_single_steps_exact(self):
        """multi_epoch(k) is bit-identical to k epoch_step calls (exact
        sampling: no stochastic draws differ between the two paths)."""
        state0, params, rng = self._state()
        counts = jnp.asarray(rng.integers(0, 20, 256), jnp.uint32)
        k = 6
        st = state0
        seq_stats = []
        for _ in range(k):
            st = st._replace(pending=st.pending + counts)
            st, plan, stats = policy.epoch_step(
                st, params, max_tenants=4, plan_size=16, exact_sampling=True
            )
            seq_stats.append(stats)
        stm, plans, stats_k, flagged = policy.multi_epoch(
            state0, params, counts, k=k, max_tenants=4, plan_size=16, exact_sampling=True
        )
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(stm)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for i in range(k):
            for a, b in zip(jax.tree.leaves(seq_stats[i]), jax.tree.leaves(stats_k)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b)[i])

    def test_stacked_outputs_shapes(self):
        state0, params, _ = self._state()
        _, plans, stats, flagged = policy.multi_epoch(
            state0, params, None, k=5, max_tenants=4, plan_size=16, exact_sampling=True
        )
        assert plans.promote.shape == (5, 16)
        assert stats.fmmr_ewma.shape == (5, 4)
        assert flagged.shape == (5, 4)

    def test_collect_plans_false_keeps_stats_exact(self):
        state0, params, rng = self._state(seed=5)
        counts = jnp.asarray(rng.integers(0, 20, 256), jnp.uint32)
        _, plans_a, stats_a, _ = policy.multi_epoch(
            state0, params, counts, k=4, max_tenants=4, plan_size=16,
            exact_sampling=True, collect_plans=True,
        )
        _, plans_b, stats_b, _ = policy.multi_epoch(
            state0, params, counts, k=4, max_tenants=4, plan_size=16,
            exact_sampling=True, collect_plans=False,
        )
        assert plans_b is None
        for a, b in zip(jax.tree.leaves(stats_a), jax.tree.leaves(stats_b)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # plan lists agree with the promoted/demoted telemetry
        assert int((np.asarray(plans_a.promote) >= 0).sum(axis=1).sum()) == int(
            np.asarray(stats_a.promoted).sum()
        )


class TestManagerEngine:
    def _mgr(self, **kw):
        defaults = dict(
            num_pages=256, fast_capacity=64, migration_budget=32,
            max_tenants=8, sample_period=1, exact_sampling=True,
        )
        defaults.update(kw)
        return CentralManager(**defaults)

    def test_run_epochs_matches_single_stepping(self):
        counts = np.zeros(256, np.int64)
        counts[:128] = np.arange(128) % 11

        m1 = self._mgr()
        h1 = m1.register(0.2)
        m1.allocate(h1, 128)
        for _ in range(8):
            m1.record_access(counts)
            m1.run_epoch()

        m2 = self._mgr()
        h2 = m2.register(0.2)
        m2.allocate(h2, 128)
        res = m2.run_epochs(8, counts=counts)
        assert len(res) == 8
        np.testing.assert_array_equal(
            np.asarray(m1.pages.tier), np.asarray(m2.pages.tier)
        )
        np.testing.assert_array_equal(
            np.asarray(m1.pages.count), np.asarray(m2.pages.count)
        )
        assert m1.fmmr_of(h1) == pytest.approx(m2.fmmr_of(h2))
        assert m1.epoch_index == m2.epoch_index == 8

    def test_free_resets_cooling_stamp(self):
        """A reallocated page must not inherit the previous owner's cooling
        stamp (stale-metadata leak)."""
        m = self._mgr()
        h = m.register(1.0)
        pages = m.allocate(h, 32)
        # drive counts over the cooling threshold a few times
        counts = np.zeros(256, np.int64)
        counts[pages] = 100
        for _ in range(4):
            m.record_access(counts)
            m.run_epoch()
        assert int(m.tenants.cool_epoch[int(h)]) > 0
        m.free(h, pages)
        assert (np.asarray(m.pages.last_cool)[pages] == 0).all()
        assert (np.asarray(m.pages.count)[pages] == 0).all()
        m.unregister(h)
        # a new tenant reusing the slot (cool_epoch restarts at 0) sees
        # counts at face value, not spuriously halved or inflated
        h2 = m.register(1.0)
        assert int(h2) == int(h)
        p2 = m.allocate(h2, 32)
        m.record_access(counts)
        m.run_epoch()
        from repro.core import bins

        eff = np.asarray(bins.effective_count(m.pages, m.tenants))
        assert eff[p2].max() > 0

    def test_record_access_folds_on_device(self):
        m = self._mgr()
        h = m.register(0.5)
        m.allocate(h, 64)
        counts = np.zeros(256, np.int64)
        counts[:64] = 3
        m.record_access(counts)
        m.record_access(counts)
        assert int(np.asarray(m._state.pending)[:64].sum()) == 2 * 3 * 64

    def test_telemetry_snapshot_caching(self):
        m = self._mgr()
        h = m.register(0.5)
        pages = m.allocate(h, 100)
        snap1 = m.tiers()
        snap2 = m.tiers()
        assert snap1 is snap2  # cached between state changes
        m.record_access(np.ones(256, np.int64))
        m.run_epoch()
        assert m.tiers() is not snap1
        assert m.fast_pages_of(h) == 64
