"""Colocation-simulator tests: the paper's §5.1 dynamics at reduced scale."""
import numpy as np
import pytest

from repro.core.baselines import AutoNUMALike, HeMemStatic, TwoLM
from repro.core.manager import CentralManager
from repro.core.simulator import OPTANE, ColocationSim, WorkloadSpec


def _maxmem(num_pages=512, fast=128, budget=64, **kw):
    return CentralManager(
        num_pages=num_pages,
        fast_capacity=fast,
        migration_budget=budget,
        max_tenants=8,
        sample_period=kw.pop("sample_period", 10),
        **kw,
    )


def test_single_tenant_converges_to_hot_set():
    """GUPS with hot(60%)/warm(30%)/cold(10%) sets: hot set -> fast tier."""
    mgr = _maxmem(num_pages=512, fast=128, budget=64)
    sim = ColocationSim(mgr, OPTANE, seed=0)
    # hot = 1/7 of pages (64), warm 2/7 (128): hot+warm > fast capacity
    spec = WorkloadSpec(
        "gups", n_pages=448, t_miss=0.1, threads=4,
        sets=((1 / 7, 0.6), (2 / 7, 0.3)),
    )
    sim.add_tenant(spec)
    sim.run(40)
    rec = sim.history[-1]
    # heat gradient keeps the hot set resident: miss ratio ~ warm+cold share
    assert rec.fmmr_true["gups"] < 0.45
    # and throughput beats an all-slow placement by construction
    assert rec.throughput["gups"] > 0


def test_heat_gradient_beats_threshold_when_oversubscribed():
    """Paper Fig. 3 (256 GB point): MaxMem ~3.3x HeMem throughput."""
    def scenario(backend):
        sim = ColocationSim(backend, OPTANE, seed=1)
        spec = WorkloadSpec(
            "gups", n_pages=448, t_miss=0.1, threads=4,
            sets=((1 / 7, 0.6), (2 / 7, 0.3)),
        )
        sim.add_tenant(spec)
        sim.run(50)
        return np.mean([r.throughput["gups"] for r in sim.history[-10:]])

    mm = scenario(_maxmem(num_pages=512, fast=128, budget=64))
    he = HeMemStatic(num_pages=512, fast_capacity=128, hot_threshold=4,
                     migration_budget=64, partitions={0: 128})
    ht = scenario(he)
    assert mm > 1.2 * ht, f"MaxMem {mm:.0f} ops/s vs HeMem {ht:.0f}"


def test_colocation_all_targets_met():
    """Five LS tenants (t=0.1) + one BE (t=1.0): a_miss <= t_miss after
    convergence (paper Fig. 4 steady state)."""
    mgr = _maxmem(num_pages=2048, fast=640, budget=128)
    sim = ColocationSim(mgr, OPTANE, seed=2)
    sim.add_tenant(WorkloadSpec("be", n_pages=256, t_miss=1.0, threads=2))
    for i in range(5):
        sim.add_tenant(
            WorkloadSpec(
                f"ls{i}", n_pages=256, t_miss=0.1, threads=2,
                sets=((0.45, 0.9),),  # 115-page hot set, 90% of accesses
            )
        )
    sim.run(60)
    rec = sim.history[-1]
    for i in range(5):
        assert rec.fmmr_true[f"ls{i}"] <= 0.15, (
            f"ls{i} fmmr {rec.fmmr_true[f'ls{i}']:.3f} misses target"
        )


def test_dynamic_arrival_reallocates():
    """A late-arriving LS tenant pulls fast memory from the BE tenant."""
    mgr = _maxmem(num_pages=1024, fast=256, budget=128)
    sim = ColocationSim(mgr, OPTANE, seed=3)
    sim.add_tenant(WorkloadSpec("be", n_pages=512, t_miss=1.0, threads=4))
    sim.run(10)
    be_fast_before = sim.history[-1].fast_pages["be"]
    sim.add_tenant(
        WorkloadSpec("ls", n_pages=384, t_miss=0.1, threads=4, sets=((0.5, 0.95),))
    )
    sim.run(40)
    rec = sim.history[-1]
    assert rec.fast_pages["ls"] > 100
    assert rec.fast_pages["be"] < be_fast_before
    assert rec.fmmr_true["ls"] <= 0.15


def test_hot_set_growth_detected_and_served():
    """Paper Fig. 4 event 5: hot set grows 50% -> FMMR spike -> reconverge."""
    mgr = _maxmem(num_pages=1024, fast=320, budget=128)
    sim = ColocationSim(mgr, OPTANE, seed=4)
    sim.add_tenant(
        WorkloadSpec("ls", n_pages=512, t_miss=0.1, threads=4, sets=((0.4, 0.9),))
    )
    sim.add_tenant(WorkloadSpec("be", n_pages=384, t_miss=1.0, threads=2))
    sim.run(30)
    fmmr_before = sim.history[-1].fmmr_true["ls"]
    sim.tenants["ls"].resize_set(0, 0.6)  # +50% hot pages
    sim.run(1)
    spike = max(r.fmmr_true["ls"] for r in sim.history[-1:])
    sim.run(40)
    fmmr_after = sim.history[-1].fmmr_true["ls"]
    assert spike > fmmr_before + 0.02, "growth not visible in FMMR"
    assert fmmr_after <= 0.15, f"did not reconverge: {fmmr_after:.3f}"


def test_baselines_no_qos_interference():
    """AutoNUMA/2LM: BE tenant steals fast memory from the LS tenant."""
    for Backend in (AutoNUMALike, TwoLM):
        be_name = Backend.__name__
        backend = Backend(num_pages=1024, fast_capacity=256)
        sim = ColocationSim(backend, OPTANE, seed=5)
        sim.add_tenant(
            WorkloadSpec("ls", n_pages=384, t_miss=0.1, threads=2, sets=((0.5, 0.9),))
        )
        sim.add_tenant(WorkloadSpec("be", n_pages=512, t_miss=1.0, threads=8))
        sim.run(40)
        rec = sim.history[-1]
        assert rec.fmmr_true["ls"] > 0.15, (
            f"{be_name}: LS unexpectedly met QoS without support"
        )


def test_maxmem_vs_baselines_ls_qos():
    """Colocation: MaxMem meets the LS target where baselines do not."""
    def run(backend):
        sim = ColocationSim(backend, OPTANE, seed=6)
        sim.add_tenant(
            WorkloadSpec("ls", n_pages=384, t_miss=0.1, threads=2, sets=((0.5, 0.9),))
        )
        sim.add_tenant(WorkloadSpec("be", n_pages=512, t_miss=1.0, threads=8))
        sim.run(50)
        return sim.history[-1]

    mm = run(_maxmem(num_pages=1024, fast=256, budget=128))
    an = run(AutoNUMALike(num_pages=1024, fast_capacity=256))
    assert mm.fmmr_true["ls"] < an.fmmr_true["ls"]
    assert mm.p99["ls"] <= an.p99["ls"]


def test_policy_chunk_scan_path_converges_like_single_stepping():
    """policy_chunk > 1 drives the backend through the fused run_epochs scan
    and still converges the hot set into fast memory."""
    def scenario(chunk):
        mgr = _maxmem(num_pages=512, fast=128, budget=64)
        sim = ColocationSim(mgr, OPTANE, seed=11, policy_chunk=chunk)
        spec = WorkloadSpec(
            "gups", n_pages=448, t_miss=0.1, threads=4,
            sets=((1 / 7, 0.6), (2 / 7, 0.3)),
        )
        sim.add_tenant(spec)
        sim.run(40)
        return sim

    single = scenario(1)
    chunked = scenario(8)
    assert len(chunked.history) == 40
    # both paths reach the same qualitative steady state (hot set resident)
    assert chunked.history[-1].fmmr_true["gups"] < 0.45
    assert abs(
        chunked.history[-1].fmmr_true["gups"] - single.history[-1].fmmr_true["gups"]
    ) < 0.15
    # chunk boundaries and events still line up with the epoch counter
    assert [r.epoch for r in chunked.history] == list(range(40))


def test_policy_chunk_respects_events():
    mgr = _maxmem(num_pages=512, fast=128, budget=64)
    sim = ColocationSim(mgr, OPTANE, seed=12, policy_chunk=16)
    sim.add_tenant(
        WorkloadSpec("a", n_pages=256, t_miss=0.5, threads=2, sets=((0.25, 0.9),))
    )
    fired = []
    events = {10: lambda s: fired.append(len(s.history))}
    sim.run(20, events=events)
    assert fired == [10]
    assert len(sim.history) == 20
