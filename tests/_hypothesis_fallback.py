"""Deterministic stand-in for ``hypothesis`` when the package is absent.

The tier-1 suite must collect and run from a clean checkout (no dev extras
installed). Property tests then run against a fixed-seed sweep of drawn
examples instead of hypothesis' adaptive search — strictly weaker shrinking,
same assertions. Install ``requirements-dev.txt`` to get the real engine.

Only the strategy surface this repo uses is implemented:
``st.integers``, ``st.floats``, ``st.lists``, ``st.sampled_from``.
"""
from __future__ import annotations

import functools
import inspect
import random

_DEFAULT_EXAMPLES = 20
_MAX_FALLBACK_EXAMPLES = 25  # keep the no-deps suite fast


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rnd: random.Random):
        return self._draw(rnd)


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
        return _Strategy(
            lambda r: [elements.draw(r) for _ in range(r.randint(min_size, max_size))]
        )

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        pool = list(seq)
        return _Strategy(lambda r: pool[r.randrange(len(pool))])


st = strategies


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    """Records the example budget on the wrapped test (applied above @given)."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(
                wrapper, "_fallback_max_examples",
                getattr(fn, "_fallback_max_examples", _DEFAULT_EXAMPLES),
            )
            rnd = random.Random(0xC0FFEE)
            for _ in range(min(n, _MAX_FALLBACK_EXAMPLES)):
                drawn = {k: s.draw(rnd) for k, s in strats.items()}
                fn(*args, **kwargs, **drawn)

        # hide the drawn parameters from pytest's fixture resolution
        del wrapper.__wrapped__
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(
            parameters=[p for p in sig.parameters.values() if p.name not in strats]
        )
        return wrapper

    return deco
