"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + one decode step on CPU; asserts shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models.model import get_model


def _smoke_batch(cfg, rng, B=2, S=32):
    ks = jax.random.split(rng, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jax.random.normal(
            ks[2], (B, cfg.max_encoder_len, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_smoke(arch):
    cfg = get_config(arch).smoke()
    api = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = api.init(rng)
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))

    loss, metrics = jax.jit(lambda p, b: api.loss(p, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss {loss}"
    assert float(loss) > 0.0
    # sane CE for random init: close to ln(vocab)
    assert float(metrics["ce"]) < np.log(cfg.vocab_size) + 2.0

    # gradients flow and are finite
    g, _ = jax.grad(lambda p: api.loss(p, batch)[0], has_aux=False)(params), None
    leaves = jax.tree.leaves(g)
    assert leaves, "no grads"
    for leaf in leaves:
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float32))), f"{arch}: NaN grad"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_step_smoke(arch):
    cfg = get_config(arch).smoke()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B, max_len = 2, 16
    cache = api.init_cache(B, max_len)
    token = jnp.array([1, 2], jnp.int32)
    step = jax.jit(lambda p, t, c: api.decode(p, t, c))
    logits, cache = step(params, token, cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))
    # second step advances position
    logits2, cache2 = step(params, token, cache)
    assert np.all(np.isfinite(np.asarray(logits2)))
    pos = jax.tree.leaves(cache2)[-1] if not hasattr(cache2, "pos") else cache2.pos
    assert int(cache2.pos) == 2


def test_param_counts_match_analytic():
    """Full-size analytic param counts are in the right ballpark."""
    expect = {
        "yi-6b": (5.5e9, 7.5e9),
        "qwen2.5-32b": (30e9, 36e9),
        "mamba2-130m": (0.10e9, 0.16e9),
        # NOTE: assignment specifies 48L (the hf checkpoint has 27); with the
        # assigned depth total params land at ~29B (active ~4B).
        "moonshot-v1-16b-a3b": (24e9, 32e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_param_pytree_finite(arch):
    cfg = get_config(arch).smoke()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(42))
    for leaf in jax.tree.leaves(params):
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float32)))
