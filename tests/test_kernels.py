"""Pallas kernel sweeps: shapes x dtypes, assert_allclose vs ref.py oracles.

Kernels run in interpret mode (CPU container); the oracle is pure jnp.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # clean checkout: deterministic fallback sweep
    from _hypothesis_fallback import given, settings, st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hot_bins import hot_bins
from repro.kernels.page_copy import page_copy
from repro.kernels.paged_attention import paged_attention

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dt):
    return TOL[dt]


class TestFlashAttention:
    @pytest.mark.parametrize("B,nh,nkv,Sq,Skv,dh", [
        (2, 4, 2, 128, 128, 64),
        (1, 8, 8, 96, 96, 128),   # MHA, non-multiple of block
        (2, 4, 1, 64, 192, 64),   # MQA, Sq < Skv
        (1, 2, 2, 300, 300, 64),  # ragged padding path
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("causal", [True, False])
    def test_sweep(self, B, nh, nkv, Sq, Skv, dh, dtype, causal):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, nh, Sq, dh), dtype)
        k = jax.random.normal(ks[1], (B, nkv, Skv, dh), dtype)
        v = jax.random.normal(ks[2], (B, nkv, Skv, dh), dtype)
        out = flash_attention(q, k, v, causal=causal, q_blk=64, kv_blk=64)
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            atol=_tol(dtype), rtol=_tol(dtype),
        )

    def test_sliding_window(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 4, 256, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.float32)
        out = flash_attention(q, k, v, causal=True, sliding_window=64, q_blk=64, kv_blk=64)
        want = ref.flash_attention_ref(q, k, v, causal=True, sliding_window=64)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)

    def test_block_size_invariance(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (1, 2, 128, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 128, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 128, 64), jnp.float32)
        a = flash_attention(q, k, v, q_blk=32, kv_blk=32)
        b = flash_attention(q, k, v, q_blk=128, kv_blk=64)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


class TestPagedAttention:
    @pytest.mark.parametrize("B,nh,nkv,dh,P,page,n_p", [
        (2, 4, 2, 64, 16, 8, 4),
        (3, 8, 1, 128, 32, 16, 6),
        (1, 4, 4, 64, 8, 8, 2),
        (4, 16, 2, 128, 64, 32, 8),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, B, nh, nkv, dh, P, page, n_p, dtype):
        rng = np.random.default_rng(B * 131 + P)
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        q = jax.random.normal(ks[0], (B, nh, dh), dtype)
        kp = jax.random.normal(ks[1], (P, page, nkv, dh), dtype)
        vp = jax.random.normal(ks[2], (P, page, nkv, dh), dtype)
        tables = np.full((B, n_p), -1, np.int32)
        lens = np.zeros((B,), np.int32)
        for b in range(B):
            used = rng.integers(1, n_p + 1)
            tables[b, :used] = rng.choice(P, used, replace=False)
            lens[b] = rng.integers(1, used * page + 1)
        out = paged_attention(q, kp, vp, jnp.asarray(tables), jnp.asarray(lens))
        want = ref.paged_attention_ref(q, kp, vp, jnp.asarray(tables), jnp.asarray(lens))
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            atol=_tol(dtype), rtol=_tol(dtype),
        )

    def test_single_token_context(self):
        """seq_len=1: only the first slot of the first page is valid."""
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (1, 2, 64), jnp.float32)
        kp = jax.random.normal(ks[1], (4, 8, 2, 64), jnp.float32)
        vp = jax.random.normal(ks[2], (4, 8, 2, 64), jnp.float32)
        tables = jnp.asarray([[2, -1]], jnp.int32)
        lens = jnp.asarray([1], jnp.int32)
        out = paged_attention(q, kp, vp, tables, lens)
        # attention over a single key = that key's value
        np.testing.assert_allclose(
            np.asarray(out[0, 0]), np.asarray(vp[2, 0, 0]), atol=1e-5, rtol=1e-5
        )


class TestHotBins:
    @pytest.mark.parametrize("N,P,tile", [(100, 64, 64), (1000, 512, 128), (257, 130, 64), (64, 4096, 512)])
    def test_sweep(self, N, P, tile):
        rng = np.random.default_rng(N + P)
        ids = rng.integers(-1, P, N).astype(np.int32)
        cin = rng.integers(0, 40, P).astype(np.int32)
        c, b = hot_bins(jnp.asarray(ids), jnp.asarray(cin), tile=tile, n_chunk=128)
        cr, br = ref.hot_bins_ref(jnp.asarray(ids), jnp.asarray(cin), 6)
        assert (np.asarray(c) == np.asarray(cr)).all()
        assert (np.asarray(b) == np.asarray(br)).all()

    def test_interpret_auto_selects_from_backend(self):
        """interpret=None compiles on TPU and interprets elsewhere; the
        result must be identical either way."""
        from repro.kernels import hot_bins as hb

        expect = jax.default_backend() != "tpu"
        assert hb._default_interpret() == expect
        rng = np.random.default_rng(0)
        ids = jnp.asarray(rng.integers(-1, 130, 257), jnp.int32)
        cin = jnp.asarray(rng.integers(0, 40, 130), jnp.int32)
        c_auto, b_auto = hot_bins(ids, cin, tile=64, n_chunk=128)
        c_exp, b_exp = hot_bins(ids, cin, tile=64, n_chunk=128, interpret=expect)
        assert (np.asarray(c_auto) == np.asarray(c_exp)).all()
        assert (np.asarray(b_auto) == np.asarray(b_exp)).all()

    @pytest.mark.parametrize("N,P,tile", [(333, 130, 64), (1023, 777, 256), (65, 513, 512)])
    def test_bincount_parity_non_multiple_of_tile(self, N, P, tile):
        """Exact jnp.bincount parity where neither the page count nor the
        sample count is a multiple of the kernel tiling (padding paths)."""
        rng = np.random.default_rng(N * 31 + P)
        ids = rng.integers(-1, P, N).astype(np.int32)
        cin = rng.integers(0, 40, P).astype(np.int32)
        c, b = hot_bins(jnp.asarray(ids), jnp.asarray(cin), tile=tile, n_chunk=128)
        valid = jnp.asarray(ids[ids >= 0])
        expect = jnp.asarray(cin) + jnp.bincount(valid, length=P).astype(jnp.int32)
        assert (np.asarray(c) == np.asarray(expect)).all()
        # fused bin ids: clip(floor(log2(count)) + 1, 0, num_bins-1)
        ce = np.asarray(expect)
        fl = np.where(ce > 0, np.floor(np.log2(np.maximum(ce, 1))).astype(np.int32), -1)
        assert (np.asarray(b) == np.clip(fl + 1, 0, 5)).all()

    @settings(max_examples=20, deadline=None)
    @given(
        ids=st.lists(st.integers(-1, 63), min_size=1, max_size=200),
        seed=st.integers(0, 100),
    )
    def test_property_matches_numpy_bincount(self, ids, seed):
        P = 64
        rng = np.random.default_rng(seed)
        cin = rng.integers(0, 10, P).astype(np.int32)
        ids_np = np.asarray(ids, np.int32)
        c, _ = hot_bins(jnp.asarray(ids_np), jnp.asarray(cin), tile=64, n_chunk=64)
        expect = cin + np.bincount(ids_np[ids_np >= 0], minlength=P).astype(np.int32)
        assert (np.asarray(c) == expect).all()


class TestPageCopy:
    @pytest.mark.parametrize("Ps,Pd,E,M", [(16, 16, 128, 5), (8, 32, 256, 8), (4, 4, 64, 1)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
    def test_sweep(self, Ps, Pd, E, M, dtype):
        rng = np.random.default_rng(Ps * 7 + M)
        if dtype == jnp.int32:
            src = jnp.asarray(rng.integers(0, 100, (Ps, E)), dtype)
            dst = jnp.asarray(rng.integers(0, 100, (Pd, E)), dtype)
        else:
            src = jnp.asarray(rng.normal(size=(Ps, E)), dtype)
            dst = jnp.asarray(rng.normal(size=(Pd, E)), dtype)
        sid = jnp.asarray(rng.choice(Ps, M, replace=True), jnp.int32)
        did = jnp.asarray(rng.choice(Pd - 1, M, replace=False), jnp.int32)
        want = ref.page_copy_ref(src, dst, sid, did)
        out = page_copy(src, jnp.copy(dst), sid, did)
        assert (np.asarray(out) == np.asarray(want)).all()

    def test_untouched_rows_preserved(self):
        src = jnp.ones((4, 32), jnp.float32)
        dst = jnp.zeros((8, 32), jnp.float32)
        out = page_copy(src, jnp.copy(dst), jnp.asarray([1], jnp.int32), jnp.asarray([3], jnp.int32))
        assert float(out[3].sum()) == 32.0
        assert float(out.sum()) == 32.0  # only one row written

    @pytest.mark.parametrize("Ps,Pd,E,M", [
        (7, 13, 100, 3),    # nothing a multiple of any tile
        (5, 9, 257, 7),     # odd row width beyond one lane tile
        (3, 3, 33, 2),      # tiny pools, narrow rows
        (17, 31, 384, 17),  # M > Pd/2, E a non-128 multiple
    ])
    def test_non_multiple_of_tile_sizes(self, Ps, Pd, E, M):
        """Interpret-mode parity at shapes where neither the pool heights
        nor the row width align with TPU tiling — the data plane uses
        whatever row_elems the caller configured."""
        rng = np.random.default_rng(Ps * 101 + E)
        src = jnp.asarray(rng.normal(size=(Ps, E)), jnp.float32)
        dst = jnp.asarray(rng.normal(size=(Pd, E)), jnp.float32)
        sid = jnp.asarray(rng.choice(Ps, M, replace=True), jnp.int32)
        did = jnp.asarray(rng.choice(Pd, M, replace=False), jnp.int32)
        want = ref.page_copy_ref(src, dst, sid, did)
        out = page_copy(src, jnp.copy(dst), sid, did)
        assert (np.asarray(out) == np.asarray(want)).all()

    def test_trash_row_padding_contract(self):
        """Fixed-size plans pad with the reserved LAST destination row: the
        padded entries must leave every real row untouched, no matter what
        source row the padding names."""
        rng = np.random.default_rng(0)
        src = jnp.asarray(rng.normal(size=(6, 64)), jnp.float32)
        dst = jnp.asarray(rng.normal(size=(10, 64)), jnp.float32)
        trash = 9
        # 2 real moves + 3 pad entries aimed at the trash row
        sid = jnp.asarray([2, 5, 0, 3, 1], jnp.int32)
        did = jnp.asarray([1, 4, trash, trash, trash], jnp.int32)
        out = np.asarray(page_copy(src, jnp.copy(dst), sid, did))
        src_np, dst_np = np.asarray(src), np.asarray(dst)
        assert (out[1] == src_np[2]).all()
        assert (out[4] == src_np[5]).all()
        keep = [0, 2, 3, 5, 6, 7, 8]
        assert (out[keep] == dst_np[keep]).all()
        # trash row holds the LAST padded source (sequential grid) — its
        # content is unspecified by the contract, only its isolation matters
        assert (out[trash] == src_np[1]).all()


class TestPageMove:
    def test_intra_pool_moves_match_ref(self):
        from repro.kernels.page_copy import page_move

        rng = np.random.default_rng(5)
        pool = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
        sid = jnp.asarray([0, 1, 2], jnp.int32)
        did = jnp.asarray([8, 9, 10], jnp.int32)
        want = ref.page_move_ref(pool, sid, did)
        out = page_move(jnp.copy(pool), sid, did)
        assert (np.asarray(out) == np.asarray(want)).all()

    def test_write_after_read_is_safe(self):
        """A plan may WRITE a row that an earlier step READ (slot reuse)."""
        from repro.kernels.page_copy import page_move

        pool = jnp.asarray(np.arange(8 * 4).reshape(8, 4), jnp.float32)
        # demote: row1 -> row6 (reads 1), promote: row5 -> row1 (writes 1)
        sid = jnp.asarray([1, 5], jnp.int32)
        did = jnp.asarray([6, 1], jnp.int32)
        out = page_move(jnp.copy(pool), sid, did)
        assert (np.asarray(out[6]) == np.asarray(pool[1])).all()
        assert (np.asarray(out[1]) == np.asarray(pool[5])).all()

    @pytest.mark.parametrize("Pr,E,M", [(11, 100, 4), (9, 257, 5), (5, 33, 3)])
    def test_non_multiple_of_tile_sizes(self, Pr, E, M):
        from repro.kernels.page_copy import page_move

        rng = np.random.default_rng(Pr * 7 + E)
        pool = jnp.asarray(rng.normal(size=(Pr, E)), jnp.float32)
        sid = jnp.asarray(rng.choice(Pr - 1, M, replace=False), jnp.int32)
        did = jnp.asarray(
            rng.permutation(Pr - 1)[:M], jnp.int32
        )
        want = ref.page_move_ref(pool, sid, did)
        out = page_move(jnp.copy(pool), sid, did)
        assert (np.asarray(out) == np.asarray(want)).all()

    def test_trash_row_padding_contract(self):
        """The data plane pads intra-pool plans with trash->trash self-copy
        entries; real rows must be untouched by the padding."""
        from repro.kernels.page_copy import page_move

        rng = np.random.default_rng(1)
        pool = jnp.asarray(rng.normal(size=(8, 48)), jnp.float32)
        trash = 7
        sid = jnp.asarray([0, trash, trash, trash], jnp.int32)
        did = jnp.asarray([3, trash, trash, trash], jnp.int32)
        out = np.asarray(page_move(jnp.copy(pool), sid, did))
        pool_np = np.asarray(pool)
        assert (out[3] == pool_np[0]).all()
        keep = [0, 1, 2, 4, 5, 6, trash]
        assert (out[keep] == pool_np[keep]).all()
