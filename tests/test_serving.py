"""Serving engine integration: tiered paged KV + MaxMem QoS end to end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.manager import CentralManager
from repro.core.types import TIER_FAST
from repro.kvcache.paged import TieredPagedKV
from repro.models.model import get_model
from repro.serving.engine import ServingEngine
from repro.serving.paged_model import PagedPools, paged_decode_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("yi-6b").smoke()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, params


def _mk_engine(cfg, params, n_fast=8, n_slow=56, page=4, **kw):
    manager = CentralManager(
        num_pages=n_fast + n_slow,
        fast_capacity=n_fast,
        migration_budget=kw.pop("budget", 8),
        max_tenants=4,
        sample_period=1,
        exact_sampling=True,
    )
    kv = TieredPagedKV(cfg, n_fast, n_slow, page_tokens=page)
    return ServingEngine(
        cfg, params, manager, kv,
        max_batch=kw.pop("max_batch", 2),
        pages_per_seq=kw.pop("pages_per_seq", 8),
        quest_pages=kw.pop("quest_pages", 3),
        epoch_steps=kw.pop("epoch_steps", 4),
    )


class TestPagedDecodeEquivalence:
    def test_paged_matches_dense_decode(self, setup):
        """With quest_pages >= all pages, paged decode == dense decode."""
        cfg, params = setup
        api = get_model(cfg)
        B, S_prompt, page, n_p = 1, 6, 4, 4
        prompt = jnp.asarray(np.arange(1, S_prompt + 1)[None, :], jnp.int32)

        # dense path
        logits_d, cache = api.prefill(params, prompt, S_prompt + 4)
        tok = jnp.argmax(logits_d[:, -1], axis=-1).astype(jnp.int32)
        dense_logits, cache = api.decode(params, tok, cache)

        # paged path
        kv = TieredPagedKV(cfg, n_fast_slots=8, n_slow_slots=8, page_tokens=page)
        k, v = cache.k[:, :, : S_prompt], cache.v[:, :, : S_prompt]
        pages = np.array([[0, 1, 2, 3]], np.int32)
        kv.write_tokens((k, v), pages, start_pos=0)
        slot_tables = kv.slot_of[pages].astype(np.int32)
        logits_p, pools, counts = paged_decode_step(
            params,
            tok,
            jnp.asarray([S_prompt], jnp.int32),
            jnp.asarray(slot_tables),
            jnp.asarray(pages),
            jnp.asarray([True]),
            PagedPools(kv.k_pool, kv.v_pool, kv.k_max, kv.k_min),
            num_logical_pages=16,
            cfg=cfg,
            quest_pages=n_p,  # select ALL pages -> exact attention
        )
        np.testing.assert_allclose(
            np.asarray(dense_logits), np.asarray(logits_p), atol=2e-3, rtol=2e-3
        )
        assert counts.sum() > 0  # access stream emitted


class TestEngine:
    def test_requests_complete_and_pages_freed(self, setup):
        cfg, params = setup
        eng = _mk_engine(cfg, params)
        eng.add_tenant("a", t_miss=0.5)
        rng = np.random.default_rng(0)
        for _ in range(3):
            eng.submit("a", rng.integers(1, cfg.vocab_size, 5), max_new_tokens=6)
        eng.run(40)
        assert len(eng.finished) == 3
        for r in eng.finished:
            assert len(r.generated) >= 6
        # all pages freed
        assert (np.asarray(eng.manager.pages.owner) == -1).all()

    def test_accesses_reach_manager_and_epochs_fire(self, setup):
        cfg, params = setup
        eng = _mk_engine(cfg, params, epoch_steps=2)
        eng.add_tenant("a", t_miss=0.2)
        eng.submit("a", np.arange(1, 9), max_new_tokens=12)
        eng.run(20)
        assert len(eng._epoch_log) >= 5
        assert any(e["fmmr"]["a"] > 0 or e["moved"] >= 0 for e in eng._epoch_log)

    def test_hot_pages_migrate_to_fast_tier(self, setup):
        """Quest-skewed access stream drives hot pages into the fast tier."""
        cfg, params = setup
        eng = _mk_engine(cfg, params, n_fast=4, n_slow=60, page=4,
                         pages_per_seq=16, quest_pages=2, epoch_steps=2, budget=8)
        eng.add_tenant("ls", t_miss=0.1)
        eng.submit("ls", np.arange(1, 25), max_new_tokens=30)  # 24-token prompt
        eng.run(34)
        # the engine's selected (hot) pages should be fast-resident more often
        # than cold pages by the end
        log = eng._epoch_log
        assert eng._migrated_pages > 0, "no migrations happened"

    def test_two_tenant_qos_preference(self, setup):
        """LS tenant's touched pages get fast residency over BE tenant's."""
        cfg, params = setup
        eng = _mk_engine(cfg, params, n_fast=6, n_slow=58, page=4,
                         max_batch=2, pages_per_seq=12, quest_pages=2,
                         epoch_steps=2, budget=12)
        eng.add_tenant("ls", t_miss=0.1)
        eng.add_tenant("be", t_miss=1.0)
        rng = np.random.default_rng(1)
        eng.submit("be", rng.integers(1, cfg.vocab_size, 16), max_new_tokens=40)
        eng.submit("ls", rng.integers(1, cfg.vocab_size, 16), max_new_tokens=40)
        eng.run(44)
        owner = np.asarray(eng.manager.pages.owner)
        tier = np.asarray(eng.manager.pages.tier)
        h_ls = int(eng.tenant_handles["ls"])
        h_be = int(eng.tenant_handles["be"])
        ls_fast = int(((owner == h_ls) & (tier == TIER_FAST)).sum())
        be_fast = int(((owner == h_be) & (tier == TIER_FAST)).sum())
        assert ls_fast >= be_fast, f"LS {ls_fast} < BE {be_fast} fast pages"

    def test_slot_mapping_stays_permutation(self, setup):
        cfg, params = setup
        eng = _mk_engine(cfg, params, epoch_steps=2)
        eng.add_tenant("a", t_miss=0.1)
        eng.submit("a", np.arange(1, 13), max_new_tokens=20)
        for _ in range(24):
            eng.step()
            s = np.sort(eng.kv.slot_of)
            assert (s == np.arange(eng.kv.n_slots)).all(), "slot_of not a permutation"
