"""Serving engine integration: tiered paged KV + MaxMem QoS end to end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.manager import CentralManager
from repro.core.types import TIER_FAST
from repro.kvcache.paged import TieredPagedKV
from repro.models.model import get_model
from repro.serving.driver import OpenLoopDriver, TenantSpec
from repro.serving.engine import ServingEngine
from repro.serving.paged_model import PagedPools, paged_decode_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("yi-6b").smoke()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, params


def _mk_engine(cfg, params, n_fast=8, n_slow=56, page=4, **kw):
    manager = CentralManager(
        num_pages=n_fast + n_slow,
        fast_capacity=n_fast,
        migration_budget=kw.pop("budget", 8),
        max_tenants=4,
        sample_period=1,
        exact_sampling=True,
        queue_size=kw.pop("queue_size", 0),
        migration_bandwidth=kw.pop("bandwidth", None),
        migration_latency=kw.pop("latency", 0),
    )
    kv = TieredPagedKV(cfg, n_fast, n_slow, page_tokens=page)
    return ServingEngine(
        cfg, params, manager, kv,
        max_batch=kw.pop("max_batch", 2),
        pages_per_seq=kw.pop("pages_per_seq", 8),
        quest_pages=kw.pop("quest_pages", 3),
        epoch_steps=kw.pop("epoch_steps", 4),
    )


class TestPagedDecodeEquivalence:
    def test_paged_matches_dense_decode(self, setup):
        """With quest_pages >= all pages, paged decode == dense decode."""
        cfg, params = setup
        api = get_model(cfg)
        B, S_prompt, page, n_p = 1, 6, 4, 4
        prompt = jnp.asarray(np.arange(1, S_prompt + 1)[None, :], jnp.int32)

        # dense path
        logits_d, cache = api.prefill(params, prompt, S_prompt + 4)
        tok = jnp.argmax(logits_d[:, -1], axis=-1).astype(jnp.int32)
        dense_logits, cache = api.decode(params, tok, cache)

        # paged path
        kv = TieredPagedKV(cfg, n_fast_slots=8, n_slow_slots=8, page_tokens=page)
        k, v = cache.k[:, :, : S_prompt], cache.v[:, :, : S_prompt]
        pages = np.array([[0, 1, 2, 3]], np.int32)
        kv.write_tokens((k, v), pages, start_pos=0)
        slot_tables = kv.slot_of[pages].astype(np.int32)
        logits_p, pools, counts = paged_decode_step(
            params,
            tok,
            jnp.asarray([S_prompt], jnp.int32),
            jnp.asarray(slot_tables),
            jnp.asarray(pages),
            jnp.asarray([True]),
            PagedPools(kv.k_pool, kv.v_pool, kv.k_max, kv.k_min),
            num_logical_pages=16,
            cfg=cfg,
            quest_pages=n_p,  # select ALL pages -> exact attention
        )
        np.testing.assert_allclose(
            np.asarray(dense_logits), np.asarray(logits_p), atol=2e-3, rtol=2e-3
        )
        assert counts.sum() > 0  # access stream emitted


class TestEngine:
    def test_requests_complete_and_pages_freed(self, setup):
        cfg, params = setup
        eng = _mk_engine(cfg, params)
        eng.add_tenant("a", t_miss=0.5)
        rng = np.random.default_rng(0)
        for _ in range(3):
            eng.submit("a", rng.integers(1, cfg.vocab_size, 5), max_new_tokens=6)
        eng.run(40)
        assert len(eng.finished) == 3
        for r in eng.finished:
            assert len(r.generated) >= 6
        # all pages freed
        assert (np.asarray(eng.manager.pages.owner) == -1).all()

    def test_accesses_reach_manager_and_epochs_fire(self, setup):
        cfg, params = setup
        eng = _mk_engine(cfg, params, epoch_steps=2)
        eng.add_tenant("a", t_miss=0.2)
        eng.submit("a", np.arange(1, 9), max_new_tokens=12)
        eng.run(20)
        assert len(eng._epoch_log) >= 5
        assert any(e["fmmr"]["a"] > 0 or e["moved"] >= 0 for e in eng._epoch_log)

    def test_hot_pages_migrate_to_fast_tier(self, setup):
        """Quest-skewed access stream drives hot pages into the fast tier."""
        cfg, params = setup
        eng = _mk_engine(cfg, params, n_fast=4, n_slow=60, page=4,
                         pages_per_seq=16, quest_pages=2, epoch_steps=2, budget=8)
        eng.add_tenant("ls", t_miss=0.1)
        eng.submit("ls", np.arange(1, 25), max_new_tokens=30)  # 24-token prompt
        eng.run(34)
        # the engine's selected (hot) pages should be fast-resident more often
        # than cold pages by the end
        log = eng._epoch_log
        assert eng._migrated_pages > 0, "no migrations happened"

    def test_two_tenant_qos_preference(self, setup):
        """LS tenant's touched pages get fast residency over BE tenant's."""
        cfg, params = setup
        eng = _mk_engine(cfg, params, n_fast=6, n_slow=58, page=4,
                         max_batch=2, pages_per_seq=12, quest_pages=2,
                         epoch_steps=2, budget=12)
        eng.add_tenant("ls", t_miss=0.1)
        eng.add_tenant("be", t_miss=1.0)
        rng = np.random.default_rng(1)
        eng.submit("be", rng.integers(1, cfg.vocab_size, 16), max_new_tokens=40)
        eng.submit("ls", rng.integers(1, cfg.vocab_size, 16), max_new_tokens=40)
        eng.run(44)
        owner = np.asarray(eng.manager.pages.owner)
        tier = np.asarray(eng.manager.pages.tier)
        h_ls = int(eng.tenant_handles["ls"])
        h_be = int(eng.tenant_handles["be"])
        ls_fast = int(((owner == h_ls) & (tier == TIER_FAST)).sum())
        be_fast = int(((owner == h_be) & (tier == TIER_FAST)).sum())
        assert ls_fast >= be_fast, f"LS {ls_fast} < BE {be_fast} fast pages"

    def test_slot_mapping_stays_permutation(self, setup):
        cfg, params = setup
        eng = _mk_engine(cfg, params, epoch_steps=2)
        eng.add_tenant("a", t_miss=0.1)
        eng.submit("a", np.arange(1, 13), max_new_tokens=20)
        for _ in range(24):
            eng.step()
            s = np.sort(eng.kv.slot_of)
            assert (s == np.arange(eng.kv.n_slots)).all(), "slot_of not a permutation"


class TestFreeReuseInvariant:
    """The stale-page Quest corruption bugfix: freed pages leave zeroed
    slots and reset (±inf) summaries, and a reused cache decodes
    bit-identically to a fresh one."""

    def test_free_scrubs_slots_and_summaries(self, setup):
        cfg, params = setup
        eng = _mk_engine(cfg, params, n_fast=4, n_slow=28, page=4,
                         epoch_steps=2, quest_pages=2)
        eng.add_tenant("a", t_miss=0.2)
        eng.submit("a", np.arange(1, 17), max_new_tokens=12)
        eng.run(20)
        assert len(eng.finished) == 1
        assert eng._migrated_pages > 0, "want migrations before the frees"
        # every slot is back to the free state: the request's own frees plus
        # the migrate() re-scrub of swapped-out rows
        assert (np.asarray(eng.kv.k_pool) == 0).all()
        assert (np.asarray(eng.kv.v_pool) == 0).all()
        assert np.isneginf(np.asarray(eng.kv.k_max)).all()
        assert np.isposinf(np.asarray(eng.kv.k_min)).all()

    def test_page_reuse_decode_bit_identical_to_fresh_cache(self, setup):
        cfg, params = setup
        kw = dict(n_fast=4, n_slow=28, page=4, epoch_steps=2,
                  quest_pages=2, budget=6)
        reused = _mk_engine(cfg, params, **kw)
        reused.add_tenant("a", t_miss=0.2)
        # first occupant: dirty the pool + summaries, drive migrations, free
        rng = np.random.default_rng(7)
        reused.submit("a", rng.integers(1, cfg.vocab_size, 16), max_new_tokens=14)
        reused.run(22)
        assert len(reused.finished) == 1 and reused._migrated_pages > 0

        fresh = _mk_engine(cfg, params, **kw)
        fresh.add_tenant("a", t_miss=0.2)
        prompt2 = rng.integers(1, cfg.vocab_size, 12)
        reused.submit("a", prompt2, max_new_tokens=10)
        fresh.submit("a", prompt2, max_new_tokens=10)
        for _ in range(14):
            reused.step()
            fresh.step()
            if reused.last_logits is not None or fresh.last_logits is not None:
                np.testing.assert_array_equal(
                    reused.last_logits, fresh.last_logits,
                    err_msg="reused-page decode diverged from a fresh cache",
                )
        assert [r.generated for r in reused.finished[1:]] == [
            r.generated for r in fresh.finished
        ]


class TestAdmissionValidation:
    def test_submit_rejects_oversized_prompt(self, setup):
        cfg, params = setup
        eng = _mk_engine(cfg, params, pages_per_seq=4, page=4)
        eng.add_tenant("a", t_miss=0.5)
        with pytest.raises(ValueError, match="page table"):
            eng.submit("a", np.arange(1, 18), max_new_tokens=4)  # 17 > 16

    def test_boundary_prompt_exactly_fills_table(self, setup):
        """S == pages_per_seq * page: admits, prefills, finishes cleanly
        (no numpy broadcast crash, no decode room -> prefill token only)."""
        cfg, params = setup
        eng = _mk_engine(cfg, params, pages_per_seq=4, page=4)
        eng.add_tenant("a", t_miss=0.5)
        eng.submit("a", np.arange(1, 17), max_new_tokens=8)  # S = 16
        eng.run(4)
        assert len(eng.finished) == 1
        assert len(eng.finished[0].generated) >= 1
        assert (np.asarray(eng.manager.pages.owner) == -1).all()

    def test_backpressure_skips_head_of_line(self, setup):
        """A too-big-for-now request must not block a small one behind it."""
        cfg, params = setup
        eng = _mk_engine(cfg, params, n_fast=2, n_slow=6, page=4,
                         pages_per_seq=8, epoch_steps=64)
        eng.add_tenant("a", t_miss=0.5)
        big = eng.submit("a", np.arange(1, 25), max_new_tokens=6)  # 6 pages
        eng.step()  # big admitted: 6 of 8 pages used
        big2 = eng.submit("a", np.arange(1, 25), max_new_tokens=6)  # blocked
        small = eng.submit("a", np.arange(1, 5), max_new_tokens=4)  # 1 page
        eng.step()
        admitted = {
            r.rid for r in list(eng.lanes) + eng.finished
            if r is not None and r.admit_step >= 0
        }
        assert big in admitted
        assert small in admitted, "small request head-of-line blocked"
        assert big2 not in admitted, "big2 should be backpressured"
        assert eng.admission_blocked > 0
        eng.run(30)
        done = {r.rid for r in eng.finished}
        assert {big, big2, small} <= done, "blocked request starved"


class TestQueueModeEngine:
    def test_queue_mode_parity_with_instant(self, setup):
        """bw=unlimited / latency=0 queue mode is bit-identical to the
        instant-apply engine: same tokens, latencies, placements, moves."""
        cfg, params = setup
        kw = dict(n_fast=4, n_slow=28, page=4, epoch_steps=2,
                  quest_pages=2, budget=6, max_batch=2)
        instant = _mk_engine(cfg, params, **kw)
        queued = _mk_engine(cfg, params, queue_size=16, **kw)
        assert queued.manager.queue_size > 0
        rng = np.random.default_rng(3)
        prompts = [rng.integers(1, cfg.vocab_size, 12) for _ in range(3)]
        for eng in (instant, queued):
            eng.add_tenant("ls", t_miss=0.1)
            eng.add_tenant("be", t_miss=1.0)
            eng.submit("be", prompts[0], max_new_tokens=16)
            eng.submit("ls", prompts[1], max_new_tokens=16)
            eng.submit("ls", prompts[2], max_new_tokens=8)
            eng.run(30)
        assert instant._migrated_pages > 0
        assert instant._migrated_pages == queued._migrated_pages
        assert instant._latencies == queued._latencies
        np.testing.assert_array_equal(instant.manager.tiers(),
                                      queued.manager.tiers())
        assert [r.generated for r in instant.finished] == [
            r.generated for r in queued.finished
        ]

    def test_queue_mode_bounded_bandwidth_commits_lag_selections(self, setup):
        """With a finite drain the engine commits at most bw pages per epoch
        and the queue carries the rest forward."""
        cfg, params = setup
        eng = _mk_engine(cfg, params, n_fast=4, n_slow=60, page=4,
                         pages_per_seq=16, quest_pages=2, epoch_steps=2,
                         budget=8, queue_size=16, bandwidth=2)
        eng.add_tenant("ls", t_miss=0.1)
        eng.submit("ls", np.arange(1, 25), max_new_tokens=30)
        eng.run(34)
        assert eng._migrated_pages > 0
        per_epoch = [e["moved"] for e in eng._epoch_log]
        assert max(per_epoch) <= 2, f"drain exceeded bandwidth: {per_epoch}"
        assert any(e["queue_depth"] > 0 for e in eng._epoch_log), (
            "bounded drain never left selections in flight"
        )
        c = eng.manager.queue_counters()
        assert c["enqueued"] == (c["drained"] + c["cancelled"]
                                 + c["dropped"] + c["depth"])

    def test_migration_preserves_kv_bytes(self, setup):
        """Data integrity: migrating pages moves their exact bytes."""
        cfg, params = setup
        n_fast, n_slow, page = 4, 12, 4
        manager = CentralManager(
            num_pages=n_fast + n_slow, fast_capacity=n_fast,
            migration_budget=6, max_tenants=2, sample_period=1,
            exact_sampling=True,
        )
        kv = TieredPagedKV(cfg, n_fast, n_slow, page_tokens=page)
        h = manager.register(t_miss=0.1)
        pages = manager.allocate(h, 8)
        L, nkv, dh = cfg.num_layers, cfg.num_kv_heads, cfg.d_head
        rng = np.random.default_rng(0)
        k = rng.normal(size=(L, 1, 8 * page, nkv, dh)).astype(np.float32)
        v = rng.normal(size=(L, 1, 8 * page, nkv, dh)).astype(np.float32)
        kv.write_tokens((jnp.asarray(k), jnp.asarray(v)),
                        pages[None, :].astype(np.int32), start_pos=0)
        before = {int(p): kv.read_page(p) for p in pages}
        # make the slow pages hot so the policy promotes (and demotes)
        counts = np.zeros(manager.num_pages, np.int64)
        counts[pages[n_fast:]] = 50
        counts[pages[:n_fast]] = 1
        moved = 0
        for _ in range(4):
            manager.record_access(counts)
            res = manager.run_epoch()
            moved += kv.migrate(res.plan, manager)
        assert moved > 0, "no migration exercised"
        for p in pages:
            after_k, after_v = kv.read_page(p)
            np.testing.assert_array_equal(before[int(p)][0], after_k)
            np.testing.assert_array_equal(before[int(p)][1], after_v)


class TestOpenLoopDriver:
    def test_poisson_arrivals_and_backpressure_telemetry(self, setup):
        cfg, params = setup
        eng = _mk_engine(cfg, params, n_fast=4, n_slow=28, page=4,
                         max_batch=2, epoch_steps=4)
        drv = OpenLoopDriver(
            eng,
            [TenantSpec("ls", t_miss=0.1, arrival_rate=0.2,
                        prompt_tokens=8, max_new_tokens=6),
             TenantSpec("be", t_miss=1.0, arrival_rate=0.4,
                        prompt_tokens=8, max_new_tokens=8)],
            seed=5,
        )
        rep = drv.run(40)
        assert rep["ls"]["submitted"] > 0 and rep["be"]["submitted"] > 0
        assert rep["ls"]["completed"] + rep["be"]["completed"] > 0
        assert rep["_engine"]["steps"] == 40
        total = sum(rep[t]["generated_tokens"] for t in ("ls", "be"))
        assert total > 0
