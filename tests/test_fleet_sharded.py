"""Sharded fleet executor: device-partition parity, padding, dirty-tracking.

The sharded entry point's contract (DESIGN.md §6) extends the fleet's: the
machine axis may be partitioned over any number of XLA devices — with K
padded up to a shard multiple by inert machines — and every per-machine row
stays BIT-IDENTICAL to the single-device vmap fleet and to running each
machine alone. The suite runs at whatever device count the host exposes
(``jax.local_device_count()``); the CI ``device_count=4`` leg re-runs it
with real logical sharding via ``--xla_force_host_platform_device_count``.
The padding contract is exercised at every device count through the
``pad_to`` testing hook.

Dirty-tracking contract: a dispatch with no intervening control-plane
operation re-uploads ZERO machine state (no restack, no OwnerSegments
rebuild, no host->device transfer at all when the backlog path is used).
"""
from __future__ import annotations

import json
import os
import sys

import numpy as np
import jax
import pytest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from repro.core.fleet import FleetManager
from repro.core.manager import CentralManager

import golden_regen


def _mk_manager(seed, budget, queue_size=0, bandwidth=None, latency=0,
                num_pages=1024, fast=256, max_tenants=8, sample_period=100,
                exact_sampling=False):
    kw = dict(
        num_pages=num_pages, fast_capacity=fast, migration_budget=budget,
        max_tenants=max_tenants, sample_period=sample_period, seed=seed,
        queue_size=queue_size, migration_latency=latency,
        exact_sampling=exact_sampling,
    )
    if bandwidth is not None:
        kw["migration_bandwidth"] = bandwidth
    m = CentralManager(**kw)
    hs = []
    for t_miss, n in ((0.1, 300), (0.5, 300), (1.0, 200)):
        h = m.register(t_miss)
        m.allocate(h, n)
        hs.append(h)
    return m, hs


def _configs(queue=False, n=3):
    """n machines (deliberately NOT a multiple of common device counts)
    with heterogeneous traced knobs."""
    if queue:
        return [
            dict(seed=s, budget=32 + 16 * s, queue_size=128,
                 bandwidth=8 + 8 * s, latency=s % 2)
            for s in range(n)
        ]
    return [dict(seed=s, budget=32 + 16 * s) for s in range(n)]


def _assert_machine_equal(fleet_m: CentralManager, solo: CentralManager):
    np.testing.assert_array_equal(fleet_m.tiers(), solo.tiers())
    np.testing.assert_array_equal(fleet_m.owners(), solo.owners())
    np.testing.assert_array_equal(
        np.asarray(fleet_m.tenants.a_miss), np.asarray(solo.tenants.a_miss)
    )


class TestShardedParity:
    @pytest.mark.parametrize("queue", [False, True], ids=["instant", "queue"])
    def test_sharded_matches_vmap_and_solo(self, queue):
        """devices=all (sharded when >1, padded) == devices=1 (plain vmap)
        == solo run_epochs, bitwise, for a K no device count divides."""
        cfgs = _configs(queue)
        K, E, P = len(cfgs), 6, 1024
        rng = np.random.default_rng(0)
        counts = rng.poisson(4, (K, E, P)).astype(np.int64)

        sharded = FleetManager([_mk_manager(**c)[0] for c in cfgs],
                               devices=None, pad_to=4)
        vmapped = FleetManager([_mk_manager(**c)[0] for c in cfgs], devices=1)
        res_s = sharded.run_epochs(E, counts=counts, collect_plans=True)
        res_v = vmapped.run_epochs(E, counts=counts, collect_plans=True)

        assert res_s.num_machines == K  # padding rows are stripped
        for la, lb in zip(jax.tree.leaves(res_s.stats), jax.tree.leaves(res_v.stats)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        np.testing.assert_array_equal(res_s.flags, res_v.flags)
        for la, lb in zip(jax.tree.leaves(res_s.plans), jax.tree.leaves(res_v.plans)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        for m, c in enumerate(cfgs):
            solo, _ = _mk_manager(**c)
            solo.run_epochs(E, counts=counts[m], collect_plans=True)
            _assert_machine_equal(sharded.machines[m], solo)
            _assert_machine_equal(vmapped.machines[m], solo)
            if queue:
                assert sharded.machines[m].queue_counters() == solo.queue_counters()

    def test_padding_machines_stay_inert_across_churn(self):
        """Two dispatches with mid-sweep churn between them: the inert pad
        rows must never bleed into real machines' results."""
        cfgs = _configs(queue=True)
        K, E, P = len(cfgs), 4, 1024
        rng = np.random.default_rng(2)
        c1 = rng.poisson(4, (K, E, P)).astype(np.int64)
        c2 = rng.poisson(6, (K, E, P)).astype(np.int64)

        fleet_ms, fleet_hs = zip(*[_mk_manager(**c) for c in cfgs])
        solo_ms, solo_hs = zip(*[_mk_manager(**c) for c in cfgs])
        fleet = FleetManager(list(fleet_ms), devices=None, pad_to=4)
        assert fleet.num_padded % 4 == 0 and fleet.num_padded > K

        def churn(m, hs):
            owned = np.flatnonzero(np.asarray(m.owners()) == int(hs[1]))
            m.free(hs[1], owned)
            m.unregister(hs[1])
            h = m.register(0.3)
            m.allocate(h, 64)

        fleet.run_epochs(E, counts=c1)
        for m, hs in zip(fleet_ms, fleet_hs):
            churn(m, hs)
        fleet.run_epochs(E, counts=c2)

        for i, (m, hs) in enumerate(zip(solo_ms, solo_hs)):
            m.run_epochs(E, counts=c1[i])
            churn(m, hs)
            m.run_epochs(E, counts=c2[i])
            _assert_machine_equal(fleet_ms[i], m)
            qc = fleet_ms[i].queue_counters()
            assert qc["enqueued"] == (
                qc["drained"] + qc["cancelled"] + qc["dropped"] + qc["depth"]
            )

    def test_device_count_validation(self):
        ms = [_mk_manager(**c)[0] for c in _configs()]
        with pytest.raises(AssertionError):
            FleetManager(ms, devices=jax.local_device_count() + 1)


class TestDirtyTracking:
    def test_noop_dispatch_zero_state_uploads(self):
        """A dispatch with no intervening control-plane op must reuse the
        cached stacked state: zero machines restacked, zero OwnerSegments
        rebuilds — and, on the backlog path, zero host->device transfers
        at all (locked with jax's transfer guard)."""
        fleet = FleetManager([_mk_manager(**c)[0] for c in _configs()])
        P = fleet.num_pages
        counts = np.random.default_rng(0).poisson(
            4, (len(fleet), P)).astype(np.int64)
        fleet.run_epochs(2, counts=counts)
        fleet.run_epochs(2)  # warm the counts=None trace
        before = dict(fleet.upload_stats)
        # global (not context-manager) guard: the dispatch and its uploads
        # run on the fleet's worker thread, which a thread-local guard
        # would not observe
        jax.config.update("jax_transfer_guard_host_to_device", "disallow")
        try:
            fleet.run_epochs(2)
        finally:
            jax.config.update("jax_transfer_guard_host_to_device", "allow")
        after = fleet.upload_stats
        assert after["restacked_machines"] == before["restacked_machines"]
        assert after["seg_rebuilds"] == before["seg_rebuilds"]
        assert after["clean_dispatches"] == before["clean_dispatches"] + 1

    def test_control_plane_op_restacks_only_touched_machine(self):
        fleet = FleetManager([_mk_manager(**c)[0] for c in _configs()])
        counts = np.random.default_rng(1).poisson(
            4, (len(fleet), fleet.num_pages)).astype(np.int64)
        fleet.run_epochs(2, counts=counts)
        h = fleet.machines[1].register(0.4)
        fleet.machines[1].allocate(h, 32)
        before = dict(fleet.upload_stats)
        fleet.run_epochs(2, counts=counts)
        after = fleet.upload_stats
        assert after["restacked_machines"] == before["restacked_machines"] + 1
        assert after["seg_rebuilds"] == before["seg_rebuilds"] + 1

    def test_params_only_change_skips_state_restack(self):
        """set_migration_bandwidth swaps a traced parameter: the params
        leaves restack, the O(P) state arrays must not."""
        fleet = FleetManager(
            [_mk_manager(**c)[0] for c in _configs(queue=True)])
        counts = np.random.default_rng(2).poisson(
            4, (len(fleet), fleet.num_pages)).astype(np.int64)
        fleet.run_epochs(2, counts=counts)
        fleet.machines[0].set_migration_bandwidth(4)
        before = dict(fleet.upload_stats)
        fleet.run_epochs(2, counts=counts)
        after = fleet.upload_stats
        assert after["restacked_machines"] == before["restacked_machines"]

    def test_dirty_results_still_exact(self):
        """Dirty-tracking is an optimization, not a semantic: interleaved
        control-plane ops + dispatches equal the solo sequence bitwise."""
        cfgs = _configs(queue=True)
        fleet_ms = [_mk_manager(**c)[0] for c in cfgs]
        solo_ms = [_mk_manager(**c)[0] for c in cfgs]
        fleet = FleetManager(list(fleet_ms))
        rng = np.random.default_rng(3)
        counts = rng.poisson(4, (3, len(cfgs), 4, fleet.num_pages)).astype(np.int64)
        for burst in range(3):
            if burst == 1:
                for m in (fleet_ms[0], solo_ms[0]):
                    m.set_migration_bandwidth(6)
            if burst == 2:
                for m in (fleet_ms[2], solo_ms[2]):
                    h = m.register(0.2)
                    m.allocate(h, 40)
            fleet.run_epochs(4, counts=counts[burst])
            for i, m in enumerate(solo_ms):
                m.run_epochs(4, counts=counts[burst][i])
        for fm, sm in zip(fleet_ms, solo_ms):
            _assert_machine_equal(fm, sm)
            assert fm.queue_counters() == sm.queue_counters()


class TestShardedGolden:
    @pytest.mark.parametrize("devices", ["all", "one"])
    def test_sharded_fleet_replays_golden_trace(self, devices):
        """The committed fleet golden (generated by the PR 4 vmap fleet)
        must replay bit-for-bit through the sharded executor — K=3 machines
        pad to the device multiple on multi-device hosts."""
        with open(golden_regen.FLEET_TRACE_PATH) as f:
            committed = json.load(f)
        dev = None if devices == "all" else 1
        pad = 4 if devices == "all" else None
        fleet = FleetManager(
            [m for m in golden_regen.make_fleet().machines], devices=dev,
            pad_to=pad,
        )
        counts = golden_regen.policy_counts()
        res = fleet.run_epochs(
            golden_regen.POLICY_EPOCHS,
            counts=np.broadcast_to(counts, (len(fleet),) + counts.shape),
            collect_plans=True,
        )
        for m, machine in enumerate(committed["machines"]):
            records = res.machine(m).unstack()
            tier = fleet.machines[m].tiers()
            for e, want in enumerate(machine["epochs"]):
                got = golden_regen.epoch_record(records[e], tier)
                for key in want:
                    if key == "tier" and e < golden_regen.POLICY_EPOCHS - 1:
                        continue
                    assert want[key] == got[key], (m, e, key)


class TestPipelinedSweep:
    @pytest.mark.parametrize("queue", [False, True], ids=["instant", "queue"])
    def test_pipelined_sweep_matches_serial_and_unpipelined(self, queue):
        """run_sweep(pipeline=True, sharded) == run_sweep(pipeline=False,
        devices=1) == per-machine chunked scenario runs, record for record,
        across mid-sweep churn (arrive/depart/resize) and — in queue mode —
        a bandwidth event landing mid-sweep."""
        from repro.core.scenario import (
            Arrive, Depart, ResizeWorkingSet, Scenario, ScenarioSweep,
            SetMigrationBandwidth, SweepPoint, run_sweep,
        )
        from repro.core.simulator import OPTANE, ColocationSim, WorkloadSpec

        chunk = 4
        events = [
            Arrive(0, WorkloadSpec("kvs", n_pages=380, t_miss=0.2, threads=4,
                                   sets=((0.2, 0.9),))),
            Arrive(0, WorkloadSpec("gap", n_pages=260, t_miss=0.5, threads=8,
                                   sets=((0.2, 0.7),))),
            Arrive(4, WorkloadSpec("gups", n_pages=160, t_miss=1.0, threads=8)),
            ResizeWorkingSet(8, "kvs", 0, 0.3),
            Depart(12, "gups"),
        ]
        if queue:
            events.append(SetMigrationBandwidth(8, 8))
        sc = Scenario(name="sharded_sweep_parity", n_epochs=16,
                      events=tuple(events))
        points = tuple(
            SweepPoint(name=f"m{i}", seed=i, migration_budget=24 + 8 * i)
            for i in range(3)
        )
        kw = dict(
            num_pages=1024, fast_capacity=256, migration_budget=32,
            max_tenants=8, policy_chunk=chunk,
            queue_size=64 if queue else 0,
        )
        piped = run_sweep(ScenarioSweep(scenario=sc, points=points), **kw)
        plain = run_sweep(ScenarioSweep(scenario=sc, points=points),
                          devices=1, pipeline=False, trim_stats=False, **kw)
        assert piped.pipeline and not plain.pipeline
        for p in points:
            mgr_kw = dict(
                num_pages=1024, fast_capacity=256,
                migration_budget=p.migration_budget, max_tenants=8,
                sample_period=100, seed=p.seed,
            )
            if queue:
                mgr_kw["queue_size"] = 64
            mgr = CentralManager(**mgr_kw)
            sim = ColocationSim(mgr, OPTANE, seed=p.seed, policy_chunk=chunk)
            want = sim.run_scenario(sc)
            for got in (piped.results[p.name], plain.results[p.name]):
                assert len(got.history) == len(want.history)
                for rg, rw in zip(got.history, want.history):
                    assert rg.throughput == rw.throughput
                    assert rg.fmmr_true == rw.fmmr_true
                    assert rg.fast_pages == rw.fast_pages
                    assert rg.migrated_pages == rw.migrated_pages
                    assert rg.queue_depth == rw.queue_depth
                for pg, pw in zip(got.phases, want.phases):
                    assert pg.label == pw.label
                    assert pg.agg_throughput == pw.agg_throughput
                    assert pg.migration_bytes == pw.migration_bytes
