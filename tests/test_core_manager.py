"""CentralManager end-to-end: allocation semantics, dynamic QoS, invariants."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # clean checkout: deterministic fallback sweep
    from _hypothesis_fallback import given, settings, st

from repro.core import CentralManager, TIER_FAST, TIER_NONE, TIER_SLOW


def _mgr(**kw):
    defaults = dict(
        num_pages=256,
        fast_capacity=64,
        migration_budget=32,
        max_tenants=8,
        sample_period=1,
        exact_sampling=True,
    )
    defaults.update(kw)
    return CentralManager(**defaults)


class TestAllocation:
    def test_fast_first_then_slow(self):
        m = _mgr()
        h = m.register(t_miss=0.5)
        pages = m.allocate(h, 100)
        tiers = m.tier_of(pages)
        assert (tiers == TIER_FAST).sum() == 64
        assert (tiers == TIER_SLOW).sum() == 36

    def test_oom_raises(self):
        m = _mgr()
        h = m.register(t_miss=1.0)
        with pytest.raises(MemoryError):
            m.allocate(h, 1000)

    def test_free_returns_pages(self):
        m = _mgr()
        h = m.register(t_miss=1.0)
        pages = m.allocate(h, 50)
        m.free(h, pages)
        assert (m.tier_of(pages) == TIER_NONE).all()
        h2 = m.register(t_miss=1.0)
        assert len(m.allocate(h2, 256)) == 256

    def test_cannot_free_other_tenants_pages(self):
        m = _mgr()
        h1, h2 = m.register(0.5), m.register(0.5)
        p1 = m.allocate(h1, 10)
        with pytest.raises(PermissionError):
            m.free(h2, p1)

    def test_t_miss_validation(self):
        m = _mgr()
        with pytest.raises(AssertionError):
            m.register(t_miss=0.0)  # FMMR 0 => disable tiering, not a target


class TestDynamicQoS:
    def _drive(self, m, tenants_pages, probs, epochs=20):
        """tenants_pages: {handle: page_ids}; probs: {handle: per-page probs}"""
        res = None
        for _ in range(epochs):
            counts = np.zeros(m.num_pages, np.int64)
            for h, ids in tenants_pages.items():
                counts[ids] += (probs[h] * 10_000).astype(np.int64)
            m.record_access(counts)
            res = m.run_epoch()
        return res

    def test_single_tenant_hot_set_lands_in_fast(self):
        m = _mgr(num_pages=128, fast_capacity=32, migration_budget=16)
        h = m.register(t_miss=0.1)
        pages = m.allocate(h, 128)
        probs = np.full(128, 0.1 / 96)
        probs[:32] = 0.9 / 32  # hot set = exactly fast capacity
        self._drive(m, {h: pages}, {h: probs}, epochs=30)
        hot_tiers = m.tier_of(pages[:32])
        assert (hot_tiers == TIER_FAST).mean() > 0.9
        assert m.fmmr_of(h) <= 0.15

    def test_qos_reallocation_between_tenants(self):
        """LS tenant (t=0.1) takes fast memory from BE tenant (t=1.0)."""
        m = _mgr(num_pages=256, fast_capacity=64, migration_budget=32)
        be = m.register(t_miss=1.0)
        be_pages = m.allocate(be, 128)  # grabs all fast first
        ls = m.register(t_miss=0.1)
        ls_pages = m.allocate(ls, 128)  # all slow now
        probs = np.full(128, 1 / 128)
        ls_probs = np.full(128, 0.05 / 80)
        ls_probs[:48] = 0.95 / 48  # LS hot set of 48 pages
        self._drive(m, {be: be_pages, ls: ls_pages}, {be: probs, ls: ls_probs}, 40)
        assert m.fmmr_of(ls) <= 0.12, f"LS tenant FMMR {m.fmmr_of(ls)} > target"
        assert m.fast_pages_of(ls) >= 40

    def test_exit_releases_memory_to_needers(self):
        m = _mgr(num_pages=256, fast_capacity=64, migration_budget=32)
        a = m.register(t_miss=0.5)
        pa = m.allocate(a, 64)
        b = m.register(t_miss=0.1)
        pb = m.allocate(b, 64)
        probs = np.full(64, 1 / 64)
        self._drive(m, {a: pa, b: pb}, {a: probs, b: probs}, 10)
        m.unregister(a)
        self._drive(m, {b: pb}, {b: probs}, 20)
        assert m.fast_pages_of(b) >= 56  # reclaimed the freed fast tier

    def test_dynamic_target_change(self):
        m = _mgr(num_pages=128, fast_capacity=32, migration_budget=16)
        h = m.register(t_miss=1.0)
        pages = m.allocate(h, 128)
        probs = np.full(128, 1 / 128)
        self._drive(m, {h: pages}, {h: probs}, 10)
        m.set_target(h, 0.1)
        # single tenant: fast capacity 32/128 pages uniform -> best FMMR .75;
        # the policy should still pull everything it can into fast
        self._drive(m, {h: pages}, {h: probs}, 30)
        assert m.fast_pages_of(h) == 32


class TestInvariants:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**16), n_tenants=st.integers(1, 4))
    def test_property_capacity_and_budget(self, seed, n_tenants):
        rng = np.random.default_rng(seed)
        m = _mgr(num_pages=128, fast_capacity=32, migration_budget=16)
        handles, pages = [], {}
        for i in range(n_tenants):
            h = m.register(t_miss=float(rng.uniform(0.05, 1.0)))
            handles.append(h)
            pages[h] = m.allocate(h, int(rng.integers(8, 32)))
        for _ in range(8):
            counts = np.zeros(m.num_pages, np.int64)
            for h in handles:
                counts[pages[h]] += rng.integers(0, 50, len(pages[h]))
            m.record_access(counts)
            res = m.run_epoch()
            tier = np.asarray(m.pages.tier)
            assert (tier == TIER_FAST).sum() <= 32
            moved = int(res.plan.num_promote) + int(res.plan.num_demote)
            assert moved <= 16
            # owners never change due to migration
            for h in handles:
                assert (np.asarray(m.pages.owner)[pages[h]] == int(h)).all()
