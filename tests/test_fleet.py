"""Fleet-vectorized engine: vmap-parity, churn, conservation, golden replay.

The fleet's contract is that batching machines NEVER changes results: every
per-machine row of the vmapped scan is bit-identical to running that machine
alone through ``CentralManager.run_epoch``/``run_epochs`` — instant apply
and bounded-queue mode, across control-plane churn (allocate / free /
unregister between fleet dispatches), with the data-plane conservation
invariant holding per machine. The owner-segment reduction path introduced
for the fleet (DESIGN.md §5) is likewise locked against the legacy one-hot
path on the same states.
"""
from __future__ import annotations

import json
import os
import sys

import numpy as np
import jax
import pytest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from repro.core.fleet import FleetManager
from repro.core.manager import CentralManager

import golden_regen


def _mk_manager(seed, budget, queue_size=0, bandwidth=None, latency=0,
                num_pages=1024, fast=256, max_tenants=8, sample_period=100,
                exact_sampling=False):
    kw = dict(
        num_pages=num_pages, fast_capacity=fast, migration_budget=budget,
        max_tenants=max_tenants, sample_period=sample_period, seed=seed,
        queue_size=queue_size, migration_latency=latency,
        exact_sampling=exact_sampling,
    )
    if bandwidth is not None:
        kw["migration_bandwidth"] = bandwidth
    m = CentralManager(**kw)
    hs = []
    for t_miss, n in ((0.1, 300), (0.5, 300), (1.0, 200)):
        h = m.register(t_miss)
        m.allocate(h, n)
        hs.append(h)
    return m, hs


def _configs(queue=False):
    """Four machines with heterogeneous TRACED knobs (seed, budget, and in
    queue mode bandwidth/latency) — the sweepable grid."""
    if queue:
        return [
            dict(seed=s, budget=32 + 16 * s, queue_size=128,
                 bandwidth=8 + 8 * s, latency=s % 2)
            for s in range(4)
        ]
    return [dict(seed=s, budget=32 + 16 * s) for s in range(4)]


def _assert_padded_prefix(fa, sa):
    """Fleet fixed-size id lists are wider (fleet-max plan size): the
    serial list is a prefix, the tail must be -1 padding."""
    fa, sa = np.asarray(fa), np.asarray(sa)
    np.testing.assert_array_equal(fa[..., : sa.shape[-1]], sa)
    assert (fa[..., sa.shape[-1]:] == -1).all()


def _assert_stats_equal(a, b):
    qa, qb = a.queue, b.queue
    a, b = a._replace(queue=None), b._replace(queue=None)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    assert (qa is None) == (qb is None)
    if qa is not None:
        # drained id lists are [W]-sized with W = queue + 2*plan_size; the
        # fleet W uses the fleet-max plan size -> prefix semantics
        _assert_padded_prefix(qa.drained_promote_ids, qb.drained_promote_ids)
        _assert_padded_prefix(qa.drained_demote_ids, qb.drained_demote_ids)
        qa = qa._replace(drained_promote_ids=None, drained_demote_ids=None)
        qb = qb._replace(drained_promote_ids=None, drained_demote_ids=None)
        for la, lb in zip(jax.tree.leaves(qa), jax.tree.leaves(qb)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _assert_plan_prefix(fleet_plan, serial_plan):
    """Fleet plan buffers are fleet-max sized; the serial machine's plan is
    a prefix, the rest must be -1 padding."""
    for side in ("promote", "demote"):
        _assert_padded_prefix(
            getattr(fleet_plan, side), getattr(serial_plan, side)
        )


class TestFleetParity:
    @pytest.mark.parametrize("queue", [False, True], ids=["instant", "queue"])
    def test_fleet_matches_serial_run_epochs(self, queue):
        cfgs = _configs(queue)
        K, E, P = len(cfgs), 6, 1024
        rng = np.random.default_rng(0)
        counts = rng.poisson(4, (K, E, P)).astype(np.int64)
        fleet = FleetManager([_mk_manager(**c)[0] for c in cfgs])
        res = fleet.run_epochs(E, counts=counts, collect_plans=True)
        for m, c in enumerate(cfgs):
            serial, _ = _mk_manager(**c)
            want = serial.run_epochs(E, counts=counts[m], collect_plans=True)
            got = res.machine(m)
            _assert_stats_equal(got.stats, want.stats)
            np.testing.assert_array_equal(got.flags, np.asarray(want.flags))
            _assert_plan_prefix(got.plans, want.plans)
            np.testing.assert_array_equal(
                fleet.machines[m].tiers(), serial.tiers()
            )
            np.testing.assert_array_equal(
                fleet.machines[m].owners(), serial.owners()
            )

    @pytest.mark.parametrize("queue", [False, True], ids=["instant", "queue"])
    def test_fleet_matches_serial_singles(self, queue):
        """One fleet dispatch == the per-epoch record_access + run_epoch
        loop on every machine (the pre-fleet sweep driver). Exact sampling:
        the scan path pre-draws its PEBS noise in one batched call, so
        scan == singles is only a bitwise contract when sampling is exact
        (the same contract multi_epoch has always had)."""
        cfgs = [dict(c, exact_sampling=True) for c in _configs(queue)]
        K, E, P = len(cfgs), 5, 1024
        rng = np.random.default_rng(1)
        counts = rng.poisson(4, (K, E, P)).astype(np.int64)
        fleet = FleetManager([_mk_manager(**c)[0] for c in cfgs])
        fleet.run_epochs(E, counts=counts)
        for m, c in enumerate(cfgs):
            serial, _ = _mk_manager(**c)
            for e in range(E):
                serial.record_access(counts[m, e])
                serial.run_epoch()
            np.testing.assert_array_equal(
                fleet.machines[m].tiers(), serial.tiers()
            )
            np.testing.assert_array_equal(
                np.asarray(fleet.machines[m].tenants.a_miss),
                np.asarray(serial.tenants.a_miss),
            )
            if queue:
                assert fleet.machines[m].queue_counters() == serial.queue_counters()

    def test_churn_between_fleet_dispatches(self):
        """free()/unregister/register/allocate between fleet dispatches
        keep per-machine parity — the control plane stays host-side on the
        underlying managers and the next dispatch restacks."""
        cfgs = _configs(queue=True)
        K, E, P = len(cfgs), 4, 1024
        rng = np.random.default_rng(2)
        c1 = rng.poisson(4, (K, E, P)).astype(np.int64)
        c2 = rng.poisson(6, (K, E, P)).astype(np.int64)

        fleet_ms, fleet_hs = zip(*[_mk_manager(**c) for c in cfgs])
        serial_ms, serial_hs = zip(*[_mk_manager(**c) for c in cfgs])
        fleet = FleetManager(list(fleet_ms))

        def churn(m, hs):
            # depart the middle tenant on machines 0/2, grow a new one on 1
            i = fleet_ms.index(m) if m in fleet_ms else serial_ms.index(m)
            if i % 2 == 0:
                owned = np.flatnonzero(np.asarray(m.owners()) == int(hs[1]))
                m.free(hs[1], owned)
                m.unregister(hs[1])
            else:
                h = m.register(0.3)
                m.allocate(h, 100)

        fleet.run_epochs(E, counts=c1)
        for m, hs in zip(fleet_ms, fleet_hs):
            churn(m, hs)
        fleet.run_epochs(E, counts=c2)

        for i, (m, hs) in enumerate(zip(serial_ms, serial_hs)):
            m.run_epochs(E, counts=c1[i])
            churn(m, hs)
            m.run_epochs(E, counts=c2[i])
            np.testing.assert_array_equal(fleet_ms[i].tiers(), m.tiers())
            np.testing.assert_array_equal(fleet_ms[i].owners(), m.owners())
            np.testing.assert_array_equal(
                np.asarray(fleet_ms[i].tenants.a_miss), np.asarray(m.tenants.a_miss)
            )
            # data-plane conservation per machine across the churn
            qc = fleet_ms[i].queue_counters()
            assert qc["enqueued"] == (
                qc["drained"] + qc["cancelled"] + qc["dropped"] + qc["depth"]
            )

    def test_fleet_shape_mismatch_rejected(self):
        a, _ = _mk_manager(seed=0, budget=32)
        b, _ = _mk_manager(seed=1, budget=32, num_pages=2048, fast=512)
        with pytest.raises(AssertionError):
            FleetManager([a, b])


class TestSegmentReductions:
    """The owner-segment reduction path must equal the legacy one-hot path
    bit-for-bit on identical states (DESIGN.md §5)."""

    @pytest.mark.parametrize("queue", [0, 64], ids=["instant", "queue"])
    def test_segment_path_matches_onehot(self, queue):
        def drive(segs_on):
            kw = dict(seed=3, budget=48, queue_size=queue)
            if queue:
                kw["bandwidth"] = 16
            m, hs = _mk_manager(**kw)
            if not segs_on:
                m._segs_owner = None  # cancel the pending lazy rebuild
                m._state = m._state._replace(segs=None)
            rng = np.random.default_rng(5)
            outs = []
            for e in range(6):
                m.record_access(rng.poisson(3, 1024).astype(np.int64))
                r = m.run_epoch()
                outs.append((
                    np.asarray(m.tiers()),
                    np.asarray(r.plan.promote), np.asarray(r.plan.demote),
                    np.asarray(r.stats.fmmr_ewma),
                    np.asarray(r.stats.promoted), np.asarray(r.stats.demoted),
                ))
                if e == 3:
                    owned = np.flatnonzero(np.asarray(m.owners()) == int(hs[1]))
                    m.free(hs[1], owned)
                    m.unregister(hs[1])
                    if not segs_on:
                        m._segs_owner = None
                        m._state = m._state._replace(segs=None)
            return outs

        for got, want in zip(drive(True), drive(False)):
            for u, v in zip(got, want):
                np.testing.assert_array_equal(u, v)


class TestFleetGolden:
    def test_fleet_trace_replays(self):
        with open(golden_regen.FLEET_TRACE_PATH) as f:
            committed = json.load(f)
        fresh = golden_regen.drive_fleet()
        assert committed["machines"] == json.loads(json.dumps(fresh))

    def test_fleet_trace_matches_serial_machines(self):
        """Each machine's golden rows equal a serial CentralManager run."""
        with open(golden_regen.FLEET_TRACE_PATH) as f:
            committed = json.load(f)
        counts = golden_regen.policy_counts()
        for spec, machine in zip(
            golden_regen.FLEET_MACHINES, committed["machines"]
        ):
            seed, budget = spec
            m = CentralManager(
                num_pages=golden_regen.POLICY_P,
                fast_capacity=golden_regen.POLICY_FAST,
                migration_budget=budget,
                max_tenants=golden_regen.POLICY_MAX_T,
                sample_period=100, exact_sampling=True, seed=seed,
            )
            for n_pages, t_miss in golden_regen.POLICY_TENANTS:
                h = m.register(t_miss)
                m.allocate(h, n_pages)
            res = m.run_epochs(
                golden_regen.POLICY_EPOCHS, counts=counts, collect_plans=True
            )
            for e, (rec, want) in enumerate(zip(res.unstack(), machine["epochs"])):
                got = golden_regen.epoch_record(rec, m.tiers())
                if e < golden_regen.POLICY_EPOCHS - 1:
                    got.pop("tier")
                else:
                    # golden snapshots only the FINAL placement; mid-run
                    # tiers from unstacked records are not comparable
                    pass
                for k in want:
                    if k in ("promote_ids", "demote_ids"):
                        # fleet plan buffers are fleet-max sized
                        n = len(got[k])
                        assert want[k][:n] == got[k]
                        assert all(v == -1 for v in want[k][n:])
                    else:
                        assert want[k] == got[k], (e, k)


class TestSweep:
    def test_sweep_equals_serial_chunked_scenarios(self):
        """run_sweep == per-machine ColocationSim(policy_chunk=k) scenario
        runs: same chunk boundaries, same access-noise streams, and the
        fleet tick is bit-identical, so every telemetry record matches."""
        from repro.core.scenario import (
            Arrive, Depart, ResizeWorkingSet, Scenario, ScenarioSweep,
            SweepPoint, run_sweep,
        )
        from repro.core.simulator import OPTANE, ColocationSim, WorkloadSpec

        chunk = 4
        sc = Scenario(name="sweep_parity", n_epochs=16, events=(
            Arrive(0, WorkloadSpec("kvs", n_pages=380, t_miss=0.2, threads=4,
                                   sets=((0.2, 0.9),))),
            Arrive(0, WorkloadSpec("gap", n_pages=260, t_miss=0.5, threads=8,
                                   sets=((0.2, 0.7),))),
            Arrive(4, WorkloadSpec("gups", n_pages=160, t_miss=1.0, threads=8)),
            ResizeWorkingSet(8, "kvs", 0, 0.3),
            Depart(12, "gups"),
        ))
        points = tuple(
            SweepPoint(name=f"m{i}", seed=i, migration_budget=24 + 8 * i)
            for i in range(3)
        )
        out = run_sweep(
            ScenarioSweep(scenario=sc, points=points),
            num_pages=1024, fast_capacity=256, migration_budget=32,
            max_tenants=8, policy_chunk=chunk,
        )
        for p in points:
            mgr = CentralManager(
                num_pages=1024, fast_capacity=256,
                migration_budget=p.migration_budget, max_tenants=8,
                sample_period=100, seed=p.seed,
            )
            sim = ColocationSim(mgr, OPTANE, seed=p.seed, policy_chunk=chunk)
            want = sim.run_scenario(sc)
            got = out.results[p.name]
            assert len(got.history) == len(want.history)
            for rg, rw in zip(got.history, want.history):
                assert rg.throughput == rw.throughput
                assert rg.fmmr_true == rw.fmmr_true
                assert rg.fast_pages == rw.fast_pages
                assert rg.migrated_pages == rw.migrated_pages
                assert rg.queue_depth == rw.queue_depth
            for pg, pw in zip(got.phases, want.phases):
                assert pg.label == pw.label
                assert pg.agg_throughput == pw.agg_throughput

    def test_sweep_point_names_unique(self):
        from repro.core.scenario import Scenario, ScenarioSweep, SweepPoint

        sc = Scenario(name="x", n_epochs=4)
        with pytest.raises(AssertionError):
            ScenarioSweep(scenario=sc, points=(
                SweepPoint(name="a"), SweepPoint(name="a", seed=1),
            ))
