"""Tests for the FMMR reallocation math and the full policy epoch (§3.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # clean checkout: deterministic fallback sweep
    from _hypothesis_fallback import given, settings, st

from repro.core import fmmr, policy
from repro.core.types import (
    TIER_FAST,
    TIER_SLOW,
    PageState,
    PolicyParams,
    TenantState,
)


def _tenants(t_miss, a_miss, fast=None):
    T = len(t_miss)
    ten = TenantState.create(T)
    return ten._replace(
        active=jnp.ones((T,), bool),
        t_miss=jnp.array(t_miss, jnp.float32),
        a_miss=jnp.array(a_miss, jnp.float32),
        arrival=jnp.arange(T, dtype=jnp.int32),
    )


class TestFMMR:
    def test_fmmr_now_zero_when_idle(self):
        out = fmmr.fmmr_now(jnp.array([0.0]), jnp.array([0.0]))
        assert float(out[0]) == 0.0  # idle tenants decay to zero (§3.1)

    def test_fmmr_now_ratio(self):
        out = fmmr.fmmr_now(jnp.array([90.0]), jnp.array([10.0]))
        assert np.isclose(float(out[0]), 0.1)

    def test_ewma_lambda_half(self):
        out = fmmr.update_ewma(jnp.array([0.4]), jnp.array([0.2]), 0.5)
        assert np.isclose(float(out[0]), 0.3)


class TestRealloc:
    def test_needer_receives_donor_gives(self):
        ten = _tenants([0.1, 1.0], [0.5, 0.2])  # t0 needs, t1 below target
        ra = fmmr.reallocate(
            ten, jnp.array([10, 100]), jnp.int32(0), jnp.int32(50)
        )
        assert int(ra.give[0]) > 0
        assert int(ra.take[1]) > 0
        assert int(ra.give[1]) == 0 and int(ra.take[0]) == 0

    def test_take_capped_at_fast_holdings(self):
        ten = _tenants([0.1, 1.0], [0.5, 0.2])
        ra = fmmr.reallocate(ten, jnp.array([10, 3]), jnp.int32(0), jnp.int32(50))
        assert int(ra.take[1]) <= 3

    def test_zero_amiss_single_donor_per_epoch(self):
        # two idle donors (a_miss=0): only the earliest-arrival one donates
        ten = _tenants([0.1, 1.0, 1.0], [0.9, 0.0, 0.0])
        ra = fmmr.reallocate(
            ten, jnp.array([5, 40, 40]), jnp.int32(0), jnp.int32(20)
        )
        donors = [i for i in range(3) if int(ra.take[i]) > 0]
        assert donors == [1]

    def test_gives_bounded_by_available(self):
        ten = _tenants([0.1], [1.0])
        ra = fmmr.reallocate(ten, jnp.array([0]), jnp.int32(7), jnp.int32(100))
        assert int(ra.give[0]) <= 7

    def test_fcfs_serves_earliest_first(self):
        ten = _tenants([0.1, 0.1], [1.0, 1.0])
        ra = fmmr.reallocate(ten, jnp.array([0, 0]), jnp.int32(10), jnp.int32(100))
        # both want 50; only 10 available; FCFS gives all to tenant 0
        assert int(ra.give[0]) == 10 and int(ra.give[1]) == 0
        assert bool(ra.flagged[1])

    def test_fair_mode_splits_proportionally(self):
        ten = _tenants([0.1, 0.1], [1.0, 1.0])
        ra = fmmr.reallocate(
            ten, jnp.array([0, 0]), jnp.int32(10), jnp.int32(100), fair_mode=True
        )
        assert int(ra.give[0]) == 5 and int(ra.give[1]) == 5

    def test_proportionality_to_distance(self):
        """Farther-from-target needers get more bandwidth (§3.4)."""
        ten = _tenants([0.1, 0.1, 1.0], [1.0, 0.2, 0.1])
        ra = fmmr.reallocate(
            ten, jnp.array([0, 0, 200]), jnp.int32(200), jnp.int32(100)
        )
        assert int(ra.give[0]) > int(ra.give[1]) > 0

    @settings(max_examples=60, deadline=None)
    @given(
        t=st.lists(st.floats(0.05, 1.0), min_size=2, max_size=8),
        a=st.lists(st.floats(0.0, 1.0), min_size=2, max_size=8),
        fast=st.lists(st.integers(0, 100), min_size=2, max_size=8),
        free=st.integers(0, 50),
        budget=st.integers(1, 64),
    )
    def test_property_invariants(self, t, a, fast, free, budget):
        n = min(len(t), len(a), len(fast))
        t, a, fast = t[:n], a[:n], fast[:n]
        ten = _tenants(t, a)
        ra = fmmr.reallocate(
            ten, jnp.array(fast, jnp.int32), jnp.int32(free), jnp.int32(budget)
        )
        give, take = np.asarray(ra.give), np.asarray(ra.take)
        assert np.all(give >= 0) and np.all(take >= 0)
        # takes never exceed holdings
        assert np.all(take <= np.array(fast))
        # gives never exceed what exists (free + takes)
        assert give.sum() <= free + take.sum()
        # nobody both gives and takes
        assert not np.any((give > 0) & (take > 0))
        # total gives bounded by the migration budget
        assert give.sum() <= budget


class TestPolicyEpoch:
    def _setup(self, P=64, T=4, F=16, R=16):
        pages = PageState.create(P)
        tenants = TenantState.create(T)
        params = PolicyParams(
            fast_capacity=jnp.int32(F),
            migration_budget=jnp.int32(R),
            sample_period=jnp.int32(1),
        )
        return pages, tenants, params

    def test_rebalance_promotes_hottest_demotes_coldest(self):
        P, T, F, R = 16, 1, 4, 8
        pages, tenants, params = self._setup(P, T, F, R)
        tenants = tenants._replace(
            active=tenants.active.at[0].set(True),
            t_miss=tenants.t_miss.at[0].set(1.0),
            arrival=tenants.arrival.at[0].set(0),
        )
        # tenant 0 owns all 16 pages; pages 0-3 fast (cold), 4-15 slow
        owner = jnp.zeros((P,), jnp.int32)
        tier = jnp.array([TIER_FAST] * 4 + [TIER_SLOW] * 12, jnp.int8)
        pages = pages._replace(owner=owner, tier=tier)
        # heat: slow pages 4,5 are hottest; fast pages are cold
        sampled = np.zeros(P, np.int64)
        sampled[4] = 20
        sampled[5] = 18
        sampled[0] = 1  # fast, slightly warm
        pages2, tenants2, plan, stats = policy.policy_epoch(
            pages,
            tenants,
            jnp.asarray(sampled, jnp.uint32),
            params,
            max_tenants=T,
            plan_size=R,
        )
        pages3 = policy.apply_plan(pages2, plan)
        tier3 = np.asarray(pages3.tier)
        assert tier3[4] == TIER_FAST and tier3[5] == TIER_FAST
        # cold fast pages displaced
        assert (tier3[:4] == TIER_SLOW).sum() >= 2

    def test_fast_capacity_never_exceeded(self):
        P, T, F, R = 64, 3, 16, 32
        pages, tenants, params = self._setup(P, T, F, R)
        rng = np.random.default_rng(0)
        owner = jnp.asarray(rng.integers(0, T, P), jnp.int32)
        tier = jnp.asarray(
            np.where(np.arange(P) < F, TIER_FAST, TIER_SLOW), jnp.int8
        )
        pages = pages._replace(owner=owner, tier=tier)
        tenants = tenants._replace(
            active=jnp.ones((T,), bool),
            t_miss=jnp.array([0.1, 0.5, 1.0], jnp.float32),
            arrival=jnp.arange(T, dtype=jnp.int32),
        )
        for step in range(10):
            sampled = jnp.asarray(rng.integers(0, 10, P), jnp.uint32)
            pages, tenants, plan, stats = policy.policy_epoch(
                pages, tenants, sampled, params, max_tenants=T, plan_size=R
            )
            pages = policy.apply_plan(pages, plan)
            n_fast = int((np.asarray(pages.tier) == TIER_FAST).sum())
            assert n_fast <= F, f"step {step}: fast tier over capacity {n_fast} > {F}"
            moved = int(plan.num_promote) + int(plan.num_demote)
            assert moved <= R, f"migration rate cap violated: {moved} > {R}"

    def test_idle_tenant_decays_and_donates(self):
        """Memory-inactive tenants converge a_miss -> 0 and give up fast mem."""
        P, T, F, R = 32, 2, 8, 8
        pages, tenants, params = self._setup(P, T, F, R)
        owner = jnp.asarray([0] * 16 + [1] * 16, jnp.int32)
        tier = jnp.asarray([TIER_FAST] * 8 + [TIER_SLOW] * 24, jnp.int8)
        pages = pages._replace(owner=owner, tier=tier)
        tenants = tenants._replace(
            active=jnp.ones((T,), bool),
            t_miss=jnp.array([1.0, 0.1], jnp.float32),
            a_miss=jnp.array([0.5, 0.0], jnp.float32),
            arrival=jnp.arange(T, dtype=jnp.int32),
        )
        rng = np.random.default_rng(1)
        for _ in range(12):
            sampled = np.zeros(P, np.int64)
            sampled[16:] = rng.integers(1, 10, 16)  # only tenant 1 active
            pages, tenants, plan, _ = policy.policy_epoch(
                pages, tenants, jnp.asarray(sampled, jnp.uint32), params,
                max_tenants=T, plan_size=int(params.migration_budget),
            )
            pages = policy.apply_plan(pages, plan)
        t0_fast = int(
            ((np.asarray(pages.owner) == 0) & (np.asarray(pages.tier) == TIER_FAST)).sum()
        )
        t1_fast = int(
            ((np.asarray(pages.owner) == 1) & (np.asarray(pages.tier) == TIER_FAST)).sum()
        )
        assert float(tenants.a_miss[0]) < 1e-3
        assert t1_fast > t0_fast  # active tenant captured the fast tier
