"""Scenario engine + differential invariant harness + golden-trace locks.

Four layers:

1. Scenario-engine semantics: spec validation, phase spans, and that every
   event kind actually perturbs the simulator.
2. Differential invariant harness: MaxMem and all three baselines run the
   SAME scripted and randomized scenarios; conservation invariants are
   asserted after every event and epoch — no page owned by an unregistered
   tenant, fast occupancy <= capacity, tiers exactly partitioned, migration
   traffic <= budget (for budgeted policies).
3. Golden-trace locks: the vectorized baselines replay
   ``tests/golden/baseline_traces.json`` (recorded from the frozen seed
   per-page implementations) bit-for-bit, and ``policy.epoch_step`` /
   ``policy.multi_epoch`` replay ``tests/golden/policy_trace.json``
   bit-identically, so refactors cannot silently change placements.
4. Churn regression: unregister scrubs per-tenant state (manager and
   baselines) — stale EWMA/targets were observable via ``fmmr_of`` before.
"""
import json

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # clean checkout: deterministic fallback sweep
    from _hypothesis_fallback import given, settings, st

import golden_regen
from repro.core.baselines import AutoNUMALike, HeMemStatic, TwoLM
from repro.core.manager import CentralManager
from repro.core.scenario import (
    Arrive,
    Depart,
    PingPongShift,
    ResizeWorkingSet,
    Retarget,
    Scenario,
    SetMigrationBandwidth,
    ShiftWorkingSet,
    SkewChange,
    pingpong_schedule,
    run_scenario,
)
from repro.core.simulator import OPTANE, ColocationSim, WorkloadSpec
from repro.core.types import TIER_FAST, TIER_NONE, TIER_SLOW

P, FAST, BUDGET = 256, 64, 32


def _backends():
    """All four policies on identical geometry (factories)."""
    return {
        "maxmem": lambda: CentralManager(
            num_pages=P, fast_capacity=FAST, migration_budget=BUDGET,
            max_tenants=8, sample_period=10),
        "hemem": lambda: HeMemStatic(
            P, FAST, partitions={i: FAST // 4 for i in range(8)},
            hot_threshold=6, migration_budget=BUDGET),
        "autonuma": lambda: AutoNUMALike(P, FAST),
        "twolm": lambda: TwoLM(P, FAST),
    }


def _fast_cap(backend) -> int:
    if hasattr(backend, "params"):
        return int(backend.params.fast_capacity)
    return backend.fast_capacity


def _migration_budget(backend):
    if hasattr(backend, "params"):
        return int(backend.params.migration_budget)
    return getattr(backend, "migration_budget", None)


def check_invariants(sim, event=None):
    """The conservation invariants every placement backend must uphold."""
    backend = sim.backend
    tier = np.asarray(backend.tiers())
    owner = np.asarray(backend.owners())
    ctx = f"after {event}" if event is not None else "after epoch"
    # tier domain + exact partition: owned <=> placed, unowned <=> NONE
    assert set(np.unique(tier).tolist()) <= {TIER_NONE, TIER_SLOW, TIER_FAST}, ctx
    owned = owner >= 0
    assert (tier[owned] != TIER_NONE).all(), f"owned page unplaced {ctx}"
    assert (tier[~owned] == TIER_NONE).all(), f"unowned page placed {ctx}"
    # no page owned by an unregistered tenant
    registered = {int(h) for h in sim.handles.values()}
    holders = set(np.unique(owner[owned]).tolist())
    assert holders <= registered, f"orphan owners {holders - registered} {ctx}"
    # fast-tier occupancy bounded by capacity
    assert int((tier == TIER_FAST).sum()) <= _fast_cap(backend), ctx
    # migration-queue conservation (data-plane backends): every entry ever
    # admitted is accounted for as drained, cancelled, dropped or in flight
    if hasattr(backend, "queue_counters"):
        c = backend.queue_counters()
        assert c["enqueued"] == (
            c["drained"] + c["cancelled"] + c["dropped"] + c["depth"]
        ), f"queue conservation broken {ctx}: {c}"


def _scripted_scenario() -> Scenario:
    return Scenario(
        name="scripted_churn",
        n_epochs=30,
        events=(
            Arrive(0, WorkloadSpec("a", 96, t_miss=0.2, threads=2, sets=((0.3, 0.9),))),
            Arrive(0, WorkloadSpec("b", 64, t_miss=1.0, threads=4)),
            Arrive(6, WorkloadSpec("c", 48, t_miss=0.5, threads=2, sets=((0.5, 0.8),))),
            ResizeWorkingSet(10, "a", 0, 0.45),
            SkewChange(14, "c", 0, 0.5),
            ShiftWorkingSet(18, "a"),
            Retarget(20, "b", 0.5),
            Depart(24, "b"),
            Arrive(26, WorkloadSpec("d", 32, t_miss=1.0, threads=2)),
        ),
    )


class TestScenarioSpec:
    def test_phase_spans_cover_run_and_label_events(self):
        sc = _scripted_scenario()
        spans = sc.phase_spans()
        assert spans[0][0] == 0 and spans[-1][1] == sc.n_epochs
        # contiguous, non-overlapping
        for (s0, e0, _), (s1, e1, _) in zip(spans[:-1], spans[1:]):
            assert e0 == s1
        labels = [l for _, _, l in spans]
        assert any("+a" in l for l in labels)
        assert any("-b" in l for l in labels)

    def test_event_epoch_out_of_range_rejected(self):
        with pytest.raises(AssertionError):
            Scenario(name="bad", n_epochs=10,
                     events=(Depart(10, "x"),))

    def test_events_perturb_simulator(self):
        mgr = _backends()["maxmem"]()
        sim = ColocationSim(mgr, OPTANE, seed=0)
        sc = Scenario(
            name="fx", n_epochs=8,
            events=(
                Arrive(0, WorkloadSpec("t", 128, t_miss=1.0, threads=2,
                                       sets=((0.25, 0.9),))),
                Retarget(2, "t", 0.3),
                ResizeWorkingSet(3, "t", 0, 0.5),
                SkewChange(4, "t", 0, 0.6),
                ShiftWorkingSet(5, "t"),
                Depart(6, "t"),
            ),
        )
        seen = []

        def spy(s, ev):
            if isinstance(ev, Retarget):
                assert s.tenants["t"].spec.t_miss == 0.3
                assert float(mgr.tenants.t_miss[s.handles["t"]]) == pytest.approx(0.3)
            if isinstance(ev, ResizeWorkingSet):
                assert s.tenants["t"].spec.sets[0][0] == 0.5
            if isinstance(ev, SkewChange):
                assert s.tenants["t"].spec.sets[0][1] == 0.6
            if isinstance(ev, Depart):
                assert "t" not in s.tenants
            seen.append(type(ev).__name__)

        res = sim.run_scenario(sc, on_event=spy)
        assert seen == ["Arrive", "Retarget", "ResizeWorkingSet", "SkewChange",
                        "ShiftWorkingSet", "Depart"]
        assert len(res.history) == 8
        assert res.steady_state.label == "-t"

    def test_shift_keeps_distribution_but_moves_pages(self):
        mgr = _backends()["maxmem"]()
        sim = ColocationSim(mgr, OPTANE, seed=3)
        sim.add_tenant(WorkloadSpec("t", 128, t_miss=1.0, threads=2,
                                    sets=((0.25, 0.9),)))
        t = sim.tenants["t"]
        before = t.probs.copy()
        t.shift_sets()
        assert not np.array_equal(before, t.probs), "shift moved no pages"
        assert np.allclose(sorted(before), sorted(t.probs)), "shift changed skew"


class TestDifferentialInvariants:
    def test_scripted_scenario_all_policies(self):
        sc = _scripted_scenario()
        for name, make in _backends().items():
            backend = make()
            sim = ColocationSim(backend, OPTANE, seed=7)
            res = run_scenario(sim, sc, on_event=check_invariants)
            check_invariants(sim)
            budget = _migration_budget(backend)
            if budget is not None:
                for rec in res.history:
                    assert rec.migrated_pages <= budget, (
                        f"{name}: migrated {rec.migrated_pages} > budget {budget} "
                        f"at epoch {rec.epoch}"
                    )

    def test_epoch_by_epoch_invariants(self):
        """Invariants hold after EVERY epoch, not just at event boundaries."""
        sc = _scripted_scenario()
        for name, make in _backends().items():
            sim = ColocationSim(make(), OPTANE, seed=11)
            for epoch in range(sc.n_epochs):
                for ev in sc.events_at(epoch):
                    ev.apply(sim)
                    check_invariants(sim, ev)
                sim.run_epoch()
                check_invariants(sim)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16), n_events=st.integers(2, 6))
    def test_randomized_event_schedules(self, seed, n_events):
        sc = _random_scenario(np.random.default_rng(seed), n_events)
        for name, make in _backends().items():
            backend = make()
            sim = ColocationSim(backend, OPTANE, seed=seed)
            res = run_scenario(sim, sc, on_event=check_invariants)
            check_invariants(sim)
            budget = _migration_budget(backend)
            if budget is not None:
                assert all(r.migrated_pages <= budget for r in res.history), name


def _random_scenario(rng: np.random.Generator, n_events: int) -> Scenario:
    """Build a valid random event schedule (arrivals fit memory, departs and
    mutations only target tenants alive at that epoch)."""
    alive = {}
    free_pages = P
    events = []
    idx = 0

    def arrive(epoch):
        nonlocal free_pages, idx
        n = int(rng.integers(16, 49))
        if free_pages - n < 8 or len(alive) >= 6:
            return
        free_pages -= n
        name = f"t{idx}"
        idx += 1
        sets = ((float(rng.uniform(0.2, 0.5)), float(rng.uniform(0.5, 0.95))),)
        spec = WorkloadSpec(name, n, t_miss=float(rng.uniform(0.1, 1.0)),
                            threads=int(rng.integers(1, 5)),
                            sets=sets if rng.random() < 0.7 else ())
        alive[name] = n
        events.append(Arrive(epoch, spec))

    arrive(0)
    arrive(0)
    epoch = 0
    for _ in range(n_events):
        epoch += int(rng.integers(2, 6))
        kind = rng.integers(0, 6)
        names = sorted(alive)
        if kind == 0:
            arrive(epoch)
        elif not names:
            arrive(epoch)
        elif kind == 1 and len(names) > 1:
            victim = names[int(rng.integers(len(names)))]
            events.append(Depart(epoch, victim))
            free_pages += alive.pop(victim)
        else:
            name = names[int(rng.integers(len(names)))]
            ev = [
                lambda: ResizeWorkingSet(epoch, name, 0, float(rng.uniform(0.2, 0.6))),
                lambda: SkewChange(epoch, name, 0, float(rng.uniform(0.4, 0.95))),
                lambda: ShiftWorkingSet(epoch, name),
                lambda: Retarget(epoch, name, float(rng.uniform(0.1, 1.0))),
            ][int(rng.integers(4))]()
            if isinstance(ev, (ResizeWorkingSet, SkewChange)):
                # only meaningful (and valid) when the tenant has skew sets
                spec = next(e.spec for e in events
                            if isinstance(e, Arrive) and e.spec.name == name)
                if not spec.sets:
                    ev = Retarget(epoch, name, 0.5)
            events.append(ev)
    return Scenario(name="random", n_epochs=epoch + 4, events=tuple(events))


class TestBoundedDataPlaneScenario:
    """The finite-bandwidth regime through the scenario engine: new events
    (SetMigrationBandwidth, ping-pong thrash) against the queue-mode
    manager, with conservation + placement invariants after every event
    and epoch."""

    def _bounded_mgr(self):
        return CentralManager(
            num_pages=P, fast_capacity=FAST, migration_budget=BUDGET,
            max_tenants=8, sample_period=10,
            queue_size=2 * BUDGET, migration_bandwidth=BUDGET // 4,
            migration_latency=1,
        )

    def _thrash_scenario(self) -> Scenario:
        return Scenario(
            name="bounded_thrash",
            n_epochs=28,
            events=(
                Arrive(0, WorkloadSpec("a", 96, t_miss=0.2, threads=2,
                                       sets=((0.3, 0.9),))),
                Arrive(0, WorkloadSpec("b", 64, t_miss=0.6, threads=4,
                                       sets=((0.25, 0.8),))),
                SetMigrationBandwidth(4, 2),
                *pingpong_schedule("a", 8, 20, 4),
                Depart(20, "b"),
                SetMigrationBandwidth(24, None),
            ),
        )

    def test_invariants_every_event_and_epoch(self):
        sc = self._thrash_scenario()
        sim = ColocationSim(self._bounded_mgr(), OPTANE, seed=13)
        for epoch in range(sc.n_epochs):
            for ev in sc.events_at(epoch):
                ev.apply(sim)
                check_invariants(sim, ev)
            sim.run_epoch()
            check_invariants(sim)
        assert sim.backend.queue_counters()["enqueued"] > 0

    def test_bandwidth_event_bounds_commits(self):
        """After SetMigrationBandwidth(2), no epoch commits more than 2
        pages until the closing unlimited event."""
        sc = self._thrash_scenario()
        sim = ColocationSim(self._bounded_mgr(), OPTANE, seed=13)
        res = run_scenario(sim, sc, on_event=check_invariants)
        for rec in res.history[4:24]:
            assert rec.migrated_pages <= 2, rec.epoch
        # per-phase data-plane columns are populated
        assert any(p.migration_bytes > 0 for p in res.phases)
        assert any(p.max_queue_depth > 0 for p in res.phases)

    def test_pingpong_toggles_between_two_scatters(self):
        sim = ColocationSim(self._bounded_mgr(), OPTANE, seed=3)
        sim.add_tenant(WorkloadSpec("t", 96, t_miss=1.0, threads=2,
                                    sets=((0.25, 0.9),)))
        t = sim.tenants["t"]
        home = t.probs.copy()
        PingPongShift(0, "t").apply(sim)
        away = t.probs.copy()
        assert not np.array_equal(home, away)
        PingPongShift(0, "t").apply(sim)
        assert np.array_equal(t.probs, home), "second flip must return home"
        PingPongShift(0, "t").apply(sim)
        assert np.array_equal(t.probs, away), "ping-pong must reuse ONE alternate"

    def test_bandwidth_event_clamps_baseline_budget(self):
        b = HeMemStatic(P, FAST, partitions={0: FAST}, hot_threshold=6,
                        migration_budget=BUDGET)
        sim = ColocationSim(b, OPTANE, seed=0)
        sim.add_tenant(WorkloadSpec("x", 128, t_miss=0.5, threads=2,
                                    sets=((0.3, 0.9),)))
        SetMigrationBandwidth(0, 4).apply(sim)
        assert b.migration_budget == 4
        for _ in range(6):
            sim.run_epoch()
            assert sim.history[-1].migrated_pages <= 4
        # None restores the CONFIGURED budget, not a permanent clamp
        SetMigrationBandwidth(0, None).apply(sim)
        assert b.migration_budget == BUDGET

    def test_bandwidth_event_bounds_autonuma(self):
        b = AutoNUMALike(P, FAST)
        sim = ColocationSim(b, OPTANE, seed=1)
        # all accesses land on 30% of pages: the cold tail gives autonuma
        # idle fast pages to evict, so unbounded churn is observable
        sim.add_tenant(WorkloadSpec("x", 200, t_miss=1.0, threads=4,
                                    sets=((0.3, 1.0),)))
        # unbounded warmup churns far more than the clamp
        sim.run_epoch()
        assert sim.history[-1].migrated_pages > 6
        SetMigrationBandwidth(0, 6).apply(sim)
        for _ in range(5):
            sim.tenants["x"].shift_sets()  # keep pressure on the migrator
            sim.run_epoch()
            assert sim.history[-1].migrated_pages <= 6
        SetMigrationBandwidth(0, None).apply(sim)
        assert b.migration_budget is None  # back to unbounded autonuma

    def test_bandwidth_event_is_inapplicable_to_twolm(self):
        """TwoLM is hardware-managed placement: the event must be a safe
        no-op (no attribute invented, behavior unchanged)."""
        b = TwoLM(P, FAST)
        sim = ColocationSim(b, OPTANE, seed=2)
        sim.add_tenant(WorkloadSpec("x", 128, t_miss=1.0, threads=2))
        SetMigrationBandwidth(0, 4).apply(sim)
        assert not hasattr(b, "migration_budget")
        sim.run_epoch()  # still runs


# ------------------------------------------------------------ golden locks
class TestGoldenTraces:
    def test_vectorized_baselines_replay_seed_golden(self):
        """The parity lock: identical placements to the recorded seed
        per-page implementations, every epoch of the churn trace."""
        import repro.core.baselines as live

        with open(golden_regen.BASELINE_TRACE_PATH) as f:
            golden = json.load(f)["traces"]
        for name, make in golden_regen.backend_factories(live).items():
            got = golden_regen.drive_baseline(make)
            assert len(got) == len(golden[name])
            for e, (g, n) in enumerate(zip(golden[name], got)):
                assert n["tier"] == g["tier"], f"{name} epoch {e}: tier diverged"
                assert n["owner"] == g["owner"], f"{name} epoch {e}: owner diverged"
                assert n["promoted"] == g["promoted"], f"{name} epoch {e}"
                assert n["demoted"] == g["demoted"], f"{name} epoch {e}"
                assert n["fmmr"] == g["fmmr"], f"{name} epoch {e}: fmmr diverged"

    def test_policy_epoch_step_replays_golden(self):
        with open(golden_regen.POLICY_TRACE_PATH) as f:
            golden = json.load(f)["epochs"]
        got = golden_regen.drive_policy_singlestep()
        assert len(got) == len(golden)
        for e, (g, n) in enumerate(zip(golden, got)):
            for key in g:
                assert n[key] == g[key], f"epoch {e}: {key} diverged"

    def test_policy_multi_epoch_replays_golden(self):
        """The fused lax.scan path reproduces the recorded single-step
        trace bit-identically (exact sampling)."""
        with open(golden_regen.POLICY_TRACE_PATH) as f:
            golden = json.load(f)["epochs"]
        m = golden_regen.make_policy_manager()
        res = m.run_epochs(golden_regen.POLICY_EPOCHS,
                           counts=golden_regen.policy_counts(),
                           collect_plans=True)
        stats = res.stats
        for e, g in enumerate(golden):
            assert np.asarray(stats.fmmr_now[e]).astype(float).tolist() == g["fmmr_now"], e
            assert np.asarray(stats.fmmr_ewma[e]).astype(float).tolist() == g["fmmr_ewma"], e
            assert np.asarray(stats.fast_pages[e]).tolist() == g["fast_pages"], e
            assert np.asarray(stats.slow_pages[e]).tolist() == g["slow_pages"], e
            assert np.asarray(stats.promoted[e]).tolist() == g["promoted"], e
            assert np.asarray(stats.demoted[e]).tolist() == g["demoted"], e
            plans = res.plans
            assert np.asarray(plans.promote[e]).tolist() == g["promote_ids"], e
            assert np.asarray(plans.demote[e]).tolist() == g["demote_ids"], e
        assert m.tiers().tolist() == golden[-1]["tier"]


# -------------------------------------------------------- churn regression
class TestUnregisterScrubsState:
    def _drive_miss(self, m, h, pages, epochs=4):
        counts = np.zeros(m.num_pages, np.int64)
        counts[pages] = 100
        for _ in range(epochs):
            m.record_access(counts)
            m.run_epoch()

    def test_manager_unregister_clears_fmmr_and_target(self):
        m = CentralManager(num_pages=128, fast_capacity=16, migration_budget=8,
                           max_tenants=4, sample_period=1, exact_sampling=True)
        h = m.register(t_miss=0.1)
        pages = m.allocate(h, 64)  # 48 pages land slow -> nonzero FMMR
        self._drive_miss(m, h, pages)
        assert m.fmmr_of(h) > 0.0
        m.unregister(h)
        assert m.fmmr_of(h) == 0.0, "stale EWMA visible after unregister"
        assert float(m.tenants.t_miss[int(h)]) == 1.0
        assert not bool(m.tenants.flagged[int(h)])
        assert int(m.tenants.cool_epoch[int(h)]) == 0

    def test_manager_handle_reuse_starts_fresh(self):
        m = CentralManager(num_pages=128, fast_capacity=16, migration_budget=8,
                           max_tenants=4, sample_period=1, exact_sampling=True)
        h = m.register(t_miss=0.1)
        pages = m.allocate(h, 64)
        self._drive_miss(m, h, pages, epochs=8)  # also advances cool_epoch
        m.unregister(h)
        h2 = m.register(t_miss=0.9)
        assert int(h2) == int(h), "expected slot reuse"
        assert m.fmmr_of(h2) == 0.0
        assert float(m.tenants.t_miss[int(h2)]) == pytest.approx(0.9)
        # reused slot must behave like a fresh tenant end-to-end
        pages2 = m.allocate(h2, 32)
        self._drive_miss(m, h2, pages2)
        assert (np.asarray(m.pages.owner)[pages2] == int(h2)).all()

    def test_baseline_unregister_drops_fmmr(self):
        for cls in (HeMemStatic, AutoNUMALike, TwoLM):
            b = cls(128, 16)
            h = b.register(0.5)
            pages = b.allocate(h, 64)
            counts = np.zeros(128, np.int64)
            counts[pages] = 50
            b.record_access(counts)
            b.run_epoch()
            assert b.fmmr_of(h) > 0.0, cls.__name__
            b.unregister(h)
            assert b.fmmr_of(h) == 0.0, f"{cls.__name__}: stale EWMA"
            assert h not in b._ewma, cls.__name__

    def test_scenario_churn_reuses_slots_cleanly(self):
        """Arrive/depart/arrive through the engine: the reused manager slot
        must not inherit the departed tenant's QoS state."""
        mgr = CentralManager(num_pages=256, fast_capacity=64, migration_budget=16,
                            max_tenants=2, sample_period=10)
        sim = ColocationSim(mgr, OPTANE, seed=5)
        sc = Scenario(
            name="churn", n_epochs=16,
            events=(
                Arrive(0, WorkloadSpec("x", 128, t_miss=0.1, threads=2,
                                       sets=((0.3, 0.9),))),
                Depart(8, "x"),
                Arrive(10, WorkloadSpec("y", 128, t_miss=1.0, threads=2)),
            ),
        )
        run_scenario(sim, sc, on_event=check_invariants)
        h = sim.handles["y"]
        assert float(mgr.tenants.t_miss[int(h)]) == pytest.approx(1.0)
        assert not bool(mgr.tenants.flagged[int(h)])
