"""Scenario engine + differential invariant harness + golden-trace locks.

Four layers:

1. Scenario-engine semantics: spec validation, phase spans, and that every
   event kind actually perturbs the simulator.
2. Differential invariant harness: MaxMem and all three baselines run the
   SAME scripted and randomized scenarios; conservation invariants are
   asserted after every event and epoch — no page owned by an unregistered
   tenant, fast occupancy <= capacity, tiers exactly partitioned, migration
   traffic <= budget (for budgeted policies).
3. Golden-trace locks: the vectorized baselines replay
   ``tests/golden/baseline_traces.json`` (recorded from the frozen seed
   per-page implementations) bit-for-bit, and ``policy.epoch_step`` /
   ``policy.multi_epoch`` replay ``tests/golden/policy_trace.json``
   bit-identically, so refactors cannot silently change placements.
4. Churn regression: unregister scrubs per-tenant state (manager and
   baselines) — stale EWMA/targets were observable via ``fmmr_of`` before.
"""
import json

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # clean checkout: deterministic fallback sweep
    from _hypothesis_fallback import given, settings, st

import golden_regen
from repro.core.baselines import AutoNUMALike, HeMemStatic, TwoLM
from repro.core.manager import CentralManager
from repro.core.scenario import (
    STORM_FAMILIES,
    Arrive,
    Depart,
    PingPongShift,
    ResizeWorkingSet,
    Retarget,
    Scenario,
    SetMigrationBandwidth,
    ShiftWorkingSet,
    SkewChange,
    adversarial_scenario,
    churn_recovery_epochs,
    diurnal_schedule,
    pingpong_schedule,
    recovery_epochs,
    responsiveness_phases,
    run_scenario,
    storm_health,
    storm_scenario,
)
from repro.core.simulator import OPTANE, ColocationSim, WorkloadSpec
from repro.core.types import TIER_FAST, TIER_NONE, TIER_SLOW

P, FAST, BUDGET = 256, 64, 32


def _backends():
    """All four policies on identical geometry (factories)."""
    return {
        "maxmem": lambda: CentralManager(
            num_pages=P, fast_capacity=FAST, migration_budget=BUDGET,
            max_tenants=8, sample_period=10),
        "hemem": lambda: HeMemStatic(
            P, FAST, partitions={i: FAST // 4 for i in range(8)},
            hot_threshold=6, migration_budget=BUDGET),
        "autonuma": lambda: AutoNUMALike(P, FAST),
        "twolm": lambda: TwoLM(P, FAST),
    }


def _fast_cap(backend) -> int:
    if hasattr(backend, "params"):
        return int(backend.params.fast_capacity)
    return backend.fast_capacity


def _migration_budget(backend):
    if hasattr(backend, "params"):
        return int(backend.params.migration_budget)
    return getattr(backend, "migration_budget", None)


def check_invariants(sim, event=None):
    """The conservation invariants every placement backend must uphold."""
    backend = sim.backend
    tier = np.asarray(backend.tiers())
    owner = np.asarray(backend.owners())
    ctx = f"after {event}" if event is not None else "after epoch"
    # tier domain + exact partition: owned <=> placed, unowned <=> NONE
    assert set(np.unique(tier).tolist()) <= {TIER_NONE, TIER_SLOW, TIER_FAST}, ctx
    owned = owner >= 0
    assert (tier[owned] != TIER_NONE).all(), f"owned page unplaced {ctx}"
    assert (tier[~owned] == TIER_NONE).all(), f"unowned page placed {ctx}"
    # no page owned by an unregistered tenant
    registered = {int(h) for h in sim.handles.values()}
    holders = set(np.unique(owner[owned]).tolist())
    assert holders <= registered, f"orphan owners {holders - registered} {ctx}"
    # fast-tier occupancy bounded by capacity
    assert int((tier == TIER_FAST).sum()) <= _fast_cap(backend), ctx
    # migration-queue conservation (data-plane backends): every entry ever
    # admitted is accounted for as drained, cancelled, dropped or in flight
    if hasattr(backend, "queue_counters"):
        c = backend.queue_counters()
        assert c["enqueued"] == (
            c["drained"] + c["cancelled"] + c["dropped"] + c["depth"]
        ), f"queue conservation broken {ctx}: {c}"


def _scripted_scenario() -> Scenario:
    return Scenario(
        name="scripted_churn",
        n_epochs=30,
        events=(
            Arrive(0, WorkloadSpec("a", 96, t_miss=0.2, threads=2, sets=((0.3, 0.9),))),
            Arrive(0, WorkloadSpec("b", 64, t_miss=1.0, threads=4)),
            Arrive(6, WorkloadSpec("c", 48, t_miss=0.5, threads=2, sets=((0.5, 0.8),))),
            ResizeWorkingSet(10, "a", 0, 0.45),
            SkewChange(14, "c", 0, 0.5),
            ShiftWorkingSet(18, "a"),
            Retarget(20, "b", 0.5),
            Depart(24, "b"),
            Arrive(26, WorkloadSpec("d", 32, t_miss=1.0, threads=2)),
        ),
    )


class TestScenarioSpec:
    def test_phase_spans_cover_run_and_label_events(self):
        sc = _scripted_scenario()
        spans = sc.phase_spans()
        assert spans[0][0] == 0 and spans[-1][1] == sc.n_epochs
        # contiguous, non-overlapping
        for (s0, e0, _), (s1, e1, _) in zip(spans[:-1], spans[1:]):
            assert e0 == s1
        labels = [l for _, _, l in spans]
        assert any("+a" in l for l in labels)
        assert any("-b" in l for l in labels)

    def test_event_epoch_out_of_range_rejected(self):
        with pytest.raises(AssertionError):
            Scenario(name="bad", n_epochs=10,
                     events=(Depart(10, "x"),))

    def test_events_perturb_simulator(self):
        mgr = _backends()["maxmem"]()
        sim = ColocationSim(mgr, OPTANE, seed=0)
        sc = Scenario(
            name="fx", n_epochs=8,
            events=(
                Arrive(0, WorkloadSpec("t", 128, t_miss=1.0, threads=2,
                                       sets=((0.25, 0.9),))),
                Retarget(2, "t", 0.3),
                ResizeWorkingSet(3, "t", 0, 0.5),
                SkewChange(4, "t", 0, 0.6),
                ShiftWorkingSet(5, "t"),
                Depart(6, "t"),
            ),
        )
        seen = []

        def spy(s, ev):
            if isinstance(ev, Retarget):
                assert s.tenants["t"].spec.t_miss == 0.3
                assert float(mgr.tenants.t_miss[s.handles["t"]]) == pytest.approx(0.3)
            if isinstance(ev, ResizeWorkingSet):
                assert s.tenants["t"].spec.sets[0][0] == 0.5
            if isinstance(ev, SkewChange):
                assert s.tenants["t"].spec.sets[0][1] == 0.6
            if isinstance(ev, Depart):
                assert "t" not in s.tenants
            seen.append(type(ev).__name__)

        res = sim.run_scenario(sc, on_event=spy)
        assert seen == ["Arrive", "Retarget", "ResizeWorkingSet", "SkewChange",
                        "ShiftWorkingSet", "Depart"]
        assert len(res.history) == 8
        assert res.steady_state.label == "-t"

    def test_shift_keeps_distribution_but_moves_pages(self):
        mgr = _backends()["maxmem"]()
        sim = ColocationSim(mgr, OPTANE, seed=3)
        sim.add_tenant(WorkloadSpec("t", 128, t_miss=1.0, threads=2,
                                    sets=((0.25, 0.9),)))
        t = sim.tenants["t"]
        before = t.probs.copy()
        t.shift_sets()
        assert not np.array_equal(before, t.probs), "shift moved no pages"
        assert np.allclose(sorted(before), sorted(t.probs)), "shift changed skew"


class TestDifferentialInvariants:
    def test_scripted_scenario_all_policies(self):
        sc = _scripted_scenario()
        for name, make in _backends().items():
            backend = make()
            sim = ColocationSim(backend, OPTANE, seed=7)
            res = run_scenario(sim, sc, on_event=check_invariants)
            check_invariants(sim)
            budget = _migration_budget(backend)
            if budget is not None:
                for rec in res.history:
                    assert rec.migrated_pages <= budget, (
                        f"{name}: migrated {rec.migrated_pages} > budget {budget} "
                        f"at epoch {rec.epoch}"
                    )

    def test_epoch_by_epoch_invariants(self):
        """Invariants hold after EVERY epoch, not just at event boundaries."""
        sc = _scripted_scenario()
        for name, make in _backends().items():
            sim = ColocationSim(make(), OPTANE, seed=11)
            for epoch in range(sc.n_epochs):
                for ev in sc.events_at(epoch):
                    ev.apply(sim)
                    check_invariants(sim, ev)
                sim.run_epoch()
                check_invariants(sim)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16), n_events=st.integers(2, 6))
    def test_randomized_event_schedules(self, seed, n_events):
        sc = _random_scenario(np.random.default_rng(seed), n_events)
        for name, make in _backends().items():
            backend = make()
            sim = ColocationSim(backend, OPTANE, seed=seed)
            res = run_scenario(sim, sc, on_event=check_invariants)
            check_invariants(sim)
            budget = _migration_budget(backend)
            if budget is not None:
                assert all(r.migrated_pages <= budget for r in res.history), name


def _random_scenario(rng: np.random.Generator, n_events: int) -> Scenario:
    """Build a valid random event schedule (arrivals fit memory, departs and
    mutations only target tenants alive at that epoch)."""
    alive = {}
    free_pages = P
    events = []
    idx = 0

    def arrive(epoch):
        nonlocal free_pages, idx
        n = int(rng.integers(16, 49))
        if free_pages - n < 8 or len(alive) >= 6:
            return
        free_pages -= n
        name = f"t{idx}"
        idx += 1
        sets = ((float(rng.uniform(0.2, 0.5)), float(rng.uniform(0.5, 0.95))),)
        spec = WorkloadSpec(name, n, t_miss=float(rng.uniform(0.1, 1.0)),
                            threads=int(rng.integers(1, 5)),
                            sets=sets if rng.random() < 0.7 else ())
        alive[name] = n
        events.append(Arrive(epoch, spec))

    arrive(0)
    arrive(0)
    epoch = 0
    for _ in range(n_events):
        epoch += int(rng.integers(2, 6))
        kind = rng.integers(0, 6)
        names = sorted(alive)
        if kind == 0:
            arrive(epoch)
        elif not names:
            arrive(epoch)
        elif kind == 1 and len(names) > 1:
            victim = names[int(rng.integers(len(names)))]
            events.append(Depart(epoch, victim))
            free_pages += alive.pop(victim)
        else:
            name = names[int(rng.integers(len(names)))]
            ev = [
                lambda: ResizeWorkingSet(epoch, name, 0, float(rng.uniform(0.2, 0.6))),
                lambda: SkewChange(epoch, name, 0, float(rng.uniform(0.4, 0.95))),
                lambda: ShiftWorkingSet(epoch, name),
                lambda: Retarget(epoch, name, float(rng.uniform(0.1, 1.0))),
            ][int(rng.integers(4))]()
            if isinstance(ev, (ResizeWorkingSet, SkewChange)):
                # only meaningful (and valid) when the tenant has skew sets
                spec = next(e.spec for e in events
                            if isinstance(e, Arrive) and e.spec.name == name)
                if not spec.sets:
                    ev = Retarget(epoch, name, 0.5)
            events.append(ev)
    return Scenario(name="random", n_epochs=epoch + 4, events=tuple(events))


class TestBoundedDataPlaneScenario:
    """The finite-bandwidth regime through the scenario engine: new events
    (SetMigrationBandwidth, ping-pong thrash) against the queue-mode
    manager, with conservation + placement invariants after every event
    and epoch."""

    def _bounded_mgr(self):
        return CentralManager(
            num_pages=P, fast_capacity=FAST, migration_budget=BUDGET,
            max_tenants=8, sample_period=10,
            queue_size=2 * BUDGET, migration_bandwidth=BUDGET // 4,
            migration_latency=1,
        )

    def _thrash_scenario(self) -> Scenario:
        return Scenario(
            name="bounded_thrash",
            n_epochs=28,
            events=(
                Arrive(0, WorkloadSpec("a", 96, t_miss=0.2, threads=2,
                                       sets=((0.3, 0.9),))),
                Arrive(0, WorkloadSpec("b", 64, t_miss=0.6, threads=4,
                                       sets=((0.25, 0.8),))),
                SetMigrationBandwidth(4, 2),
                *pingpong_schedule("a", 8, 20, 4),
                Depart(20, "b"),
                SetMigrationBandwidth(24, None),
            ),
        )

    def test_invariants_every_event_and_epoch(self):
        sc = self._thrash_scenario()
        sim = ColocationSim(self._bounded_mgr(), OPTANE, seed=13)
        for epoch in range(sc.n_epochs):
            for ev in sc.events_at(epoch):
                ev.apply(sim)
                check_invariants(sim, ev)
            sim.run_epoch()
            check_invariants(sim)
        assert sim.backend.queue_counters()["enqueued"] > 0

    def test_bandwidth_event_bounds_commits(self):
        """After SetMigrationBandwidth(2), no epoch commits more than 2
        pages until the closing unlimited event."""
        sc = self._thrash_scenario()
        sim = ColocationSim(self._bounded_mgr(), OPTANE, seed=13)
        res = run_scenario(sim, sc, on_event=check_invariants)
        for rec in res.history[4:24]:
            assert rec.migrated_pages <= 2, rec.epoch
        # per-phase data-plane columns are populated
        assert any(p.migration_bytes > 0 for p in res.phases)
        assert any(p.max_queue_depth > 0 for p in res.phases)

    def test_pingpong_toggles_between_two_scatters(self):
        sim = ColocationSim(self._bounded_mgr(), OPTANE, seed=3)
        sim.add_tenant(WorkloadSpec("t", 96, t_miss=1.0, threads=2,
                                    sets=((0.25, 0.9),)))
        t = sim.tenants["t"]
        home = t.probs.copy()
        PingPongShift(0, "t").apply(sim)
        away = t.probs.copy()
        assert not np.array_equal(home, away)
        PingPongShift(0, "t").apply(sim)
        assert np.array_equal(t.probs, home), "second flip must return home"
        PingPongShift(0, "t").apply(sim)
        assert np.array_equal(t.probs, away), "ping-pong must reuse ONE alternate"

    def test_bandwidth_event_clamps_baseline_budget(self):
        b = HeMemStatic(P, FAST, partitions={0: FAST}, hot_threshold=6,
                        migration_budget=BUDGET)
        sim = ColocationSim(b, OPTANE, seed=0)
        sim.add_tenant(WorkloadSpec("x", 128, t_miss=0.5, threads=2,
                                    sets=((0.3, 0.9),)))
        SetMigrationBandwidth(0, 4).apply(sim)
        assert b.migration_budget == 4
        for _ in range(6):
            sim.run_epoch()
            assert sim.history[-1].migrated_pages <= 4
        # None restores the CONFIGURED budget, not a permanent clamp
        SetMigrationBandwidth(0, None).apply(sim)
        assert b.migration_budget == BUDGET

    def test_bandwidth_event_bounds_autonuma(self):
        b = AutoNUMALike(P, FAST)
        sim = ColocationSim(b, OPTANE, seed=1)
        # all accesses land on 30% of pages: the cold tail gives autonuma
        # idle fast pages to evict, so unbounded churn is observable
        sim.add_tenant(WorkloadSpec("x", 200, t_miss=1.0, threads=4,
                                    sets=((0.3, 1.0),)))
        # unbounded warmup churns far more than the clamp
        sim.run_epoch()
        assert sim.history[-1].migrated_pages > 6
        SetMigrationBandwidth(0, 6).apply(sim)
        for _ in range(5):
            sim.tenants["x"].shift_sets()  # keep pressure on the migrator
            sim.run_epoch()
            assert sim.history[-1].migrated_pages <= 6
        SetMigrationBandwidth(0, None).apply(sim)
        assert b.migration_budget is None  # back to unbounded autonuma

    def test_bandwidth_event_is_inapplicable_to_twolm(self):
        """TwoLM is hardware-managed placement: the event must be a safe
        no-op (no attribute invented, behavior unchanged)."""
        b = TwoLM(P, FAST)
        sim = ColocationSim(b, OPTANE, seed=2)
        sim.add_tenant(WorkloadSpec("x", 128, t_miss=1.0, threads=2))
        SetMigrationBandwidth(0, 4).apply(sim)
        assert not hasattr(b, "migration_budget")
        sim.run_epoch()  # still runs


class TestStormScenarios:
    """The adversarial storm suite (DESIGN.md §11) through the differential
    invariant harness: every family, every policy, invariants after every
    event and epoch — plus construction-time validation of the builders."""

    N_EPOCHS = 24

    @pytest.mark.parametrize("family", STORM_FAMILIES)
    def test_storm_family_all_policies_invariants(self, family):
        sc = storm_scenario(family, P, self.N_EPOCHS)
        for name, make in _backends().items():
            backend = make()
            sim = ColocationSim(backend, OPTANE, seed=17)
            res = run_scenario(sim, sc, on_event=check_invariants)
            check_invariants(sim)
            budget = _migration_budget(backend)
            if budget is not None:
                assert all(r.migrated_pages <= budget for r in res.history), name

    def test_composite_storm_guarded_bounded_manager(self):
        """The adversarial composite on the queue-mode manager with every
        guard ON: invariants hold after each epoch and the queue keeps
        conserving under hysteresis + admission + cooldown."""
        sc = adversarial_scenario(P, self.N_EPOCHS, fast_capacity=FAST)
        mgr = CentralManager(
            num_pages=P, fast_capacity=FAST, migration_budget=BUDGET,
            max_tenants=8, sample_period=10,
            queue_size=2 * BUDGET, migration_bandwidth=BUDGET // 4,
            migration_latency=1,
            promote_band=0.12, demote_band=0.04,
            promote_admission=BUDGET // 4, demote_cooldown=3,
        )
        sim = ColocationSim(mgr, OPTANE, seed=19)
        for epoch in range(sc.n_epochs):
            for ev in sc.events_at(epoch):
                ev.apply(sim)
                check_invariants(sim, ev)
            sim.run_epoch()
            check_invariants(sim)
        assert mgr.queue_counters()["enqueued"] > 0

    def test_storm_builders_validate_at_construction(self):
        """Degenerate storm parameters fail loudly at build time (the PR 6
        validation contract), not as silent NaN/empty schedules."""
        with pytest.raises(KeyError, match="unknown storm family"):
            storm_scenario("quake", P, 24)
        with pytest.raises(ValueError, match="n_epochs"):
            storm_scenario("boundary", P, 4)
        with pytest.raises(ValueError, match="too thin"):
            storm_scenario("boundary", 64, 24)
        for eps in (0.0, 0.5, -0.1, float("nan")):
            with pytest.raises(ValueError, match="epsilon"):
                storm_scenario("boundary", P, 24, epsilon=eps)
        with pytest.raises(ValueError, match="flippers"):
            storm_scenario("correlated", P, 24, n_flippers=1)
        with pytest.raises(ValueError, match="burst"):
            storm_scenario("burst", P, 24, burst=0)
        for period in (0, -3):
            with pytest.raises(ValueError, match="period"):
                pingpong_schedule("t", 4, 12, period)
            with pytest.raises(ValueError, match="period"):
                diurnal_schedule("t", 4, 12, period)
        with pytest.raises(ValueError, match="window is empty"):
            pingpong_schedule("t", 12, 12, 2)
        with pytest.raises(ValueError, match="window is empty"):
            diurnal_schedule("t", 12, 4, 2)
        for lo, hi in ((-0.1, 0.9), (0.2, 1.5), (float("nan"), 0.9)):
            with pytest.raises(ValueError, match="diurnal"):
                diurnal_schedule("t", 0, 12, 4, lo=lo, hi=hi)
        with pytest.raises(ValueError, match="lo <= hi"):
            diurnal_schedule("t", 0, 12, 4, lo=0.9, hi=0.2)

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_randomized_storm_parameters(self, seed):
        """Randomized storm shapes (family, flip period, epsilon, burst
        width) through every policy with invariants at every event."""
        rng = np.random.default_rng(seed)
        family = STORM_FAMILIES[int(rng.integers(len(STORM_FAMILIES)))]
        kw = {}
        if family == "boundary":
            kw = dict(epsilon=float(rng.uniform(0.02, 0.4)),
                      period=int(rng.integers(2, 6)))
        elif family == "correlated":
            kw = dict(n_flippers=int(rng.integers(2, 5)),
                      period=int(rng.integers(2, 6)))
        elif family == "burst":
            kw = dict(burst=int(rng.integers(1, 4)))
        else:
            kw = dict(lo=float(rng.uniform(0.1, 0.4)),
                      hi=float(rng.uniform(0.5, 1.0)))
        sc = storm_scenario(family, P, int(rng.integers(16, 33)), **kw)
        for name, make in _backends().items():
            sim = ColocationSim(make(), OPTANE, seed=seed)
            run_scenario(sim, sc, on_event=check_invariants)
            check_invariants(sim)


class TestResponsiveness:
    """ResponsivenessStats + storm_health: the recovery metric and the
    storm-health counters the adversarial bench gates on."""

    def _run_composite(self, **guard_kw):
        sc = adversarial_scenario(P, 32, fast_capacity=FAST)
        mgr = CentralManager(
            num_pages=P, fast_capacity=FAST, migration_budget=BUDGET,
            max_tenants=8, sample_period=1, exact_sampling=True,
            queue_size=2 * BUDGET, migration_bandwidth=BUDGET // 4,
            migration_latency=1, **guard_kw,
        )
        sim = ColocationSim(mgr, OPTANE, seed=23)
        return mgr, run_scenario(sim, sc, on_event=check_invariants)

    def test_phase_flow_counters_sum_to_manager_totals(self):
        mgr, res = self._run_composite()
        phases = responsiveness_phases(res)
        assert [p.label for p in phases] == [p.label for p in res.phases]
        c = mgr.queue_counters()
        assert sum(p.enqueued for p in phases) == c["enqueued"]
        assert sum(p.drained for p in phases) == c["drained"]
        assert sum(p.cancelled for p in phases) == c["cancelled"]
        assert c["enqueued"] > 0

    def test_recovery_keys_name_affected_tenants(self):
        _, res = self._run_composite()
        phases = responsiveness_phases(res)
        keyed = [p for p in phases if p.recovery]
        assert keyed, "storm produced no recovery-scored phases"
        for p in keyed:
            assert set(p.recovery) <= {"edge", "flip", "*"}, p.recovery
            assert all(v >= 0 for v in p.recovery.values())
        # epoch-0 arrivals have no baseline: never scored
        assert not phases[0].recovery

    def test_storm_health_summary_is_jsonable_and_consistent(self):
        _, res = self._run_composite()
        h = storm_health(res)
        json.dumps(h)  # must be committable as bench payload
        worst = max(
            (v for rec in h["recovery_epochs"].values() for v in rec.values()),
            default=0,
        )
        assert h["worst_recovery_epochs"] == worst
        assert h["cancel_ratio"] == pytest.approx(
            h["cancelled"] / max(h["drained"], 1))
        assert h["pingpong_rate"] == pytest.approx(
            h["cancelled"] / max(h["enqueued"], 1))

    def test_recovery_epochs_reexported_by_hillclimb(self):
        """The PR 8 online-tuner metric moved here; the tuner re-exports it
        so existing call sites keep working."""
        from repro.launch.hillclimb import recovery_epochs as tuner_metric
        assert tuner_metric is recovery_epochs

    def test_churn_recovery_counts_epochs_to_balance(self):
        """Queue-axis recovery: first epoch at/after the event whose
        enqueue/drain balance is non-positive; never = whole window."""
        from types import SimpleNamespace

        def _h(flows):
            return [SimpleNamespace(queue_enqueued=e, queue_drained=d)
                    for e, d in flows]

        # storm at epoch 2, balance closes at epoch 5
        h = _h([(4, 4), (4, 4), (30, 4), (20, 4), (9, 4), (4, 4), (4, 4)])
        assert churn_recovery_epochs(h, 2) == 3
        # already balanced at the event: instant
        assert churn_recovery_epochs(h, 5) == 0
        # never balances: scores the remaining window
        sat = _h([(30, 4)] * 8)
        assert churn_recovery_epochs(sat, 3) == 5

    def test_churn_recovery_on_live_storm(self):
        """On the composite storm the flow records feed the metric directly:
        a guarded manager's balance closes within the run, and the metric
        agrees with a hand check of the recorded flow columns."""
        _, res = self._run_composite(
            promote_band=0.12, demote_band=0.04,
            promote_admission=2, demote_cooldown=3,
        )
        starts = [s for s, _e, _l in res.scenario.phase_spans() if s > 0]
        for s in starts:
            rec = churn_recovery_epochs(res.history, s)
            assert 0 <= rec <= len(res.history) - s
            if rec < len(res.history) - s:
                r = res.history[s + rec]
                assert r.queue_enqueued - r.queue_drained <= 0


# ------------------------------------------------------------ golden locks
class TestGoldenTraces:
    def test_vectorized_baselines_replay_seed_golden(self):
        """The parity lock: identical placements to the recorded seed
        per-page implementations, every epoch of the churn trace."""
        import repro.core.baselines as live

        with open(golden_regen.BASELINE_TRACE_PATH) as f:
            golden = json.load(f)["traces"]
        for name, make in golden_regen.backend_factories(live).items():
            got = golden_regen.drive_baseline(make)
            assert len(got) == len(golden[name])
            for e, (g, n) in enumerate(zip(golden[name], got)):
                assert n["tier"] == g["tier"], f"{name} epoch {e}: tier diverged"
                assert n["owner"] == g["owner"], f"{name} epoch {e}: owner diverged"
                assert n["promoted"] == g["promoted"], f"{name} epoch {e}"
                assert n["demoted"] == g["demoted"], f"{name} epoch {e}"
                assert n["fmmr"] == g["fmmr"], f"{name} epoch {e}: fmmr diverged"

    def test_policy_epoch_step_replays_golden(self):
        with open(golden_regen.POLICY_TRACE_PATH) as f:
            golden = json.load(f)["epochs"]
        got = golden_regen.drive_policy_singlestep()
        assert len(got) == len(golden)
        for e, (g, n) in enumerate(zip(golden, got)):
            for key in g:
                assert n[key] == g[key], f"epoch {e}: {key} diverged"

    def test_policy_multi_epoch_replays_golden(self):
        """The fused lax.scan path reproduces the recorded single-step
        trace bit-identically (exact sampling)."""
        with open(golden_regen.POLICY_TRACE_PATH) as f:
            golden = json.load(f)["epochs"]
        m = golden_regen.make_policy_manager()
        res = m.run_epochs(golden_regen.POLICY_EPOCHS,
                           counts=golden_regen.policy_counts(),
                           collect_plans=True)
        stats = res.stats
        for e, g in enumerate(golden):
            assert np.asarray(stats.fmmr_now[e]).astype(float).tolist() == g["fmmr_now"], e
            assert np.asarray(stats.fmmr_ewma[e]).astype(float).tolist() == g["fmmr_ewma"], e
            assert np.asarray(stats.fast_pages[e]).tolist() == g["fast_pages"], e
            assert np.asarray(stats.slow_pages[e]).tolist() == g["slow_pages"], e
            assert np.asarray(stats.promoted[e]).tolist() == g["promoted"], e
            assert np.asarray(stats.demoted[e]).tolist() == g["demoted"], e
            plans = res.plans
            assert np.asarray(plans.promote[e]).tolist() == g["promote_ids"], e
            assert np.asarray(plans.demote[e]).tolist() == g["demote_ids"], e
        assert m.tiers().tolist() == golden[-1]["tier"]


# -------------------------------------------------------- churn regression
class TestUnregisterScrubsState:
    def _drive_miss(self, m, h, pages, epochs=4):
        counts = np.zeros(m.num_pages, np.int64)
        counts[pages] = 100
        for _ in range(epochs):
            m.record_access(counts)
            m.run_epoch()

    def test_manager_unregister_clears_fmmr_and_target(self):
        m = CentralManager(num_pages=128, fast_capacity=16, migration_budget=8,
                           max_tenants=4, sample_period=1, exact_sampling=True)
        h = m.register(t_miss=0.1)
        pages = m.allocate(h, 64)  # 48 pages land slow -> nonzero FMMR
        self._drive_miss(m, h, pages)
        assert m.fmmr_of(h) > 0.0
        m.unregister(h)
        assert m.fmmr_of(h) == 0.0, "stale EWMA visible after unregister"
        assert float(m.tenants.t_miss[int(h)]) == 1.0
        assert not bool(m.tenants.flagged[int(h)])
        assert int(m.tenants.cool_epoch[int(h)]) == 0

    def test_manager_handle_reuse_starts_fresh(self):
        m = CentralManager(num_pages=128, fast_capacity=16, migration_budget=8,
                           max_tenants=4, sample_period=1, exact_sampling=True)
        h = m.register(t_miss=0.1)
        pages = m.allocate(h, 64)
        self._drive_miss(m, h, pages, epochs=8)  # also advances cool_epoch
        m.unregister(h)
        h2 = m.register(t_miss=0.9)
        assert int(h2) == int(h), "expected slot reuse"
        assert m.fmmr_of(h2) == 0.0
        assert float(m.tenants.t_miss[int(h2)]) == pytest.approx(0.9)
        # reused slot must behave like a fresh tenant end-to-end
        pages2 = m.allocate(h2, 32)
        self._drive_miss(m, h2, pages2)
        assert (np.asarray(m.pages.owner)[pages2] == int(h2)).all()

    def test_baseline_unregister_drops_fmmr(self):
        for cls in (HeMemStatic, AutoNUMALike, TwoLM):
            b = cls(128, 16)
            h = b.register(0.5)
            pages = b.allocate(h, 64)
            counts = np.zeros(128, np.int64)
            counts[pages] = 50
            b.record_access(counts)
            b.run_epoch()
            assert b.fmmr_of(h) > 0.0, cls.__name__
            b.unregister(h)
            assert b.fmmr_of(h) == 0.0, f"{cls.__name__}: stale EWMA"
            assert h not in b._ewma, cls.__name__

    def test_scenario_churn_reuses_slots_cleanly(self):
        """Arrive/depart/arrive through the engine: the reused manager slot
        must not inherit the departed tenant's QoS state."""
        mgr = CentralManager(num_pages=256, fast_capacity=64, migration_budget=16,
                            max_tenants=2, sample_period=10)
        sim = ColocationSim(mgr, OPTANE, seed=5)
        sc = Scenario(
            name="churn", n_epochs=16,
            events=(
                Arrive(0, WorkloadSpec("x", 128, t_miss=0.1, threads=2,
                                       sets=((0.3, 0.9),))),
                Depart(8, "x"),
                Arrive(10, WorkloadSpec("y", 128, t_miss=1.0, threads=2)),
            ),
        )
        run_scenario(sim, sc, on_event=check_invariants)
        h = sim.handles["y"]
        assert float(mgr.tenants.t_miss[int(h)]) == pytest.approx(1.0)
        assert not bool(mgr.tenants.flagged[int(h)])
