"""Million-page scale push tests (DESIGN.md §10).

Covers the three tentpole mechanisms of the scaling PR:

  * tiled integer cumsums (``core/tiling.py``) — bit-identical to the
    plain scan across the trace-selection threshold, on every axis and
    dtype the tick uses, and the whole fused epoch unchanged when the
    tiling heuristic flips;
  * packed state layouts (``core/types.py``) — dtype-width contracts for
    the i16 owner / i8 queue heat leaves and the ``MAX_TENANT_SLOTS``
    guard, plus the ``state_nbytes`` audit helper;
  * incremental ``OwnerSegments`` (``types.segments_update_host`` +
    the CentralManager delta wiring) — bit-identical to the from-scratch
    sort at T >= 256 under heavy register/allocate/free/unregister churn,
    with the permutation invariants checked after EVERY mutation.

Plus the scaling-bench scaffolding: the geometry-parameterized
``scale_colocation`` scenario, the log-log slope fit, and the fleet
``live_bytes`` accounting.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tiling
from repro.core.manager import CentralManager
from repro.core.types import (
    MAX_TENANT_SLOTS,
    MigrationQueue,
    OwnerSegments,
    PageState,
    PolicyState,
    segments_build_host,
    segments_update_host,
    state_nbytes,
)


# ------------------------------------------------------------ tiled cumsum
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.uint32])
@pytest.mark.parametrize(
    "n",
    [
        1,
        tiling.CUMSUM_BLOCK - 1,
        tiling.CUMSUM_BLOCK,
        tiling.CUMSUM_TILE_THRESHOLD,  # last untiled size
        tiling.CUMSUM_TILE_THRESHOLD + 1,  # first tiled size
        tiling.CUMSUM_TILE_THRESHOLD + tiling.CUMSUM_BLOCK // 2,  # ragged pad
        4 * tiling.CUMSUM_TILE_THRESHOLD + 17,
    ],
)
def test_tiled_cumsum_bit_identical_1d(dtype, n):
    rng = np.random.default_rng(n)
    lo = 0 if np.issubdtype(np.dtype(dtype), np.unsignedinteger) else -1000
    x = jnp.asarray(rng.integers(lo, 1000, n), dtype)
    got = tiling.tiled_cumsum(x)
    want = jnp.cumsum(x)
    assert got.dtype == want.dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_tiled_cumsum_bit_identical_2d_rows():
    # the [T, C] cutoff-table shape: cumsum along axis=1 with a long row
    rng = np.random.default_rng(0)
    n = tiling.CUMSUM_TILE_THRESHOLD + 3 * tiling.CUMSUM_BLOCK + 7
    x = jnp.asarray(rng.integers(-50, 50, (3, n)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(tiling.tiled_cumsum(x, axis=1)),
        np.asarray(jnp.cumsum(x, axis=1)),
    )
    # non-trailing scanned axis exercises the moveaxis path
    np.testing.assert_array_equal(
        np.asarray(tiling.tiled_cumsum(x.T, axis=0)),
        np.asarray(jnp.cumsum(x.T, axis=0)),
    )


def test_tiled_cumsum_float_falls_back_to_plain_scan():
    # float addition does not reassociate losslessly -> must NOT tile
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=tiling.CUMSUM_TILE_THRESHOLD + 5), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(tiling.tiled_cumsum(x)), np.asarray(jnp.cumsum(x))
    )


def test_full_epoch_identical_across_tiling_threshold():
    """The whole fused tick is bit-identical whichever trace the heuristic
    selects: run one epoch at a tiled size, then force the plain-scan trace
    by raising the threshold, and compare every output leaf."""
    from benchmarks.scale_bench import make_scale_state, _scale_params
    from repro.core import policy

    P, T, R = tiling.CUMSUM_TILE_THRESHOLD + 8192, 64, 512
    st = make_scale_state(P, T, seed=7)
    params = _scale_params(P, R)

    def one_epoch():
        policy._jitted_epoch_step.cache_clear()  # drop the cached jit trace
        s2, plan, stats = policy.epoch_step(
            st, params, max_tenants=T, plan_size=R)
        return (
            np.asarray(s2.pages.tier), np.asarray(s2.pages.count),
            np.asarray(plan.promote), np.asarray(plan.demote),
            np.asarray(stats.fmmr_now), np.asarray(stats.fast_pages),
        )

    tiled = one_epoch()
    old = tiling.CUMSUM_TILE_THRESHOLD
    tiling.CUMSUM_TILE_THRESHOLD = P  # next trace keeps the plain scans
    try:
        plain = one_epoch()
    finally:
        tiling.CUMSUM_TILE_THRESHOLD = old
        policy._jitted_epoch_step.cache_clear()
    for a, b in zip(tiled, plain):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------- packed layouts
def test_packed_dtype_contracts():
    pages = PageState.create(64)
    assert pages.owner.dtype == jnp.int16  # MAX_TENANT_SLOTS fits i16
    assert pages.tier.dtype == jnp.int8
    assert pages.count.dtype == jnp.uint32  # NOT narrowable: see docstring
    q = MigrationQueue.create(32)
    assert q.heat.dtype == jnp.int8  # heat bins bounded by num_bins-1
    st = PolicyState.create(256, 16, queue_size=32)
    assert st.pages.owner.dtype == jnp.int16
    assert st.queue.heat.dtype == jnp.int8


def test_max_tenant_slots_guard():
    assert MAX_TENANT_SLOTS == 32767  # i16 positive range
    with pytest.raises(AssertionError):
        PolicyState.create(64, MAX_TENANT_SLOTS + 1)


def test_state_nbytes_counts_leaf_widths():
    st = PolicyState.create(1024, 8)
    n = state_nbytes(st)
    assert n == sum(
        int(np.size(leaf)) * np.dtype(leaf.dtype).itemsize
        for leaf in __import__("jax").tree_util.tree_leaves(st)
        if hasattr(leaf, "dtype")
    )
    # owner at i16 vs the old i32: the delta is exactly 2 bytes/page
    wide = st._replace(pages=st.pages._replace(
        owner=st.pages.owner.astype(jnp.int32)))
    assert state_nbytes(wide) - n == 2 * 1024


# ------------------------------------------------- incremental OwnerSegments
def _assert_segs_valid(order, inv, start, owner, T):
    P = len(owner)
    # permutation + inverse
    assert np.array_equal(np.sort(order), np.arange(P))
    assert np.array_equal(inv[order], np.arange(P))
    # start offsets: monotone, bracketed, consistent with per-tenant counts
    assert start[0] == 0 and len(start) == T + 1
    assert np.all(np.diff(start) >= 0)
    counts = np.bincount(owner[owner >= 0], minlength=T)
    assert np.array_equal(np.diff(start), counts)
    # segment contents: tenant t's window holds exactly its pages, id-sorted
    for t in np.unique(owner[owner >= 0]):
        seg = order[start[t]:start[t + 1]]
        assert np.array_equal(seg, np.flatnonzero(owner == t))
    # unowned tail id-sorted after the owned windows
    tail = order[start[T]:]
    assert np.array_equal(tail, np.flatnonzero(owner < 0))


def test_segments_update_bit_identical_high_tenant_churn():
    """T=320 with heavy mixed churn: every incremental splice must equal
    the from-scratch sort bit for bit, and the permutation invariants must
    hold after every mutation batch."""
    P, T = 8192, 320
    rng = np.random.default_rng(42)
    owner = rng.integers(-1, T, P).astype(np.int16)
    order, inv, start = segments_build_host(owner, T)
    _assert_segs_valid(order, inv, start, owner, T)
    for step in range(40):
        d = int(rng.integers(1, 400))
        changed = rng.choice(P, size=d, replace=False)
        new_owner = owner.copy()
        if step % 3 == 0:  # mass-free wave: pages -> unowned
            new_owner[changed] = -1
        elif step % 3 == 1:  # mass-register wave: one tenant absorbs all
            new_owner[changed] = int(rng.integers(0, T))
        else:  # scattered reassignment
            new_owner[changed] = rng.integers(-1, T, d)
        changed = changed[new_owner[changed] != owner[changed]]
        if changed.size == 0:
            continue
        order, inv, start = segments_update_host(
            order, inv, start, owner, new_owner, changed, T)
        owner = new_owner
        ref_order, ref_inv, ref_start = segments_build_host(owner, T)
        np.testing.assert_array_equal(order, ref_order)
        np.testing.assert_array_equal(inv, ref_inv)
        np.testing.assert_array_equal(start, ref_start)
        _assert_segs_valid(order, inv, start, owner, T)


def test_manager_incremental_segs_through_churn_t256():
    """CentralManager at T=256: interleaved register/allocate/run/free/
    unregister keeps the lazily patched segments identical to a full
    rebuild of the current owner array."""
    P, T = 4096, 256
    m = CentralManager(
        num_pages=P, fast_capacity=P // 4, migration_budget=64,
        max_tenants=T, sample_period=100, seed=0,
    )
    rng = np.random.default_rng(3)
    handles = []
    for _ in range(T // 2):  # initial cohort
        h = m.register(t_miss=0.5)
        m.allocate(h, int(rng.integers(4, 12)))
        handles.append(h)

    def check():
        m._ensure_segs()
        segs = m._state.segs
        assert segs is not None
        owner = np.asarray(m.pages.owner)
        ref = segments_build_host(owner, T)
        np.testing.assert_array_equal(np.asarray(segs.order), ref[0])
        np.testing.assert_array_equal(np.asarray(segs.inv), ref[1])
        np.testing.assert_array_equal(np.asarray(segs.start), ref[2])

    check()
    for step in range(24):
        op = step % 4
        if op == 0 and handles:  # partial free
            h = handles[int(rng.integers(0, len(handles)))]
            owned = np.flatnonzero(np.asarray(m.pages.owner) == int(h))
            if len(owned) > 1:
                m.free(h, owned[: len(owned) // 2])
        elif op == 1:  # depart
            if handles:
                m.unregister(handles.pop(int(rng.integers(0, len(handles)))))
        elif op == 2:  # arrive
            h = m.register(t_miss=float(rng.uniform(0.2, 1.0)))
            m.allocate(h, int(rng.integers(4, 12)))
            handles.append(h)
        else:  # epochs consume the segments on-device
            m.record_access(rng.poisson(3, P).astype(np.int64))
            m.run_epoch()
        check()


# --------------------------------------------------- scale bench scaffolding
def test_scale_colocation_geometry():
    from repro.core.scenario import Arrive, Depart, scale_colocation

    sc = scale_colocation(65536, 16, 16)
    arrivals = [e for e in sc.events if isinstance(e, Arrive)]
    departs = [e for e in sc.events if isinstance(e, Depart)]
    assert len(arrivals) == 16 and len(departs) == 4  # churn=0.25
    # peak-concurrency footprints must fit the page pool with headroom
    assert sum(a.spec.n_pages for a in arrivals) <= 65536
    # churn cohort: arrives strictly inside the run, departs later
    churn_names = {d.name for d in departs}
    for a in arrivals:
        if a.spec.name in churn_names:
            assert 0 < a.epoch < min(d.epoch for d in departs)
    with pytest.raises(AssertionError):
        scale_colocation(64, 16, 16)  # geometry too thin


def test_fit_slope():
    from benchmarks.scale_bench import fit_slope

    sizes = [65536, 262144, 1048576]
    assert fit_slope(sizes, [s / 1000 for s in sizes]) == pytest.approx(1.0)
    assert fit_slope(sizes, [7.0, 7.0, 7.0]) == pytest.approx(0.0)
    assert fit_slope(sizes, [s ** 1.5 for s in sizes]) == pytest.approx(1.5)


def test_fleet_live_bytes_scales_with_machines():
    from repro.core.fleet import FleetManager

    def mk(k):
        ms = []
        for seed in range(k):
            m = CentralManager(
                num_pages=1024, fast_capacity=256, migration_budget=32,
                max_tenants=8, seed=seed,
            )
            h = m.register(t_miss=0.5)
            m.allocate(h, 128)
            ms.append(m)
        return FleetManager(ms, devices=1)

    f1, f2 = mk(1), mk(2)
    b1, b2 = f1.live_bytes(), f2.live_bytes()
    assert b1 > 0 and b2 == 2 * b1  # per-page leaves stack along K
    # live_bytes is the stacked pytree's audit sum, not an estimate
    assert b1 == state_nbytes(f1._fstate)
