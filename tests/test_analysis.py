"""Unit tests for the HLO cost parser and roofline math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo_cost as H
from repro.analysis.roofline import PEAK_FLOPS, compute_terms, model_flops_per_step
from repro.configs import get_config, get_shape


def _compile(f, *shapes):
    return jax.jit(f).lower(*shapes).compile()


class TestHloCost:
    def test_scan_trip_count_multiplies_flops(self):
        def make(L):
            def f(x, w):
                def body(c, _):
                    return jnp.tanh(c @ w), None
                return jax.lax.scan(body, x, None, length=L)[0]
            return f

        x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        for L in (1, 3, 7):
            mc = H.module_cost(_compile(make(L), x, w).as_text())
            assert mc.flops == pytest.approx(2 * 64 * 128 * 128 * L, rel=1e-6), L

    def test_nested_scan_trip_counts_compose(self):
        def f(x, w):
            def outer(c, _):
                def inner(ci, _):
                    return ci @ w, None
                c2, _ = jax.lax.scan(inner, c, None, length=4)
                return c2, None
            return jax.lax.scan(outer, x, None, length=3)[0]

        x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        mc = H.module_cost(_compile(f, x, w).as_text())
        assert mc.flops == pytest.approx(2 * 32 * 64 * 64 * 12, rel=1e-6)

    def test_dot_flops_from_contracting_dims(self):
        def f(a, b):
            return jnp.einsum("bij,bjk->bik", a, b)

        a = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
        b = jax.ShapeDtypeStruct((4, 16, 32), jnp.float32)
        mc = H.module_cost(_compile(f, a, b).as_text())
        assert mc.flops == pytest.approx(2 * 4 * 8 * 32 * 16, rel=1e-6)

    def test_shape_parsing_tuple_with_index_comments(self):
        # the bug that broke instruction parsing: /*index=5*/ inside tuples
        comps, entry = H.parse_module(
            "ENTRY %main (p: f32[4]) -> f32[4] {\n"
            "  %t = (f32[2,4]{1,0}, s32[]{}, /*index=2*/f32[8]{0}) tuple(%a, %b, %c)\n"
            "  ROOT %r = f32[4]{0} add(%p, %p)\n"
            "}\n"
        )
        assert entry == "main"
        kinds = [i.kind for i in comps["main"].instrs]
        assert kinds == ["tuple", "add"]

    def test_bytes_slicing_semantics(self):
        elems, nbytes = H.shape_elems_bytes("bf16[8,128]{1,0}")
        assert elems == 1024 and nbytes == 2048


class TestRoofline:
    def test_terms_and_dominance(self):
        cfg = get_config("yi-6b")
        shape = get_shape("train_4k")
        t = compute_terms(cfg, shape, 256, flops_per_device=1e15,
                          bytes_per_device=1e13, collective_bytes_dev=1e11)
        assert t.compute_s == pytest.approx(1e15 / PEAK_FLOPS)
        assert t.dominant == "memory"
        assert 0 < t.roofline_fraction <= 1

    def test_model_flops_train_scales_with_tokens(self):
        cfg = get_config("qwen2.5-3b")
        f_train = model_flops_per_step(cfg, get_shape("train_4k"), 256)
        f_decode = model_flops_per_step(cfg, get_shape("decode_32k"), 256)
        # train processes 1M tokens with fwd+bwd; decode 128 tokens fwd-only
        assert f_train > 1000 * f_decode
        # 6·N·D lower bound (attention term only adds)
        n = cfg.active_param_count()
        assert f_train >= 6.0 * n * 256 * 4096

    def test_moe_uses_active_params(self):
        cfg = get_config("moonshot-v1-16b-a3b")
        assert cfg.active_param_count() < 0.3 * cfg.param_count()
