"""Bounded-bandwidth migration data plane (DESIGN.md §4).

Locks the four contracts that make the queue safe to land:

1. Degeneracy — queue mode with unlimited bandwidth and zero latency is
   bit-identical to instant apply (placements, plans, stats), epoch by
   epoch, on both the fused single step and the ``lax.scan`` path.
2. Bounded drain — commits per epoch never exceed the bandwidth, entries
   respect the latency floor, FIFO order holds within a direction, and
   fast-tier occupancy never exceeds capacity mid-flight.
3. Conservation — cumulative enqueued == drained + cancelled + dropped +
   in-flight depth after every epoch, including across free() scrubs.
4. Pool-backed data plane — the Pallas page-move executor keeps page
   contents intact across arbitrary migration schedules and keeps the
   frame table consistent with the tier metadata.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import policy
from repro.core.manager import CentralManager
from repro.core.types import (
    DIR_DEMOTE,
    DIR_NONE,
    DIR_PROMOTE,
    TIER_FAST,
    MigrationQueue,
    PolicyParams,
    PolicyState,
    TIER_SLOW,
)

P, T, FAST, BUDGET = 128, 3, 32, 16


def _mgr(queue_size=0, bandwidth=None, latency=0, data_plane_elems=None, seed=3,
         **kw):
    return CentralManager(
        num_pages=P, fast_capacity=FAST, migration_budget=BUDGET,
        max_tenants=T, sample_period=1, exact_sampling=True, seed=seed,
        queue_size=queue_size, migration_bandwidth=bandwidth,
        migration_latency=latency, data_plane_elems=data_plane_elems, **kw,
    )


def _populate(m):
    handles = []
    for n_pages, t_miss in ((60, 0.1), (40, 0.8)):
        h = m.register(t_miss)
        handles.append((h, m.allocate(h, n_pages)))
    return handles


def _counts(rng):
    c = np.zeros(P, np.int64)
    hot = rng.choice(P, 24, replace=False)
    c[hot] = rng.integers(20, 200, 24)
    return c


class TestDegeneracy:
    def test_unlimited_bandwidth_is_bit_identical_to_instant(self):
        """bandwidth=inf, latency=0: the queue drains fully every epoch and
        every observable matches the instant-apply engine exactly."""
        rng = np.random.default_rng(0)
        a, b = _mgr(queue_size=0), _mgr(queue_size=2 * BUDGET)
        _populate(a), _populate(b)
        for e in range(10):
            c = _counts(rng)
            a.record_access(c)
            b.record_access(c)
            ra, rb = a.run_epoch(), b.run_epoch()
            assert (a.tiers() == b.tiers()).all(), e
            assert (np.asarray(ra.plan.promote) == np.asarray(rb.plan.promote)).all(), e
            assert (np.asarray(ra.plan.demote) == np.asarray(rb.plan.demote)).all(), e
            np.testing.assert_array_equal(
                np.asarray(ra.stats.fmmr_ewma), np.asarray(rb.stats.fmmr_ewma), str(e)
            )
            assert rb.queue_depth == 0, e
            assert rb.migrated_pages == ra.migrated_pages, e

    def test_unlimited_bandwidth_scan_path_matches_instant(self):
        rng = np.random.default_rng(1)
        a, b = _mgr(queue_size=0), _mgr(queue_size=2 * BUDGET)
        _populate(a), _populate(b)
        counts = np.stack([_counts(rng) for _ in range(6)])
        ra = a.run_epochs(6, counts=counts, collect_plans=True)
        rb = b.run_epochs(6, counts=counts, collect_plans=True)
        assert (a.tiers() == b.tiers()).all()
        np.testing.assert_array_equal(
            np.asarray(ra.plans.promote), np.asarray(rb.plans.promote)
        )
        np.testing.assert_array_equal(ra.migrated_per_epoch, rb.migrated_per_epoch)
        assert (rb.queue_depth_per_epoch == 0).all()


class TestBoundedDrain:
    def test_commits_capped_by_bandwidth_and_capacity_held(self):
        rng = np.random.default_rng(2)
        bw = 3
        m = _mgr(queue_size=64, bandwidth=bw)
        _populate(m)
        for e in range(16):
            m.record_access(_counts(rng))
            r = m.run_epoch()
            assert r.migrated_pages <= bw, e
            assert int((m.tiers() == TIER_FAST).sum()) <= FAST, e

    def test_latency_floor(self):
        """No entry commits before spending ``latency`` epochs in flight."""
        rng = np.random.default_rng(3)
        m = _mgr(queue_size=64, bandwidth=None, latency=2)
        _populate(m)
        m.record_access(_counts(rng))
        r1 = m.run_epoch()  # selections enqueue, nothing eligible yet
        assert r1.migrated_pages == 0
        assert r1.queue_depth == int(r1.stats.queue.enqueued)
        r2 = m.run_epoch()
        assert r2.migrated_pages == 0  # age 1 < latency
        r3 = m.run_epoch()  # age 2 == latency: first batch commits
        assert r3.migrated_pages > 0 or r3.queue_depth == 0

    def test_fifo_within_direction(self):
        """Older queued promotions commit before newer ones."""
        rng = np.random.default_rng(4)
        m = _mgr(queue_size=64, bandwidth=2)
        _populate(m)
        seen_epochs = {}
        for e in range(12):
            m.record_access(_counts(rng))
            r = m.run_epoch()
            q = r.stats.queue
            ids = np.asarray(q.drained_promote_ids)
            for p in ids[ids >= 0]:
                seen_epochs.setdefault(int(p), e)
        # the queue state itself must be front-compacted FIFO: enqueue
        # epochs never decrease along the array
        qs = m._state.queue
        pages = np.asarray(qs.page)
        enq = np.asarray(qs.enqueue_epoch)[pages >= 0]
        assert (np.diff(enq) >= 0).all()

    def test_thrash_guard_cancels_reheated_demotions(self):
        """A queued demotion whose page re-heats is cancelled, not drained."""
        m = _mgr(queue_size=64, bandwidth=0)  # bandwidth 0: nothing drains
        h0, p0 = _populate(m)[0]
        cold_fast = [int(p) for p in p0 if m.tier_of([p])[0] == TIER_FAST][:4]
        # heat everything EXCEPT the cold fast pages -> they get demote-queued
        c = np.zeros(P, np.int64)
        hot = [int(p) for p in p0 if int(p) not in cold_fast]
        c[hot] = 50
        m.record_access(c)
        m.run_epoch()
        qs = m._state.queue
        queued_dem = set(
            np.asarray(qs.page)[
                (np.asarray(qs.page) >= 0)
                & (np.asarray(qs.direction) == DIR_DEMOTE)
            ].tolist()
        )
        assert queued_dem & set(cold_fast), "expected queued demotions"
        # now the queued pages become the hottest pages in the pool
        c2 = np.zeros(P, np.int64)
        c2[list(queued_dem)] = 500
        m.record_access(c2)
        r = m.run_epoch()
        assert int(r.stats.queue.cancelled) > 0
        still = np.asarray(m._state.queue.page)
        dirs = np.asarray(m._state.queue.direction)
        remaining_dem = set(still[(still >= 0) & (dirs == DIR_DEMOTE)].tolist())
        assert not (remaining_dem & queued_dem), "re-heated demotion survived"


class TestConservation:
    def test_counters_balance_every_epoch(self):
        rng = np.random.default_rng(5)
        m = _mgr(queue_size=24, bandwidth=2, latency=1)
        handles = _populate(m)
        for e in range(20):
            m.record_access(_counts(rng))
            m.run_epoch()
            c = m.queue_counters()
            assert c["enqueued"] == (
                c["drained"] + c["cancelled"] + c["dropped"] + c["depth"]
            ), (e, c)
        # small queue + tiny bandwidth must actually exercise overflow
        assert m.queue_counters()["dropped"] > 0

    def test_free_scrubs_inflight_entries(self):
        rng = np.random.default_rng(6)
        m = _mgr(queue_size=64, bandwidth=0)
        (h0, p0), (h1, p1) = _populate(m)
        m.record_access(_counts(rng))
        m.run_epoch()
        assert m.queue_depth() > 0
        m.free(h0, p0)
        m.unregister(h0)
        qp = np.asarray(m._state.queue.page)
        assert not (set(qp[qp >= 0].tolist()) & set(int(p) for p in p0))
        c = m.queue_counters()
        assert c["enqueued"] == c["drained"] + c["cancelled"] + c["dropped"] + c["depth"]

    def test_scan_path_counters_balance(self):
        rng = np.random.default_rng(7)
        m = _mgr(queue_size=24, bandwidth=2)
        _populate(m)
        m.run_epochs(12, counts=_counts(rng))
        c = m.queue_counters()
        assert c["enqueued"] == c["drained"] + c["cancelled"] + c["dropped"] + c["depth"]


def _queue_dirs(m):
    """(real demote pages, real promote pages, tombstone pages) sets."""
    q = m._state.queue
    page, d = np.asarray(q.page), np.asarray(q.direction)
    occ = page >= 0
    return (
        set(page[occ & (d == DIR_DEMOTE)].tolist()),
        set(page[occ & (d == DIR_PROMOTE)].tolist()),
        set(page[occ & (d == DIR_NONE)].tolist()),
    )


class TestStormGuards:
    """The DESIGN.md §11 policy-hardening knobs. Every guard defaults OFF
    and the off-state is bit-identical to the ungarded engine (locked here
    and by the golden traces); on-states are behavioral contracts."""

    def test_guards_require_queue(self):
        """Admission / cooldown act on the migration queue: configuring them
        on an instant-apply manager must fail loudly, not silently no-op."""
        with pytest.raises(ValueError, match="queue_size"):
            _mgr(queue_size=0, promote_admission=2)
        with pytest.raises(ValueError, match="queue_size"):
            _mgr(queue_size=0, demote_cooldown=2)
        _mgr(queue_size=0, promote_band=0.1, demote_band=0.1)  # bands: fine

    def test_explicit_sentinels_bit_identical_to_defaults(self):
        """Passing the documented off-values explicitly is the same machine
        as not passing the knobs at all — every state leaf, every epoch."""
        rng = np.random.default_rng(21)
        a = _mgr(queue_size=24, bandwidth=2, latency=1)
        b = _mgr(queue_size=24, bandwidth=2, latency=1,
                 promote_band=-1.0, demote_band=-1.0,
                 promote_admission=None, demote_cooldown=0)
        _populate(a), _populate(b)
        for e in range(12):
            c = _counts(rng)
            a.record_access(c), b.record_access(c)
            a.run_epoch(), b.run_epoch()
            for x, y in zip(jax.tree.leaves(a._state), jax.tree.leaves(b._state)):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y), str(e))
        assert a.queue_counters() == b.queue_counters()

    def test_promote_admission_caps_new_enqueues_per_epoch(self):
        """With the clamp on, at most ``promote_admission`` NEW promotion
        entries appear per epoch; the unclamped twin admits more."""
        rng = np.random.default_rng(22)
        adm = 2
        a = _mgr(queue_size=64, bandwidth=2, promote_admission=adm, seed=5)
        b = _mgr(queue_size=64, bandwidth=2, seed=5)
        _populate(a), _populate(b)
        burst_seen = False
        prev_a, prev_b = set(), set()
        for e in range(8):
            # rotating wide hot set: keeps promotion pressure above the clamp
            c = np.zeros(P, np.int64)
            hot = rng.choice(P, 48, replace=False)
            c[hot] = rng.integers(100, 500, 48)
            a.record_access(c), b.record_access(c)
            a.run_epoch(), b.run_epoch()
            prom_a, prom_b = _queue_dirs(a)[1], _queue_dirs(b)[1]
            assert len(prom_a - prev_a) <= adm, e
            burst_seen |= len(prom_b - prev_b) > adm
            prev_a, prev_b = prom_a, prom_b
            # rejected selections are not half-admitted anywhere
            ca = a.queue_counters()
            assert ca["enqueued"] == (
                ca["drained"] + ca["cancelled"] + ca["dropped"] + ca["depth"]
            ), e
        assert burst_seen, "clamp never bound: workload too tame"

    def test_demote_cooldown_tombstones_bar_reselection(self):
        """A reheat-cancelled demotion leaves a tombstone: the cancel is
        counted once, the page is barred from re-selection while the
        tombstone lives, and the slot is reclaimed at expiry."""
        cooldown = 3
        m = _mgr(queue_size=64, bandwidth=0, demote_cooldown=cooldown)
        h0, p0 = _populate(m)[0]
        cold_fast = [int(p) for p in p0 if m.tier_of([p])[0] == TIER_FAST][:4]
        hot = [int(p) for p in p0 if int(p) not in cold_fast]
        c = np.zeros(P, np.int64)
        c[hot] = 50
        m.record_access(c)
        m.run_epoch()
        queued_dem = _queue_dirs(m)[0]
        assert queued_dem & set(cold_fast), "expected queued demotions"
        # reheat the queued pages -> cancel + entomb instead of plain drop
        c2 = np.zeros(P, np.int64)
        c2[sorted(queued_dem)] = 500
        m.record_access(c2)
        r = m.run_epoch()
        assert int(r.stats.queue.cancelled) > 0
        dem, _, tombs = _queue_dirs(m)
        assert queued_dem <= tombs, "cancelled demotions must become tombstones"
        assert not (dem & queued_dem)
        # tombstones are not pending work: the real depth excludes them
        assert m.queue_depth() == len(dem) + len(_queue_dirs(m)[1])
        # go cold again: while the tombstone lives the page must NOT be
        # re-selected for demotion (this is the anti-ping-pong bar)
        for e in range(cooldown - 1):
            m.record_access(c)  # original heat: queued_dem pages cold again
            m.run_epoch()
            dem, _, tombs = _queue_dirs(m)
            assert not (dem & queued_dem), (e, dem, queued_dem)
        # after expiry the slots are reclaimed and the pages are selectable
        reappeared = False
        for e in range(6):
            m.record_access(c)
            m.run_epoch()
            dem, _, tombs = _queue_dirs(m)
            assert not (tombs & queued_dem) or e == 0
            reappeared |= bool(dem & queued_dem)
        assert reappeared, "page never selectable again after cooldown"
        cc = m.queue_counters()
        assert cc["enqueued"] == (
            cc["drained"] + cc["cancelled"] + cc["dropped"] + cc["depth"]
        )

    def test_hysteresis_bands_gate_reallocation_triggers(self):
        """The asymmetric bands move the needer/donor trigger thresholds:
        a tenant 10% over target is a needer under the default band but not
        under ``need_band=0.2``; a tenant 10% under target donates under the
        default band but not under ``donor_band=0.2``."""
        from repro.core import fmmr
        from repro.core.types import TenantState

        ts = TenantState.create(2)._replace(
            active=jnp.asarray([True, True]),
            t_miss=jnp.asarray([0.2, 0.2], jnp.float32),
            # tenant 0: a=0.22 (10% over target); tenant 1: a=0.18 (10% under)
            a_miss=jnp.asarray([0.22, 0.18], jnp.float32),
            arrival=jnp.asarray([0, 1], jnp.int32),
        )
        fast = jnp.asarray([8, 24], jnp.int32)

        def go(**kw):
            return fmmr.reallocate(ts, fast, jnp.int32(0), jnp.int32(8), **kw)

        base = go(hysteresis=0.0)
        assert int(base.give[0]) > 0, "10%-over tenant must be served by default"
        assert int(base.take[1]) > 0, "10%-under tenant must donate by default"
        banded = go(hysteresis=0.0, need_band=0.2, donor_band=0.2)
        assert int(banded.give[0]) == 0, "need_band=0.2 must absorb a 10% excursion"
        assert int(banded.take[1]) == 0, "donor_band=0.2 must absorb a 10% dip"
        # asymmetry: each band gates only its own side. With the donor side
        # gated the needer is still recognized — unservable, so flagged.
        only_donor = go(hysteresis=0.0, need_band=0.0, donor_band=0.2)
        assert int(only_donor.take[1]) == 0
        assert bool(only_donor.flagged[0])
        only_need = go(hysteresis=0.0, need_band=0.2, donor_band=0.0)
        assert int(only_need.give[0]) == 0
        # None falls back to the symmetric hysteresis (the original engine)
        sym = go(hysteresis=0.2)
        assert int(sym.give[0]) == 0 and int(sym.take[1]) == 0


class TestStormConservation:
    """The cancel-requeue accounting lock: a storm of tenant churn plus
    heat flips over a tiny queue/bandwidth must keep the conservation
    identity exact, never hold two live entries for one page, and never
    trip the in-trace sentinel — guards off AND on."""

    GUARDED = dict(promote_admission=3, demote_cooldown=4,
                   promote_band=0.15, demote_band=0.02)

    def _storm(self, seed, **guard_kw):
        rng = np.random.default_rng(seed)
        m = CentralManager(
            num_pages=P, fast_capacity=FAST, migration_budget=BUDGET,
            max_tenants=4, sample_period=1, exact_sampling=True, seed=seed,
            queue_size=8, migration_bandwidth=2, migration_latency=1,
            sentinel=True, **guard_kw,
        )
        tenants = {}
        for i in range(3):
            h = m.register(0.3)
            tenants[h] = m.allocate(h, 30)
        for step in range(40):
            op = rng.integers(0, 4)
            if op == 0 and len(tenants) > 1:
                h = list(tenants)[rng.integers(len(tenants))]
                m.free(h, tenants.pop(h))
                m.unregister(h)
            elif op == 1 and len(tenants) < 4:
                h = m.register(0.3)
                tenants[h] = m.allocate(h, int(rng.integers(5, 35)))
            counts = np.zeros(P, np.uint32)
            hot = rng.integers(0, P, size=40)
            counts[hot] = rng.integers(50, 500, size=40)
            m.record_access(counts)
            res = m.run_epoch()
            c = m.queue_counters()
            assert c["enqueued"] == (
                c["drained"] + c["cancelled"] + c["dropped"] + c["depth"]
            ), (step, c)
            qp = np.asarray(m._state.queue.page)
            occ = qp[qp >= 0]
            assert len(occ) == len(set(occ.tolist())), (step, occ)
            assert int(np.asarray(res.stats.sentinel)) == 0, step
        return m.queue_counters()

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_churn_storm_guards_off(self, seed):
        c = self._storm(seed)
        assert c["cancelled"] > 0, "storm too tame: no cancels exercised"

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_churn_storm_guards_on(self, seed):
        self._storm(seed, **self.GUARDED)

    def test_guards_reduce_queue_churn(self):
        """The point of the guards: strictly less enqueue traffic on the
        same storm (fewer cancel-requeue cycles), without starving drains."""
        base = self._storm(2)
        guarded = self._storm(2, **self.GUARDED)
        assert guarded["enqueued"] < base["enqueued"], (base, guarded)
        assert guarded["drained"] > 0


class TestScanParity:
    def test_multi_epoch_matches_single_steps_in_queue_mode(self):
        """The fused lax.scan path and k single fused steps produce the
        same final state bit-for-bit with the queue active."""
        rng = np.random.default_rng(8)
        counts = _counts(rng)
        a = _mgr(queue_size=24, bandwidth=3, latency=1, seed=9)
        b = _mgr(queue_size=24, bandwidth=3, latency=1, seed=9)
        _populate(a), _populate(b)
        for _ in range(6):
            a.record_access(counts)
            a.run_epoch()
        b.run_epochs(6, counts=counts)
        for x, y in zip(jax.tree.leaves(a._state), jax.tree.leaves(b._state)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert a.queue_counters() == b.queue_counters()


class TestPolicyStateCompat:
    def test_legacy_construction_without_queue_fields(self):
        """PolicyState built without queue/epoch (older call sites) still
        drives the instant engine."""
        st = PolicyState(
            pages=PolicyState.create(64, 2).pages,
            tenants=PolicyState.create(64, 2).tenants._replace(
                active=jnp.asarray([True, False]),
                arrival=jnp.asarray([0, jnp.iinfo(jnp.int32).max], jnp.int32),
            ),
            pending=jnp.zeros((64,), jnp.uint32),
            rng=jax.random.PRNGKey(0),
        )
        assert st.queue is None and st.epoch is None
        params = PolicyParams(
            fast_capacity=jnp.int32(16), migration_budget=jnp.int32(8),
            sample_period=jnp.int32(1),
        )
        st2, plan, stats = policy.epoch_step(
            st, params, max_tenants=2, plan_size=8, exact_sampling=True
        )
        assert st2.queue is None and st2.epoch is None
        assert stats.queue is None


class TestDataPlane:
    def _written(self, m, handles, rng):
        data = {}
        for h, pages in handles:
            rows = rng.normal(size=(len(pages), m.pool.row_elems)).astype(np.float32)
            m.pool.write_pages(pages, rows)
            for p, r in zip(pages, rows):
                data[int(p)] = r
        return data

    def test_contents_survive_bounded_migrations(self):
        rng = np.random.default_rng(10)
        m = _mgr(queue_size=64, bandwidth=3, data_plane_elems=16)
        handles = _populate(m)
        data = self._written(m, handles, rng)
        for e in range(16):
            m.record_access(_counts(rng))
            m.run_epoch()
            m.pool.check(m.tiers())
        assert m.pool.moved_pages > 0, "no migrations exercised"
        for p, want in data.items():
            np.testing.assert_array_equal(m.pool.read_page(p), want, str(p))

    def test_contents_survive_instant_mode_and_scan(self):
        rng = np.random.default_rng(11)
        m = _mgr(queue_size=0, data_plane_elems=16)
        handles = _populate(m)
        data = self._written(m, handles, rng)
        m.run_epochs(6, counts=_counts(rng))
        m.pool.check(m.tiers())
        for p, want in data.items():
            np.testing.assert_array_equal(m.pool.read_page(p), want, str(p))

    def test_fast_frames_track_fast_tier(self):
        """Every fast-tier page sits on a fast frame after any schedule —
        the frame table cannot drift from the placement metadata."""
        rng = np.random.default_rng(12)
        m = _mgr(queue_size=32, bandwidth=2, latency=1, data_plane_elems=8)
        handles = _populate(m)
        self._written(m, handles, rng)
        for e in range(10):
            m.record_access(_counts(rng))
            m.run_epoch()
        m.pool.check(m.tiers())
        (h0, p0) = handles[0]
        m.free(h0, p0)
        m.unregister(h0)
        m.pool.check(m.tiers())
        assert (m.pool.frame[np.asarray(p0, np.int64)] == -1).all()


class TestBandwidthRequiresQueue:
    def test_finite_bandwidth_without_queue_fails_loudly(self):
        """An instant-apply manager has no drain to bound: a finite
        bandwidth request must raise, not silently no-op while the same
        scenario event clamps the baselines."""
        with pytest.raises(ValueError, match="queue data plane"):
            _mgr(queue_size=0, bandwidth=4)
        m = _mgr(queue_size=0)
        with pytest.raises(ValueError, match="queue data plane"):
            m.set_migration_bandwidth(4)
        m.set_migration_bandwidth(None)  # unlimited is always legal


class TestQueueTypes:
    def test_queue_create_and_depth(self):
        q = MigrationQueue.create(8)
        assert q.size == 8
        assert int(q.depth) == 0
        q2 = q._replace(page=q.page.at[0].set(5))
        assert int(q2.depth) == 1

    @pytest.mark.parametrize("tier_const", [TIER_FAST, TIER_SLOW])
    def test_tier_constants_stable(self, tier_const):
        # the queue commit scatters these literals; lock their values
        assert tier_const in (0, 1)
