"""Training stack tests: optimizer, train loop, data determinism, checkpoint
restart equivalence, grad compression, fault-tolerance runtime."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import DataConfig, PrefetchIterator, SyntheticTokens
from repro.runtime.fault_tolerance import (
    HeartbeatTracker,
    StragglerDetector,
    plan_elastic_mesh,
)
from repro.training import grad_compression as gc
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state, lr_schedule
from repro.training.train_state import init_train_state, make_train_step


@pytest.fixture(scope="module")
def smoke_cfg():
    return get_config("qwen2.5-3b").smoke()


def _batch(cfg, seed=0, B=2, S=16):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    return {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }


class TestOptimizer:
    def test_lr_schedule_shape(self):
        cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
        lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in [0, 5, 10, 50, 100]]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(5e-4)
        assert lrs[2] == pytest.approx(1e-3)
        assert lrs[3] < lrs[2]
        assert lrs[4] == pytest.approx(1e-4, rel=1e-2)

    def test_loss_decreases(self, smoke_cfg):
        cfg = smoke_cfg
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)))
        batch = _batch(cfg)  # overfit one batch
        losses = []
        for _ in range(15):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.2, f"no learning: {losses[0]:.3f}->{losses[-1]:.3f}"
        assert np.isfinite(losses).all()

    def test_grad_clipping_bounds_update(self, smoke_cfg):
        cfg = smoke_cfg
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        step = make_train_step(cfg, AdamWConfig(lr=1e-3, grad_clip=1e-9))
        s2, m = jax.jit(step)(state, _batch(cfg))
        # with a vanishing clip the params barely move
        d = max(
            float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(s2.params))
        )
        assert d < 1e-2


class TestGradCompression:
    def test_error_feedback_preserves_sum(self):
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)), jnp.float32)}
        e = gc.init_error_buf(g)
        total_deq = jnp.zeros_like(g["w"])
        for _ in range(30):
            deq, e = gc.compress_decompress(g, e)
            total_deq = total_deq + deq["w"]
        # error feedback: sum of dequantized grads ~= 30 * g
        err = float(jnp.max(jnp.abs(total_deq / 30 - g["w"])))
        assert err < 0.02, f"error feedback drift {err}"

    def test_compressed_training_still_learns(self, smoke_cfg):
        cfg = smoke_cfg
        state = init_train_state(cfg, jax.random.PRNGKey(0), compress_grads=True)
        step = jax.jit(
            make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=2), compress_grads=True)
        )
        batch = _batch(cfg)
        losses = []
        for _ in range(12):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.1


class TestData:
    def test_determinism_across_shardings(self):
        cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=8, seed=7)
        whole = SyntheticTokens(cfg, shard=0, num_shards=1).batch_at(3)
        parts = [SyntheticTokens(cfg, shard=s, num_shards=4).batch_at(3) for s in range(4)]
        merged = np.concatenate([p["tokens"] for p in parts], axis=0)
        assert (merged == whole["tokens"]).all(), "elastic resharding changes the stream"

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2, seed=1)
        b = SyntheticTokens(cfg).batch_at(0)
        assert b["tokens"].shape == (2, 16)
        assert (b["tokens"][:, 1:] == b["labels"][:, :-1]).all()

    def test_prefetch_matches_direct(self):
        cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=2, seed=2)
        src = SyntheticTokens(cfg)
        it = PrefetchIterator(src, start_step=0, depth=2)
        try:
            for want_step in range(3):
                step, batch = next(it)
                assert step == want_step
                ref = src.batch_at(step)
                assert (batch["tokens"] == ref["tokens"]).all()
        finally:
            it.close()


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path, smoke_cfg):
        cfg = smoke_cfg
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        ck = Checkpointer(str(tmp_path), keep=2)
        ck.save(0, state, meta={"data_step": 0}, blocking=True)
        restored, meta = ck.restore(state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        assert meta["data_step"] == 0

    def test_restart_equivalence(self, tmp_path, smoke_cfg):
        """Train 6 steps straight == train 3, checkpoint, restore, train 3."""
        cfg = smoke_cfg
        opt = AdamWConfig(lr=1e-3, warmup_steps=2)
        step = jax.jit(make_train_step(cfg, opt))
        data = SyntheticTokens(DataConfig(cfg.vocab_size, 16, 2, seed=3))

        def run(state, start, n):
            for s in range(start, start + n):
                b = data.batch_at(s)
                state, _ = step(state, {k: jnp.asarray(v) for k, v in b.items()})
            return state

        s_direct = run(init_train_state(cfg, jax.random.PRNGKey(0)), 0, 6)

        s_a = run(init_train_state(cfg, jax.random.PRNGKey(0)), 0, 3)
        ck = Checkpointer(str(tmp_path))
        ck.save(3, s_a, blocking=True)
        s_b, _ = ck.restore(s_a)
        s_b = run(s_b, 3, 3)
        for a, b in zip(jax.tree.leaves(s_direct.params), jax.tree.leaves(s_b.params)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-6
            )

    def test_atomicity_prunes_and_latest(self, tmp_path, smoke_cfg):
        state = init_train_state(smoke_cfg, jax.random.PRNGKey(0))
        ck = Checkpointer(str(tmp_path), keep=2)
        for s in [0, 10, 20]:
            ck.save(s, state, blocking=True)
        assert ck.all_steps() == [10, 20]
        assert ck.latest_step() == 20
        assert not any(d.startswith("tmp.") for d in os.listdir(tmp_path))


class TestFaultTolerance:
    def test_heartbeat_detects_death(self):
        t = [0.0]
        hb = HeartbeatTracker([0, 1, 2], timeout=5.0, clock=lambda: t[0])
        t[0] = 3.0
        hb.beat(0)
        hb.beat(1)
        t[0] = 7.0
        dead = hb.check()
        assert dead == [2]
        assert hb.alive_hosts() == [0, 1]

    def test_straggler_detection(self):
        sd = StragglerDetector([0, 1, 2, 3], ratio=1.5)
        for _ in range(5):
            for h in range(3):
                sd.record(h, 1.0)
            sd.record(3, 3.0)
        assert sd.stragglers() == [3]

    def test_elastic_mesh_plan(self):
        assert plan_elastic_mesh(32, 8, 16) == (16, 16)
        assert plan_elastic_mesh(31, 8, 16) == (8, 16)  # shrink to pow2 rows
        with pytest.raises(RuntimeError):
            plan_elastic_mesh(1, 8, 16)

    def test_elastic_runner_restores_and_continues(self, tmp_path, smoke_cfg):
        from repro.runtime.fault_tolerance import ElasticRunner

        cfg = smoke_cfg
        opt = AdamWConfig(lr=1e-3)
        data = SyntheticTokens(DataConfig(cfg.vocab_size, 16, 2, seed=5))
        tstep = jax.jit(make_train_step(cfg, opt))

        def make_step(world_size):
            def fn(state, step):
                b = data.batch_at(step)
                state, _ = tstep(state, {k: jnp.asarray(v) for k, v in b.items()})
                return state
            return fn

        ck = Checkpointer(str(tmp_path))
        runner = ElasticRunner(ck, make_step, save_every=4)
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        final, world = runner.run(state, world_size=8, n_steps=12, fail_at=[6])
        assert runner.restarts == 1
        assert world == 4
        assert int(final.opt.step) >= 12 - 4  # resumed from step 4 checkpoint


class TestMicrobatching:
    def test_grad_accumulation_matches_full_batch(self, smoke_cfg):
        """K-microbatch accumulation == full-batch step (same data)."""
        cfg = smoke_cfg
        opt = AdamWConfig(lr=1e-3, warmup_steps=2)
        batch = _batch(cfg, seed=9, B=4, S=16)
        s0 = init_train_state(cfg, jax.random.PRNGKey(0))
        s_full, m_full = jax.jit(make_train_step(cfg, opt))(s0, batch)
        s_mb, m_mb = jax.jit(make_train_step(cfg, opt, microbatch=2))(s0, batch)
        # loss is the mean over microbatches == full-batch mean (equal sizes)
        assert float(m_mb["loss"]) == pytest.approx(float(m_full["loss"]), rel=1e-4)
        for a, b in zip(jax.tree.leaves(s_full.params), jax.tree.leaves(s_mb.params)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                atol=5e-5, rtol=5e-4,
            )

    def test_microbatch_still_learns(self, smoke_cfg):
        cfg = smoke_cfg
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), microbatch=2))
        batch = _batch(cfg, B=4, S=16)
        losses = []
        for _ in range(10):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.1
