"""Multi-tenant serving engine over the tiered paged KV cache.

Continuous batching: requests from multiple tenants (each with its own MaxMem
``t_miss`` target) share one fixed decode batch. Every step:

  1. admit queued requests into free batch lanes (dense prefill -> pages);
     a request whose pages cannot be allocated yet exerts *backpressure*
     (it waits in FIFO order) without head-of-line blocking smaller
     requests behind it
  2. one batched paged-decode step (Quest top-k page selection)
  3. report the selected-page access stream to the central manager
  4. on page-boundary crossings, first-touch allocate new pages
  5. every ``epoch_steps`` decode steps: run the MaxMem epoch. With a
     queue-mode manager (``queue_size > 0``) the epoch's DRAINED batch is
     committed to the KV pools (commit-on-completion: selections still in
     flight move no bytes); an instant-apply manager executes the whole
     plan immediately. Either way the Pallas ``page_move`` data plane does
     the actual copies.
  6. finished sequences free their pages back to the tiered pool AND scrub
     their KV slots (zero content, ±inf Quest summaries) so a reused page
     never folds against a prior owner's stale summaries

A step-latency model (HBM vs host-DMA page reads) attributes per-tenant
decode latency so QoS benchmarks can measure p50/p99 per tenant.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.manager import CentralManager, TenantHandle
from repro.core.types import TIER_FAST
from repro.kvcache.paged import TieredPagedKV
from repro.models.model import get_model
from repro.serving.paged_model import PagedPools, paged_decode_step


@dataclasses.dataclass
class Request:
    rid: int
    tenant: str
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int
    # runtime
    generated: List[int] = dataclasses.field(default_factory=list)
    lane: int = -1
    pages: List[int] = dataclasses.field(default_factory=list)
    submit_step: int = 0
    admit_step: int = -1
    finish_step: int = -1

    @property
    def queue_delay_steps(self) -> int:
        """Decode steps spent waiting for admission (backpressure)."""
        return max(self.admit_step - self.submit_step, 0)


@dataclasses.dataclass
class StepLatency:
    fast_pages: int
    slow_pages: int
    seconds: float


class ServingEngine:
    def __init__(
        self,
        cfg,
        params,
        manager: CentralManager,
        kv: TieredPagedKV,
        *,
        max_batch: int = 8,
        pages_per_seq: int = 16,
        quest_pages: int = 4,
        epoch_steps: int = 8,
        fast_page_s: float = 1e-6,
        slow_page_s: float = 20e-6,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.params = params
        self.manager = manager
        self.kv = kv
        self.api = get_model(cfg)
        self.max_batch = max_batch
        self.n_p = pages_per_seq
        self.quest_pages = quest_pages
        self.epoch_steps = epoch_steps
        self.fast_page_s = fast_page_s
        self.slow_page_s = slow_page_s

        self.tenant_handles: Dict[str, TenantHandle] = {}
        self.queue: Deque[Request] = deque()
        self.lanes: List[Optional[Request]] = [None] * max_batch
        self.tables = np.full((max_batch, pages_per_seq), -1, np.int32)
        self.positions = np.zeros(max_batch, np.int32)
        self.step_count = 0
        self._rid = 0
        self._latencies: Dict[str, List[float]] = {}
        self._migrated_pages = 0
        self.admission_blocked = 0  # allocation-failure backpressure events
        self._epoch_log: List[dict] = []
        self.finished: List[Request] = []
        self.last_logits: Optional[np.ndarray] = None  # [B, V] of last step

    # ------------------------------------------------------------- tenants
    def add_tenant(self, name: str, t_miss: float) -> None:
        self.tenant_handles[name] = self.manager.register(t_miss)
        self._latencies[name] = []

    def set_target(self, name: str, t_miss: float) -> None:
        self.manager.set_target(self.tenant_handles[name], t_miss)

    # ------------------------------------------------------------- requests
    def submit(self, tenant: str, prompt: np.ndarray, max_new_tokens: int) -> int:
        prompt = np.asarray(prompt, np.int32)
        max_tokens = self.n_p * self.kv.page
        if len(prompt) > max_tokens:
            raise ValueError(
                f"prompt of {len(prompt)} tokens exceeds the per-sequence "
                f"page table: pages_per_seq={self.n_p} x page={self.kv.page} "
                f"= {max_tokens} tokens"
            )
        if len(prompt) == 0:
            raise ValueError("empty prompt")
        self._rid += 1
        self.queue.append(
            Request(
                rid=self._rid,
                tenant=tenant,
                prompt=prompt,
                max_new_tokens=max_new_tokens,
                submit_step=self.step_count,
            )
        )
        return self._rid

    # ------------------------------------------------------------- admission
    def _admit(self) -> None:
        free_lanes = [i for i, r in enumerate(self.lanes) if r is None]
        blocked: List[Request] = []
        while free_lanes and self.queue:
            req = self.queue.popleft()
            S = len(req.prompt)
            h = self.tenant_handles[req.tenant]
            n_pages = (S + self.kv.page - 1) // self.kv.page
            try:
                pages = self.manager.allocate(h, n_pages)
            except MemoryError:
                # backpressure: the request keeps waiting (FIFO order is
                # preserved below) but does NOT head-of-line block smaller
                # requests behind it from taking this lane
                self.admission_blocked += 1
                blocked.append(req)
                continue
            lane = free_lanes.pop(0)
            req.pages = list(map(int, pages))
            req.lane = lane
            req.admit_step = self.step_count
            self.lanes[lane] = req
            self.tables[lane, :] = -1
            self.tables[lane, :n_pages] = req.pages
            # Prefill: dense forward collecting KV, then scatter into pages.
            logits, cache = self.api.prefill(
                self.params, jnp.asarray(req.prompt[None, :]), S
            )
            k, v = cache.k, cache.v  # [L, 1, S, nkv, dh]
            self.kv.write_tokens(
                (k, v), np.asarray([req.pages], np.int32), start_pos=0
            )
            # prefill accesses: every page of the prompt touched once
            counts = np.zeros(self.manager.num_pages, np.int64)
            counts[req.pages] += 1
            self.manager.record_access(counts)
            first = int(np.argmax(np.asarray(logits[0])))
            req.generated.append(first)
            self.positions[lane] = S  # next token index to write
        for req in reversed(blocked):
            self.queue.appendleft(req)

    # ------------------------------------------------------------- stepping
    def _ensure_page(self, lane: int) -> bool:
        """Allocate the page for the position about to be written."""
        req = self.lanes[lane]
        p_idx = int(self.positions[lane]) // self.kv.page
        if p_idx >= self.n_p:
            return False  # out of table space: finish the request
        if self.tables[lane, p_idx] >= 0:
            return True
        h = self.tenant_handles[req.tenant]
        try:
            pages = self.manager.allocate(h, 1)
        except MemoryError:
            return False
        self.tables[lane, p_idx] = int(pages[0])
        req.pages.append(int(pages[0]))
        return True

    def step(self) -> Dict[str, StepLatency]:
        self._admit()
        active_mask = np.array([r is not None for r in self.lanes])
        if not active_mask.any():
            self.step_count += 1
            return {}
        for lane, req in enumerate(self.lanes):
            if req is not None and not self._ensure_page(lane):
                self._finish(lane)
                active_mask[lane] = False
        if not active_mask.any():
            self.step_count += 1
            return {}

        tokens = np.array(
            [
                (r.generated[-1] if r is not None and r.generated else 0)
                for r in self.lanes
            ],
            np.int32,
        )
        slot_tables = np.where(self.tables >= 0, self.kv.slot_of[np.maximum(self.tables, 0)], -1)
        logits, pools, counts = paged_decode_step(
            self.params,
            jnp.asarray(tokens),
            jnp.asarray(self.positions),
            jnp.asarray(slot_tables.astype(np.int32)),
            jnp.asarray(self.tables),
            jnp.asarray(active_mask),
            PagedPools(self.kv.k_pool, self.kv.v_pool, self.kv.k_max, self.kv.k_min),
            num_logical_pages=self.manager.num_pages,
            cfg=self.cfg,
            quest_pages=self.quest_pages,
        )
        self.kv.k_pool, self.kv.v_pool = pools.k, pools.v
        self.kv.k_max, self.kv.k_min = pools.kmax, pools.kmin
        counts_np = np.asarray(counts, np.int64)
        self.manager.record_access(counts_np)

        # ---- latency attribution: page tiers touched this step -------------
        lat: Dict[str, StepLatency] = {}
        touched = np.flatnonzero(counts_np > 0)
        owner = self.manager.owners()
        for name, h in self.tenant_handles.items():
            mine = touched[(owner[touched] == int(h))] if len(touched) else touched
            nf = int((self.manager.tier_of(mine) == TIER_FAST).sum()) if len(mine) else 0
            ns = len(mine) - nf
            sec = nf * self.fast_page_s + ns * self.slow_page_s
            if len(mine):
                lat[name] = StepLatency(fast_pages=nf, slow_pages=ns, seconds=sec)
                self._latencies[name].append(sec)

        # ---- token bookkeeping ---------------------------------------------
        self.last_logits = np.asarray(logits)
        greedy = np.argmax(self.last_logits, axis=-1)
        for lane, req in enumerate(self.lanes):
            if req is None or not active_mask[lane]:
                continue
            req.generated.append(int(greedy[lane]))
            self.positions[lane] += 1
            if len(req.generated) >= req.max_new_tokens:
                self._finish(lane)

        self.step_count += 1
        # ---- MaxMem epoch ----------------------------------------------------
        if self.step_count % self.epoch_steps == 0:
            res = self.manager.run_epoch()
            if res.stats.queue is not None:
                # queue mode: only the DRAINED batch moves bytes this epoch
                # (commit-on-completion); enqueued selections still in
                # flight keep serving from their source tier
                q = res.stats.queue
                moved = self.kv.apply_drained(
                    q.drained_promote_ids, q.drained_demote_ids, self.manager
                )
            else:
                moved = self.kv.migrate(res.plan, self.manager)
            self._migrated_pages += moved
            self._epoch_log.append(
                {
                    "step": self.step_count,
                    "moved": moved,
                    "queue_depth": res.queue_depth,
                    "fmmr": {
                        n: float(self.manager.fmmr_of(h))
                        for n, h in self.tenant_handles.items()
                    },
                }
            )
        return lat

    def _finish(self, lane: int) -> None:
        req = self.lanes[lane]
        req.finish_step = self.step_count
        h = self.tenant_handles[req.tenant]
        if req.pages:
            # scrub the KV slots BEFORE releasing the ids: a freed page's
            # slot must hold zero content and ±inf Quest summaries so the
            # next owner starts from a fresh page (free/reuse invariant)
            self.kv.free_pages(req.pages)
            self.manager.free(h, np.asarray(req.pages, np.int32))
        self.tables[lane, :] = -1
        self.positions[lane] = 0
        self.lanes[lane] = None
        self.finished.append(req)

    def run(self, n_steps: int) -> None:
        for _ in range(n_steps):
            self.step()

    # ------------------------------------------------------------- telemetry
    @property
    def migrated_bytes(self) -> int:
        """Bytes physically moved across the tier boundary so far."""
        return self._migrated_pages * self.kv.page_bytes()

    def latency_percentiles(self, tenant: str):
        xs = np.asarray(self._latencies.get(tenant, []))
        if len(xs) == 0:
            return {}
        return {
            "p50": float(np.percentile(xs, 50)),
            "p90": float(np.percentile(xs, 90)),
            "p99": float(np.percentile(xs, 99)),
            "mean": float(xs.mean()),
        }
