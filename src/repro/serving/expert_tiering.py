"""MoE expert-weight tiering — MaxMem's second Big-Data object (DESIGN §2).

A *page* here is one (layer, expert) weight block (w_gate+w_up+w_down,
~17 MB for moonshot) living in pooled storage: fast slots = HBM-resident,
slow slots = host memory. Routing skew (top-k gating concentrates traffic on
few experts) is the heat signal: each decode/prefill step's routed expert ids
feed the central manager exactly like KV-page touches, and the policy
migrates hot experts into the fast pool with the Pallas page_move kernel.

The jitted forward gathers each layer's expert weights from the pools by
physical slot (``moe_layer_from_pools``), so migrations change real data
placement, not just bookkeeping.
"""
from __future__ import annotations

from functools import partial
from typing import List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.manager import CentralManager
from repro.core.types import MigrationPlan
from repro.kernels import ops


class ExpertPools(NamedTuple):
    w_gate: jax.Array  # [n_slots, d, ff]
    w_up: jax.Array  # [n_slots, d, ff]
    w_down: jax.Array  # [n_slots, ff, d]


class ExpertTierManager:
    """Tiered storage + QoS manager for one MoE model's expert weights.

    Logical page id = layer * E + expert. The MODEL is the tenant (one
    t_miss per model; multiple colocated models can each register one)."""

    def __init__(self, cfg, n_fast_slots: int, t_miss: float = 0.1,
                 migration_budget: int = 8, epoch_steps: int = 8):
        self.cfg = cfg
        L, E = cfg.num_layers, cfg.num_experts
        self.n_pages = L * E
        self.n_fast = n_fast_slots
        self.n_slots = self.n_pages  # 1:1 slots (a permutation), like kvcache
        assert n_fast_slots <= self.n_slots
        self.manager = CentralManager(
            num_pages=self.n_pages,
            fast_capacity=n_fast_slots,
            migration_budget=migration_budget,
            max_tenants=2,
            sample_period=1,
            exact_sampling=True,
        )
        self.tenant = self.manager.register(t_miss=t_miss)
        self.manager.allocate(self.tenant, self.n_pages)
        self.slot_of = np.arange(self.n_slots, dtype=np.int32)
        self.epoch_steps = epoch_steps
        self._step = 0
        self.pools: ExpertPools | None = None
        # plan entries that could not be executed because the 1:1 slot
        # layout pairs every promotion with a demotion: an odd plan's
        # remainder is counted here instead of being silently dropped
        self.unpaired_promotes = 0
        self.unpaired_demotes = 0

    # ------------------------------------------------------------- pools
    def build_pools(self, params) -> ExpertPools:
        """Pack stacked MoE weights [L, E, ...] into pooled [L*E, ...]."""
        moe = params["layers"]["moe"]
        L, E = self.cfg.num_layers, self.cfg.num_experts
        Ep = moe["w_gate"].shape[1]

        def pack(w):  # [L, Ep, a, b] -> rows for the REAL experts only
            return w[:, :E].reshape(L * E, *w.shape[2:])

        self.pools = ExpertPools(
            w_gate=pack(moe["w_gate"]),
            w_up=pack(moe["w_up"]),
            w_down=pack(moe["w_down"]),
        )
        return self.pools

    def slot_table(self) -> jax.Array:
        """[L, E] physical slot of each (layer, expert)."""
        L, E = self.cfg.num_layers, self.cfg.num_experts
        return jnp.asarray(self.slot_of.reshape(L, E))

    # ------------------------------------------------------------- accounting
    def record_routing(self, expert_counts: np.ndarray) -> None:
        """expert_counts: [L, E] routed-assignment counts from the step."""
        self.manager.record_access(np.asarray(expert_counts, np.int64).reshape(-1))
        self._step += 1

    def maybe_epoch(self) -> int:
        """Run a policy epoch every epoch_steps; returns pages migrated."""
        if self._step % self.epoch_steps != 0 or self._step == 0:
            return 0
        res = self.manager.run_epoch()
        return self._migrate(res.plan)

    # ------------------------------------------------------------- migration
    def _migrate(self, plan: MigrationPlan) -> int:
        promote = np.asarray(plan.promote)
        demote = np.asarray(plan.demote)
        promote = promote[promote >= 0]
        demote = demote[demote >= 0]
        if len(promote) == 0 and len(demote) == 0:
            return 0
        # every page is allocated (1:1 slots): migrations are PAIRED SWAPS of
        # a promoted page with a demoted page. page_move has gather semantics
        # (all reads see the pre-plan pool), so the swap src=[a,b]/dst=[b,a]
        # is exact with no temp slot.
        src: List[int] = []
        dst: List[int] = []
        promote = [int(p) for p in promote if int(self.slot_of[p]) >= self.n_fast]
        demote = [int(p) for p in demote if int(self.slot_of[p]) < self.n_fast]
        # zip truncates to the shorter side: the unpaired remainder cannot
        # move (no partner slot in a full 1:1 layout) — count it so the
        # telemetry shows the plan was wider than the swaps executed; the
        # policy re-selects still-hot leftovers next epoch
        self.unpaired_promotes += max(len(promote) - len(demote), 0)
        self.unpaired_demotes += max(len(demote) - len(promote), 0)
        for pg_up, pg_down in zip(promote, demote):
            s_up = int(self.slot_of[pg_up])  # slow slot
            s_down = int(self.slot_of[pg_down])  # fast slot
            src.extend([s_up, s_down])
            dst.extend([s_down, s_up])
            self.slot_of[pg_up], self.slot_of[pg_down] = s_down, s_up
        if not src:
            return 0
        sidx = jnp.asarray(src, jnp.int32)
        didx = jnp.asarray(dst, jnp.int32)
        p = self.pools
        self.pools = ExpertPools(
            w_gate=ops.page_move(p.w_gate.reshape(self.n_slots, -1), sidx, didx
                                 ).reshape(p.w_gate.shape),
            w_up=ops.page_move(p.w_up.reshape(self.n_slots, -1), sidx, didx
                               ).reshape(p.w_up.shape),
            w_down=ops.page_move(p.w_down.reshape(self.n_slots, -1), sidx, didx
                                 ).reshape(p.w_down.shape),
        )
        return len(src)

    # ------------------------------------------------------------- telemetry
    def fast_resident(self, layer: int, expert: int) -> bool:
        return int(self.slot_of[layer * self.cfg.num_experts + expert]) < self.n_fast

    def fmmr(self) -> float:
        return self.manager.fmmr_of(self.tenant)

    def fast_share_of_traffic(self, expert_counts: np.ndarray) -> float:
        """Fraction of routed traffic hitting fast-resident experts."""
        flat = np.asarray(expert_counts, np.float64).reshape(-1)
        fast = self.slot_of < self.n_fast
        tot = flat.sum()
        return float(flat[fast].sum() / tot) if tot else 0.0


# --------------------------------------------------------------------------
# Pool-consuming MoE forward (jitted): gathers each layer's expert weights by
# physical slot, so placement changes flow through real compute.
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("cfg",))
def moe_layer_from_pools(
    pools: ExpertPools,
    slots_l: jax.Array,  # [E] physical slots for this layer's experts
    router: jax.Array,  # [d, E]
    x: jax.Array,  # [T, d]
    cfg=None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out [T, d], expert_counts [E])."""
    T, d = x.shape
    E, k = cfg.num_experts, cfg.moe_top_k
    logits = (x.astype(jnp.float32) @ router)
    gate_w, gate_ids = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    wg = pools.w_gate[slots_l]  # [E, d, ff] gathered by PHYSICAL slot
    wu = pools.w_up[slots_l]
    wd = pools.w_down[slots_l]

    # small-T dense-per-token dispatch (serving decode batch sizes)
    def per_assignment(tok, e, w):
        g = tok @ wg[e]
        u = tok @ wu[e]
        return ((jax.nn.silu(g) * u) @ wd[e]) * w

    out = jnp.zeros((T, d), x.dtype)
    for j in range(k):
        o = jax.vmap(per_assignment)(x, gate_ids[:, j], gate_w[:, j])
        out = out + o.astype(x.dtype)
    counts = jnp.zeros((E,), jnp.int32).at[gate_ids.reshape(-1)].add(1)
    return out, counts
