"""Serving-side placement baselines (DESIGN.md §8).

``FixedPartitionManager`` is the HeMem-style static KV partition: every
tenant gets a fixed fast-tier quota carved out at registration, first-touch
allocation fills the tenant's own quota (never another tenant's), and no
migration reshuffles placement afterwards. This is what a per-tenant
reserved-HBM serving deployment gives you — the colocation benchmark runs
it as the "provisioned-for-peak" reference the paper's FMMR control beats:
the partition can neither lend idle fast pages to a bursting LS tenant nor
reclaim them from an idle BE tenant.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.manager import CentralManager, TenantHandle
from repro.core.types import TIER_FAST, TIER_NONE, TIER_SLOW


class FixedPartitionManager(CentralManager):
    """A :class:`CentralManager` whose fast tier is statically partitioned.

    ``fast_quota`` maps tenant handle -> fast pages reserved for it;
    :meth:`register_with_quota` assigns quotas as tenants arrive. Tenants
    without a quota allocate slow-only. Construct with a zero-drain queue
    (``migration_bandwidth=0``) or ``migration_budget=0`` so the partition
    stays frozen; allocation is the only placement mechanism.
    """

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.fast_quota: Dict[int, int] = {}

    def register_with_quota(self, t_miss: float, fast_quota: int) -> TenantHandle:
        h = self.register(t_miss)
        self.fast_quota[int(h)] = int(fast_quota)
        return h

    def allocate(self, h: TenantHandle, n_pages: int) -> np.ndarray:
        """First-touch within the tenant's own fast partition, then slow."""
        snap = self._snapshot()
        tier = snap["tier"]
        owner = snap["owner"]
        unalloc = np.flatnonzero(tier == TIER_NONE)
        if len(unalloc) < n_pages:
            raise MemoryError(
                f"tenant {int(h)}: out of tiered memory "
                f"({n_pages} requested, {len(unalloc)} free)"
            )
        quota = self.fast_quota.get(int(h), 0)
        mine_fast = int(((owner == int(h)) & (tier == TIER_FAST)).sum())
        fast_used = int((tier == TIER_FAST).sum())
        fast_room = min(
            max(quota - mine_fast, 0),
            max(int(self.params.fast_capacity) - fast_used, 0),
        )
        take = unalloc[:n_pages]
        n_fast = min(fast_room, n_pages)
        new_tier = tier.copy()
        new_owner = owner.copy()
        new_tier[take[:n_fast]] = TIER_FAST
        new_tier[take[n_fast:]] = TIER_SLOW
        new_owner[take] = int(h)
        self.pages = self.pages._replace(
            tier=jnp.asarray(new_tier), owner=jnp.asarray(new_owner)
        )
        if self.pool is not None:
            self.pool.on_allocate(take, new_tier[take])
        return take


def make_serving_manager(
    mode: str,
    *,
    num_pages: int,
    fast_capacity: int,
    migration_budget: int,
    queue_size: int,
    migration_bandwidth: Optional[int] = None,
    migration_latency: int = 0,
    fast_quota: Optional[Dict[str, int]] = None,
    alloc_headroom: int = 0,
    max_tenants: int = 8,
    seed: int = 0,
) -> CentralManager:
    """One constructor for the three benchmark placements, shaped so all of
    them share ONE ``epoch_step`` trace: identical ``num_pages`` /
    ``max_tenants`` / ``queue_size`` / ``plan_size`` — only the *traced*
    ``PolicyParams`` differ (DESIGN.md §8).

      * ``maxmem`` — queue-mode bounded-bandwidth FMMR control, with a
        TPP-style ``alloc_headroom`` fast-page reserve for first-touch
        allocations (traced, like the rest of ``PolicyParams``);
      * ``static`` — same program with ``migration_bandwidth=0``: selections
        enqueue but never drain, so first-touch placement stays frozen;
      * ``fixed`` — :class:`FixedPartitionManager`, also zero-drain, with
        per-tenant fast quotas applied at allocation.
    """
    kw = dict(
        num_pages=num_pages,
        fast_capacity=fast_capacity,
        migration_budget=migration_budget,
        max_tenants=max_tenants,
        sample_period=1,
        exact_sampling=True,
        queue_size=queue_size,
        migration_latency=migration_latency,
        seed=seed,
    )
    if mode == "maxmem":
        return CentralManager(
            migration_bandwidth=migration_bandwidth,
            alloc_headroom=alloc_headroom,
            **kw,
        )
    if mode == "static":
        return CentralManager(migration_bandwidth=0, **kw)
    if mode == "fixed":
        mgr = FixedPartitionManager(migration_bandwidth=0, **kw)
        mgr._named_quota = dict(fast_quota or {})  # resolved by the driver
        return mgr
    raise ValueError(f"unknown serving manager mode: {mode!r}")
