"""Open-loop multi-tenant serving driver (DESIGN.md §8).

Arrivals are open-loop: each tenant submits new requests at a Poisson rate
per decode step, independent of how loaded the engine is — the shape under
which admission backpressure and tail latency actually mean something (a
closed loop self-throttles and hides both; TPP/the paper's Fig. 5-7 are
open-loop for the same reason). The arrival stream is drawn from its own
RNG, so two engines driven with the same seed and specs see the SAME
request sequence — placement policy is the only difference between
benchmark legs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from repro.serving.engine import ServingEngine


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One open-loop tenant: LS tenants run tight ``t_miss`` targets and
    lower arrival rates; BE co-runners run ``t_miss`` ~ 1.0 and flood."""

    name: str
    t_miss: float
    arrival_rate: float  # expected new requests per decode step
    prompt_tokens: int
    max_new_tokens: int


class OpenLoopDriver:
    def __init__(self, engine: ServingEngine, tenants: Sequence[TenantSpec],
                 seed: int = 0):
        self.engine = engine
        self.tenants = list(tenants)
        self.rng = np.random.default_rng(seed)
        for t in self.tenants:
            engine.add_tenant(t.name, t.t_miss)
            # resolve named fast quotas onto handles (FixedPartitionManager)
            named = getattr(engine.manager, "_named_quota", None)
            if named is not None and t.name in named:
                engine.manager.fast_quota[int(engine.tenant_handles[t.name])] = (
                    named[t.name]
                )
        self.submitted: Dict[str, int] = {t.name: 0 for t in self.tenants}
        self.steps_run = 0

    def run(self, n_steps: int) -> Dict[str, dict]:
        """Drive ``n_steps`` decode steps (callable repeatedly — e.g. a
        warmup segment then a timed segment); the report always covers the
        whole run so far."""
        eng = self.engine
        for _ in range(n_steps):
            for t in self.tenants:
                for _ in range(int(self.rng.poisson(t.arrival_rate))):
                    prompt = self.rng.integers(
                        1, eng.cfg.vocab_size, t.prompt_tokens
                    )
                    eng.submit(t.name, prompt, t.max_new_tokens)
                    self.submitted[t.name] += 1
            eng.step()
        self.steps_run += n_steps
        return self.report(self.steps_run)

    def report(self, n_steps: int) -> Dict[str, dict]:
        eng = self.engine
        out: Dict[str, dict] = {}
        for t in self.tenants:
            done = [r for r in eng.finished if r.tenant == t.name]
            active = [
                r for r in eng.lanes if r is not None and r.tenant == t.name
            ]
            tokens = sum(len(r.generated) for r in done + active)
            delays: List[int] = [r.queue_delay_steps for r in done]
            out[t.name] = {
                "latency": eng.latency_percentiles(t.name),
                "submitted": self.submitted[t.name],
                "completed": len(done),
                "generated_tokens": tokens,
                "tokens_per_step": tokens / max(n_steps, 1),
                "queue_delay_mean_steps": float(np.mean(delays)) if delays else 0.0,
                "queue_delay_max_steps": int(np.max(delays)) if delays else 0,
            }
        out["_engine"] = {
            "steps": n_steps,
            "migrated_pages": eng._migrated_pages,
            "migrated_bytes": eng.migrated_bytes,
            "admission_blocked": eng.admission_blocked,
            "queue_depth_end": len(eng.queue),
        }
        return out
