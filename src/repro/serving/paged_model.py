"""Jitted batched decode over the tiered paged KV cache.

Per layer and step:
  1. project q/k/v for the new token; write k/v into the current page slot
  2. update the page's Quest summaries (key min/max)
  3. score all pages of each sequence with the Quest upper bound
         score(p) = sum_h sum_d max(q_hd * kmax_pd, q_hd * kmin_pd)
     and select the top-``quest_pages`` pages (current page force-included)
  4. gather ONLY the selected pages and run masked decode attention
  5. emit the selected logical page ids -> per-page access counts

The per-page access counts are the PEBS-analogue stream MaxMem samples: with
top-k selection, page touches are heat-skewed, which is exactly what makes
tiering profitable (hot pages earn fast-tier residency).
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.moe import moe_mlp
from repro.models.transformer import lm_head_weight

NEG_INF = -1e30


class PagedPools(NamedTuple):
    k: jax.Array  # [L, n_slots, page, nkv, dh]
    v: jax.Array
    kmax: jax.Array  # [L, n_slots, nkv, dh] f32
    kmin: jax.Array


@partial(jax.jit, static_argnames=("cfg", "quest_pages", "num_logical_pages"))
def paged_decode_step(
    params,
    tokens: jax.Array,  # [B] int32
    positions: jax.Array,  # [B] int32 (index of the token being generated)
    slot_tables: jax.Array,  # [B, n_p] int32 physical slots (-1 = no page)
    logical_tables: jax.Array,  # [B, n_p] int32 logical page ids (-1 = none)
    active: jax.Array,  # [B] bool
    pools: PagedPools,
    num_logical_pages: int = 0,
    cfg=None,
    quest_pages: int = 4,
):
    """Returns (logits [B, V], pools', access_counts [P_logical] i32)."""
    B = tokens.shape[0]
    page = pools.k.shape[2]
    n_p = slot_tables.shape[1]
    nkv, dh, nh = cfg.num_kv_heads, cfg.d_head, cfg.num_heads
    g = nh // nkv

    x = params["embed"][tokens[:, None]].astype(cfg.cdtype)  # [B, 1, d]
    pos_b = positions  # [B]
    cur_p = pos_b // page
    cur_off = pos_b % page
    cur_slot = jnp.take_along_axis(slot_tables, cur_p[:, None], axis=1)[:, 0]
    cur_slot = jnp.maximum(cur_slot, 0)
    # Inactive lanes must not write: their clamped cur_slot would be row 0,
    # silently corrupting whatever page physically lives there (KV bytes AND
    # Quest summaries). Route their writes out of bounds so the scatter
    # drops them.
    n_slots = pools.k.shape[1]
    write_slot = jnp.where(active, cur_slot, n_slots)
    seq_lens = jnp.where(active, pos_b + 1, 0)

    k_sel_n = min(quest_pages, n_p)
    P_logical = num_logical_pages

    def layer_fn(carry, xs):
        x, counts = carry
        lp, kp, vp, kmx, kmn = xs  # per-layer pools
        h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        q, k, v = L.qkv_project(lp["attn"], h, cfg)  # q [B,1,nh,dh]
        rope_pos = pos_b[:, None]
        q = L.apply_rope(q, rope_pos, cfg.rope_theta)
        k = L.apply_rope(k, rope_pos, cfg.rope_theta)

        # ---- write new token into its page slot (idle lanes dropped) -----
        kp = kp.at[write_slot, cur_off].set(k[:, 0].astype(kp.dtype), mode="drop")
        vp = vp.at[write_slot, cur_off].set(v[:, 0].astype(vp.dtype), mode="drop")
        kmx = kmx.at[write_slot].max(k[:, 0].astype(jnp.float32), mode="drop")
        kmn = kmn.at[write_slot].min(k[:, 0].astype(jnp.float32), mode="drop")

        # ---- Quest page scores -------------------------------------------
        st = jnp.maximum(slot_tables, 0)
        kmx_t = kmx[st]  # [B, n_p, nkv, dh]
        kmn_t = kmn[st]
        qg = q.reshape(B, nkv, g, dh).astype(jnp.float32)
        hi = jnp.einsum("bngd,bpnd->bpng", qg, kmx_t)
        lo = jnp.einsum("bngd,bpnd->bpng", qg, kmn_t)
        score = jnp.maximum(hi, lo).sum(axis=(2, 3))  # [B, n_p]
        valid_page = (slot_tables >= 0) & (
            jnp.arange(n_p)[None, :] * page < seq_lens[:, None]
        )
        score = jnp.where(valid_page, score, NEG_INF)
        # force-include the current page
        score = jnp.where(
            jnp.arange(n_p)[None, :] == cur_p[:, None], jnp.inf, score
        )
        _, sel = jax.lax.top_k(score, k_sel_n)  # [B, k_sel] table positions

        # ---- gather selected pages + attention ---------------------------
        sel_slots = jnp.take_along_axis(st, sel, axis=1)  # [B, k_sel]
        k_sel = kp[sel_slots]  # [B, k_sel, page, nkv, dh]
        v_sel = vp[sel_slots]
        tok_pos = sel[:, :, None] * page + jnp.arange(page)[None, None, :]
        tok_valid = (tok_pos < seq_lens[:, None, None]) & jnp.take_along_axis(
            valid_page | (jnp.arange(n_p)[None, :] == cur_p[:, None]), sel, axis=1
        )[:, :, None]
        kk = k_sel.reshape(B, k_sel_n * page, nkv, dh)
        vv = v_sel.reshape(B, k_sel_n * page, nkv, dh)
        mask = tok_valid.reshape(B, k_sel_n * page)
        s = jnp.einsum(
            "bngd,bknd->bngk", q.reshape(B, nkv, g, dh), kk,
            preferred_element_type=jnp.float32,
        ) / math.sqrt(dh)
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        p_att = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum(
            "bngk,bknd->bngd", p_att.astype(vv.dtype), vv,
            preferred_element_type=jnp.float32,
        ).reshape(B, 1, nh * dh).astype(x.dtype)
        x = x + o @ lp["attn"]["w_o"]

        h2 = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
        if cfg.is_moe:
            m, _ = moe_mlp(lp["moe"], h2, cfg)
        else:
            m = L.mlp(lp["mlp"], h2, cfg)
        x = x + m

        # ---- access accounting (selected logical pages) -------------------
        sel_logical = jnp.take_along_axis(logical_tables, sel, axis=1)  # [B,k]
        ok = (sel_logical >= 0) & active[:, None]
        idx = jnp.where(ok, sel_logical, P_logical)
        counts = counts.at[idx.reshape(-1)].add(1, mode="drop")
        return (x, counts), (kp, vp, kmx, kmn)

    counts0 = jnp.zeros((int(P_logical) + 1,), jnp.int32)
    (x, counts), new_pools = jax.lax.scan(
        layer_fn,
        (x, counts0),
        (params["layers"], pools.k, pools.v, pools.kmax, pools.kmin),
    )
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, 0] @ lm_head_weight(params, cfg)).astype(jnp.float32)
    # an inactive lane's attention gathers whatever page physically sits at
    # row 0 — layout-dependent garbage. Zero those rows so the step output
    # is a pure function of logical state (the reuse-parity tests rely on
    # this, and callers never consume dead-lane logits anyway).
    logits = jnp.where(active[:, None], logits, 0.0)
    return logits, PagedPools(*new_pools), counts[:-1]
