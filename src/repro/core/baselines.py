"""Baseline tiered-memory policies the paper compares against (§5).

All expose the CentralManager surface the simulator drives:
  register / set_target / unregister / allocate / free /
  record_access / run_epoch / pages / num_pages / fmmr_of

* HeMemStatic  — per-tenant *static* fast partitions, each managed by an
  independent HeMem-style instance: single hotness *threshold* (not a heat
  gradient); among qualifying pages victims are arbitrary, so hot and warm
  pages compete blindly for the partition (paper Fig. 3: ~30% of MaxMem when
  hot+warm exceed DRAM). Partitions cannot help other tenants (Fig. 8).
* AutoNUMALike — tenant-blind global promotion of recently-touched pages,
  LRU-ish demotion, effectively unbounded churn; no QoS.
* TwoLM       — Optane 2LM/Memory-Mode analogue: fast tier as a direct-mapped
  cache; resident page per set = most recently dominant accessor. No QoS.

Vectorized NumPy implementations (DESIGN.md §3): every per-epoch step is
array ops over cached ownership groupings — no per-page Python loops and no
per-tenant full-pool mask passes — so the baselines run the same 256k+ page
scenarios as the fused MaxMem engine. Placements are bit-identical to the
seed per-page implementations (``benchmarks/seed_baselines_frozen.py``),
locked by ``tests/golden/baseline_traces.json``: victim "arbitrariness" is
the same RNG shuffle sequence, applied per tenant in registration order.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.types import TIER_FAST, TIER_NONE, TIER_SLOW


@dataclasses.dataclass
class _Pages:
    owner: np.ndarray
    tier: np.ndarray
    count: np.ndarray


def _segment_starts(sorted_keys: np.ndarray) -> np.ndarray:
    """Start offsets of each run of equal values in a sorted key array."""
    n = len(sorted_keys)
    if n == 0:
        return np.zeros(0, np.int64)
    boundary = np.empty(n, bool)
    boundary[0] = True
    boundary[1:] = sorted_keys[1:] != sorted_keys[:-1]
    return np.flatnonzero(boundary)


class _BaselineBase:
    def __init__(self, num_pages: int, fast_capacity: int, seed: int = 0):
        self.num_pages = num_pages
        self.fast_capacity = fast_capacity
        self.pages = _Pages(
            owner=np.full(num_pages, -1, np.int32),
            tier=np.full(num_pages, TIER_NONE, np.int8),
            count=np.zeros(num_pages, np.int64),
        )
        self._pending = np.zeros(num_pages, np.int64)
        self._next = 0
        self.rng = np.random.default_rng(seed)
        self._ewma: Dict[int, float] = {}
        self._groups_dirty = True  # ownership changed since the last epoch
        self._order: Optional[np.ndarray] = None
        self._sorted_owner: Optional[np.ndarray] = None

    # --- tenancy ------------------------------------------------------------
    def register(self, t_miss: float) -> int:
        h = self._next
        self._next += 1
        self._ewma[h] = 0.0
        return h

    def set_target(self, h: int, t_miss: float) -> None:
        pass  # no QoS

    def unregister(self, h: int) -> None:
        mine = self.pages.owner == h
        self.pages.owner[mine] = -1
        self.pages.tier[mine] = TIER_NONE
        self.pages.count[mine] = 0
        # drop QoS telemetry with the tenant: a departed handle must read as
        # fresh (fmmr_of == 0.0), not replay its last EWMA forever
        self._ewma.pop(h, None)
        self._groups_dirty = True

    def allocate(self, h: int, n_pages: int) -> np.ndarray:
        free = np.flatnonzero(self.pages.tier == TIER_NONE)
        if len(free) < n_pages:
            raise MemoryError("out of tiered memory")
        take = free[:n_pages]
        fast_used = int((self.pages.tier == TIER_FAST).sum())
        room = max(self._fast_room(h, fast_used), 0)
        # the quota may over-commit; the physical fast tier cannot
        n_fast = min(room, max(self.fast_capacity - fast_used, 0), n_pages)
        self.pages.tier[take[:n_fast]] = TIER_FAST
        self.pages.tier[take[n_fast:]] = TIER_SLOW
        self.pages.owner[take] = h
        self._groups_dirty = True
        return take

    def free(self, h: int, ids: Sequence[int]) -> None:
        ids = np.asarray(ids)
        self.pages.owner[ids] = -1
        self.pages.tier[ids] = TIER_NONE
        self.pages.count[ids] = 0
        self._groups_dirty = True

    def record_access(self, counts: np.ndarray) -> None:
        self._pending += counts

    # --- ownership grouping (cached between control-plane changes) ----------
    def _groups(self):
        """Page ids sorted by owner (stable => ascending ids within a
        tenant), plus per-owner segment offsets; recomputed only after
        allocate/free/unregister."""
        if self._groups_dirty:
            self._order = np.argsort(self.pages.owner, kind="stable")
            so = self.pages.owner[self._order]
            self._sorted_owner = so
            self._seg_starts = _segment_starts(so)
            self._seg_owners = so[self._seg_starts]
            self._groups_dirty = False
        return self._order, self._sorted_owner

    def _tenant_pages(self, h: int) -> np.ndarray:
        """Ascending page ids owned by ``h`` — one binary search, no mask."""
        order, so = self._groups()
        lo = np.searchsorted(so, h, side="left")
        hi = np.searchsorted(so, h, side="right")
        return order[lo:hi]

    # telemetry surface shared with CentralManager (simulator batch reads)
    def tiers(self) -> np.ndarray:
        return self.pages.tier

    def owners(self) -> np.ndarray:
        return self.pages.owner

    def fmmr_of(self, h: int) -> float:
        return self._ewma.get(h, 0.0)

    def _update_fmmr(self, tp: Optional[np.ndarray] = None):
        """EWMA of the slow-tier access share: two segment reduceats over
        the cached ownership grouping — O(P) total, independent of tenant
        count, instead of the seed's O(P) mask passes per tenant. Sums are
        sequential int64 (exact), so the EWMA values match the seed
        bit-for-bit."""
        if not self._ewma:
            return
        if tp is None:
            tp = np.flatnonzero(self._pending > 0)
        if len(tp) * 4 <= self.num_pages:
            # sparse epoch: only touched pages contribute to the sums (int64
            # values are exact in the f64 bincount accumulator)
            ow = self.pages.owner[tp]
            owned = ow >= 0
            ow = ow[owned].astype(np.int64)
            pend = self._pending[tp][owned].astype(np.float64)
            tots = np.bincount(ow, weights=pend, minlength=self._next)
            slows = np.bincount(
                ow, weights=pend * (self.pages.tier[tp][owned] == TIER_SLOW),
                minlength=self._next,
            )
            for h in self._ewma:
                cur = slows[h] / tots[h] if tots[h] > 0 else 0.0
                self._ewma[h] = 0.5 * cur + 0.5 * self._ewma[h]
            return
        order, _ = self._groups()
        ps = self._pending[order]
        slow_ps = ps * (self.pages.tier[order] == TIER_SLOW)
        tots = np.add.reduceat(ps, self._seg_starts)
        slows = np.add.reduceat(slow_ps, self._seg_starts)
        seg_of = {int(h): i for i, h in enumerate(self._seg_owners) if h >= 0}
        for h in self._ewma:
            i = seg_of.get(h)
            cur = slows[i] / tots[i] if i is not None and tots[i] > 0 else 0.0
            self._ewma[h] = 0.5 * cur + 0.5 * self._ewma[h]

    def _fast_room(self, h: int, fast_used: int) -> int:
        return self.fast_capacity - fast_used

    # result shim (simulator reads .plan.num_promote/num_demote)
    class _Plan:
        def __init__(self, p, d):
            self.num_promote = p
            self.num_demote = d

    class _Result:
        def __init__(self, p, d):
            self.plan = _BaselineBase._Plan(p, d)


class HeMemStatic(_BaselineBase):
    """Static partitions + per-partition hotness threshold."""

    def __init__(
        self,
        num_pages: int,
        fast_capacity: int,
        partitions: Optional[Dict[int, int]] = None,
        hot_threshold: int = 8,
        migration_budget: int = 2048,
        seed: int = 0,
    ):
        super().__init__(num_pages, fast_capacity, seed)
        self.partitions = dict(partitions or {})
        self.hot_threshold = hot_threshold
        self.migration_budget = migration_budget

    def set_partition(self, h: int, fast_pages: int):
        self.partitions[h] = fast_pages

    def _fast_room(self, h: int, fast_used: int) -> int:
        quota = self.partitions.get(h, 0)
        mine = self._tenant_pages(h)
        mine_fast = int((self.pages.tier[mine] == TIER_FAST).sum())
        return quota - mine_fast

    def run_epoch(self):
        self._update_fmmr()
        count = self.pages.count
        np.right_shift(count, 1, out=count)  # crude cooling, in place
        np.add(count, self._pending, out=count)
        self._pending[:] = 0
        tier = self.pages.tier
        promoted = demoted = 0
        budget = self.migration_budget
        # static partitions may over-commit (sum of quotas > fast_capacity);
        # the physical fast tier is still finite, so promotions are globally
        # clamped to the actual free fast slots as well as the quota
        fast_free = self.fast_capacity - int((tier == TIER_FAST).sum())
        # per-tenant work is O(tenant pages) on the cached grouping — the
        # only O(P) passes this epoch are the cooling update above
        for h in list(self._ewma):
            mine = self._tenant_pages(h)
            quota = self.partitions.get(h, 0)
            t_loc = tier[mine]
            hot_loc = count[mine] >= self.hot_threshold
            fast_loc = t_loc == TIER_FAST
            hot_slow = mine[(t_loc == TIER_SLOW) & hot_loc]
            cold_fast = mine[fast_loc & ~hot_loc]
            # victims arbitrary among qualifying (no heat gradient): shuffle
            self.rng.shuffle(hot_slow)
            n_fast = int(fast_loc.sum())
            room = quota - n_fast
            if room < len(hot_slow):  # evict arbitrary cold pages first
                evict = cold_fast[: min(len(cold_fast), len(hot_slow) - room, budget)]
                tier[evict] = TIER_SLOW
                demoted += len(evict)
                budget -= len(evict)
                fast_free += len(evict)
                room = quota - (n_fast - len(evict))
            promo = hot_slow[: max(min(room, budget, fast_free, len(hot_slow)), 0)]
            tier[promo] = TIER_FAST
            promoted += len(promo)
            budget -= len(promo)
            fast_free -= len(promo)
            if budget <= 0:
                break
        return self._Result(promoted, demoted)


class AutoNUMALike(_BaselineBase):
    """Tenant-blind promotion of recently-touched pages; no QoS, heavy churn.

    ``migration_budget=None`` (the default, and the golden-trace
    configuration) migrates every qualifying page like real autonuma
    balancing under no rate limit; an integer bounds total moves per epoch
    (promotions + evictions), which is how the scenario engine's
    ``SetMigrationBandwidth`` event reaches instant-apply baselines."""

    def __init__(self, num_pages: int, fast_capacity: int, seed: int = 0,
                 migration_budget: Optional[int] = None):
        super().__init__(num_pages, fast_capacity, seed)
        self.migration_budget = migration_budget

    def run_epoch(self):
        recent = self._pending
        touched = recent > 0
        tp = np.flatnonzero(touched)
        self._update_fmmr(tp)
        # FAST/SLOW tiers imply ownership (unallocated pages are TIER_NONE),
        # so the seed's owner>=0 conjunct is redundant
        fast = self.pages.tier == TIER_FAST
        slow = self.pages.tier == TIER_SLOW
        touched_slow = tp[slow[tp]]
        idle_fast = np.flatnonzero(fast & ~touched)
        self.rng.shuffle(touched_slow)
        self.rng.shuffle(idle_fast)
        free_fast = self.fast_capacity - int(fast.sum())
        want = len(touched_slow)
        if self.migration_budget is None:
            # demote idle pages to make room (autonuma demotion to CPUless
            # node); unbounded = the bit-exact golden-trace path
            need_evict = max(want - free_fast, 0)
            evict = idle_fast[:need_evict]
            n_promo = free_fast + len(evict)
        else:
            # promotions into free room cost 1 move, beyond it 2 (evict +
            # promote); fill free room first, then pair within the budget
            b = int(self.migration_budget)
            p_free = min(want, free_fast, b)
            paired = min(want - p_free, len(idle_fast), max(b - p_free, 0) // 2)
            evict = idle_fast[:paired]
            n_promo = p_free + paired
        self.pages.tier[evict] = TIER_SLOW
        demoted = len(evict)
        promo = touched_slow[:n_promo]
        self.pages.tier[promo] = TIER_FAST
        promoted = len(promo)
        self._pending[tp] = 0  # pending is nonzero exactly at tp
        return self._Result(promoted, demoted)


class TwoLM(_BaselineBase):
    """Direct-mapped hardware cache (Optane Memory Mode) analogue."""

    def __init__(self, num_pages: int, fast_capacity: int, seed: int = 0):
        super().__init__(num_pages, fast_capacity, seed)
        self._cache_dirty = True
        self._grouped: Optional[np.ndarray] = None  # owned ids grouped by set
        self._starts: Optional[np.ndarray] = None  # group start offsets
        self._group_of: Optional[np.ndarray] = None  # group index per element
        self._residents: Optional[np.ndarray] = None  # page per set, last epoch

    def allocate(self, h, n_pages):
        self._cache_dirty = True
        return super().allocate(h, n_pages)

    def free(self, h, ids):
        self._cache_dirty = True
        super().free(h, ids)

    def unregister(self, h):
        self._cache_dirty = True
        super().unregister(h)

    def _set_groups(self):
        """Owned page ids grouped by cache set (page % fast_capacity),
        ascending ids within a group; rebuilt only on ownership changes."""
        if self._cache_dirty:
            F = max(self.fast_capacity, 1)
            owned = np.flatnonzero(self.pages.owner >= 0)
            sets = owned % F
            order = np.argsort(sets, kind="stable")
            self._grouped = owned[order]
            self._starts = _segment_starts(sets[order])
            self._group_of = np.zeros(len(owned), np.int64)
            self._group_of[self._starts] = 1
            self._group_of = np.cumsum(self._group_of) - 1
            # all-idle resident per set (max page id: every score ties at 0)
            # and the page -> group index map for the sparse update path
            ends = np.append(self._starts[1:], len(owned)) - 1
            self._idle_res = self._grouped[ends] if len(owned) else None
            self._page_group = np.full(self.num_pages, -1, np.int64)
            self._page_group[self._grouped] = self._group_of
            self._residents = None  # tier no longer "residents FAST, rest SLOW"
            self._cache_dirty = False
        return self._grouped, self._starts, self._group_of

    def run_epoch(self):
        tp = np.flatnonzero(self._pending > 0)
        self._update_fmmr(tp)
        grouped, starts, group_of = self._set_groups()
        tier = self.pages.tier
        if not len(grouped):
            moved = int((tier == TIER_FAST).sum())
            tier[tier == TIER_FAST] = TIER_SLOW
            self._residents = None
            self._pending[:] = 0
            return self._Result(moved // 2, moved // 2)
        # resident page per set = max recent score, tie -> largest page id
        # (the seed's last-write-wins over its stable lexsort order)
        touched = tp[self._page_group[tp] >= 0]
        if len(touched) * 4 <= len(grouped):
            # sparse epoch: untouched sets keep their all-idle resident (max
            # page id); only sets with accessed members need the argmax
            residents = self._idle_res.copy()
            if len(touched):
                g = self._page_group[touched]
                sc = self._pending[touched]
                # (group, score, id) lexicographic order via ONE composite
                # int64 sort (np.lexsort costs 3 indirect sorts); the guard
                # keeps group*span + score*P + id below 2^63
                span = (int(sc.max()) + 1) * np.int64(self.num_pages)
                if span <= (1 << 62) // (int(g.max()) + 1):
                    v = np.sort(g * span + sc * np.int64(self.num_pages) + touched)
                    gs = v // span
                    last = np.empty(len(v), bool)
                    last[-1] = True
                    last[:-1] = gs[1:] != gs[:-1]
                    residents[gs[last]] = (v[last] % span) % self.num_pages
                else:  # astronomically hot pages: exact but slower
                    o = np.lexsort((touched, sc, g))
                    gs = g[o]
                    last = np.empty(len(o), bool)
                    last[-1] = True
                    last[:-1] = gs[1:] != gs[:-1]
                    residents[gs[last]] = touched[o][last]
        else:
            score = self._pending[grouped]
            best = np.maximum.reduceat(score, starts)
            is_best = score == best[group_of]
            cand = np.where(is_best, grouped, -1)
            residents = np.maximum.reduceat(cand, starts)
        if self._residents is None:
            # ownership changed since the last epoch (fast-first allocation
            # may have scattered FAST pages anywhere): rebuild from scratch
            new_tier = np.full_like(tier, TIER_SLOW)
            new_tier[tier == TIER_NONE] = TIER_NONE
            new_tier[residents] = TIER_FAST
            moved = int((new_tier != tier).sum())
            self.pages.tier = new_tier
        else:
            # steady state: exactly the previous residents are FAST, so the
            # delta is the per-set resident swaps — O(sets), not O(P)
            changed = self._residents != residents
            tier[self._residents[changed]] = TIER_SLOW
            tier[residents[changed]] = TIER_FAST
            moved = 2 * int(changed.sum())
        self._residents = residents
        self._pending[tp] = 0  # pending is nonzero exactly at tp
        return self._Result(moved // 2, moved // 2)
