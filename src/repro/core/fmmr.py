"""FMMR measurement + proportional fast-memory reallocation (paper §3.1).

All functions are pure/jittable and operate on [T]-shaped tenant arrays.

Reallocation semantics implemented exactly as §3.1:
  * needers (a_miss > t_miss) receive migration bandwidth
        M_p = (a_miss/t_miss) / F_need * R
  * donors (a_miss < t_miss, holding fast memory) give up
        M_p = (t_miss/a_miss) / F_surplus * R
  * a_miss == 0 denominators substitute infinity, inf/inf = 1; with multiple
    a_miss == 0 donors only ONE (earliest arrival) donates per epoch.
  * takes are capped at the donor's current fast pages.
  * gives are additionally capped by what is actually available (free fast
    pages + takes); when infeasible, needers are served FCFS by arrival
    (paper default) or equal-fraction (fair_mode).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import TenantState

_EPS = 1e-9


def fmmr_now(a_fast: jax.Array, a_slow: jax.Array) -> jax.Array:
    """Instantaneous FMMR; 0 when no samples (idle tenants decay, §3.1)."""
    tot = a_fast + a_slow
    return jnp.where(tot > 0, a_slow / jnp.maximum(tot, 1), 0.0).astype(jnp.float32)


def update_ewma(prev: jax.Array, now: jax.Array, lam) -> jax.Array:
    return (lam * now + (1.0 - lam) * prev).astype(jnp.float32)


class Realloc(NamedTuple):
    give: jax.Array  # i32[T] fast pages granted this epoch
    take: jax.Array  # i32[T] fast pages reclaimed this epoch
    flagged: jax.Array  # bool[T] needers that could not be served


def reallocate(
    tenants: TenantState,
    fast_pages: jax.Array,  # i32[T] current fast-page holdings
    free_fast: jax.Array,  # i32[] unallocated fast slots
    budget: jax.Array,  # i32[] R: pages of reallocation bandwidth this epoch
    fair_mode: bool = False,
    hysteresis=0.0,
    need_band=None,
    donor_band=None,
) -> Realloc:
    act = tenants.active
    a, t = tenants.a_miss, tenants.t_miss
    R = budget.astype(jnp.float32)
    band = jnp.asarray(hysteresis, jnp.float32)
    # Asymmetric trigger bands (PolicyParams.promote_band/demote_band): the
    # needer and donor thresholds may carry their own hysteresis. ``None``
    # falls back to the symmetric ``hysteresis`` band (the original engine).
    nb = band if need_band is None else jnp.asarray(need_band, jnp.float32)
    db = band if donor_band is None else jnp.asarray(donor_band, jnp.float32)

    need_mask = act & (a > t * (1.0 + nb))
    # donors: below target AND holding fast memory. a==0 handled separately.
    donor_mask = act & (a < t * (1.0 - db)) & (fast_pages > 0)
    zero_donor = donor_mask & (a <= _EPS)

    # --- takes ---------------------------------------------------------------
    # finite-ratio donors
    ratio_d = jnp.where(donor_mask & ~zero_donor, t / jnp.maximum(a, _EPS), 0.0)
    # a_miss == 0 donors: ratio would be inf; only the earliest-arrival one
    # donates, and (inf / inf == 1) it absorbs the full take bandwidth.
    any_zero = zero_donor.any()
    arrival_key = jnp.where(zero_donor, tenants.arrival, jnp.iinfo(jnp.int32).max)
    first_zero = jnp.argmin(arrival_key)
    F_surplus = ratio_d.sum()
    take_frac = jnp.where(
        any_zero,
        jnp.zeros_like(ratio_d).at[first_zero].set(1.0) * zero_donor.any(),
        jnp.where(F_surplus > 0, ratio_d / jnp.maximum(F_surplus, _EPS), 0.0),
    )
    take = jnp.minimum(jnp.floor(take_frac * R).astype(jnp.int32), fast_pages)
    take = jnp.where(act, take, 0)

    # --- gives ---------------------------------------------------------------
    ratio_n = jnp.where(need_mask, a / jnp.maximum(t, _EPS), 0.0)
    F_need = ratio_n.sum()
    give_want = jnp.where(
        F_need > 0, jnp.floor(ratio_n / jnp.maximum(F_need, _EPS) * R), 0.0
    ).astype(jnp.int32)

    available = free_fast.astype(jnp.int32) + take.sum()
    total_want = give_want.sum()

    def _fcfs(give_want):
        # serve earliest arrivals fully first (paper default)
        order = jnp.argsort(jnp.where(need_mask, tenants.arrival, jnp.iinfo(jnp.int32).max))
        want_sorted = give_want[order]
        cum = jnp.cumsum(want_sorted)
        grant_sorted = jnp.clip(available - (cum - want_sorted), 0, want_sorted)
        return jnp.zeros_like(give_want).at[order].set(grant_sorted)

    def _fair(give_want):
        scale = jnp.where(
            total_want > 0,
            jnp.minimum(1.0, available.astype(jnp.float32) / jnp.maximum(total_want, 1)),
            0.0,
        )
        return jnp.floor(give_want.astype(jnp.float32) * scale).astype(jnp.int32)

    # fair_mode may be a traced bool (it lives in PolicyParams): evaluate both
    # allocations (cheap, [T]-sized) and select.
    give = jnp.where(jnp.asarray(fair_mode), _fair(give_want), _fcfs(give_want))
    give = jnp.where(act, give, 0)

    # avoid useless churn: don't take more than what gets redistributed
    # (paper: "stopping once it has met all the target FMMRs it can")
    excess_take = jnp.maximum(take.sum() - jnp.maximum(give.sum() - free_fast, 0), 0)
    # release excess from donors proportionally (largest takes first)
    def _trim(take, excess):
        order = jnp.argsort(-take)
        t_sorted = take[order]
        cum = jnp.cumsum(t_sorted)
        # keep = take - portion of excess assigned greedily
        reduce_sorted = jnp.clip(excess - (cum - t_sorted), 0, t_sorted)
        return jnp.zeros_like(take).at[order].set(t_sorted - reduce_sorted)

    take = _trim(take, excess_take)

    # --- §3.4 fair sharing: with no needers, equalize the surplus -----------
    # "If more fast memory is still available at this point, then MaxMem
    # allocates the remaining equally to all processes." Tenants strictly
    # below target shed fast pages beyond their equal share; under-share
    # tenants receive them (bounded by the same migration budget).
    no_needers = ~need_mask.any()
    n_act = jnp.maximum(act.sum(), 1)
    share = (fast_pages.sum() + free_fast) // n_act
    # a TRICKLE (budget/8) so equalization can never fight target convergence:
    # tenants drift toward equal share; the moment one crosses its target the
    # needer path (full budget) dominates again.
    trickle = jnp.maximum(budget.astype(jnp.int32) // 8, 1)
    # only tenants COMFORTABLY below target donate surplus (hysteresis margin
    # keeps tenants hovering at their target from oscillating)
    want_take_eq = jnp.where(
        act & (a < 0.7 * t), jnp.maximum(fast_pages - share, 0), 0
    )
    want_give_eq = jnp.where(act, jnp.maximum(share - fast_pages, 0), 0)

    def _scale(want, cap):
        tot = jnp.maximum(want.sum(), 1.0)
        return jnp.floor(want * (jnp.minimum(cap, tot) / tot)).astype(jnp.int32)

    matched = jnp.minimum(
        jnp.minimum(want_take_eq.sum(), want_give_eq.sum() + free_fast), trickle
    ).astype(jnp.float32)
    take_eq = _scale(want_take_eq.astype(jnp.float32), matched)
    give_eq = _scale(
        want_give_eq.astype(jnp.float32),
        jnp.minimum((take_eq.sum() + free_fast).astype(jnp.float32),
                    trickle.astype(jnp.float32)),
    )
    give = jnp.where(no_needers, give_eq, give)
    take = jnp.where(no_needers, take_eq, take)

    flagged = need_mask & (give == 0) & (give_want > 0)
    return Realloc(give=give, take=take, flagged=flagged)


def clamp_gives(give: jax.Array, arrival: jax.Array, available: jax.Array) -> jax.Array:
    """Greedy FCFS clamp so that sum(give) <= available (invariant repair
    after integer rescaling)."""
    order = jnp.argsort(jnp.where(give > 0, arrival, jnp.iinfo(jnp.int32).max))
    g_sorted = give[order]
    cum = jnp.cumsum(g_sorted)
    grant = jnp.clip(available - (cum - g_sorted), 0, g_sorted)
    return jnp.zeros_like(give).at[order].set(grant)
