"""MaxMem core: FMMR QoS policy, hotness bins, sampling, central manager,
fleet-vectorized sweep engine, colocation simulator and the dynamic-scenario
engine."""
from repro.core.fleet import FleetManager
from repro.core.manager import CentralManager, TenantHandle
from repro.core.types import (
    TIER_FAST,
    TIER_NONE,
    TIER_SLOW,
    EpochStats,
    MigrationPlan,
    OwnerSegments,
    PageState,
    PolicyParams,
    TenantState,
)

__all__ = [
    "CentralManager",
    "FleetManager",
    "TenantHandle",
    "TIER_FAST",
    "TIER_NONE",
    "TIER_SLOW",
    "EpochStats",
    "MigrationPlan",
    "OwnerSegments",
    "PageState",
    "PolicyParams",
    "TenantState",
]
