"""MaxMem core: FMMR QoS policy, hotness bins, sampling, central manager,
colocation simulator and the dynamic-scenario engine."""
from repro.core.manager import CentralManager, TenantHandle
from repro.core.types import (
    TIER_FAST,
    TIER_NONE,
    TIER_SLOW,
    EpochStats,
    MigrationPlan,
    PageState,
    PolicyParams,
    TenantState,
)

__all__ = [
    "CentralManager",
    "TenantHandle",
    "TIER_FAST",
    "TIER_NONE",
    "TIER_SLOW",
    "EpochStats",
    "MigrationPlan",
    "PageState",
    "PolicyParams",
    "TenantState",
]
