"""Pool-backed page data plane: the Pallas-kernel-driven DMA analogue.

``PagePool`` holds actual page *contents* in one device pool whose rows are
physical frames: rows ``[0, F)`` are fast-tier frames, ``[F, F + P)`` slow
frames, and the last row is the reserved trash row that pads fixed-size
plans (the convention ``kernels/page_copy.py`` documents). A host-side frame
table maps page id -> frame; the control plane (allocate/free) is host
bookkeeping, while every data movement goes through the Pallas kernels:

  * migrations  — ONE :func:`repro.kernels.page_copy.page_move` call per
    drained batch: demote entries first (their vacated fast frames are
    legally reused as promote destinations — the grid reads a row before
    any later step writes it), then promotes, padded to a fixed plan size
    with trash-row self-copies so plan shapes never retrace;
  * bulk writes — tenant data is staged host-side and DMA'd into frames
    with :func:`repro.kernels.page_copy.page_copy` (staging pool -> page
    pool), again trash-padded to the fixed plan size.

``CentralManager(data_plane_elems=...)`` owns a pool and feeds it the
drained id lists from each epoch's queue tick (or the instant-apply plan),
so simulated placements and actual page bytes can never diverge — which is
what the data-integrity tests assert.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.types import TIER_FAST
from repro.kernels.page_copy import page_copy, page_move


class PagePool:
    def __init__(
        self,
        num_pages: int,
        fast_capacity: int,
        row_elems: int = 128,
        dtype=jnp.float32,
        plan_slots: int = 64,
        interpret: bool = True,
    ):
        self.num_pages = num_pages
        self.fast_capacity = fast_capacity
        self.row_elems = row_elems
        self.plan_slots = plan_slots
        self.interpret = interpret
        self.trash = fast_capacity + num_pages  # reserved last row
        self.pool = jnp.zeros((self.trash + 1, row_elems), dtype)
        self.frame = np.full(num_pages, -1, np.int64)  # page -> frame row
        # LIFO free lists; fast frames are scarce, slow frames can hold all
        self._free_fast = list(range(fast_capacity - 1, -1, -1))
        self._free_slow = list(range(self.trash - 1, fast_capacity - 1, -1))
        self.moved_pages = 0  # cumulative pages DMA'd by migrations
        # Fault injection (core/faults.py). With an injector attached each
        # page move runs through its bounded-retry loop; moves that exhaust
        # the budget are abandoned — the page keeps its source-tier frame
        # (commit-on-completion fallback: degraded, never corrupt) and its
        # id lands in ``last_failed`` so the manager can revert the
        # already-flipped tier metadata.
        self.fault_injector = None
        self.last_failed = (np.empty(0, np.int64), np.empty(0, np.int64))

    def set_fault_injector(self, injector) -> None:
        """Attach (or with ``None`` detach) a ``FaultInjector``."""
        self.fault_injector = injector

    # ------------------------------------------------------------ control
    def on_allocate(self, page_ids: Sequence[int], tiers: Sequence[int]) -> None:
        """Assign a frame (in the page's tier) to each newly allocated page."""
        for p, t in zip(np.asarray(page_ids), np.asarray(tiers)):
            free = self._free_fast if t == TIER_FAST else self._free_slow
            self.frame[p] = free.pop()

    def on_free(self, page_ids: Sequence[int]) -> None:
        for p in np.asarray(page_ids):
            f = int(self.frame[p])
            if f < 0:
                continue
            (self._free_fast if f < self.fast_capacity else self._free_slow).append(f)
            self.frame[p] = -1

    # --------------------------------------------------------------- data
    def write_pages(self, page_ids: Sequence[int], rows: np.ndarray) -> None:
        """DMA tenant data into page frames (staging -> pool, page_copy)."""
        ids = np.asarray(page_ids, np.int64)
        rows = np.asarray(rows)
        M = self.plan_slots
        for lo in range(0, len(ids), M):
            chunk = ids[lo : lo + M]
            staging = np.zeros((M, self.row_elems), rows.dtype)
            staging[: len(chunk)] = rows[lo : lo + len(chunk)]
            src = np.arange(M, dtype=np.int32)
            dst = np.full(M, self.trash, np.int32)
            dst[: len(chunk)] = self.frame[chunk]
            self.pool = page_copy(
                jnp.asarray(staging, self.pool.dtype), self.pool,
                jnp.asarray(src), jnp.asarray(dst), interpret=self.interpret,
            )

    def read_page(self, page_id: int) -> np.ndarray:
        f = int(self.frame[page_id])
        assert f >= 0, f"page {page_id} has no frame"
        return np.asarray(self.pool[f])

    # ---------------------------------------------------------- migration
    def execute(self, demote_ids, promote_ids) -> int:
        """Move drained pages across tiers; returns pages moved.

        ``demote_ids``/``promote_ids`` are -1-padded id lists (the queue
        tick's drained lists, or an instant-mode plan's sides). Demotes are
        planned first so their vacated fast frames can serve as promote
        destinations within the same ``page_move`` sweep — the sequential
        grid reads every source row before a later step writes it (the
        write-after-read contract ``tests/test_kernels.py`` locks).
        """
        dem = np.asarray(demote_ids).ravel()
        pro = np.asarray(promote_ids).ravel()
        dem = dem[dem >= 0]
        pro = pro[pro >= 0]
        fi = self.fault_injector
        failed_dem, failed_pro = [], []
        src, dst = [], []
        for p in dem:
            if fi is not None and int(self.frame[p]) >= self.fast_capacity:
                # already physically slow: an earlier promote of this page
                # failed, and the policy has now demoted it again — the
                # "move" is already satisfied, no DMA needed
                continue
            if fi is not None and not fi.attempt_move():
                # abandoned after the retry budget: the page keeps its fast
                # frame, so this batch's promotes have one fewer slot
                failed_dem.append(int(p))
                continue
            f = int(self.frame[p])
            src.append(f)
            dst.append(self._free_slow.pop())
            self.frame[p] = dst[-1]
            self._free_fast.append(f)  # reusable by this batch's promotes
        freed_slow = []
        for p in pro:
            if fi is not None:
                if int(self.frame[p]) < self.fast_capacity:
                    # already physically fast (an earlier failed demote
                    # kept its frame): nothing to move
                    continue
                if not self._free_fast:
                    # a failed demote kept its frame: refuse rather than
                    # oversubscribe the fast tier
                    fi.no_frame += 1
                    failed_pro.append(int(p))
                    continue
                if not fi.attempt_move():
                    failed_pro.append(int(p))
                    continue
            f = int(self.frame[p])
            src.append(f)
            dst.append(self._free_fast.pop())
            self.frame[p] = dst[-1]
            freed_slow.append(f)  # released only after the sweep: a demote
            # destination must never alias a row this sweep still reads
        self.last_failed = (
            np.asarray(failed_dem, np.int64),
            np.asarray(failed_pro, np.int64),
        )
        n = len(src)
        M = self.plan_slots
        for lo in range(0, n, M):
            s = np.full(M, self.trash, np.int32)
            d = np.full(M, self.trash, np.int32)
            s[: len(src[lo : lo + M])] = src[lo : lo + M]
            d[: len(dst[lo : lo + M])] = dst[lo : lo + M]
            self.pool = page_move(
                self.pool, jnp.asarray(s), jnp.asarray(d), interpret=self.interpret
            )
        self._free_slow.extend(freed_slow)
        self.moved_pages += n
        return n

    # ------------------------------------------------------------- checks
    def check(self, tier: Optional[np.ndarray] = None) -> None:
        """Frame-table invariants (tests): frames are a bijection onto used
        rows, fast frames exactly back fast-tier pages, free lists disjoint."""
        used = self.frame[self.frame >= 0]
        assert len(np.unique(used)) == len(used), "frame table not injective"
        assert self.trash not in used, "trash row assigned to a page"
        free = self._free_fast + self._free_slow
        assert not set(free) & set(used.tolist()), "free list overlaps used"
        assert len(set(free)) == len(free), "duplicate free frames"
        assert len(free) + len(used) == self.trash, "frames leaked"
        if tier is not None:
            fast_pages = np.flatnonzero(np.asarray(tier) == TIER_FAST)
            backed = self.frame[fast_pages]
            assert (backed >= 0).all(), "fast page without a frame"
            assert (backed < self.fast_capacity).all(), "fast page on slow frame"
