"""Tiered-memory colocation simulator (drives the paper-figure benchmarks).

The simulator runs GUPS/KVS-like tenant workloads against a placement policy
(MaxMem's CentralManager or a baseline from ``core.baselines``) and evaluates
a machine cost model each epoch:

  * per-access latency  = hit * lat_fast + miss * lat_slow(load)
  * slow-tier load      = sum of tenant miss traffic + migration traffic;
                          latency scales by demand/capacity when saturated
  * tenant throughput   = threads / avg_latency  (closed-loop, fixed point)
  * tail latencies      = quantiles of the two-point access mixture with a
                          migration-interference term (write-protect stalls)

Constants are published-order-of-magnitude (DRAM ~80ns/100GB/s, Optane
~300ns/30GB/s read, I/OAT ~4GB/s/chan; TPU profile: HBM 819GB/s vs host DMA
~50GB/s). The *policies* are exact; the cost model only needs to rank them,
matching the paper's qualitative claims.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.types import TIER_FAST, TIER_SLOW


@dataclass(frozen=True)
class TierSpec:
    latency_ns: float
    bandwidth_GBps: float


@dataclass(frozen=True)
class MachineSpec:
    fast: TierSpec
    slow: TierSpec
    page_bytes: int = 2 << 20  # 2 MB huge pages (paper granularity)
    migration_GBps: float = 4.0  # I/OAT DMA engine class
    access_bytes: int = 64  # one cache line per op (GUPS)


OPTANE = MachineSpec(fast=TierSpec(80, 100.0), slow=TierSpec(300, 30.0))
TPU_HOST = MachineSpec(
    fast=TierSpec(500, 819.0),
    slow=TierSpec(2500, 50.0),
    page_bytes=2 << 20,
    migration_GBps=25.0,
)


@dataclass
class WorkloadSpec:
    """Hot/warm/cold set access skew, GUPS-style closed-loop tenant."""

    name: str
    n_pages: int
    t_miss: float = 1.0
    threads: int = 2
    # (fraction_of_pages, fraction_of_accesses) per set; remainder uniform
    sets: Tuple[Tuple[float, float], ...] = ()
    value_bytes: int = 64  # per-op payload (16 KB for the KVS workload)

    def __post_init__(self):
        # Reject NaN/negative/degenerate workload parameters at construction
        # (DESIGN.md §7): a poisoned spec must fail loudly HERE, not as a
        # silent NaN deep inside the cost-model fixed point.
        if not (isinstance(self.n_pages, (int, np.integer)) and self.n_pages > 0):
            raise ValueError(f"{self.name}: n_pages must be a positive int, got {self.n_pages!r}")
        if not (np.isfinite(self.t_miss) and 0.0 < self.t_miss <= 1.0):
            raise ValueError(f"{self.name}: t_miss must be finite in (0, 1], got {self.t_miss!r}")
        if not (isinstance(self.threads, (int, np.integer)) and self.threads >= 1):
            raise ValueError(f"{self.name}: threads must be an int >= 1, got {self.threads!r}")
        for i, (fp, fa) in enumerate(self.sets):
            if not (np.isfinite(fp) and 0.0 <= fp <= 1.0 and np.isfinite(fa) and 0.0 <= fa <= 1.0):
                raise ValueError(
                    f"{self.name}: sets[{i}] fractions must be finite in [0, 1], got {(fp, fa)!r}"
                )
        if not (isinstance(self.value_bytes, (int, np.integer)) and self.value_bytes > 0):
            raise ValueError(
                f"{self.name}: value_bytes must be a positive int, got {self.value_bytes!r}"
            )


class TenantSim:
    def __init__(self, spec: WorkloadSpec, page_ids: np.ndarray, rng: np.random.Generator):
        self.spec = spec
        self.page_ids = np.asarray(page_ids)
        self.rng = rng
        # scatter hot/warm sets across the virtual address space: the initial
        # fast-first allocation must not accidentally equal the hot set
        self._perm = rng.permutation(len(page_ids))
        self.probs = self._build_probs(spec, len(page_ids))[self._perm]

    @staticmethod
    def _build_probs(spec: WorkloadSpec, n: int) -> np.ndarray:
        probs = np.zeros(n)
        start = 0
        frac_left = 1.0
        for fp, fa in spec.sets:
            k = max(1, int(round(fp * n)))
            probs[start : start + k] = fa / k
            start += k
            frac_left -= fa
        rest = n - start
        if rest > 0 and frac_left > 0:
            probs[start:] = frac_left / rest
        s = probs.sum()
        return probs / s if s > 0 else np.full(n, 1.0 / n)

    def resize_set(self, set_index: int, new_frac_pages: float):
        """Dynamic hot-set change (Fig. 4 event 5 / Fig. 8 event 2)."""
        sets = list(self.spec.sets)
        fp, fa = sets[set_index]
        sets[set_index] = (new_frac_pages, fa)
        self.spec = dataclasses.replace(self.spec, sets=tuple(sets))
        self.probs = self._build_probs(self.spec, len(self.page_ids))[self._perm]

    def set_skew(self, set_index: int, new_frac_accesses: float):
        """Hotness-skew change: a set's share of accesses moves, its page
        footprint does not (scenario event ``SkewChange``)."""
        sets = list(self.spec.sets)
        fp, fa = sets[set_index]
        sets[set_index] = (fp, new_frac_accesses)
        self.spec = dataclasses.replace(self.spec, sets=tuple(sets))
        self.probs = self._build_probs(self.spec, len(self.page_ids))[self._perm]

    def shift_sets(self):
        """Working-set shift (phase change): re-scatter the skew sets onto a
        fresh permutation of the tenant's pages. Set sizes and access shares
        are unchanged but the policy's learned heat map is instantly stale
        (scenario event ``ShiftWorkingSet``)."""
        self._perm = self.rng.permutation(len(self.page_ids))
        self.probs = self._build_probs(self.spec, len(self.page_ids))[self._perm]

    def pingpong_shift(self):
        """Ping-pong working-set thrash (scenario event ``PingPongShift``):
        toggle between the CURRENT scatter and one fixed alternate. Unlike
        :meth:`shift_sets` the hot set keeps returning to pages the policy
        may still be demoting — the schedule that makes migration cost (and
        the thrashing guard) observable under finite bandwidth."""
        if not hasattr(self, "_pp_perms"):
            self._pp_perms = (self._perm, self.rng.permutation(len(self.page_ids)))
            self._pp_side = 0
        self._pp_side ^= 1
        self._perm = self._pp_perms[self._pp_side]
        self.probs = self._build_probs(self.spec, len(self.page_ids))[self._perm]

    def miss_ratio(self, tier: np.ndarray) -> float:
        t = tier[self.page_ids]
        return float(self.probs[t == TIER_SLOW].sum())


@dataclass
class EpochRecord:
    epoch: int
    throughput: Dict[str, float]  # ops/s per tenant
    fmmr_true: Dict[str, float]
    fmmr_measured: Dict[str, float]
    fast_pages: Dict[str, int]
    p50: Dict[str, float]
    p90: Dict[str, float]
    p99: Dict[str, float]
    migrated_pages: int  # pages COMMITTED this epoch (drains in queue mode)
    stalled: bool
    migration_bytes: float = 0.0  # committed bytes charged to the slow tier
    queue_depth: int = 0  # in-flight migrations after the epoch
    # storm-health flow (queue-mode backends; zeros otherwise): entries
    # enqueued / drained / cancelled during the epoch. Phase-level
    # cancel/drain ratios and ping-pong rates (ResponsivenessStats) sum
    # these per-epoch deltas.
    queue_enqueued: int = 0
    queue_drained: int = 0
    queue_cancelled: int = 0


class ColocationSim:
    """Closed-loop multi-tenant simulation against a placement backend.

    The cost model is vectorized over a tenant axis (prob-matrix [n, P]):
    miss ratios, the 4-iteration latency fixed point and the access-count
    scatter are single array expressions, so simulator overhead stays flat
    as tenants are added. With ``policy_chunk > 1`` and a backend exposing
    ``run_epochs`` (CentralManager), steady-state stretches run k policy
    epochs per device dispatch via the ``lax.scan`` fast path; chunked
    epochs approximate intermediate miss ratios with the backend's sampled
    FMMR telemetry and do not model migration stalls (chunk boundaries
    always re-measure exactly).
    """

    def __init__(
        self,
        backend,  # CentralManager or a baseline with the same surface
        machine: MachineSpec = OPTANE,
        epoch_seconds: float = 1.0,
        seed: int = 0,
        access_noise: bool = True,
        policy_chunk: int = 1,
    ):
        self.backend = backend
        self.machine = machine
        self.epoch_s = epoch_seconds
        self.rng = np.random.default_rng(seed)
        self.tenants: Dict[str, TenantSim] = {}
        self.handles: Dict[str, int] = {}
        self.history: List[EpochRecord] = []
        self.access_noise = access_noise
        self.policy_chunk = policy_chunk
        self._stall_epochs = 0.0
        # machine failure (scenario MachineFail): a failed sim is frozen —
        # no accesses, no policy ticks; epochs are recorded as down-time
        self.failed = False

    # ----------------------------------------------------------- lifecycle
    def add_tenant(self, spec: WorkloadSpec) -> TenantSim:
        h = self.backend.register(spec.t_miss)
        pages = self.backend.allocate(h, spec.n_pages)
        sim = TenantSim(spec, pages, self.rng)
        self.tenants[spec.name] = sim
        self.handles[spec.name] = h
        return sim

    def remove_tenant(self, name: str):
        h = self.handles.pop(name)
        self.backend.unregister(h)
        del self.tenants[name]

    def fail(self):
        """Machine failure: freeze the backend (scenario ``MachineFail``).
        Nothing mutates while down; :meth:`_record_down` fills the history
        with zero-throughput epochs so the down window is visible in every
        figure. Idempotence is rejected — failing a failed machine is a
        schedule bug."""
        if self.failed:
            raise ValueError("machine is already failed")
        self.failed = True

    def recover(self):
        """Machine recovery (scenario ``MachineRecover``): the backend
        resumes exactly where the failure froze it."""
        if not self.failed:
            raise ValueError("machine is not failed")
        self.failed = False

    def _record_down(self, k: int = 1) -> List[EpochRecord]:
        """Record ``k`` down-time epochs: zero throughput, all-miss FMMR,
        no fast pages, no migrations. Keeps per-epoch histories aligned
        across a fleet when one machine is failed."""
        names = list(self.tenants)
        zero = {nm: 0.0 for nm in names}
        one = {nm: 1.0 for nm in names}
        for _ in range(k):
            self.history.append(EpochRecord(
                epoch=len(self.history),
                throughput=dict(zero),
                fmmr_true=dict(one),
                fmmr_measured=dict(one),
                fast_pages={nm: 0 for nm in names},
                p50=dict(zero), p90=dict(zero), p99=dict(zero),
                migrated_pages=0, stalled=False,
                migration_bytes=0.0, queue_depth=0,
            ))
        return self.history[-k:]

    def set_target(self, name: str, t_miss: float):
        self.backend.set_target(self.handles[name], t_miss)
        self.tenants[name].spec = dataclasses.replace(
            self.tenants[name].spec, t_miss=t_miss
        )

    # ----------------------------------------------------------- cost model
    def _arrays(self):
        """(names, prob_matrix [n,P], page_mask [n,P], threads [n], bpo [n]).

        Rebuilt per epoch (cheap at simulator scale) so hot-set resizes and
        tenant churn are always reflected."""
        names = list(self.tenants)
        P = self.backend.num_pages
        n = len(names)
        M = np.zeros((n, P))
        page_mask = np.zeros((n, P), bool)
        threads = np.empty(n)
        bpo = np.empty(n)
        for i, nm in enumerate(names):
            t = self.tenants[nm]
            M[i, t.page_ids] = t.probs
            page_mask[i, t.page_ids] = True
            threads[i] = t.spec.threads
            bpo[i] = max(t.spec.value_bytes, self.machine.access_bytes)
        return names, M, page_mask, threads, bpo

    def _latencies(
        self, miss: np.ndarray, migration_bytes: float, threads: np.ndarray, bpo: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Fixed-point closed-loop: returns (avg_latency_s [n], slow_op_lat_s [n]).

        Per-op latency = tier latency + value transfer at the tier's
        (contention-scaled) bandwidth; bandwidth contention couples tenants
        through the demand sums, so the iteration runs on whole arrays."""
        m = self.machine
        lat_f = m.fast.latency_ns * 1e-9
        lat_s0 = m.slow.latency_ns * 1e-9
        slow_cap = m.slow.bandwidth_GBps * 1e9
        fast_cap = m.fast.bandwidth_GBps * 1e9

        def op_lat(sf=1.0, ss=1.0):
            f = lat_f + bpo / (fast_cap / sf)
            s = lat_s0 * ss + bpo / (slow_cap / ss)
            return f * (1.0 - miss) + s * miss, s

        lat, slow_op = op_lat()
        for _ in range(4):
            tput = threads / lat
            demand_slow = migration_bytes / self.epoch_s + (tput * miss * bpo).sum()
            demand_fast = migration_bytes / self.epoch_s + (tput * (1.0 - miss) * bpo).sum()
            scale_s = max(1.0, demand_slow / slow_cap)
            scale_f = max(1.0, demand_fast / fast_cap)
            lat, slow_op = op_lat(scale_f, scale_s)
        return lat, slow_op

    @staticmethod
    def _mixture_quantile(q: float, miss: float, lat_fast: float, lat_slow: float) -> float:
        return lat_slow if miss > (1.0 - q) else lat_fast

    def _sample_counts(self, M: np.ndarray, ops: np.ndarray) -> np.ndarray:
        """i64[P] access counts reported to the backend this epoch.

        The backend only ever sees the per-page TOTAL across tenants, and a
        sum of independent Poissons is itself Poisson of the summed rate —
        so the noisy path draws ONE [P] sample from the aggregate
        expectation (``ops @ M``) instead of an [n, P] per-tenant draw:
        distributionally identical through every observable, and an
        n-fold cheaper host step on the sweep pipeline's critical path."""
        if self.access_noise:
            drawn = self.rng.poisson(np.maximum(ops @ M, 0.0))
            return drawn.astype(np.int64)
        # noiseless: per-tenant truncation before the sum, exactly as before
        expect = M * ops[:, None]
        return expect.astype(np.int64).sum(axis=0)

    def _record(
        self, names, miss, tput, measured, fast_pages, mig_frac, fast_op, slow_op,
        migrated, stalled, queue_depth=0, queue_flow=(0, 0, 0),
    ) -> EpochRecord:
        """Assemble the per-epoch telemetry dicts from the tenant-axis arrays."""
        quant = {}
        for qq in (0.50, 0.90, 0.99):
            quant[qq] = {
                nm: self._mixture_quantile(qq, miss[i] + mig_frac, fast_op[i], slow_op[i])
                for i, nm in enumerate(names)
            }
        rec = EpochRecord(
            epoch=len(self.history),
            throughput={nm: float(tput[i]) for i, nm in enumerate(names)},
            fmmr_true={nm: float(miss[i]) for i, nm in enumerate(names)},
            fmmr_measured={nm: float(measured[i]) for i, nm in enumerate(names)},
            fast_pages={nm: int(fast_pages[i]) for i, nm in enumerate(names)},
            p50=quant[0.50],
            p90=quant[0.90],
            p99=quant[0.99],
            migrated_pages=int(migrated),
            stalled=stalled,
            migration_bytes=float(migrated) * self.machine.page_bytes,
            queue_depth=int(queue_depth),
            queue_enqueued=int(queue_flow[0]),
            queue_drained=int(queue_flow[1]),
            queue_cancelled=int(queue_flow[2]),
        )
        self.history.append(rec)
        return rec

    def _measured_fmmr(self, names) -> np.ndarray:
        backend = self.backend
        if hasattr(backend, "tenants") and hasattr(backend.tenants, "a_miss"):
            a_miss = np.asarray(backend.tenants.a_miss)  # one batched transfer
            return np.array([a_miss[self.handles[nm]] for nm in names])
        if hasattr(backend, "fmmr_of"):
            return np.array([backend.fmmr_of(self.handles[nm]) for nm in names])
        return np.zeros(len(names))

    # ----------------------------------------------------------- epoch
    def run_epoch(self) -> EpochRecord:
        m = self.machine
        names, M, page_mask, threads, bpo = self._arrays()
        tier = np.asarray(self.backend.tiers())
        miss = (M * (tier == TIER_SLOW)[None, :]).sum(axis=1)

        # migration traffic of the PREVIOUS epoch's plan affects this epoch's
        # latency; simpler: compute after policy and charge within this epoch.
        lat, _slow0 = self._latencies(miss, 0.0, threads, bpo)
        ops = threads / lat * self.epoch_s
        self.backend.record_access(self._sample_counts(M, ops))

        # policy tick (may be stalled by over-requested migration, Fig. 9)
        stalled = self._stall_epochs >= 1.0
        migrated = 0
        queue_depth = 0
        queue_flow = (0, 0, 0)
        if stalled:
            self._stall_epochs -= 1.0
            # the policy thread is frozen but queued migrations are still
            # in flight: report the live depth, not 0
            if hasattr(self.backend, "queue_depth"):
                queue_depth = self.backend.queue_depth()
        else:
            result = self.backend.run_epoch()
            mp = getattr(result, "migrated_pages", None)
            # queue-mode backends report COMMITTED moves (selections may
            # still be in flight); instant backends report the plan
            migrated = (
                mp if mp is not None
                else int(result.plan.num_promote) + int(result.plan.num_demote)
            )
            queue_depth = getattr(result, "queue_depth", 0)
            queue_flow = getattr(result, "queue_flow", (0, 0, 0))
            mig_bytes = migrated * m.page_bytes
            mig_time = mig_bytes / (m.migration_GBps * 1e9)
            # a backend whose drain is ALREADY paced by a finite bandwidth
            # models its own DMA contention; everyone else (instant apply,
            # or a queue with unlimited bandwidth dumping its backlog) is
            # subject to the over-requested-migration stall (Fig. 9)
            paced = getattr(self.backend, "migration_bounded", False)
            if mig_time > self.epoch_s and not paced:
                self._stall_epochs += mig_time / self.epoch_s - 1.0

        # recompute latency including migration interference
        mig_bytes = migrated * m.page_bytes
        lat, slow_op = self._latencies(miss, mig_bytes, threads, bpo)
        fast_op = m.fast.latency_ns * 1e-9 + bpo / (m.fast.bandwidth_GBps * 1e9)
        # write-protect stall term: fraction of accesses landing on in-flight
        # pages pay the slow-tier copy latency
        mig_frac = min(mig_bytes / max(m.page_bytes, 1) / max(self.backend.num_pages, 1), 1.0)

        tput = threads / lat
        measured = self._measured_fmmr(names)
        tier = np.asarray(self.backend.tiers())
        owner = np.asarray(self.backend.owners())
        fast_pages = (page_mask & (owner >= 0)[None, :] & (tier == TIER_FAST)[None, :]).sum(axis=1)
        return self._record(
            names, miss, tput, measured, fast_pages, mig_frac, fast_op, slow_op,
            migrated, stalled, queue_depth=queue_depth, queue_flow=queue_flow,
        )

    def _chunk_prepare(self, arrays=None, tier=None):
        """(counts[P], ctx) for a chunked stretch: freeze the access
        distribution at the chunk entry and draw one epoch's worth of
        access counts (replayed every epoch by the scan). ``ctx`` carries
        the frozen cost-model arrays for :meth:`_chunk_record`.

        ``arrays`` (a prior :meth:`_arrays` result) and ``tier`` (the
        chunk-entry placement) let the pipelined sweep driver reuse the
        tenant matrices across the chunks of an event-free stretch and feed
        the placement from one stacked fleet transfer — same values either
        way, so the drawn counts (and the RNG stream) are bit-identical to
        the self-measuring path."""
        names, M, page_mask, threads, bpo = arrays if arrays is not None else self._arrays()
        if tier is None:
            tier = np.asarray(self.backend.tiers())
        miss0 = (M * (tier == TIER_SLOW)[None, :]).sum(axis=1)
        lat, _ = self._latencies(miss0, 0.0, threads, bpo)
        ops = threads / lat * self.epoch_s
        return self._sample_counts(M, ops), (names, M, threads, bpo)

    def _chunk_record(self, res, k: int, ctx, tier_end=None) -> List[EpochRecord]:
        """Fold a ``MultiEpochResult`` for a chunk prepared by
        :meth:`_chunk_prepare` into the epoch history (one telemetry
        snapshot for the whole chunk). ``tier_end`` is the post-chunk
        placement; passing it (captured at the NEXT chunk's prepare) lets
        the pipelined driver record this chunk while the next one is
        already executing on device."""
        m = self.machine
        names, M, threads, bpo = ctx

        handles = [self.handles[nm] for nm in names]
        fmmr_now = np.asarray(res.stats.fmmr_now)[:, handles]  # [k, n]
        # stats.fast_pages is the holding BEFORE that epoch's migration; add
        # the epoch's own moves so chunked records match the single-step
        # path's post-migration read (ownership is static within a chunk).
        # In queue mode selections are not commits: the next epoch's holdings
        # already reflect the bounded drain, so no adjustment is sound there.
        if getattr(res.stats, "queue", None) is not None:
            fastp = np.asarray(res.stats.fast_pages)[:, handles]
        else:
            fastp = (
                np.asarray(res.stats.fast_pages)
                + np.asarray(res.stats.promoted)
                - np.asarray(res.stats.demoted)
            )[:, handles]
        migrated = res.migrated_per_epoch
        depth = res.queue_depth_per_epoch
        flows = (
            res.queue_flow_per_epoch
            if hasattr(res, "queue_flow_per_epoch")
            else np.zeros((k, 3), np.int64)
        )
        measured_k = np.asarray(res.stats.fmmr_ewma)[:, handles]
        if tier_end is None:
            tier_end = np.asarray(self.backend.tiers())
        miss_end = (M * (tier_end == TIER_SLOW)[None, :]).sum(axis=1)
        fast_op = m.fast.latency_ns * 1e-9 + bpo / (m.fast.bandwidth_GBps * 1e9)
        for i in range(k):
            miss = miss_end if i == k - 1 else fmmr_now[i]
            mig_bytes = migrated[i] * m.page_bytes
            lat, slow_op = self._latencies(miss, mig_bytes, threads, bpo)
            mig_frac = min(mig_bytes / max(m.page_bytes, 1) / max(self.backend.num_pages, 1), 1.0)
            self._record(
                names, miss, threads / lat, measured_k[i], fastp[i], mig_frac,
                fast_op, slow_op, migrated[i], stalled=False, queue_depth=depth[i],
                queue_flow=flows[i],
            )
        return self.history[-k:]

    def run_chunk(self, k: int) -> List[EpochRecord]:
        """Run k epochs through the backend's fused ``lax.scan`` path.

        The access distribution is frozen at the chunk entry (steady-state
        assumption); intermediate miss ratios come from the backend's sampled
        FMMR telemetry, the final epoch re-measures placement exactly.
        Migration stalls are not modeled inside a chunk.
        """
        counts, ctx = self._chunk_prepare()
        res = self.backend.run_epochs(k, counts=counts)
        return self._chunk_record(res, k, ctx)

    def run(
        self,
        n_epochs: int,
        events: Optional[Dict[int, Callable[["ColocationSim"], None]]] = None,
    ) -> List[EpochRecord]:
        events = events or {}
        end = len(self.history) + n_epochs
        while len(self.history) < end:
            cur = len(self.history)
            if cur in events:
                events[cur](self)
            if self.failed:
                self._record_down(1)
                continue
            chunkable = (
                self.policy_chunk > 1
                and self.tenants
                and hasattr(self.backend, "run_epochs")
                and self._stall_epochs < 1.0
            )
            if chunkable:
                horizon = min([e for e in events if e > cur], default=end)
                k = min(self.policy_chunk, horizon - cur, end - cur)
            else:
                k = 1
            if k > 1:
                self.run_chunk(k)
            else:
                self.run_epoch()
        return self.history

    def run_scenario(self, scenario, on_event=None):
        """Execute a declarative ``core.scenario.Scenario`` against this
        sim's backend; returns a ``ScenarioResult`` with per-phase
        aggregates. (Thin delegate — the engine lives in core/scenario.py.)
        """
        from repro.core.scenario import run_scenario

        return run_scenario(self, scenario, on_event=on_event)
