"""Tiered-memory colocation simulator (drives the paper-figure benchmarks).

The simulator runs GUPS/KVS-like tenant workloads against a placement policy
(MaxMem's CentralManager or a baseline from ``core.baselines``) and evaluates
a machine cost model each epoch:

  * per-access latency  = hit * lat_fast + miss * lat_slow(load)
  * slow-tier load      = sum of tenant miss traffic + migration traffic;
                          latency scales by demand/capacity when saturated
  * tenant throughput   = threads / avg_latency  (closed-loop, fixed point)
  * tail latencies      = quantiles of the two-point access mixture with a
                          migration-interference term (write-protect stalls)

Constants are published-order-of-magnitude (DRAM ~80ns/100GB/s, Optane
~300ns/30GB/s read, I/OAT ~4GB/s/chan; TPU profile: HBM 819GB/s vs host DMA
~50GB/s). The *policies* are exact; the cost model only needs to rank them,
matching the paper's qualitative claims.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.manager import CentralManager
from repro.core.types import TIER_FAST, TIER_SLOW


@dataclass(frozen=True)
class TierSpec:
    latency_ns: float
    bandwidth_GBps: float


@dataclass(frozen=True)
class MachineSpec:
    fast: TierSpec
    slow: TierSpec
    page_bytes: int = 2 << 20  # 2 MB huge pages (paper granularity)
    migration_GBps: float = 4.0  # I/OAT DMA engine class
    access_bytes: int = 64  # one cache line per op (GUPS)


OPTANE = MachineSpec(fast=TierSpec(80, 100.0), slow=TierSpec(300, 30.0))
TPU_HOST = MachineSpec(
    fast=TierSpec(500, 819.0),
    slow=TierSpec(2500, 50.0),
    page_bytes=2 << 20,
    migration_GBps=25.0,
)


@dataclass
class WorkloadSpec:
    """Hot/warm/cold set access skew, GUPS-style closed-loop tenant."""

    name: str
    n_pages: int
    t_miss: float = 1.0
    threads: int = 2
    # (fraction_of_pages, fraction_of_accesses) per set; remainder uniform
    sets: Tuple[Tuple[float, float], ...] = ()
    value_bytes: int = 64  # per-op payload (16 KB for the KVS workload)


class TenantSim:
    def __init__(self, spec: WorkloadSpec, page_ids: np.ndarray, rng: np.random.Generator):
        self.spec = spec
        self.page_ids = np.asarray(page_ids)
        self.rng = rng
        # scatter hot/warm sets across the virtual address space: the initial
        # fast-first allocation must not accidentally equal the hot set
        self._perm = rng.permutation(len(page_ids))
        self.probs = self._build_probs(spec, len(page_ids))[self._perm]

    @staticmethod
    def _build_probs(spec: WorkloadSpec, n: int) -> np.ndarray:
        probs = np.zeros(n)
        start = 0
        frac_left = 1.0
        for fp, fa in spec.sets:
            k = max(1, int(round(fp * n)))
            probs[start : start + k] = fa / k
            start += k
            frac_left -= fa
        rest = n - start
        if rest > 0 and frac_left > 0:
            probs[start:] = frac_left / rest
        s = probs.sum()
        return probs / s if s > 0 else np.full(n, 1.0 / n)

    def resize_set(self, set_index: int, new_frac_pages: float):
        """Dynamic hot-set change (Fig. 4 event 5 / Fig. 8 event 2)."""
        sets = list(self.spec.sets)
        fp, fa = sets[set_index]
        sets[set_index] = (new_frac_pages, fa)
        self.spec = dataclasses.replace(self.spec, sets=tuple(sets))
        self.probs = self._build_probs(self.spec, len(self.page_ids))[self._perm]

    def miss_ratio(self, tier: np.ndarray) -> float:
        t = tier[self.page_ids]
        return float(self.probs[t == TIER_SLOW].sum())


@dataclass
class EpochRecord:
    epoch: int
    throughput: Dict[str, float]  # ops/s per tenant
    fmmr_true: Dict[str, float]
    fmmr_measured: Dict[str, float]
    fast_pages: Dict[str, int]
    p50: Dict[str, float]
    p90: Dict[str, float]
    p99: Dict[str, float]
    migrated_pages: int
    stalled: bool


class ColocationSim:
    """Closed-loop multi-tenant simulation against a placement backend."""

    def __init__(
        self,
        backend,  # CentralManager or a baseline with the same surface
        machine: MachineSpec = OPTANE,
        epoch_seconds: float = 1.0,
        seed: int = 0,
        access_noise: bool = True,
    ):
        self.backend = backend
        self.machine = machine
        self.epoch_s = epoch_seconds
        self.rng = np.random.default_rng(seed)
        self.tenants: Dict[str, TenantSim] = {}
        self.handles: Dict[str, int] = {}
        self.history: List[EpochRecord] = []
        self.access_noise = access_noise
        self._stall_epochs = 0.0

    # ----------------------------------------------------------- lifecycle
    def add_tenant(self, spec: WorkloadSpec) -> TenantSim:
        h = self.backend.register(spec.t_miss)
        pages = self.backend.allocate(h, spec.n_pages)
        sim = TenantSim(spec, pages, self.rng)
        self.tenants[spec.name] = sim
        self.handles[spec.name] = h
        return sim

    def remove_tenant(self, name: str):
        h = self.handles.pop(name)
        self.backend.unregister(h)
        del self.tenants[name]

    def set_target(self, name: str, t_miss: float):
        self.backend.set_target(self.handles[name], t_miss)
        self.tenants[name].spec = dataclasses.replace(
            self.tenants[name].spec, t_miss=t_miss
        )

    # ----------------------------------------------------------- cost model
    def _latencies(
        self, misses: Dict[str, float], migration_bytes: float
    ) -> Tuple[Dict[str, float], Dict[str, float]]:
        """Fixed-point closed-loop: returns (avg_latency_s, slow_op_lat_s).

        Per-op latency = tier latency + value transfer at the tier's
        (contention-scaled) bandwidth; bandwidth contention couples tenants."""
        m = self.machine
        lat_f = m.fast.latency_ns * 1e-9
        lat_s0 = m.slow.latency_ns * 1e-9
        slow_cap = m.slow.bandwidth_GBps * 1e9
        fast_cap = m.fast.bandwidth_GBps * 1e9

        def op_lat(ms, bytes_per_op, sf=1.0, ss=1.0):
            f = lat_f + bytes_per_op / (fast_cap / sf)
            s = lat_s0 * ss + bytes_per_op / (slow_cap / ss)
            return f * (1 - ms) + s * ms, s

        lat = {}
        slow_op = {}
        for n, t in self.tenants.items():
            lat[n], slow_op[n] = op_lat(misses[n], max(t.spec.value_bytes, m.access_bytes))
        for _ in range(4):
            demand_slow = migration_bytes / self.epoch_s
            demand_fast = migration_bytes / self.epoch_s
            for n, t in self.tenants.items():
                tput = t.spec.threads / lat[n]
                bytes_per_op = max(t.spec.value_bytes, m.access_bytes)
                demand_slow += tput * misses[n] * bytes_per_op
                demand_fast += tput * (1 - misses[n]) * bytes_per_op
            scale_s = max(1.0, demand_slow / slow_cap)
            scale_f = max(1.0, demand_fast / fast_cap)
            for n, t in self.tenants.items():
                lat[n], slow_op[n] = op_lat(
                    misses[n], max(t.spec.value_bytes, m.access_bytes),
                    scale_f, scale_s,
                )
        return lat, slow_op

    @staticmethod
    def _mixture_quantile(q: float, miss: float, lat_fast: float, lat_slow: float) -> float:
        return lat_slow if miss > (1.0 - q) else lat_fast

    # ----------------------------------------------------------- epoch
    def run_epoch(self) -> EpochRecord:
        m = self.machine
        tier = np.asarray(self.backend.pages.tier)
        misses = {n: t.miss_ratio(tier) for n, t in self.tenants.items()}

        # migration traffic of the PREVIOUS epoch's plan affects this epoch's
        # latency; simpler: compute after policy and charge within this epoch.
        lat, _slow0 = self._latencies(misses, migration_bytes=0.0)
        ops = {
            n: t.spec.threads / lat[n] * self.epoch_s for n, t in self.tenants.items()
        }

        # report accesses
        counts = np.zeros(self.backend.num_pages, np.int64)
        for n, t in self.tenants.items():
            expect = t.probs * ops[n]
            if self.access_noise:
                expect = self.rng.poisson(np.maximum(expect, 0))
            counts[t.page_ids] += expect.astype(np.int64)
        self.backend.record_access(counts)

        # policy tick (may be stalled by over-requested migration, Fig. 9)
        stalled = self._stall_epochs >= 1.0
        migrated = 0
        if stalled:
            self._stall_epochs -= 1.0
            result = None
        else:
            result = self.backend.run_epoch()
            migrated = int(result.plan.num_promote) + int(result.plan.num_demote)
            mig_bytes = migrated * m.page_bytes
            mig_time = mig_bytes / (m.migration_GBps * 1e9)
            if mig_time > self.epoch_s:
                self._stall_epochs += mig_time / self.epoch_s - 1.0

        # recompute latency including migration interference
        mig_bytes = migrated * m.page_bytes
        lat, slow_op = self._latencies(misses, migration_bytes=mig_bytes)

        def fast_op(n):
            b = max(self.tenants[n].spec.value_bytes, m.access_bytes)
            return m.fast.latency_ns * 1e-9 + b / (m.fast.bandwidth_GBps * 1e9)
        # write-protect stall term: fraction of accesses landing on in-flight
        # pages pay the slow-tier copy latency
        mig_frac = min(mig_bytes / max(m.page_bytes, 1) / max(self.backend.num_pages, 1), 1.0)

        tput = {n: t.spec.threads / lat[n] for n, t in self.tenants.items()}
        measured = {}
        for n in self.tenants:
            h = self.handles[n]
            measured[n] = (
                float(self.backend.fmmr_of(h)) if hasattr(self.backend, "fmmr_of") else misses[n]
            )
        fast_pages = {
            n: int(
                (
                    (np.asarray(self.backend.pages.owner)[self.tenants[n].page_ids] >= 0)
                    & (np.asarray(self.backend.pages.tier)[self.tenants[n].page_ids] == TIER_FAST)
                ).sum()
            )
            for n in self.tenants
        }
        q = lambda qq, n: self._mixture_quantile(
            qq, misses[n] + mig_frac, fast_op(n), slow_op[n]
        )
        rec = EpochRecord(
            epoch=len(self.history),
            throughput=tput,
            fmmr_true=misses,
            fmmr_measured=measured,
            fast_pages=fast_pages,
            p50={n: q(0.50, n) for n in self.tenants},
            p90={n: q(0.90, n) for n in self.tenants},
            p99={n: q(0.99, n) for n in self.tenants},
            migrated_pages=migrated,
            stalled=stalled,
        )
        self.history.append(rec)
        return rec

    def run(
        self,
        n_epochs: int,
        events: Optional[Dict[int, Callable[["ColocationSim"], None]]] = None,
    ) -> List[EpochRecord]:
        events = events or {}
        for e in range(n_epochs):
            if len(self.history) in events:
                events[len(self.history)](self)
            self.run_epoch()
        return self.history
