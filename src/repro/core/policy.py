"""The MaxMem per-epoch policy step (paper §3.1 + §3.2), fully jittable.

Pipeline per epoch (cf. Figure 1 of the paper):
  1. fold sampled accesses into per-page counters (+ lazy cooling)   [bins]
  2. compute instantaneous FMMR per tenant, update EWMA (lambda=.5)  [fmmr]
  3. reallocate fast memory proportionally to distance from target   [fmmr]
     using half the migration budget
  4. intra-tenant rebalance with the other half: promote hottest-slow
     / demote coldest-fast pairs where it strictly improves FMMR
  5. emit a bounded MigrationPlan (page id lists) + telemetry

Victim selection uses the dense heat gradient: per-tenant rank of every page
within its (owner, tier) group by effective count — a composite-key argsort
replaces the paper's per-bin linked lists (TPU adaptation, DESIGN.md §2).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import bins, fmmr
from repro.core.types import (
    TIER_FAST,
    TIER_SLOW,
    EpochStats,
    MigrationPlan,
    PageState,
    PolicyParams,
    TenantState,
)


def _per_tenant_pages(pages: PageState, max_tenants: int) -> Tuple[jax.Array, jax.Array]:
    """(fast_pages[T], slow_pages[T]) holdings."""
    owner = jnp.where(pages.owner >= 0, pages.owner, max_tenants)
    fast = jnp.zeros((max_tenants + 1,), jnp.int32).at[owner].add(pages.tier == TIER_FAST)
    slow = jnp.zeros((max_tenants + 1,), jnp.int32).at[owner].add(pages.tier == TIER_SLOW)
    return fast[:-1], slow[:-1]


@partial(jax.jit, static_argnames=("max_tenants", "plan_size"))
def policy_epoch(
    pages: PageState,
    tenants: TenantState,
    sampled: jax.Array,  # u32[P] sampled accesses this epoch (PEBS analogue)
    params: PolicyParams,
    *,
    max_tenants: int,
    plan_size: int,
):
    """Returns (pages', tenants', MigrationPlan, EpochStats)."""
    P = pages.owner.shape[0]
    T = max_tenants

    # ---- 1. per-tenant fast/slow sample counts (tier *before* migration) ----
    owner_c = jnp.where(pages.owner >= 0, pages.owner, T)
    s_fast = (
        jnp.zeros((T + 1,), jnp.uint32)
        .at[owner_c]
        .add(jnp.where(pages.tier == TIER_FAST, sampled, 0))[:-1]
    )
    s_slow = (
        jnp.zeros((T + 1,), jnp.uint32)
        .at[owner_c]
        .add(jnp.where(pages.tier == TIER_SLOW, sampled, 0))[:-1]
    )
    pages, tenants, cooled = bins.accumulate_samples(
        pages, tenants, sampled, params.num_bins
    )

    # ---- 2. FMMR update ------------------------------------------------------
    now = fmmr.fmmr_now(s_fast.astype(jnp.float32), s_slow.astype(jnp.float32))
    ewma = fmmr.update_ewma(tenants.a_miss, now, params.ewma_lambda)
    ewma = jnp.where(tenants.active, ewma, 0.0)
    tenants = tenants._replace(a_miss=ewma)

    # ---- 3. proportional reallocation (budget R/2) ---------------------------
    fast_pages, slow_pages = _per_tenant_pages(pages, T)
    free_fast = params.fast_capacity - fast_pages.sum()
    realloc_budget = params.migration_budget // 2
    ra = fmmr.reallocate(
        tenants, fast_pages, free_fast, realloc_budget,
        fair_mode=params.fair_mode, hysteresis=params.hysteresis,
    )
    tenants = tenants._replace(flagged=ra.flagged)
    # the R/2 reallocation budget counts BOTH promotions and the demotions
    # that make room for them: rescale if gives+takes overshoot.
    ra_moves = ra.give.sum() + ra.take.sum()
    ra_scale = jnp.where(
        ra_moves > realloc_budget,
        realloc_budget.astype(jnp.float32) / jnp.maximum(ra_moves, 1),
        1.0,
    )
    take2 = jnp.floor(ra.take * ra_scale).astype(jnp.int32)
    give2 = jnp.floor(ra.give * ra_scale).astype(jnp.int32)
    # integer flooring can break gives <= free + takes: FCFS re-clamp
    give2 = fmmr.clamp_gives(give2, tenants.arrival, free_fast + take2.sum())
    ra = ra._replace(give=give2, take=take2)

    # ---- 4. intra-tenant rebalance (budget R/2; each pair = 2 moves) ---------
    eff = bins.effective_count(pages, tenants).astype(jnp.int32)  # [P]
    n_active = jnp.maximum(tenants.active.sum(), 1)
    rebal_share = (params.migration_budget - realloc_budget) // (2 * n_active)

    is_owned = pages.owner >= 0
    owner = jnp.maximum(pages.owner, 0)
    slow_cand = is_owned & (pages.tier == TIER_SLOW)
    fast_cand = is_owned & (pages.tier == TIER_FAST)

    # per-tenant rank by heat: composite sort key (tenant-major), then rank
    # within the (tenant, tier) segment. hot ranks: descending count.
    def _ranks(cand, descending):
        sign = -1 if descending else 1
        t_key = jnp.where(cand, owner, T).astype(jnp.int32)
        count_key = sign * jnp.where(cand, eff, 0).astype(jnp.int32)
        # lexsort: last key is primary -> grouped by tenant, heat-ordered within
        order = jnp.lexsort((count_key, t_key))
        sorted_t = t_key[order]
        idx = jnp.arange(P, dtype=jnp.int32)
        first = (
            jnp.full((T + 1,), jnp.iinfo(jnp.int32).max, jnp.int32)
            .at[sorted_t]
            .min(idx, mode="drop")
        )
        rank_sorted = idx - first[sorted_t]
        rank = jnp.full((P,), jnp.iinfo(jnp.int32).max, jnp.int32).at[order].set(rank_sorted)
        return jnp.where(cand, rank, jnp.iinfo(jnp.int32).max)

    hot_rank = _ranks(slow_cand, descending=True)  # 0 = hottest slow page
    cold_rank = _ranks(fast_cand, descending=False)  # 0 = coldest fast page

    # rebalance pair count n_t: compare i-th hottest slow vs i-th coldest fast
    def _sorted_counts(rank, cand, descending):
        vals = jnp.full((T, min(P, 4096)), -1, jnp.int32)
        # gather counts by (tenant, rank) for rank < window
        window = vals.shape[1]
        ok = cand & (rank < window)
        flat = jnp.where(ok, owner * window + rank, T * window)
        out = jnp.full((T * window + 1,), -1, jnp.int32).at[flat].max(
            jnp.where(ok, eff, -1), mode="drop"
        )
        return out[:-1].reshape(T, window)

    W = min(P, 4096)
    rebal_share = jnp.minimum(rebal_share, W)
    hot_counts = _sorted_counts(hot_rank, slow_cand, True)  # [T, W] desc
    cold_counts = _sorted_counts(cold_rank, fast_cand, False)  # [T, W] asc

    # Reallocation consumes the first `give` hottest-slow / `take` coldest-fast
    # victims; the i-th REBALANCE pair is (hot[give+i], cold[take+i]). Pairs
    # must fit the remaining candidates on BOTH sides so promote/demote stay
    # 1:1 per tenant (capacity invariant).
    n_slow_cand = jnp.zeros((T + 1,), jnp.int32).at[owner_c].add(slow_cand)[:-1]
    n_fast_cand = jnp.zeros((T + 1,), jnp.int32).at[owner_c].add(fast_cand)[:-1]
    give_eff = jnp.minimum(ra.give, n_slow_cand)
    take_eff = jnp.minimum(ra.take, n_fast_cand)
    max_pairs = jnp.clip(
        jnp.minimum(n_fast_cand - take_eff, n_slow_cand - give_eff), 0, rebal_share
    )
    i_idx = jnp.arange(W, dtype=jnp.int32)
    hot_i = jnp.take_along_axis(
        hot_counts, jnp.minimum(give_eff[:, None] + i_idx[None, :], W - 1), axis=1
    )
    cold_i = jnp.take_along_axis(
        cold_counts, jnp.minimum(take_eff[:, None] + i_idx[None, :], W - 1), axis=1
    )
    improves = (
        (hot_i > cold_i)
        & (hot_i >= 0)
        & (cold_i >= 0)
        & (i_idx[None, :] < max_pairs[:, None])
    )
    n_rebal = improves.sum(axis=1).astype(jnp.int32)  # [T]
    n_rebal = jnp.where(tenants.active, n_rebal, 0)

    # ---- 5. quotas -> plan ----------------------------------------------------
    promote_quota = give_eff + n_rebal  # <= n_slow_cand by construction
    demote_quota = take_eff + n_rebal  # <= n_fast_cand by construction

    promote_mask = slow_cand & (hot_rank < promote_quota[owner])
    demote_mask = fast_cand & (cold_rank < demote_quota[owner])

    promote_ids = jnp.nonzero(promote_mask, size=plan_size, fill_value=-1)[0].astype(jnp.int32)
    demote_ids = jnp.nonzero(demote_mask, size=plan_size, fill_value=-1)[0].astype(jnp.int32)
    plan = MigrationPlan(promote=promote_ids, demote=demote_ids)

    # ---- stats ---------------------------------------------------------------
    promoted = jnp.zeros((T + 1,), jnp.int32).at[owner_c].add(promote_mask)[:-1]
    demoted = jnp.zeros((T + 1,), jnp.int32).at[owner_c].add(demote_mask)[:-1]
    stats = EpochStats(
        fmmr_now=now,
        fmmr_ewma=ewma,
        fast_pages=fast_pages,
        slow_pages=slow_pages,
        promoted=promoted,
        demoted=demoted,
        cooled=cooled,
    )
    return pages, tenants, plan, stats


@jax.jit
def apply_plan(pages: PageState, plan: MigrationPlan) -> PageState:
    """Execute a migration plan on the metadata (data movement is the
    caller's job — pools + Pallas page_copy kernel, or DMA on real HW)."""
    P = pages.tier.shape[0]
    # -1 padding would wrap to P-1: remap to P so mode="drop" discards it
    promote = jnp.where(plan.promote >= 0, plan.promote, P)
    demote = jnp.where(plan.demote >= 0, plan.demote, P)
    tier = pages.tier
    tier = tier.at[promote].set(jnp.int8(TIER_FAST), mode="drop")
    tier = tier.at[demote].set(jnp.int8(TIER_SLOW), mode="drop")
    return pages._replace(tier=tier)
