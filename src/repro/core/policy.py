"""The MaxMem per-epoch policy step (paper §3.1 + §3.2), fully jittable.

Pipeline per epoch (cf. Figure 1 of the paper):
  1. fold sampled accesses into per-page counters (+ lazy cooling)   [bins]
  2. compute instantaneous FMMR per tenant, update EWMA (lambda=.5)  [fmmr]
  3. reallocate fast memory proportionally to distance from target   [fmmr]
     using half the migration budget
  4. intra-tenant rebalance with the other half: promote hottest-slow
     / demote coldest-fast pairs where it strictly improves FMMR
  5. emit a bounded MigrationPlan (page id lists) + telemetry

Victim selection is O(P) and *exact*: instead of sorting, each (tenant, tier)
candidate group is histogrammed by clamped effective count, and prefix sums
over the count axis yield a per-tenant cutoff count plus a residual for the
bucket the quota lands in — the paper's per-bin lists restated as cumulative
offsets at count granularity (DESIGN.md §2). Ties within a count bucket break
by lowest page id, matching the stable lexsort the seed used, and there is no
candidate window: selection is exact for any number of candidates per tenant.

Entry points:
  * ``policy_epoch``  — one epoch on explicit (pages, tenants, sampled).
  * ``epoch_step``    — fused sample -> policy -> apply on a ``PolicyState``
                        (single dispatch; buffers donated off-CPU).
  * ``multi_epoch``   — ``lax.scan`` of the epoch across k epochs in one
                        dispatch, with stacked per-epoch telemetry.
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import bins, fmmr
from repro.core.faults import (
    SENTINEL_NAN,
    SENTINEL_OCCUPANCY,
    SENTINEL_ORPHAN,
    SENTINEL_OWNERSHIP,
    SENTINEL_QUEUE,
)
from repro.core.sampler import sample_accesses
from repro.core.tiling import tiled_cumsum
from repro.core.types import (
    DIR_DEMOTE,
    DIR_NONE,
    DIR_PROMOTE,
    TIER_FAST,
    TIER_NONE,
    TIER_SLOW,
    EpochStats,
    MigrationPlan,
    MigrationQueue,
    OwnerSegments,
    PageState,
    PolicyParams,
    PolicyState,
    QueueStats,
    TenantState,
)

# Effective counts at or above this value share one histogram bucket (their
# relative order becomes a tie). Cooling fires at most once per epoch
# (paper §3.2), so steady-state effective counts approach 2x the per-epoch
# sampled adds — ~64 at paper-scale sampling, but THOUSANDS under
# simulator-scale access streams, where a tighter clamp would saturate hot
# and cold candidates into one bucket and strictly-improving rebalance
# pairs would vanish. 4096 keeps count-granular ranks through that regime;
# the [T, C] tables it sizes are consulted by per-tenant binary searches
# (not full-width reductions), so the width costs two cumsums, not a
# dozen O(T*C) passes.
COUNT_CLAMP = 4096

# Buffer donation saves a copy of the O(P) state arrays on accelerators; the
# CPU backend cannot donate and would warn on every call. The decision is
# made per call (not at import) so configuring the platform after importing
# this module still does the right thing.
def _donate_state() -> bool:
    return jax.default_backend() != "cpu"




def _per_tenant_pages(
    pages: PageState,
    max_tenants: int,
    segs: Optional[OwnerSegments] = None,
    owner_onehot: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """(fast_pages[T], slow_pages[T]) holdings.

    With owner segments: two O(P) segment cumsums. Otherwise a [T, P]
    one-hot reduction (still far cheaper than a P-element scatter-add on
    XLA:CPU, where scatters execute element-serially)."""
    if segs is not None:
        tier_s = pages.tier[segs.order]
        fast = bins.seg_sums((tier_s == TIER_FAST).astype(jnp.int32), segs.start)
        slow = bins.seg_sums((tier_s == TIER_SLOW).astype(jnp.int32), segs.start)
        return fast, slow
    if owner_onehot is None:
        T = max_tenants
        owner_onehot = pages.owner[None, :] == jnp.arange(T, dtype=jnp.int32)[:, None]
    fast = (owner_onehot & (pages.tier == TIER_FAST)[None, :]).sum(axis=1)
    slow = (owner_onehot & (pages.tier == TIER_SLOW)[None, :]).sum(axis=1)
    return fast.astype(jnp.int32), slow.astype(jnp.int32)


def _select_victims(
    key,  # i32[P] clamped effective counts
    owner,  # i32[P] owner clamped to >= 0
    slow_cand,  # bool[P] promotion candidates
    fast_cand,  # bool[P] demotion candidates
    hist_slow,  # i32[T,C]
    hist_fast,  # i32[T,C]
    cum_slow,  # i32[T,C] inclusive prefix sums of the histograms
    cum_fast,
    pq,  # i32[T] promote quota
    dq,  # i32[T] demote quota
    owner_onehot,  # bool[T,P] (one-hot path; None when segs is given)
    segs: Optional[OwnerSegments] = None,
):
    """(promote_mask, demote_mask) bool[P]: per tenant, exactly the ``pq[t]``
    HOTTEST slow candidates and ``dq[t]`` COLDEST fast candidates.

    Counting-rank selection from the [T, C] candidate histograms: buckets
    strictly beyond a per-tenant cutoff count are taken whole; the single
    bucket each quota lands in is filled in page-id order (stable, matching
    the seed's lexsort tie-break). The two in-bucket position counters are
    packed into one u32 prefix sum (promote low 16 bits, demote high 16) —
    sound for P <= 65536 because the only overflow case (65536 same-count
    members in one tenant) forces the other side's quota to zero.
    """
    T, C = hist_slow.shape
    P = key.shape[0]
    srch = jax.vmap(partial(jnp.searchsorted, side="left"))
    srch_r = jax.vmap(partial(jnp.searchsorted, side="right"))
    idx_t = jnp.arange(T)

    # hot side: smallest count whose whole bucket fits under the quota.
    # #candidates with count >= c is total - cum[c-1] (non-increasing), so
    # the cutoff is a per-tenant binary search on the cumulative table —
    # [T] log C work instead of materializing the [T, C] suffix-count
    # table and reducing over it (bit-identical: same integer predicate).
    total_slow = cum_slow[:, -1]
    v = total_slow - pq
    c_full = jnp.where(v <= 0, 0, 1 + srch(cum_slow, v))  # [T]; C when none fit
    cum_at = cum_slow[idx_t, jnp.maximum(c_full - 1, 0)]
    above = total_slow - jnp.where(c_full > 0, cum_at, 0)
    above = jnp.where(c_full < C, above, 0)  # candidates already taken whole
    r_p = pq - above  # residual from the straddling bucket c_full - 1

    # cold side: largest count whose whole bucket fits (cum_fast increasing)
    n_full = srch_r(cum_fast, dq)  # buckets taken whole: c < n_full
    below = cum_fast[idx_t, jnp.clip(n_full - 1, 0, C - 1)]
    below = jnp.where(n_full > 0, below, 0)
    r_d = dq - below  # residual from the straddling bucket n_full

    # The per-page tests below consume four per-tenant scalars (c_full,
    # n_full, r_p, r_d) — naively eight [T] -> [P] gathers through `owner`,
    # which dominate the whole selection pass on XLA:CPU. Pack each side's
    # (cutoff, residual) into ONE u32 table entry so each side costs a
    # single gather: cutoff in the high bits, residual (clamped at 0 —
    # the tests only consult positive residuals) in the low `rbits`.
    # r <= P < 2^rbits and cutoff <= C, so the pack is exact whenever
    # cbits + rbits <= 32; the unpacked comparands are bit-identical to
    # the unpacked path, which remains for (huge-P, huge-C) configurations.
    rbits = int(P).bit_length()
    cbits = int(C).bit_length()
    if cbits + rbits <= 32:
        def _pack(cut, res):
            return (cut.astype(jnp.uint32) << rbits) | jnp.maximum(res, 0).astype(jnp.uint32)

        sp = _pack(c_full, r_p)[owner]  # one gather for the slow side
        fp = _pack(n_full, r_d)[owner]  # one gather for the fast side
        cf_pg = (sp >> rbits).astype(jnp.int32)
        rp_pg = (sp & ((1 << rbits) - 1)).astype(jnp.int32)
        nf_pg = (fp >> rbits).astype(jnp.int32)
        rd_pg = (fp & ((1 << rbits) - 1)).astype(jnp.int32)
    else:
        cf_pg, rp_pg = c_full[owner], r_p[owner]
        nf_pg, rd_pg = n_full[owner], r_d[owner]
    member_p = slow_cand & (key == cf_pg - 1) & (rp_pg > 0)
    member_d = fast_cand & (key == nf_pg) & (rd_pg > 0)

    if segs is not None:
        occ_p, occ_d = _occ_segments(member_p, member_d, owner, segs)
    elif P <= 65536:
        # member counts are bounded by P <= 2^16, and the single possible
        # wrap (one tenant, all 2^16 pages in one bucket) is healed inside
        # _occ_packed — no runtime branch needed
        occ_p, occ_d = _occ_packed(member_p, member_d, owner, owner_onehot)
    else:
        # a 16-bit field wraps iff one tenant has >= 2^16 members in its
        # straddling bucket (mid-pool wraps also corrupt the carry, so the
        # in-packed healing is not enough here); bucket sizes are known, so
        # branch at runtime — the slow two-pass path only ever executes on
        # degenerate states
        safe = jnp.maximum(hist_slow.max(), hist_fast.max()) < (1 << 16)
        occ_p, occ_d = jax.lax.cond(
            safe, _occ_packed, _occ_twopass, member_p, member_d, owner, owner_onehot
        )

    promote = (slow_cand & (key >= cf_pg)) | (member_p & (occ_p <= rp_pg))
    demote = (fast_cand & (key < nf_pg)) | (member_d & (occ_d <= rd_pg))
    return promote, demote


def _occ_segments(member_p, member_d, owner, segs: OwnerSegments):
    """In-bucket page-id-order positions (1-based) via owner segments:
    gather the member flags into owner-sorted order, ONE global cumsum,
    subtract each segment's starting offset, gather back. Within a tenant
    the sorted order is page-id ascending (stable host sort), so positions
    are bit-identical to the one-hot [T, P] prefix sum.

    For P <= 65536 both member sets ride one packed u32 cumsum (promote
    low 16 bits, demote high 16). A field holds the GLOBAL member count at
    each sorted position; per-segment differences stay below 2^16 except
    the degenerate all-pages-one-bucket case, where the other side's quota
    is forced to zero and the wrapped 0 is healed exactly like
    :func:`_occ_packed`. Beyond 65536 pages the global count itself can
    wrap mid-pool, so two separate i32 cumsums are used instead.
    """
    P = member_p.shape[0]
    order, inv, start = segs.order, segs.inv, segs.start
    owner_s = owner[order]
    if P <= 65536:
        packed = member_p.astype(jnp.uint32) + (member_d.astype(jnp.uint32) << 16)
        cum = jnp.cumsum(packed[order])
        cum0 = jnp.concatenate([jnp.zeros((1,), jnp.uint32), cum])
        local = (cum - cum0[start[owner_s]])[inv]
        occ_p = (local & 0xFFFF).astype(jnp.int32)
        occ_d = (local >> 16).astype(jnp.int32)
        occ_p = jnp.where(member_p & (occ_p == 0), 1 << 16, occ_p)
        occ_d = jnp.where(member_d & (occ_d == 0), 1 << 16, occ_d)
        return occ_p, occ_d
    zero = jnp.zeros((1,), jnp.int32)
    cum_p = tiled_cumsum(member_p[order].astype(jnp.int32))
    cum0_p = jnp.concatenate([zero, cum_p])
    cum_d = tiled_cumsum(member_d[order].astype(jnp.int32))
    cum0_d = jnp.concatenate([zero, cum_d])
    off = start[owner_s]
    return (cum_p - cum0_p[off])[inv], (cum_d - cum0_d[off])[inv]


def _occ_packed(member_p, member_d, owner, owner_onehot):
    """In-bucket page-id-order positions (1-based) for both member sets via
    ONE per-tenant prefix sum: promote occupancy in the low 16 bits, demote
    in the high 16 (the sets are disjoint, so fields never interact)."""
    P = member_p.shape[0]
    packed = member_p.astype(jnp.uint32) + (member_d.astype(jnp.uint32) << 16)
    cum = jnp.cumsum(
        jnp.where(owner_onehot, packed[None, :], 0), axis=1, dtype=jnp.uint32
    )[owner, jnp.arange(P)]
    occ_p = (cum & 0xFFFF).astype(jnp.int32)
    occ_d = (cum >> 16).astype(jnp.int32)
    # members have 1-based positions, so a 0 field can only mean the value
    # wrapped at exactly 2^16 members (one tenant owning every page of a
    # 2^16-page pool in one bucket): restore the true position. The +1 the
    # wrap carries into the high field is unreachable — it would require a
    # demote member after the last page.
    occ_p = jnp.where(member_p & (occ_p == 0), 1 << 16, occ_p)
    occ_d = jnp.where(member_d & (occ_d == 0), 1 << 16, occ_d)
    return occ_p, occ_d


def _occ_twopass(member_p, member_d, owner, owner_onehot):
    """Wrap-proof fallback: one int32 prefix sum per member set."""
    P = member_p.shape[0]
    idx = jnp.arange(P)
    occ_p = jnp.cumsum(
        (owner_onehot & member_p[None, :]).astype(jnp.int8), axis=1, dtype=jnp.int32
    )[owner, idx]
    occ_d = jnp.cumsum(
        (owner_onehot & member_d[None, :]).astype(jnp.int8), axis=1, dtype=jnp.int32
    )[owner, idx]
    return occ_p, occ_d


def _pair_count(cum_slow, cum_fast, give, take, cap):
    """i32[T]: number of strictly-improving (hottest-slow, coldest-fast)
    rebalance pairs after skipping the reallocation victims.

    With hot counts descending and cold counts ascending the improving pairs
    form a prefix, and its length has a closed form over the count domain:
    pair m-1 improves iff some count c separates it, i.e.

        max_c min(#slow_hotter_than(c) - give, #fast_at_most(c) - take)

    f(c) = #slow_hotter_than(c) - give is non-increasing and g(c) =
    #fast_at_most(c) - take non-decreasing, so min(f, g) is unimodal with
    its maximum at the crossing: max = max(g(c*-1), f(c*)) where c* is the
    first c with g >= f. The crossing is a per-tenant binary search on the
    (non-decreasing) sum cum_fast + cum_slow — [T] log C work instead of
    building and max-reducing the [T, C] pairwise-minimum table, with the
    identical integer result.
    """
    T, C = cum_slow.shape
    idx_t = jnp.arange(T)
    total_slow = cum_slow[:, -1]
    # g(c) - f(c) = cum_fast[c] + cum_slow[c] - (total_slow + take - give)
    # (hotter(c) = #slow with count > c = total - cum_slow[c])
    h = cum_fast + cum_slow  # non-decreasing
    thr = total_slow + take - give
    c_star = jax.vmap(partial(jnp.searchsorted, side="left"))(h, thr)  # [T]
    # g(c*-1) (valid when c* > 0) and f(c*) (valid when c* < C)
    g_lo = cum_fast[idx_t, jnp.maximum(c_star - 1, 0)] - take
    f_hi = total_slow - cum_slow[idx_t, jnp.minimum(c_star, C - 1)] - give
    m = jnp.maximum(
        jnp.where(c_star > 0, g_lo, jnp.iinfo(jnp.int32).min),
        jnp.where(c_star < C, f_hi, jnp.iinfo(jnp.int32).min),
    )
    return jnp.clip(m, 0, cap).astype(jnp.int32)


def _epoch_core(
    pages: PageState,
    tenants: TenantState,
    sampled: jax.Array,  # u32[P] sampled accesses this epoch (PEBS analogue)
    params: PolicyParams,
    max_tenants: int,
    plan_size: int,
    count_clamp: int,
    collect_plan: bool,
    exclude: Optional[jax.Array] = None,  # bool[P] pages barred from selection
    segs: Optional[OwnerSegments] = None,  # owner-sorted permutation (§5)
):
    """One policy epoch; trace-time body shared by all jitted entry points.

    Returns (pages, tenants, promote_mask, demote_mask, plan | None, stats).
    ``pages`` still carries pre-migration tiers; callers apply the masks (or
    the plan) themselves so data movement can be scheduled separately.

    ``exclude`` (queue mode) removes in-flight pages from the candidate
    sets so a queued migration is never re-selected; holdings telemetry and
    the free-fast computation still count them — an in-flight page keeps
    serving from (and occupying) its source tier until the drain commits.
    With ``exclude=None`` the trace is the original instant-apply program.
    """
    P = pages.owner.shape[0]
    T = max_tenants
    C = count_clamp
    # Per-tenant reductions: owner-segment cumsums when the state carries
    # the sorted permutation (manager-built states), else a [T, P] one-hot.
    oh = None
    if segs is None:
        oh = pages.owner[None, :] == jnp.arange(T, dtype=jnp.int32)[:, None]  # [T,P]

    # ---- 1. per-tenant fast/slow sample counts (tier *before* migration) ----
    is_fast = pages.tier == TIER_FAST
    is_slow = pages.tier == TIER_SLOW
    # owner is stored i16 (packed layouts, types.py); every slot-arithmetic
    # consumer below (flat histogram keys, T + owner offsets) needs i32
    # range, so upcast ONCE here — one fused elementwise pass
    owner32 = pages.owner.astype(jnp.int32)
    if segs is not None:
        # one [2T+1] scatter-add replaces the two global segment cumsums
        # plus their sorted-order gathers (measurably faster under both
        # XLA:CPU runtimes); u32 adds are associative mod 2^32, so the
        # per-tenant totals are bit-identical to the cumsum path whatever
        # the accumulation order (owned pages are always fast or slow:
        # allocate/free set owner and tier together, so fast|slow covers
        # every owned page exactly once)
        T2 = max_tenants
        own_ok = pages.owner >= 0
        idx = jnp.where(
            own_ok & is_fast, owner32,
            jnp.where(own_ok, T2 + owner32, 2 * T2),
        )
        tbl = jnp.zeros((2 * T2 + 1,), jnp.uint32).at[idx].add(
            sampled.astype(jnp.uint32), mode="drop"
        )
        s_fast = tbl[:T2]
        s_slow = tbl[T2 : 2 * T2]
    else:
        s_fast = jnp.where(oh & is_fast[None, :], sampled[None, :], 0).sum(axis=1)
        s_slow = jnp.where(oh & is_slow[None, :], sampled[None, :], 0).sum(axis=1)
    pages, tenants, cooled, eff = bins.accumulate_and_count(
        pages, tenants, sampled, params.num_bins, owner_onehot=oh, segs=segs
    )

    # ---- 2. FMMR update ------------------------------------------------------
    now = fmmr.fmmr_now(s_fast.astype(jnp.float32), s_slow.astype(jnp.float32))
    ewma = fmmr.update_ewma(tenants.a_miss, now, params.ewma_lambda)
    ewma = jnp.where(tenants.active, ewma, 0.0)
    tenants = tenants._replace(a_miss=ewma)

    # ---- per-(tenant, tier, clamped count) candidate histograms --------------
    # ONE P-element scatter; everything below — holdings, candidate totals,
    # rebalance pair counts, victim cutoffs — reads off these two tables and
    # their prefix sums.
    is_owned = pages.owner >= 0
    owner = jnp.maximum(owner32, 0)
    slow_cand = is_owned & is_slow
    fast_cand = is_owned & is_fast
    if exclude is not None:
        slow_cand = slow_cand & ~exclude
        fast_cand = fast_cand & ~exclude
    key = jnp.minimum(eff.astype(jnp.int32), C - 1)
    flat = jnp.where(
        slow_cand,
        owner * C + key,
        jnp.where(fast_cand, T * C + owner * C + key, 2 * T * C),
    )
    hist2 = jnp.zeros((2 * T * C + 1,), jnp.int32).at[flat].add(1, mode="drop")
    hist_slow = hist2[: T * C].reshape(T, C)
    hist_fast = hist2[T * C : 2 * T * C].reshape(T, C)
    # tiled past 64k-element rows — at [256, 4096] the row scans alone cost
    # ~20 ms untiled (core/tiling.py; bit-identical integer addition)
    cum_slow = tiled_cumsum(hist_slow, axis=1)  # [T,C] candidates with count <= c
    cum_fast = tiled_cumsum(hist_fast, axis=1)
    n_slow_cand = cum_slow[:, -1]  # == per-tenant slow-page holdings
    n_fast_cand = cum_fast[:, -1]  # == per-tenant fast-page holdings
    if exclude is None:
        fast_hold, slow_hold = n_fast_cand, n_slow_cand
    else:
        # in-flight pages are excluded from the candidate histograms but
        # still occupy their source tier: holdings must count them
        fast_hold, slow_hold = _per_tenant_pages(
            pages, max_tenants, segs=segs, owner_onehot=oh
        )

    # ---- 3. proportional reallocation (budget R/2) ---------------------------
    # alloc_headroom fast pages are reserved for first-touch allocation
    # (DESIGN.md §8): the policy never promotes into them, so a new page's
    # allocation can land fast instead of waiting an epoch for promotion.
    # Allocations may transiently consume the reserve (holdings then exceed
    # the promotion ceiling) — clamp at zero rather than forcing net
    # demotions; request churn regenerates the headroom on free.
    free_fast = jnp.maximum(
        params.fast_capacity - params.alloc_headroom - fast_hold.sum(), 0
    )
    realloc_budget = params.migration_budget // 2
    # asymmetric hysteresis guards: negative band = inherit the symmetric
    # ``hysteresis`` value, which keeps the default program bit-identical
    band_need = jnp.where(
        params.promote_band >= 0, params.promote_band, params.hysteresis
    )
    band_donor = jnp.where(
        params.demote_band >= 0, params.demote_band, params.hysteresis
    )
    ra = fmmr.reallocate(
        tenants, fast_hold, free_fast, realloc_budget,
        fair_mode=params.fair_mode, hysteresis=params.hysteresis,
        need_band=band_need, donor_band=band_donor,
    )
    tenants = tenants._replace(flagged=ra.flagged)
    # the R/2 reallocation budget counts BOTH promotions and the demotions
    # that make room for them: rescale if gives+takes overshoot.
    ra_moves = ra.give.sum() + ra.take.sum()
    ra_scale = jnp.where(
        ra_moves > realloc_budget,
        realloc_budget.astype(jnp.float32) / jnp.maximum(ra_moves, 1),
        1.0,
    )
    take2 = jnp.floor(ra.take * ra_scale).astype(jnp.int32)
    give2 = jnp.floor(ra.give * ra_scale).astype(jnp.int32)
    # integer flooring can break gives <= free + takes: FCFS re-clamp
    give2 = fmmr.clamp_gives(give2, tenants.arrival, free_fast + take2.sum())
    ra = ra._replace(give=give2, take=take2)

    # ---- 4. intra-tenant rebalance (budget R/2; each pair = 2 moves) ---------
    n_active = jnp.maximum(tenants.active.sum(), 1)
    rebal_share = (params.migration_budget - realloc_budget) // (2 * n_active)

    # Reallocation consumes the first `give` hottest-slow / `take` coldest-fast
    # victims; the i-th REBALANCE pair is (hot[give+i], cold[take+i]). Pairs
    # must fit the remaining candidates on BOTH sides so promote/demote stay
    # 1:1 per tenant (capacity invariant) — _pair_count enforces this.
    give_eff = jnp.minimum(ra.give, n_slow_cand)
    take_eff = jnp.minimum(ra.take, n_fast_cand)
    n_rebal = _pair_count(cum_slow, cum_fast, give_eff, take_eff, rebal_share)
    n_rebal = jnp.where(tenants.active, n_rebal, 0)

    # ---- 5. quotas -> victim masks -> plan -----------------------------------
    promote_quota = give_eff + n_rebal  # <= n_slow_cand by construction
    demote_quota = take_eff + n_rebal  # <= n_fast_cand by construction

    promote_mask, demote_mask = _select_victims(
        key, owner, slow_cand, fast_cand, hist_slow, hist_fast,
        cum_slow, cum_fast, promote_quota, demote_quota, oh, segs,
    )

    plan = None
    if collect_plan:
        # id lists by rank lookup: the j-th selected page is the first index
        # whose running selection count reaches j+1 — cumsum + searchsorted
        # + masked identity, no P-element scatter (XLA:CPU scatters are
        # element-serial; binary-searching plan_size ranks is ~20x cheaper)
        j = jnp.arange(plan_size, dtype=jnp.int32)
        cum_p = tiled_cumsum(promote_mask.astype(jnp.int32))
        cum_d = tiled_cumsum(demote_mask.astype(jnp.int32))
        idx_p = jnp.searchsorted(cum_p, j + 1, side="left").astype(jnp.int32)
        idx_d = jnp.searchsorted(cum_d, j + 1, side="left").astype(jnp.int32)
        plan = MigrationPlan(
            promote=jnp.where(j < cum_p[-1], idx_p, -1),
            demote=jnp.where(j < cum_d[-1], idx_d, -1),
        )

    # ---- stats ---------------------------------------------------------------
    # selection takes exactly min(quota, candidates) pages per tenant, so the
    # per-tenant promoted/demoted telemetry needs no extra reduction.
    promoted = jnp.minimum(promote_quota, n_slow_cand)
    demoted = jnp.minimum(demote_quota, n_fast_cand)
    stats = EpochStats(
        fmmr_now=now,
        fmmr_ewma=ewma,
        fast_pages=fast_hold,
        slow_pages=slow_hold,
        promoted=promoted,
        demoted=demoted,
        cooled=cooled,
    )
    return pages, tenants, promote_mask, demote_mask, plan, stats


def _apply_masks(pages: PageState, promote_mask, demote_mask) -> PageState:
    """Metadata migration via the victim masks — one fused elementwise pass."""
    tier = jnp.where(
        promote_mask,
        jnp.int8(TIER_FAST),
        jnp.where(demote_mask, jnp.int8(TIER_SLOW), pages.tier),
    )
    return pages._replace(tier=tier)


@partial(jax.jit, static_argnames=("max_tenants", "plan_size", "count_clamp"))
def policy_epoch(
    pages: PageState,
    tenants: TenantState,
    sampled: jax.Array,  # u32[P] sampled accesses this epoch (PEBS analogue)
    params: PolicyParams,
    *,
    max_tenants: int,
    plan_size: int,
    count_clamp: int = COUNT_CLAMP,
):
    """Returns (pages', tenants', MigrationPlan, EpochStats). Tiers in
    ``pages'`` are pre-migration; use :func:`apply_plan` to commit the plan."""
    pages, tenants, _pm, _dm, plan, stats = _epoch_core(
        pages, tenants, sampled, params, max_tenants, plan_size, count_clamp,
        collect_plan=True,
    )
    return pages, tenants, plan, stats


def _apply_plan_core(pages: PageState, plan: MigrationPlan) -> PageState:
    P = pages.tier.shape[0]
    # -1 padding would wrap to P-1: remap to P so mode="drop" discards it
    promote = jnp.where(plan.promote >= 0, plan.promote, P)
    demote = jnp.where(plan.demote >= 0, plan.demote, P)
    tier = pages.tier
    tier = tier.at[promote].set(jnp.int8(TIER_FAST), mode="drop")
    tier = tier.at[demote].set(jnp.int8(TIER_SLOW), mode="drop")
    return pages._replace(tier=tier)


@jax.jit
def apply_plan(pages: PageState, plan: MigrationPlan) -> PageState:
    """Execute a migration plan on the metadata (data movement is the
    caller's job — pools + Pallas page_copy kernel, or DMA on real HW)."""
    return _apply_plan_core(pages, plan)


# --------------------------------------------------------------------------
# Bounded-bandwidth asynchronous migration data plane (DESIGN.md §4).
# --------------------------------------------------------------------------

def _compact(mask, out_len: int, arrays, pads):
    """Stable-compact entries where ``mask`` holds to the front of fresh
    arrays of length ``out_len`` (entries beyond it are dropped — callers
    count them as overflow). Rank lookup instead of scatter: ONE cumsum
    shared by every array, then the j-th kept entry is found by binary
    search and gathered — searchsorted + gathers are orders of magnitude
    cheaper than element-serial scatters on XLA:CPU."""
    cum = tiled_cumsum(mask.astype(jnp.int32))
    j = jnp.arange(out_len, dtype=jnp.int32)
    idx = jnp.searchsorted(cum, j + 1, side="left").astype(jnp.int32)
    idx = jnp.minimum(idx, mask.shape[0] - 1)
    keep = j < cum[-1]
    return [jnp.where(keep, a[idx], pad) for a, pad in zip(arrays, pads)]


def _real_depth(queue: MigrationQueue) -> jax.Array:
    """i32[] count of REAL in-flight migrations: occupied slots whose
    direction is +-1. Cooldown tombstones (direction DIR_NONE) hold their
    page in the exclusion mask but carry no pending migration, so every
    depth consumer of the conservation identity must skip them.
    ``MigrationQueue.depth`` remains the physical slot-occupancy count."""
    return ((queue.page >= 0) & (queue.direction != DIR_NONE)).sum()


def _inflight_mask(state: PolicyState) -> Optional[jax.Array]:
    """bool[P] pages with a queued migration (None when the queue is off)."""
    queue = state.queue
    if queue is None or queue.size == 0:
        return None
    P = state.pending.shape[0]
    idx = jnp.where(queue.page >= 0, queue.page, P)
    return jnp.zeros((P,), bool).at[idx].set(True, mode="drop")


def _queue_tick(
    queue: MigrationQueue,
    plan: MigrationPlan,
    pages: PageState,
    tenants: TenantState,
    params: PolicyParams,
    epoch: jax.Array,  # i32[] current epoch (the queue clock)
):
    """Enqueue this epoch's selections, then drain the FIFO under the
    bandwidth/latency budget and commit the drained tier flips.

    Semantics (all inside the fused tick, fixed shapes throughout):
      * commit-on-completion — tier metadata changes only when an entry
        drains, so in-flight pages keep serving from their source tier;
      * thrashing guard — queued demotions whose page re-heated (hotness
        bin rose above its enqueue-time bin) are cancelled, as are entries
        whose page was freed;
      * drain order — demotions first (they free the fast slots promotions
        need: fast occupancy can never exceed capacity mid-flight), FIFO
        within each direction, promotions additionally capped by free fast
        room; at most ``migration_bandwidth`` total commits per epoch;
      * overflow — entries that neither drain nor fit the fixed queue are
        dropped newest-first (the policy re-selects them next epoch since
        the tiers did not change);
      * storm guards (DESIGN.md §11, all default-off) —
        ``params.promote_admission`` caps new enqueues per direction per
        tick and tightens under cancel pressure; ``params.demote_cooldown``
        turns reheat-cancelled demotions into exclusion tombstones so
        their pages cannot ping-pong straight back into the queue.

    With ``bandwidth=BANDWIDTH_UNLIMITED`` and ``latency=0`` every entry
    drains in its enqueue epoch: placements are identical to instant apply
    and the queue is empty at every epoch boundary.
    """
    Q = queue.size
    S = plan.promote.shape[0]
    W = Q + 2 * S  # workspace: worst-case live entries this epoch
    P = pages.tier.shape[0]

    heat_bin = bins.bin_of(bins.effective_count(pages, tenants), params.num_bins)

    # ---- thrashing / ownership guard on the in-flight entries --------------
    # Slots split into REAL migrations (direction +-1) and TOMBSTONES
    # (direction DIR_NONE): under ``demote_cooldown`` a reheat-cancelled
    # demotion parks its page in the queue instead of vacating, so the
    # in-flight exclusion keeps barring it from re-selection for
    # ``cooldown`` epochs — the select -> cancel -> re-select ping-pong the
    # thrash guard otherwise burns enqueue bandwidth on. Tombstones never
    # drain, never count toward depth/conservation, and expire when the
    # epoch reaches the expiry stored in ``complete_epoch``. With
    # cooldown == 0 no tombstone is ever created and the tick is
    # bit-identical to the pre-guard engine.
    occupied = queue.page >= 0
    real = occupied & (queue.direction != DIR_NONE)
    tomb = occupied & (queue.direction == DIR_NONE)
    qp = jnp.maximum(queue.page, 0)
    owned = pages.owner[qp] >= 0
    reheat = real & (queue.direction == DIR_DEMOTE) & (heat_bin[qp] > queue.heat)
    cancel = real & (~owned | reheat)
    cooldown = jnp.maximum(params.demote_cooldown, 0)
    entomb = cancel & reheat & owned & (cooldown > 0)
    tomb_live = tomb & owned & (epoch < queue.complete_epoch)
    keep = (real & ~cancel) | entomb | tomb_live
    n_cancel = cancel.sum()

    # ---- enqueue: kept entries first (FIFO), then new demotes, promotes ----
    lat = jnp.maximum(params.migration_latency, 0)

    # Same-tick dedupe (queue-conservation fix): a page already carried by
    # a kept entry — live or tombstone — must never gain a second entry in
    # the same tick. Manager paths pre-exclude in-flight pages from
    # selection, but a free -> allocate -> re-select sequence inside one
    # epoch (or a direct policy caller without the exclusion mask) could
    # otherwise enqueue the page twice, double-counting it in
    # ``enqueued == drained + cancelled + dropped + depth``.
    in_q = (
        jnp.zeros((P,), bool)
        .at[jnp.where(keep, queue.page, P)]
        .set(True, mode="drop")
    )

    def _dedupe(ids):
        return jnp.where(in_q[jnp.maximum(ids, 0)], -1, ids)

    d_ids = _dedupe(plan.demote)
    p_ids = _dedupe(plan.promote)

    # ---- queue admission control (params.promote_admission) ----------------
    # Cap NEW enqueues per direction at ``clamp`` per tick, tightening to
    # clamp/2 (clamp/4) when this tick's cancels reach half (all) of the
    # pre-tick depth — a storm that cancels faster than it drains gets its
    # inflow throttled instead of livelocking the queue. The cap is
    # per-direction because a drop-requeue cycle feeds on either side: an
    # oversubscribed selector floods the queue with promotions after a
    # phase flip and with rebalance demotions under steady contention; both
    # overflow the same FIFO and burn the same enqueue work. A rejected
    # selection never enqueues and is NOT counted: the tiers did not
    # change, so the policy simply re-selects it next epoch.
    clamp = params.promote_admission
    depth_pre = real.sum()
    sev = jnp.clip((2 * n_cancel) // jnp.maximum(depth_pre, 1), 0, 2)
    eff = jnp.where(
        clamp < 0,
        jnp.int32(jnp.iinfo(jnp.int32).max),
        jnp.maximum(jnp.maximum(clamp, 0) >> sev, 1),
    )
    pv = p_ids >= 0
    p_ids = jnp.where(pv & (tiled_cumsum(pv.astype(jnp.int32)) <= eff), p_ids, -1)
    dv = d_ids >= 0
    d_ids = jnp.where(dv & (tiled_cumsum(dv.astype(jnp.int32)) <= eff), d_ids, -1)

    def _new(ids, direction):
        v = ids >= 0
        pid = jnp.maximum(ids, 0)
        return (
            ids,
            jnp.where(v, jnp.int8(direction), jnp.int8(0)),
            jnp.full((S,), epoch, jnp.int32),
            jnp.full((S,), epoch + lat, jnp.int32),
            # bins are < 2^7 by construction (types.py): store i8 to match
            # the packed queue leaf
            jnp.where(v, heat_bin[pid], 0).astype(jnp.int8),
        )

    nd, npr = _new(d_ids, DIR_DEMOTE), _new(p_ids, DIR_PROMOTE)
    # entombed slots flip to DIR_NONE and carry their expiry epoch in
    # ``complete_epoch``; ordinary kept entries pass through unchanged
    k_dir = jnp.where(entomb, jnp.int8(DIR_NONE), queue.direction)
    k_cmp = jnp.where(entomb, epoch + cooldown, queue.complete_epoch)
    w_page = jnp.concatenate([jnp.where(keep, queue.page, -1), nd[0], npr[0]])
    w_dir = jnp.concatenate([k_dir, nd[1], npr[1]])
    w_enq = jnp.concatenate([queue.enqueue_epoch, nd[2], npr[2]])
    w_cmp = jnp.concatenate([k_cmp, nd[3], npr[3]])
    w_heat = jnp.concatenate([queue.heat, nd[4], npr[4]])
    n_new = (p_ids >= 0).sum() + (d_ids >= 0).sum()

    # The workspace is already in FIFO order: the surviving queue prefix is
    # front-compacted from the previous tick and new entries append after
    # it. Cancellation holes and plan padding carry page == -1 and drop out
    # of every mask below, so the drain can run DIRECTLY on the workspace —
    # the old front-compaction pass (one cumsum + five scatters) was pure
    # overhead and is gone; only the survivors are re-compacted at the end.
    c_page, c_dir, c_enq, c_cmp, c_heat = w_page, w_dir, w_enq, w_cmp, w_heat

    # ---- bounded drain: demotes first, FIFO within each direction ----------
    cv = c_page >= 0
    elig = cv & (epoch >= c_cmp)
    bw = jnp.where(
        params.migration_bandwidth < 0,
        jnp.int32(jnp.iinfo(jnp.int32).max),
        params.migration_bandwidth,
    ).astype(jnp.int32)
    is_d = elig & (c_dir == DIR_DEMOTE)
    is_p = elig & (c_dir == DIR_PROMOTE)
    drain_d = is_d & (tiled_cumsum(is_d.astype(jnp.int32)) <= bw)
    n_d = drain_d.sum()
    fast_occ = (pages.tier == TIER_FAST).sum()
    # drained promotions respect the allocation reserve too: a promotion
    # selected before an allocation burst must not retake the headroom the
    # burst just consumed (it stays queued until room reappears)
    room = params.fast_capacity - params.alloc_headroom - (fast_occ - n_d)
    drain_p = is_p & (tiled_cumsum(is_p.astype(jnp.int32)) <= jnp.minimum(bw - n_d, room))
    n_p = drain_p.sum()

    # commit-on-completion: tier flips only for the drained entries
    tier = pages.tier
    tier = tier.at[jnp.where(drain_d, c_page, P)].set(jnp.int8(TIER_SLOW), mode="drop")
    tier = tier.at[jnp.where(drain_p, c_page, P)].set(jnp.int8(TIER_FAST), mode="drop")
    pages = pages._replace(tier=tier)

    (drained_d_ids,) = _compact(drain_d, W, (c_page,), (-1,))
    (drained_p_ids,) = _compact(drain_p, W, (c_page,), (-1,))

    # ---- survivors back into the fixed queue; overflow drops the newest ----
    left = cv & ~drain_d & ~drain_p
    n_drop = jnp.maximum(left.sum() - Q, 0)
    q_page, q_dir, q_enq, q_cmp, q_heat = _compact(
        left, Q, (c_page, c_dir, c_enq, c_cmp, c_heat), (-1, 0, 0, 0, 0)
    )
    new_queue = MigrationQueue(
        page=q_page, direction=q_dir, enqueue_epoch=q_enq,
        complete_epoch=q_cmp, heat=q_heat,
    )
    # depth counts REAL migrations only: tombstones occupy slots but carry
    # no pending work, so the conservation identity stays exact under
    # cooldown (the cancel was already counted when the tombstone formed).
    # Overflow drops can only hit new entries — the kept prefix fits the
    # fixed queue by construction — so ``dropped`` is real-only too.
    qstats = QueueStats(
        depth=((q_page >= 0) & (q_dir != DIR_NONE)).sum(),
        enqueued=n_new,
        drained_promote=n_p,
        drained_demote=n_d,
        cancelled=n_cancel,
        dropped=n_drop,
        drained_promote_ids=drained_p_ids,
        drained_demote_ids=drained_d_ids,
    )
    return pages, new_queue, qstats


def _commit(state, pages, tenants, pm, dm, plan, stats, params):
    """Apply this epoch's migrations: instantly (zero-capacity queue — the
    original engine, bit-identical) or through the bounded queue tick.
    Returns (pages', queue', epoch', stats'). The branch is on a static
    array shape, so each mode traces to its own program."""
    queue = state.queue
    if queue is None or queue.size == 0:
        pages = _apply_masks(pages, pm, dm)
        epoch = None if state.epoch is None else state.epoch + 1
        return pages, queue, epoch, stats
    pages, queue, qstats = _queue_tick(queue, plan, pages, tenants, params, state.epoch)
    return pages, queue, state.epoch + 1, stats._replace(queue=qstats)


def _sentinel_bits(
    pages: PageState,
    tenants: TenantState,
    params: PolicyParams,
    max_tenants: int,
    qstats: Optional[QueueStats],
    depth_before: Optional[jax.Array],
) -> jax.Array:
    """Invariant-sentinel bitmask (core/faults.py SENTINEL_*), computed on the
    POST-commit state inside the fused tick. A handful of O(P) reductions —
    cheap next to the tick itself — gated by the traced ``params.sentinel``
    flag so flipping the sentinel never retraces. The host-side
    :func:`repro.core.faults.deep_validate` is the exhaustive counterpart.

    The reductions sit under ``lax.cond`` so a flag-OFF program SKIPS them
    at runtime, not just masks their result — that is what keeps the
    perf-gate's sentinel-off overhead band tight. (Inside the vmapped
    fleet tick the cond lowers to a select and both branches execute; the
    gated band is the single-machine tick, and the fleet's per-machine
    epoch cost dwarfs the reductions.)"""
    i32 = jnp.int32

    def compute(_):
        fast_occ = (pages.tier == TIER_FAST).sum()
        bits = jnp.where(
            fast_occ > params.fast_capacity, i32(SENTINEL_OCCUPANCY), i32(0)
        )
        owned = pages.owner >= 0
        placed = pages.tier != TIER_NONE
        bits = bits | jnp.where(
            jnp.any(owned != placed), i32(SENTINEL_OWNERSHIP), i32(0)
        )
        own = jnp.clip(pages.owner, 0, max_tenants - 1)
        orphan = owned & ~tenants.active[own]
        bits = bits | jnp.where(jnp.any(orphan), i32(SENTINEL_ORPHAN), i32(0))
        bad = jnp.any(~jnp.isfinite(tenants.a_miss))
        bits = bits | jnp.where(bad, i32(SENTINEL_NAN), i32(0))
        if qstats is not None and depth_before is not None:
            flow = (
                qstats.enqueued
                - qstats.drained_promote
                - qstats.drained_demote
                - qstats.cancelled
                - qstats.dropped
            )
            bits = bits | jnp.where(
                qstats.depth != depth_before + flow, i32(SENTINEL_QUEUE), i32(0)
            )
        return bits

    return jax.lax.cond(params.sentinel > 0, compute, lambda _: i32(0), None)


def _epoch_step_impl(
    state: PolicyState,
    params: PolicyParams,
    *,
    max_tenants: int,
    plan_size: int,
    exact_sampling: bool,
    count_clamp: int,
    compile_sentinel: bool = True,
):
    rng, sub = jax.random.split(state.rng)
    sampled = sample_accesses(sub, state.pending, params.sample_period, exact=exact_sampling)
    depth_before = None
    if state.queue is not None and state.queue.size > 0:
        depth_before = _real_depth(state.queue)
    pages, tenants, pm, dm, plan, stats = _epoch_core(
        state.pages, state.tenants, sampled, params, max_tenants, plan_size,
        count_clamp, collect_plan=True, exclude=_inflight_mask(state),
        segs=state.segs,
    )
    pages, queue, epoch, stats = _commit(state, pages, tenants, pm, dm, plan, stats, params)
    if compile_sentinel:
        stats = stats._replace(sentinel=_sentinel_bits(
            pages, tenants, params, max_tenants, stats.queue, depth_before
        ))
    new_state = state._replace(
        pages=pages, tenants=tenants,
        pending=jnp.zeros_like(state.pending), rng=rng,
        queue=queue, epoch=epoch,
    )
    return new_state, plan, stats


@lru_cache(maxsize=None)
def _jitted_epoch_step(donate: bool):
    return jax.jit(
        _epoch_step_impl,
        static_argnames=(
            "max_tenants", "plan_size", "exact_sampling", "count_clamp",
            "compile_sentinel",
        ),
        donate_argnums=(0,) if donate else (),
    )


def epoch_step(
    state: PolicyState,
    params: PolicyParams,
    *,
    max_tenants: int,
    plan_size: int,
    exact_sampling: bool = False,
    count_clamp: int = COUNT_CLAMP,
    compile_sentinel: bool = True,
):
    """Fused policy tick: sample -> policy -> migrate, one dispatch.

    Consumes ``state.pending`` (the access backlog) and the PRNG key carried
    in the state; returns (state', plan, stats) with ``pending`` zeroed and
    the migration already applied to the metadata. The state buffers are
    donated on accelerator backends — do not reuse the argument there.
    ``compile_sentinel=False`` omits the invariant-sentinel reductions from
    the program entirely (the reference point for the perf-gate overhead
    band); the default compiles them in, gated by the traced
    ``params.sentinel`` flag.
    """
    return _jitted_epoch_step(_donate_state())(
        state, params, max_tenants=max_tenants, plan_size=plan_size,
        exact_sampling=exact_sampling, count_clamp=count_clamp,
        compile_sentinel=compile_sentinel,
    )


def _trim_stats(stats: EpochStats) -> EpochStats:
    """Drop the telemetry leaves the sweep record path never reads
    (DESIGN.md §6): ``cooled``/``slow_pages``, and — the big one in queue
    mode — the fixed-size drained id lists, whose [W]-wide rows dominate
    the stacked snapshot transfer. ``None`` leaves are empty pytree
    subtrees, so stacking, slicing and host copies all skip them. Safe
    because trimming only runs on paths without a pool-backed data plane
    (the only consumer of the drained id lists)."""
    if stats.queue is not None:
        stats = stats._replace(
            queue=stats.queue._replace(
                drained_promote_ids=None, drained_demote_ids=None
            )
        )
    return stats._replace(cooled=None, slow_pages=None)


def _multi_epoch_impl(
    state: PolicyState,
    params: PolicyParams,
    counts: Optional[jax.Array],
    *,
    k: int,
    max_tenants: int,
    plan_size: int,
    exact_sampling: bool,
    count_clamp: int,
    collect_plans: bool,
    trim_stats: bool = False,
    compile_sentinel: bool = True,
):
    P = state.pending.shape[0]
    per_epoch = None
    xs_counts = None
    if counts is not None:
        counts = jnp.asarray(counts, jnp.uint32)
        if counts.ndim == 1:
            per_epoch = counts
        else:
            xs_counts = counts  # [k, P]

    # Pre-draw all sampling noise in one batched call (the per-epoch PRNG
    # split chain still advances identically to k epoch_step calls, so the
    # exact-sampling path is bit-identical to single-stepping). The scan's
    # noise stream was never bit-compatible with single-stepped sampling,
    # so it uses exactly-standardized CLT deviates instead of true
    # normals: popcount of 16 random bits is Binomial(16, 1/2), giving
    # (pc - 8)/2 mean 0 and variance 1 EXACTLY. FMMR consumes per-tenant
    # aggregates of thousands of pages where the CLT washes out the
    # half-sigma granularity — and this costs half the threefry bits and
    # none of the erfinv of a normal draw, which together were the single
    # largest line in the fleet-scan profile (DESIGN.md §5).
    xs_z = None
    if not exact_sampling:
        half = (P + 1) // 2
        bits = jax.random.bits(
            jax.random.fold_in(state.rng, 0x5A), (k, half), jnp.uint32
        )
        pc = jax.lax.population_count
        z2 = jnp.stack([pc(bits & 0xFFFF), pc(bits >> 16)], axis=-1)
        xs_z = (z2.reshape(k, 2 * half)[:, :P].astype(jnp.float32) - 8.0) * 0.5

    # the queue tick consumes the plan id lists, so queue mode always
    # collects them internally even when the caller does not want them out
    queue_mode = state.queue is not None and state.queue.size > 0

    def step(st: PolicyState, x):
        x_counts, z = x
        pending = st.pending
        if per_epoch is not None:
            pending = pending + per_epoch
        if x_counts is not None:
            pending = pending + x_counts
        rng, sub = jax.random.split(st.rng)
        sampled = sample_accesses(
            sub, pending, params.sample_period, exact=exact_sampling, z=z
        )
        depth_before = _real_depth(st.queue) if queue_mode else None
        pages, tenants, pm, dm, plan, stats = _epoch_core(
            st.pages, st.tenants, sampled, params, max_tenants, plan_size,
            count_clamp, collect_plan=collect_plans or queue_mode,
            exclude=_inflight_mask(st), segs=st.segs,
        )
        pages, queue, epoch, stats = _commit(st, pages, tenants, pm, dm, plan, stats, params)
        if compile_sentinel:
            stats = stats._replace(sentinel=_sentinel_bits(
                pages, tenants, params, max_tenants, stats.queue, depth_before
            ))
        st2 = st._replace(
            pages=pages, tenants=tenants,
            pending=jnp.zeros_like(pending), rng=rng,
            queue=queue, epoch=epoch,
        )
        if trim_stats:
            stats = _trim_stats(stats)
        return st2, (plan if collect_plans else None, stats, tenants.flagged)

    state, (plans, stats, flagged) = jax.lax.scan(step, state, (xs_counts, xs_z), length=k)
    return state, plans, stats, flagged


@lru_cache(maxsize=None)
def _jitted_multi_epoch(donate: bool):
    return jax.jit(
        _multi_epoch_impl,
        static_argnames=(
            "k", "max_tenants", "plan_size", "exact_sampling", "count_clamp",
            "collect_plans", "trim_stats", "compile_sentinel",
        ),
        donate_argnums=(0,) if donate else (),
    )


def multi_epoch(
    state: PolicyState,
    params: PolicyParams,
    counts: Optional[jax.Array] = None,
    *,
    k: int,
    max_tenants: int,
    plan_size: int,
    exact_sampling: bool = False,
    count_clamp: int = COUNT_CLAMP,
    collect_plans: bool = True,
    trim_stats: bool = False,
    compile_sentinel: bool = True,
):
    """Scan the fused epoch across ``k`` epochs in ONE dispatch.

    ``counts`` feeds the access stream: ``None`` consumes the backlog already
    in ``state.pending`` (epoch 1) and runs the rest idle; ``[P]`` replays the
    same exact counts every epoch (steady-state workload); ``[k, P]`` gives
    each epoch its own counts. Returns (state', plans, stats, flagged) with
    every per-epoch output stacked on a leading k axis; ``plans`` is None
    when ``collect_plans=False`` (metadata-only simulation — the per-tenant
    promoted/demoted telemetry in ``stats`` is still exact). The state
    buffers are donated on accelerator backends — do not reuse the argument
    there. ``trim_stats=True`` drops the telemetry leaves the sweep record
    path never reads (see :func:`_trim_stats`).
    """
    return _jitted_multi_epoch(_donate_state())(
        state, params, counts, k=k, max_tenants=max_tenants, plan_size=plan_size,
        exact_sampling=exact_sampling, count_clamp=count_clamp,
        collect_plans=collect_plans, trim_stats=trim_stats,
        compile_sentinel=compile_sentinel,
    )
