"""Hotness bins with lazy cooling (paper §3.2), dense-array TPU adaptation.

The paper keeps per-bin linked lists; pointer chasing is hostile to TPU, so
bins are *derived* from a dense per-page counter array:

    bin(count) = 0                 if count == 0
               = min(floor(log2(count)) + 1, num_bins - 1)

i.e. bin k>=1 holds counts in [2^(k-1), 2^k) — exponential heat classes, one
bin ~2x hotter than its colder neighbor, exactly the paper's semantics.

Cooling: when any page of a tenant would exceed the hottest bin's threshold
(2^(num_bins-1) with 6 bins), all of that tenant's pages halve — implemented
*lazily* via a per-tenant ``cool_epoch`` counter and per-page ``last_cool``
stamp; a page's effective count is ``count >> (cool_epoch - last_cool)``,
applied on its next touch. Cooling fires at most once per epoch (paper).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.types import PageState, TenantState


def bin_of(count: jax.Array, num_bins) -> jax.Array:
    """Vectorized heat-bin id for (effective) counts."""
    c = count.astype(jnp.uint32)
    # floor(log2(c)) via bit width; c==0 -> bin 0
    fl = jnp.where(c > 0, 31 - jax.lax.clz(jnp.maximum(c, 1).astype(jnp.int32)), -1)
    return jnp.clip(fl + 1, 0, num_bins - 1).astype(jnp.int32)


def cool_threshold(num_bins) -> jax.Array:
    """Counts >= 2^(num_bins-1) trigger a tenant-wide cooling event."""
    return (jnp.uint32(1) << jnp.uint32(num_bins - 1)).astype(jnp.uint32)


def effective_count(pages: PageState, tenants: TenantState) -> jax.Array:
    """Apply pending (lazy) cooling: count >> cooling events since last touch."""
    owner = jnp.maximum(pages.owner, 0)
    pending = jnp.maximum(tenants.cool_epoch[owner] - pages.last_cool, 0)
    pending = jnp.minimum(pending, 31).astype(jnp.uint32)
    eff = pages.count >> pending
    return jnp.where(pages.owner >= 0, eff, jnp.uint32(0))


def accumulate_samples(
    pages: PageState,
    tenants: TenantState,
    sampled: jax.Array,  # u32[P] sampled accesses this epoch
    num_bins,
) -> Tuple[PageState, TenantState, jax.Array]:
    """Fold one epoch of samples into the counters; fire cooling if needed.

    Returns (pages, tenants, cooled[T] bool). Lazy-cooling bookkeeping: pages
    touched this epoch materialize their pending shifts; untouched pages keep
    their stale counts + stamps (materialized on their next touch or read via
    ``effective_count``).
    """
    eff = effective_count(pages, tenants)
    new_count = eff + sampled.astype(jnp.uint32)
    touched = sampled > 0
    owner = jnp.maximum(pages.owner, 0)

    count1 = jnp.where(touched, new_count, pages.count)
    last1 = jnp.where(touched, tenants.cool_epoch[owner], pages.last_cool)

    # cooling: any page of tenant t reaching the top-bin threshold halves all
    thresh = cool_threshold(num_bins)
    over = touched & (new_count >= thresh) & (pages.owner >= 0)
    cooled = (
        jnp.zeros_like(tenants.cool_epoch, dtype=bool)
        .at[owner]
        .max(over, mode="drop")
    )
    cool_epoch2 = tenants.cool_epoch + cooled.astype(jnp.int32)

    # materialize the new cooling event for touched pages immediately
    do_halve = cooled[owner] & touched
    count2 = jnp.where(do_halve, count1 >> 1, count1)
    last2 = jnp.where(touched, cool_epoch2[owner], last1)

    pages2 = pages._replace(count=count2, last_cool=last2)
    tenants2 = tenants._replace(cool_epoch=cool_epoch2)
    return pages2, tenants2, cooled


def heat_histogram(
    pages: PageState, tenants: TenantState, num_bins: int, max_tenants: int
) -> jax.Array:
    """[T, num_bins] page counts per (tenant, bin) — the heat gradient."""
    eff = effective_count(pages, tenants)
    b = bin_of(eff, num_bins)
    owner = pages.owner
    flat = jnp.where(owner >= 0, owner * num_bins + b, max_tenants * num_bins)
    hist = jnp.zeros((max_tenants * num_bins + 1,), jnp.int32).at[flat].add(1)
    return hist[:-1].reshape(max_tenants, num_bins)
