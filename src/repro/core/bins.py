"""Hotness bins with lazy cooling (paper §3.2), dense-array TPU adaptation.

The paper keeps per-bin linked lists; pointer chasing is hostile to TPU, so
bins are *derived* from a dense per-page counter array:

    bin(count) = 0                 if count == 0
               = min(floor(log2(count)) + 1, num_bins - 1)

i.e. bin k>=1 holds counts in [2^(k-1), 2^k) — exponential heat classes, one
bin ~2x hotter than its colder neighbor, exactly the paper's semantics.

Cooling: when any page of a tenant would exceed the hottest bin's threshold
(2^(num_bins-1) with 6 bins), all of that tenant's pages halve — implemented
*lazily* via a per-tenant ``cool_epoch`` counter and per-page ``last_cool``
stamp; a page's effective count is ``count >> (cool_epoch - last_cool)``,
applied on its next touch. Cooling fires at most once per epoch (paper).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.tiling import tiled_cumsum
from repro.core.types import OwnerSegments, PageState, TenantState


def seg_sums(values_sorted: jax.Array, start: jax.Array) -> jax.Array:
    """Per-tenant segment sums of an owner-sorted value array.

    ``values_sorted`` is any [P] array already gathered into owner-sorted
    order (``x[segs.order]``); ``start`` is ``OwnerSegments.start``. ONE
    global cumsum (tiled past 64k elements, core/tiling.py) plus two [T+1]
    gathers replaces a [T, P] one-hot reduction or a P-element scatter-add
    — bit-identical for integer dtypes (same addends, associative exact
    arithmetic).
    """
    cum = tiled_cumsum(values_sorted)
    cum0 = jnp.concatenate([jnp.zeros((1,), cum.dtype), cum])
    return cum0[start[1:]] - cum0[start[:-1]]


def bin_of(count: jax.Array, num_bins) -> jax.Array:
    """Vectorized heat-bin id for (effective) counts."""
    c = count.astype(jnp.uint32)
    # floor(log2(c)) via bit width; c==0 -> bin 0
    fl = jnp.where(c > 0, 31 - jax.lax.clz(jnp.maximum(c, 1).astype(jnp.int32)), -1)
    return jnp.clip(fl + 1, 0, num_bins - 1).astype(jnp.int32)


def cool_threshold(num_bins) -> jax.Array:
    """Counts >= 2^(num_bins-1) trigger a tenant-wide cooling event."""
    return (jnp.uint32(1) << jnp.uint32(num_bins - 1)).astype(jnp.uint32)


def effective_count(pages: PageState, tenants: TenantState) -> jax.Array:
    """Apply pending (lazy) cooling: count >> cooling events since last touch."""
    owner = jnp.maximum(pages.owner, 0)
    pending = jnp.maximum(tenants.cool_epoch[owner] - pages.last_cool, 0)
    pending = jnp.minimum(pending, 31).astype(jnp.uint32)
    eff = pages.count >> pending
    return jnp.where(pages.owner >= 0, eff, jnp.uint32(0))


def accumulate_and_count(
    pages: PageState,
    tenants: TenantState,
    sampled: jax.Array,  # u32[P] sampled accesses this epoch
    num_bins,
    owner_onehot: jax.Array = None,  # bool[T, P] (owner == t), built if None
    segs: OwnerSegments = None,  # owner segments: cooled via seg_sums instead
) -> Tuple[PageState, TenantState, jax.Array, jax.Array]:
    """Fold one epoch of samples into the counters; fire cooling if needed.

    Returns (pages, tenants, cooled[T] bool, eff u32[P]) where ``eff`` is the
    post-accumulation effective count (what ``effective_count`` would return
    on the new state) — computed here for free so the policy hot path does not
    need a second cooling-materialization pass. Lazy-cooling bookkeeping:
    pages touched this epoch materialize their pending shifts; untouched
    pages keep their stale counts + stamps (materialized on their next touch
    or read via ``effective_count``).
    """
    T = tenants.cool_epoch.shape[0]
    eff = effective_count(pages, tenants)
    new_count = eff + sampled.astype(jnp.uint32)
    touched = sampled > 0
    owner = jnp.maximum(pages.owner, 0)

    count1 = jnp.where(touched, new_count, pages.count)
    last1 = jnp.where(touched, tenants.cool_epoch[owner], pages.last_cool)

    # cooling: any page of tenant t reaching the top-bin threshold halves all.
    thresh = cool_threshold(num_bins)
    over = touched & (new_count >= thresh) & (pages.owner >= 0)
    if segs is not None:
        # one [T+1] scatter-add of the over flags (cheaper than the global
        # cumsum + sorted gather under both XLA:CPU runtimes; exact integer
        # counts, so the any-reduction is bit-identical)
        idx = jnp.where(over, owner, T)
        cooled = jnp.zeros((T + 1,), jnp.int32).at[idx].add(1, mode="drop")[:T] > 0
    else:
        if owner_onehot is None:
            owner_onehot = pages.owner[None, :] == jnp.arange(T, dtype=jnp.int32)[:, None]
        cooled = (owner_onehot & over[None, :]).any(axis=1)
    cool_epoch2 = tenants.cool_epoch + cooled.astype(jnp.int32)

    # materialize the new cooling event for touched pages immediately
    do_halve = cooled[owner] & touched
    count2 = jnp.where(do_halve, count1 >> 1, count1)
    last2 = jnp.where(touched, cool_epoch2[owner], last1)

    pages2 = pages._replace(count=count2, last_cool=last2)
    tenants2 = tenants._replace(cool_epoch=cool_epoch2)
    # effective count on the NEW state: touched pages are fully materialized;
    # untouched pages halve once more if their tenant cooled this epoch.
    eff_new = jnp.where(do_halve, count1 >> 1, jnp.where(touched, count1, eff))
    eff_new = jnp.where(~touched & cooled[owner], eff_new >> 1, eff_new)
    eff_new = jnp.where(pages.owner >= 0, eff_new, jnp.uint32(0))
    return pages2, tenants2, cooled, eff_new


def accumulate_samples(
    pages: PageState,
    tenants: TenantState,
    sampled: jax.Array,  # u32[P] sampled accesses this epoch
    num_bins,
) -> Tuple[PageState, TenantState, jax.Array]:
    """Compatibility wrapper around :func:`accumulate_and_count`; returns
    (pages, tenants, cooled[T] bool)."""
    pages2, tenants2, cooled, _ = accumulate_and_count(pages, tenants, sampled, num_bins)
    return pages2, tenants2, cooled


def count_histogram(
    values: jax.Array,  # i32/u32[P] per-page bucket keys (clamped to num_buckets-1)
    owner: jax.Array,  # i32[P] tenant slot; entries with mask=False ignored
    mask: jax.Array,  # bool[P] which pages participate
    num_buckets: int,
    max_tenants: int,
) -> jax.Array:
    """[T, num_buckets] page counts per (tenant, bucket).

    The generic form of the paper's per-bin lists: one scatter-add builds the
    whole (tenant, bucket) occupancy table in O(P); cumulative sums over the
    bucket axis then give exact victim *ranks* without any sort (DESIGN.md §2).
    """
    key = jnp.minimum(values.astype(jnp.int32), num_buckets - 1)
    # owner may be the packed i16 leaf: the flat key needs i32 range
    flat = jnp.where(
        mask, owner.astype(jnp.int32) * num_buckets + key,
        max_tenants * num_buckets,
    )
    hist = jnp.zeros((max_tenants * num_buckets + 1,), jnp.int32).at[flat].add(
        1, mode="drop"
    )
    return hist[:-1].reshape(max_tenants, num_buckets)


def heat_histogram(
    pages: PageState, tenants: TenantState, num_bins: int, max_tenants: int
) -> jax.Array:
    """[T, num_bins] page counts per (tenant, bin) — the heat gradient."""
    eff = effective_count(pages, tenants)
    b = bin_of(eff, num_bins)
    return count_histogram(b, pages.owner, pages.owner >= 0, num_bins, max_tenants)
