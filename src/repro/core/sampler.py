"""PEBS-analogue access-stream sampling (paper §3.2).

The paper samples 1-in-100 loads via PEBS counters. Here the serving engine
reports *exact* per-page access counts (it owns the attention page selector),
and we binomially subsample them with p = 1/sample_period — statistically the
same observable the paper's PEBS stream provides, without PMU noise.

``exact=True`` bypasses sampling (useful for deterministic tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_accesses(
    rng: jax.Array,
    counts: jax.Array,  # u32[P] exact accesses this epoch
    sample_period: int,
    *,
    exact: bool = False,
) -> jax.Array:
    """Returns u32[P] sampled access counts."""
    if exact or sample_period <= 1:
        return counts.astype(jnp.uint32)
    p = 1.0 / float(sample_period)
    n = counts.astype(jnp.float32)
    # Binomial(n, p) ~ Normal(np, np(1-p)) for large n; exact Bernoulli sum is
    # wasteful under jit. Poisson(np) is the standard PEBS model; clamp at n.
    lam = n * p
    draw = jax.random.poisson(rng, lam, dtype=jnp.int32).astype(jnp.float32)
    return jnp.minimum(draw, n).astype(jnp.uint32)
