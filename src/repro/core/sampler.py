"""PEBS-analogue access-stream sampling (paper §3.2).

The paper samples 1-in-100 loads via PEBS counters. Here the serving engine
reports *exact* per-page access counts (it owns the attention page selector),
and we binomially subsample them with p = 1/sample_period — statistically the
same observable the paper's PEBS stream provides, without PMU noise.

``exact=True`` bypasses sampling (useful for deterministic tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_accesses(
    rng: jax.Array,
    counts: jax.Array,  # u32[P] exact accesses this epoch
    sample_period,  # int or traced i32 scalar (PolicyParams.sample_period)
    *,
    exact: bool = False,
    z: jax.Array = None,  # optional pre-drawn f32[P] standard normals
) -> jax.Array:
    """Returns u32[P] sampled access counts.

    ``sample_period`` may be a traced scalar so the whole epoch (including
    sampling) can live inside one jitted/scanned program; only ``exact`` must
    be static. Callers scanning many epochs can pre-draw all noise in one
    batched call and pass rows via ``z`` (``rng`` is then unused); ``z`` may
    be any mean-0/variance-1 deviates — the scan path uses standardized
    popcount (CLT) deviates, which are cheaper than normals and
    indistinguishable through the per-tenant aggregates FMMR consumes.
    """
    if exact:
        return counts.astype(jnp.uint32)
    period = jnp.asarray(sample_period, jnp.float32)
    p = 1.0 / jnp.maximum(period, 1.0)
    n = counts.astype(jnp.float32)
    # Poisson(np) is the standard PEBS model. jax.random.poisson is a
    # rejection sampler (20x the cost of the whole policy epoch on CPU), so
    # draw Normal(np, np) rounded and clamped to [0, n] instead: identical
    # mean/variance, and FMMR only consumes per-tenant aggregates of
    # thousands of pages where the CLT washes out the per-page shape.
    lam = n * p
    if z is None:
        z = jax.random.normal(rng, lam.shape, jnp.float32)
    draw = jnp.round(lam + jnp.sqrt(lam) * z)
    sampled = jnp.clip(draw, 0.0, n).astype(jnp.uint32)
    # period <= 1 means "no subsampling": return the exact integer counts
    # (not the f32 round-trip, which loses counts above 2^24)
    return jnp.where(period <= 1.0, counts.astype(jnp.uint32), sampled)
