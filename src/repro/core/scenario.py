"""Declarative dynamic-colocation scenarios (paper §5, Figs. 7-9).

The paper's headline results come from *dynamic* workloads — tenants
arriving, departing and shifting working sets while competitors hold static
partitions or thrash. A :class:`Scenario` is a declarative script of timed
events that :func:`run_scenario` executes against any placement backend
driven by ``ColocationSim`` (MaxMem's ``CentralManager`` or any baseline
from ``core.baselines``), so all policies face byte-identical workload
timelines.

Event semantics (all events fire *before* the epoch they are stamped with,
in the order they appear in ``Scenario.events``):

  ``Arrive(epoch, spec)``       register + allocate a tenant (fast-first)
  ``Depart(epoch, name)``       free all pages + unregister the tenant
  ``ResizeWorkingSet(...)``     grow/shrink a skew set's page fraction
                                (paper Fig. 4 event 5 / Fig. 8 event 2)
  ``ShiftWorkingSet(...)``      re-scatter the skew sets onto fresh pages —
                                a phase change: the learned heat map is
                                instantly stale (TPP-style thrash)
  ``SkewChange(...)``           change a set's share of accesses (hotness
                                skew), page footprint unchanged
  ``Retarget(...)``             dynamic QoS t_miss update (paper §3.3)
  ``PingPongShift(...)``        toggle the working set between two fixed
                                scatters — the thrash schedule that makes
                                bounded migration bandwidth observable
  ``SetMigrationBandwidth(...)`` bound the backend's migration drain
                                (pages/epoch; None = unlimited); backends
                                without a data plane clamp their per-epoch
                                migration budget instead

Fault events (DESIGN.md §7) share the same surface; each takes an optional
``machine`` index that a fleet sweep (:func:`run_sweep`) uses to target one
machine (None = all), while single-sim runs apply it to the whole backend:

  ``MachineFail(...)``          drop a machine: its fleet row is parked and
                                runs inert; epochs record as down-time
  ``MachineRecover(...)``       restore the parked state bit-identically
  ``BandwidthDegrade(...)``     scale migration bandwidth RELATIVE to the
                                configured value (degraded DMA engine);
                                factor=1.0 restores
  ``DataPlaneError(...)``       attach a seeded ``FaultInjector`` to the
                                page pool: moves fail probabilistically
                                with bounded retry; no-op without a pool
  ``TelemetryCorrupt(...)``     poison one cell of the policy state — the
                                corruption the invariant sentinel catches

Epoch boundaries at which any event fires split the timeline into *phases*;
:class:`ScenarioResult` aggregates per-tenant throughput/p99/FMMR per phase
(plus migration bytes and mean queue depth), which is exactly the shape of
the paper's Fig. 7-9 curves.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core.faults import SentinelError
from repro.core.manager import CentralManager
from repro.core.simulator import OPTANE, ColocationSim, EpochRecord, WorkloadSpec


# ------------------------------------------------------------------ events
@dataclass(frozen=True)
class Arrive:
    epoch: int
    spec: WorkloadSpec

    def apply(self, sim: ColocationSim) -> None:
        sim.add_tenant(self.spec)

    def label(self) -> str:
        return f"+{self.spec.name}"


@dataclass(frozen=True)
class Depart:
    epoch: int
    name: str

    def apply(self, sim: ColocationSim) -> None:
        sim.remove_tenant(self.name)

    def label(self) -> str:
        return f"-{self.name}"


@dataclass(frozen=True)
class ResizeWorkingSet:
    epoch: int
    name: str
    set_index: int
    frac_pages: float

    def validate(self) -> None:
        if not (np.isfinite(self.frac_pages) and 0.0 <= self.frac_pages <= 1.0):
            raise ValueError(
                f"ResizeWorkingSet frac_pages must be finite in [0, 1], "
                f"got {self.frac_pages!r}"
            )

    def apply(self, sim: ColocationSim) -> None:
        sim.tenants[self.name].resize_set(self.set_index, self.frac_pages)

    def label(self) -> str:
        return f"{self.name}.set{self.set_index}~{self.frac_pages:g}p"


@dataclass(frozen=True)
class ShiftWorkingSet:
    epoch: int
    name: str

    def apply(self, sim: ColocationSim) -> None:
        sim.tenants[self.name].shift_sets()

    def label(self) -> str:
        return f"{self.name}.shift"


@dataclass(frozen=True)
class SkewChange:
    epoch: int
    name: str
    set_index: int
    frac_accesses: float

    def validate(self) -> None:
        if not (np.isfinite(self.frac_accesses) and 0.0 <= self.frac_accesses <= 1.0):
            raise ValueError(
                f"SkewChange frac_accesses must be finite in [0, 1], "
                f"got {self.frac_accesses!r}"
            )

    def apply(self, sim: ColocationSim) -> None:
        sim.tenants[self.name].set_skew(self.set_index, self.frac_accesses)

    def label(self) -> str:
        return f"{self.name}.set{self.set_index}~{self.frac_accesses:g}a"


@dataclass(frozen=True)
class Retarget:
    epoch: int
    name: str
    t_miss: float

    def validate(self) -> None:
        if not (np.isfinite(self.t_miss) and 0.0 < self.t_miss <= 1.0):
            raise ValueError(
                f"Retarget t_miss must be finite in (0, 1], got {self.t_miss!r}"
            )

    def apply(self, sim: ColocationSim) -> None:
        sim.set_target(self.name, self.t_miss)

    def label(self) -> str:
        return f"{self.name}.t={self.t_miss:g}"


@dataclass(frozen=True)
class PingPongShift:
    epoch: int
    name: str

    def apply(self, sim: ColocationSim) -> None:
        sim.tenants[self.name].pingpong_shift()

    def label(self) -> str:
        return f"{self.name}.pingpong"


@dataclass(frozen=True)
class SetMigrationBandwidth:
    epoch: int
    pages_per_epoch: Optional[int]  # None = unlimited

    def validate(self) -> None:
        bw = self.pages_per_epoch
        if bw is not None and (not np.isfinite(bw) or int(bw) < 0):
            raise ValueError(
                f"SetMigrationBandwidth pages_per_epoch must be None or a "
                f"non-negative int, got {bw!r}"
            )

    def apply(self, sim: ColocationSim) -> None:
        backend = sim.backend
        if hasattr(backend, "set_migration_bandwidth"):
            backend.set_migration_bandwidth(self.pages_per_epoch)
            return
        if not hasattr(backend, "migration_budget"):
            # hardware-managed placement (TwoLM): every access IS the
            # insertion path — there is no migration engine to throttle
            return
        # instant-apply baselines (HeMem, AutoNUMA): their per-epoch budget
        # IS the bandwidth. Stash the configured value on first clamp so a
        # later None event restores it rather than leaving the clamp behind.
        if not hasattr(backend, "_unclamped_migration_budget"):
            backend._unclamped_migration_budget = backend.migration_budget
        if self.pages_per_epoch is None:
            backend.migration_budget = backend._unclamped_migration_budget
        else:
            backend.migration_budget = int(self.pages_per_epoch)

    def label(self) -> str:
        bw = "inf" if self.pages_per_epoch is None else self.pages_per_epoch
        return f"bw={bw}"


# ----------------------------------------------------------- fault events
def _machine_tag(machine: Optional[int]) -> str:
    return "*" if machine is None else str(machine)


@dataclass(frozen=True)
class MachineFail:
    """Drop a machine mid-run (DESIGN.md §7).

    In a fleet sweep the targeted machine's ``PolicyState`` is parked
    host-side and the row runs inert until :class:`MachineRecover`; its
    epochs record as down-time (zero throughput, all-miss). On a single sim
    the whole backend freezes (``ColocationSim.fail``)."""

    epoch: int
    machine: Optional[int] = None  # sweep machine index; None = all

    def apply(self, sim: ColocationSim) -> None:
        sim.fail()

    def label(self) -> str:
        return f"fail[{_machine_tag(self.machine)}]"


@dataclass(frozen=True)
class MachineRecover:
    """Restore a failed machine's parked state bit-identically; its PRNG
    stream and migration queue resume exactly where the failure froze
    them."""

    epoch: int
    machine: Optional[int] = None

    def apply(self, sim: ColocationSim) -> None:
        sim.recover()

    def label(self) -> str:
        return f"recover[{_machine_tag(self.machine)}]"


@dataclass(frozen=True)
class BandwidthDegrade:
    """Scale migration bandwidth RELATIVE to the configured value (a
    degraded DMA engine / interconnect), unlike the absolute
    :class:`SetMigrationBandwidth`. ``factor=1.0`` restores full bandwidth.
    A queue-mode manager running unlimited is first pinned to its migration
    budget (the engine's nominal peak) so there is a finite value to scale;
    hardware-managed baselines (TwoLM) have no migration engine and no-op."""

    epoch: int
    factor: float
    machine: Optional[int] = None

    def validate(self) -> None:
        if not (np.isfinite(self.factor) and 0.0 < self.factor <= 1.0):
            raise ValueError(
                f"BandwidthDegrade factor must be finite in (0, 1], "
                f"got {self.factor!r}"
            )

    def apply(self, sim: ColocationSim) -> None:
        backend = sim.backend
        if hasattr(backend, "set_migration_bandwidth") and getattr(backend, "queue_size", 0) > 0:
            # queue-mode manager: scale the drain bandwidth (traced param)
            if not hasattr(backend, "_undegraded_bandwidth"):
                bw = int(backend.params.migration_bandwidth)
                backend._undegraded_bandwidth = None if bw < 0 else bw
            orig = backend._undegraded_bandwidth
            if self.factor >= 1.0:
                backend.set_migration_bandwidth(orig)
            else:
                nominal = int(backend.params.migration_budget) if orig is None else orig
                backend.set_migration_bandwidth(max(1, int(nominal * self.factor)))
            return
        if hasattr(backend, "migration_budget"):
            # instant-apply baselines: the per-epoch budget IS the bandwidth.
            # budget None = unlimited (AutoNUMA's default) — no finite
            # engine rate exists to scale, so degradation is a no-op there
            if not hasattr(backend, "_undegraded_migration_budget"):
                backend._undegraded_migration_budget = backend.migration_budget
            orig = backend._undegraded_migration_budget
            if orig is not None:
                backend.migration_budget = (
                    orig if self.factor >= 1.0 else max(1, int(orig * self.factor))
                )
            return
        if hasattr(backend, "params") and hasattr(backend.params, "migration_budget"):
            # instant-apply CentralManager: scale the traced budget leaf
            if not hasattr(backend, "_undegraded_migration_budget"):
                backend._undegraded_migration_budget = int(backend.params.migration_budget)
            orig = backend._undegraded_migration_budget
            new = orig if self.factor >= 1.0 else max(1, int(orig * self.factor))
            backend.params = backend.params._replace(migration_budget=jnp.int32(new))
        # hardware-managed placement (TwoLM): nothing to degrade

    def label(self) -> str:
        return f"bw*{self.factor:g}[{_machine_tag(self.machine)}]"


@dataclass(frozen=True)
class DataPlaneError:
    """Attach a seeded ``core.faults.FaultInjector`` to the backend's page
    pool: each DMA page move fails with probability ``rate``, retried with
    exponential backoff up to ``max_retries`` times; abandoned moves stay in
    their source tier (commit-on-completion fallback — degraded, never
    corrupt). ``rate=0`` detaches. No-op on backends without a pool."""

    epoch: int
    rate: float
    max_retries: int = 3
    seed: int = 0
    machine: Optional[int] = None

    def validate(self) -> None:
        if not (np.isfinite(self.rate) and 0.0 <= self.rate <= 1.0):
            raise ValueError(
                f"DataPlaneError rate must be finite in [0, 1], got {self.rate!r}"
            )
        if self.max_retries < 0:
            raise ValueError(
                f"DataPlaneError max_retries must be >= 0, got {self.max_retries!r}"
            )

    def apply(self, sim: ColocationSim) -> None:
        backend = sim.backend
        if getattr(backend, "pool", None) is None or not hasattr(backend, "set_fault_injector"):
            return  # no page data plane — nothing whose move can fail
        if self.rate <= 0.0:
            backend.set_fault_injector(None)
        else:
            from repro.core.faults import FaultInjector

            backend.set_fault_injector(FaultInjector(
                move_fail_rate=self.rate, max_retries=self.max_retries,
                seed=self.seed,
            ))

    def label(self) -> str:
        return f"dma-err={self.rate:g}[{_machine_tag(self.machine)}]"


@dataclass(frozen=True)
class TelemetryCorrupt:
    """Poison one cell of the policy state (``kind='tier'`` unplaces an
    owned page, ``'nan'`` drops NaN into an FMMR EWMA) — exactly the
    corruptions the invariant sentinel exists to catch. Transient: a sweep
    restoring from a checkpoint does NOT replay an already-fired poison
    (else detect -> restore would loop forever)."""

    epoch: int
    kind: str = "tier"
    machine: Optional[int] = None

    transient = True  # class attr: one-shot, skipped on restore replay

    def validate(self) -> None:
        if self.kind not in ("tier", "nan"):
            raise ValueError(
                f"TelemetryCorrupt kind must be 'tier' or 'nan', got {self.kind!r}"
            )

    def apply(self, sim: ColocationSim) -> None:
        backend = sim.backend
        if hasattr(backend, "poison_telemetry"):
            backend.poison_telemetry(self.kind)

    def label(self) -> str:
        return f"poison:{self.kind}[{_machine_tag(self.machine)}]"


ScenarioEvent = Union[Arrive, Depart, ResizeWorkingSet, ShiftWorkingSet,
                      SkewChange, Retarget, PingPongShift, SetMigrationBandwidth,
                      MachineFail, MachineRecover, BandwidthDegrade,
                      DataPlaneError, TelemetryCorrupt]


def _check_window(kind: str, start: int, end: int, period: int) -> None:
    """Construction-time guards shared by the schedule generators: a
    degenerate window or period silently yields an empty/endless schedule
    downstream, so it fails HERE with a clear message (PR 6 validation
    contract)."""
    if not (np.isfinite(period) and int(period) > 0):
        raise ValueError(f"{kind} period must be a positive int, got {period!r}")
    if not (np.isfinite(start) and int(start) >= 0):
        raise ValueError(f"{kind} start must be >= 0, got {start!r}")
    if not (np.isfinite(end) and int(end) > int(start)):
        raise ValueError(
            f"{kind} window is empty: end ({end!r}) must be > start ({start!r})"
        )


def pingpong_schedule(name: str, start: int, end: int, period: int) -> Tuple[PingPongShift, ...]:
    """A ping-pong thrash schedule: flip ``name``'s working set every
    ``period`` epochs in ``[start, end)`` — each flip returns the hot set to
    pages the policy may still be draining, so queued demotions keep
    re-heating (the thrashing-guard regime)."""
    _check_window("pingpong_schedule", start, end, period)
    return tuple(PingPongShift(e, name) for e in range(start, end, period))


def diurnal_schedule(
    name: str,
    start: int,
    end: int,
    period: int,
    lo: float = 0.2,
    hi: float = 0.9,
    set_index: int = 0,
) -> Tuple[SkewChange, ...]:
    """Diurnal traffic generator: oscillate ``name``'s hot-set access share
    sinusoidally between ``lo`` and ``hi`` with the given ``period``
    (sampled every quarter period) — the day/night load swing that slowly
    invalidates a learned heat map instead of snapping it (contrast
    :func:`pingpong_schedule`)."""
    _check_window("diurnal_schedule", start, end, period)
    for label, v in (("lo", lo), ("hi", hi)):
        if not (np.isfinite(v) and 0.0 <= v <= 1.0):
            raise ValueError(
                f"diurnal_schedule {label} must be finite in [0, 1], got {v!r}"
            )
    if lo > hi:
        raise ValueError(f"diurnal_schedule needs lo <= hi, got {lo!r} > {hi!r}")
    mid, amp = (hi + lo) / 2.0, (hi - lo) / 2.0
    step = max(int(period) // 4, 1)
    return tuple(
        SkewChange(
            e, name, set_index,
            float(mid + amp * np.sin(2.0 * np.pi * (e - start) / period)),
        )
        for e in range(start, end, step)
    )


# ---------------------------------------------------------------- scenario
@dataclass(frozen=True)
class Scenario:
    """A named, validated script of timed events over ``n_epochs``."""

    name: str
    n_epochs: int
    events: Tuple[ScenarioEvent, ...] = ()
    description: str = ""

    def __post_init__(self):
        assert self.n_epochs > 0, "scenario must run at least one epoch"
        for ev in self.events:
            assert 0 <= ev.epoch < self.n_epochs, (
                f"event {ev} outside [0, {self.n_epochs})"
            )
            # events with value constraints self-validate at construction
            # (NaN/negative rates, bandwidths, working-set fractions fail
            # HERE with a clear message, not as silent NaN downstream)
            validate = getattr(ev, "validate", None)
            if validate is not None:
                validate()

    def events_at(self, epoch: int) -> List[ScenarioEvent]:
        return [ev for ev in self.events if ev.epoch == epoch]

    def phase_boundaries(self) -> List[int]:
        """Sorted epoch indices that open a phase (0 plus event epochs)."""
        return sorted({0, *(ev.epoch for ev in self.events)})

    def phase_spans(self) -> List[Tuple[int, int, str]]:
        """(start, end, label) per phase; label names the opening events."""
        bounds = self.phase_boundaries() + [self.n_epochs]
        spans = []
        for start, end in zip(bounds[:-1], bounds[1:]):
            if start == end:
                continue
            evs = self.events_at(start)
            label = ",".join(ev.label() for ev in evs) if evs else "start"
            spans.append((start, end, label))
        return spans


def scale_colocation(
    n_pages: int,
    n_tenants: int,
    n_epochs: int,
    churn: float = 0.25,
) -> Scenario:
    """Geometry-parameterized colocation scenario for the scaling sweep.

    Unlike the hand-tuned figure scenarios, this builder takes the
    (pages, tenants) geometry as free axes so the scale bench and the
    churn tests can script a manager-grade run at ANY grid point. Core
    tenants (all but a ``churn`` fraction) arrive at epoch 0; the churn
    cohort arrives in a batch at n_epochs/4 and departs at 3·n_epochs/4 —
    two mass register/free/unregister waves that exercise the incremental
    ``OwnerSegments`` splice with many tenants mutating at once.

    Footprints total 3/4 of ``n_pages`` at peak concurrency, leaving
    allocation headroom; odd-index tenants are latency-sensitive (skewed
    hot set, reachable t_miss), even-index are best-effort uniform — so
    the reallocation loop has real FMMR gradients to act on at every T.
    """
    assert n_tenants >= 2, "scale scenario needs at least two tenants"
    assert n_epochs >= 4, "scale scenario needs at least four epochs"
    assert 0.0 <= churn < 1.0, f"churn fraction must be in [0, 1), got {churn}"
    n_churn = int(round(churn * n_tenants))
    n_core = n_tenants - n_churn
    fp = (3 * n_pages) // (4 * n_tenants)
    assert fp >= 8, (
        f"geometry too thin: {n_pages} pages / {n_tenants} tenants "
        f"leaves {fp} pages per tenant (need >= 8)"
    )

    def _spec(i: int) -> WorkloadSpec:
        if i % 2 == 1:  # latency-sensitive: skewed, reachable target
            return WorkloadSpec(f"t{i:03d}", n_pages=fp, t_miss=0.3,
                                threads=2, sets=((0.2, 0.8),))
        return WorkloadSpec(f"t{i:03d}", n_pages=fp, t_miss=1.0, threads=2)

    arrive_at = max(1, n_epochs // 4)
    depart_at = max(arrive_at + 1, (3 * n_epochs) // 4)
    events: List[ScenarioEvent] = [Arrive(0, _spec(i)) for i in range(n_core)]
    for j in range(n_churn):
        i = n_core + j
        events.append(Arrive(arrive_at, _spec(i)))
        events.append(Depart(depart_at, f"t{i:03d}"))
    return Scenario(
        name=f"scale_{n_pages // 1024}k_x{n_tenants}",
        n_epochs=n_epochs,
        events=tuple(events),
        description="geometry-parameterized colocation with batch tenant churn",
    )


# ------------------------------------------------- adversarial storm suite
#
# Jenga-class storms (PAPERS.md): schedules engineered to provoke
# promotion/demotion storms rather than model a realistic mix. Each
# builder composes the validated event vocabulary above, lives in core so
# the tuner family and the differential tests need only ``src`` on the
# path (the skewshift precedent), and uses the repo-wide geometry
# convention fast = P/8 unless told otherwise.

def _storm_geometry(n_pages: int, n_epochs: int, fast_capacity: Optional[int]) -> int:
    if n_epochs < 8:
        raise ValueError(f"storm scenarios need n_epochs >= 8, got {n_epochs}")
    fast = n_pages // 8 if fast_capacity is None else int(fast_capacity)
    if fast < 16:
        raise ValueError(
            f"storm geometry too thin: fast tier of {fast} pages (need >= 16)"
        )
    return fast


def boundary_straddle_scenario(
    n_pages: int,
    n_epochs: int,
    fast_capacity: Optional[int] = None,
    epsilon: float = 0.08,
    period: Optional[int] = None,
) -> Scenario:
    """Working set sized at ``fast_capacity ± epsilon``: the ``edge``
    tenant's hot set oscillates between just-fits and just-overflows, so
    every flip re-decides which boundary pages deserve the fast tier —
    the canonical promotion/demotion storm (Jenga §1)."""
    fast = _storm_geometry(n_pages, n_epochs, fast_capacity)
    if not (np.isfinite(epsilon) and 0.0 < epsilon < 0.5):
        raise ValueError(
            f"boundary_straddle epsilon must be finite in (0, 0.5), got {epsilon!r}"
        )
    footprint = 2 * fast
    lo_frac = (1.0 - epsilon) / 2.0  # hot pages = fast * (1 - epsilon)
    hi_frac = (1.0 + epsilon) / 2.0  # hot pages = fast * (1 + epsilon)
    per = max(2, n_epochs // 8) if period is None else period
    _check_window("boundary_straddle", n_epochs // 4, (3 * n_epochs) // 4, per)
    flips = tuple(
        ResizeWorkingSet(e, "edge", 0, hi_frac if i % 2 == 0 else lo_frac)
        for i, e in enumerate(range(n_epochs // 4, (3 * n_epochs) // 4, per))
    )
    return Scenario(
        name=f"storm_boundary_{n_pages // 1024}k",
        n_epochs=n_epochs,
        events=(
            Arrive(0, WorkloadSpec(
                "edge", footprint, t_miss=0.3, threads=4,
                sets=((lo_frac, 0.9),),
            )),
            Arrive(0, WorkloadSpec(
                "kvs", n_pages // 8, t_miss=0.3, threads=4,
                sets=((0.2, 0.85),),
            )),
            Arrive(0, WorkloadSpec("gups", n_pages // 4, threads=6)),
            *flips,
        ),
        description="hot set straddles fast capacity (fast*(1 +- epsilon))",
    )


def correlated_flips_scenario(
    n_pages: int,
    n_epochs: int,
    fast_capacity: Optional[int] = None,
    n_flippers: int = 3,
    period: Optional[int] = None,
) -> Scenario:
    """Correlated multi-tenant phase flips: every flipper ping-pongs its
    working set at the SAME epochs, so the migration queue absorbs all
    tenants' stale-heat churn at once instead of amortizing it."""
    _storm_geometry(n_pages, n_epochs, fast_capacity)
    if n_flippers < 2:
        raise ValueError(f"correlated_flips needs >= 2 flippers, got {n_flippers}")
    per = max(2, n_epochs // 8) if period is None else period
    fp = (3 * n_pages) // (8 * n_flippers)
    flips: List[ScenarioEvent] = []
    arrivals: List[ScenarioEvent] = []
    for i in range(n_flippers):
        nm = f"flip{i}"
        arrivals.append(Arrive(0, WorkloadSpec(
            nm, fp, t_miss=0.3, threads=4, sets=((0.25, 0.85),),
        )))
        flips.extend(pingpong_schedule(nm, n_epochs // 4, (3 * n_epochs) // 4, per))
    return Scenario(
        name=f"storm_correlated_{n_pages // 1024}k",
        n_epochs=n_epochs,
        events=(
            *arrivals,
            Arrive(0, WorkloadSpec("gups", n_pages // 4, threads=6)),
            *flips,
        ),
        description=f"{n_flippers} tenants ping-pong in lockstep",
    )


def burst_arrivals_scenario(
    n_pages: int,
    n_epochs: int,
    fast_capacity: Optional[int] = None,
    burst: int = 3,
) -> Scenario:
    """Open-loop burst arrivals: cohorts of tenants register and allocate
    in one epoch regardless of system state (open-loop: the schedule never
    waits for the queue to drain), each cohort departing as the next
    lands — allocation-reserve pressure plus mass ownership churn."""
    _storm_geometry(n_pages, n_epochs, fast_capacity)
    if burst < 1:
        raise ValueError(f"burst_arrivals burst must be >= 1, got {burst}")
    fp = n_pages // 16
    b1, b2, b3 = n_epochs // 4, n_epochs // 2, (3 * n_epochs) // 4
    events: List[ScenarioEvent] = [
        Arrive(0, WorkloadSpec(
            "kvs", n_pages // 4, t_miss=0.3, threads=4, sets=((0.2, 0.85),),
        )),
        Arrive(0, WorkloadSpec("gups", n_pages // 8, threads=6)),
    ]
    for j in range(burst):
        events.append(Arrive(b1, WorkloadSpec(f"burst0_{j}", fp, threads=2)))
    for j in range(burst):  # cohort 0 leaves exactly as cohort 1 lands
        events.append(Depart(b2, f"burst0_{j}"))
        events.append(Arrive(b2, WorkloadSpec(f"burst1_{j}", fp, threads=2)))
    for j in range(burst):
        events.append(Depart(b3, f"burst1_{j}"))
    return Scenario(
        name=f"storm_burst_{n_pages // 1024}k",
        n_epochs=n_epochs,
        events=tuple(events),
        description=f"open-loop arrival bursts of {burst} tenants",
    )


def diurnal_scenario(
    n_pages: int,
    n_epochs: int,
    fast_capacity: Optional[int] = None,
    lo: float = 0.3,
    hi: float = 0.95,
) -> Scenario:
    """Diurnal load swing: the ``web`` tenant's hot-set share follows a
    sine between ``lo`` and ``hi`` (:func:`diurnal_schedule`) while a
    batch tenant soaks the slack — the slow phase change that rewards a
    policy for NOT chasing every sample."""
    _storm_geometry(n_pages, n_epochs, fast_capacity)
    swings = diurnal_schedule(
        "web", 1, n_epochs, max(n_epochs // 2, 4), lo=lo, hi=hi
    )
    return Scenario(
        name=f"storm_diurnal_{n_pages // 1024}k",
        n_epochs=n_epochs,
        events=(
            Arrive(0, WorkloadSpec(
                "web", (3 * n_pages) // 8, t_miss=0.3, threads=4,
                sets=((0.15, lo),),
            )),
            Arrive(0, WorkloadSpec("gups", n_pages // 4, threads=6)),
            *swings,
        ),
        description="sinusoidal hot-share swing (day/night traffic)",
    )


STORM_FAMILIES = ("boundary", "correlated", "burst", "diurnal")

_STORM_MAKERS = {
    "boundary": boundary_straddle_scenario,
    "correlated": correlated_flips_scenario,
    "burst": burst_arrivals_scenario,
    "diurnal": diurnal_scenario,
}


def storm_scenario(family: str, n_pages: int, n_epochs: int, **kw) -> Scenario:
    """Build one storm family by name (``STORM_FAMILIES``)."""
    if family not in _STORM_MAKERS:
        raise KeyError(
            f"unknown storm family {family!r}; choose from {STORM_FAMILIES}"
        )
    return _STORM_MAKERS[family](n_pages, n_epochs, **kw)


def adversarial_scenario(
    n_pages: int,
    n_epochs: int,
    fast_capacity: Optional[int] = None,
    epsilon: float = 0.08,
) -> Scenario:
    """The composite storm the ``adversarial`` tuner family trains on: a
    boundary-straddling working set whose resize flips are phase-locked
    with a ping-pong flipper — boundary pressure and correlated stale heat
    hitting the queue in the same epochs."""
    base = boundary_straddle_scenario(
        n_pages, n_epochs, fast_capacity=fast_capacity, epsilon=epsilon
    )
    per = max(2, n_epochs // 8)
    flip_spec = Arrive(0, WorkloadSpec(
        "flip", n_pages // 8, t_miss=0.3, threads=4, sets=((0.25, 0.85),),
    ))
    # replace the plain kvs tenant with the flipper, keeping total footprint
    events = tuple(
        ev for ev in base.events
        if not (isinstance(ev, Arrive) and ev.spec.name == "kvs")
    )
    return Scenario(
        name=f"storm_{n_pages // 1024}k",
        n_epochs=n_epochs,
        events=(
            flip_spec,
            *events,
            *pingpong_schedule("flip", n_epochs // 4, (3 * n_epochs) // 4, per),
        ),
        description="boundary straddle + phase-locked ping-pong composite",
    )


# ------------------------------------------------------------------ result
@dataclass
class PhaseStats:
    """Per-phase aggregates (the paper-figure observables)."""

    label: str
    start: int
    end: int
    throughput: Dict[str, float]  # mean ops/s per tenant while present
    p99: Dict[str, float]  # mean p99 seconds per tenant
    fmmr: Dict[str, float]  # mean true FMMR per tenant
    agg_throughput: float  # mean over epochs of sum-over-tenants ops/s
    mean_p99: float  # mean over (epoch, tenant) p99 seconds
    migrated_pages: int
    migration_bytes: float = 0.0  # committed migration traffic in the phase
    mean_queue_depth: float = 0.0  # mean in-flight migrations per epoch
    max_queue_depth: int = 0

    def to_jsonable(self) -> dict:
        return {
            "label": self.label, "start": self.start, "end": self.end,
            "agg_throughput": self.agg_throughput,
            "mean_p99_us": self.mean_p99 * 1e6,
            "throughput": self.throughput,
            "p99_us": {k: v * 1e6 for k, v in self.p99.items()},
            "fmmr": self.fmmr,
            "migrated_pages": self.migrated_pages,
            "migration_bytes": self.migration_bytes,
            "mean_queue_depth": self.mean_queue_depth,
            "max_queue_depth": self.max_queue_depth,
        }


@dataclass
class ScenarioResult:
    scenario: Scenario
    history: List[EpochRecord]
    phases: List[PhaseStats] = field(default_factory=list)

    @property
    def steady_state(self) -> PhaseStats:
        """The final phase — the paper's end-of-run comparison window."""
        return self.phases[-1]

    def to_jsonable(self) -> dict:
        return {
            "scenario": self.scenario.name,
            "n_epochs": self.scenario.n_epochs,
            "phases": [p.to_jsonable() for p in self.phases],
        }


def _phase_stats(history: List[EpochRecord], start: int, end: int, label: str) -> PhaseStats:
    recs = history[start:end]
    names = sorted({nm for r in recs for nm in r.throughput})
    tput, p99, fmmr = {}, {}, {}
    for nm in names:
        ts = [r.throughput[nm] for r in recs if nm in r.throughput]
        tput[nm] = float(np.mean(ts))
        p99[nm] = float(np.mean([r.p99[nm] for r in recs if nm in r.p99]))
        fmmr[nm] = float(np.mean([r.fmmr_true[nm] for r in recs if nm in r.fmmr_true]))
    agg = float(np.mean([sum(r.throughput.values()) for r in recs])) if recs else 0.0
    all_p99 = [v for r in recs for v in r.p99.values()]
    depths = [r.queue_depth for r in recs]
    return PhaseStats(
        label=label, start=start, end=end,
        throughput=tput, p99=p99, fmmr=fmmr,
        agg_throughput=agg,
        mean_p99=float(np.mean(all_p99)) if all_p99 else 0.0,
        migrated_pages=int(sum(r.migrated_pages for r in recs)),
        migration_bytes=float(sum(r.migration_bytes for r in recs)),
        mean_queue_depth=float(np.mean(depths)) if depths else 0.0,
        max_queue_depth=int(max(depths, default=0)),
    )


# --------------------------------------------------------- responsiveness
def recovery_epochs(
    history,
    event_epoch: int,
    frac: float = 0.95,
    baseline_window: int = 8,
    tenant: Optional[str] = None,
) -> Tuple[int, float]:
    """Jenga-style responsiveness: epochs after ``event_epoch`` until
    throughput regains ``frac`` of its pre-event mean, measured from the
    event to the END of the post-event dip (with chunked records the first
    post-event epochs can still carry pre-shift telemetry, so the dip is
    located first; no dip at all counts as instant recovery).

    ``tenant`` selects one tenant's throughput as the observable — the
    right probe for a working-set shift, because the aggregate MASKS the
    dip (a missing LS tenant frees bandwidth and the batch tenants speed
    up). ``None`` scores the aggregate. Returns (epochs, baseline).

    This is the PR 8 online-tuner metric promoted into the scenario
    engine; ``repro.launch.hillclimb`` re-exports it."""
    if tenant is None:
        agg = np.array([sum(r.throughput.values()) for r in history], float)
    else:
        agg = np.array([r.throughput.get(tenant, 0.0) for r in history], float)
    lo = max(0, event_epoch - baseline_window)
    base = float(agg[lo:event_epoch].mean()) if event_epoch > lo else float(agg.mean())
    after = agg[event_epoch:]
    target = frac * base
    below = after < target
    if not below.any():
        return 0, base
    dip = int(np.argmax(below))
    hit = after[dip:] >= target
    if not hit.any():
        return len(after), base
    return dip + int(np.argmax(hit)), base


def churn_recovery_epochs(history, event_epoch: int) -> int:
    """Queue-axis twin of :func:`recovery_epochs`: epochs after
    ``event_epoch`` until the migration queue's enqueue/drain balance
    first goes non-positive — the epoch the control plane stops selecting
    more work than the data plane commits, i.e. the queue storm the event
    kicked off has subsided. A policy whose balance never recovers (it
    keeps overflowing the FIFO with selections that are dropped and
    re-selected every epoch) scores the whole remaining window — the
    saturated worst case the adversarial bench gates against.

    Throughput masks this failure mode entirely: two managers with
    identical committed migrations (identical throughput timelines) can
    differ 10x in enqueue work, and only the flow counters
    (``EpochRecord.queue_enqueued``/``queue_drained``) expose it."""
    for i in range(event_epoch, len(history)):
        if history[i].queue_enqueued - history[i].queue_drained <= 0:
            return i - event_epoch
    return len(history) - event_epoch


@dataclass
class ResponsivenessStats(PhaseStats):
    """:class:`PhaseStats` plus the adversarial-dynamics observables
    (DESIGN.md §11): per-event epochs-to-recover on each affected tenant's
    own throughput, and the phase's storm-health counters.

    ``pingpong_rate`` is cancelled/enqueued — the fraction of enqueue work
    burned on migrations that were later cancelled; every thrash-guard
    reheat cancel is one leg of a promote <-> demote ping-pong on that
    page, so a rate near 1 means the queue is churning, not migrating.
    ``cancel_ratio`` (cancelled/drained) is the livelock indicator the
    adversarial bench gates on."""

    recovery: Dict[str, int] = field(default_factory=dict)
    enqueued: int = 0
    drained: int = 0
    cancelled: int = 0
    cancel_ratio: float = 0.0
    pingpong_rate: float = 0.0

    def to_jsonable(self) -> dict:
        d = super().to_jsonable()
        d.update(
            recovery_epochs=self.recovery,
            queue_enqueued=self.enqueued,
            queue_drained=self.drained,
            queue_cancelled=self.cancelled,
            cancel_ratio=self.cancel_ratio,
            pingpong_rate=self.pingpong_rate,
        )
        return d


def _affected_tenants(evs) -> List[str]:
    """Tenants whose own throughput the recovery probe should watch. An
    arriving tenant has no pre-event baseline and a departing one no
    post-event signal, so both are skipped; machine-/bandwidth-level
    events affect everyone and fall back to the aggregate probe."""
    names = set()
    for ev in evs:
        if isinstance(ev, (Arrive, Depart)):
            continue
        nm = getattr(ev, "name", None)
        if nm is not None:
            names.add(nm)
    return sorted(names)


def responsiveness_phases(
    result: ScenarioResult,
    frac: float = 0.95,
    baseline_window: int = 8,
) -> List["ResponsivenessStats"]:
    """Recompute ``result``'s phases as :class:`ResponsivenessStats`.

    Each phase opened by events gets per-affected-tenant epochs-to-recover
    (measured over the remaining history, not just the phase — a dip may
    outlive its phase); phases whose events name no tenant use the
    aggregate probe under the key ``"*"``. Storm-health counters sum the
    per-epoch queue flow the simulator records."""
    history = result.history
    out: List[ResponsivenessStats] = []
    for ps in result.phases:
        recs = history[ps.start:ps.end]
        enq = sum(r.queue_enqueued for r in recs)
        drn = sum(r.queue_drained for r in recs)
        can = sum(r.queue_cancelled for r in recs)
        recovery: Dict[str, int] = {}
        evs = result.scenario.events_at(ps.start)
        if evs and ps.start > 0:  # epoch-0 events have no baseline window
            names = _affected_tenants(evs)
            if names:
                for nm in names:
                    ep, _base = recovery_epochs(
                        history, ps.start, frac=frac,
                        baseline_window=baseline_window, tenant=nm,
                    )
                    recovery[nm] = ep
            else:
                ep, _base = recovery_epochs(
                    history, ps.start, frac=frac, baseline_window=baseline_window
                )
                recovery["*"] = ep
        out.append(ResponsivenessStats(
            **vars(ps),
            recovery=recovery,
            enqueued=enq,
            drained=drn,
            cancelled=can,
            cancel_ratio=float(can) / max(drn, 1),
            pingpong_rate=float(can) / max(enq, 1),
        ))
    return out


def storm_health(result: ScenarioResult, frac: float = 0.95) -> dict:
    """Scenario-level storm summary the adversarial bench gates on:
    worst per-event recovery, whole-run cancel/drain ratio and ping-pong
    rate, plus the per-phase breakdown."""
    phases = responsiveness_phases(result, frac=frac)
    enq = sum(p.enqueued for p in phases)
    drn = sum(p.drained for p in phases)
    can = sum(p.cancelled for p in phases)
    worst = max(
        (max(p.recovery.values()) for p in phases if p.recovery), default=0
    )
    return {
        "worst_recovery_epochs": int(worst),
        "recovery_epochs": {
            f"{p.start}:{p.label}": p.recovery for p in phases if p.recovery
        },
        "enqueued": int(enq),
        "drained": int(drn),
        "cancelled": int(can),
        "cancel_ratio": float(can) / max(drn, 1),
        "pingpong_rate": float(can) / max(enq, 1),
        "phases": [p.to_jsonable() for p in phases],
    }


# ---------------------------------------------------------------- executor
def _collect_phases(sim: ColocationSim, scenario: Scenario, base: int) -> ScenarioResult:
    history = sim.history[base : base + scenario.n_epochs]
    phases = [
        _phase_stats(history, start, end, label)
        for start, end, label in scenario.phase_spans()
    ]
    return ScenarioResult(scenario=scenario, history=history, phases=phases)


def run_scenario(
    sim: ColocationSim,
    scenario: Scenario,
    on_event: Optional[Callable] = None,
) -> ScenarioResult:
    """Execute ``scenario`` on ``sim`` (any backend) and aggregate phases.

    ``on_event(sim, event)`` is called after each event is applied — the
    differential test harness uses it to assert invariants at every
    perturbation point.
    """
    base = len(sim.history)
    by_epoch: Dict[int, List[ScenarioEvent]] = {}
    for ev in scenario.events:
        by_epoch.setdefault(base + ev.epoch, []).append(ev)

    def fire(s: ColocationSim, evs=None) -> None:
        for ev in evs:
            ev.apply(s)
            if on_event is not None:
                on_event(s, ev)

    events = {
        epoch: (lambda s, evs=evs: fire(s, evs)) for epoch, evs in by_epoch.items()
    }
    sim.run(scenario.n_epochs, events)
    return _collect_phases(sim, scenario, base)


# ------------------------------------------------------------------- sweep
@dataclass(frozen=True)
class SweepPoint:
    """One machine of a :class:`ScenarioSweep` — the per-machine knobs that
    vary across the batched grid. Every field maps onto a TRACED
    ``PolicyParams`` leaf (or the PRNG seed), so a whole grid shares one
    compiled fleet program; shape-defining knobs (page count, queue size,
    tenant-table size) live on the sweep itself because changing them
    forces a fresh trace (DESIGN.md §5)."""

    name: str
    seed: int = 0  # manager PRNG + simulator access-noise stream
    migration_budget: Optional[int] = None  # None = the sweep-wide default
    migration_bandwidth: Optional[int] = None  # needs queue_size > 0
    migration_latency: int = 0
    sample_period: Optional[int] = None
    # remaining traced policy knobs (docs/PARAMS.md is the reference) —
    # None = the CentralManager default. The autotuner
    # (repro.launch.hillclimb) maps one candidate config onto each point.
    ewma_lambda: Optional[float] = None
    hysteresis: Optional[float] = None
    num_bins: Optional[int] = None
    alloc_headroom: Optional[int] = None
    fast_capacity: Optional[int] = None  # tier size is traced too (≤ num_pages)
    # storm guards (DESIGN.md §11) — default-off traced knobs; admission
    # and cooldown act on the queue tick, so they need queue_size > 0
    promote_band: Optional[float] = None
    demote_band: Optional[float] = None
    promote_admission: Optional[int] = None
    demote_cooldown: Optional[int] = None


@dataclass(frozen=True)
class ScenarioSweep:
    """One event schedule, a batched grid of machine configurations.

    Every sweep point runs the SAME scenario (byte-identical event
    timeline) on its own logical machine; the fleet backend advances all
    of them in one vmapped device program per chunk
    (``core.fleet.FleetManager``)."""

    scenario: Scenario
    points: Tuple[SweepPoint, ...]

    def __post_init__(self):
        assert len(self.points) > 0, "sweep needs at least one point"
        names = [p.name for p in self.points]
        assert len(set(names)) == len(names), "sweep point names must be unique"


@dataclass
class SweepResult:
    sweep: ScenarioSweep
    results: Dict[str, ScenarioResult]  # per sweep-point name
    wall_s: float = 0.0
    devices: int = 1  # shards the machine axis ran over
    pipeline: bool = False  # double-buffered host/device driving was on
    partial: bool = False  # stopped at a checkpoint via ``stop_after``
    fallbacks: int = 0  # dispatch faults recovered onto the inline path
    restores: int = 0  # sentinel-triggered checkpoint restores

    def to_jsonable(self) -> dict:
        return {
            "scenario": self.sweep.scenario.name,
            "n_machines": len(self.sweep.points),
            "wall_s": round(self.wall_s, 3),
            "devices": self.devices,
            "pipeline": self.pipeline,
            "partial": self.partial,
            "fallbacks": self.fallbacks,
            "restores": self.restores,
            "machines": {k: r.to_jsonable() for k, r in self.results.items()},
        }


def run_sweep(
    sweep: ScenarioSweep,
    *,
    num_pages: int,
    fast_capacity: int,
    migration_budget: int,
    max_tenants: int = 16,
    sample_period: int = 100,
    queue_size: int = 0,
    machine=None,
    epoch_seconds: float = 1.0,
    access_noise: bool = True,
    policy_chunk: int = 16,
    devices=None,
    pipeline: bool = True,
    trim_stats: bool = True,
    sentinel: bool = False,
    checkpoint_every: Optional[int] = None,
    checkpoint_dir: Optional[str] = None,
    resume: bool = False,
    dispatch_timeout: Optional[float] = None,
    stop_after: Optional[int] = None,
    max_restores: int = 3,
    on_fleet: Optional[Callable] = None,
) -> SweepResult:
    """Execute a :class:`ScenarioSweep` against the fleet backend.

    Builds one ``CentralManager`` per sweep point (identical shapes, the
    point's traced parameter overrides), wraps them in a
    ``core.fleet.FleetManager`` — sharded over ``devices`` (default: every
    visible XLA device) — and drives the shared event schedule: at every
    phase boundary the events fire on each machine's simulator
    (control-plane host operations — arrive/depart/resize work mid-sweep),
    and the epochs between boundaries run CHUNKED through the fleet.

    The chunk driving is a double-buffered pipeline (DESIGN.md §6): while
    chunk *k* executes on device, the host records chunk *k−1*'s telemetry
    (its end placement is chunk *k*'s entry placement, captured in ONE
    stacked transfer that also seeds every manager's snapshot cache) and
    the cost-model matrices for the next event-free stretch are reused
    across its chunks. The telemetry snapshot is fetched asynchronously and
    — with ``trim_stats`` — carries only the fields the record path reads.
    ``pipeline=False`` serializes prepare → execute → record per chunk (the
    pre-pipeline driver shape, used as the benchmark baseline leg); the
    recorded histories are IDENTICAL either way, because every record
    consumes the same placement and telemetry values in the same order.

    Chunk semantics match ``ColocationSim.run_chunk``: within a chunk the
    access distribution is frozen and migration stalls are not modeled;
    chunk boundaries (every event epoch, at least every ``policy_chunk``
    epochs) re-measure placement exactly.

    Fault tolerance (DESIGN.md §7):

      * ``sentinel=True`` compiles each machine's tick with the in-trace
        invariant sentinel; a non-zero bitmask in a chunk's telemetry
        raises :class:`~repro.core.faults.SentinelError` BEFORE the chunk
        is recorded, and — when checkpointing is on — the sweep restores
        from the last checkpoint and replays (transient corruptions like
        ``TelemetryCorrupt`` are not re-fired). After ``max_restores``
        round trips the error propagates.
      * ``checkpoint_every=N`` (requires ``checkpoint_dir``) saves the
        complete sweep state at the first fully-flushed chunk boundary
        every N epochs; ``resume=True`` continues from the latest step,
        bit-identically to an uninterrupted run. ``stop_after=E`` returns
        a partial result right after the first checkpoint at/past epoch E
        (the kill-simulation hook the resume-parity tests drive).
      * ``dispatch_timeout`` bounds every wait on the async dispatch
        worker (and arms the fleet's heartbeat supervision); a timeout or
        worker fault rolls the epoch clocks back, re-runs the chunk on the
        serialized inline path with the SAME pre-drawn access counts, and
        degrades the rest of the sweep to serialized dispatch — recorded
        histories are unaffected.
      * ``on_fleet(fleet)`` runs right after fleet construction (chaos
        tests use it to arm failure hooks).
    """
    import time as _time

    from repro.core.fleet import DispatchError, FleetManager
    from repro.runtime.fault_tolerance import DispatchSupervisor, SweepCheckpoint

    if checkpoint_every is not None and checkpoint_dir is None:
        raise ValueError("checkpoint_every requires checkpoint_dir")
    if resume and checkpoint_dir is None:
        raise ValueError("resume=True requires checkpoint_dir")

    t0 = _time.time()
    scenario = sweep.scenario
    managers = []
    for p in sweep.points:
        mgr_kw = dict(
            num_pages=num_pages,
            fast_capacity=fast_capacity if p.fast_capacity is None
            else p.fast_capacity,
            migration_budget=migration_budget if p.migration_budget is None
            else p.migration_budget,
            max_tenants=max_tenants,
            sample_period=sample_period if p.sample_period is None
            else p.sample_period,
            seed=p.seed, queue_size=queue_size,
            migration_latency=p.migration_latency,
            sentinel=sentinel,
        )
        if p.migration_bandwidth is not None:
            mgr_kw["migration_bandwidth"] = p.migration_bandwidth
        for knob in (
            "ewma_lambda", "hysteresis", "num_bins", "alloc_headroom",
            "promote_band", "demote_band", "promote_admission",
            "demote_cooldown",
        ):
            v = getattr(p, knob)
            if v is not None:
                mgr_kw[knob] = v
        managers.append(CentralManager(**mgr_kw))
    fleet = FleetManager(managers, devices=devices)
    if on_fleet is not None:
        on_fleet(fleet)
    supervisor = DispatchSupervisor(fleet, timeout=dispatch_timeout)
    ckpt = SweepCheckpoint(checkpoint_dir) if checkpoint_dir is not None else None
    sims = [
        ColocationSim(
            mgr, machine or OPTANE, epoch_seconds=epoch_seconds,
            seed=p.seed, access_noise=access_noise,
        )
        for mgr, p in zip(managers, sweep.points)
    ]
    K = len(sims)
    for ev in scenario.events:
        mt = getattr(ev, "machine", None)
        if mt is not None and not (0 <= int(mt) < K):
            raise ValueError(
                f"event {ev.label()} targets machine {mt}; sweep has {K}"
            )

    boundaries = sorted({0, *(ev.epoch for ev in scenario.events), scenario.n_epochs})
    pending = None  # (handle, k, ctxs, counts) — the chunk currently on device
    arrays = None  # per-sim cost-model matrices, valid within an event-free stretch
    fired: set = set()  # id() of transient events already applied this process
    restores = 0
    cur = 0
    last_ckpt = 0

    if resume:
        step = ckpt.latest()
        if step is not None:
            cur = ckpt.restore(fleet, sims)
            last_ckpt = cur

    def redispatch_pending() -> None:
        """Dispatch-fault recovery: roll the epoch clocks back and re-run
        the in-flight chunk on the serialized inline path. The retry
        consumes the SAME pre-drawn access counts against the SAME
        pre-dispatch state, so the recorded history is bit-identical to
        what the worker should have produced. Degrades the rest of the
        sweep to serialized dispatch (sticky)."""
        nonlocal pending
        fleet.recover_dispatch()
        supervisor.note_fallback()
        if pending is not None:
            handle, k, ctxs, counts = pending
            handle = fleet.run_epochs_async(
                k, counts=counts, trim_stats=trim_stats, inline=True
            )
            pending = (handle, k, ctxs, counts)

    def join_pending() -> None:
        """Bounded wait on the in-flight chunk (the supervision point)."""
        if pending is None:
            return
        try:
            supervisor.join(pending[0])
        except DispatchError:
            redispatch_pending()

    def sync_placement():
        try:
            return fleet.stacked_placement()
        except DispatchError:
            redispatch_pending()
            return fleet.stacked_placement()

    def flush(tiers: np.ndarray) -> None:
        """Record the in-flight chunk against its end placement. With the
        sentinel armed, a violation raises BEFORE anything is recorded —
        corrupted telemetry never reaches the history."""
        nonlocal pending
        if pending is None:
            return
        handle, k, ctxs, _counts = pending
        res = handle.result()
        if sentinel:
            bits = np.asarray(res.stats.sentinel)
            if bits.any():
                where = np.argwhere(bits != 0)[:4].tolist()
                pending = None
                raise SentinelError(
                    f"sentinel bits {sorted({int(v) for v in bits[bits != 0]})} "
                    f"at (machine, chunk-epoch) {where}"
                )
        for i, (sim, ctx) in enumerate(zip(sims, ctxs)):
            if ctx is None:  # machine was down for this chunk
                sim._record_down(k)
            else:
                sim._chunk_record(res.machine(i), k, ctx, tier_end=tiers[i])
        pending = None

    def restore_from_checkpoint() -> bool:
        nonlocal cur, last_ckpt, pending, arrays, restores
        if ckpt is None or ckpt.latest() is None or restores >= max_restores:
            return False
        restores += 1
        pending = None
        arrays = None
        cur = ckpt.restore(fleet, sims)
        last_ckpt = cur
        return True

    def flush_checked(tiers: np.ndarray) -> bool:
        """flush(); on a sentinel violation restore from the last
        checkpoint. False = the caller must restart the loop at the
        restored cursor."""
        try:
            flush(tiers)
        except SentinelError:
            if not restore_from_checkpoint():
                raise
            return False
        return True

    def fire_events(evs) -> None:
        for ev in evs:
            if getattr(ev, "transient", False) and id(ev) in fired:
                continue  # one-shot fault already injected before a restore
            if isinstance(ev, (Arrive, Depart)) and fleet.failed_machines:
                raise ValueError(
                    f"{ev.label()} while machines {fleet.failed_machines} are "
                    "down: tenant churn on an inert row is lost at recovery "
                    "(schedule contract, DESIGN.md §7)"
                )
            targets = (
                range(K) if getattr(ev, "machine", None) is None
                else [int(ev.machine)]
            )
            if isinstance(ev, MachineFail):
                for i in targets:
                    fleet.fail_machine(i)
                    sims[i].fail()
            elif isinstance(ev, MachineRecover):
                for i in targets:
                    fleet.recover_machine(i)
                    sims[i].recover()
            elif hasattr(ev, "machine"):
                for i in targets:
                    ev.apply(sims[i])
            else:
                for sim in sims:
                    ev.apply(sim)
            fired.add(id(ev))

    partial = False
    while True:
        if cur >= scenario.n_epochs:
            join_pending()
            tiers, _ = sync_placement()
            if not flush_checked(tiers):
                continue
            break
        evs = scenario.events_at(cur)
        if evs:
            # events read and mutate placement: the in-flight chunk must be
            # recorded against the PRE-event placement first
            join_pending()
            tiers, _ = sync_placement()
            if not flush_checked(tiers):
                continue
            fire_events(evs)
            arrays = None  # tenant sets / probs may have changed
        horizon = min(b for b in boundaries if b > cur)
        k = min(policy_chunk, horizon - cur)
        # chunk-entry placement: one stacked transfer; blocks until the
        # previous chunk's device work is done (the pipeline sync point)
        join_pending()
        tiers, _ = sync_placement()
        if arrays is None:
            arrays = [None if sim.failed else sim._arrays() for sim in sims]
        preps = []
        for i, sim in enumerate(sims):
            if sim.failed:
                # down machine: no accesses drawn (its PRNG stream freezes
                # with the parked state), its inert fleet row ticks on zeros
                preps.append((np.zeros(num_pages, np.int64), None))
            else:
                preps.append(sim._chunk_prepare(arrays=arrays[i], tier=tiers[i]))
        counts = np.stack([c for c, _ctx in preps])
        handle = supervisor.dispatch(k, counts=counts, trim_stats=trim_stats)
        # the previous chunk's end placement IS this chunk's entry: record
        # it now, overlapped with this chunk's device execution
        if not flush_checked(tiers):
            continue
        pending = (handle, k, [ctx for _c, ctx in preps], counts)
        if not pipeline or supervisor.degraded:
            join_pending()
            end_tiers, _ = sync_placement()
            if not flush_checked(end_tiers):
                continue
        cur += k
        if (
            ckpt is not None and checkpoint_every is not None
            and cur - last_ckpt >= checkpoint_every
        ):
            # checkpoint only fully-flushed states: join + record the chunk
            # that just ran, then save. The extra flush here consumes the
            # same placement/telemetry values the next iteration would —
            # recorded histories are unchanged by checkpointing (tested).
            join_pending()
            t2, _ = sync_placement()
            if not flush_checked(t2):
                continue
            ckpt.save(cur, fleet, sims)
            last_ckpt = cur
            if stop_after is not None and cur >= stop_after and cur < scenario.n_epochs:
                partial = True  # simulated kill right after the save
                break

    results = {
        p.name: _collect_phases(sim, scenario, 0)
        for p, sim in zip(sweep.points, sims)
    }
    return SweepResult(
        sweep=sweep, results=results, wall_s=_time.time() - t0,
        devices=fleet.num_shards, pipeline=pipeline and not supervisor.degraded,
        partial=partial, fallbacks=supervisor.fallbacks, restores=restores,
    )
