"""Declarative dynamic-colocation scenarios (paper §5, Figs. 7-9).

The paper's headline results come from *dynamic* workloads — tenants
arriving, departing and shifting working sets while competitors hold static
partitions or thrash. A :class:`Scenario` is a declarative script of timed
events that :func:`run_scenario` executes against any placement backend
driven by ``ColocationSim`` (MaxMem's ``CentralManager`` or any baseline
from ``core.baselines``), so all policies face byte-identical workload
timelines.

Event semantics (all events fire *before* the epoch they are stamped with,
in the order they appear in ``Scenario.events``):

  ``Arrive(epoch, spec)``       register + allocate a tenant (fast-first)
  ``Depart(epoch, name)``       free all pages + unregister the tenant
  ``ResizeWorkingSet(...)``     grow/shrink a skew set's page fraction
                                (paper Fig. 4 event 5 / Fig. 8 event 2)
  ``ShiftWorkingSet(...)``      re-scatter the skew sets onto fresh pages —
                                a phase change: the learned heat map is
                                instantly stale (TPP-style thrash)
  ``SkewChange(...)``           change a set's share of accesses (hotness
                                skew), page footprint unchanged
  ``Retarget(...)``             dynamic QoS t_miss update (paper §3.3)
  ``PingPongShift(...)``        toggle the working set between two fixed
                                scatters — the thrash schedule that makes
                                bounded migration bandwidth observable
  ``SetMigrationBandwidth(...)`` bound the backend's migration drain
                                (pages/epoch; None = unlimited); backends
                                without a data plane clamp their per-epoch
                                migration budget instead

Epoch boundaries at which any event fires split the timeline into *phases*;
:class:`ScenarioResult` aggregates per-tenant throughput/p99/FMMR per phase
(plus migration bytes and mean queue depth), which is exactly the shape of
the paper's Fig. 7-9 curves.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core.manager import CentralManager
from repro.core.simulator import OPTANE, ColocationSim, EpochRecord, WorkloadSpec


# ------------------------------------------------------------------ events
@dataclass(frozen=True)
class Arrive:
    epoch: int
    spec: WorkloadSpec

    def apply(self, sim: ColocationSim) -> None:
        sim.add_tenant(self.spec)

    def label(self) -> str:
        return f"+{self.spec.name}"


@dataclass(frozen=True)
class Depart:
    epoch: int
    name: str

    def apply(self, sim: ColocationSim) -> None:
        sim.remove_tenant(self.name)

    def label(self) -> str:
        return f"-{self.name}"


@dataclass(frozen=True)
class ResizeWorkingSet:
    epoch: int
    name: str
    set_index: int
    frac_pages: float

    def apply(self, sim: ColocationSim) -> None:
        sim.tenants[self.name].resize_set(self.set_index, self.frac_pages)

    def label(self) -> str:
        return f"{self.name}.set{self.set_index}~{self.frac_pages:g}p"


@dataclass(frozen=True)
class ShiftWorkingSet:
    epoch: int
    name: str

    def apply(self, sim: ColocationSim) -> None:
        sim.tenants[self.name].shift_sets()

    def label(self) -> str:
        return f"{self.name}.shift"


@dataclass(frozen=True)
class SkewChange:
    epoch: int
    name: str
    set_index: int
    frac_accesses: float

    def apply(self, sim: ColocationSim) -> None:
        sim.tenants[self.name].set_skew(self.set_index, self.frac_accesses)

    def label(self) -> str:
        return f"{self.name}.set{self.set_index}~{self.frac_accesses:g}a"


@dataclass(frozen=True)
class Retarget:
    epoch: int
    name: str
    t_miss: float

    def apply(self, sim: ColocationSim) -> None:
        sim.set_target(self.name, self.t_miss)

    def label(self) -> str:
        return f"{self.name}.t={self.t_miss:g}"


@dataclass(frozen=True)
class PingPongShift:
    epoch: int
    name: str

    def apply(self, sim: ColocationSim) -> None:
        sim.tenants[self.name].pingpong_shift()

    def label(self) -> str:
        return f"{self.name}.pingpong"


@dataclass(frozen=True)
class SetMigrationBandwidth:
    epoch: int
    pages_per_epoch: Optional[int]  # None = unlimited

    def apply(self, sim: ColocationSim) -> None:
        backend = sim.backend
        if hasattr(backend, "set_migration_bandwidth"):
            backend.set_migration_bandwidth(self.pages_per_epoch)
            return
        if not hasattr(backend, "migration_budget"):
            # hardware-managed placement (TwoLM): every access IS the
            # insertion path — there is no migration engine to throttle
            return
        # instant-apply baselines (HeMem, AutoNUMA): their per-epoch budget
        # IS the bandwidth. Stash the configured value on first clamp so a
        # later None event restores it rather than leaving the clamp behind.
        if not hasattr(backend, "_unclamped_migration_budget"):
            backend._unclamped_migration_budget = backend.migration_budget
        if self.pages_per_epoch is None:
            backend.migration_budget = backend._unclamped_migration_budget
        else:
            backend.migration_budget = int(self.pages_per_epoch)

    def label(self) -> str:
        bw = "inf" if self.pages_per_epoch is None else self.pages_per_epoch
        return f"bw={bw}"


ScenarioEvent = Union[Arrive, Depart, ResizeWorkingSet, ShiftWorkingSet,
                      SkewChange, Retarget, PingPongShift, SetMigrationBandwidth]


def pingpong_schedule(name: str, start: int, end: int, period: int) -> Tuple[PingPongShift, ...]:
    """A ping-pong thrash schedule: flip ``name``'s working set every
    ``period`` epochs in ``[start, end)`` — each flip returns the hot set to
    pages the policy may still be draining, so queued demotions keep
    re-heating (the thrashing-guard regime)."""
    assert period > 0
    return tuple(PingPongShift(e, name) for e in range(start, end, period))


# ---------------------------------------------------------------- scenario
@dataclass(frozen=True)
class Scenario:
    """A named, validated script of timed events over ``n_epochs``."""

    name: str
    n_epochs: int
    events: Tuple[ScenarioEvent, ...] = ()
    description: str = ""

    def __post_init__(self):
        assert self.n_epochs > 0, "scenario must run at least one epoch"
        for ev in self.events:
            assert 0 <= ev.epoch < self.n_epochs, (
                f"event {ev} outside [0, {self.n_epochs})"
            )

    def events_at(self, epoch: int) -> List[ScenarioEvent]:
        return [ev for ev in self.events if ev.epoch == epoch]

    def phase_boundaries(self) -> List[int]:
        """Sorted epoch indices that open a phase (0 plus event epochs)."""
        return sorted({0, *(ev.epoch for ev in self.events)})

    def phase_spans(self) -> List[Tuple[int, int, str]]:
        """(start, end, label) per phase; label names the opening events."""
        bounds = self.phase_boundaries() + [self.n_epochs]
        spans = []
        for start, end in zip(bounds[:-1], bounds[1:]):
            if start == end:
                continue
            evs = self.events_at(start)
            label = ",".join(ev.label() for ev in evs) if evs else "start"
            spans.append((start, end, label))
        return spans


# ------------------------------------------------------------------ result
@dataclass
class PhaseStats:
    """Per-phase aggregates (the paper-figure observables)."""

    label: str
    start: int
    end: int
    throughput: Dict[str, float]  # mean ops/s per tenant while present
    p99: Dict[str, float]  # mean p99 seconds per tenant
    fmmr: Dict[str, float]  # mean true FMMR per tenant
    agg_throughput: float  # mean over epochs of sum-over-tenants ops/s
    mean_p99: float  # mean over (epoch, tenant) p99 seconds
    migrated_pages: int
    migration_bytes: float = 0.0  # committed migration traffic in the phase
    mean_queue_depth: float = 0.0  # mean in-flight migrations per epoch
    max_queue_depth: int = 0

    def to_jsonable(self) -> dict:
        return {
            "label": self.label, "start": self.start, "end": self.end,
            "agg_throughput": self.agg_throughput,
            "mean_p99_us": self.mean_p99 * 1e6,
            "throughput": self.throughput,
            "p99_us": {k: v * 1e6 for k, v in self.p99.items()},
            "fmmr": self.fmmr,
            "migrated_pages": self.migrated_pages,
            "migration_bytes": self.migration_bytes,
            "mean_queue_depth": self.mean_queue_depth,
            "max_queue_depth": self.max_queue_depth,
        }


@dataclass
class ScenarioResult:
    scenario: Scenario
    history: List[EpochRecord]
    phases: List[PhaseStats] = field(default_factory=list)

    @property
    def steady_state(self) -> PhaseStats:
        """The final phase — the paper's end-of-run comparison window."""
        return self.phases[-1]

    def to_jsonable(self) -> dict:
        return {
            "scenario": self.scenario.name,
            "n_epochs": self.scenario.n_epochs,
            "phases": [p.to_jsonable() for p in self.phases],
        }


def _phase_stats(history: List[EpochRecord], start: int, end: int, label: str) -> PhaseStats:
    recs = history[start:end]
    names = sorted({nm for r in recs for nm in r.throughput})
    tput, p99, fmmr = {}, {}, {}
    for nm in names:
        ts = [r.throughput[nm] for r in recs if nm in r.throughput]
        tput[nm] = float(np.mean(ts))
        p99[nm] = float(np.mean([r.p99[nm] for r in recs if nm in r.p99]))
        fmmr[nm] = float(np.mean([r.fmmr_true[nm] for r in recs if nm in r.fmmr_true]))
    agg = float(np.mean([sum(r.throughput.values()) for r in recs])) if recs else 0.0
    all_p99 = [v for r in recs for v in r.p99.values()]
    depths = [r.queue_depth for r in recs]
    return PhaseStats(
        label=label, start=start, end=end,
        throughput=tput, p99=p99, fmmr=fmmr,
        agg_throughput=agg,
        mean_p99=float(np.mean(all_p99)) if all_p99 else 0.0,
        migrated_pages=int(sum(r.migrated_pages for r in recs)),
        migration_bytes=float(sum(r.migration_bytes for r in recs)),
        mean_queue_depth=float(np.mean(depths)) if depths else 0.0,
        max_queue_depth=int(max(depths, default=0)),
    )


# ---------------------------------------------------------------- executor
def _collect_phases(sim: ColocationSim, scenario: Scenario, base: int) -> ScenarioResult:
    history = sim.history[base : base + scenario.n_epochs]
    phases = [
        _phase_stats(history, start, end, label)
        for start, end, label in scenario.phase_spans()
    ]
    return ScenarioResult(scenario=scenario, history=history, phases=phases)


def run_scenario(
    sim: ColocationSim,
    scenario: Scenario,
    on_event: Optional[Callable] = None,
) -> ScenarioResult:
    """Execute ``scenario`` on ``sim`` (any backend) and aggregate phases.

    ``on_event(sim, event)`` is called after each event is applied — the
    differential test harness uses it to assert invariants at every
    perturbation point.
    """
    base = len(sim.history)
    by_epoch: Dict[int, List[ScenarioEvent]] = {}
    for ev in scenario.events:
        by_epoch.setdefault(base + ev.epoch, []).append(ev)

    def fire(s: ColocationSim, evs=None) -> None:
        for ev in evs:
            ev.apply(s)
            if on_event is not None:
                on_event(s, ev)

    events = {
        epoch: (lambda s, evs=evs: fire(s, evs)) for epoch, evs in by_epoch.items()
    }
    sim.run(scenario.n_epochs, events)
    return _collect_phases(sim, scenario, base)


# ------------------------------------------------------------------- sweep
@dataclass(frozen=True)
class SweepPoint:
    """One machine of a :class:`ScenarioSweep` — the per-machine knobs that
    vary across the batched grid. Every field maps onto a TRACED
    ``PolicyParams`` leaf (or the PRNG seed), so a whole grid shares one
    compiled fleet program; shape-defining knobs (page count, queue size,
    tenant-table size) live on the sweep itself because changing them
    forces a fresh trace (DESIGN.md §5)."""

    name: str
    seed: int = 0  # manager PRNG + simulator access-noise stream
    migration_budget: Optional[int] = None  # None = the sweep-wide default
    migration_bandwidth: Optional[int] = None  # needs queue_size > 0
    migration_latency: int = 0
    sample_period: Optional[int] = None


@dataclass(frozen=True)
class ScenarioSweep:
    """One event schedule, a batched grid of machine configurations.

    Every sweep point runs the SAME scenario (byte-identical event
    timeline) on its own logical machine; the fleet backend advances all
    of them in one vmapped device program per chunk
    (``core.fleet.FleetManager``)."""

    scenario: Scenario
    points: Tuple[SweepPoint, ...]

    def __post_init__(self):
        assert len(self.points) > 0, "sweep needs at least one point"
        names = [p.name for p in self.points]
        assert len(set(names)) == len(names), "sweep point names must be unique"


@dataclass
class SweepResult:
    sweep: ScenarioSweep
    results: Dict[str, ScenarioResult]  # per sweep-point name
    wall_s: float = 0.0
    devices: int = 1  # shards the machine axis ran over
    pipeline: bool = False  # double-buffered host/device driving was on

    def to_jsonable(self) -> dict:
        return {
            "scenario": self.sweep.scenario.name,
            "n_machines": len(self.sweep.points),
            "wall_s": round(self.wall_s, 3),
            "devices": self.devices,
            "pipeline": self.pipeline,
            "machines": {k: r.to_jsonable() for k, r in self.results.items()},
        }


def run_sweep(
    sweep: ScenarioSweep,
    *,
    num_pages: int,
    fast_capacity: int,
    migration_budget: int,
    max_tenants: int = 16,
    sample_period: int = 100,
    queue_size: int = 0,
    machine=None,
    epoch_seconds: float = 1.0,
    access_noise: bool = True,
    policy_chunk: int = 16,
    devices=None,
    pipeline: bool = True,
    trim_stats: bool = True,
) -> SweepResult:
    """Execute a :class:`ScenarioSweep` against the fleet backend.

    Builds one ``CentralManager`` per sweep point (identical shapes, the
    point's traced parameter overrides), wraps them in a
    ``core.fleet.FleetManager`` — sharded over ``devices`` (default: every
    visible XLA device) — and drives the shared event schedule: at every
    phase boundary the events fire on each machine's simulator
    (control-plane host operations — arrive/depart/resize work mid-sweep),
    and the epochs between boundaries run CHUNKED through the fleet.

    The chunk driving is a double-buffered pipeline (DESIGN.md §6): while
    chunk *k* executes on device, the host records chunk *k−1*'s telemetry
    (its end placement is chunk *k*'s entry placement, captured in ONE
    stacked transfer that also seeds every manager's snapshot cache) and
    the cost-model matrices for the next event-free stretch are reused
    across its chunks. The telemetry snapshot is fetched asynchronously and
    — with ``trim_stats`` — carries only the fields the record path reads.
    ``pipeline=False`` serializes prepare → execute → record per chunk (the
    pre-pipeline driver shape, used as the benchmark baseline leg); the
    recorded histories are IDENTICAL either way, because every record
    consumes the same placement and telemetry values in the same order.

    Chunk semantics match ``ColocationSim.run_chunk``: within a chunk the
    access distribution is frozen and migration stalls are not modeled;
    chunk boundaries (every event epoch, at least every ``policy_chunk``
    epochs) re-measure placement exactly.
    """
    import time as _time

    from repro.core.fleet import FleetManager

    t0 = _time.time()
    scenario = sweep.scenario
    managers = []
    for p in sweep.points:
        mgr_kw = dict(
            num_pages=num_pages, fast_capacity=fast_capacity,
            migration_budget=migration_budget if p.migration_budget is None
            else p.migration_budget,
            max_tenants=max_tenants,
            sample_period=sample_period if p.sample_period is None
            else p.sample_period,
            seed=p.seed, queue_size=queue_size,
            migration_latency=p.migration_latency,
        )
        if p.migration_bandwidth is not None:
            mgr_kw["migration_bandwidth"] = p.migration_bandwidth
        managers.append(CentralManager(**mgr_kw))
    fleet = FleetManager(managers, devices=devices)
    sims = [
        ColocationSim(
            mgr, machine or OPTANE, epoch_seconds=epoch_seconds,
            seed=p.seed, access_noise=access_noise,
        )
        for mgr, p in zip(managers, sweep.points)
    ]

    boundaries = sorted({0, *(ev.epoch for ev in scenario.events), scenario.n_epochs})
    pending = None  # (handle, k, ctxs) — the chunk currently on device
    arrays = None  # per-sim cost-model matrices, valid within an event-free stretch

    def flush(tiers: np.ndarray) -> None:
        """Record the in-flight chunk against its end placement."""
        nonlocal pending
        if pending is None:
            return
        handle, k, ctxs = pending
        res = handle.result()
        for i, (sim, ctx) in enumerate(zip(sims, ctxs)):
            sim._chunk_record(res.machine(i), k, ctx, tier_end=tiers[i])
        pending = None

    cur = 0
    while cur < scenario.n_epochs:
        evs = scenario.events_at(cur)
        if evs:
            # events read and mutate placement: the in-flight chunk must be
            # recorded against the PRE-event placement first
            tiers, _ = fleet.stacked_placement()
            flush(tiers)
            for ev in evs:
                for sim in sims:
                    ev.apply(sim)
            arrays = None  # tenant sets / probs may have changed
        horizon = min(b for b in boundaries if b > cur)
        k = min(policy_chunk, horizon - cur)
        # chunk-entry placement: one stacked transfer; blocks until the
        # previous chunk's device work is done (the pipeline sync point)
        tiers, _ = fleet.stacked_placement()
        if arrays is None:
            arrays = [sim._arrays() for sim in sims]
        preps = [
            sim._chunk_prepare(arrays=arr, tier=tiers[i])
            for i, (sim, arr) in enumerate(zip(sims, arrays))
        ]
        counts = np.stack([c for c, _ctx in preps])
        handle = fleet.run_epochs_async(k, counts=counts, trim_stats=trim_stats)
        # the previous chunk's end placement IS this chunk's entry: record
        # it now, overlapped with this chunk's device execution
        flush(tiers)
        pending = (handle, k, [ctx for _c, ctx in preps])
        if not pipeline:
            end_tiers, _ = fleet.stacked_placement()
            flush(end_tiers)
        cur += k

    tiers, _ = fleet.stacked_placement()
    flush(tiers)

    results = {
        p.name: _collect_phases(sim, scenario, 0)
        for p, sim in zip(sweep.points, sims)
    }
    return SweepResult(
        sweep=sweep, results=results, wall_s=_time.time() - t0,
        devices=fleet.num_shards, pipeline=pipeline,
    )
