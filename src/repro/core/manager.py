"""MaxMem central manager + tenant handles (paper §3.3 user-space design).

The manager owns all policy state (trust model: tenants cannot touch it) and
exposes the libMaxMem-analogue surface:

    mgr = CentralManager(num_pages=..., fast_capacity=..., ...)
    h = mgr.register(t_miss=0.1)          # process connects over the socket
    pages = mgr.allocate(h, n_pages)      # mmap/page-fault analogue
    mgr.record_access(counts)             # engine reports page accesses
    stats = mgr.run_epoch()               # policy thread tick
    res = mgr.run_epochs(k, counts)       # k ticks in ONE device dispatch
    mgr.set_target(h, 0.5)                # dynamic QoS update
    mgr.free(h, pages); mgr.unregister(h) # process exit

Allocation follows §3.1: fast first, slow if fast exhausted, error if both
exhausted. On tenant exit, memory returns to the free pool and is granted to
needers on the next epoch.

All hot-path state (pages, tenants, the un-sampled access backlog, the PRNG
key) lives on device in one ``PolicyState`` pytree: ``record_access`` folds
reports with a jitted add, ``run_epoch`` is one fused dispatch
(``policy.epoch_step``), and ``run_epochs`` scans k epochs in one dispatch
(``policy.multi_epoch``). Telemetry reads go through a cached host snapshot
so a burst of ``fast_pages_of``/``tier_of`` calls costs one transfer.
Control-plane operations (register/allocate/free) stay host-side — they are
rare and inherently serial.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy
from repro.core.dataplane import PagePool
from repro.core.types import (
    BANDWIDTH_UNLIMITED,
    TIER_FAST,
    TIER_NONE,
    TIER_SLOW,
    EpochStats,
    MigrationPlan,
    OwnerSegments,
    PageState,
    PolicyParams,
    PolicyState,
    TenantState,
    segments_build_host,
    segments_update_host,
)


class TenantHandle(int):
    """Opaque tenant slot id (the libMaxMem connection analogue)."""


@jax.jit
def _fold_counts(pending: jax.Array, counts: jax.Array) -> jax.Array:
    return pending + counts


@dataclasses.dataclass
class EpochResult:
    stats: EpochStats
    plan: Optional[MigrationPlan]
    flags: np.ndarray  # bool[T] tenants that could not be served

    def fmmr(self, h: int) -> float:
        return float(self.stats.fmmr_ewma[h])

    @property
    def migrated_pages(self) -> int:
        """Pages actually MOVED this epoch: queue drains in data-plane mode
        (selections may still be in flight), plan selections otherwise."""
        q = self.stats.queue
        if q is not None:
            return int(q.drained_promote) + int(q.drained_demote)
        return int(self.plan.num_promote) + int(self.plan.num_demote)

    @property
    def queue_depth(self) -> int:
        q = self.stats.queue
        return 0 if q is None else int(q.depth)

    @property
    def queue_flow(self) -> Tuple[int, int, int]:
        """(enqueued, drained, cancelled) this epoch — the storm-health
        observables (scenario ``ResponsivenessStats``); zeros without a
        queue."""
        q = self.stats.queue
        if q is None:
            return (0, 0, 0)
        return (
            int(q.enqueued),
            int(q.drained_promote) + int(q.drained_demote),
            int(q.cancelled),
        )


@dataclasses.dataclass
class MultiEpochResult:
    """Stacked output of ``run_epochs``: every array has a leading k axis."""

    stats: EpochStats  # [k, T] leaves
    plans: Optional[MigrationPlan]  # [k, R] leaves, None if not collected
    flags: np.ndarray  # bool[k, T]

    def __len__(self) -> int:
        return self.flags.shape[0]

    def unstack(self) -> List[EpochResult]:
        k = len(self)
        return [
            EpochResult(
                stats=jax.tree.map(lambda a: a[i], self.stats),
                plan=None if self.plans is None else jax.tree.map(lambda a: a[i], self.plans),
                flags=self.flags[i],
            )
            for i in range(k)
        ]

    @property
    def migrated_per_epoch(self) -> np.ndarray:
        """i64[k] pages MOVED each epoch: drained commits in data-plane
        mode, otherwise the selections from the exact stats telemetry."""
        q = self.stats.queue
        if q is not None:
            return np.asarray(q.drained_promote, np.int64) + np.asarray(
                q.drained_demote, np.int64
            )
        moved = np.asarray(self.stats.promoted) + np.asarray(self.stats.demoted)
        return moved.sum(axis=1)

    @property
    def queue_depth_per_epoch(self) -> np.ndarray:
        q = self.stats.queue
        if q is None:
            return np.zeros(len(self), np.int64)
        return np.asarray(q.depth, np.int64)

    @property
    def queue_flow_per_epoch(self) -> np.ndarray:
        """i64[k, 3] (enqueued, drained, cancelled) per epoch; zeros
        without a queue (storm-health telemetry, scenario
        ``ResponsivenessStats``)."""
        q = self.stats.queue
        if q is None:
            return np.zeros((len(self), 3), np.int64)
        return np.stack(
            [
                np.asarray(q.enqueued, np.int64),
                np.asarray(q.drained_promote, np.int64)
                + np.asarray(q.drained_demote, np.int64),
                np.asarray(q.cancelled, np.int64),
            ],
            axis=1,
        )


class CentralManager:
    def __init__(
        self,
        num_pages: int,
        fast_capacity: int,
        migration_budget: int,
        max_tenants: int = 16,
        num_bins: int = 6,
        sample_period: int = 100,
        ewma_lambda: float = 0.5,
        fair_mode: bool = False,
        hysteresis: float = 0.08,
        seed: int = 0,
        exact_sampling: bool = False,
        queue_size: int = 0,
        migration_bandwidth: Optional[int] = None,
        migration_latency: int = 0,
        data_plane_elems: Optional[int] = None,
        sentinel: bool = False,
        alloc_headroom: int = 0,
        promote_band: float = -1.0,
        demote_band: float = -1.0,
        promote_admission: Optional[int] = None,
        demote_cooldown: int = 0,
    ):
        """``queue_size > 0`` enables the asynchronous migration data plane
        (DESIGN.md §4): selections are queued and committed by a bounded
        per-epoch drain of ``migration_bandwidth`` pages (None = unlimited)
        after ``migration_latency`` epochs in flight. The default
        ``queue_size=0`` is the instant-apply engine, bit-identical to the
        pre-data-plane behavior. ``data_plane_elems`` additionally backs
        every page with ``data_plane_elems`` elements of real content in a
        :class:`~repro.core.dataplane.PagePool`; drained migrations then
        move actual bytes through the Pallas page-move kernel.
        ``sentinel=True`` turns on the in-trace invariant sentinel
        (DESIGN.md §7): each epoch's stats carry a violation bitmask
        (``EpochStats.sentinel``, core/faults.py SENTINEL_*). The flag is a
        traced parameter — toggling it via :meth:`set_sentinel` never
        retraces. ``alloc_headroom`` reserves that many fast pages the
        policy never promotes into, so first-touch allocations of new pages
        can land fast (TPP-style allocation reserve, DESIGN.md §8); also
        traced.

        Storm guards (DESIGN.md §11, all default-off and traced):
        ``promote_band``/``demote_band`` give the FMMR needer/donor
        triggers separate hysteresis (negative = inherit the symmetric
        ``hysteresis``); ``promote_admission`` caps new enqueues per
        direction per epoch, tightening under cancel pressure
        (None = unlimited);
        ``demote_cooldown`` bars a reheat-cancelled demotion's page from
        re-selection for that many epochs."""
        assert fast_capacity <= num_pages
        if migration_bandwidth is not None and queue_size == 0:
            raise ValueError(
                "finite migration_bandwidth requires the queue data plane: "
                "pass queue_size > 0"
            )
        if (promote_admission is not None or demote_cooldown) and queue_size == 0:
            raise ValueError(
                "promote_admission / demote_cooldown act on the migration "
                "queue: pass queue_size > 0"
            )
        self.num_pages = num_pages
        self.max_tenants = max_tenants
        # fleet dirty-tracking (core/fleet.py): the policy state lives behind
        # a property; any setter marks the machine mutated, and a fleet
        # dispatch parks the advanced slice as a lazy thunk so clean
        # machines never materialize (or re-upload) per-machine arrays
        self._state_val = None
        self._state_thunk = None
        self._mutated = True
        self.params = PolicyParams(
            fast_capacity=jnp.int32(fast_capacity),
            migration_budget=jnp.int32(migration_budget),
            num_bins=jnp.int32(num_bins),
            ewma_lambda=jnp.float32(ewma_lambda),
            sample_period=jnp.int32(sample_period),
            fair_mode=fair_mode,
            hysteresis=jnp.float32(hysteresis),
            migration_bandwidth=jnp.int32(
                BANDWIDTH_UNLIMITED if migration_bandwidth is None
                else migration_bandwidth
            ),
            migration_latency=jnp.int32(migration_latency),
            sentinel=jnp.int32(1 if sentinel else 0),
            alloc_headroom=jnp.int32(alloc_headroom),
            promote_band=jnp.float32(promote_band),
            demote_band=jnp.float32(demote_band),
            promote_admission=jnp.int32(
                -1 if promote_admission is None else promote_admission
            ),
            demote_cooldown=jnp.int32(demote_cooldown),
        )
        self.plan_size = int(migration_budget)
        self.queue_size = int(queue_size)
        self._state = PolicyState.create(
            num_pages, max_tenants, seed=seed, queue_size=queue_size
        )
        # owner-sorted permutation for the tick's segment reductions
        # (DESIGN.md §5); ownership only changes here in the control plane,
        # so allocate/free mark it stale and the next tick rebuilds it.
        # The rebuild is incremental when the churn since the last build is
        # known (DESIGN.md §10): host numpy mirrors of the current segs
        # (`_segs_host`), the owner array they were built from
        # (`_segs_built_owner`), and the changed page ids since
        # (`_segs_delta`; None = unknown -> full rebuild). `_segs_ref`
        # guards staleness by identity: checkpoint restores and fleet
        # parking swap `_state.segs` wholesale, which invalidates the
        # mirrors without going through these helpers.
        self._segs_owner: Optional[np.ndarray] = None
        self._segs_host = None
        self._segs_built_owner: Optional[np.ndarray] = None
        self._segs_delta: Optional[list] = None
        self._segs_ref = None
        self._refresh_segs(np.full((num_pages,), -1, np.int32))
        self._arrival_seq = 0
        self.exact_sampling = exact_sampling
        self.epoch_index = 0
        self._snap: Optional[Dict[str, np.ndarray]] = None
        # cumulative queue counters (conservation invariant, tests):
        # enqueued == drained + cancelled + dropped + queue_depth()
        self.queue_enqueued = 0
        self.queue_drained = 0
        self.queue_cancelled = 0
        self.queue_dropped = 0
        # pages whose DMA move was abandoned by the fault injector and whose
        # tier flip was reverted (commit-on-completion fallback)
        self.migration_failures = 0
        self.pool: Optional[PagePool] = None
        if data_plane_elems is not None:
            self.pool = PagePool(
                num_pages, fast_capacity, row_elems=data_plane_elems,
                plan_slots=max(2 * self.plan_size, 8),
            )

    # --------------------------------------------------------- state views
    @property
    def _state(self) -> PolicyState:
        if self._state_thunk is not None:
            self._state_val = self._state_thunk()
            self._state_thunk = None
        return self._state_val

    @_state.setter
    def _state(self, value: PolicyState) -> None:
        self._state_val = value
        self._state_thunk = None
        self._mutated = True

    def _set_fleet_state(self, thunk) -> None:
        """Park the machine's advanced state as a lazy slice of the fleet's
        stacked pytree (core/fleet.py). The slice only materializes if a
        control-plane or telemetry path actually reads it; until a setter
        fires, the fleet knows this machine's row in its cached stack is
        current and skips the restack entirely."""
        self._state_val = None
        self._state_thunk = thunk
        self._mutated = False

    @property
    def pages(self) -> PageState:
        return self._state.pages

    @pages.setter
    def pages(self, value: PageState) -> None:
        self._state = self._state._replace(pages=value)
        self._snap = None
        # state.segs must mirror pages.owner (DESIGN.md §5): any path that
        # can change ownership — allocate/free or a client assigning the
        # documented state view directly — marks the permutation stale here
        self._refresh_segs(np.asarray(value.owner))

    def _set_pages_churn(self, value: PageState, changed_ids) -> None:
        """Pages setter for allocate/free, which KNOW which page ids they
        mutated: the recorded delta lets ``_ensure_segs`` patch the
        owner-sorted permutation instead of re-sorting the pool."""
        self._state = self._state._replace(pages=value)
        self._snap = None
        self._refresh_segs(np.asarray(value.owner), changed=changed_ids)

    @property
    def tenants(self) -> TenantState:
        return self._state.tenants

    @tenants.setter
    def tenants(self, value: TenantState) -> None:
        self._state = self._state._replace(tenants=value)

    def _refresh_segs(self, owner: np.ndarray, changed=None) -> None:
        """Note an ownership change; the owner-sorted permutation is
        rebuilt lazily before the next policy tick (``_ensure_segs``), so a
        burst of control-plane operations (scenario arrivals allocating a
        dozen tenants) pays ONE host rebuild instead of one per call.

        ``changed`` names the page ids the caller mutated; the lazy rebuild
        can then PATCH the previous permutation (types.segments_update_host
        — a windowed splice, ~20x cheaper than the argsort for localized
        churn) instead of re-sorting from scratch. ``changed=None`` (a
        wholesale state assignment) invalidates the delta and forces the
        full rebuild."""
        self._segs_owner = np.asarray(owner)
        if changed is None:
            self._segs_delta = None
        elif self._segs_delta is not None:
            self._segs_delta.append(np.asarray(changed, np.int64))

    def _ensure_segs(self) -> None:
        if self._segs_owner is None:
            return
        cur = self._segs_owner
        T = self.max_tenants
        host = None
        segs = self._state.segs
        # the incremental path needs mirrors that describe the CURRENT segs:
        # `_segs_ref` identity breaks when a checkpoint restore or fleet
        # park replaced _state.segs behind our back
        if (
            self._segs_delta is not None
            and self._segs_host is not None
            and self._segs_built_owner is not None
            and segs is not None
            and segs.order is self._segs_ref
        ):
            if self._segs_delta:
                ids = np.unique(np.concatenate(self._segs_delta))
            else:
                ids = np.empty((0,), np.int64)
            ids = ids[self._segs_built_owner[ids] != cur[ids]]
            if ids.size == 0:
                host = self._segs_host
            else:
                host = segments_update_host(
                    *self._segs_host, self._segs_built_owner, cur, ids, T
                )
        if host is None:
            host = segments_build_host(cur, T)
        if host is not self._segs_host:
            order, inv, start = host
            self._state = self._state._replace(
                segs=OwnerSegments(
                    order=jnp.asarray(order),
                    inv=jnp.asarray(inv),
                    start=jnp.asarray(start),
                )
            )
        self._segs_host = host
        self._segs_built_owner = cur
        self._segs_ref = self._state.segs.order
        self._segs_delta = []
        self._segs_owner = None

    def _snapshot(self) -> Dict[str, np.ndarray]:
        """Host copy of the page metadata; ONE batched transfer per epoch no
        matter how many telemetry reads follow."""
        if self._snap is None:
            tier, owner = jax.device_get((self._state.pages.tier, self._state.pages.owner))
            self._snap = {"tier": tier, "owner": owner}
        return self._snap

    # ------------------------------------------------------------- tenants
    def register(self, t_miss: float) -> TenantHandle:
        assert 0.0 < t_miss <= 1.0, "t_miss must be in (0, 1] (§3.1)"
        active = np.asarray(self.tenants.active)
        free = np.flatnonzero(~active)
        if len(free) == 0:
            raise RuntimeError("tenant table full")
        slot = int(free[0])
        t = self.tenants
        self.tenants = t._replace(
            active=t.active.at[slot].set(True),
            t_miss=t.t_miss.at[slot].set(t_miss),
            a_miss=t.a_miss.at[slot].set(0.0),
            arrival=t.arrival.at[slot].set(self._arrival_seq),
            cool_epoch=t.cool_epoch.at[slot].set(0),
            flagged=t.flagged.at[slot].set(False),
        )
        self._arrival_seq += 1
        return TenantHandle(slot)

    def set_target(self, h: TenantHandle, t_miss: float) -> None:
        assert 0.0 < t_miss <= 1.0
        self.tenants = self.tenants._replace(
            t_miss=self.tenants.t_miss.at[int(h)].set(t_miss)
        )

    def unregister(self, h: TenantHandle) -> None:
        owned = np.flatnonzero(self._snapshot()["owner"] == int(h))
        if len(owned):
            self.free(h, owned)
        # scrub the whole slot (not just active=False): stale a_miss/t_miss
        # was observable via fmmr_of until the next epoch, and a reused
        # handle inherited the departed tenant's cool_epoch pairing
        self.tenants = self.tenants.clear_slot(int(h))

    # ------------------------------------------------------------- memory
    def allocate(self, h: TenantHandle, n_pages: int) -> np.ndarray:
        """First-touch allocation: fast while available, then slow (§3.1)."""
        snap = self._snapshot()
        tier = snap["tier"]
        owner = snap["owner"]
        unalloc = np.flatnonzero(tier == TIER_NONE)
        if len(unalloc) < n_pages:
            raise MemoryError(
                f"tenant {int(h)}: out of tiered memory "
                f"({n_pages} requested, {len(unalloc)} free)"
            )
        fast_used = int((tier == TIER_FAST).sum())
        fast_room = max(int(self.params.fast_capacity) - fast_used, 0)
        take = unalloc[:n_pages]
        n_fast = min(fast_room, n_pages)
        new_tier = tier.copy()
        new_owner = owner.copy()
        new_tier[take[:n_fast]] = TIER_FAST
        new_tier[take[n_fast:]] = TIER_SLOW
        new_owner[take] = int(h)
        self._set_pages_churn(
            self.pages._replace(tier=jnp.asarray(new_tier), owner=jnp.asarray(new_owner)),
            take,
        )
        if self.pool is not None:
            self.pool.on_allocate(take, new_tier[take])
        return take

    def free(self, h: TenantHandle, page_ids: Sequence[int]) -> None:
        ids = np.asarray(page_ids, np.int32)
        snap = self._snapshot()
        owner = snap["owner"]
        if not np.all(owner[ids] == int(h)):
            raise PermissionError("tenant freeing pages it does not own")
        tier = snap["tier"].copy()
        owner = owner.copy()
        tier[ids] = TIER_NONE
        owner[ids] = -1
        count = np.asarray(self.pages.count).copy()
        count[ids] = 0
        # reset the cooling stamp too: a freed slot must not leak the previous
        # owner's cool_epoch, or a tenant that reuses it would see its counts
        # spuriously halved (stale last_cool > 0 vs a fresh tenant's epoch 0
        # is no halving, but a RE-registered slot restarts cool_epoch at 0
        # while a stale stamp could be arbitrarily high — keep them paired).
        last_cool = np.asarray(self.pages.last_cool).copy()
        last_cool[ids] = 0
        self._set_pages_churn(
            self.pages._replace(
                tier=jnp.asarray(tier),
                owner=jnp.asarray(owner),
                count=jnp.asarray(count),
                last_cool=jnp.asarray(last_cool),
            ),
            ids,
        )
        pending = np.asarray(self._state.pending).copy()
        pending[ids] = 0
        self._state = self._state._replace(pending=jnp.asarray(pending))
        # scrub queued migrations of the freed pages NOW (not at the next
        # epoch's ownership guard): the slots may be re-allocated before the
        # next tick and a stale entry would then migrate the new owner's page
        queue = self._state.queue
        if queue is not None and queue.size:
            qp = np.asarray(queue.page)
            qd = np.asarray(queue.direction)
            stale = (qp >= 0) & np.isin(qp, ids)
            if stale.any():
                # only REAL migrations count as cancelled here: a stale
                # cooldown tombstone (direction 0) was already counted when
                # its demotion was cancelled, and is simply scrubbed
                self.queue_cancelled += int((stale & (qd != 0)).sum())
                qp = qp.copy()
                qp[stale] = -1
                qd = qd.copy()
                qd[stale] = 0
                self._state = self._state._replace(
                    queue=queue._replace(
                        page=jnp.asarray(qp), direction=jnp.asarray(qd)
                    )
                )
        if self.pool is not None:
            self.pool.on_free(ids)

    # ------------------------------------------------------------- accesses
    def record_access(self, counts: np.ndarray) -> None:
        """Engine-side access report: exact per-page access counts since the
        last call (the instrumented attention/GUPS stream). Folded into the
        on-device backlog with a jitted add — no host-side accumulator."""
        c = jnp.asarray(np.asarray(counts).astype(np.uint32, copy=False))
        self._state = self._state._replace(
            pending=_fold_counts(self._state.pending, c)
        )

    # ------------------------------------------------------------- epoch
    def _fold_queue_stats(self, q) -> None:
        self.queue_enqueued += int(np.asarray(q.enqueued).sum())
        self.queue_drained += int(
            np.asarray(q.drained_promote).sum() + np.asarray(q.drained_demote).sum()
        )
        self.queue_cancelled += int(np.asarray(q.cancelled).sum())
        self.queue_dropped += int(np.asarray(q.dropped).sum())

    def _pool_execute(self, dem_ids, pro_ids, failed_dem: set, failed_pro: set) -> None:
        """Run one drained batch through the pool, folding fault outcomes.

        Pages moved successfully drop out of the accumulated failed sets (a
        later retry superseded the earlier failure); freshly failed ids are
        added. With no injector attached this is exactly ``pool.execute``.
        """
        self.pool.execute(dem_ids, pro_ids)
        if self.pool.fault_injector is None:
            return
        fd, fp = self.pool.last_failed
        dem = np.asarray(dem_ids).ravel()
        pro = np.asarray(pro_ids).ravel()
        ok = set(dem[dem >= 0].tolist()) | set(pro[pro >= 0].tolist())
        ok -= set(fd.tolist()) | set(fp.tolist())
        failed_dem -= ok
        failed_pro -= ok
        failed_dem.update(fd.tolist())
        failed_pro.update(fp.tolist())

    def _revert_failed_moves(self, failed_dem: set, failed_pro: set) -> None:
        """Commit-on-completion fallback: a page whose DMA move was
        abandoned stays in its SOURCE tier — roll the policy's optimistic
        tier flip back so placements and frames never diverge. Degraded
        (the policy will re-select the page next epoch), never corrupt."""
        if not failed_dem and not failed_pro:
            return
        tier = np.asarray(self.pages.tier).copy()
        if failed_dem:
            tier[list(failed_dem)] = TIER_FAST
        if failed_pro:
            tier[list(failed_pro)] = TIER_SLOW
        # ownership is untouched, so the owner-sorted segments stay valid
        self._state = self._state._replace(
            pages=self.pages._replace(tier=jnp.asarray(tier))
        )
        self._snap = None
        self.migration_failures += len(failed_dem) + len(failed_pro)

    def run_epoch(self) -> EpochResult:
        """Policy-thread tick: sample -> policy -> migrate, one dispatch."""
        self._ensure_segs()
        self._state, plan, stats = policy.epoch_step(
            self._state,
            self.params,
            max_tenants=self.max_tenants,
            plan_size=self.plan_size,
            exact_sampling=self.exact_sampling,
        )
        self.epoch_index += 1
        self._snap = None
        fd, fp = set(), set()
        if stats.queue is not None:
            self._fold_queue_stats(stats.queue)
            if self.pool is not None:
                self._pool_execute(
                    np.asarray(stats.queue.drained_demote_ids),
                    np.asarray(stats.queue.drained_promote_ids),
                    fd, fp,
                )
        elif self.pool is not None:
            self._pool_execute(np.asarray(plan.demote), np.asarray(plan.promote), fd, fp)
        self._revert_failed_moves(fd, fp)
        return EpochResult(stats=stats, plan=plan, flags=np.asarray(self._state.tenants.flagged))

    def run_epochs(
        self,
        k: int,
        counts: Optional[np.ndarray] = None,
        collect_plans: bool = False,
    ) -> MultiEpochResult:
        """Run ``k`` policy epochs in ONE device dispatch (``lax.scan``).

        ``counts``: None (consume the recorded backlog, then idle), [P]
        (replayed every epoch — steady-state workload), or [k, P]. With the
        default ``collect_plans=False`` the per-epoch page-id lists are not
        materialized (the per-tenant promoted/demoted telemetry in ``stats``
        is still exact); pass True when a DMA driver needs the ids.
        """
        self._ensure_segs()
        c = None
        if counts is not None:
            c = jnp.asarray(np.asarray(counts).astype(np.uint32, copy=False))
        self._state, plans, stats, flagged = policy.multi_epoch(
            self._state,
            self.params,
            c,
            k=k,
            max_tenants=self.max_tenants,
            plan_size=self.plan_size,
            exact_sampling=self.exact_sampling,
            collect_plans=collect_plans or (self.pool is not None and not self.queue_size),
        )
        self.epoch_index += k
        self._snap = None
        # With faults injected, failed moves accumulate over the k-epoch host
        # loop and the tier flips are reverted ONCE at chunk end: the in-scan
        # trajectory is internally consistent (it committed optimistically),
        # and the chunk boundary is where placements and frames reconverge.
        fd, fp = set(), set()
        if stats.queue is not None:
            self._fold_queue_stats(stats.queue)
            if self.pool is not None:
                dem = np.asarray(stats.queue.drained_demote_ids)
                pro = np.asarray(stats.queue.drained_promote_ids)
                for i in range(k):
                    self._pool_execute(dem[i], pro[i], fd, fp)
        elif self.pool is not None:
            dem = np.asarray(plans.demote)
            pro = np.asarray(plans.promote)
            for i in range(k):
                self._pool_execute(dem[i], pro[i], fd, fp)
        self._revert_failed_moves(fd, fp)
        return MultiEpochResult(stats=stats, plans=plans, flags=np.asarray(flagged))

    # ------------------------------------------------------- data plane
    @property
    def migration_bounded(self) -> bool:
        """True when the data-plane queue actually paces migrations (a
        finite bandwidth is set). The simulator's DMA-stall model only
        applies to backends whose drain is NOT already paced."""
        return self.queue_size > 0 and int(self.params.migration_bandwidth) >= 0

    def set_migration_bandwidth(self, pages_per_epoch: Optional[int]) -> None:
        """Dynamically bound the migration drain (None = unlimited). The
        bandwidth is a traced policy parameter: no recompilation. An
        instant-apply manager (queue_size=0) has no drain to bound — a
        finite request there would be silently ignored while the same
        scenario event clamps the baselines, so it fails loudly instead."""
        if pages_per_epoch is not None and self.queue_size == 0:
            raise ValueError(
                "finite migration_bandwidth requires the queue data plane: "
                "construct CentralManager(queue_size > 0)"
            )
        self.params = self.params._replace(
            migration_bandwidth=jnp.int32(
                BANDWIDTH_UNLIMITED if pages_per_epoch is None else pages_per_epoch
            )
        )

    def set_migration_latency(self, epochs: int) -> None:
        self.params = self.params._replace(migration_latency=jnp.int32(epochs))

    # --------------------------------------------------- faults & sentinel
    def set_sentinel(self, on: bool) -> None:
        """Toggle the in-trace invariant sentinel (traced: no retrace)."""
        self.params = self.params._replace(sentinel=jnp.int32(1 if on else 0))

    def set_fault_injector(self, injector) -> None:
        """Attach a ``core.faults.FaultInjector`` to the page data plane
        (or detach with ``None``). Requires a pool — without real frames
        there is nothing whose move can fail."""
        if self.pool is None:
            raise ValueError(
                "data-plane fault injection requires a page pool: construct "
                "CentralManager(data_plane_elems=...)"
            )
        self.pool.set_fault_injector(injector)

    def poison_telemetry(self, kind: str = "tier") -> None:
        """Corrupt one cell of the policy state (the TelemetryCorrupt
        scenario event): ``"tier"`` unplaces the first owned page (its owner
        survives — an owned page with no tier), ``"nan"`` drops a NaN into
        an active tenant's FMMR EWMA. Both are exactly the corruptions the
        invariant sentinel exists to catch; tests assert it does."""
        snap = self._snapshot()
        if kind == "tier":
            owned = np.flatnonzero(snap["owner"] >= 0)
            if len(owned) == 0:
                raise RuntimeError("no owned pages to poison")
            tier = snap["tier"].copy()
            tier[owned[0]] = TIER_NONE
            self._state = self._state._replace(
                pages=self.pages._replace(tier=jnp.asarray(tier))
            )
            self._snap = None
        elif kind == "nan":
            act = np.flatnonzero(np.asarray(self.tenants.active))
            if len(act) == 0:
                raise RuntimeError("no active tenants to poison")
            self.tenants = self.tenants._replace(
                a_miss=self.tenants.a_miss.at[int(act[0])].set(jnp.nan)
            )
        else:
            raise ValueError(f"unknown poison kind: {kind!r}")

    def queue_depth(self) -> int:
        """In-flight migrations right now (0 when the queue is off).
        Counts REAL migrations only — cooldown tombstones (direction 0,
        ``demote_cooldown``) occupy slots without pending work and sit
        outside the conservation identity."""
        queue = self._state.queue
        if queue is None or not queue.size:
            return 0
        return int(
            ((np.asarray(queue.page) >= 0) & (np.asarray(queue.direction) != 0)).sum()
        )

    def queue_counters(self) -> Dict[str, int]:
        """Cumulative data-plane counters; conservation must always hold:
        enqueued == drained + cancelled + dropped + depth."""
        return {
            "enqueued": self.queue_enqueued,
            "drained": self.queue_drained,
            "cancelled": self.queue_cancelled,
            "dropped": self.queue_dropped,
            "depth": self.queue_depth(),
        }

    # ------------------------------------------------------------- telemetry
    def tiers(self) -> np.ndarray:
        """i8[P] tier of every page (cached host snapshot)."""
        return self._snapshot()["tier"]

    def owners(self) -> np.ndarray:
        """i32[P] owner of every page (cached host snapshot)."""
        return self._snapshot()["owner"]

    def fast_pages_of(self, h: TenantHandle) -> int:
        snap = self._snapshot()
        m = (snap["owner"] == int(h)) & (snap["tier"] == TIER_FAST)
        return int(m.sum())

    def tier_of(self, page_ids) -> np.ndarray:
        return self._snapshot()["tier"][np.asarray(page_ids)]

    def fmmr_of(self, h: TenantHandle) -> float:
        return float(self.tenants.a_miss[int(h)])
