"""MaxMem central manager + tenant handles (paper §3.3 user-space design).

The manager owns all policy state (trust model: tenants cannot touch it) and
exposes the libMaxMem-analogue surface:

    mgr = CentralManager(num_pages=..., fast_capacity=..., ...)
    h = mgr.register(t_miss=0.1)          # process connects over the socket
    pages = mgr.allocate(h, n_pages)      # mmap/page-fault analogue
    mgr.record_access(counts)             # engine reports page accesses
    stats = mgr.run_epoch()               # policy thread tick
    mgr.set_target(h, 0.5)                # dynamic QoS update
    mgr.free(h, pages); mgr.unregister(h) # process exit

Allocation follows §3.1: fast first, slow if fast exhausted, error if both
exhausted. On tenant exit, memory returns to the free pool and is granted to
needers on the next epoch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy
from repro.core.sampler import sample_accesses
from repro.core.types import (
    TIER_FAST,
    TIER_NONE,
    TIER_SLOW,
    EpochStats,
    MigrationPlan,
    PageState,
    PolicyParams,
    TenantState,
)


class TenantHandle(int):
    """Opaque tenant slot id (the libMaxMem connection analogue)."""


@dataclasses.dataclass
class EpochResult:
    stats: EpochStats
    plan: MigrationPlan
    flags: np.ndarray  # bool[T] tenants that could not be served

    def fmmr(self, h: int) -> float:
        return float(self.stats.fmmr_ewma[h])


class CentralManager:
    def __init__(
        self,
        num_pages: int,
        fast_capacity: int,
        migration_budget: int,
        max_tenants: int = 16,
        num_bins: int = 6,
        sample_period: int = 100,
        ewma_lambda: float = 0.5,
        fair_mode: bool = False,
        seed: int = 0,
        exact_sampling: bool = False,
    ):
        assert fast_capacity <= num_pages
        self.num_pages = num_pages
        self.max_tenants = max_tenants
        self.params = PolicyParams(
            fast_capacity=jnp.int32(fast_capacity),
            migration_budget=jnp.int32(migration_budget),
            num_bins=jnp.int32(num_bins),
            ewma_lambda=jnp.float32(ewma_lambda),
            sample_period=jnp.int32(sample_period),
            fair_mode=fair_mode,
        )
        self.plan_size = int(migration_budget)
        self.pages = PageState.create(num_pages)
        self.tenants = TenantState.create(max_tenants)
        self._arrival_seq = 0
        self._rng = jax.random.PRNGKey(seed)
        self._pending = np.zeros((num_pages,), np.int64)  # un-sampled accesses
        self.exact_sampling = exact_sampling
        self.epoch_index = 0

    # ------------------------------------------------------------- tenants
    def register(self, t_miss: float) -> TenantHandle:
        assert 0.0 < t_miss <= 1.0, "t_miss must be in (0, 1] (§3.1)"
        active = np.asarray(self.tenants.active)
        free = np.flatnonzero(~active)
        if len(free) == 0:
            raise RuntimeError("tenant table full")
        slot = int(free[0])
        t = self.tenants
        self.tenants = t._replace(
            active=t.active.at[slot].set(True),
            t_miss=t.t_miss.at[slot].set(t_miss),
            a_miss=t.a_miss.at[slot].set(0.0),
            arrival=t.arrival.at[slot].set(self._arrival_seq),
            cool_epoch=t.cool_epoch.at[slot].set(0),
            flagged=t.flagged.at[slot].set(False),
        )
        self._arrival_seq += 1
        return TenantHandle(slot)

    def set_target(self, h: TenantHandle, t_miss: float) -> None:
        assert 0.0 < t_miss <= 1.0
        self.tenants = self.tenants._replace(
            t_miss=self.tenants.t_miss.at[int(h)].set(t_miss)
        )

    def unregister(self, h: TenantHandle) -> None:
        owned = np.flatnonzero(np.asarray(self.pages.owner) == int(h))
        if len(owned):
            self.free(h, owned)
        t = self.tenants
        self.tenants = t._replace(active=t.active.at[int(h)].set(False))

    # ------------------------------------------------------------- memory
    def allocate(self, h: TenantHandle, n_pages: int) -> np.ndarray:
        """First-touch allocation: fast while available, then slow (§3.1)."""
        tier = np.asarray(self.pages.tier)
        owner = np.asarray(self.pages.owner)
        unalloc = np.flatnonzero(tier == TIER_NONE)
        if len(unalloc) < n_pages:
            raise MemoryError(
                f"tenant {int(h)}: out of tiered memory "
                f"({n_pages} requested, {len(unalloc)} free)"
            )
        fast_used = int((tier == TIER_FAST).sum())
        fast_room = max(int(self.params.fast_capacity) - fast_used, 0)
        take = unalloc[:n_pages]
        n_fast = min(fast_room, n_pages)
        new_tier = tier.copy()
        new_owner = owner.copy()
        new_tier[take[:n_fast]] = TIER_FAST
        new_tier[take[n_fast:]] = TIER_SLOW
        new_owner[take] = int(h)
        self.pages = self.pages._replace(
            tier=jnp.asarray(new_tier), owner=jnp.asarray(new_owner)
        )
        return take

    def free(self, h: TenantHandle, page_ids: Sequence[int]) -> None:
        ids = np.asarray(page_ids, np.int32)
        owner = np.asarray(self.pages.owner)
        if not np.all(owner[ids] == int(h)):
            raise PermissionError("tenant freeing pages it does not own")
        tier = np.asarray(self.pages.tier).copy()
        owner = owner.copy()
        tier[ids] = TIER_NONE
        owner[ids] = -1
        count = np.asarray(self.pages.count).copy()
        count[ids] = 0
        self.pages = self.pages._replace(
            tier=jnp.asarray(tier), owner=jnp.asarray(owner), count=jnp.asarray(count)
        )
        self._pending[ids] = 0

    # ------------------------------------------------------------- accesses
    def record_access(self, counts: np.ndarray) -> None:
        """Engine-side access report: exact per-page access counts since the
        last call (the instrumented attention/GUPS stream)."""
        self._pending += np.asarray(counts, np.int64)

    # ------------------------------------------------------------- epoch
    def run_epoch(self) -> EpochResult:
        """Policy-thread tick: sample -> policy -> migrate metadata."""
        self._rng, sub = jax.random.split(self._rng)
        sampled = sample_accesses(
            sub,
            jnp.asarray(self._pending, jnp.uint32),
            int(self.params.sample_period),
            exact=self.exact_sampling,
        )
        self._pending[:] = 0
        pages, tenants, plan, stats = policy.policy_epoch(
            self.pages,
            self.tenants,
            sampled,
            self.params,
            max_tenants=self.max_tenants,
            plan_size=self.plan_size,
        )
        pages = policy.apply_plan(pages, plan)
        self.pages, self.tenants = pages, tenants
        self.epoch_index += 1
        return EpochResult(stats=stats, plan=plan, flags=np.asarray(tenants.flagged))

    # ------------------------------------------------------------- telemetry
    def fast_pages_of(self, h: TenantHandle) -> int:
        m = (np.asarray(self.pages.owner) == int(h)) & (
            np.asarray(self.pages.tier) == TIER_FAST
        )
        return int(m.sum())

    def tier_of(self, page_ids) -> np.ndarray:
        return np.asarray(self.pages.tier)[np.asarray(page_ids)]

    def fmmr_of(self, h: TenantHandle) -> float:
        return float(self.tenants.a_miss[int(h)])
