"""Declarative fault injection + the invariant sentinel (DESIGN.md §7).

MaxMem's QoS claims only matter if the engine survives the regimes nobody
benchmarks: machines dropping mid-sweep, DMA moves failing, telemetry
corrupting in flight. This module is the host half of the fault-tolerance
layer:

  * :class:`FaultInjector` — seeded, probabilistic page-move failures for
    the pool-backed data plane (``PagePool``), with bounded retry and
    exponential backoff. A move that exhausts its retry budget is abandoned
    and the page stays in its source tier (commit-on-completion fallback:
    degraded, never corrupt — the manager reverts the metadata flip so
    placements and frames never diverge).
  * :func:`deep_validate` — the host-side deep validator behind the fused
    tick's cheap in-trace sentinel (``policy`` emits a per-epoch violation
    bitmask; this walks the full state when a bit fires or a test asks).
  * :class:`SentinelError` — raised on detection; ``scenario.run_sweep``
    catches it and restores from the last checkpoint.

The in-trace sentinel bits (``EpochStats.sentinel``):
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional

import numpy as np

from repro.core.types import TIER_FAST, TIER_NONE, TIER_SLOW

# Violation bitmask emitted by the fused tick (policy._sentinel_bits) and by
# the host validator below. 0 == green.
SENTINEL_OCCUPANCY = 1  # fast-tier occupancy exceeds fast_capacity
SENTINEL_QUEUE = 2  # queue flow: depth' != depth + enq - drain - cancel - drop
SENTINEL_OWNERSHIP = 4  # owned <-> placed mismatch (owner without tier or v.v.)
SENTINEL_ORPHAN = 8  # page owned by an inactive tenant slot
SENTINEL_NAN = 16  # non-finite FMMR EWMA


class SentinelError(RuntimeError):
    """An invariant the engine promises unconditionally was violated."""


@dataclasses.dataclass
class FaultInjector:
    """Seeded probabilistic failures for ``PagePool`` page moves.

    Each page move draws from a private PRNG stream: with probability
    ``move_fail_rate`` the attempt fails and is retried after an
    exponentially growing backoff (``backoff_base_s * 2**attempt``), up to
    ``max_retries`` retries. ``sleep`` is injectable for tests (default
    ``None`` records the backoff without sleeping — simulated faults must
    not slow the suite down).

    The counters are cumulative telemetry: ``attempts`` counts every draw,
    ``failures`` every failed draw, ``retries`` every backoff taken,
    ``gave_up`` moves abandoned after the retry budget, ``no_frame``
    promotions refused because a failed demotion left no free fast frame.
    """

    move_fail_rate: float = 0.0
    max_retries: int = 3
    backoff_base_s: float = 1e-3
    seed: int = 0
    sleep: Optional[Callable[[float], None]] = None
    attempts: int = 0
    failures: int = 0
    retries: int = 0
    gave_up: int = 0
    no_frame: int = 0
    backoff_total_s: float = 0.0

    def __post_init__(self):
        if not (0.0 <= float(self.move_fail_rate) <= 1.0) or math.isnan(
            float(self.move_fail_rate)
        ):
            raise ValueError(
                f"move_fail_rate must be in [0, 1], got {self.move_fail_rate}"
            )
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if not (self.backoff_base_s >= 0.0):
            raise ValueError("backoff_base_s must be >= 0")
        self._rng = np.random.default_rng(self.seed)

    def attempt_move(self) -> bool:
        """One page move through the retry loop: True = committed."""
        for attempt in range(self.max_retries + 1):
            self.attempts += 1
            if self._rng.random() >= self.move_fail_rate:
                return True
            self.failures += 1
            if attempt < self.max_retries:
                self.retries += 1
                delay = self.backoff_base_s * (2.0 ** attempt)
                self.backoff_total_s += delay
                if self.sleep is not None:
                    self.sleep(delay)
        self.gave_up += 1
        return False

    def counters(self) -> dict:
        return {
            "attempts": self.attempts,
            "failures": self.failures,
            "retries": self.retries,
            "gave_up": self.gave_up,
            "no_frame": self.no_frame,
            "backoff_total_s": self.backoff_total_s,
        }


def validate_state(
    tier: np.ndarray,
    owner: np.ndarray,
    fast_capacity: int,
    max_tenants: int,
    active: Optional[np.ndarray] = None,
    a_miss: Optional[np.ndarray] = None,
    queue_counters: Optional[dict] = None,
) -> List[str]:
    """Pure-array invariant checks shared by :func:`deep_validate` and the
    tests; returns human-readable violation strings (empty == green)."""
    tier = np.asarray(tier)
    owner = np.asarray(owner)
    out: List[str] = []
    if not np.isin(tier, (TIER_NONE, TIER_SLOW, TIER_FAST)).all():
        out.append("tier outside {-1, 0, 1}")
    owned = owner >= 0
    placed = tier != TIER_NONE
    if (owned != placed).any():
        n = int((owned != placed).sum())
        out.append(f"{n} pages with owner<->placement mismatch")
    if (owner >= max_tenants).any() or (owner < -1).any():
        out.append("owner outside [-1, max_tenants)")
    fast_occ = int((tier == TIER_FAST).sum())
    if fast_occ > int(fast_capacity):
        out.append(f"fast occupancy {fast_occ} > capacity {int(fast_capacity)}")
    if active is not None:
        act = np.asarray(active)
        orphan = owned & ~act[np.clip(owner, 0, max_tenants - 1)]
        if orphan.any():
            out.append(f"{int(orphan.sum())} pages owned by inactive tenants")
    if a_miss is not None and not np.isfinite(np.asarray(a_miss)).all():
        out.append("non-finite FMMR EWMA")
    if queue_counters is not None:
        q = queue_counters
        lhs = q["enqueued"]
        rhs = q["drained"] + q["cancelled"] + q["dropped"] + q["depth"]
        if lhs != rhs:
            out.append(f"queue conservation: enqueued {lhs} != {rhs}")
    return out


def deep_validate(manager, raise_on_violation: bool = True) -> List[str]:
    """Host-side deep validator for a ``CentralManager``-shaped backend.

    Walks the full placement/tenant/queue/segment/pool state — the slow,
    exhaustive counterpart of the in-trace sentinel bitmask. Returns the
    violation list; with ``raise_on_violation`` (default) a non-empty list
    raises :class:`SentinelError` instead.
    """
    tier = np.asarray(manager.tiers())
    owner = np.asarray(manager.owners())
    active = np.asarray(manager.tenants.active)
    a_miss = np.asarray(manager.tenants.a_miss)
    qc = manager.queue_counters() if hasattr(manager, "queue_counters") else None
    out = validate_state(
        tier, owner, int(manager.params.fast_capacity), manager.max_tenants,
        active=active, a_miss=a_miss, queue_counters=qc,
    )
    # owner segments must mirror the owner array (DESIGN.md §5)
    segs = getattr(manager._state, "segs", None)
    if segs is not None and manager._segs_owner is None:
        from repro.core.types import OwnerSegments

        want = OwnerSegments.build(owner, manager.max_tenants)
        if not (
            np.array_equal(np.asarray(segs.order), np.asarray(want.order))
            and np.array_equal(np.asarray(segs.start), np.asarray(want.start))
        ):
            out.append("owner segments stale vs owner array")
    pool = getattr(manager, "pool", None)
    if pool is not None:
        try:
            pool.check(tier)
        except AssertionError as e:
            out.append(f"data plane: {e}")
    if out and raise_on_violation:
        raise SentinelError("; ".join(out))
    return out
