"""MaxMem core state pytrees.

All policy state lives in fixed-size jnp arrays so the per-epoch policy step
is one jittable pure function (`repro.core.policy.policy_epoch`). Tenants are
slots in [0, max_tenants); pages are slots in a global pool [0, num_pages).

Tier encoding per page: -1 unallocated, 0 slow, 1 fast.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

TIER_NONE = -1
TIER_SLOW = 0
TIER_FAST = 1

# Migration-queue entry directions (core/policy.py data plane).
DIR_NONE = 0
DIR_PROMOTE = 1
DIR_DEMOTE = -1

# PolicyParams.migration_bandwidth sentinel: drain the whole queue per epoch.
BANDWIDTH_UNLIMITED = -1

# Widest tenant slot index an int16 ``PageState.owner`` can carry (packed
# state layouts, DESIGN.md §10). Enforced at state-construction time; every
# compute site that does slot *arithmetic* (e.g. ``owner * C + key`` flat
# histogram keys) upcasts to int32 first, so the narrow width is purely a
# storage/bandwidth contract.
MAX_TENANT_SLOTS = 32767


class PolicyParams(NamedTuple):
    """Knobs of the paper's policy (§3.1/§3.2) in page units."""

    fast_capacity: jnp.int32  # F: fast-tier page slots
    migration_budget: jnp.int32  # R: total pages migrated per epoch (paper: 4 GB)
    num_bins: jnp.int32 = 6  # hotness bins (paper: 6)
    ewma_lambda: jnp.float32 = 0.5  # FMMR EWMA (paper: 0.5)
    sample_period: jnp.int32 = 100  # PEBS-analogue: 1-in-100 accesses
    fair_mode: bool = False  # False = paper FCFS; True = equal-distance fairness
    # Stability addition (beyond paper; see EXPERIMENTS §Perf notes): tenants
    # within +-hysteresis of target are neither needers nor donors. Without
    # it, near-saturated mixes oscillate: serving one needer flips marginal
    # donors over target and starvation rotates tenant-to-tenant.
    hysteresis: jnp.float32 = 0.08
    # Migration data plane (DESIGN.md §4). Only consulted when the state
    # carries a non-empty MigrationQueue; with queue_size=0 the policy
    # applies migrations instantly (the pre-data-plane behavior).
    # bandwidth: pages the DMA engine can commit per epoch
    # (BANDWIDTH_UNLIMITED = drain everything — degenerates to instant).
    migration_bandwidth: jnp.int32 = BANDWIDTH_UNLIMITED
    # latency: epochs an entry waits in the queue before it may commit.
    migration_latency: jnp.int32 = 0
    # Invariant sentinel (DESIGN.md §7): when > 0 the fused tick emits a
    # violation bitmask (core/faults.py SENTINEL_*) in EpochStats.sentinel.
    # Traced, so flipping it never retraces; compiling the checks out
    # entirely is the static ``compile_sentinel`` knob on the entry points.
    sentinel: jnp.int32 = 0
    # Allocation headroom (DESIGN.md §8, TPP-style): fast pages the policy
    # leaves unfilled so first-touch allocations of NEW pages can land fast
    # instead of waiting an epoch for promotion. The policy treats
    # ``fast_capacity - alloc_headroom`` as its promotion ceiling; the
    # allocator still fills to ``fast_capacity``, and request churn
    # (free -> allocate) keeps regenerating the reserve. Traced: the
    # serving benchmark legs flip it without retracing.
    alloc_headroom: jnp.int32 = 0
    # Adversarial-dynamics guards (DESIGN.md §11) — every knob defaults OFF
    # and is traced, so guarded and unguarded runs share one compiled
    # program and the default program is bit-identical to the pre-guard
    # engine.
    # Asymmetric FMMR hysteresis: separate trigger bands for needers
    # (promotion pressure) and donors (demotion pressure). A tenant only
    # becomes a needer above ``t * (1 + promote_band)`` and a donor below
    # ``t * (1 - demote_band)``. Negative = inherit the symmetric
    # ``hysteresis`` band.
    promote_band: jnp.float32 = -1.0
    demote_band: jnp.float32 = -1.0
    # Promotion admission control: cap on NEW promotion enqueues per queue
    # tick. The effective cap tightens (halves, then quarters) as the
    # tick's cancel count rises against the pre-tick queue depth — graceful
    # degradation under promotion/demotion storms instead of queue
    # livelock. Negative = unlimited (bit-identical to no admission).
    promote_admission: jnp.int32 = -1
    # Queue-aware victim cooldown: epochs a reheat-cancelled demotion's
    # page stays barred from re-selection. The cancelled entry leaves a
    # tombstone (direction DIR_NONE) in the queue, which keeps the page in
    # the in-flight exclusion mask until the tombstone expires — breaking
    # the select -> cancel -> re-select ping-pong that burns enqueue
    # bandwidth. 0 = off (cancelled entries vacate immediately).
    demote_cooldown: jnp.int32 = 0

    @classmethod
    def from_profile(cls, name: str, **overrides) -> "PolicyParams":
        """Load a committed tuned profile from ``repro.configs.tuned``.

        Profiles are the autotuner's committed winners (one JSON per
        scenario family × geometry, e.g. ``"thrash_4k"``; see DESIGN.md §9
        and docs/PARAMS.md). Returns a fully-populated ``PolicyParams``
        with every leaf cast to its traced dtype; keyword ``overrides``
        replace individual fields (e.g. a different ``fast_capacity`` when
        replaying a profile on a machine with another tier geometry).
        """
        # lazy import: configs.tuned needs PolicyParams itself
        from repro.configs.tuned import params_from_profile

        return params_from_profile(name, **overrides)


class TenantState(NamedTuple):
    """Per-tenant QoS state. Arrays of length max_tenants."""

    active: jax.Array  # bool[T]
    t_miss: jax.Array  # f32[T] target FMMR in (0, 1]
    a_miss: jax.Array  # f32[T] EWMA of achieved FMMR
    arrival: jax.Array  # i32[T] arrival order (FCFS tie-break); lower = earlier
    cool_epoch: jax.Array  # i32[T] per-tenant cooling counter (lazy cooling)
    flagged: jax.Array  # bool[T] cannot meet target (admin signal, §3.1)

    @classmethod
    def create(cls, max_tenants: int) -> "TenantState":
        T = max_tenants
        return cls(
            active=jnp.zeros((T,), bool),
            t_miss=jnp.ones((T,), jnp.float32),
            a_miss=jnp.zeros((T,), jnp.float32),
            arrival=jnp.full((T,), jnp.iinfo(jnp.int32).max, jnp.int32),
            cool_epoch=jnp.zeros((T,), jnp.int32),
            flagged=jnp.zeros((T,), bool),
        )

    def clear_slot(self, slot: int) -> "TenantState":
        """Reset one slot to its creation defaults. Departure must scrub the
        whole slot: a merely-deactivated slot leaks its EWMA/target through
        ``fmmr_of`` until the next epoch zeroes it, and scenario-driven churn
        reuses slots within the same epoch."""
        return self._replace(
            active=self.active.at[slot].set(False),
            t_miss=self.t_miss.at[slot].set(1.0),
            a_miss=self.a_miss.at[slot].set(0.0),
            arrival=self.arrival.at[slot].set(jnp.iinfo(jnp.int32).max),
            cool_epoch=self.cool_epoch.at[slot].set(0),
            flagged=self.flagged.at[slot].set(False),
        )


class PageState(NamedTuple):
    """Per-page metadata. Arrays of length num_pages.

    Dtype-width audit (packed state layouts, DESIGN.md §10) — the [P]
    leaves dominate state bytes, upload cost, and the memory-bound passes
    of the fused tick, so each field carries the narrowest width its value
    range admits:

    * ``owner`` i16: tenant slots are bounded by :data:`MAX_TENANT_SLOTS`
      (asserted at construction). Index gathers take any int width; the
      flat-key arithmetic sites upcast to i32 locally.
    * ``tier`` i8: three-valued.
    * ``count`` u32 — NOT narrowable: counts accumulate raw sampled
      accesses between cooling events, and cooling only halves a tenant's
      pages when one of them crosses ``2^(num_bins-1)`` *via a touch* —
      exact-sampling replays fold entire backlogs in at once, so a single
      epoch can legitimately add far more than 2^16 to one page.
    * ``last_cool`` i32 — pairs with ``TenantState.cool_epoch`` (i32,
      monotone over the run); a narrower stamp would wrap on long sweeps
      and silently un-cool a stale page.
    """

    owner: jax.Array  # i16[P] tenant slot, -1 if unallocated
    tier: jax.Array  # i8[P]
    count: jax.Array  # u32[P] accumulated (lazily cooled) sample count
    last_cool: jax.Array  # i32[P] owner cool_epoch at last count update

    @classmethod
    def create(cls, num_pages: int) -> "PageState":
        P = num_pages
        return cls(
            owner=jnp.full((P,), -1, jnp.int16),
            tier=jnp.full((P,), TIER_NONE, jnp.int8),
            count=jnp.zeros((P,), jnp.uint32),
            last_cool=jnp.zeros((P,), jnp.int32),
        )


class OwnerSegments(NamedTuple):
    """Host-maintained owner-sorted page permutation (DESIGN.md §5).

    Page ownership only changes on control-plane operations (allocate /
    free), so the manager keeps a permutation of page ids sorted by
    (owner, page id) — stable, unowned pages last — and rebuilds it there.
    Inside the fused tick every per-tenant reduction then becomes a gather
    into owner-sorted order plus ONE global cumsum with per-segment offset
    subtraction: O(P) gathers/cumsums (cheap, batchable over a fleet axis)
    instead of [T, P] one-hot passes and P-element scatters (the two op
    classes XLA:CPU executes serially). Results are bit-identical — the
    within-tenant order is page-id ascending, exactly the tie-break order
    the one-hot path reduces in.
    """

    order: jax.Array  # i32[P] page ids sorted by (owner, id); unowned last
    inv: jax.Array  # i32[P] inverse permutation: inv[order[i]] = i
    start: jax.Array  # i32[T+1] first sorted index per tenant; start[T] = #owned

    @classmethod
    def build(cls, owner, max_tenants: int) -> "OwnerSegments":
        """Host-side rebuild from an owner array (numpy or device)."""
        import numpy as np

        order, inv, start = segments_build_host(np.asarray(owner), max_tenants)
        return cls(
            order=jnp.asarray(order), inv=jnp.asarray(inv), start=jnp.asarray(start)
        )


def segments_build_host(owner, max_tenants: int):
    """From-scratch ``(order, inv, start)`` host arrays for an owner array
    — ONE stable argsort; the reference the incremental patcher must match
    bit-for-bit."""
    import numpy as np

    own = np.asarray(owner)
    key = np.where(own >= 0, own, max_tenants)
    order = np.argsort(key, kind="stable").astype(np.int32)
    inv = np.empty_like(order)
    inv[order] = np.arange(order.shape[0], dtype=np.int32)
    counts = np.bincount(key, minlength=max_tenants + 1)
    start = np.zeros((max_tenants + 1,), np.int32)
    np.cumsum(counts[:max_tenants], out=start[1:])
    return order, inv, start


def segments_update_host(order, inv, start, prev_owner, new_owner, changed, max_tenants):
    """Patch ``(order, inv, start)`` for the pages in ``changed`` whose
    owner moved from ``prev_owner`` to ``new_owner`` — the incremental
    alternative to :func:`segments_build_host` the manager uses on
    register/allocate/free/unregister churn (DESIGN.md §10).

    The permutation is uniquely determined by the stable (key, id) sort
    order and ids are unique, so delete-then-merge reproduces the full
    rebuild BIT-IDENTICALLY: changed entries are deleted from their old
    sorted positions (known in O(1) each via ``inv``), re-keyed, sorted
    among themselves (d log d for d changes), and merged back at positions
    found by binary search on the composite (key, id) rank. Sequential
    O(P) memmoves + O(d log P) searches replace the full O(P log P)
    random-access argsort.

    ``changed`` must contain each mutated page id exactly once with
    ``prev_owner[p] != new_owner[p]``; ``inv``/``order``/``start`` must
    describe ``prev_owner``.
    """
    import numpy as np

    P = order.shape[0]
    T = max_tenants
    changed = np.asarray(changed, np.int64)
    old_k = np.where(prev_owner[changed] >= 0, prev_owner[changed], T).astype(np.int64)
    new_k = np.where(new_owner[changed] >= 0, new_owner[changed], T).astype(np.int64)

    # Every changed page is removed once and inserted once, and both its
    # segments lie inside [first affected segment, last affected segment] —
    # so sorted positions OUTSIDE that segment-aligned window carry zero net
    # shift and the splice (delete + merge + inverse-permutation scatter)
    # only has to touch the window. bounds[t] is the first sorted index of
    # segment t (t == T is the unowned tail), bounds[T+1] == P.
    bounds = np.concatenate([start.astype(np.int64), [np.int64(P)]])
    k_lo = int(min(old_k.min(), new_k.min()))
    k_hi = int(max(old_k.max(), new_k.max()))
    lo = int(bounds[k_lo])
    hi = int(bounds[k_hi + 1])

    win = order[lo:hi]
    rm_local = np.sort(inv[changed]) - lo
    kept_win = np.delete(win, rm_local)
    # kept segment starts, window-relative: old starts shifted left by the
    # removals in earlier window segments
    rem_counts = np.bincount(old_k - k_lo, minlength=k_hi - k_lo + 1)
    wb = bounds[k_lo : k_hi + 2] - lo
    kept_wb = wb - np.concatenate([[0], np.cumsum(rem_counts)])

    # Merge positions WITHOUT materializing an O(P) composite key: within a
    # segment `kept_win` is id-ascending, so group the (re-keyed, id-sorted)
    # changed entries by destination segment — at most min(d, T+1) groups —
    # and binary-search each group's ids inside that one segment slice.
    ins_sort = np.argsort(new_k * np.int64(P) + changed, kind="stable")
    changed_sorted = changed[ins_sort].astype(np.int32)
    keys_sorted = new_k[ins_sort]
    pos = np.empty(changed_sorted.shape[0], np.int64)
    seg_ids, run_starts = np.unique(keys_sorted, return_index=True)
    run_ends = np.append(run_starts[1:], keys_sorted.shape[0])
    for k, rlo, rhi in zip(seg_ids, run_starts, run_ends):
        kw = int(k) - k_lo
        seg = kept_win[kept_wb[kw] : kept_wb[kw + 1]]
        pos[rlo:rhi] = kept_wb[kw] + np.searchsorted(seg, changed_sorted[rlo:rhi])
    new_win = np.insert(kept_win, pos, changed_sorted)

    new_order = order.copy()
    new_order[lo:hi] = new_win
    new_inv = inv.copy()
    new_inv[new_win] = np.arange(lo, hi, dtype=np.int32)

    counts = np.concatenate([np.diff(start), [np.int32(P) - start[T]]]).astype(np.int64)
    np.add.at(counts, new_k, 1)
    np.add.at(counts, old_k, -1)
    new_start = np.zeros((T + 1,), np.int32)
    new_start[1:] = np.cumsum(counts[:T]).astype(np.int32)
    return new_order, new_inv, new_start


class MigrationQueue(NamedTuple):
    """Fixed-shape in-flight migration queue (DESIGN.md §4).

    Array order IS FIFO order (the per-epoch tick compacts valid entries to
    the front). ``page == -1`` marks an empty slot. Tier metadata does not
    change at enqueue: a queued page keeps serving from its source tier
    until the bounded-bandwidth drain commits the entry
    (commit-on-completion, like the paper's asynchronous DMA migrations).
    """

    page: jax.Array  # i32[Q] page id, -1 = empty slot (pools exceed 2^15 pages)
    direction: jax.Array  # i8[Q] DIR_PROMOTE / DIR_DEMOTE / DIR_NONE
    enqueue_epoch: jax.Array  # i32[Q] epoch the entry was admitted
    complete_epoch: jax.Array  # i32[Q] first epoch the entry may commit
    # Heat bins are ``bin_of`` values, bounded by num_bins - 1 <= 31 (bins
    # derive from u32 counts), so one byte holds the thrashing-guard
    # snapshot; epochs stay i32 (monotone queue clock, wraps on long runs
    # otherwise).
    heat: jax.Array  # i8[Q] hotness bin at enqueue (thrashing guard)

    @classmethod
    def create(cls, size: int) -> "MigrationQueue":
        return cls(
            page=jnp.full((size,), -1, jnp.int32),
            direction=jnp.zeros((size,), jnp.int8),
            enqueue_epoch=jnp.zeros((size,), jnp.int32),
            complete_epoch=jnp.zeros((size,), jnp.int32),
            heat=jnp.zeros((size,), jnp.int8),
        )

    @property
    def size(self) -> int:
        return self.page.shape[0]

    @property
    def depth(self) -> jax.Array:
        return (self.page >= 0).sum()


class QueueStats(NamedTuple):
    """Per-epoch migration-queue telemetry (scalars + fixed-size id lists).

    Conservation contract (tested after every event and epoch):
    cumulative enqueued == drained + cancelled + dropped + current depth.
    The drained id lists are sized [W] (W = queue capacity + both plan
    sides), padded with -1 — fixed-size plans the pool-backed data plane
    feeds straight to the Pallas page-move kernel.
    """

    depth: jax.Array  # i32[] in-flight entries after the tick
    enqueued: jax.Array  # i32[] new entries admitted this epoch
    drained_promote: jax.Array  # i32[] promotions committed this epoch
    drained_demote: jax.Array  # i32[] demotions committed this epoch
    cancelled: jax.Array  # i32[] thrash/ownership cancellations this epoch
    dropped: jax.Array  # i32[] overflow drops (queue full) this epoch
    drained_promote_ids: jax.Array  # i32[W] committed promote ids, -1 pad
    drained_demote_ids: jax.Array  # i32[W] committed demote ids, -1 pad


class PolicyState(NamedTuple):
    """The complete on-device policy-engine state threaded through epochs.

    Bundling pages + tenants + the un-sampled access backlog + the PRNG key
    into one pytree lets ``policy.epoch_step`` / ``policy.multi_epoch`` run
    the whole tick (sample -> bin -> FMMR -> realloc -> rebalance -> apply)
    as a single dispatch with donated buffers — no host round-trips.

    ``queue``/``epoch`` carry the asynchronous migration data plane: with a
    zero-capacity queue (the default) the tick applies migrations instantly
    and is bit-identical to the pre-data-plane engine; with ``queue_size >
    0`` selections are enqueued and committed by the bounded-bandwidth
    drain (DESIGN.md §4).
    """

    pages: "PageState"
    tenants: "TenantState"
    pending: jax.Array  # u32[P] accesses reported since the last epoch
    rng: jax.Array  # PRNG key for the PEBS-analogue subsampling
    queue: Optional["MigrationQueue"] = None  # None == zero-capacity queue
    epoch: Optional[jax.Array] = None  # i32[] epoch counter (queue clock)
    # Owner-sorted page permutation (None = derive reductions from a [T, P]
    # one-hot instead — the legacy path; states built by the manager carry
    # segments and take the cheaper gather/cumsum path, DESIGN.md §5).
    segs: Optional["OwnerSegments"] = None

    @classmethod
    def create(
        cls, num_pages: int, max_tenants: int, seed: int = 0, queue_size: int = 0
    ) -> "PolicyState":
        # pending stays u32: it accumulates UNSAMPLED access reports across
        # arbitrarily many control-plane calls between epochs — no policy
        # invariant bounds it below 2^16.
        assert max_tenants <= MAX_TENANT_SLOTS, (
            f"max_tenants {max_tenants} exceeds the int16 owner width "
            f"({MAX_TENANT_SLOTS}); widen PageState.owner to grow further"
        )
        return cls(
            pages=PageState.create(num_pages),
            tenants=TenantState.create(max_tenants),
            pending=jnp.zeros((num_pages,), jnp.uint32),
            rng=jax.random.PRNGKey(seed),
            queue=MigrationQueue.create(queue_size),
            epoch=jnp.int32(0),
        )


class MigrationPlan(NamedTuple):
    """Output of the policy step: bounded page-move lists.

    promote/demote: i32[R] page ids (padded with -1). Promotions move
    slow->fast, demotions fast->slow. len <= migration_budget by construction.
    """

    promote: jax.Array
    demote: jax.Array

    @property
    def num_promote(self) -> jax.Array:
        return (self.promote >= 0).sum()

    @property
    def num_demote(self) -> jax.Array:
        return (self.demote >= 0).sum()


class EpochStats(NamedTuple):
    """Telemetry emitted each epoch (per tenant unless noted).

    ``promoted``/``demoted`` count policy *selections*; with a migration
    queue the committed moves are in ``queue`` (``None`` in instant mode).
    """

    fmmr_now: jax.Array  # f32[T] instantaneous FMMR this epoch
    fmmr_ewma: jax.Array  # f32[T]
    fast_pages: jax.Array  # i32[T]
    slow_pages: jax.Array  # i32[T]
    promoted: jax.Array  # i32[T]
    demoted: jax.Array  # i32[T]
    cooled: jax.Array  # bool[T] cooling event fired
    queue: Optional["QueueStats"] = None  # data-plane telemetry (queue mode)
    # Invariant-sentinel bitmask (i32[], core/faults.py SENTINEL_*); zero
    # when green, and identically zero when params.sentinel == 0. None when
    # the checks were compiled out (compile_sentinel=False).
    sentinel: Optional[jax.Array] = None


def state_nbytes(tree) -> int:
    """Total array bytes of a pytree of device (or host) arrays.

    The packed-layout audit observable: ``PageState.owner`` at i16 and
    ``MigrationQueue.heat`` at i8 shrink this directly, and a stacked
    fleet state multiplies every per-page leaf by the machine axis — so
    the scale bench records it per (pages, tenants, machines) geometry.
    Python scalars in the tree count as zero (they occupy no array
    storage).
    """
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        dtype = getattr(leaf, "dtype", None)
        if dtype is None:
            continue
        total += int(jnp.size(leaf)) * jnp.dtype(dtype).itemsize
    return total
