"""Fleet-vectorized policy engine: K machines in one device program.

MaxMem's headline claims are statements about *populations* of colocation
scenarios — policy x seed x bandwidth sweeps — and the pre-fleet engine ran
one machine per Python process, paying full dispatch and host-sync cost
serially for every machine-epoch. This module stacks the complete per-machine
``PolicyState`` (pages, tenants, backlog, PRNG, migration queue, owner
segments) along a leading machine axis and runs the fused policy tick
``jax.vmap``-ed inside the single donated ``lax.scan`` of
``policy._multi_epoch_impl``: K machines x k epochs advance in ONE dispatch
with ONE host transfer for the stacked telemetry snapshot.

Sharding (DESIGN.md §6): when more than one XLA device is visible the
machine axis is additionally partitioned over ``jax.devices()`` with
``shard_map`` — K is padded up to a device multiple with *inert* machines
(no tenants, no backlog) whose rows are dropped from every result. No
reduction crosses a machine slice, so per-machine rows stay BIT-IDENTICAL
to the single-device vmap path and to running each machine alone
(``tests/test_fleet.py``, ``tests/test_fleet_sharded.py``). On CPU hosts
the layout is demonstrable via
``XLA_FLAGS=--xla_force_host_platform_device_count=4``.

Sweepable without recompilation (traced, batched ``PolicyParams`` leaves):
seeds, migration budgets/bandwidth/latency, sample periods, fast capacities,
targets, fairness mode. Forcing a fresh trace (static shapes): page count,
tenant-table size, queue capacity, plan size, epoch count per call.

Surface:

  * :func:`fleet_multi_epoch` — raw batched entry point on stacked pytrees.
  * :func:`fleet_multi_epoch_sharded` — the same program with the machine
    axis partitioned over a device mesh.
  * :class:`FleetManager` — facade over K :class:`CentralManager` control
    planes: register/allocate/free/telemetry stay per-machine host
    operations on the underlying managers; ``run_epochs`` stacks their
    states, runs the fleet program, and writes the advanced slices back.
    Dirty-tracking makes the stack incremental: machines untouched since
    the previous dispatch are never restacked (their advanced slices stay
    parked as lazy views), so a dispatch with no intervening control-plane
    operations performs ZERO host->device state uploads.
    ``run_epochs_async`` overlaps the telemetry fetch with host work — the
    double-buffered sweep pipeline in ``scenario.run_sweep`` builds on it.
"""
from __future__ import annotations

import atexit
import concurrent.futures
import dataclasses
import queue as queue_mod
import threading
import time
from functools import lru_cache, partial
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from repro.core import policy
from repro.core.manager import CentralManager, MultiEpochResult
from repro.core.types import (
    EpochStats,
    MigrationPlan,
    OwnerSegments,
    PolicyState,
    state_nbytes,
)


def fleet_multi_epoch(
    fstate,
    fparams,
    counts: Optional[jax.Array] = None,
    *,
    k: int,
    max_tenants: int,
    plan_size: int,
    exact_sampling: bool = False,
    count_clamp: int = policy.COUNT_CLAMP,
    collect_plans: bool = False,
    trim_stats: bool = False,
    compile_sentinel: bool = True,
):
    """Advance K stacked machines by ``k`` epochs in one dispatch.

    ``fstate``/``fparams`` are a ``PolicyState``/``PolicyParams`` whose
    leaves carry a leading machine axis. ``counts`` is ``None`` (consume
    each machine's recorded backlog), ``[K, P]`` (each machine replays its
    row every epoch) or ``[K, k, P]``. Returns (fstate', plans, stats,
    flagged) with leaves shaped ``[K, k, ...]`` for the per-epoch outputs.
    State buffers are donated on accelerator backends. ``trim_stats`` drops
    the telemetry leaves the sweep record path never reads
    (``policy._trim_stats``).
    """
    return _jitted_fleet(policy._donate_state())(
        fstate, fparams, counts, k=k, max_tenants=max_tenants,
        plan_size=plan_size, exact_sampling=exact_sampling,
        count_clamp=count_clamp, collect_plans=collect_plans,
        trim_stats=trim_stats, compile_sentinel=compile_sentinel,
    )


def _fleet_impl(
    fstate, fparams, counts, *, k, max_tenants, plan_size, exact_sampling,
    count_clamp, collect_plans, trim_stats=False, compile_sentinel=True,
):
    step = partial(
        policy._multi_epoch_impl, k=k, max_tenants=max_tenants,
        plan_size=plan_size, exact_sampling=exact_sampling,
        count_clamp=count_clamp, collect_plans=collect_plans,
        trim_stats=trim_stats, compile_sentinel=compile_sentinel,
    )
    if counts is None:
        return jax.vmap(lambda s, p: step(s, p, None))(fstate, fparams)
    return jax.vmap(lambda s, p, c: step(s, p, c))(fstate, fparams, counts)


@lru_cache(maxsize=None)
def _machine_slicer():
    """One jitted program extracting machine ``i``'s slice from the stacked
    state: a single dispatch for the whole pytree. Eager per-leaf ``a[i]``
    indexing costs milliseconds PER LEAF on a device-sharded stack (each
    slice is its own cross-device gather); this is the difference between
    ~1 ms and ~70 ms per machine materialization on a 4-device CPU host."""
    def slice_i(tree_, i):
        return jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            tree_,
        )
    return jax.jit(slice_i)


@lru_cache(maxsize=None)
def _machine_updater():
    """Jitted counterpart of :func:`_machine_slicer` for the dirty-machine
    re-upload: writes one machine's state back into row ``i`` of the
    stacked pytree in a single dispatch."""
    def update_i(tree_, state_i, i):
        return jax.tree.map(
            lambda F, s: jax.lax.dynamic_update_index_in_dim(
                F, jnp.expand_dims(s, 0), i, 0
            ),
            tree_, state_i,
        )
    return jax.jit(update_i)


@lru_cache(maxsize=None)
def _jitted_fleet(donate: bool):
    return jax.jit(
        _fleet_impl,
        static_argnames=(
            "k", "max_tenants", "plan_size", "exact_sampling", "count_clamp",
            "collect_plans", "trim_stats", "compile_sentinel",
        ),
        donate_argnums=(0,) if donate else (),
    )


@lru_cache(maxsize=None)
def _jitted_sharded_fleet(
    mesh: Mesh, donate: bool, has_counts: bool, k: int, max_tenants: int,
    plan_size: int, exact_sampling: bool, count_clamp: int,
    collect_plans: bool, trim_stats: bool, compile_sentinel: bool = True,
):
    """One compiled shard_map program per (mesh, static-config) pair.

    Every input/output leaf carries the machine axis in front, so a single
    ``PartitionSpec('machines')`` prefix partitions the whole pytree; the
    per-shard body is the plain vmapped scan, and since no collective
    crosses a machine slice the partitioning is communication-free
    (``check_rep=False`` only disables the replication check shard_map
    would otherwise try to prove)."""
    impl = partial(
        _fleet_impl, k=k, max_tenants=max_tenants, plan_size=plan_size,
        exact_sampling=exact_sampling, count_clamp=count_clamp,
        collect_plans=collect_plans, trim_stats=trim_stats,
        compile_sentinel=compile_sentinel,
    )
    spec = PartitionSpec("machines")
    if has_counts:
        fn = shard_map(
            lambda s, p, c: impl(s, p, c), mesh=mesh,
            in_specs=(spec, spec, spec), out_specs=spec, check_rep=False,
        )
    else:
        fn = shard_map(
            lambda s, p: impl(s, p, None), mesh=mesh,
            in_specs=(spec, spec), out_specs=spec, check_rep=False,
        )
    return jax.jit(fn, donate_argnums=(0,) if donate else ())


def fleet_multi_epoch_sharded(
    fstate,
    fparams,
    counts: Optional[jax.Array] = None,
    *,
    mesh: Mesh,
    k: int,
    max_tenants: int,
    plan_size: int,
    exact_sampling: bool = False,
    count_clamp: int = policy.COUNT_CLAMP,
    collect_plans: bool = False,
    trim_stats: bool = False,
    compile_sentinel: bool = True,
):
    """:func:`fleet_multi_epoch` with the machine axis partitioned over
    ``mesh`` (axis name ``machines``). The leading dimension of every leaf
    must be divisible by the mesh size — :class:`FleetManager` guarantees
    this by padding with inert machines. Per-machine rows are bit-identical
    to the unsharded path (no reduction crosses a machine slice)."""
    fn = _jitted_sharded_fleet(
        mesh, policy._donate_state(), counts is not None, k, max_tenants,
        plan_size, exact_sampling, count_clamp, collect_plans, trim_stats,
        compile_sentinel,
    )
    if counts is None:
        return fn(fstate, fparams)
    return fn(fstate, fparams, counts)


@dataclasses.dataclass
class FleetMultiEpochResult:
    """Stacked output of ``FleetManager.run_epochs``.

    All leaves are HOST numpy arrays with leading ``[K, k]`` axes — the one
    batched transfer per fleet telemetry snapshot. ``machine(m)`` views one
    machine's slice as a regular :class:`MultiEpochResult`.
    """

    stats: EpochStats  # [K, k, ...] leaves
    plans: Optional[MigrationPlan]  # [K, k, R] leaves or None
    flags: np.ndarray  # bool[K, k, T]

    @property
    def num_machines(self) -> int:
        return self.flags.shape[0]

    @property
    def num_epochs(self) -> int:
        return self.flags.shape[1]

    def machine(self, m: int) -> MultiEpochResult:
        return MultiEpochResult(
            stats=jax.tree.map(lambda a: a[m], self.stats),
            plans=None if self.plans is None else jax.tree.map(lambda a: a[m], self.plans),
            flags=self.flags[m],
        )


class DispatchError(RuntimeError):
    """The fleet dispatch worker failed or timed out; the fleet state is
    still the pre-dispatch one — ``FleetManager.recover_dispatch`` rolls the
    epoch clocks back so the chunk can be retried (DESIGN.md §7)."""


class _DispatchWorker:
    """The fleet's dedicated dispatch thread.

    A plain ``ThreadPoolExecutor`` has two lifecycle hazards here: its
    atexit hook JOINS the worker, so a wedged device program blocks
    interpreter exit forever, and a leaked executor keeps the process alive.
    This minimal worker is a daemon thread draining a queue of (future,
    thunk) pairs — it can never hold the interpreter hostage — and
    ``close()`` (registered with atexit, bounded join) gives orderly
    shutdown when the worker is healthy. ``FleetManager.recover_dispatch``
    simply abandons a wedged worker and starts a fresh one."""

    def __init__(self):
        self._q: "queue_mod.Queue" = queue_mod.Queue()
        self._thread = threading.Thread(
            target=self._loop, name="fleet-dispatch", daemon=True
        )
        self._closed = False
        self._thread.start()
        atexit.register(self.close)

    def _loop(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            fut, fn = item
            if not fut.set_running_or_notify_cancel():
                continue
            try:
                fut.set_result(fn())
            except BaseException as e:  # propagate EVERYTHING to the future
                fut.set_exception(e)

    def submit(self, fn) -> "concurrent.futures.Future":
        if self._closed:
            raise RuntimeError("dispatch worker is closed")
        fut: concurrent.futures.Future = concurrent.futures.Future()
        self._q.put((fut, fn))
        return fut

    def close(self, timeout: float = 5.0) -> None:
        """Ask the thread to drain and exit; join at most ``timeout``
        seconds (a wedged device program is abandoned, not waited on)."""
        if self._closed:
            return
        self._closed = True
        self._q.put(None)
        if timeout > 0:
            self._thread.join(timeout)
        try:
            atexit.unregister(self.close)
        except Exception:
            pass


class FleetPendingResult:
    """A fleet advance running on the fleet's dispatch worker thread.

    JAX's CPU backend executes dispatches synchronously on the calling
    thread, so genuine host/device overlap needs the device program driven
    from a dedicated worker: XLA releases the GIL for the whole execution,
    and the telemetry ``device_get`` happens inside the worker too — the
    main thread records the previous chunk / prepares the next one while
    the device runs. ``result()`` joins, folds the per-machine queue
    counters exactly once, strips the inert padding rows and returns the
    host-side :class:`FleetMultiEpochResult`. (On accelerator backends the
    worker merely dispatches and blocks on the transfer — the same overlap,
    provided by the hardware queue instead.)"""

    def __init__(self, fleet: "FleetManager", future):
        self._fleet = fleet
        self._future = future
        self._result: Optional[FleetMultiEpochResult] = None

    def result(self, timeout: Optional[float] = None) -> FleetMultiEpochResult:
        """Join the dispatch. ``timeout`` (seconds) bounds the wait: on
        expiry a :class:`DispatchError` is raised and the dispatch keeps
        running — call again to keep waiting, or let the sweep supervisor
        recover and fall back to the serialized path."""
        if self._result is None:
            try:
                _fstate, (stats, flags, plans) = self._future.result(timeout)
            except concurrent.futures.TimeoutError:
                raise DispatchError(
                    f"fleet dispatch did not complete within {timeout}s"
                ) from None
            except concurrent.futures.CancelledError:
                raise
            except BaseException as e:
                # uniform fault surface: whatever the worker raised arrives
                # as a DispatchError (cause preserved) so supervisors need
                # one except clause, not a taxonomy
                raise DispatchError(f"fleet dispatch failed: {e!r}") from e
            K = len(self._fleet.machines)
            stats, flags, plans = jax.tree.map(
                lambda a: a[:K], (stats, flags, plans)
            )
            if stats.queue is not None:
                for i, m in enumerate(self._fleet.machines):
                    m._fold_queue_stats(jax.tree.map(lambda a: a[i], stats.queue))
            self._result = FleetMultiEpochResult(
                stats=stats, plans=plans, flags=flags
            )
        return self._result


class FleetManager:
    """K :class:`CentralManager` machines advancing as one device program.

    Control-plane operations (register/allocate/free/telemetry/bandwidth
    events) address the underlying managers directly — ``fleet.machines[m]``
    exposes the full per-machine surface, and any state they mutate is
    restacked on the next fleet dispatch. ``run_epochs`` is the data plane:
    stack -> one vmapped (and, with multiple devices, sharded) scan ->
    park advanced slices -> one host telemetry snapshot.

    ``devices`` selects the shard layout: ``None`` uses every local XLA
    device (sharded whenever more than one is visible), an int takes the
    first n local devices, a sequence pins explicit devices, and ``1``
    forces the single-device vmap path. K is padded up to a device multiple
    with inert machines (no tenants, no backlog — DESIGN.md §6 padding
    contract); padded rows are dropped from every result and telemetry
    read. ``pad_to`` overrides the padding multiple (testing hook).

    Dirty-tracking: after a dispatch each machine's advanced slice stays
    parked as a lazy view into the cached stacked state. Only machines
    whose control plane actually fired (any state/params mutation or a
    pending ``OwnerSegments`` rebuild) are re-uploaded before the next
    dispatch — a no-op dispatch performs zero host->device state uploads
    (``upload_stats`` counts restacked machines and segment rebuilds;
    locked by a regression test).

    Machines must agree on every SHAPE-defining knob (num_pages,
    max_tenants, queue_size, exact_sampling); traced parameters (budgets,
    bandwidth, latency, sample period, capacity, fairness) may differ per
    machine — that is the sweepable grid. Plan buffers take the fleet-wide
    maximum budget so shapes stay uniform; per-machine selections are
    unaffected (the budget itself is traced).
    """

    def __init__(
        self,
        machines: Sequence[CentralManager],
        devices=None,
        pad_to: Optional[int] = None,
    ):
        assert len(machines) > 0, "fleet needs at least one machine"
        self.machines: List[CentralManager] = list(machines)
        first = self.machines[0]
        for m in self.machines:
            assert m.num_pages == first.num_pages, "fleet machines must share num_pages"
            assert m.max_tenants == first.max_tenants, "fleet machines must share max_tenants"
            assert m.queue_size == first.queue_size, "fleet machines must share queue_size"
            assert m.exact_sampling == first.exact_sampling, (
                "fleet machines must share exact_sampling"
            )
            assert m.pool is None, (
                "pool-backed data planes are per-machine host objects; "
                "run them on a single CentralManager"
            )
        self.num_pages = first.num_pages
        self.max_tenants = first.max_tenants
        self.queue_size = first.queue_size
        self.exact_sampling = first.exact_sampling
        self.plan_size = max(m.plan_size for m in self.machines)

        if devices is None:
            devs = list(jax.devices())
        elif isinstance(devices, int):
            assert devices >= 1, "devices must be >= 1"
            local = list(jax.devices())
            assert devices <= len(local), (
                f"requested {devices} devices, only {len(local)} visible"
            )
            devs = local[:devices]
        else:
            devs = list(devices)
        self.devices = devs
        self.num_shards = len(devs)
        self.mesh = (
            Mesh(np.array(devs), ("machines",)) if len(devs) > 1 else None
        )
        K = len(self.machines)
        multiple = pad_to if pad_to is not None else self.num_shards
        assert multiple >= 1
        self.num_padded = K + (-K) % multiple
        if self.mesh is not None:
            assert self.num_padded % self.num_shards == 0, (
                f"padded machine count {self.num_padded} must divide over "
                f"{self.num_shards} devices (pad_to must be a shard multiple)"
            )
        # dirty-tracking: cached stacked state/params + per-machine params
        # identity from the moment each slice was last uploaded
        self._fstate = None
        self._fparams = None
        self._written_params: List[object] = [None] * K
        self._inert_state = None
        # the dispatch worker: one thread so device programs serialize
        # naturally while the main thread keeps the host pipeline busy
        self._worker: Optional[_DispatchWorker] = None
        self._inflight = None
        self._inflight_k = 0
        # first worker exception, noted at FAULT time by a done-callback —
        # every subsequent fleet operation raises it promptly instead of
        # deferring to the next .result() (satellite: prompt propagation)
        self._dispatch_error: Optional[BaseException] = None
        # failed machines: slot -> the real PolicyState parked at fail time
        # (the machine itself runs as an inert row until recovery)
        self._parked: Dict[int, PolicyState] = {}
        # optional worker supervision (enable_supervision): host 0 is the
        # dispatch worker; it beats when a dispatch starts and completes
        self.heartbeat = None
        # chaos hooks (tests): fail the next n dispatches / delay each one
        self._chaos_fail_n = 0
        self._chaos_delay_s = 0.0
        self.upload_stats = {
            "dispatches": 0,
            "clean_dispatches": 0,
            "restacked_machines": 0,
            "seg_rebuilds": 0,
        }

    @property
    def num_machines(self) -> int:
        return len(self.machines)

    def __len__(self) -> int:
        return len(self.machines)

    # ------------------------------------------------------------ stacking
    def _machine_dirty(self, m: CentralManager) -> bool:
        """True when the machine's row in the cached stack is stale: any
        state setter fired since the last dispatch, or an ownership change
        left a pending ``OwnerSegments`` rebuild. (Params staleness is
        tracked separately — it re-stacks the tiny params leaves only.)"""
        return m._mutated or m._segs_owner is not None

    def _make_inert_state(self) -> PolicyState:
        """A machine that computes but matters to nobody: no tenants, no
        backlog, the same static shapes as every real machine. Its rows are
        sliced off every output; its only job is making the machine count a
        shard multiple."""
        if self._inert_state is None:
            state = PolicyState.create(
                self.num_pages, self.max_tenants, seed=0,
                queue_size=self.queue_size,
            )
            self._inert_state = state._replace(
                segs=OwnerSegments.build(
                    np.full((self.num_pages,), -1, np.int32), self.max_tenants
                )
            )
        return self._inert_state

    def _check_dispatch_error(self) -> None:
        """Surface a worker fault NOW (not at the next ``.result()``). The
        error stays sticky until ``recover_dispatch`` clears it."""
        if self._dispatch_error is not None:
            raise DispatchError(
                f"fleet dispatch worker failed: {self._dispatch_error!r}"
            ) from self._dispatch_error

    def _join(self):
        """Adopt the in-flight dispatch's advanced stacked state (if any).
        This is the pipeline's sync point: it blocks until the worker's
        device program — and its telemetry transfer — completed."""
        self._check_dispatch_error()
        if self._inflight is not None:
            try:
                fstate, _host = self._inflight.result()
            except concurrent.futures.CancelledError:
                raise
            except BaseException as e:
                raise DispatchError(f"fleet dispatch failed: {e!r}") from e
            self._fstate = fstate
            self._inflight = None
        return self._fstate

    def _assemble(self) -> None:
        """Bring the cached stacked state/params up to date, uploading only
        the machines whose control plane fired since the last dispatch."""
        self._join()
        K = len(self.machines)
        pad = self.num_padded - K
        dirty = [
            i for i, m in enumerate(self.machines)
            if self._fstate is None or self._machine_dirty(m)
        ]
        for i in dirty:
            if self.machines[i]._segs_owner is not None:
                self.upload_stats["seg_rebuilds"] += 1
            self.machines[i]._ensure_segs()
        if self._fstate is None or len(dirty) == K:
            states = [m._state for m in self.machines]
            if pad:
                states = states + [self._make_inert_state()] * pad
            self._fstate = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
            self.upload_stats["restacked_machines"] += K
        elif dirty:
            for i in dirty:
                self._fstate = _machine_updater()(
                    self._fstate, self.machines[i]._state, i
                )
            self.upload_stats["restacked_machines"] += len(dirty)
        params_dirty = self._fparams is None or any(
            m.params is not self._written_params[i]
            for i, m in enumerate(self.machines)
        )
        if params_dirty:
            plist = [m.params for m in self.machines]
            if pad:
                plist = plist + [self.machines[0].params] * pad
            self._fparams = jax.tree.map(
                lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *plist
            )
        if not dirty and not params_dirty:
            self.upload_stats["clean_dispatches"] += 1

    def _park_slices(self) -> None:
        """Point every machine's state at its (lazy) slice of the advanced
        stack; nothing materializes — and the in-flight dispatch is not
        even joined — until a control-plane or telemetry path actually
        reads a machine."""

        def slicer(i: int) -> Callable[[], PolicyState]:
            return lambda: _machine_slicer()(self._join(), i)

        for i, m in enumerate(self.machines):
            m._set_fleet_state(slicer(i))
            self._written_params[i] = m.params

    # ------------------------------------------------------------ dispatch
    def run_epochs_async(
        self,
        k: int,
        counts: Optional[np.ndarray] = None,
        collect_plans: bool = False,
        trim_stats: bool = False,
        inline: bool = False,
    ) -> FleetPendingResult:
        """Dispatch ``k`` epochs for every machine and return immediately.

        The returned handle's ``result()`` materializes the telemetry; in
        the meantime the host can record the previous chunk, prepare the
        next one, or fire control-plane events — the double-buffered sweep
        pipeline (``scenario.run_sweep``) lives on exactly this overlap.
        ``inline=True`` runs the same program synchronously on the calling
        thread and returns a pre-resolved handle — the serialized fallback
        the sweep supervisor degrades to when the worker misbehaves.
        """
        self._check_dispatch_error()
        K = len(self.machines)
        pad = self.num_padded - K
        self._assemble()
        cn = None
        if counts is not None:
            cn = np.asarray(counts)
            assert cn.ndim in (2, 3) and cn.shape[0] == K, (
                f"counts must be [K, P] or [K, k, P] with K={K}, got {cn.shape}"
            )
            if pad:
                cn = np.concatenate(
                    [cn, np.zeros((pad,) + cn.shape[1:], cn.dtype)], axis=0
                )
        kw = dict(
            k=k, max_tenants=self.max_tenants, plan_size=self.plan_size,
            exact_sampling=self.exact_sampling, collect_plans=collect_plans,
            trim_stats=trim_stats,
        )
        mesh = self.mesh
        fstate_in, fparams_in = self._fstate, self._fparams
        hb = self.heartbeat
        chaos_fail = self._chaos_fail_n > 0
        if chaos_fail:
            self._chaos_fail_n -= 1
        chaos_delay = self._chaos_delay_s

        def work():
            if hb is not None:
                hb.beat(0)
            if chaos_delay:
                time.sleep(chaos_delay)
            if chaos_fail:
                raise RuntimeError("injected dispatch failure (chaos hook)")
            c = None
            if cn is not None:
                # host->device upload of the workload happens in the worker
                # too — off the main thread's critical path
                c = jnp.asarray(cn.astype(np.uint32, copy=False))
            if mesh is not None:
                fstate, plans, stats, flagged = fleet_multi_epoch_sharded(
                    fstate_in, fparams_in, c, mesh=mesh, **kw
                )
            else:
                fstate, plans, stats, flagged = fleet_multi_epoch(
                    fstate_in, fparams_in, c, **kw
                )
            host = jax.device_get(
                (stats, flagged, plans if collect_plans else None)
            )
            if hb is not None:
                hb.beat(0)
            return fstate, host

        if inline:
            # serialized fallback: run on the calling thread; failures raise
            # here directly and leave the pre-dispatch state intact
            fut: concurrent.futures.Future = concurrent.futures.Future()
            fut.set_result(work())
            self._inflight = fut
        else:
            if self._worker is None:
                self._worker = _DispatchWorker()
            self._inflight = self._worker.submit(work)
            self._inflight.add_done_callback(self._note_dispatch_outcome)
        self._inflight_k = k
        self._park_slices()
        for m in self.machines:
            m.epoch_index += k
            m._snap = None
        self.upload_stats["dispatches"] += 1
        return FleetPendingResult(self, self._inflight)

    def _note_dispatch_outcome(self, fut) -> None:
        """Done-callback on the worker future: record the first failure at
        FAULT time so the main thread learns about it at its next fleet
        call, not only when it finally asks for the result."""
        if fut.cancelled() or getattr(fut, "_fleet_abandoned", False):
            return
        exc = fut.exception()
        if exc is not None and self._dispatch_error is None:
            self._dispatch_error = exc

    def recover_dispatch(self) -> None:
        """Reset after a failed (or wedged) dispatch so the chunk can be
        retried. The stacked state is still the pre-dispatch assembly (the
        CPU path never donates it), so recovery is: drop the in-flight
        future, clear the sticky error, roll the per-machine epoch clocks
        back by the dispatched k, and abandon the worker thread — a fresh
        daemon is created on the next dispatch. A supervised fleet also gets
        a fresh ``HeartbeatTracker`` (the old one latched the worker dead).
        """
        if self._inflight is not None:
            # flag before cancel: an abandoned-but-running future resolves
            # later and must not re-arm the sticky error we just cleared
            self._inflight._fleet_abandoned = True
            self._inflight.cancel()
            self._inflight = None
            for m in self.machines:
                m.epoch_index -= self._inflight_k
                m._snap = None
            # the parked lazy slices point at _join(); with the in-flight
            # future dropped they resolve to the pre-dispatch stack rows
        self._inflight_k = 0
        self._dispatch_error = None
        if self._worker is not None:
            self._worker.close(timeout=0.0)  # abandon, never block on a wedge
            self._worker = None
        if self.heartbeat is not None:
            self.enable_supervision(
                timeout=self.heartbeat.timeout, clock=self.heartbeat.clock
            )

    # ---------------------------------------------------------- supervision
    def enable_supervision(self, timeout: float = 60.0, clock=None) -> None:
        """Watch the dispatch worker with the seed's ``HeartbeatTracker``
        (host id 0 = the worker; it beats at dispatch start and completion).
        ``check_worker()`` returning a non-empty list means the worker has
        been silent longer than ``timeout`` — the sweep supervisor then
        recovers and falls back to the serialized path. ``clock`` is
        injectable for tests (fake time)."""
        from repro.runtime.fault_tolerance import HeartbeatTracker

        kw = {} if clock is None else {"clock": clock}
        self.heartbeat = HeartbeatTracker([0], timeout=timeout, **kw)

    def check_worker(self) -> List[int]:
        """Newly-dead host ids from the supervision tracker ([] when
        healthy or supervision is off)."""
        if self.heartbeat is None:
            return []
        return self.heartbeat.check()

    # --------------------------------------------------------- machine faults
    @property
    def failed_machines(self) -> List[int]:
        return sorted(self._parked)

    def fail_machine(self, i: int) -> None:
        """Drop machine ``i`` mid-sweep (the MachineFail scenario event).

        Its real ``PolicyState`` is parked host-side and the machine runs as
        an inert row — same static shapes, no tenants, no backlog — so the
        fleet program's geometry never changes. The PRNG stream and queue
        are frozen exactly where the failure left them; ``recover_machine``
        restores them bit-identically. The machine's ``epoch_index`` keeps
        advancing while parked: it is the fleet's wall clock, and the down
        window is real elapsed time (the simulator records it as zero
        throughput)."""
        if i in self._parked:
            raise ValueError(f"machine {i} is already failed")
        m = self.machines[i]
        m._ensure_segs()  # park a self-consistent state (segs current)
        self._parked[i] = m._state  # materializes the lazy slice
        m._state = self._make_inert_state()
        m._snap = None

    def recover_machine(self, i: int) -> None:
        """Restore machine ``i``'s parked state (the MachineRecover event).
        The state setter marks the row dirty, so the next dispatch uploads
        the real state back into the stack."""
        if i not in self._parked:
            raise ValueError(f"machine {i} is not failed")
        m = self.machines[i]
        m._state = self._parked.pop(i)
        m._snap = None

    def run_epochs(
        self,
        k: int,
        counts: Optional[np.ndarray] = None,
        collect_plans: bool = False,
        trim_stats: bool = False,
    ) -> FleetMultiEpochResult:
        """Advance every machine by ``k`` epochs in ONE device dispatch.

        ``counts``: None (consume each machine's recorded backlog), ``[K,
        P]`` (per-machine steady-state replay) or ``[K, k, P]``. Per-machine
        telemetry is bit-identical to ``CentralManager.run_epochs`` on each
        machine alone.
        """
        return self.run_epochs_async(
            k, counts=counts, collect_plans=collect_plans,
            trim_stats=trim_stats,
        ).result()

    # ----------------------------------------------------------- telemetry
    def live_bytes(self) -> int:
        """Array bytes of the stacked fleet state (padded machine rows
        included — padding occupies real device memory). The scale bench
        records this per geometry: every per-page leaf scales as K x P, so
        the packed i16 owner / i8 queue-heat layouts shrink exactly the
        term that dominates at a million pages."""
        self._assemble()
        return state_nbytes(self._fstate)

    def stacked_placement(self) -> Tuple[np.ndarray, np.ndarray]:
        """(tier[K, P], owner[K, P]) for every machine in ONE batched
        device->host transfer, seeding each manager's telemetry snapshot
        cache — replaces K per-machine ``device_get`` round trips on the
        sweep pipeline's critical path. Falls back to per-machine snapshots
        when a machine mutated since the last dispatch (its row in the
        cached stack is stale)."""
        K = len(self.machines)
        self._join()
        clean = self._fstate is not None and not any(
            m._mutated for m in self.machines
        )
        if clean:
            tier, owner = jax.device_get(
                (self._fstate.pages.tier, self._fstate.pages.owner)
            )
            tier, owner = tier[:K], owner[:K]
            for i, m in enumerate(self.machines):
                m._snap = {"tier": tier[i], "owner": owner[i]}
            return tier, owner
        tier = np.stack([m.tiers() for m in self.machines])
        owner = np.stack([m.owners() for m in self.machines])
        return tier, owner
