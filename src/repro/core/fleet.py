"""Fleet-vectorized policy engine: K machines in one device program.

MaxMem's headline claims are statements about *populations* of colocation
scenarios — policy x seed x bandwidth sweeps — and the pre-fleet engine ran
one machine per Python process, paying full dispatch and host-sync cost
serially for every machine-epoch. This module stacks the complete per-machine
``PolicyState`` (pages, tenants, backlog, PRNG, migration queue, owner
segments) along a leading machine axis and runs the fused policy tick
``jax.vmap``-ed inside the single donated ``lax.scan`` of
``policy._multi_epoch_impl``: K machines x k epochs advance in ONE dispatch
with ONE host transfer for the stacked telemetry snapshot.

Sweepable without recompilation (traced, batched ``PolicyParams`` leaves):
seeds, migration budgets/bandwidth/latency, sample periods, fast capacities,
targets, fairness mode. Forcing a fresh trace (static shapes): page count,
tenant-table size, queue capacity, plan size, epoch count per call.

Per-machine results are BIT-IDENTICAL to running each machine alone through
``policy.epoch_step``/``policy.multi_epoch`` — vmap only adds a batch axis,
every reduction stays within its machine slice. ``tests/test_fleet.py``
locks this, including queue mode and mid-sweep free()/unregister churn.

Surface:

  * :func:`fleet_multi_epoch` — raw batched entry point on stacked pytrees.
  * :class:`FleetManager` — facade over K :class:`CentralManager` control
    planes: register/allocate/free/telemetry stay per-machine host
    operations on the underlying managers; ``run_epochs`` stacks their
    states, runs the fleet program, and writes the advanced slices back.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy
from repro.core.manager import CentralManager, MultiEpochResult
from repro.core.types import EpochStats, MigrationPlan


def fleet_multi_epoch(
    fstate,
    fparams,
    counts: Optional[jax.Array] = None,
    *,
    k: int,
    max_tenants: int,
    plan_size: int,
    exact_sampling: bool = False,
    count_clamp: int = policy.COUNT_CLAMP,
    collect_plans: bool = False,
):
    """Advance K stacked machines by ``k`` epochs in one dispatch.

    ``fstate``/``fparams`` are a ``PolicyState``/``PolicyParams`` whose
    leaves carry a leading machine axis. ``counts`` is ``None`` (consume
    each machine's recorded backlog), ``[K, P]`` (each machine replays its
    row every epoch) or ``[K, k, P]``. Returns (fstate', plans, stats,
    flagged) with leaves shaped ``[K, k, ...]`` for the per-epoch outputs.
    State buffers are donated on accelerator backends.
    """
    return _jitted_fleet(policy._donate_state())(
        fstate, fparams, counts, k=k, max_tenants=max_tenants,
        plan_size=plan_size, exact_sampling=exact_sampling,
        count_clamp=count_clamp, collect_plans=collect_plans,
    )


def _fleet_impl(
    fstate, fparams, counts, *, k, max_tenants, plan_size, exact_sampling,
    count_clamp, collect_plans,
):
    step = partial(
        policy._multi_epoch_impl, k=k, max_tenants=max_tenants,
        plan_size=plan_size, exact_sampling=exact_sampling,
        count_clamp=count_clamp, collect_plans=collect_plans,
    )
    if counts is None:
        return jax.vmap(lambda s, p: step(s, p, None))(fstate, fparams)
    return jax.vmap(lambda s, p, c: step(s, p, c))(fstate, fparams, counts)


@lru_cache(maxsize=None)
def _jitted_fleet(donate: bool):
    return jax.jit(
        _fleet_impl,
        static_argnames=(
            "k", "max_tenants", "plan_size", "exact_sampling", "count_clamp",
            "collect_plans",
        ),
        donate_argnums=(0,) if donate else (),
    )


@dataclasses.dataclass
class FleetMultiEpochResult:
    """Stacked output of ``FleetManager.run_epochs``.

    All leaves are HOST numpy arrays with leading ``[K, k]`` axes — the one
    batched transfer per fleet telemetry snapshot. ``machine(m)`` views one
    machine's slice as a regular :class:`MultiEpochResult`.
    """

    stats: EpochStats  # [K, k, ...] leaves
    plans: Optional[MigrationPlan]  # [K, k, R] leaves or None
    flags: np.ndarray  # bool[K, k, T]

    @property
    def num_machines(self) -> int:
        return self.flags.shape[0]

    @property
    def num_epochs(self) -> int:
        return self.flags.shape[1]

    def machine(self, m: int) -> MultiEpochResult:
        return MultiEpochResult(
            stats=jax.tree.map(lambda a: a[m], self.stats),
            plans=None if self.plans is None else jax.tree.map(lambda a: a[m], self.plans),
            flags=self.flags[m],
        )


class FleetManager:
    """K :class:`CentralManager` machines advancing as one device program.

    Control-plane operations (register/allocate/free/telemetry/bandwidth
    events) address the underlying managers directly — ``fleet.machines[m]``
    exposes the full per-machine surface, and any state they mutate is
    restacked on the next fleet dispatch. ``run_epochs`` is the data plane:
    stack -> one vmapped scan -> write advanced slices back -> one host
    telemetry snapshot.

    Machines must agree on every SHAPE-defining knob (num_pages,
    max_tenants, queue_size, exact_sampling); traced parameters (budgets,
    bandwidth, latency, sample period, capacity, fairness) may differ per
    machine — that is the sweepable grid. Plan buffers take the fleet-wide
    maximum budget so shapes stay uniform; per-machine selections are
    unaffected (the budget itself is traced).
    """

    def __init__(self, machines: Sequence[CentralManager]):
        assert len(machines) > 0, "fleet needs at least one machine"
        self.machines: List[CentralManager] = list(machines)
        first = self.machines[0]
        for m in self.machines:
            assert m.num_pages == first.num_pages, "fleet machines must share num_pages"
            assert m.max_tenants == first.max_tenants, "fleet machines must share max_tenants"
            assert m.queue_size == first.queue_size, "fleet machines must share queue_size"
            assert m.exact_sampling == first.exact_sampling, (
                "fleet machines must share exact_sampling"
            )
            assert m.pool is None, (
                "pool-backed data planes are per-machine host objects; "
                "run them on a single CentralManager"
            )
        self.num_pages = first.num_pages
        self.max_tenants = first.max_tenants
        self.queue_size = first.queue_size
        self.exact_sampling = first.exact_sampling
        self.plan_size = max(m.plan_size for m in self.machines)

    @property
    def num_machines(self) -> int:
        return len(self.machines)

    def __len__(self) -> int:
        return len(self.machines)

    def run_epochs(
        self,
        k: int,
        counts: Optional[np.ndarray] = None,
        collect_plans: bool = False,
    ) -> FleetMultiEpochResult:
        """Advance every machine by ``k`` epochs in ONE device dispatch.

        ``counts``: None (consume each machine's recorded backlog), ``[K,
        P]`` (per-machine steady-state replay) or ``[K, k, P]``. Per-machine
        telemetry is bit-identical to ``CentralManager.run_epochs`` on each
        machine alone.
        """
        K = len(self.machines)
        for m in self.machines:
            m._ensure_segs()
        fstate = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[m._state for m in self.machines]
        )
        fparams = jax.tree.map(
            lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
            *[m.params for m in self.machines],
        )
        c = None
        if counts is not None:
            c = jnp.asarray(np.asarray(counts).astype(np.uint32, copy=False))
            assert c.ndim in (2, 3) and c.shape[0] == K, (
                f"counts must be [K, P] or [K, k, P] with K={K}, got {c.shape}"
            )
        fstate, plans, stats, flagged = fleet_multi_epoch(
            fstate, fparams, c,
            k=k, max_tenants=self.max_tenants, plan_size=self.plan_size,
            exact_sampling=self.exact_sampling, collect_plans=collect_plans,
        )
        for i, m in enumerate(self.machines):
            m._state = jax.tree.map(lambda a: a[i], fstate)
            m.epoch_index += k
            m._snap = None
        stats, flags, plans = jax.device_get(
            (stats, flagged, plans if collect_plans else None)
        )
        if stats.queue is not None:
            for i, m in enumerate(self.machines):
                m._fold_queue_stats(jax.tree.map(lambda a: a[i], stats.queue))
        return FleetMultiEpochResult(stats=stats, plans=plans, flags=flags)
