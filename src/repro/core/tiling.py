"""Tiled (blocked) integer reductions for million-page geometries.

XLA:CPU lowers a long single-axis ``cumsum`` to a reduce-window /
associative-scan program whose cost grows far worse than linearly with the
scanned length under the pre-thunk runtime the CI host pins: a 1M-element
int32 cumsum measures ~115 ms on one core while the same values summed in
2k-element blocks (block-local cumsum + carry of block totals) take ~8 ms.
The fused tick performs a handful of P-length and [T, C]-row cumsums per
epoch, so at 1M pages the scans ARE the scaling wall (DESIGN.md §10).

``tiled_cumsum`` reshapes the scanned axis into ``[n_blocks, block]``,
cumsums within blocks, prefix-sums the per-block totals (recursively, so
arbitrarily long axes stay in the fast regime), and adds the exclusive
block offsets back. For integer dtypes addition is exact and associative,
so the result is BIT-IDENTICAL to ``jnp.cumsum`` — the same guarantee the
owner-segment reductions rely on (DESIGN.md §5) — and the golden traces
cannot observe the tiling. Float inputs fall back to ``jnp.cumsum``
(float addition does not reassociate losslessly).

Trace selection is a static-shape heuristic: axes at or below
``CUMSUM_TILE_THRESHOLD`` elements keep today's single-scan program, so
small geometries (every committed golden runs at <= 64k pages) trace to
exactly the HLO they traced to before this module existed.
"""
from __future__ import annotations

import jax.numpy as jnp

# Scanned axes at or below this length keep the plain jnp.cumsum program.
# 65536 keeps every existing golden/bench geometry (4k..64k pages) on the
# untiled trace; the first tiled size is 128k. Above the threshold the
# plain scan is already several times slower than the blocked form.
CUMSUM_TILE_THRESHOLD = 65536

# Block length for the within-block cumsum. Swept at 1M elements on the CI
# host: 256 -> 9.5 ms, 1024 -> 8.0 ms, 4096 -> 9.2 ms; 1024 also keeps the
# per-block working set (two blocks of i32) inside L1.
CUMSUM_BLOCK = 1024


def tiled_cumsum(x, axis: int = -1):
    """``jnp.cumsum(x, axis)`` — bit-identical for integer dtypes — tiled
    into :data:`CUMSUM_BLOCK` chunks when the scanned axis is longer than
    :data:`CUMSUM_TILE_THRESHOLD` (a trace-time shape test; short axes
    trace to the plain scan, unchanged from the pre-tiling engine)."""
    ax = axis % x.ndim
    n = x.shape[ax]
    if n <= CUMSUM_TILE_THRESHOLD or not jnp.issubdtype(x.dtype, jnp.integer):
        return jnp.cumsum(x, axis=ax)
    moved = ax != x.ndim - 1
    if moved:
        x = jnp.moveaxis(x, ax, -1)
    pad = (-n) % CUMSUM_BLOCK
    if pad:
        widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
        x = jnp.pad(x, widths)  # zero pad: exact under integer addition
    nb = (n + pad) // CUMSUM_BLOCK
    blocks = x.reshape(*x.shape[:-1], nb, CUMSUM_BLOCK)
    within = jnp.cumsum(blocks, axis=-1)
    totals = within[..., -1]
    offsets = tiled_cumsum(totals, axis=-1) - totals  # exclusive carry
    out = (within + offsets[..., None]).reshape(*x.shape[:-1], nb * CUMSUM_BLOCK)
    out = out[..., :n]
    if moved:
        out = jnp.moveaxis(out, -1, ax)
    return out
