"""Unified model API — dispatch on cfg.family.

    api = get_model(cfg)
    params = api.init(rng)
    loss, metrics = api.loss(params, batch)
    cache = api.init_cache(batch_size, max_len)
    logits, cache = api.decode(params, token, cache)

``input_specs(cfg, shape)`` builds ShapeDtypeStruct stand-ins for the dry-run
(never allocates). Modality frontends are stubs: whisper takes precomputed
frame embeddings; chameleon takes unified text+VQ token ids.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, hybrid, ssm_lm, transformer


@dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[..., Any]
    init_cache: Callable[..., Any]
    decode: Callable[..., Any]
    prefill: Optional[Callable[..., Any]] = None


def get_model(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return ModelAPI(
            cfg=cfg,
            init=lambda rng: transformer.init_params(rng, cfg),
            loss=lambda p, b, **kw: transformer.loss_fn(p, b, cfg, **kw),
            init_cache=lambda bs, ml, **kw: transformer.init_kv_cache(cfg, bs, ml, **kw),
            decode=lambda p, t, c: transformer.decode_step(p, t, c, cfg),
            prefill=lambda p, t, ml: transformer.prefill(p, t, cfg, ml),
        )
    if fam == "ssm":
        return ModelAPI(
            cfg=cfg,
            init=lambda rng: ssm_lm.init_params(rng, cfg),
            loss=lambda p, b, **kw: ssm_lm.loss_fn(p, b, cfg, **kw),
            init_cache=lambda bs, ml=0, **kw: ssm_lm.init_cache(cfg, bs, ml, **kw),
            decode=lambda p, t, c: ssm_lm.decode_step(p, t, c, cfg),
        )
    if fam == "hybrid":
        return ModelAPI(
            cfg=cfg,
            init=lambda rng: hybrid.init_params(rng, cfg),
            loss=lambda p, b, **kw: hybrid.loss_fn(p, b, cfg, **kw),
            init_cache=lambda bs, ml, **kw: hybrid.init_cache(cfg, bs, ml, **kw),
            decode=lambda p, t, c: hybrid.decode_step(p, t, c, cfg),
        )
    if fam == "audio":
        return ModelAPI(
            cfg=cfg,
            init=lambda rng: encdec.init_params(rng, cfg),
            loss=lambda p, b, **kw: encdec.loss_fn(p, b, cfg, **kw),
            init_cache=lambda bs, ml, **kw: encdec.init_cache(cfg, bs, ml, **kw),
            decode=lambda p, t, c: encdec.decode_step(p, t, c, cfg),
            prefill=lambda p, e, ml: encdec.prefill_cross(p, e, cfg, ml),
        )
    raise ValueError(f"unknown family {fam}")


# --------------------------------------------------------------------------- specs
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell.

    train/prefill cells feed ``loss_fn`` (prefill cost == one fwd pass);
    decode cells feed ``serve_step`` (handled by launch.dryrun, which also
    builds the cache spec via eval_shape)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.is_decode:
        specs["token"] = jax.ShapeDtypeStruct((B,), i32)
        return specs
    specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    if cfg.is_encoder_decoder:
        specs["enc_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.max_encoder_len, cfg.d_model), cfg.cdtype
        )
    return specs
