"""Pure Mamba2 LM (mamba2-130m): embed -> N SSD layers -> norm -> logits."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.launch.partitioning import shard
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.transformer import chunked_ce_loss, lm_head_weight

Params = Dict[str, Any]


class SSMLMCache(NamedTuple):
    layers: S.SSMCache  # leading dim [L]
    pos: jax.Array


def init_params(rng, cfg) -> Params:
    ks = jax.random.split(rng, 3)
    lkeys = jax.random.split(ks[0], cfg.num_layers)

    def one(k):
        k1, k2 = jax.random.split(k)
        return {"norm": jnp.ones((cfg.d_model,), cfg.pdtype), "ssm": S.init_ssm(k2, cfg)}

    p: Params = {
        "embed": L.embed_init(ks[1], cfg.vocab_size, cfg.d_model, cfg.pdtype),
        "layers": jax.vmap(one)(lkeys),
        "final_norm": jnp.ones((cfg.d_model,), cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(ks[2], cfg.d_model, cfg.vocab_size, cfg.pdtype)
    return p


def forward_hidden(params: Params, x: jax.Array, cfg, positions=None, *, remat="block",
                   collect_kv: bool = False):
    def body(h, lp):
        hn = L.rms_norm(h, lp["norm"], cfg.norm_eps)
        out, _ = S.ssm_forward(lp["ssm"], hn, cfg)
        return h + out, None

    if remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32), None


def loss_fn(params: Params, batch, cfg, *, remat: str = "block"):
    tokens, labels = batch["tokens"], batch["labels"]
    x = params["embed"][tokens].astype(cfg.cdtype)
    x = shard(x, "batch", "seq", None)
    h, aux, _ = forward_hidden(params, x, cfg, remat=remat)
    tot, cnt = chunked_ce_loss(h, lm_head_weight(params, cfg), labels, cfg)
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss, {"ce": loss, "aux": aux, "tokens": cnt}


def prefill(params: Params, tokens: jax.Array, cfg, max_len: int = 0):
    """Full-prompt forward, returning (last_logits, SSMLMCache)."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.cdtype)
    x = shard(x, "batch", "seq", None)
    init = S_init = None

    from repro.models import ssm as S_mod

    def body(h, inp):
        lp, c = inp
        hn = L.rms_norm(h, lp["norm"], cfg.norm_eps)
        out, c2 = S_mod.ssm_forward(lp["ssm"], hn, cfg, cache=c)
        return h + out, c2

    cache0 = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape),
        S_mod.init_ssm_cache(cfg, B),
    )
    x, caches = jax.lax.scan(body, x, (params["layers"], cache0))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, -1] @ lm_head_weight(params, cfg)).astype(jnp.float32)
    logits = shard(logits, "batch", "vocab")
    return logits, SSMLMCache(layers=caches, pos=jnp.asarray(S, jnp.int32))


def init_cache(cfg, batch: int, max_len: int = 0, dtype=None) -> SSMLMCache:
    one = S.init_ssm_cache(cfg, batch)
    stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape), one
    )
    return SSMLMCache(layers=stacked, pos=jnp.zeros((), jnp.int32))


def decode_step(params: Params, token: jax.Array, cache: SSMLMCache, cfg):
    x = params["embed"][token[:, None]].astype(cfg.cdtype)

    def body(h, inp):
        lp, c = inp
        hn = L.rms_norm(h, lp["norm"], cfg.norm_eps)
        out, c2 = S.ssm_decode_step(lp["ssm"], hn, c, cfg)
        return h + out, c2

    x, new_layers = jax.lax.scan(body, x, (params["layers"], cache.layers))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, 0] @ lm_head_weight(params, cfg)).astype(jnp.float32)
    logits = shard(logits, "batch", "vocab")
    return logits, SSMLMCache(layers=new_layers, pos=cache.pos + 1)
