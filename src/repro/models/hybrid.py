"""Zamba2-style hybrid: Mamba2 trunk + one *shared* (weight-tied) attention
block invoked after every ``cfg.attn_every`` SSM layers [arXiv:2411.15242].

Simplifications vs the released checkpoints (noted in DESIGN.md): the shared
block consumes the hidden state directly (no concat-with-embedding re-
projection, no per-invocation LoRA deltas). The shared attention runs with a
sliding window (cfg.sliding_window) so long_500k decode stays sub-quadratic.

Layer layout for L=38, attn_every=6:
  6 groups x (6 mamba layers -> shared attn+mlp) + 2 tail mamba layers.
Each shared-attn invocation has its own KV cache (weights shared, state not).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.launch.partitioning import shard
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.transformer import (
    block_decode,
    block_full,
    chunked_ce_loss,
    lm_head_weight,
)

Params = Dict[str, Any]


class HybridCache(NamedTuple):
    group_ssm: S.SSMCache  # leading dims [G, per_group]
    tail_ssm: S.SSMCache  # leading dim [n_tail]
    k: jax.Array  # [G, B, S, nkv, dh]
    v: jax.Array
    pos: jax.Array  # [] int32


def _layout(cfg) -> Tuple[int, int, int]:
    groups = cfg.attn_invocations
    per_group = cfg.attn_every
    tail = cfg.num_layers - groups * per_group
    return groups, per_group, tail


def init_mamba_layer(rng, cfg) -> Params:
    k1, k2 = jax.random.split(rng)
    return {"norm": jnp.ones((cfg.d_model,), cfg.pdtype), "ssm": S.init_ssm(k2, cfg)}


def init_params(rng, cfg) -> Params:
    groups, per_group, tail = _layout(cfg)
    ks = jax.random.split(rng, 4)
    gkeys = jax.random.split(ks[0], groups * per_group).reshape(groups, per_group, 2)
    p: Params = {
        "embed": L.embed_init(ks[1], cfg.vocab_size, cfg.d_model, cfg.pdtype),
        "mamba_groups": jax.vmap(jax.vmap(lambda k: init_mamba_layer(k, cfg)))(gkeys),
        "shared_attn": {
            "attn_norm": jnp.ones((cfg.d_model,), cfg.pdtype),
            "attn": L.init_attention(ks[2], cfg),
            "mlp_norm": jnp.ones((cfg.d_model,), cfg.pdtype),
            "mlp": L.init_mlp(ks[3], cfg),
        },
        "final_norm": jnp.ones((cfg.d_model,), cfg.pdtype),
    }
    if tail:
        tkeys = jax.random.split(jax.random.fold_in(rng, 7), tail)
        p["mamba_tail"] = jax.vmap(lambda k: init_mamba_layer(k, cfg))(tkeys)
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(jax.random.fold_in(rng, 9), cfg.d_model, cfg.vocab_size, cfg.pdtype)
    return p


def _mamba_layer(lp: Params, x: jax.Array, cfg, cache=None):
    h = L.rms_norm(x, lp["norm"], cfg.norm_eps)
    out, new_cache = S.ssm_forward(lp["ssm"], h, cfg, cache)
    return x + out, new_cache


# --------------------------------------------------------------------------- train
def forward_hidden(params: Params, x: jax.Array, cfg, positions, *, remat="block",
                   collect_kv: bool = False):
    groups, per_group, tail = _layout(cfg)
    shared = params["shared_attn"]

    def layer_body(h, lp):
        h, _ = _mamba_layer(lp, h, cfg)
        return h, None

    def group_body(h, gp):
        h, _ = jax.lax.scan(layer_body, h, gp)
        h, _, kv = block_full(shared, h, cfg, positions)
        return h, kv if collect_kv else None

    if remat != "none":
        layer_body = jax.checkpoint(layer_body, prevent_cse=False)
        group_body = jax.checkpoint(group_body, prevent_cse=False)

    x, kv = jax.lax.scan(group_body, x, params["mamba_groups"])
    if tail:
        x, _ = jax.lax.scan(layer_body, x, params["mamba_tail"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32), kv


def loss_fn(params: Params, batch, cfg, *, remat: str = "block"):
    tokens, labels = batch["tokens"], batch["labels"]
    B, Sq = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    x = params["embed"][tokens].astype(cfg.cdtype)
    x = shard(x, "batch", "seq", None)
    h, aux, _ = forward_hidden(params, x, cfg, positions, remat=remat)
    tot, cnt = chunked_ce_loss(h, lm_head_weight(params, cfg), labels, cfg)
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss + aux, {"ce": loss, "aux": aux, "tokens": cnt}


def prefill(params: Params, tokens: jax.Array, cfg, max_len: int = 0):
    """Full-prompt forward; builds SSM states + ring-buffer attention KV.

    The ring buffer stores key position p at slot p % window, matching
    decode_step's write pattern."""
    B, Sq = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    x = params["embed"][tokens].astype(cfg.cdtype)
    x = shard(x, "batch", "seq", None)
    groups, per_group, tail = _layout(cfg)
    shared = params["shared_attn"]

    from repro.models import ssm as S_mod

    def layer_body(h, inp):
        lp, c = inp
        hn = L.rms_norm(h, lp["norm"], cfg.norm_eps)
        out, c2 = S_mod.ssm_forward(lp["ssm"], hn, cfg, cache=c)
        return h + out, c2

    cache0 = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (per_group,) + a.shape),
        S_mod.init_ssm_cache(cfg, B),
    )
    g_cache0 = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (groups,) + a.shape), cache0
    )

    def group_body(h, inp):
        gp, gc = inp
        h, gc2 = jax.lax.scan(layer_body, h, (gp, gc))
        h, _, kv = block_full(shared, h, cfg, positions)
        return h, (gc2, kv)

    x, (g_ssm, kv) = jax.lax.scan(group_body, x, (params["mamba_groups"], g_cache0))
    tail_ssm = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (max(tail, 1),) + a.shape),
        S_mod.init_ssm_cache(cfg, B),
    )
    if tail:
        x, tail_ssm = jax.lax.scan(layer_body, x, (params["mamba_tail"], tail_ssm))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, -1] @ lm_head_weight(params, cfg)).astype(jnp.float32)
    logits = shard(logits, "batch", "vocab")

    # pack the last `window` keys into ring-buffer order. The ring geometry
    # must match init_cache's (min(max_len, sliding_window)) or the slot
    # mapping diverges after handoff to decode_step.
    k_full, v_full = kv  # [G, B, Sq, nkv, dh]
    max_len = max(max_len, Sq)
    window = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    keep = min(Sq, window)
    lo = Sq - keep
    slots = (jnp.arange(lo, Sq)) % window
    kc = jnp.zeros(
        (groups, B, window, cfg.num_kv_heads, cfg.d_head), cfg.cdtype
    ).at[:, :, slots].set(k_full[:, :, lo:Sq].astype(cfg.cdtype))
    vc = jnp.zeros_like(kc).at[:, :, slots].set(v_full[:, :, lo:Sq].astype(cfg.cdtype))
    cache = HybridCache(
        group_ssm=g_ssm, tail_ssm=tail_ssm, k=kc, v=vc,
        pos=jnp.asarray(Sq, jnp.int32),
    )
    return logits, cache


# --------------------------------------------------------------------------- decode
def init_cache(cfg, batch: int, max_len: int, dtype=None) -> HybridCache:
    groups, per_group, tail = _layout(cfg)
    dt = dtype or cfg.cdtype
    one = S.init_ssm_cache(cfg, batch)

    def stack(n, tree):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), tree)

    kv_len = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    kv_shape = (groups, batch, kv_len, cfg.num_kv_heads, cfg.d_head)
    return HybridCache(
        group_ssm=stack(groups, stack(per_group, one)),
        tail_ssm=stack(max(tail, 1), one),
        k=jnp.zeros(kv_shape, dt),
        v=jnp.zeros(kv_shape, dt),
        pos=jnp.zeros((), jnp.int32),
    )


def decode_step(params: Params, token: jax.Array, cache: HybridCache, cfg):
    """One decode step. token: [B]. Sliding-window KV: position pos is written
    at slot pos % window (ring buffer), attention masks by recency."""
    groups, per_group, tail = _layout(cfg)
    B = token.shape[0]
    x = params["embed"][token[:, None]].astype(cfg.cdtype)
    pos = cache.pos
    shared = params["shared_attn"]
    window = cache.k.shape[2]
    slot = pos % window

    def layer_body(h, inp):
        lp, c = inp
        hn = L.rms_norm(h, lp["norm"], cfg.norm_eps)
        out, c2 = S.ssm_decode_step(lp["ssm"], hn, c, cfg)
        return h + out, c2

    def group_body(h, inp):
        gp, gc, kc, vc = inp
        h, gc2 = jax.lax.scan(layer_body, h, (gp, gc))
        # shared attention with ring-buffer KV
        hn = L.rms_norm(h, shared["attn_norm"], cfg.norm_eps)
        q, k, v = L.qkv_project(shared["attn"], hn, cfg)
        positions = jnp.full((B, 1), pos, jnp.int32)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), slot, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), slot, 1)
        n_valid = jnp.minimum(pos + 1, window)
        o = L.decode_attention(q, kc, vc, n_valid)  # ring: all written slots valid
        h = h + o.reshape(B, 1, -1) @ shared["attn"]["w_o"]
        hn = L.rms_norm(h, shared["mlp_norm"], cfg.norm_eps)
        h = h + L.mlp(shared["mlp"], hn, cfg)
        return h, (gc2, kc, vc)

    x, (g_ssm, k_new, v_new) = jax.lax.scan(
        group_body, x, (params["mamba_groups"], cache.group_ssm, cache.k, cache.v)
    )
    tail_ssm = cache.tail_ssm
    if tail:
        x, tail_ssm = jax.lax.scan(layer_body, x, (params["mamba_tail"], cache.tail_ssm))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, 0] @ lm_head_weight(params, cfg)).astype(jnp.float32)
    logits = shard(logits, "batch", "vocab")
    return logits, HybridCache(
        group_ssm=g_ssm, tail_ssm=tail_ssm, k=k_new, v=v_new, pos=pos + 1
    )
