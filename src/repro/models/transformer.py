"""Decoder-only transformer assembly (dense / MoE / VLM backbones).

Layers are stacked along a leading axis and iterated with ``lax.scan`` so the
HLO stays O(1) in depth (fast compiles at 64 layers, small dry-run graphs).
Per-layer remat (``jax.checkpoint``) wraps the scan body.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.launch.partitioning import shard
from repro.models import layers as L
from repro.models import tuning
from repro.models.moe import init_moe, moe_mlp

Params = Dict[str, Any]

REMAT_POLICIES = {
    "none": None,  # no remat
    "block": "recompute_all",  # recompute everything within a layer
    "dots": "dots_saveable",
}


class KVCache(NamedTuple):
    """Dense (contiguous) decode cache. k/v: [L, B, S_max, nkv, dh]."""

    k: jax.Array
    v: jax.Array
    pos: jax.Array  # [] int32 — tokens already in cache


# --------------------------------------------------------------------------- init
def init_block(rng, cfg) -> Params:
    ks = jax.random.split(rng, 3)
    d = cfg.d_model
    p: Params = {
        "attn_norm": jnp.ones((d,), cfg.pdtype),
        "attn": L.init_attention(ks[0], cfg),
        "mlp_norm": jnp.ones((d,), cfg.pdtype),
    }
    if cfg.is_moe:
        p["moe"] = init_moe(ks[1], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg)
    return p


def init_params(rng, cfg) -> Params:
    ks = jax.random.split(rng, 4)
    layer_keys = jax.random.split(ks[0], cfg.num_layers)
    p: Params = {
        "embed": L.embed_init(ks[1], cfg.vocab_size, cfg.d_model, cfg.pdtype),
        "layers": jax.vmap(lambda k: init_block(k, cfg))(layer_keys),
        "final_norm": jnp.ones((cfg.d_model,), cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(ks[2], cfg.d_model, cfg.vocab_size, cfg.pdtype)
    return p


# --------------------------------------------------------------------------- block
def _attn_full(lp: Params, x: jax.Array, cfg, positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence attention (train / prefill). Returns (out, k, v)."""
    h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = L.qkv_project(lp["attn"], h, cfg)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    o = L.blocked_attention(
        q, k, v, causal=True, sliding_window=cfg.sliding_window,
        q_block=tuning.FLAGS.q_block, kv_block=tuning.FLAGS.kv_block,
    )
    o = o.reshape(*x.shape[:2], -1) @ lp["attn"]["w_o"]
    return o, k, v


def block_full(lp: Params, x: jax.Array, cfg, positions: jax.Array):
    """One decoder layer over a full sequence. Returns (x, aux, (k, v))."""
    o, k, v = _attn_full(lp, x, cfg, positions)
    x = x + o
    h = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    if cfg.is_moe:
        m, aux = moe_mlp(lp["moe"], h, cfg)
    else:
        m, aux = L.mlp(lp["mlp"], h, cfg), jnp.zeros((), jnp.float32)
    if tuning.FLAGS.seq_parallel_activations and not cfg.is_moe:
        # Megatron-style sequence parallelism: the residual stream is
        # model-axis sharded between layers; XLA inserts the ag/rs pair.
        h2 = shard(x + m, "batch", "seq_sp", None)
    else:
        h2 = shard(x + m, "batch", "seq", None)
    return h2, aux, (k, v)


def block_decode(lp: Params, x: jax.Array, cfg, k_cache, v_cache, pos):
    """One decoder layer for a single new token.

    x: [B, 1, d]; k_cache/v_cache: [B, S, nkv, dh]; pos: [] int32.
    Returns (x, k_cache, v_cache).
    """
    B = x.shape[0]
    h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = L.qkv_project(lp["attn"], h, cfg)
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), pos, 1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), pos, 1)
    o = L.decode_attention(
        q, k_cache, v_cache, pos + 1, sliding_window=cfg.sliding_window
    )
    x = x + o.reshape(B, 1, -1) @ lp["attn"]["w_o"]
    h = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    if cfg.is_moe:
        m, _ = moe_mlp(lp["moe"], h, cfg)
    else:
        m = L.mlp(lp["mlp"], h, cfg)
    return x + m, k_cache, v_cache


# --------------------------------------------------------------------------- forward
def embed_tokens(params: Params, tokens: jax.Array, cfg) -> jax.Array:
    x = params["embed"][tokens].astype(cfg.cdtype)
    return shard(x, "batch", "seq", None)


def forward_hidden(
    params: Params,
    x: jax.Array,
    cfg,
    positions: jax.Array,
    *,
    remat: str = "block",
    collect_kv: bool = False,
):
    """Run the layer stack. x: [B, S, d]. Returns (hidden, aux, kv|None)."""

    def body(carry, lp):
        h, aux = carry
        h, a, kv = block_full(lp, h, cfg, positions)
        ys = kv if collect_kv else None
        return (h, aux + a), ys

    if remat != "none":
        policy = None
        if remat == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)

    (h, aux), kv = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["layers"])
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, aux, kv


def lm_head_weight(params: Params, cfg) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T  # [d, V]
    return params["lm_head"]


def chunked_ce_loss(
    hidden: jax.Array,  # [B, S, d]
    head: jax.Array,  # [d, V]
    labels: jax.Array,  # [B, S] int32, -1 = ignore
    cfg,
    chunk: int = 0,
) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy scanned over sequence chunks: peak memory is
    [B, chunk, V] logits instead of [B, S, V]. Returns (sum_loss, n_valid)."""
    B, S, d = hidden.shape
    V = head.shape[1]
    if chunk <= 0:
        # target <= ~64 MB fp32 logits per chunk (pre-sharding)
        chunk = max(16, min(S, int(64e6 / max(B * V * 4, 1)) or 16))
        chunk = max(16, 1 << (chunk.bit_length() - 1))
        chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (S + pad) // chunk
    hc = hidden.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, chunk).transpose(1, 0, 2)

    def step(carry, inp):
        tot, cnt = carry
        h, lab = inp  # [B, chunk, d], [B, chunk]
        ldt = jnp.bfloat16 if tuning.FLAGS.loss_logits_bf16 else jnp.float32
        logits = (h @ head).astype(ldt)  # [B, chunk, V]
        logits = shard(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        lab_c = jnp.clip(lab, 0, V - 1)
        ll = jnp.take_along_axis(logits, lab_c[..., None], axis=-1)[..., 0].astype(jnp.float32)
        valid = (lab >= 0).astype(jnp.float32)
        tot = tot + ((lse - ll) * valid).sum()
        cnt = cnt + valid.sum()
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros(()), jnp.zeros(())), (hc, lc))
    return tot, cnt


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg, *, remat: str = "block"):
    """Next-token LM loss. batch: tokens [B, S], labels [B, S] (-1 ignore)."""
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = embed_tokens(params, tokens, cfg)
    h, aux, _ = forward_hidden(params, x, cfg, positions, remat=remat)
    tot, cnt = chunked_ce_loss(h, lm_head_weight(params, cfg), labels, cfg)
    loss = tot / jnp.maximum(cnt, 1.0)
    metrics = {"ce": loss, "aux": aux, "tokens": cnt}
    return loss + aux, metrics


# --------------------------------------------------------------------------- decode
def init_kv_cache(cfg, batch: int, max_len: int, dtype=None) -> KVCache:
    dt = dtype or cfg.cdtype
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.d_head)
    return KVCache(
        k=jnp.zeros(shape, dt), v=jnp.zeros(shape, dt), pos=jnp.zeros((), jnp.int32)
    )


def shard_kv_cache(cache: KVCache) -> KVCache:
    return KVCache(
        k=shard(cache.k, None, "batch", "kv_seq", "kv_heads", None),
        v=shard(cache.v, None, "batch", "kv_seq", "kv_heads", None),
        pos=cache.pos,
    )


def prefill(params: Params, tokens: jax.Array, cfg, max_len: int):
    """Process a full prompt; returns (last_logits, KVCache of size max_len)."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = embed_tokens(params, tokens, cfg)
    h, _, kv = forward_hidden(params, x, cfg, positions, remat="none", collect_kv=True)
    k, v = kv  # [L, B, S, nkv, dh]
    pad = max_len - S
    if pad > 0:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = shard_kv_cache(
        KVCache(k=k.astype(cfg.cdtype), v=v.astype(cfg.cdtype), pos=jnp.asarray(S, jnp.int32))
    )
    logits = (h[:, -1:] @ lm_head_weight(params, cfg)).astype(jnp.float32)
    return logits, cache


def _block_decode_deferred(lp, x, cfg, k_cache, v_cache, pos):
    """block_decode that does NOT mutate the cache: attention runs over the
    existing ``pos`` tokens (read-only) and the current token's key/value are
    merged into the softmax exactly; returns the new (k, v) for a post-scan
    batched commit."""
    B = x.shape[0]
    h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = L.qkv_project(lp["attn"], h, cfg)
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    nkv, dh = cfg.num_kv_heads, cfg.d_head
    g = cfg.num_heads // nkv
    acc, m, l = L.decode_attention_stats(
        q, k_cache, v_cache, pos, sliding_window=cfg.sliding_window
    )
    # merge the current token: score q·k_new, value v_new
    qg = q.reshape(B, 1, nkv, g, dh)
    s_new = jnp.einsum(
        "bqngd,bqnd->bngq", qg, k, preferred_element_type=jnp.float32
    ) / jnp.sqrt(jnp.asarray(dh, jnp.float32))  # [B,nkv,g,1]
    m2 = jnp.maximum(m, s_new)
    w_c = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m2))
    w_n = jnp.exp(s_new - m2)
    acc2 = acc * w_c[..., None] + w_n[..., None] * v.astype(jnp.float32).reshape(
        B, 1, nkv, 1, dh
    ).transpose(0, 2, 3, 1, 4)
    l2 = l * w_c + w_n
    o = (acc2 / jnp.maximum(l2[..., None], 1e-30)).astype(x.dtype)
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, 1, cfg.num_heads * dh)
    x = x + o @ lp["attn"]["w_o"]
    h = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    if cfg.is_moe:
        mo, _ = moe_mlp(lp["moe"], h, cfg)
    else:
        mo = L.mlp(lp["mlp"], h, cfg)
    return x + mo, k, v


def decode_step(params: Params, token: jax.Array, cache: KVCache, cfg):
    """One decode step. token: [B] int32. Returns (logits [B, V], cache)."""
    B = token.shape[0]
    x = embed_tokens(params, token[:, None], cfg)
    pos = cache.pos

    if tuning.FLAGS.decode_deferred_commit:
        def body(h, inp):
            lp, kc, vc = inp
            h, k_new, v_new = _block_decode_deferred(lp, h, cfg, kc, vc, pos)
            return h, (k_new.astype(kc.dtype), v_new.astype(vc.dtype))

        h, (k_tok, v_tok) = jax.lax.scan(
            body, x, (params["layers"], cache.k, cache.v)
        )
        # one small commit for ALL layers: [L, B, 1, nkv, dh] at seq pos
        k_all = jax.lax.dynamic_update_slice(cache.k, k_tok, (0, 0, pos, 0, 0))
        v_all = jax.lax.dynamic_update_slice(cache.v, v_tok, (0, 0, pos, 0, 0))
        new_cache = shard_kv_cache(KVCache(k=k_all, v=v_all, pos=pos + 1))
    else:
        def body(h, inp):
            lp, kc, vc = inp
            h, kc, vc = block_decode(lp, h, cfg, kc, vc, pos)
            return h, (kc, vc)

        h, (k_new, v_new) = jax.lax.scan(body, x, (params["layers"], cache.k, cache.v))
        new_cache = shard_kv_cache(KVCache(k=k_new, v=v_new, pos=pos + 1))
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = (h[:, 0] @ lm_head_weight(params, cfg)).astype(jnp.float32)
    logits = shard(logits, "batch", "vocab")
    return logits, new_cache
