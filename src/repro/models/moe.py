"""Mixture-of-Experts block: top-k routing + capacity grouped matmul.

Dispatch strategy (TPU-native): instead of GShard's [T, E, C] one-hot einsum
(memory-hostile at Big-Data batch sizes) we compute per-assignment slots with a
one-hot cumsum rank, scatter tokens into an [E, C, d] buffer, and run the
expert FFNs as one batched einsum. With experts sharded over the "model" mesh
axis this lowers to an all-to-all-style resharding + per-device grouped GEMM.

Dropped tokens (beyond capacity) fall through via the residual connection,
standard for capacity-factor routing. An auxiliary load-balance loss follows
Switch/GShard.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.launch.partitioning import shard
from repro.models import tuning
from repro.models.layers import dense_init

Params = Dict[str, Any]


def _padded_experts(cfg) -> int:
    return max(cfg.num_experts, cfg.expert_pad_to or 0)


def init_moe(rng, cfg) -> Params:
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    Ep = _padded_experts(cfg)  # weight arrays padded for even EP sharding
    ks = jax.random.split(rng, 5)
    dt = cfg.pdtype
    scale = 1.0 / math.sqrt(d)
    p: Params = {
        "router": dense_init(ks[0], d, E, jnp.float32, scale=scale),
        "w_gate": (jax.random.normal(ks[1], (Ep, d, ff), jnp.float32) * scale).astype(dt),
        "w_up": (jax.random.normal(ks[2], (Ep, d, ff), jnp.float32) * scale).astype(dt),
        "w_down": (
            jax.random.normal(ks[3], (Ep, ff, d), jnp.float32) / math.sqrt(ff)
        ).astype(dt),
    }
    if cfg.num_shared_experts:
        sf = cfg.num_shared_experts * ff
        sks = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(sks[0], d, sf, dt),
            "w_up": dense_init(sks[1], d, sf, dt),
            "w_down": dense_init(sks[2], sf, d, dt),
        }
    return p


def _capacity(tokens: int, cfg) -> int:
    cf = tuning.FLAGS.capacity_factor or cfg.capacity_factor
    cap = int(math.ceil(tokens * cfg.moe_top_k / cfg.num_experts * cf))
    # keep lane-aligned for TPU
    return max(8, ((cap + 7) // 8) * 8)


def moe_mlp(params: Params, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    if tuning.FLAGS.moe_shardmap:
        from repro.launch import partitioning as _pt

        ctx = _pt._current()
        if ctx is not None:
            mesh, rules = ctx
            return moe_mlp_shardmap(params, x, cfg, mesh, rules)
    B, S, d = x.shape
    T = B * S
    E, k = cfg.num_experts, cfg.moe_top_k
    Ep = _padded_experts(cfg)
    C = _capacity(T, cfg)
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ params["router"]).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_ids = jax.lax.top_k(probs, k)  # [T, k]
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=0)  # [E]
    assign = jax.nn.one_hot(gate_ids[:, 0], E, dtype=jnp.float32)  # top-1 fraction
    ce = assign.mean(axis=0)
    aux = E * jnp.sum(me * ce) * cfg.router_aux_weight

    # ----- slot computation: rank within expert via one-hot cumsum ---------
    flat_ids = gate_ids.reshape(T * k)  # assignment order: token-major
    oh = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)  # [TK, E]
    pos_in_expert = jnp.cumsum(oh, axis=0) - 1  # rank of each assignment
    rank = jnp.take_along_axis(pos_in_expert, flat_ids[:, None], axis=1)[:, 0]  # [TK]
    valid = rank < C
    rank_c = jnp.minimum(rank, C - 1)

    # ----- dispatch: masked scatter-add into [E, C, d] ----------------------
    # (add of masked values: valid assignments own unique (e, c) slots, so no
    # collisions; dropped assignments contribute zero. Keeps the [E, C, d]
    # layout intact so the "experts" sharding annotation survives.)
    token_idx = jnp.repeat(jnp.arange(T), k)
    contrib = xf[token_idx] * valid[:, None].astype(x.dtype)
    xe = jnp.zeros((Ep, C, d), x.dtype).at[flat_ids, rank_c].add(contrib)
    if tuning.FLAGS.moe_explicit_a2a:
        # scatter stays token-local (C over data), then one explicit
        # resharding to expert-parallel layout = the dispatch all-to-all
        xe = shard(xe, None, "a2a_cap", None)
        xe = shard(xe, "experts", None, None)
    else:
        xe = shard(xe, "experts_buf", "expert_cap", None)

    # ----- expert FFN: batched grouped GEMM ---------------------------------
    g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"])  # [E, C, d]
    if tuning.FLAGS.moe_explicit_a2a:
        ye = shard(ye, "experts", None, None)
        ye = shard(ye, None, "a2a_cap", None)  # combine all-to-all back
    else:
        ye = shard(ye, "experts_buf", "expert_cap", None)

    # ----- combine: gather back, weight, sum over k --------------------------
    per_assign = ye[flat_ids, rank_c] * (
        gate_w.reshape(T * k, 1) * valid[:, None]
    ).astype(ye.dtype)
    out = per_assign.reshape(T, k, d).sum(axis=1)

    if cfg.num_shared_experts:
        sp = params["shared"]
        gs = xf @ sp["w_gate"]
        us = xf @ sp["w_up"]
        out = out + (jax.nn.silu(gs) * us) @ sp["w_down"]

    return out.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Token-motion-free expert parallelism (§Perf, beyond-paper optimization).
#
# Dry-run attribution finding: with pjit-annotation dispatch the partitioner
# materializes/reshards the GLOBAL [E, C, d] buffer (O(T·d) f32 wire bytes
# per layer). But activations are REPLICATED over the "model" axis in this
# framework's layout — each device already holds all the tokens of its data
# shard AND a slice of the experts. So dispatch can be 100% local:
#
#   each device: route local tokens -> local buffer for ITS experts only
#                -> grouped GEMM -> partial token outputs
#   one psum over "model" combines the partials (T_local · d bytes).
#
# Token dropping becomes per-(device, expert) instead of global (same
# expected drop rate, different tail pattern — documented in EXPERIMENTS).
# ---------------------------------------------------------------------------
def moe_mlp_shardmap(
    params: Params, x: jax.Array, cfg, mesh, rules
) -> Tuple[jax.Array, jax.Array]:
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.moe_top_k
    Ep = _padded_experts(cfg)
    dp_axes = rules.get("batch") or ()
    dp_axes = (dp_axes,) if isinstance(dp_axes, str) else tuple(dp_axes)
    model_ax = "model"
    m_size = mesh.shape[model_ax]
    ep_sharded = Ep % m_size == 0
    E_local = Ep // m_size if ep_sharded else Ep
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    T_local = (B // dp_size if B % dp_size == 0 else B) * S
    cf = tuning.FLAGS.capacity_factor or cfg.capacity_factor
    C_dev = max(8, int(math.ceil(T_local * k / E * cf / 8.0)) * 8)

    bspec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    x_spec = P(bspec, None, None)
    w_spec = P(model_ax if ep_sharded else None, None, None)
    sf = cfg.num_shared_experts * cfg.moe_d_ff
    shared_ff_sharded = ep_sharded and cfg.num_shared_experts and sf % m_size == 0
    sg_spec = P(None, model_ax) if shared_ff_sharded else P(None, None)
    sd_spec = P(model_ax, None) if shared_ff_sharded else P(None, None)

    def local_fn(xl, router, wg, wu, wd, sg, su, sd):
        Bl, Sl, _ = xl.shape
        Tl = Bl * Sl
        xf = xl.reshape(Tl, d)
        logits = (xf.astype(jnp.float32) @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, gate_ids = jax.lax.top_k(probs, k)
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

        me = probs.mean(axis=0)
        ce = jax.nn.one_hot(gate_ids[:, 0], E, dtype=jnp.float32).mean(axis=0)
        aux_l = E * jnp.sum(me * ce) * cfg.router_aux_weight
        if dp_axes:
            aux_l = jax.lax.pmean(aux_l, dp_axes)

        # local ranks across ALL experts (local compute, no wire traffic)
        flat_ids = gate_ids.reshape(Tl * k)
        oh = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)
        rank = jnp.take_along_axis(
            jnp.cumsum(oh, axis=0) - 1, flat_ids[:, None], axis=1
        )[:, 0]
        # keep only assignments to THIS device's expert slice
        e_lo = (jax.lax.axis_index(model_ax) * E_local) if ep_sharded else 0
        local_e = flat_ids - e_lo
        mine = (local_e >= 0) & (local_e < wg.shape[0]) & (rank < C_dev)
        le = jnp.clip(local_e, 0, wg.shape[0] - 1)
        rc = jnp.minimum(rank, C_dev - 1)
        token_idx = jnp.repeat(jnp.arange(Tl), k)
        contrib = xf[token_idx] * mine[:, None].astype(xl.dtype)
        xe = jnp.zeros((wg.shape[0], C_dev, d), xl.dtype).at[le, rc].add(contrib)

        g = jnp.einsum("ecd,edf->ecf", xe, wg)
        u = jnp.einsum("ecd,edf->ecf", xe, wu)
        h = jax.nn.silu(g) * u
        ye = jnp.einsum("ecf,efd->ecd", h, wd)

        per = ye[le, rc] * (gate_w.reshape(Tl * k, 1) * mine[:, None]).astype(ye.dtype)
        out = per.reshape(Tl, k, d).sum(axis=1)
        if cfg.num_shared_experts and shared_ff_sharded:
            # shared experts ff-sharded over the SAME axis: partial sums ride
            # the same psum as the routed experts (one collective total)
            out = out + (jax.nn.silu(xf @ sg) * (xf @ su)) @ sd
        if ep_sharded:
            out = jax.lax.psum(out, model_ax)  # the ONLY cross-model traffic
        if cfg.num_shared_experts and not shared_ff_sharded:
            out = out + (jax.nn.silu(xf @ sg) * (xf @ su)) @ sd
        return out.reshape(Bl, Sl, d), aux_l

    sp = params.get("shared")
    sg = sp["w_gate"] if sp else jnp.zeros((d, 0), x.dtype)
    su = sp["w_up"] if sp else jnp.zeros((d, 0), x.dtype)
    sd = sp["w_down"] if sp else jnp.zeros((0, d), x.dtype)
    out, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            x_spec, P(None, None), w_spec, w_spec, w_spec,
            sg_spec, sg_spec, sd_spec,
        ),
        out_specs=(x_spec, P()),
        check_rep=False,
    )(
        x, params["router"],
        params["w_gate"], params["w_up"], params["w_down"],
        sg, su, sd,
    )
    return out, aux
