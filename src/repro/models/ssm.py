"""Mamba2 / SSD (state-space duality) layer [arXiv:2405.21060].

Chunked SSD algorithm for training/prefill (within-chunk quadratic attention-
like form + inter-chunk linear recurrence via lax.scan), and the O(1)-state
recurrent form for decode. Pure JAX; reductions in fp32.

Decay exponents are sums of negative terms, so every ``exp`` here is <= 1 —
numerically safe without max-subtraction.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import tuning
from repro.models.layers import dense_init, rms_norm

Params = Dict[str, Any]


class SSMCache(NamedTuple):
    conv: jax.Array  # [B, W-1, conv_dim] most recent inputs
    state: jax.Array  # [B, H, P, N] fp32


def _dims(cfg):
    d = cfg.d_model
    di = cfg.ssm_d_inner
    H = cfg.ssm_heads
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    G = cfg.ssm_n_groups
    conv_dim = di + 2 * G * N
    return d, di, H, P, N, G, conv_dim


def init_ssm(rng, cfg) -> Params:
    d, di, H, P, N, G, conv_dim = _dims(cfg)
    assert G == 1, "ssm_n_groups > 1 not implemented"
    ks = jax.random.split(rng, 6)
    dt = cfg.pdtype
    d_in_proj = 2 * di + 2 * G * N + H
    # dt bias: softplus(dt_bias) ~ Uniform(log 1e-3, log 1e-1) exp
    dt0 = jnp.exp(
        jax.random.uniform(ks[0], (H,), jnp.float32)
        * (math.log(1e-1) - math.log(1e-3))
        + math.log(1e-3)
    )
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))
    return {
        "in_proj": dense_init(ks[1], d, d_in_proj, dt),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv_width, conv_dim), jnp.float32)
                   / math.sqrt(cfg.ssm_conv_width)).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(
            jax.random.uniform(ks[3], (H,), jnp.float32, minval=1.0, maxval=16.0)
        ),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias,
        "norm_w": jnp.ones((di,), dt),
        "out_proj": dense_init(ks[4], di, d, dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d as shift-and-multiply. x: [B, L, C], w: [W, C].

    W is tiny (4): unrolled shifts keep FLOPs at 2·W·B·L·C and — unlike
    ``lax.conv_general_dilated`` with feature groups — the filter gradient
    stays depthwise instead of exploding into a full [C, C] cross-correlation
    (XLA lowers grouped-conv grads without batch_group_count; measured 100x
    FLOP blowup in the dry-run, see EXPERIMENTS.md §Dry-run)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    L = x.shape[1]
    out = b
    for i in range(W):
        out = out + xp[:, i : i + L, :] * w[i]
    return out


def ssd_scan(
    xh: jax.Array,  # [B, L, H, P]  (pre-dt)
    dt: jax.Array,  # [B, L, H]     (post-softplus)
    A_log: jax.Array,  # [H]
    Bm: jax.Array,  # [B, L, N]
    Cm: jax.Array,  # [B, L, N]
    chunk: int,
    initial_state: Optional[jax.Array] = None,  # [B, H, P, N] fp32
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y [B, L, H, P], final_state [B, H, P, N])."""
    Bsz, L, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Lp = L + pad
    nc = Lp // Q

    A = -jnp.exp(A_log.astype(jnp.float32))  # [H], negative
    dA = dt.astype(jnp.float32) * A  # [B, Lp, H] log-decay increments (<=0)
    xdt = (xh * dt[..., None]).astype(xh.dtype)  # discretized input

    # chunked views
    dAc = dA.reshape(Bsz, nc, Q, H)
    ac = jnp.cumsum(dAc, axis=2)  # [B,c,Q,H] fp32
    xc = xdt.reshape(Bsz, nc, Q, H, P)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    # 1) within-chunk (diagonal) term
    seg = ac[:, :, :, None, :] - ac[:, :, None, :, :]  # [B,c,Q(i),Q(j),H]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)  # fp32
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc, preferred_element_type=jnp.float32)
    W = (CB[..., None] * Lmat).astype(xh.dtype)  # [B,c,Q,Q,H]
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", W, xc, preferred_element_type=jnp.float32)

    # 2) end-of-chunk states from within-chunk inputs
    decay_states = jnp.exp(ac[:, :, -1:, :] - ac)  # [B,c,Q,H]
    states = jnp.einsum(
        "bcjn,bcjh,bcjhp->bchpn",
        Bc.astype(jnp.float32),
        decay_states,
        xc.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )  # [B,c,H,P,N]

    # 3) inter-chunk recurrence (sequential scan over chunks)
    chunk_decay = jnp.exp(ac[:, :, -1, :])  # [B,c,H]
    h0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((Bsz, H, P, N), jnp.float32)
    )

    def step(h, inp):
        s_c, g_c = inp  # [B,H,P,N], [B,H]
        h_new = h * g_c[:, :, None, None] + s_c
        return h_new, h  # emit state *entering* the chunk

    final_state, h_prev = jax.lax.scan(
        step,
        h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)  # [B,c,H,P,N]

    # 4) contribution of entering state to outputs
    state_decay = jnp.exp(ac)  # [B,c,Q,H]
    y_off = jnp.einsum(
        "bcin,bchpn,bcih->bcihp",
        Cc.astype(jnp.float32),
        h_prev,
        state_decay,
        preferred_element_type=jnp.float32,
    )

    y = (y_diag + y_off).reshape(Bsz, Lp, H, P)[:, :L]
    return y.astype(xh.dtype), final_state


def ssm_forward(
    params: Params,
    x: jax.Array,  # [B, L, d]
    cfg,
    cache: Optional[SSMCache] = None,
) -> Tuple[jax.Array, Optional[SSMCache]]:
    """Full-sequence Mamba2 layer (train/prefill)."""
    d, di, H, P, N, G, conv_dim = _dims(cfg)
    B, L, _ = x.shape
    zxbcdt = x @ params["in_proj"]  # [B, L, 2di + 2N + H]
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : di + conv_dim]
    dt = zxbcdt[..., di + conv_dim :]
    xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"], params["conv_b"]))
    xs = xBC[..., :di].reshape(B, L, H, P)
    Bm = xBC[..., di : di + N]
    Cm = xBC[..., di + N :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B, L, H]

    chunk = tuning.FLAGS.ssd_chunk or cfg.ssm_chunk
    y, final_state = ssd_scan(xs, dt, params["A_log"], Bm, Cm, chunk)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, L, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], cfg.norm_eps)
    out = y @ params["out_proj"]

    new_cache = None
    if cache is not None:
        Wd = cfg.ssm_conv_width
        # conv state: last W-1 raw xBC inputs (pre-conv)
        raw = zxbcdt[..., di : di + conv_dim]
        tail = raw[:, -(Wd - 1) :, :]
        pad = (Wd - 1) - tail.shape[1]
        if pad > 0:
            tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
        new_cache = SSMCache(conv=tail, state=final_state)
    return out, new_cache


def init_ssm_cache(cfg, batch: int) -> SSMCache:
    d, di, H, P, N, G, conv_dim = _dims(cfg)
    return SSMCache(
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), cfg.cdtype),
        state=jnp.zeros((batch, H, P, N), jnp.float32),
    )


def ssm_decode_step(
    params: Params, x: jax.Array, cache: SSMCache, cfg
) -> Tuple[jax.Array, SSMCache]:
    """One-token recurrent step. x: [B, 1, d]."""
    d, di, H, P, N, G, conv_dim = _dims(cfg)
    B = x.shape[0]
    zxbcdt = (x @ params["in_proj"])[:, 0]  # [B, ...]
    z = zxbcdt[:, :di]
    xBC_new = zxbcdt[:, di : di + conv_dim]
    dt = zxbcdt[:, di + conv_dim :]

    # causal conv over (state ++ new)
    win = jnp.concatenate([cache.conv, xBC_new[:, None, :]], axis=1)  # [B, W, C]
    conv_out = jnp.einsum("bwc,wc->bc", win, params["conv_w"]) + params["conv_b"]
    xBC = jax.nn.silu(conv_out)
    xs = xBC[:, :di].reshape(B, H, P)
    Bm = xBC[:, di : di + N]
    Cm = xBC[:, di + N :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B, H]

    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    g = jnp.exp(dt * A)  # [B, H]
    delta = (
        dt[:, :, None, None]
        * xs.astype(jnp.float32)[:, :, :, None]
        * Bm.astype(jnp.float32)[:, None, None, :]
    )  # [B,H,P,N]
    h = cache.state * g[:, :, None, None] + delta
    y = jnp.einsum("bhpn,bn->bhp", h, Cm.astype(jnp.float32))
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], cfg.norm_eps)
    out = (y @ params["out_proj"])[:, None, :]  # [B, 1, d]
    return out, SSMCache(conv=win[:, 1:], state=h)
