"""Perf-tuning flags (§Perf hillclimb knobs).

A process-global mutable config consulted at TRACE time by the model code.
The dry-run/hillclimb harness sets flags, lowers, measures, resets. Defaults
reproduce the paper-faithful baseline recorded in EXPERIMENTS.md §Roofline.

This is deliberately not part of ModelConfig: architecture configs are
immutable published facts; these are implementation/schedule choices.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional


@dataclasses.dataclass
class TuningFlags:
    # attention: dtype of materialized score/probability tensors in the
    # XLA (non-Pallas) blocked-attention path. fp32 = baseline.
    attn_score_f32: bool = True
    # attention block sizes for the blocked path
    q_block: int = 512
    kv_block: int = 1024
    # residual stream sharded over the model axis between layers
    # (Megatron-style sequence parallelism; XLA inserts ag/rs at boundaries).
    # Applied to non-MoE blocks only (conflicts with moe_shardmap's in_specs).
    seq_parallel_activations: bool = True
    # MoE: shard the dispatch buffer's capacity dim over the data axes so the
    # scatter stays shard-local (baseline: expert dim sharded => XLA
    # materializes the GLOBAL [E, C, d] buffer per device)
    moe_shard_capacity: bool = False
    # MoE: 2-D dispatch buffer sharding (E over model AND C over data)
    moe_shard_both: bool = False
    # MoE: scatter into a C-sharded buffer, then explicitly re-anchor to the
    # E-sharded layout before the expert GEMM (forces a real all-to-all
    # instead of leaving the resharding choice to the partitioner)
    moe_explicit_a2a: bool = False
    # MoE: token-motion-free shard_map expert parallelism (see moe.py)
    moe_shardmap: bool = True
    # decode: read-only cache in the layer scan; new k/v committed in ONE
    # small DUS after the scan (avoids XLA's per-layer full-cache f32
    # round-trip — measured 68x the physical cache traffic). Exact math via
    # online-softmax merge of the current token.
    decode_deferred_commit: bool = True
    # serving: replicate weights across the data axes (no FSDP gathers per
    # decode step; weights are TP-sharded only — standard inference layout)
    serve_resident_weights: bool = True
    # MoE capacity factor override (baseline: cfg.capacity_factor = 1.25)
    capacity_factor: Optional[float] = None
    # chunked CE loss: logits compute dtype (False = fp32 baseline)
    loss_logits_bf16: bool = False
    # SSD chunk length override (0 = cfg.ssm_chunk). Within-chunk quadratic
    # work scales with Q; inter-chunk state materialization with L/Q.
    ssd_chunk: int = 0
    # rms_norm: keep only the variance/scale in fp32 (the [B,S,1] factor);
    # the full-width multiply stays in compute dtype. Baseline: full fp32.
    norm_bf16_apply: bool = False


FLAGS = TuningFlags()  # consumers: `from repro.models import tuning` then
# `tuning.FLAGS.<attr>` at trace time (one shared object, mutated in place)


@contextlib.contextmanager
def tuned(**kw):
    prev = {k: getattr(FLAGS, k) for k in kw}
    for k, v in kw.items():
        setattr(FLAGS, k, v)
    try:
        yield FLAGS
    finally:
        for k, v in prev.items():
            setattr(FLAGS, k, v)
