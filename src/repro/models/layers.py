"""Shared model building blocks (pure JAX, functional, param dicts).

Conventions:
  - params are nested dicts of jnp arrays
  - activations flow as [batch, seq, d_model] in ``cfg.compute_dtype``
  - reductions (norms, softmax) accumulate in fp32
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import tuning

Params = Dict[str, Any]


# --------------------------------------------------------------------------- init
def dense_init(rng, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(rng, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(rng, vocab: int, dim: int, dtype):
    return (jax.random.normal(rng, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------- norms
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    if tuning.FLAGS.norm_bf16_apply and dt != jnp.float32:
        # fp32 only for the reduction; the [B,S,1] scale applies in bf16 so
        # the full-width tensors (and their cotangents -> TP collectives)
        # stay at 2 bytes. §Perf knob.
        scale = jax.lax.rsqrt(var + eps).astype(dt)
        return (x * scale) * weight
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------- rope
def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, d_head]; positions: [..., seq] (int)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)  # [d_head/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, d/2]
    cos = jnp.cos(angles)[..., :, None, :]  # [..., seq, 1, d/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- mlp
def init_mlp(rng, cfg) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 3)
    dt = cfg.pdtype
    if cfg.activation in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], d, ff, dt),
            "w_up": dense_init(ks[1], d, ff, dt),
            "w_down": dense_init(ks[2], ff, d, dt),
        }
    return {
        "w_up": dense_init(ks[0], d, ff, dt),
        "w_down": dense_init(ks[1], ff, d, dt),
    }


def mlp(params: Params, x: jax.Array, cfg) -> jax.Array:
    act = cfg.activation
    if act == "swiglu":
        g = x @ params["w_gate"]
        u = x @ params["w_up"]
        h = jax.nn.silu(g) * u
    elif act == "geglu":
        g = x @ params["w_gate"]
        u = x @ params["w_up"]
        h = jax.nn.gelu(g) * u
    elif act == "squared_relu":
        h = jnp.square(jax.nn.relu(x @ params["w_up"]))
    elif act == "gelu":
        h = jax.nn.gelu(x @ params["w_up"])
    else:
        raise ValueError(f"unknown activation {act}")
    return h @ params["w_down"]


# --------------------------------------------------------------------------- attention
def init_attention(rng, cfg, d_model: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    dh, nh, nkv = cfg.d_head, cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(rng, 4)
    dt = cfg.pdtype
    p: Params = {
        "w_q": dense_init(ks[0], d, nh * dh, dt),
        "w_k": dense_init(ks[1], d, nkv * dh, dt),
        "w_v": dense_init(ks[2], d, nkv * dh, dt),
        "w_o": dense_init(ks[3], nh * dh, d, dt),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((nh * dh,), dt)
        p["b_k"] = jnp.zeros((nkv * dh,), dt)
        p["b_v"] = jnp.zeros((nkv * dh,), dt)
    if cfg.use_qk_norm:
        p["q_norm"] = jnp.ones((dh,), dt)
        p["k_norm"] = jnp.ones((dh,), dt)
    return p


def qkv_project(params: Params, x: jax.Array, cfg):
    """x: [B, S, d] -> q [B, S, nh, dh], k/v [B, S, nkv, dh]."""
    B, S, _ = x.shape
    q = x @ params["w_q"]
    k = x @ params["w_k"]
    v = x @ params["w_v"]
    if "b_q" in params:
        q = q + params["b_q"]
        k = k + params["b_k"]
        v = v + params["b_v"]
    q = q.reshape(B, S, cfg.num_heads, cfg.d_head)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.d_head)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.d_head)
    if "q_norm" in params:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    return q, k, v


def blocked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_block: int = 512,
    kv_block: int = 1024,
    sliding_window: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    """Memory-efficient (flash-style) attention in pure JAX.

    q: [B, Sq, nh, dh]; k, v: [B, Skv, nkv, dh] with nh % nkv == 0.
    Online-softmax over kv blocks via lax.scan, so peak score memory is
    [B, nh, q_block, kv_block] rather than [B, nh, Sq, Skv].
    Returns [B, Sq, nh, dh].
    """
    B, Sq, nh, dh = q.shape
    Skv, nkv = k.shape[1], k.shape[2]
    scale = 1.0 / math.sqrt(dh)

    # GQA-expand KV to full heads: keeps the head dim uniform so TP sharding
    # (heads -> "model") stays aligned. On real TPU the Pallas flash kernel
    # dedups the reads; here the expansion is a cheap broadcast.
    if nkv != nh:
        rep = nh // nkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    pq = (-Sq) % q_block
    pk = (-Skv) % kv_block
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = (Sq + pq) // q_block, (Skv + pk) // kv_block

    qb = q.reshape(B, nq, q_block, nh, dh).transpose(0, 3, 1, 2, 4)  # [B,h,nq,qb,dh]
    kb = k.reshape(B, nk, kv_block, nh, dh).transpose(1, 0, 3, 2, 4)  # [nk,B,h,kb,dh]
    vb = v.reshape(B, nk, kv_block, nh, dh).transpose(1, 0, 3, 2, 4)

    q_pos = q_offset + jnp.arange(nq * q_block).reshape(nq, q_block)
    kv_pos = jnp.arange(nk * kv_block).reshape(nk, kv_block)
    kv_valid = (jnp.arange(nk * kv_block) < Skv).reshape(nk, kv_block)

    # §Perf knob: dtype of the materialized score/probability tensors.
    # fp32 = paper-faithful baseline; bf16 halves the dominant HBM traffic
    # of the XLA attention path (the Pallas kernel keeps them in VMEM).
    sdt = jnp.float32 if tuning.FLAGS.attn_score_f32 else jnp.bfloat16

    def kv_step(carry, inputs):
        acc, m, l = carry  # acc [B,h,nq,qb,dh], m/l [B,h,nq,qb]
        k_j, v_j, kpos_j, kvalid_j = inputs  # [B,h,kb,dh], [kb], [kb]
        s = jnp.einsum(
            "bhqtd,bhkd->bhqtk", qb, k_j, preferred_element_type=sdt
        ) * jnp.asarray(scale, sdt)  # [B,h,nq,qb,kb]
        mask = jnp.broadcast_to(kvalid_j[None, None, :], (nq, q_block, kv_block))
        if causal:
            mask = mask & (kpos_j[None, None, :] <= q_pos[:, :, None])
        if sliding_window:
            mask = mask & (kpos_j[None, None, :] > q_pos[:, :, None] - sliding_window)
        neg = jnp.asarray(-jnp.inf, sdt)
        s = jnp.where(mask[None, None], s, neg)
        m_new = jnp.maximum(m, s.max(axis=-1).astype(jnp.float32))
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)  # fully-masked rows
        # one materialized p tensor in sdt: (sub, exp, where) fuse into it
        p = jnp.where(
            mask[None, None],
            jnp.exp(s - m_safe[..., None].astype(sdt)),
            jnp.asarray(0.0, sdt),
        )
        corr = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
        l_new = l * corr + p.sum(axis=-1).astype(jnp.float32)
        acc = acc * corr[..., None].astype(sdt) + jnp.einsum(
            "bhqtk,bhkd->bhqtd", p.astype(v_j.dtype), v_j,
            preferred_element_type=sdt,
        )
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((B, nh, nq, q_block, dh), sdt)
    m0 = jnp.full((B, nh, nq, q_block), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, nh, nq, q_block), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), (kb, vb, kv_pos, kv_valid))
    out = acc / jnp.maximum(l[..., None], 1e-37)
    out = out.transpose(0, 2, 3, 1, 4).reshape(B, nq * q_block, nh, dh)
    return out[:, :Sq].astype(q.dtype)


def decode_attention_stats(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    length: jax.Array | int,
    *,
    sliding_window: int = 0,
):
    """decode_attention returning (out_unnormalized, m, l) online-softmax
    stats so callers can merge additional keys exactly (deferred cache
    commit, §Perf). out = acc / l recovers the normalized result."""
    B, S, nkv, dh = k_cache.shape
    nh = q.shape[2]
    group = nh // nkv
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(B, 1, nkv, group, dh)
    s = jnp.einsum(
        "bqngd,bknd->bngqk", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale
    pos = jnp.arange(S)
    mask = pos[None, :] < jnp.asarray(length).reshape(-1, 1)
    if sliding_window:
        mask = mask & (pos[None, :] >= jnp.asarray(length).reshape(-1, 1) - sliding_window)
    s = jnp.where(mask[:, None, None, None, :], s, -jnp.inf)
    m = s.max(axis=-1)  # [B,nkv,g,1]
    m_safe = jnp.where(jnp.isneginf(m), 0.0, m)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(mask[:, None, None, None, :], p, 0.0)
    l = p.sum(axis=-1)
    acc = jnp.einsum(
        "bngqk,bknd->bngqd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )  # [B,nkv,g,1,dh] unnormalized
    return acc, m, l


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    length: jax.Array | int,
    *,
    sliding_window: int = 0,
) -> jax.Array:
    """Single-token decode attention.

    q: [B, 1, nh, dh]; k_cache/v_cache: [B, S, nkv, dh]; length: current
    context length (static or traced scalar). Returns [B, 1, nh, dh].
    """
    B, S, nkv, dh = k_cache.shape
    nh = q.shape[2]
    group = nh // nkv
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(B, 1, nkv, group, dh)
    # q [B,1,nkv,g,dh] x k [B,S,nkv,dh] -> [B,nkv,g,1,S]
    s = jnp.einsum(
        "bqngd,bknd->bngqk", qg, k_cache, preferred_element_type=jnp.float32
    )
    s = s * scale
    pos = jnp.arange(S)
    mask = pos[None, :] < jnp.asarray(length).reshape(-1, 1)  # [B or 1, S]
    if sliding_window:
        mask = mask & (pos[None, :] >= jnp.asarray(length).reshape(-1, 1) - sliding_window)
    s = jnp.where(mask[:, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bngqk,bknd->bngqd",
        p.astype(v_cache.dtype),
        v_cache,
        preferred_element_type=jnp.float32,
    )  # [B,nkv,g,1,dh]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, 1, nh, dh).astype(q.dtype)
