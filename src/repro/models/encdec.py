"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

The conv audio frontend is a STUB: inputs are precomputed frame embeddings
[B, enc_len, d] (what the 2x strided conv1d stem would produce). Whisper uses
absolute positions; we use on-the-fly sinusoidal embeddings (parameter-free)
so decoder shape cells beyond the original 448-token max are well-defined.
Pre-LN LayerNorm blocks with biases, GELU MLPs, MHA (kv == heads).
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.launch.partitioning import shard
from repro.models import layers as L
from repro.models.transformer import chunked_ce_loss

Params = Dict[str, Any]


class EncDecCache(NamedTuple):
    k: jax.Array  # [L, B, S, h, dh] decoder self-attn
    v: jax.Array
    ck: jax.Array  # [L, B, enc_len, h, dh] cross-attn (static after prefill)
    cv: jax.Array
    pos: jax.Array


def sinusoid(positions: jax.Array, d: int) -> jax.Array:
    """positions [...,] -> [..., d] sinusoidal embedding (fp32)."""
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _ln_params(d, dt):
    return {"w": jnp.ones((d,), dt), "b": jnp.zeros((d,), dt)}


def _ln(x, p, eps):
    return L.layer_norm(x, p["w"], p["b"], eps)


def init_enc_layer(rng, cfg) -> Params:
    k1, k2 = jax.random.split(rng)
    d = cfg.d_model
    return {
        "attn_norm": _ln_params(d, cfg.pdtype),
        "attn": L.init_attention(k1, cfg),
        "mlp_norm": _ln_params(d, cfg.pdtype),
        "mlp": L.init_mlp(k2, cfg),
    }


def init_dec_layer(rng, cfg) -> Params:
    k1, k2, k3 = jax.random.split(rng, 3)
    d = cfg.d_model
    return {
        "attn_norm": _ln_params(d, cfg.pdtype),
        "attn": L.init_attention(k1, cfg),
        "cross_norm": _ln_params(d, cfg.pdtype),
        "cross": L.init_attention(k2, cfg),
        "mlp_norm": _ln_params(d, cfg.pdtype),
        "mlp": L.init_mlp(k3, cfg),
    }


def init_params(rng, cfg) -> Params:
    ks = jax.random.split(rng, 4)
    ekeys = jax.random.split(ks[0], cfg.encoder_layers)
    dkeys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "embed": L.embed_init(ks[2], cfg.vocab_size, cfg.d_model, cfg.pdtype),
        "enc_layers": jax.vmap(lambda k: init_enc_layer(k, cfg))(ekeys),
        "enc_norm": _ln_params(cfg.d_model, cfg.pdtype),
        "dec_layers": jax.vmap(lambda k: init_dec_layer(k, cfg))(dkeys),
        "final_norm": _ln_params(cfg.d_model, cfg.pdtype),
    }


# --------------------------------------------------------------------------- encoder
def encode(params: Params, enc_embeds: jax.Array, cfg, *, remat="block") -> jax.Array:
    """enc_embeds: [B, T, d] stub frontend output."""
    B, T, d = enc_embeds.shape
    x = enc_embeds.astype(cfg.cdtype) + sinusoid(jnp.arange(T), d).astype(cfg.cdtype)
    x = shard(x, "batch", "enc_seq", None)

    def body(h, lp):
        a = _ln(h, lp["attn_norm"], cfg.norm_eps)
        q, k, v = L.qkv_project(lp["attn"], a, cfg)
        o = L.blocked_attention(q, k, v, causal=False)
        h = h + o.reshape(B, T, -1) @ lp["attn"]["w_o"]
        m = _ln(h, lp["mlp_norm"], cfg.norm_eps)
        h = h + L.mlp(lp["mlp"], m, cfg)
        return h, None

    if remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return _ln(x, params["enc_norm"], cfg.norm_eps)


# --------------------------------------------------------------------------- decoder
def _dec_layer_full(lp, x, enc_out, cfg, B, Sq):
    a = _ln(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = L.qkv_project(lp["attn"], a, cfg)
    o = L.blocked_attention(q, k, v, causal=True)
    x = x + o.reshape(B, Sq, -1) @ lp["attn"]["w_o"]
    c = _ln(x, lp["cross_norm"], cfg.norm_eps)
    qc, _, _ = L.qkv_project(lp["cross"], c, cfg)
    _, kc, vc = L.qkv_project(lp["cross"], enc_out, cfg)
    oc = L.blocked_attention(qc, kc, vc, causal=False)
    x = x + oc.reshape(B, Sq, -1) @ lp["cross"]["w_o"]
    m = _ln(x, lp["mlp_norm"], cfg.norm_eps)
    return x + L.mlp(lp["mlp"], m, cfg)


def loss_fn(params: Params, batch, cfg, *, remat: str = "block"):
    """batch: enc_embeds [B, T, d], tokens [B, S], labels [B, S]."""
    tokens, labels = batch["tokens"], batch["labels"]
    B, Sq = tokens.shape
    enc_out = encode(params, batch["enc_embeds"], cfg, remat=remat)
    x = params["embed"][tokens].astype(cfg.cdtype)
    x = x + sinusoid(jnp.arange(Sq), cfg.d_model).astype(cfg.cdtype)
    x = shard(x, "batch", "seq", None)

    def body(h, lp):
        return _dec_layer_full(lp, h, enc_out, cfg, B, Sq), None

    if remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = _ln(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T  # whisper ties output head to embedding
    tot, cnt = chunked_ce_loss(x, head, labels, cfg)
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss, {"ce": loss, "aux": jnp.zeros(()), "tokens": cnt}


# --------------------------------------------------------------------------- decode
def init_cache(cfg, batch: int, max_len: int, dtype=None) -> EncDecCache:
    dt = dtype or cfg.cdtype
    Ld = cfg.num_layers
    h, dh = cfg.num_kv_heads, cfg.d_head
    T = cfg.max_encoder_len
    return EncDecCache(
        k=jnp.zeros((Ld, batch, max_len, h, dh), dt),
        v=jnp.zeros((Ld, batch, max_len, h, dh), dt),
        ck=jnp.zeros((Ld, batch, T, h, dh), dt),
        cv=jnp.zeros((Ld, batch, T, h, dh), dt),
        pos=jnp.zeros((), jnp.int32),
    )


def prefill_cross(params: Params, enc_embeds: jax.Array, cfg, max_len: int) -> EncDecCache:
    """Encode + precompute per-layer cross-attn K/V."""
    enc_out = encode(params, enc_embeds, cfg, remat="none")
    B = enc_out.shape[0]

    def per_layer(lp):
        _, kc, vc = L.qkv_project(lp["cross"], enc_out, cfg)
        return kc.astype(cfg.cdtype), vc.astype(cfg.cdtype)

    ck, cv = jax.vmap(per_layer)(params["dec_layers"])  # [L, B, T, h, dh]
    base = init_cache(cfg, B, max_len)
    return base._replace(ck=ck, cv=cv)


def prefill(params: Params, enc_embeds: jax.Array, tokens: jax.Array, cfg, max_len: int):
    """Encoder + teacher-forced decoder prefill: builds the full EncDecCache."""
    enc_out = encode(params, enc_embeds, cfg, remat="none")
    B, Sq = tokens.shape
    x = params["embed"][tokens].astype(cfg.cdtype)
    x = x + sinusoid(jnp.arange(Sq), cfg.d_model).astype(cfg.cdtype)
    x = shard(x, "batch", "seq", None)

    def body(h, lp):
        a = _ln(h, lp["attn_norm"], cfg.norm_eps)
        q, k, v = L.qkv_project(lp["attn"], a, cfg)
        o = L.blocked_attention(q, k, v, causal=True)
        h = h + o.reshape(B, Sq, -1) @ lp["attn"]["w_o"]
        c = _ln(h, lp["cross_norm"], cfg.norm_eps)
        qc, _, _ = L.qkv_project(lp["cross"], c, cfg)
        _, kc, vc = L.qkv_project(lp["cross"], enc_out, cfg)
        oc = L.blocked_attention(qc, kc, vc, causal=False)
        h = h + oc.reshape(B, Sq, -1) @ lp["cross"]["w_o"]
        m = _ln(h, lp["mlp_norm"], cfg.norm_eps)
        h = h + L.mlp(lp["mlp"], m, cfg)
        return h, (k.astype(cfg.cdtype), v.astype(cfg.cdtype),
                   kc.astype(cfg.cdtype), vc.astype(cfg.cdtype))

    x, (k, v, ck, cv) = jax.lax.scan(body, x, params["dec_layers"])
    x = _ln(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, -1] @ params["embed"].T).astype(jnp.float32)
    logits = shard(logits, "batch", "vocab")
    pad = max_len - Sq
    if pad > 0:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return logits, EncDecCache(k=k, v=v, ck=ck, cv=cv, pos=jnp.asarray(Sq, jnp.int32))


def decode_step(params: Params, token: jax.Array, cache: EncDecCache, cfg):
    B = token.shape[0]
    pos = cache.pos
    x = params["embed"][token[:, None]].astype(cfg.cdtype)
    x = x + sinusoid(jnp.full((1,), pos), cfg.d_model).astype(cfg.cdtype)

    def body(h, inp):
        lp, kc, vc, cck, ccv = inp
        a = _ln(h, lp["attn_norm"], cfg.norm_eps)
        q, k, v = L.qkv_project(lp["attn"], a, cfg)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, 1)
        o = L.decode_attention(q, kc, vc, pos + 1)
        h = h + o.reshape(B, 1, -1) @ lp["attn"]["w_o"]
        c = _ln(h, lp["cross_norm"], cfg.norm_eps)
        qc, _, _ = L.qkv_project(lp["cross"], c, cfg)
        oc = L.decode_attention(qc, cck, ccv, cck.shape[1])
        h = h + oc.reshape(B, 1, -1) @ lp["cross"]["w_o"]
        m = _ln(h, lp["mlp_norm"], cfg.norm_eps)
        return h + L.mlp(lp["mlp"], m, cfg), (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec_layers"], cache.k, cache.v, cache.ck, cache.cv)
    )
    x = _ln(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, 0] @ params["embed"].T).astype(jnp.float32)
    logits = shard(logits, "batch", "vocab")
    return logits, cache._replace(k=k_new, v=v_new, pos=pos + 1)
