"""qwen2-moe-a2.7b: 60 routed experts top-4 + 4 shared
[hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    activation="swiglu",
    qkv_bias=True,
    num_experts=60,
    num_shared_experts=4,
    moe_top_k=4,
    moe_d_ff=1408,
    expert_pad_to=64,  # even 16-way EP sharding; routing stays over 60

    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
