"""mamba2-130m: pure SSM, SSD (state-space duality) [arXiv:2405.21060].

Attention-free: no KV cache, so MaxMem KV-page tiering is inapplicable
(DESIGN.md §4) — the arch is fully implemented and dry-run without the
technique. Runs the long_500k cell (O(1) state decode).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,  # attention-free
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
