"""Architecture config registry: ``get_config("yi-6b")`` etc."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (
    LM_SHAPES,
    LONG_CONTEXT_ARCHS,
    SHAPES_BY_NAME,
    ModelConfig,
    ShapeConfig,
    applicable_shapes,
)

_ARCH_MODULES: Dict[str, str] = {
    "yi-6b": "repro.configs.yi_6b",
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "whisper-tiny": "repro.configs.whisper_tiny",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return importlib.import_module(_ARCH_MODULES[name]).CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}


def get_shape(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]


__all__ = [
    "ARCH_NAMES",
    "LM_SHAPES",
    "LONG_CONTEXT_ARCHS",
    "ModelConfig",
    "ShapeConfig",
    "all_configs",
    "applicable_shapes",
    "get_config",
    "get_shape",
]
