"""chameleon-34b: early-fusion VLM, VQ image tokens [arXiv:2405.09818].

The transformer backbone only; image VQ tokenizer frontend is a stub —
``input_specs()`` provides precomputed token ids drawn from the unified
(text + image-codebook) vocabulary. Uses qk-norm as in the paper.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    activation="swiglu",
    use_qk_norm=True,
    frontend="vision_stub",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
