"""whisper-tiny: encoder-decoder, conv audio frontend (stubbed)
[arXiv:2212.04356].

Backbone only: ``input_specs()`` provides precomputed frame embeddings
(the 2x conv1d stem output) for the encoder; decoder is a standard causal
transformer with cross-attention. 4 encoder + 4 decoder layers.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,  # decoder layers
    encoder_layers=4,
    is_encoder_decoder=True,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    activation="gelu",
    max_encoder_len=1500,
    frontend="audio_stub",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
