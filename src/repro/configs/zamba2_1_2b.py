"""zamba2-1.2b: hybrid Mamba2 stack + shared attention blocks [arXiv:2411.15242].

38 Mamba2 layers; one *shared* (weight-tied) attention+MLP block is invoked
after every 6th SSM layer (6 invocations). Attention is MHA (kv=32 heads).
The shared block uses a sliding window at long context so the hybrid stays
sub-quadratic end to end (noted in DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    activation="gelu",
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,  # §Perf cell D: +12% step-time bound vs Q=128
    attn_every=6,
    sliding_window=4096,
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
