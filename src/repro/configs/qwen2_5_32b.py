"""qwen2.5-32b: dense GQA with QKV bias [hf:Qwen/Qwen2.5 family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    activation="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
