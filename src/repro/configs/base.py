"""Config system: model architecture configs + input-shape configs.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` exporting
``CONFIG: ModelConfig`` built from the exact published dimensions. Reduced
("smoke") variants are derived mechanically via ``ModelConfig.smoke()`` so CPU
tests instantiate the same code paths at toy scale.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. All families share this one config record."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    # -- head geometry ------------------------------------------------------
    d_head: int = 0  # 0 -> d_model // num_heads

    # -- block flavor --------------------------------------------------------
    activation: str = "swiglu"  # swiglu | squared_relu | geglu | gelu
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    use_qk_norm: bool = False

    # -- MoE -----------------------------------------------------------------
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0  # per-expert FFN width
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # pad expert weight arrays to this count for even EP sharding (0 = none);
    # routing stays over the REAL num_experts (dead pad experts never hit)
    expert_pad_to: int = 0

    # -- SSM (Mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128  # SSD chunk length
    ssm_n_groups: int = 1

    # -- hybrid (zamba2-style shared attention blocks) ------------------------
    attn_every: int = 0  # insert shared attn+mlp block after every k SSM layers

    # -- encoder-decoder (whisper-style) --------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    max_encoder_len: int = 1_500  # whisper: 30s audio -> 1500 frames

    # -- modality frontend stub ----------------------------------------------
    frontend: str = "none"  # none | audio_stub | vision_stub

    # -- numerics --------------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    # -- long context ----------------------------------------------------------
    sliding_window: int = 0  # 0 = full attention (hybrid archs cap attn window)

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // max(self.num_heads, 1))

    # ------------------------------------------------------------------ props
    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.family == "ssm"

    @property
    def is_hybrid(self) -> bool:
        return self.family == "hybrid"

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def attn_invocations(self) -> int:
        """Number of shared-attention invocations in a hybrid stack."""
        if self.attn_every <= 0:
            return 0
        return self.num_layers // self.attn_every

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, dh = self.d_model, self.d_head
        n = 0
        n += self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d  # lm head
        if self.family in ("dense", "moe", "vlm"):
            per = self._attn_params() + self._mlp_params() + 2 * d
            n += self.num_layers * per
        elif self.family == "ssm":
            n += self.num_layers * (self._ssm_params() + d)
        elif self.family == "hybrid":
            n += self.num_layers * (self._ssm_params() + d)
            # one shared attn+mlp block
            n += self._attn_params() + self._mlp_params() + 2 * d
        elif self.family == "audio":
            enc = self.encoder_layers * (self._attn_params() + self._mlp_params() + 2 * d)
            dec = self.num_layers * (2 * self._attn_params() + self._mlp_params() + 3 * d)
            n += enc + dec
        n += d  # final norm
        return n

    def active_param_count(self) -> int:
        """Per-token active parameters (differs from total for MoE)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.num_layers * self._mlp_params()
        act_mlp = (self.moe_top_k + self.num_shared_experts) * 3 * d * self.moe_d_ff
        act_mlp += d * self.num_experts  # router
        return dense + self.num_layers * act_mlp

    def _attn_params(self) -> int:
        d, dh = self.d_model, self.d_head
        qkv = d * (self.num_heads * dh) + 2 * d * (self.num_kv_heads * dh)
        if self.qkv_bias:
            qkv += (self.num_heads + 2 * self.num_kv_heads) * dh
        return qkv + self.num_heads * dh * d

    def _mlp_params(self) -> int:
        d = self.d_model
        if self.is_moe:
            per_expert = 3 * d * self.moe_d_ff
            return (
                self.num_experts * per_expert
                + self.num_shared_experts * per_expert
                + d * self.num_experts
            )
        if self.activation in ("swiglu", "geglu"):
            return 3 * d * self.d_ff
        return 2 * d * self.d_ff

    def _ssm_params(self) -> int:
        d, di, ns = self.d_model, self.ssm_d_inner, self.ssm_state
        g = self.ssm_n_groups
        h = self.ssm_heads
        in_proj = d * (2 * di + 2 * g * ns + h)
        conv = (di + 2 * g * ns) * self.ssm_conv_width
        out = di * d
        extra = 3 * h  # A_log, D, dt_bias
        return in_proj + conv + out + extra + di  # + gate norm

    # ------------------------------------------------------------------ smoke
    def smoke(self) -> "ModelConfig":
        """Reduced config of the same family for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=max(2, min(3, self.num_layers)),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(max(1, self.num_kv_heads * 4 // max(self.num_heads, 1)), 4),
            d_head=16,
            d_ff=128,
            vocab_size=256,
            moe_d_ff=32 if self.is_moe else 0,
            num_experts=8 if self.is_moe else 0,
            moe_top_k=min(self.moe_top_k, 2) if self.is_moe else 0,
            num_shared_experts=min(self.num_shared_experts, 1),
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=16,
            attn_every=2 if self.attn_every else 0,
            encoder_layers=2 if self.is_encoder_decoder else 0,
            max_encoder_len=32,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            param_dtype="float32",
            compute_dtype="float32",
        )


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per architecture)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


LM_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in LM_SHAPES}

# Archs allowed to run the sub-quadratic long-context cell.
LONG_CONTEXT_ARCHS = ("zamba2-1.2b", "mamba2-130m")


def applicable_shapes(config: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """Shape cells applicable to an arch (skips noted in DESIGN.md §4)."""
    out = []
    for s in LM_SHAPES:
        if s.name == "long_500k" and config.name not in LONG_CONTEXT_ARCHS:
            continue  # pure full-attention archs skip 500k decode
        out.append(s)
    return tuple(out)
