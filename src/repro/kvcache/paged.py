"""Tiered paged KV cache.

A *logical page* (what MaxMem tracks and migrates) is a block of
``page_tokens`` consecutive tokens of one sequence, spanning ALL layers and
both K and V — for yi-6b with 16-token pages that is ~0.5 MB, i.e. exactly a
huge-page-sized migration unit (DESIGN.md §2).

Physically, pools are [L, n_slots, page, nkv, dh] for K and V. Slots
[0, n_fast) live in the fast tier (HBM), slots [n_fast, n_slots) in the slow
tier (host memory via ``pinned_host`` on real TPU). ``slot_of`` maps logical
page id -> physical slot; migration copies slot contents across the boundary
and rewrites the mapping — block tables hold logical ids and never change.

Page heat summaries (Quest-style per-page key min/max) ride along for the
top-k page selector in the serving engine.

Free/reuse invariant (DESIGN.md §8): the slot of an unallocated logical page
always holds zeroed K/V content and reset (±inf) Quest summaries. Two paths
maintain it: :meth:`TieredPagedKV.free_pages` scrubs slots when a sequence
finishes, and :meth:`TieredPagedKV.migrate` re-scrubs the vacated source
rows its swaps hand to free holders (``page_move`` has gather semantics, so
a swapped-out row otherwise retains a stale copy of the migrated page).
Without the invariant, a reused page's ``write_tokens`` folds max/min
against the PREVIOUS owner's summaries, corrupting Quest top-k selection.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.manager import CentralManager
from repro.core.types import TIER_FAST, TIER_SLOW, MigrationPlan
from repro.kernels import ops


class TieredPagedKV:
    def __init__(
        self,
        cfg,
        n_fast_slots: int,
        n_slow_slots: int,
        page_tokens: int = 16,
        dtype=jnp.float32,
    ):
        self.cfg = cfg
        self.page = page_tokens
        self.n_fast = n_fast_slots
        self.n_slots = n_fast_slots + n_slow_slots
        L, nkv, dh = cfg.num_layers, cfg.num_kv_heads, cfg.d_head
        self.k_pool = jnp.zeros((L, self.n_slots, page_tokens, nkv, dh), dtype)
        self.v_pool = jnp.zeros((L, self.n_slots, page_tokens, nkv, dh), dtype)
        # Quest summaries (per layer): elementwise min/max of keys in the page
        self.k_max = jnp.full((L, self.n_slots, nkv, dh), -jnp.inf, jnp.float32)
        self.k_min = jnp.full((L, self.n_slots, nkv, dh), jnp.inf, jnp.float32)
        # logical page id -> physical slot. Identity at boot: manager hands
        # out page ids with tier semantics (id < n_fast iff fast at alloc).
        self.slot_of = np.arange(self.n_slots, dtype=np.int32)
        self._slot_owner = np.full(self.n_slots, -1, np.int32)  # logical page or -1

    # ------------------------------------------------------------ mapping
    def slots_for(self, logical_pages: np.ndarray) -> np.ndarray:
        return self.slot_of[np.asarray(logical_pages)]

    def page_bytes(self) -> int:
        L, nkv, dh = self.cfg.num_layers, self.cfg.num_kv_heads, self.cfg.d_head
        return L * 2 * self.page * nkv * dh * self.k_pool.dtype.itemsize

    # ------------------------------------------------------------ writes
    def write_tokens(
        self,
        layer_kv: Tuple[jax.Array, jax.Array],  # k,v: [L, B, T, nkv, dh]
        logical_pages: np.ndarray,  # [B, n_pages_of_write] logical ids
        start_pos: int,
    ) -> None:
        """Scatter T tokens (from prefill) into pages. Host-side loop over
        pages — prefill writes are not the steady-state hot path."""
        k, v = layer_kv
        L, B, T, nkv, dh = k.shape
        p = self.page
        for b in range(B):
            for j in range((start_pos + T + p - 1) // p):
                lo = max(j * p - start_pos, 0)
                hi = min((j + 1) * p - start_pos, T)
                if hi <= lo:
                    continue
                slot = int(self.slot_of[int(logical_pages[b, j])])
                off = (start_pos + lo) % p
                kb = k[:, b, lo:hi]
                vb = v[:, b, lo:hi]
                self.k_pool = jax.lax.dynamic_update_slice(
                    self.k_pool, kb[:, None].astype(self.k_pool.dtype), (0, slot, off, 0, 0)
                )
                self.v_pool = jax.lax.dynamic_update_slice(
                    self.v_pool, vb[:, None].astype(self.v_pool.dtype), (0, slot, off, 0, 0)
                )
                kmax = jnp.maximum(self.k_max[:, slot], kb.max(axis=1).astype(jnp.float32))
                kmin = jnp.minimum(self.k_min[:, slot], kb.min(axis=1).astype(jnp.float32))
                self.k_max = self.k_max.at[:, slot].set(kmax)
                self.k_min = self.k_min.at[:, slot].set(kmin)

    def _scrub_slots(self, slots: np.ndarray) -> None:
        """Reset the given physical slots to the free-slot state: zero K/V
        content, ±inf Quest summaries (one fused device update per pool)."""
        if len(slots) == 0:
            return
        s = jnp.asarray(np.asarray(slots, np.int32))
        self.k_pool = self.k_pool.at[:, s].set(0)
        self.v_pool = self.v_pool.at[:, s].set(0)
        self.k_max = self.k_max.at[:, s].set(-jnp.inf)
        self.k_min = self.k_min.at[:, s].set(jnp.inf)

    def free_pages(self, logical_pages) -> None:
        """Scrub the slots of freed logical pages (call BEFORE or after the
        manager's ``free`` — the slot mapping is engine-owned either way).

        Without this, a reused page's ``write_tokens`` does maximum/minimum
        against the previous owner's stale Quest summaries — corrupting
        top-k page selection — and its pool slot leaks the prior sequence's
        KV bytes. The reuse round-trip test locks decode on a reused cache
        bit-equal to a fresh one."""
        ids = np.asarray(logical_pages, np.int32)
        if ids.size == 0:
            return
        self._scrub_slots(self.slot_of[ids])

    # ------------------------------------------------------------ migration
    def apply_drained(self, promote_ids, demote_ids, manager: CentralManager) -> int:
        """Commit a drained queue batch (commit-on-completion): the manager's
        queue tick already flipped the tier metadata of exactly these pages,
        so the KV pool moves the same ids. -1-padded id lists as emitted in
        ``QueueStats.drained_promote_ids`` / ``drained_demote_ids``."""
        return self.migrate(
            MigrationPlan(
                promote=jnp.asarray(np.asarray(promote_ids, np.int32).ravel()),
                demote=jnp.asarray(np.asarray(demote_ids, np.int32).ravel()),
            ),
            manager,
        )

    def migrate(self, plan: MigrationPlan, manager: CentralManager) -> int:
        """Execute a MaxMem plan: move page data across the tier boundary and
        rewrite slot_of. Demotions first (they free fast slots). Returns the
        number of pages moved."""
        promote = np.asarray(plan.promote)
        demote = np.asarray(plan.demote)
        promote = promote[promote >= 0]
        demote = demote[demote >= 0]
        if len(promote) == 0 and len(demote) == 0:
            return 0

        # slot_of is a permutation: "free" slots are those whose logical
        # holder is unallocated in the manager. Moving a page swaps its
        # mapping with such a holder (whose slot content is garbage).
        owner = np.asarray(manager.pages.owner)
        inv = np.empty_like(self.slot_of)
        inv[self.slot_of] = np.arange(self.n_slots, dtype=np.int32)
        free_fast = [s for s in range(self.n_fast) if owner[inv[s]] < 0]
        free_slow = [s for s in range(self.n_fast, self.n_slots) if owner[inv[s]] < 0]

        moves_src: List[int] = []
        moves_dst: List[int] = []

        def _swap(pg: int, dst: int):
            src = int(self.slot_of[pg])
            holder = int(inv[dst])  # unallocated logical page holding dst
            self.slot_of[pg] = dst
            self.slot_of[holder] = src
            inv[dst] = pg
            inv[src] = holder
            moves_src.append(src)
            moves_dst.append(dst)
            return src

        for pg in demote:
            if int(self.slot_of[pg]) >= self.n_fast:
                continue  # already slow (idempotent)
            if not free_slow:
                break
            freed = _swap(int(pg), free_slow.pop())
            free_fast.append(freed)
        for pg in promote:
            if int(self.slot_of[pg]) < self.n_fast:
                continue
            if not free_fast:
                break  # plan over-eager for the slots actually available
            freed = _swap(int(pg), free_fast.pop())
            free_slow.append(freed)
        if not moves_src:
            return 0

        src = jnp.asarray(moves_src, jnp.int32)
        dst = jnp.asarray(moves_dst, jnp.int32)
        L = self.cfg.num_layers
        n = self.n_slots
        # expand page moves across layers: row id = l * n_slots + slot
        src_all = (jnp.arange(L)[:, None] * n + src[None, :]).reshape(-1)
        dst_all = (jnp.arange(L)[:, None] * n + dst[None, :]).reshape(-1)
        E = int(np.prod(self.k_pool.shape[2:]))
        self.k_pool = ops.page_move(
            self.k_pool.reshape(L * n, E), src_all, dst_all
        ).reshape(self.k_pool.shape)
        self.v_pool = ops.page_move(
            self.v_pool.reshape(L * n, E), src_all, dst_all
        ).reshape(self.v_pool.shape)
        Es = int(np.prod(self.k_max.shape[2:]))
        self.k_max = ops.page_move(
            self.k_max.reshape(L * n, Es), src_all, dst_all
        ).reshape(self.k_max.shape)
        self.k_min = ops.page_move(
            self.k_min.reshape(L * n, Es), src_all, dst_all
        ).reshape(self.k_min.shape)
        # page_move is a gather: a swapped-out source row keeps a stale COPY
        # of the migrated page's data. Any such row now held by a free
        # logical page must be re-scrubbed or the free/reuse invariant
        # breaks the moment a migration swaps with a free holder.
        freed_rows = np.asarray(
            [r for r in moves_src if owner[inv[r]] < 0], np.int32
        )
        self._scrub_slots(freed_rows)
        return len(moves_src)

    # ------------------------------------------------------------ telemetry
    def tier_of_pages(self, logical_pages: np.ndarray) -> np.ndarray:
        return np.where(self.slots_for(logical_pages) < self.n_fast, TIER_FAST, TIER_SLOW)

    def read_page(self, logical_page: int) -> Tuple[np.ndarray, np.ndarray]:
        """Host copy of one logical page's (k, v) contents — [L, page, nkv,
        dh] each, independent of where the page physically lives. The
        migration-integrity tests read pages back across a migrate() and
        assert bit-equality."""
        slot = int(self.slot_of[int(logical_page)])
        return np.asarray(self.k_pool[:, slot]), np.asarray(self.v_pool[:, slot])
