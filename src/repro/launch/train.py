"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \\
        --steps 100 --ckpt-dir /tmp/ckpt

Wires together: config -> (optional) mesh + shardings -> deterministic data
pipeline with prefetch -> jitted train_step -> async checkpointing ->
heartbeat/straggler telemetry. On this CPU container run with --smoke
(reduced config); on a TPU slice the same driver runs the full config with
the production mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import DataConfig, PrefetchIterator, SyntheticTokens
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.launch.partitioning import use_partitioning
from repro.launch.shardings import (
    batch_specs,
    rules_for,
    train_state_sharding,
)
from repro.runtime.fault_tolerance import HeartbeatTracker, StragglerDetector
from repro.training.optimizer import AdamWConfig
from repro.training.train_state import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", choices=["none", "test", "prod"], default="none")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)

    rng = jax.random.PRNGKey(0)
    state = init_train_state(cfg, rng)
    step_fn = make_train_step(cfg, opt_cfg)

    start_step = 0
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and args.resume and ckpt.latest_step() is not None:
        state, meta = ckpt.restore(state)
        start_step = int(meta.get("data_step", ckpt.latest_step()))
        print(f"resumed from step {start_step}")

    data_cfg = DataConfig(cfg.vocab_size, args.seq, args.batch, seed=17)
    source = SyntheticTokens(data_cfg)
    it = PrefetchIterator(source, start_step=start_step)

    hb = HeartbeatTracker([0], timeout=600.0)
    sd = StragglerDetector([0])

    if args.mesh != "none":
        mesh = (make_test_mesh if args.mesh == "test" else make_production_mesh)()
        rules = rules_for(cfg, mesh)
        state_sh = train_state_sharding(jax.eval_shape(lambda: state), mesh, rules)
        jstep = jax.jit(step_fn, in_shardings=(state_sh, None),
                        out_shardings=(state_sh, None), donate_argnums=(0,))
        pctx = use_partitioning(mesh, rules)
    else:
        jstep = jax.jit(step_fn, donate_argnums=(0,))
        from contextlib import nullcontext
        pctx = nullcontext()

    t_start = time.time()
    with pctx:
        try:
            for i in range(start_step, args.steps):
                step_i, batch = next(it)
                t0 = time.time()
                state, metrics = jstep(
                    state, {k: jnp.asarray(v) for k, v in batch.items()}
                )
                jax.block_until_ready(metrics["loss"])
                dt = time.time() - t0
                hb.beat(0)
                sd.record(0, dt)
                if (i + 1) % args.log_every == 0 or i == start_step:
                    toks = args.batch * args.seq / dt
                    print(
                        f"step {i + 1:5d} loss={float(metrics['loss']):.4f} "
                        f"gnorm={float(metrics['grad_norm']):.3f} "
                        f"lr={float(metrics['lr']):.2e} {dt * 1e3:6.1f} ms "
                        f"({toks:,.0f} tok/s)"
                    )
                if ckpt and (i + 1) % args.ckpt_every == 0:
                    ckpt.save(i + 1, state, meta={"data_step": i + 1})
        finally:
            it.close()
            if ckpt:
                ckpt.wait()
    print(f"done: {args.steps - start_step} steps in {time.time() - t_start:.1f}s")


if __name__ == "__main__":
    main()
