"""Per-architecture sharding rules + input/cache/state spec builders.

Rules adapt to the mesh's model-axis size: logical axes whose dimension does
not divide the axis fall back to replication (or to sequence sharding for KV
caches), per DESIGN.md §5. Everything downstream (param specs, cache specs,
batch specs) derives from the one rules dict.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.partitioning import default_rules, logical_spec, param_specs
from repro.models import tuning
from repro.models.encdec import EncDecCache
from repro.models.hybrid import HybridCache
from repro.models.ssm_lm import SSMLMCache
from repro.models.transformer import KVCache


def rules_for(cfg, mesh: Mesh, shape=None) -> Dict[str, Any]:
    multi_pod = "pod" in mesh.axis_names
    r = default_rules(multi_pod)
    m = mesh.shape["model"]
    dp = r["batch"]
    dp_size = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dp_size *= mesh.shape[a]
    if shape is not None and shape.global_batch % dp_size != 0:
        # e.g. long_500k (B=1): batch replicated; KV sequence carries memory
        r["batch"] = None
        r["kv_seq"] = ("model",)

    # big embeddings also shard their d_model dim over the data axes (FSDP)
    r["fsdp_embed"] = dp if cfg.vocab_size * cfg.d_model > 5e7 else None

    if shape is not None and shape.is_decode and tuning.FLAGS.serve_resident_weights:
        # inference layout: no optimizer state, weights replicated over the
        # data axes (TP-sharded only) => zero per-step FSDP gathers
        r["fsdp"] = None
        r["fsdp_embed"] = None

    def divides(n):
        return n > 0 and n % m == 0

    if not divides(cfg.num_heads):
        # uneven head sharding (GSPMD pads); replicate only tiny models
        r["heads"] = ("model",) if cfg.num_heads >= m else None
    if not divides(cfg.num_kv_heads):
        r["kv_heads"] = None
        # shard decode KV over sequence instead (flash-decoding split-K)
        r["kv_seq"] = ("model",)
    if not divides(cfg.d_ff):
        r["d_ff"] = None
    if cfg.vocab_size % m:
        r["vocab"] = ("model",) if cfg.vocab_size > 100_000 else None
    if cfg.is_moe and tuning.FLAGS.moe_shard_both:
        r["experts_buf"] = ("model",)
        r["expert_cap"] = dp
    elif cfg.is_moe and tuning.FLAGS.moe_shard_capacity:
        # §Perf: keep the dispatch buffer token-sharded (scatter stays local;
        # the expert einsum does the honest all-to-all instead of XLA
        # materializing the GLOBAL [E, C, d] buffer per device)
        r["experts_buf"] = None
        r["expert_cap"] = dp
    if cfg.ssm_state:
        r["ssm_heads"] = ("model",) if divides(cfg.ssm_heads) else None
        # packed in_proj dim is not TP-shardable (slice boundaries misalign);
        # SSM weights stay FSDP-only. See DESIGN.md §5 + EXPERIMENTS §Perf.
        r["ssm_inner"] = None
    return r


# --------------------------------------------------------------------------- specs
def _ns(mesh, *names):
    def f(rules):
        return NamedSharding(mesh, logical_spec(names, rules))
    return f


def batch_specs(cfg, shape, mesh: Mesh, rules) -> Dict[str, NamedSharding]:
    mk = lambda *names: NamedSharding(mesh, logical_spec(names, rules))
    if shape.is_decode:
        return {"token": mk("batch")}
    specs = {"tokens": mk("batch", "seq"), "labels": mk("batch", "seq")}
    if cfg.is_encoder_decoder:
        specs["enc_embeds"] = mk("batch", "enc_seq", None)
    return specs


def params_sharding(params_shape, mesh: Mesh, rules):
    specs = param_specs(params_shape, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def cache_sharding(cache_shape, cfg, mesh: Mesh, rules):
    """NamedSharding tree for a decode cache (family-specific layouts)."""
    mk = lambda *names: NamedSharding(mesh, logical_spec(names, rules))
    rep = mk()

    def kv5(_):  # [L, B, S, h, dh]
        return mk(None, "batch", "kv_seq", "kv_heads", None)

    if isinstance(cache_shape, KVCache):
        return KVCache(k=kv5(None), v=kv5(None), pos=rep)
    if isinstance(cache_shape, SSMLMCache):
        from repro.models.ssm import SSMCache

        return SSMLMCache(
            layers=SSMCache(
                conv=mk(None, "batch", None, None),
                state=mk(None, "batch", "ssm_heads", None, None),
            ),
            pos=rep,
        )
    if isinstance(cache_shape, HybridCache):
        from repro.models.ssm import SSMCache

        return HybridCache(
            group_ssm=SSMCache(
                conv=mk(None, None, "batch", None, None),
                state=mk(None, None, "batch", "ssm_heads", None, None),
            ),
            tail_ssm=SSMCache(
                conv=mk(None, "batch", None, None),
                state=mk(None, "batch", "ssm_heads", None, None),
            ),
            k=kv5(None),
            v=kv5(None),
            pos=rep,
        )
    if isinstance(cache_shape, EncDecCache):
        # cross-attn KV: enc_len (1500) divides nothing; replicate seq dim
        cross = mk(None, "batch", "enc_seq", "kv_heads", None)
        return EncDecCache(k=kv5(None), v=kv5(None), ck=cross, cv=cross, pos=rep)
    raise TypeError(f"unknown cache type {type(cache_shape)}")


def train_state_sharding(state_shape, mesh: Mesh, rules):
    """TrainState: opt state mirrors param shardings; step replicated."""
    from repro.training.train_state import TrainState
    from repro.training.optimizer import OptState

    p_sh = params_sharding(state_shape.params, mesh, rules)
    return TrainState(
        params=p_sh,
        opt=OptState(
            m=params_sharding(state_shape.opt.m, mesh, rules),
            v=params_sharding(state_shape.opt.v, mesh, rules),
            step=NamedSharding(mesh, P()),
        ),
        error_buf=(
            params_sharding(state_shape.error_buf, mesh, rules)
            if state_shape.error_buf is not None
            else None
        ),
    )
