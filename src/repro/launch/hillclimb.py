"""Fleet-driven policy autotuner (DESIGN.md §9).

"From Good to Great" (PAPERS.md) shows tiering systems leave large factors
on the table at default parameters. This module searches the traced
``PolicyParams`` surface (docs/PARAMS.md is the field reference;
``SEARCH_SPACE`` below is the machine-readable subset the tuner explores)
using the sharded fleet as a parallel evaluator: every generation of
candidate configurations becomes one :class:`~repro.core.scenario.
ScenarioSweep` — one machine per candidate, every machine replaying the
SAME scenario schedule — advanced by ``run_sweep`` in one vmapped/sharded
dispatch per chunk. Because every searched knob is a traced leaf, the whole
population shares one compiled fleet program: the grid is free.

Two modes:

* **offline** (:class:`PolicyAutotuner`) — evolutionary search (elite-keep
  + uniform crossover + clamped mutation, seeded ``numpy`` Generators, so
  the full trajectory is deterministic) over a scenario family from
  ``benchmarks/dynamic_workload.py``. Winners are committed as named
  profiles under ``src/repro/configs/tuned/`` and load back through
  ``PolicyParams.from_profile("thrash_4k")``. The paper-default candidate
  is always index 0 of generation 0, and the winner must weakly dominate
  it (aggregate throughput ≥ default AND LS p99 ≤ default), so the
  committed tuned-vs-default claim in ``BENCH_autotune.json`` holds by
  construction at the tuned geometry.
* **online** (:class:`OnlineTuner`) — a controller attached to a live
  ``ColocationSim`` that watches phase telemetry (Arrive / SkewChange /
  ShiftWorkingSet events), re-dispatches a small tuning burst mid-run
  (candidate params × frozen access distribution through a throwaway
  ``FleetManager``) and hot-swaps the winning params into the live
  manager. Params are traced, so the swap never recompiles; the burst
  draws from its own seeded RNG stream, so the host run's randomness is
  untouched and default-vs-online legs stay comparable.

Search is resumable (PR 6 checkpoints): the tuner persists its own state
after every generation and forwards ``checkpoint_every`` to each
generation's ``run_sweep``, so a kill mid-generation resumes bit-identically
to the uninterrupted run.

Quickstart::

    PYTHONPATH=src:. python -m repro.launch.hillclimb --scenario thrash --smoke
    PYTHONPATH=src:. python -m repro.launch.hillclimb --scenario colocation \
        --smoke --commit-profile
"""
from __future__ import annotations

import argparse
import json
import math
import os
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.manager import CentralManager
from repro.core.scenario import (
    Arrive,
    Scenario,
    ScenarioSweep,
    ShiftWorkingSet,
    SkewChange,
    SweepPoint,
    adversarial_scenario,
    recovery_epochs,
    run_sweep,
)
from repro.core.simulator import WorkloadSpec

# --------------------------------------------------------------- search space
#
# The knobs the offline tuner explores — each a traced ``PolicyParams`` leaf
# reachable through ``SweepPoint`` (so a generation needs no recompile).
# ``frac`` knobs are fractions of the fast tier and resolve to page counts
# at SweepPoint construction, which lets one candidate transfer across
# geometries; ``log=True`` searches/mutates multiplicatively. ``default``
# is the paper/engine default (docs/PARAMS.md documents every field,
# including the ones deliberately NOT searched here and why).
SEARCH_SPACE: Dict[str, Dict] = {
    "sample_period": dict(kind="int", lo=25, hi=400, log=True, default=100),
    "ewma_lambda": dict(kind="float", lo=0.1, hi=0.9, log=False, default=0.5),
    "hysteresis": dict(kind="float", lo=0.0, hi=0.2, log=False, default=0.08),
    "num_bins": dict(kind="int", lo=4, hi=10, log=False, default=6),
    "migration_budget": dict(
        kind="frac", lo=1 / 64, hi=1 / 4, log=True, default=1 / 8
    ),
    "alloc_headroom": dict(kind="frac", lo=0.0, hi=1 / 8, log=False, default=0.0),
}

P99_WEIGHT = 4.0  # score = tput gain − weight · relative LS-p99 regression


@dataclass(frozen=True)
class TunerGeometry:
    """The shape knobs of one tuning run — everything that would force a
    retrace if it varied across candidates, so it is fixed per search and
    recorded in the committed profile."""

    n_pages: int
    n_epochs: int
    fast: int
    queue_size: int = 0
    max_tenants: int = 8
    policy_chunk: int = 8


# ------------------------------------------------------------- candidates
Candidate = Dict[str, float]  # knob -> value in search units (JSON-stable)


def default_candidate() -> Candidate:
    return {k: float(s["default"]) for k, s in SEARCH_SPACE.items()}


def sample_candidate(rng: np.random.Generator) -> Candidate:
    cand = {}
    for k, s in SEARCH_SPACE.items():
        if s["log"]:
            lo, hi = math.log(max(s["lo"], 1e-9)), math.log(s["hi"])
            cand[k] = float(math.exp(rng.uniform(lo, hi)))
        else:
            cand[k] = float(rng.uniform(s["lo"], s["hi"]))
    return cand


def mutate(cand: Candidate, rng: np.random.Generator, scale: float = 0.25) -> Candidate:
    out = dict(cand)
    for k, s in SEARCH_SPACE.items():
        if rng.random() >= 0.6:  # per-knob mutation probability
            continue
        if s["log"]:
            v = out[k] * math.exp(float(rng.normal(0.0, scale)))
        else:
            v = out[k] + float(rng.normal(0.0, scale * (s["hi"] - s["lo"])))
        out[k] = float(min(max(v, s["lo"]), s["hi"]))
    return out


def crossover(a: Candidate, b: Candidate, rng: np.random.Generator) -> Candidate:
    return {k: float(a[k] if rng.random() < 0.5 else b[k]) for k in SEARCH_SPACE}


def resolve_knobs(cand: Candidate, geom: TunerGeometry) -> Dict[str, object]:
    """Candidate (search units) -> concrete ``SweepPoint`` overrides."""
    kw: Dict[str, object] = {}
    for k, v in cand.items():
        s = SEARCH_SPACE[k]
        if s["kind"] == "frac":
            pages = int(round(v * geom.fast))
            if k == "migration_budget":
                kw[k] = max(2, min(pages, geom.fast))
            else:
                kw[k] = max(0, min(pages, geom.fast // 2))
        elif s["kind"] == "int":
            kw[k] = int(round(min(max(v, s["lo"]), s["hi"])))
        else:
            kw[k] = float(min(max(v, s["lo"]), s["hi"]))
    return kw


# ---------------------------------------------------------------- scoring
def ls_tenants(scenario: Scenario) -> List[str]:
    """Latency-sensitive tenants = Arrive specs with a real FMMR target."""
    return sorted(
        {
            ev.spec.name
            for ev in scenario.events
            if isinstance(ev, Arrive) and ev.spec.t_miss < 1.0
        }
    )


def measure_history(
    history: Sequence, window: Tuple[int, int], ls_names: Sequence[str]
) -> Tuple[float, float]:
    """(mean aggregate ops/s, mean LS p99 seconds) over ``window`` epochs."""
    recs = list(history[window[0] : window[1]])
    if not recs:
        return 0.0, 0.0
    agg = float(np.mean([sum(r.throughput.values()) for r in recs]))
    vals = [r.p99[nm] for r in recs for nm in ls_names if nm in r.p99]
    return agg, float(np.mean(vals)) if vals else 0.0


def scalarize(
    agg: float, ls_p99: float, ref_agg: float, ref_p99: float,
    p99_weight: float = P99_WEIGHT,
) -> float:
    """Throughput gain over the reference minus a one-sided p99 penalty —
    p99 *improvements* are not rewarded (the paper's QoS framing: meet the
    target, spend the rest on aggregate throughput)."""
    gain = agg / max(ref_agg, 1e-12)
    pen = max(0.0, ls_p99 / max(ref_p99, 1e-12) - 1.0)
    return float(gain - p99_weight * pen)


# recovery_epochs (the Jenga-style responsiveness metric this tuner scores
# online candidates on) moved to ``repro.core.scenario`` in the adversarial
# hardening pass; it is re-imported above so every existing call site —
# benchmarks, tests, the online tuner — keeps working unchanged.
assert recovery_epochs is not None  # re-exported from repro.core.scenario


# ------------------------------------------------------- scenario families
# Built-in responsiveness probe — no benchmarks/ import, so tests and the
# online bench can run with only ``src`` on the path.
def skewshift_scenario(n_pages: int, n_epochs: int, shift_epoch: Optional[int] = None) -> Scenario:
    """Two LS tenants + one BE; mid-run the KVS tenant's accesses jump to a
    previously-cold scatter (``SkewChange`` set 0 -> set 1). The learned
    heat map is instantly stale and the recovery slope is governed by the
    migration budget + sampling rate — the probe the online tuner is
    scored on (epochs-to-recover, :func:`recovery_epochs`)."""
    kvs = (3 * n_pages) // 8
    gap = n_pages // 4
    shift = n_epochs // 2 if shift_epoch is None else shift_epoch
    return Scenario(
        name=f"skewshift_{n_pages // 1024}k",
        n_epochs=n_epochs,
        events=(
            Arrive(0, WorkloadSpec(
                "kvs", kvs, t_miss=0.2, threads=4,
                sets=((0.18, 0.9), (0.18, 0.0)), value_bytes=16384,
            )),
            Arrive(0, WorkloadSpec(
                "gapbs", gap, t_miss=0.4, threads=8, sets=((0.2, 0.85),),
            )),
            Arrive(0, WorkloadSpec("gups", n_pages // 4, threads=6)),
            SkewChange(shift, "kvs", 0, 0.0),
            SkewChange(shift, "kvs", 1, 0.9),
        ),
        description="hot-set jump responsiveness probe (online autotuner)",
    )


# family -> needs the bounded data plane (queue-mode shapes)
FAMILY_BOUNDED = {"thrash": True, "adversarial": True}
FAMILY_MAX_TENANTS = {"sweep": 16}
FAMILIES = ("colocation", "thrash", "skewshift", "faults", "sweep", "adversarial")


def family_geometry(
    family: str,
    *,
    smoke: bool = False,
    n_pages: Optional[int] = None,
    n_epochs: Optional[int] = None,
) -> TunerGeometry:
    """Mirror ``benchmarks/dynamic_workload.py`` geometry conventions:
    fast tier = P/8 (the paper's 128G/1T box), default budget = fast/8.
    The queue (when the family is bounded) is sized for the LARGEST budget
    in the search range — queue size is a shape, so it is fixed across
    candidates and both bench legs."""
    if n_pages is None:
        n_pages = 4096 if smoke else 65536
    if n_epochs is None:
        n_epochs = 16 if smoke else 96
    fast = n_pages // 8
    return TunerGeometry(
        n_pages=n_pages,
        n_epochs=n_epochs,
        fast=fast,
        queue_size=fast // 2 if FAMILY_BOUNDED.get(family, False) else 0,
        max_tenants=FAMILY_MAX_TENANTS.get(family, 8),
        policy_chunk=4 if smoke else 8,
    )


def family_scenario(family: str, geom: TunerGeometry) -> Scenario:
    if family == "skewshift":
        return skewshift_scenario(geom.n_pages, geom.n_epochs)
    if family == "adversarial":
        # composite storm (core/scenario.py): boundary straddle phase-locked
        # with a ping-pong flipper — src-only path, like skewshift
        return adversarial_scenario(
            geom.n_pages, geom.n_epochs, fast_capacity=geom.fast
        )
    try:
        from benchmarks import dynamic_workload as dw
    except ImportError as e:  # pragma: no cover - depends on caller's path
        raise ImportError(
            f"scenario family {family!r} lives in benchmarks/dynamic_workload.py; "
            "run from the repo root with PYTHONPATH=src:."
        ) from e
    makers: Dict[str, Callable] = {
        "colocation": dw.colocation_scenario,
        "thrash": dw.thrash_scenario,
        "faults": dw.faults_scenario,
        "sweep": dw.sweep_scenario,
    }
    if family not in makers:
        raise KeyError(f"unknown scenario family {family!r}; choose from {FAMILIES}")
    return makers[family](geom.n_pages, geom.n_epochs)


def scale_tag(n_pages: int) -> str:
    return f"{n_pages // 1024}k"


# ---------------------------------------------------------------- offline
@dataclass
class TunerResult:
    family: str
    interrupted: bool
    winner: Optional[Dict]  # {candidate, resolved, agg, ls_p99, score, generation, index}
    ref: Optional[Dict]  # default-candidate measures {agg, ls_p99}
    trajectory: List[Dict] = field(default_factory=list)


class PolicyAutotuner:
    """Offline population search over ``SEARCH_SPACE`` with the fleet as
    the evaluator (one sweep point per candidate, one dispatch per chunk).

    Candidate 0 of generation 0 is ALWAYS the paper-default configuration;
    its measures become the reference for scoring and for the weak-
    domination winner rule (tuned throughput ≥ default AND tuned LS p99 ≤
    default). The simulator and the search are both seeded, so the same
    ``seed`` reproduces the trajectory bit-for-bit.
    """

    def __init__(
        self,
        family: str,
        geom: TunerGeometry,
        scenario: Optional[Scenario] = None,
        *,
        population: int = 8,
        generations: int = 4,
        elites: int = 2,
        seed: int = 0,
        eval_seed: int = 0,
        p99_weight: float = P99_WEIGHT,
        out_dir: Optional[str] = None,
        checkpoint_every: Optional[int] = None,
        devices=None,
        pipeline: bool = True,
        verbose: bool = False,
    ):
        assert population >= 2 and generations >= 1 and 1 <= elites < population
        self.family = family
        self.geom = geom
        self.scenario = scenario if scenario is not None else family_scenario(family, geom)
        self.population = population
        self.generations = generations
        self.elites = elites
        self.seed = seed
        self.eval_seed = eval_seed
        self.p99_weight = p99_weight
        self.out_dir = out_dir
        self.checkpoint_every = checkpoint_every
        self.devices = devices
        self.pipeline = pipeline
        self.verbose = verbose
        # the steady window the paper figures compare on: skip the opening
        # quarter (arrivals + first convergence) and score the rest
        self.window = (geom.n_epochs // 4, geom.n_epochs)
        self.ls_names = ls_tenants(self.scenario)
        if out_dir is not None:
            os.makedirs(out_dir, exist_ok=True)

    # ------------------------------------------------------------ state io
    def _state_path(self) -> Optional[str]:
        return None if self.out_dir is None else os.path.join(self.out_dir, "tuner_state.json")

    def _save_state(self, next_gen: int, population, trajectory, ref) -> None:
        path = self._state_path()
        if path is None:
            return
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(
                {
                    "family": self.family,
                    "seed": self.seed,
                    "next_generation": next_gen,
                    "population": population,
                    "trajectory": trajectory,
                    "ref": ref,
                },
                f,
            )
        os.replace(tmp, path)

    def _load_state(self) -> Optional[Dict]:
        path = self._state_path()
        if path is None or not os.path.exists(path):
            return None
        with open(path) as f:
            state = json.load(f)
        if state["family"] != self.family or state["seed"] != self.seed:
            raise ValueError(
                f"tuner state at {path} is for family={state['family']!r} "
                f"seed={state['seed']}; this run is {self.family!r}/{self.seed}"
            )
        return state

    def _log(self, msg: str) -> None:
        if self.verbose:
            print(f"[hillclimb:{self.family}] {msg}", flush=True)

    # ---------------------------------------------------------- evaluation
    def _evaluate(self, gen, population, *, resume=False, stop_after=None):
        """One generation = one ScenarioSweep. Returns [(agg, ls_p99)] per
        candidate, or None if the sweep was stopped early (kill simulation
        / checkpoint-resume tests)."""
        geom = self.geom
        points = tuple(
            SweepPoint(name=f"c{i:02d}", seed=self.eval_seed, **resolve_knobs(c, geom))
            for i, c in enumerate(population)
        )
        sweep = ScenarioSweep(scenario=self.scenario, points=points)
        ckpt_kw: Dict[str, object] = {}
        if self.out_dir is not None and self.checkpoint_every is not None:
            gen_dir = os.path.join(self.out_dir, f"gen{gen:03d}")
            os.makedirs(gen_dir, exist_ok=True)
            ckpt_kw = dict(
                checkpoint_every=self.checkpoint_every,
                checkpoint_dir=gen_dir,
                resume=resume,
                stop_after=stop_after,
            )
        res = run_sweep(
            sweep,
            num_pages=geom.n_pages,
            fast_capacity=geom.fast,
            migration_budget=resolve_knobs(default_candidate(), geom)["migration_budget"],
            max_tenants=geom.max_tenants,
            queue_size=geom.queue_size,
            policy_chunk=geom.policy_chunk,
            devices=self.devices,
            pipeline=self.pipeline,
            **ckpt_kw,
        )
        if any(len(r.history) < geom.n_epochs for r in res.results.values()):
            return None  # stopped at a checkpoint boundary before the end
        return [
            measure_history(res.results[p.name].history, self.window, self.ls_names)
            for p in points
        ]

    # ----------------------------------------------------------- evolution
    def _evolve(self, population, scores, rng: np.random.Generator):
        order = sorted(range(len(population)), key=lambda i: (-scores[i], i))
        keep = [dict(population[i]) for i in order[: self.elites]]
        parents = order[: max(2, len(order) // 2)]  # top half breeds
        children = []
        while len(keep) + len(children) < self.population:
            pa = population[parents[int(rng.integers(len(parents)))]]
            pb = population[parents[int(rng.integers(len(parents)))]]
            children.append(mutate(crossover(pa, pb, rng), rng))
        return keep + children

    def _pick_winner(self, trajectory, ref) -> Dict:
        """Best-scoring candidate that weakly dominates the default (ties
        resolve to the earliest generation/index, so the default itself is
        the floor)."""
        best = None
        for rec in trajectory:
            for i, cand in enumerate(rec["candidates"]):
                agg, p99 = rec["agg"][i], rec["ls_p99"][i]
                if agg < ref["agg"] * (1 - 1e-9) or p99 > ref["ls_p99"] * (1 + 1e-9):
                    continue
                entry = {
                    "candidate": dict(cand),
                    "resolved": resolve_knobs(cand, self.geom),
                    "agg": agg,
                    "ls_p99": p99,
                    "score": rec["scores"][i],
                    "generation": rec["generation"],
                    "index": i,
                }
                if best is None or entry["score"] > best["score"] + 1e-12:
                    best = entry
        assert best is not None, "default candidate must qualify as winner floor"
        return best

    # -------------------------------------------------------------- search
    def search(self, *, resume: bool = False, stop_after: Optional[int] = None) -> TunerResult:
        """Run (or resume) the population search.

        ``stop_after`` forwards to each generation's ``run_sweep`` as the
        kill-simulation hook: the sweep returns a partial result at the
        first checkpoint past that epoch and the tuner stops with
        ``interrupted=True`` — call ``search(resume=True)`` to continue
        bit-identically (PR 6 checkpoint machinery underneath).
        """
        state = self._load_state() if resume else None
        gen0, trajectory, ref, population = 0, [], None, None
        if state is not None:
            gen0 = state["next_generation"]
            population = [dict(c) for c in state["population"]]
            trajectory = state["trajectory"]
            ref = state["ref"]
        if population is None:
            rng0 = np.random.default_rng([self.seed, 0])
            population = [default_candidate()] + [
                sample_candidate(rng0) for _ in range(self.population - 1)
            ]
        for gen in range(gen0, self.generations):
            measures = self._evaluate(
                gen, population, resume=resume and gen == gen0, stop_after=stop_after
            )
            if measures is None:
                self._log(f"gen {gen}: stopped early (stop_after={stop_after})")
                return TunerResult(self.family, True, None, ref, trajectory)
            if ref is None:  # candidate 0 of generation 0 is the default
                ref = {"agg": measures[0][0], "ls_p99": measures[0][1]}
            scores = [
                scalarize(a, p, ref["agg"], ref["ls_p99"], self.p99_weight)
                for a, p in measures
            ]
            trajectory.append(
                {
                    "generation": gen,
                    "candidates": [dict(c) for c in population],
                    "agg": [a for a, _ in measures],
                    "ls_p99": [p for _, p in measures],
                    "scores": scores,
                    "best_index": int(np.argmax(scores)),
                }
            )
            self._log(
                f"gen {gen}: best score {max(scores):.4f} "
                f"(agg {measures[int(np.argmax(scores))][0]:,.0f} ops/s)"
            )
            # stateless per-generation RNG: resuming at generation g draws
            # the same stream without serializing generator state
            rng = np.random.default_rng([self.seed, 1, gen])
            population = self._evolve(population, scores, rng)
            self._save_state(gen + 1, population, trajectory, ref)
        winner = self._pick_winner(trajectory, ref)
        self._log(
            f"winner: gen {winner['generation']} c{winner['index']:02d} "
            f"{winner['resolved']} (+{100 * (winner['agg'] / ref['agg'] - 1):.1f}% agg)"
        )
        return TunerResult(self.family, False, winner, ref, trajectory)

    # -------------------------------------------------------------- commit
    def commit_profile(self, result: TunerResult, name: Optional[str] = None) -> str:
        """Write the winner as a named profile under ``configs/tuned/``."""
        from repro.configs.tuned import save_profile
        from repro.runtime.fault_tolerance import _params_to_meta

        assert not result.interrupted and result.winner is not None
        geom, w = self.geom, result.winner
        kw = w["resolved"]
        mgr = CentralManager(
            num_pages=geom.n_pages,
            fast_capacity=geom.fast,
            migration_budget=kw["migration_budget"],
            max_tenants=geom.max_tenants,
            num_bins=kw["num_bins"],
            sample_period=kw["sample_period"],
            ewma_lambda=kw["ewma_lambda"],
            hysteresis=kw["hysteresis"],
            alloc_headroom=kw["alloc_headroom"],
            queue_size=geom.queue_size,
        )
        prof = {
            "name": name or f"{self.family}_{scale_tag(geom.n_pages)}",
            "family": self.family,
            "geometry": {
                "n_pages": geom.n_pages,
                "n_epochs": geom.n_epochs,
                "fast_capacity": geom.fast,
                "queue_size": geom.queue_size,
                "max_tenants": geom.max_tenants,
                "policy_chunk": geom.policy_chunk,
            },
            "params": _params_to_meta(mgr.params),
            "metrics": {
                "default": {
                    "agg_throughput": result.ref["agg"],
                    "ls_p99_us": result.ref["ls_p99"] * 1e6,
                },
                "tuned": {
                    "agg_throughput": w["agg"],
                    "ls_p99_us": w["ls_p99"] * 1e6,
                },
            },
            "search": {
                "seed": self.seed,
                "eval_seed": self.eval_seed,
                "generations": self.generations,
                "population": self.population,
                "score": w["score"],
                "scored_window": list(self.window),
                "generation": w["generation"],
                "index": w["index"],
            },
        }
        return save_profile(prof)


# ----------------------------------------------------------------- online
class OnlineTuner:
    """Mid-run re-tuner: on a phase-telemetry trigger, evaluate a small
    burst of candidate params against the CURRENT policy state and frozen
    access distribution, then hot-swap the winner into the live manager.

    The burst clones the manager's (immutable) state pytree into K
    throwaway ``CentralManager`` shells — one per candidate — and advances
    them ``burst_epochs`` through a single-device ``FleetManager`` dispatch
    with access counts drawn from the tuner's own seeded RNG (the live
    sim's stream is swapped out and restored, so attaching the controller
    never perturbs the host run). Scoring mirrors the simulator's chunk
    record: per-epoch tenant FMMR -> closed-loop latency fixed point ->
    aggregate throughput, charged with each candidate's own migration
    traffic, with the offline tuner's one-sided LS-p99 penalty PLUS a QoS-
    deficit term (mean excess of measured LS FMMR over its target — the
    policy's own objective). The deficit term DOMINATES (default weight 10,
    the paper's lexicographic QoS framing: meet LS targets first, spend the
    remainder on throughput) because during recovery both other terms
    mislead — a starved LS tenant *raises* aggregate throughput (its
    bandwidth goes to the batch tenants), and a recovering one *raises*
    measured p99 (more traffic inflates the contended slow-op latency while
    the mixture quantile stays pinned to it until the miss ratio is tiny). Candidate 0 is "keep the
    current params", so a swap only happens on a strict improvement. Every searched knob is a traced leaf and shapes
    never change, so the swap costs one params restack — no recompile.

    The manager's ``plan_size`` (static migration-plan buffer) caps how far
    ``migration_budget`` can be tuned UP at runtime — construct the live
    manager with the budget headroom you want the controller to have.
    """

    TRIGGERS = (Arrive, SkewChange, ShiftWorkingSet)

    def __init__(
        self,
        sim,
        *,
        knobs: Tuple[str, ...] = ("migration_budget", "sample_period", "ewma_lambda"),
        candidates: int = 6,
        burst_epochs: int = 8,
        seed: int = 0,
        p99_weight: float = P99_WEIGHT,
        qos_weight: float = 10.0,
        triggers: Optional[Tuple[type, ...]] = None,
    ):
        assert candidates >= 2 and burst_epochs >= 2
        self.sim = sim
        if triggers is not None:
            self.TRIGGERS = tuple(triggers)
        self.knobs = knobs
        self.candidates = candidates
        self.burst_epochs = burst_epochs
        self.seed = seed
        self.p99_weight = p99_weight
        self.qos_weight = qos_weight
        self.retunes: List[Dict] = []

    # `run_scenario(..., on_event=tuner.on_event)` wiring
    def on_event(self, sim, ev) -> None:
        if isinstance(ev, self.TRIGGERS) and sim is self.sim and sim.tenants:
            self.retune(trigger=ev.label())

    def _perturb(self, cur, rng: np.random.Generator):
        import jax.numpy as jnp

        plan = self.sim.backend.plan_size
        rep = {}
        for k in self.knobs:
            if k == "migration_budget":
                v = int(round(int(cur.migration_budget) * math.exp(rng.normal(0.0, 0.7))))
                rep[k] = jnp.int32(min(max(v, 1), plan))
            elif k == "sample_period":
                v = int(round(int(cur.sample_period) * math.exp(rng.normal(0.0, 0.5))))
                rep[k] = jnp.int32(min(max(v, 5), 2000))
            elif k == "ewma_lambda":
                rep[k] = jnp.float32(min(max(float(cur.ewma_lambda) + rng.normal(0.0, 0.15), 0.05), 0.95))
            elif k == "hysteresis":
                rep[k] = jnp.float32(min(max(float(cur.hysteresis) + rng.normal(0.0, 0.05), 0.0), 0.3))
            elif k == "alloc_headroom":
                v = int(round(int(cur.alloc_headroom) + rng.normal(0.0, plan / 4)))
                rep[k] = jnp.int32(min(max(v, 0), int(cur.fast_capacity) // 2))
            else:
                raise KeyError(f"online tuner cannot perturb {k!r}")
        return cur._replace(**rep)

    def _candidate_params(self, rng: np.random.Generator):
        import jax.numpy as jnp

        cur = self.sim.backend.params
        plan = self.sim.backend.plan_size
        out = [cur]
        # deterministic recovery play: full plan-buffer budget + faster
        # sampling, the aggressive config a phase change usually wants
        out.append(
            cur._replace(
                migration_budget=jnp.int32(plan),
                sample_period=jnp.int32(max(10, int(cur.sample_period) // 2)),
            )
        )
        while len(out) < self.candidates:
            out.append(self._perturb(cur, rng))
        return out

    def _burst(self, cands, rng: np.random.Generator):
        from repro.core.fleet import FleetManager

        sim, mgr = self.sim, self.sim.backend
        mgr._ensure_segs()  # clones share the segs-complete state pytree
        state = mgr._state
        clones = []
        for p in cands:
            c = CentralManager(
                num_pages=mgr.num_pages,
                fast_capacity=int(mgr.params.fast_capacity),
                migration_budget=mgr.plan_size,
                max_tenants=mgr.max_tenants,
                queue_size=mgr.queue_size,
            )
            c._state = state
            c._segs_owner = None  # do NOT rebuild segs from the empty init owner
            c.params = p
            c.epoch_index = mgr.epoch_index
            clones.append(c)
        arrays = sim._arrays()
        names, M, page_mask, threads, bpo = arrays
        tier = np.asarray(mgr.tiers())
        saved_rng = sim.rng  # burst draws must not advance the host stream
        sim.rng = rng
        try:
            counts, _ctx = sim._chunk_prepare(arrays, tier)
        finally:
            sim.rng = saved_rng
        fleet = FleetManager(clones, devices=1)
        res = fleet.run_epochs(self.burst_epochs, counts=np.tile(counts, (len(cands), 1)))

        handles = [sim.handles[nm] for nm in names]
        fmmr = np.asarray(res.stats.fmmr_now)[:, :, handles]  # [K, k, n]
        moved = (
            np.asarray(res.stats.promoted) + np.asarray(res.stats.demoted)
        ).sum(axis=-1)  # [K, k] selection traffic (commit upper bound)
        m = sim.machine
        fast_op = m.fast.latency_ns * 1e-9 + bpo / (m.fast.bandwidth_GBps * 1e9)
        ls = [i for i, nm in enumerate(names) if sim.tenants[nm].spec.t_miss < 1.0]
        targets = np.array([sim.tenants[names[i]].spec.t_miss for i in ls], float)
        # terminal-state scoring: the burst asks "where will this candidate
        # have taken the machine by the end of the horizon", so only the
        # last epoch counts — scoring the transient would charge the
        # migration investment against exactly the candidates that make it
        start = self.burst_epochs - 1
        measures = []
        for ki in range(len(cands)):
            aggs, p99s, deficits = [], [], []
            for e in range(start, self.burst_epochs):
                miss = fmmr[ki, e]
                lat, slow_op = sim._latencies(
                    miss, float(moved[ki, e]) * m.page_bytes, threads, bpo
                )
                aggs.append((threads / lat).sum())
                if ls:
                    p99s.append(
                        np.mean(
                            [
                                sim._mixture_quantile(0.99, miss[i], fast_op[i], slow_op[i])
                                for i in ls
                            ]
                        )
                    )
                    deficits.append(np.maximum(miss[ls] - targets, 0.0).mean())
            measures.append(
                (
                    float(np.mean(aggs)),
                    float(np.mean(p99s)) if p99s else 0.0,
                    float(np.mean(deficits)) if deficits else 0.0,
                )
            )
        ref_agg, ref_p99 = measures[0][0], measures[0][1]
        scores = [
            scalarize(a, p, ref_agg, ref_p99, self.p99_weight) - self.qos_weight * d
            for a, p, d in measures
        ]
        return int(np.argmax(scores)), scores, measures  # ties keep current

    def retune(self, trigger: str = "manual"):
        """Run one tuning burst now; hot-swap on strict improvement.
        Returns the params left installed on the live manager."""
        sim = self.sim
        if self.retunes and self.retunes[-1]["epoch"] == len(sim.history):
            return sim.backend.params  # coalesce same-epoch event storms
        rng = np.random.default_rng([self.seed, 23, len(self.retunes)])
        cands = self._candidate_params(rng)
        best, scores, measures = self._burst(cands, rng)
        if best != 0:
            sim.backend.params = cands[best]  # traced leaves: no recompile
        self.retunes.append(
            {
                "epoch": len(sim.history),
                "trigger": trigger,
                "chosen": best,
                "scores": scores,
                "measures": measures,
                "budget": int(sim.backend.params.migration_budget),
                "sample_period": int(sim.backend.params.sample_period),
            }
        )
        return sim.backend.params


# -------------------------------------------------------------------- CLI
def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Fleet-driven policy autotuner (DESIGN.md §9)"
    )
    ap.add_argument("--scenario", default="thrash", choices=FAMILIES,
                    help="scenario family to tune")
    ap.add_argument("--smoke", action="store_true", help="toy geometry (~seconds)")
    ap.add_argument("--pages", type=int, default=None, help="override page count")
    ap.add_argument("--epochs", type=int, default=None, help="override epoch count")
    ap.add_argument("--population", type=int, default=8)
    ap.add_argument("--generations", type=int, default=4)
    ap.add_argument("--elites", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-dir", default=None,
                    help="state + sweep checkpoints here (enables --resume)")
    ap.add_argument("--checkpoint-every", type=int, default=None,
                    help="epochs between sweep checkpoints inside a generation")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--stop-after", type=int, default=None,
                    help="kill-simulation: stop the current generation at the "
                         "first checkpoint past this epoch")
    ap.add_argument("--commit-profile", action="store_true",
                    help="write the winner under src/repro/configs/tuned/")
    ap.add_argument("--profile-name", default=None)
    ap.add_argument("--devices", type=int, default=None)
    args = ap.parse_args(argv)

    geom = family_geometry(
        args.scenario, smoke=args.smoke, n_pages=args.pages, n_epochs=args.epochs
    )
    tuner = PolicyAutotuner(
        args.scenario,
        geom,
        population=args.population,
        generations=args.generations,
        elites=args.elites,
        seed=args.seed,
        out_dir=args.out_dir,
        checkpoint_every=args.checkpoint_every,
        devices=args.devices,
        verbose=True,
    )
    result = tuner.search(resume=args.resume, stop_after=args.stop_after)
    if result.interrupted:
        print("search interrupted at a checkpoint; rerun with --resume")
        return 2
    w, ref = result.winner, result.ref
    print(f"\nscenario family : {args.scenario} ({geom.n_pages} pages x {geom.n_epochs} epochs)")
    print(f"default         : agg {ref['agg']:,.0f} ops/s  LS p99 {ref['ls_p99'] * 1e6:.1f} us")
    print(f"tuned           : agg {w['agg']:,.0f} ops/s  LS p99 {w['ls_p99'] * 1e6:.1f} us")
    print(f"delta           : {100 * (w['agg'] / max(ref['agg'], 1e-12) - 1):+.2f}% agg, "
          f"{100 * (w['ls_p99'] / max(ref['ls_p99'], 1e-12) - 1):+.2f}% p99")
    print(f"winning knobs   : {w['resolved']}")
    if args.commit_profile:
        path = tuner.commit_profile(result, name=args.profile_name)
        print(f"profile written : {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
