import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: lower a cell under tuning-flag variants, report
the three roofline terms per variant, and dump top byte/collective
contributors for hypothesis formation.

  PYTHONPATH=src python -m repro.launch.hillclimb --arch yi-6b --shape train_4k \
      --variants baseline,bf16_scores --attribute
"""
import argparse
import json

from repro.analysis.attribution import attribute, top
from repro.launch.dryrun import run_cell
from repro.models import tuning

# named variants: tuning-flag overrides (+ optional remat override)
VARIANTS = {
    "baseline": {  # paper-faithful configuration (pre-hillclimb defaults)
        "q_block": 512, "kv_block": 1024, "seq_parallel_activations": False,
        "moe_shardmap": False, "decode_deferred_commit": False,
        "serve_resident_weights": False,
    },
    "optimized": {},  # current framework defaults
    "bf16_scores": {"attn_score_f32": False},
    "kv2048": {"kv_block": 2048},
    "kv4096": {"kv_block": 4096, "q_block": 1024},
    "seq_parallel": {"seq_parallel_activations": True},
    "loss_bf16": {"loss_logits_bf16": True},
    "remat_dots": {"_remat": "dots"},
    "no_remat": {"_remat": "none"},
    "moe_local_dispatch": {"moe_shard_capacity": True},
    "cap1.0": {"capacity_factor": 1.0},
    "moe_local+cap1.0": {"moe_shard_capacity": True, "capacity_factor": 1.0},
    "combo_mem": {"attn_score_f32": False, "loss_logits_bf16": True},
    "combo_mem_sp": {
        "attn_score_f32": False,
        "loss_logits_bf16": True,
        "seq_parallel_activations": True,
    },
    "sp+kv4096": {"seq_parallel_activations": True, "kv_block": 4096,
                  "q_block": 1024},
    "sp+loss_bf16": {"seq_parallel_activations": True, "loss_logits_bf16": True},
    "sp+kv4096+bf16": {"seq_parallel_activations": True, "kv_block": 4096,
                       "q_block": 1024, "attn_score_f32": False},
    "sp+kv4096+dots": {"seq_parallel_activations": True, "kv_block": 4096,
                       "q_block": 1024, "_remat": "dots"},
    "sp+kv4096q2048+dots": {"seq_parallel_activations": True, "kv_block": 4096,
                            "q_block": 2048, "_remat": "dots"},
    "best+loss_bf16": {"seq_parallel_activations": True, "kv_block": 4096,
                       "q_block": 1024, "_remat": "dots", "loss_logits_bf16": True},
    "best+norm_bf16": {"seq_parallel_activations": True, "kv_block": 4096,
                       "q_block": 1024, "_remat": "dots", "norm_bf16_apply": True},
    "moe_2d": {"moe_shard_both": True},
    "moe_a2a": {"moe_explicit_a2a": True},
    "moe_sm": {"moe_shardmap": True},
    "deferred": {"decode_deferred_commit": True},
    "deferred+resident": {"decode_deferred_commit": True,
                          "serve_resident_weights": True},
    "moe_sm+cap1.0": {"moe_shardmap": True, "capacity_factor": 1.0},
    "moe_best": {"moe_shardmap": True, "capacity_factor": 1.0, "_remat": "dots"},
    "moe_best+kv": {"moe_shardmap": True, "capacity_factor": 1.0,
                    "_remat": "dots", "kv_block": 4096, "q_block": 1024},
    "moe_best+loss": {"moe_shardmap": True, "capacity_factor": 1.0,
                      "_remat": "dots", "loss_logits_bf16": True},
    "ssd_q64": {"ssd_chunk": 64},
    "ssd_q256": {"ssd_chunk": 256},
    "ssd_q64+dots": {"ssd_chunk": 64, "_remat": "dots"},
    "ssd_q512": {"ssd_chunk": 512},
    "ssd_q256+dots": {"ssd_chunk": 256, "_remat": "dots"},
    "moe_a2a+cap1.0": {"moe_explicit_a2a": True, "capacity_factor": 1.0},
    "moe_2d+cap1.0": {"moe_shard_both": True, "capacity_factor": 1.0},
    "moe_2d+cap1.0+sp": {"moe_shard_both": True, "capacity_factor": 1.0,
                         "seq_parallel_activations": True},
}


def run_variant(arch, shape, name, *, multi_pod=False, attribute_top=False):
    spec = dict(VARIANTS[name])
    remat = spec.pop("_remat", "block")
    with tuning.tuned(**spec):
        res = run_cell(
            arch, shape, multi_pod=multi_pod, remat=remat,
            save=False, verbose=False,
        )
    r = res["roofline"]
    print(
        f"{name:20s} compute={r['compute_s']:9.3e} memory={r['memory_s']:9.3e} "
        f"collective={r['collective_s']:9.3e} dom={r['dominant']:10s} "
        f"bound={r['step_time_lower_bound_s']:9.3e} useful={r['useful_ratio']:.3f} "
        f"frac={r['roofline_fraction']:.4f}"
    )
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--attribute", action="store_true",
                    help="dump top contributors for the FIRST variant")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    results = {}
    for i, name in enumerate(args.variants.split(",")):
        res = run_variant(args.arch, args.shape, name, multi_pod=args.multi_pod)
        results[name] = res
        if args.attribute and i == 0:
            # re-lower to get text (run_cell doesn't keep it); cheap enough
            import jax
            from repro.configs import get_config, get_shape
            from repro.launch.dryrun import build_cell
            from repro.launch.mesh import make_production_mesh
            from repro.launch.partitioning import use_partitioning
            from repro.launch.shardings import rules_for

            cfg, shp = get_config(args.arch), get_shape(args.shape)
            mesh = make_production_mesh(multi_pod=args.multi_pod)
            rules = rules_for(cfg, mesh, shp)
            spec = dict(VARIANTS[name])
            remat = spec.pop("_remat", "block")
            with tuning.tuned(**spec), use_partitioning(mesh, rules):
                fn, in_sh, out_sh, in_shapes, donate = build_cell(
                    cfg, shp, mesh, rules, remat=remat
                )
                compiled = (
                    jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                            donate_argnums=donate)
                    .lower(*in_shapes).compile()
                )
            contribs = attribute(compiled.as_text())
            top(contribs, "bytes", 12)
            top(contribs, "coll_bytes", 8)
            top(contribs, "flops", 8)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
