import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices stand in for 2 pods x 256 chips.
For each cell we jit the right step function with full in/out shardings,
``.lower().compile()``, and record:

  * memory_analysis()      -> bytes per device (fits-in-HBM proof)
  * cost_analysis()        -> FLOPs / bytes for the roofline terms
  * trip-count-corrected FLOPs/bytes/collectives (analysis/hlo_cost.py)

Results land in results/dryrun/<mesh>/<arch>__<shape>.json, consumed by
benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all              # single pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod  # 2 pods
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo_cost import module_cost
from repro.analysis.roofline import compute_terms
from repro.configs import applicable_shapes, ARCH_NAMES, get_config, get_shape
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.launch.partitioning import use_partitioning
from repro.launch.shardings import (
    batch_specs,
    cache_sharding,
    params_sharding,
    rules_for,
    train_state_sharding,
)
from repro.models import encdec, hybrid, ssm_lm, transformer
from repro.models.model import get_model, input_specs
from repro.training.optimizer import AdamWConfig
from repro.training.train_state import init_train_state, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


def _prefill_fn(cfg, shape):
    """Family-dispatched prefill step (logits + cache for the full prompt)."""
    max_len = shape.seq_len

    if cfg.family in ("dense", "moe", "vlm"):
        def fn(params, batch):
            return transformer.prefill(params, batch["tokens"], cfg, max_len)
    elif cfg.family == "ssm":
        def fn(params, batch):
            return ssm_lm.prefill(params, batch["tokens"], cfg, max_len)
    elif cfg.family == "hybrid":
        def fn(params, batch):
            return hybrid.prefill(params, batch["tokens"], cfg, max_len)
    elif cfg.family == "audio":
        def fn(params, batch):
            return encdec.prefill(params, batch["enc_embeds"], batch["tokens"], cfg, max_len)
    else:
        raise ValueError(cfg.family)
    return fn


def build_cell(cfg, shape, mesh, rules, *, remat: str = "block",
               microbatch: int = 1):
    """Returns (fn, in_shardings, out_shardings, input_shapes, donate)."""
    api = get_model(cfg)
    rng = jax.random.PRNGKey(0)

    if shape.kind == "train":
        opt_cfg = AdamWConfig(total_steps=10_000)
        step = make_train_step(cfg, opt_cfg, remat=remat, microbatch=microbatch)
        state_shape = jax.eval_shape(lambda: init_train_state(cfg, rng))
        state_sh = train_state_sharding(state_shape, mesh, rules)
        b_sh = batch_specs(cfg, shape, mesh, rules)
        in_shapes = (state_shape, input_specs(cfg, shape))
        in_sh = (state_sh, b_sh)
        out_sh = (state_sh, None)
        return step, in_sh, out_sh, in_shapes, (0,)

    params_shape = jax.eval_shape(api.init, rng)
    p_sh = params_sharding(params_shape, mesh, rules)

    if shape.kind == "prefill":
        fn = _prefill_fn(cfg, shape)
        b_sh = batch_specs(cfg, shape, mesh, rules)
        cache_out_shape = jax.eval_shape(fn, params_shape, input_specs(cfg, shape))[1]
        c_sh = cache_sharding(cache_out_shape, cfg, mesh, rules)
        logits_sh = NamedSharding(mesh, P())
        in_shapes = (params_shape, input_specs(cfg, shape))
        return fn, (p_sh, b_sh), (None, c_sh), in_shapes, ()

    # decode / long-context decode: serve_step over an S-token cache
    B, S = shape.global_batch, shape.seq_len
    cache_shape = jax.eval_shape(lambda: api.init_cache(B, S))
    c_sh = cache_sharding(cache_shape, cfg, mesh, rules)
    tok_sh = batch_specs(cfg, shape, mesh, rules)["token"]

    def serve_step(params, token, cache):
        return api.decode(params, token, cache)

    in_shapes = (params_shape, input_specs(cfg, shape)["token"], cache_shape)
    return serve_step, (p_sh, tok_sh, c_sh), (None, c_sh), in_shapes, (2,)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             test_mesh: bool = False, remat: str = "block",
             microbatch: int = 1,
             out_dir: str = RESULTS_DIR, save: bool = True,
             verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = (make_test_mesh if test_mesh else make_production_mesh)(multi_pod=multi_pod)
    rules = rules_for(cfg, mesh, shape)
    n_chips = mesh.devices.size

    t0 = time.time()
    with use_partitioning(mesh, rules):
        fn, in_sh, out_sh, in_shapes, donate = build_cell(
            cfg, shape, mesh, rules, remat=remat, microbatch=microbatch)
        jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=donate)
        lowered = jfn.lower(*in_shapes)
        compiled = lowered.compile()
    compile_s = time.time() - t0

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0] if cost else {}
    # NOTE: XLA cost_analysis counts while-loop bodies ONCE (verified), which
    # under-reports scan-over-layers models by ~L x. The trip-count-aware HLO
    # parser (analysis/hlo_cost.py) provides the real totals; XLA's numbers
    # are retained for reference.
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))

    try:
        mem = compiled.memory_analysis()
        mem_stats = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not support it
        mem_stats = {"error": str(e)}

    hlo_text = compiled.as_text()
    mc = module_cost(hlo_text)
    flops = mc.flops
    bytes_acc = mc.bytes
    coll_total = mc.coll_total

    terms = compute_terms(cfg, shape, n_chips, flops, bytes_acc, float(coll_total))
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": list(mesh.devices.shape),
        "axes": list(mesh.axis_names),
        "n_chips": int(n_chips),
        "remat": remat,
        "microbatch": microbatch,
        "compile_seconds": round(compile_s, 2),
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "xla_cost_analysis": {"flops": xla_flops, "bytes": xla_bytes},
        "collective_bytes": dict(mc.coll_bytes),
        "collective_counts": dict(mc.coll_counts),
        "collective_bytes_total": coll_total,
        "memory": mem_stats,
        "roofline": {
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "dominant": terms.dominant,
            "step_time_lower_bound_s": terms.step_time_s,
            "model_flops": terms.model_flops,
            "useful_ratio": terms.useful_ratio,
            "roofline_fraction": terms.roofline_fraction,
        },
    }
    if save:
        sub = "multipod" if multi_pod else ("testmesh" if test_mesh else "singlepod")
        d = os.path.join(out_dir, sub)
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, f"{arch}__{shape_name}.json"), "w") as f:
            json.dump(result, f, indent=1)
    if verbose:
        r = result["roofline"]
        print(
            f"[{'2pod' if multi_pod else '1pod'}] {arch:22s} {shape_name:12s} "
            f"compile={compile_s:6.1f}s flops/dev={flops:.3e} bytes/dev={bytes_acc:.3e} "
            f"coll={coll_total:.3e}B dom={r['dominant']:10s} "
            f"useful={r['useful_ratio']:.3f} frac={r['roofline_fraction']:.3f}"
        )
        if mem_stats.get("temp_bytes") is not None:
            print(f"    memory_analysis: {mem_stats}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--test-mesh", action="store_true")
    ap.add_argument("--remat", default="block")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--out-dir", default=RESULTS_DIR)
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_NAMES:
            for shape in applicable_shapes(get_config(arch)):
                cells.append((arch, shape.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    failures = []
    for mp in meshes:
        for arch, shape in cells:
            try:
                run_cell(arch, shape, multi_pod=mp, test_mesh=args.test_mesh,
                         remat=args.remat, microbatch=args.microbatch,
                         out_dir=args.out_dir)
            except Exception as e:
                failures.append((arch, shape, mp, repr(e)))
                print(f"FAILED [{'2pod' if mp else '1pod'}] {arch} {shape}: {e}")
                traceback.print_exc()
    print(f"\n{len(cells) * len(meshes) - len(failures)}/{len(cells) * len(meshes)} cells compiled")
    if failures:
        for f in failures:
            print("  FAIL:", f)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
