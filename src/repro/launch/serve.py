"""Multi-tenant tiered-KV serving driver (the paper's scenario, end to end).

    PYTHONPATH=src python -m repro.launch.serve --steps 80

Builds a smoke-scale model, a MaxMem central manager over an HBM-sized fast
pool + host-sized slow pool, registers a latency-sensitive and a best-effort
tenant, runs continuous-batching decode with Quest page selection, and prints
per-tenant FMMR/latency telemetry each epoch — Figure 4 of the paper, live on
the real serving stack instead of the simulator.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.manager import CentralManager
from repro.core.types import TIER_FAST
from repro.kvcache.paged import TieredPagedKV
from repro.models.model import get_model
from repro.serving.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--fast-pages", type=int, default=8)
    ap.add_argument("--slow-pages", type=int, default=120)
    ap.add_argument("--page-tokens", type=int, default=4)
    ap.add_argument("--quest-pages", type=int, default=3)
    ap.add_argument("--ls-target", type=float, default=0.1)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))

    manager = CentralManager(
        num_pages=args.fast_pages + args.slow_pages,
        fast_capacity=args.fast_pages,
        migration_budget=max(args.fast_pages, 8),
        max_tenants=4,
        sample_period=1,
        exact_sampling=True,
    )
    kv = TieredPagedKV(cfg, args.fast_pages, args.slow_pages,
                       page_tokens=args.page_tokens)
    eng = ServingEngine(
        cfg, params, manager, kv,
        max_batch=2, pages_per_seq=16, quest_pages=args.quest_pages,
        epoch_steps=4,
    )
    eng.add_tenant("ls", t_miss=args.ls_target)
    eng.add_tenant("be", t_miss=1.0)

    rng = np.random.default_rng(0)
    eng.submit("ls", rng.integers(1, cfg.vocab_size, 16), max_new_tokens=args.steps)
    eng.submit("be", rng.integers(1, cfg.vocab_size, 16), max_new_tokens=args.steps)

    print(f"{'step':>5} {'LS fmmr':>8} {'BE fmmr':>8} {'LS fast':>8} "
          f"{'BE fast':>8} {'moved':>6}")
    for i in range(args.steps + 8):
        eng.step()
        if eng._epoch_log and eng._epoch_log[-1]["step"] == eng.step_count:
            e = eng._epoch_log[-1]
            owner = np.asarray(manager.pages.owner)
            tier = np.asarray(manager.pages.tier)
            ls_fast = int(((owner == int(eng.tenant_handles["ls"])) & (tier == TIER_FAST)).sum())
            be_fast = int(((owner == int(eng.tenant_handles["be"])) & (tier == TIER_FAST)).sum())
            print(f"{e['step']:>5} {e['fmmr'].get('ls', 0):>8.3f} "
                  f"{e['fmmr'].get('be', 0):>8.3f} {ls_fast:>8} {be_fast:>8} "
                  f"{e['moved']:>6}")

    for t in ("ls", "be"):
        pct = eng.latency_percentiles(t)
        if pct:
            print(f"{t}: p50={pct['p50'] * 1e6:.1f}us p99={pct['p99'] * 1e6:.1f}us "
                  f"mean={pct['mean'] * 1e6:.1f}us")
    print(f"migrated pages total: {eng._migrated_pages}")
    print(f"completed requests: {len(eng.finished)}")


if __name__ == "__main__":
    main()
