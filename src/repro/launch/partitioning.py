"""Logical-axis partitioning.

Model code annotates activations with *logical* axis names via ``shard(x,
"batch", "seq", None)``. The launch layer installs a (mesh, rules) context;
outside any context the calls are no-ops, so the same model code runs on a
laptop CPU and on a 512-chip mesh unchanged.

Rules map logical names -> mesh axis name(s) (or None = replicated). Param
shardings are derived from the same rules by ``param_specs`` via pytree-path
heuristics, so adding a new architecture does not require hand-writing a
sharding tree.
"""
from __future__ import annotations

import re
import threading
from contextlib import contextmanager
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisNames = Union[None, str, Tuple[str, ...]]

_STATE = threading.local()


def _current() -> Optional[Tuple[Mesh, Dict[str, AxisNames]]]:
    return getattr(_STATE, "ctx", None)


@contextmanager
def use_partitioning(mesh: Mesh, rules: Dict[str, AxisNames]):
    prev = _current()
    _STATE.ctx = (mesh, rules)
    try:
        yield
    finally:
        _STATE.ctx = prev


def logical_spec(names: Sequence[Optional[str]], rules: Dict[str, AxisNames]) -> P:
    """Translate logical dim names -> PartitionSpec, dropping duplicate axes."""
    used: set = set()
    out = []
    for n in names:
        ax = rules.get(n) if n else None
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        axes = tuple(a for a in axes if a not in used)
        used.update(axes)
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(axes)
    return P(*out)


def shard(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Apply a logical sharding constraint (no-op without a context)."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_spec(names, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# --------------------------------------------------------------------------
# Default logical rules
# --------------------------------------------------------------------------
def default_rules(multi_pod: bool = False) -> Dict[str, AxisNames]:
    dp: AxisNames = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": dp,
        "fsdp": dp,
        "seq": None,
        "d_model": None,
        "heads": ("model",),
        "kv_heads": ("model",),
        "kv_seq": None,
        "d_ff": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "experts_buf": ("model",),  # MoE dispatch buffer expert dim
        "expert_cap": None,  # MoE dispatch buffer capacity dim
        "a2a_cap": ("data",),  # explicit-a2a staging: C over data
        "seq_sp": ("model",),  # sequence-parallel residual stream
        "ssm_heads": ("model",),
        "ssm_state": None,
        "enc_seq": None,
    }


# --------------------------------------------------------------------------
# Param spec derivation (pytree-path heuristics)
# --------------------------------------------------------------------------
# Each entry: (regex on '/'.joined path, logical names per trailing dims).
# Leading stacked-layer dims (from scan) are detected by ndim mismatch and
# get None. First match wins.
_PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"embed$", ("vocab", "fsdp_embed")),
    (r"lm_head$", ("fsdp_embed", "vocab")),
    (r"pos_embed$", (None, None)),
    (r"attn/w_q$", ("fsdp", "heads")),
    (r"attn/w_k$", ("fsdp", "kv_heads")),
    (r"attn/w_v$", ("fsdp", "kv_heads")),
    (r"attn/w_o$", ("heads", "fsdp")),
    (r"attn/b_q$", ("heads",)),
    (r"attn/b_[kv]$", ("kv_heads",)),
    (r"attn/[qk]_norm$", (None,)),
    (r"(mlp|shared)/w_(gate|up)$", ("fsdp", "d_ff")),
    (r"(mlp|shared)/w_down$", ("d_ff", "fsdp")),
    (r"moe/router$", ("fsdp", None)),
    (r"moe/w_(gate|up)$", ("experts", "fsdp", None)),
    (r"moe/w_down$", ("experts", None, "fsdp")),
    (r"ssm/in_proj$", ("fsdp", "ssm_inner")),
    (r"ssm/out_proj$", ("ssm_inner", "fsdp")),
    (r"ssm/conv_[wb]$", None),  # tiny; replicated
    (r"ssm/(A_log|D|dt_bias)$", None),
    (r"norm", None),
    (r"", None),  # default: replicated
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params_shape: Any, rules: Dict[str, AxisNames], num_layers_dims: int = 1):
    """Derive a PartitionSpec pytree for a param pytree (of ShapeDtypeStruct
    or arrays). Stacked-layer leading dims get None."""

    def spec_for(path, leaf) -> P:
        ps = _path_str(path)
        shape = leaf.shape
        for pat, names in _PARAM_RULES:
            if re.search(pat, ps):
                if names is None:
                    return P()
                extra = len(shape) - len(names)
                full = (None,) * extra + tuple(names)
                return logical_spec(full, rules)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)
