"""Production mesh construction.

A function (never a module-level constant) so importing this module does not
touch jax device state — device counts are locked at first jax init, and only
``launch/dryrun.py`` is allowed to force the 512-placeholder-device config.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one v5e pod's worth of chips) or 2x16x16 (two pods).

    Axes: "data" carries batch + FSDP; "model" carries TP/EP; "pod" is the
    cross-pod data-parallel axis (DCN-connected in a real deployment)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Small-device-count mesh with the same axis names (CI smoke)."""
    shape = (2, 2, 4) if multi_pod else (4, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
