"""Roofline model for TPU v5e (the deployment target).

Three terms per (arch x shape x mesh), all in seconds-per-step, derived from
the compiled dry-run artifact (cost_analysis + HLO collective parse):

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW

cost_analysis reports the per-device (partitioned) module, so no further
division by chip count is needed. The dominant term is the bottleneck; the
MODEL_FLOPS / HLO_FLOPs ratio measures how much compiled compute is "useful"
(catches remat recompute, masked-block waste, MoE capacity padding,
replicated compute on unused mesh axes).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link (one-direction usable, per prompt spec)
HBM_BYTES = 16 << 30


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    model_flops: float
    useful_ratio: float  # MODEL_FLOPS / (HLO_FLOPs * chips)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs MFU bound at this step time: how close the USEFUL
        work runs to the chips' peak given the dominant term."""
        if self.step_time_s <= 0:
            return 0.0
        chips_flops = self.flops_per_device / max(self.step_time_s, 1e-30)
        return min(chips_flops / PEAK_FLOPS, 1.0) * self.useful_ratio


def _attn_context_flops(cfg, shape, fwd_bwd: float) -> float:
    """Attention score+value matmul FLOPs (outside the N·D parameter rule).

    fwd causal full-seq: 2·B·h·dh·S·ctx (QK^T + PV, halved for causality);
    decode: 4·B·h·dh·ctx per step. ``fwd_bwd`` = 1 (inference) or 3 (train:
    fwd + 2x bwd; remat recompute is NOT useful work)."""
    if not cfg.num_heads:
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    L_attn = cfg.attn_invocations if cfg.family == "hybrid" else cfg.num_layers
    hd = cfg.num_heads * cfg.d_head
    ctx = min(S, cfg.sliding_window) if cfg.sliding_window else S
    if shape.kind == "decode":
        flops = 4.0 * B * L_attn * hd * ctx
        if cfg.is_encoder_decoder:
            flops += 4.0 * B * cfg.num_layers * hd * cfg.max_encoder_len
        return flops
    # full-sequence score elements: S*ctx (window) or causal half S^2/2
    score_elems = S * ctx if cfg.sliding_window else S * S / 2
    flops = fwd_bwd * 4.0 * B * L_attn * hd * score_elems
    if cfg.is_encoder_decoder:
        T = cfg.max_encoder_len
        flops += fwd_bwd * 4.0 * B * cfg.encoder_layers * hd * T * T  # bidir enc
        flops += fwd_bwd * 4.0 * B * cfg.num_layers * hd * S * T  # cross
    return flops


def model_flops_per_step(cfg, shape, n_chips: int) -> float:
    """Useful FLOPs: 6·N_active·D train / 2·N_active·D inference, plus the
    attention-context term. Remat recompute, MoE capacity padding, masked
    blocks, and replicated compute are deliberately excluded — their absence
    is what useful_ratio measures."""
    n_active = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    if cfg.is_encoder_decoder:
        # encoder processes enc_len frames, decoder processes S tokens
        d = cfg.d_model
        enc_p = cfg.encoder_layers * (cfg._attn_params() + cfg._mlp_params() + 2 * d)
        dec_p = cfg.num_layers * (2 * cfg._attn_params() + cfg._mlp_params() + 3 * d)
        head_p = cfg.vocab_size * d
        mult = 6.0 if shape.kind == "train" else 2.0
        if shape.kind == "decode":
            flops = mult * (dec_p + head_p) * B  # encoder already ran
        else:
            flops = mult * (enc_p * B * cfg.max_encoder_len + (dec_p + head_p) * B * S)
        return flops + _attn_context_flops(
            cfg, shape, 3.0 if shape.kind == "train" else 1.0
        )
    if shape.kind == "train":
        return (6.0 * n_active * B * S + _attn_context_flops(cfg, shape, 3.0)
                + _ssd_context_flops(cfg, shape, 3.0))
    if shape.kind == "prefill":
        return (2.0 * n_active * B * S + _attn_context_flops(cfg, shape, 1.0)
                + _ssd_context_flops(cfg, shape, 1.0))
    return 2.0 * n_active * B + _attn_context_flops(cfg, shape, 1.0)


def _ssd_context_flops(cfg, shape, fwd_bwd: float) -> float:
    """SSD (Mamba2) within-chunk + state matmuls, not covered by N·D:
    per token/layer ~ 2·(Q·N + d_inner·Q + 2·d_inner·N). Approximate — the
    compiler's einsum contraction order can undercut it; useful_ratio for SSM
    archs is therefore indicative (EXPERIMENTS.md §Roofline note)."""
    if not cfg.ssm_state:
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    Q, N, di = cfg.ssm_chunk, cfg.ssm_state, cfg.ssm_d_inner
    per_tok = 2.0 * (Q * N + di * Q + 2 * di * N)
    return fwd_bwd * B * S * cfg.num_layers * per_tok


def compute_terms(
    cfg,
    shape,
    n_chips: int,
    flops_per_device: float,
    bytes_per_device: float,
    collective_bytes_dev: float,
) -> RooflineTerms:
    mf = model_flops_per_step(cfg, shape, n_chips)
    total_hlo = flops_per_device * n_chips
    return RooflineTerms(
        compute_s=flops_per_device / PEAK_FLOPS,
        memory_s=bytes_per_device / HBM_BW,
        collective_s=collective_bytes_dev / ICI_BW,
        flops_per_device=flops_per_device,
        bytes_per_device=bytes_per_device,
        collective_bytes=collective_bytes_dev,
        model_flops=mf,
        useful_ratio=min(mf / max(total_hlo, 1.0), 1.0),
    )
