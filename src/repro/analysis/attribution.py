"""Per-instruction cost attribution — the dry-run 'profiler'.

Walks the module like hlo_cost but keeps (computation, instruction, kind,
metadata op_name) per contribution, multiplied by enclosing loop trip counts.
This is how §Perf picks what to attack: no wall-clock trace exists on this
host, so the lowered IR is the profile (per the Pallas-specific hints).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.analysis import hlo_cost as H


@dataclass
class Contribution:
    comp: str
    instr: str
    kind: str
    op_name: str
    flops: float
    bytes: float
    coll_bytes: float
    rtype: str = ""


_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


def _opname(attrs: str) -> str:
    m = _OPNAME_RE.search(attrs)
    return m.group(1)[-110:] if m else ""


def attribute(hlo_text: str) -> List[Contribution]:
    comps, entry = H.parse_module(hlo_text)
    out: List[Contribution] = []

    def walk(comp_name: str, mult: float, in_fusion: bool):
        comp = comps[comp_name]
        for ins in comp.instrs:
            kind = ins.kind
            base = kind[:-6] if kind.endswith("-start") else kind
            fl = by = cb = 0.0
            if base == "dot":
                fl = H._dot_flops(ins, comp)
            elif base == "convolution":
                fl = H._conv_flops(ins, comp)
            if base in H.COLLECTIVE_KINDS and not kind.endswith("-done"):
                _, cb = H.shape_elems_bytes(ins.result_type)
            if base == "while":
                body = H._called(ins.attrs, "body")
                trip = H._trip_count(ins, comps)
                if body in comps:
                    walk(body, mult * trip, in_fusion)
                continue
            if base == "fusion":
                called = H._called(ins.attrs, "calls")
                if called in comps:
                    walk(called, mult, True)
                    if not in_fusion:
                        by = H._fusion_bytes(comps[called])
                if fl or by or cb:
                    out.append(Contribution(comp_name, ins.name, base,
                                            _opname(ins.attrs), fl * mult,
                                            by * mult, cb * mult,
                                            ins.result_type[:48]))
                continue
            if not in_fusion:
                _, rb = H.shape_elems_bytes(ins.result_type)
                if base in H._BYTES_OPS_FULL:
                    ob = sum(
                        H.shape_elems_bytes(comp.types.get(op, ""))[1]
                        for op in ins.operand_names
                    )
                    by = rb + ob
                elif base in H._BYTES_OPS_RESULT_ONLY:
                    by = 2 * rb
                elif base in H._BYTES_OPS_UPDATE:
                    if len(ins.operand_names) > 1:
                        _, ub = H.shape_elems_bytes(
                            comp.types.get(ins.operand_names[1], "")
                        )
                        by = 2 * ub
            if fl or by or cb:
                out.append(Contribution(comp_name, ins.name, base,
                                        _opname(ins.attrs), fl * mult,
                                        by * mult, cb * mult,
                                        ins.result_type[:48]))

    if entry:
        walk(entry, 1.0, False)
    return out


def top(contribs: List[Contribution], key: str = "bytes", n: int = 15):
    rows = sorted(contribs, key=lambda c: -getattr(c, key))[:n]
    total = sum(getattr(c, key) for c in contribs)
    print(f"--- top {n} by {key} (total {total:.3e}) ---")
    for c in rows:
        print(
            f"{getattr(c, key):>12.3e}  {c.kind:18s} {c.instr[:26]:28s} "
            f"{c.rtype:40s} {c.op_name[-70:]}"
        )
    return rows


# ---------------------------------------------------------------------------
# Kernel-adjusted memory term (§Perf): the XLA attention path materializes
# score/probability tensors (shape [..., q_blk, kv_blk] and their stacked
# residuals); the Pallas flash kernel (kernels/flash_attention.py, validated
# vs ref) keeps them in VMEM. This pass removes those contributions and adds
# the kernel's true HBM traffic, giving the deploy-with-kernel memory term.
# Clearly a MODEL, labeled as such in EXPERIMENTS.md.
# ---------------------------------------------------------------------------
def kernel_adjusted_bytes(
    contribs: List[Contribution],
    cfg,
    shape,
    n_chips: int,
    q_blk: int = 512,
    kv_blk: int = 1024,
) -> Tuple[float, float]:
    """Returns (xla_bytes, kernel_adjusted_bytes) per device."""
    import re as _re

    pat = _re.compile(rf"\[(?:\d+,)*{q_blk},{kv_blk}\]")
    total = sum(c.bytes for c in contribs)
    attn_chain = sum(c.bytes for c in contribs if pat.search(c.rtype))
    # flash kernel HBM traffic per layer (bf16): fwd reads q,k,v + writes o;
    # bwd reads q,k,v,o,do + writes dq,dk,dv; remat re-reads q,k,v.
    B, S = shape.global_batch, shape.seq_len
    heads_local = max(cfg.num_heads // 16, 1)  # model axis 16
    kv_local = max(cfg.num_kv_heads // 16, 1)
    dh = cfg.d_head
    dp = n_chips // 16
    per_tensor_q = B * S * heads_local * dh * 2 / dp
    per_tensor_kv = B * S * kv_local * dh * 2 / dp
    fwd = 1 * per_tensor_q + 2 * per_tensor_kv + per_tensor_q  # q,k,v -> o
    bwd = 2 * per_tensor_q + 2 * per_tensor_kv + 2 * per_tensor_q + 3 * per_tensor_kv
    remat = fwd
    L_attn = cfg.attn_invocations if cfg.family == "hybrid" else cfg.num_layers
    kernel_traffic = (fwd + bwd + remat) * L_attn
    return total, total - attn_chain + kernel_traffic
