"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, so any
scan-over-layers model under-reports FLOPs/bytes/collectives by ~num_layers x
(verified empirically; see EXPERIMENTS.md §Dry-run). This module re-derives
totals from the optimized HLO text with loop semantics:

  cost(computation) = sum over instructions of
      dot/conv FLOPs (operand shapes resolved via a per-computation symbol
      table + contracting dims)
    + fusion        -> FLOPs of the called computation; HBM bytes are the
                       fusion wrapper's operands+result (internals stay in
                       registers/VMEM)
    + while         -> trip_count * cost(body); trip count from the
                       backend_config known_trip_count (scans always carry
                       it), falling back to the cond's compare constant
    + collectives   -> result bytes per kind (x trip inside loops)
    + HBM bytes for materializing ops (operands + result)

Approximations (documented in EXPERIMENTS.md):
  * conv FLOPs = result_elems * 2 * prod(kernel_spatial) * Cin/groups
  * unparseable trip counts default to 1 (conservative)
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# ops whose operands+result all cross HBM. Bare elementwise ops (add, mul,
# convert, ...) are EXCLUDED: on the TPU target they fuse into neighbors;
# counting the CPU backend's unfused forms would overstate the memory term.
_BYTES_OPS_FULL = {
    "fusion", "dot", "convolution", "copy", "reduce", "sort",
    "concatenate", "pad", "transpose", "reverse", "select-and-scatter",
    "reduce-window", "cholesky", "triangular-solve", "fft", "rng",
    "custom-call",
} | set(COLLECTIVE_KINDS)
# slicing ops touch only the sliced region, not the full operand
_BYTES_OPS_RESULT_ONLY = {"dynamic-slice", "slice", "gather", "broadcast"}
# update ops touch the update region twice (read + write), not the buffer
_BYTES_OPS_UPDATE = {"dynamic-update-slice", "scatter"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")
_OP_NAME_RE = re.compile(r"\s*([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_TRIP_RE = re.compile(r"known_trip_count[\"':{\s]+n[\"':\s]+(\d+)")


def shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    elems = 0
    nbytes = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * DTYPE_BYTES[dt]
    return elems, nbytes


def shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(x) for x in m.group(2).split(",")]


@dataclass
class Instr:
    name: str
    kind: str
    result_type: str
    operand_names: List[str]
    attrs: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)  # instr name -> type


_OPERAND_NAME_RE = re.compile(r"%([\w\.\-]+)")


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        s = raw.strip()
        if cur is None:
            m = _COMP_HDR_RE.match(s)
            if m:
                cur = Computation(name=m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
            continue
        if s == "}":
            cur = None
            continue
        m = _INSTR_HEAD_RE.match(s)
        if not m:
            continue
        name = m.group(1)
        i = m.end()
        # result type: balanced-paren tuple (may contain /*index=N*/ comments
        # with '=' inside) or a single token
        if i < len(s) and s[i] == "(":
            depth = 0
            j = i
            while j < len(s):
                if s[j] == "(":
                    depth += 1
                elif s[j] == ")":
                    depth -= 1
                    if depth == 0:
                        j += 1
                        break
                j += 1
            rtype = s[i:j]
        else:
            j = i
            while j < len(s) and not s[j].isspace():
                j += 1
            rtype = s[i:j]
        mo = _OP_NAME_RE.match(s, j)
        if not mo:
            continue
        kind = mo.group(1)
        start = mo.end()
        depth, k = 1, start
        while k < len(s) and depth > 0:
            if s[k] == "(":
                depth += 1
            elif s[k] == ")":
                depth -= 1
            k += 1
        operand_str = s[start : k - 1]
        attrs = s[k:]
        operands = _OPERAND_NAME_RE.findall(operand_str)
        cur.types[name] = rtype
        cur.instrs.append(Instr(name, kind, rtype, operands, attrs))
    return comps, entry


def _trip_count(ins: Instr, comps: Dict[str, Computation]) -> int:
    m = _TRIP_RE.search(ins.attrs)
    if m:
        return max(int(m.group(1)), 1)
    cond_name = _called(ins.attrs, "condition")
    cond = comps.get(cond_name)
    if cond is not None:
        consts = []
        for ci in cond.instrs:
            if ci.kind == "constant":
                mm = re.search(r"constant\((\d+)\)", ci.attrs)
                if mm:
                    consts.append(int(mm.group(1)))
        if len(consts) == 1:
            return max(consts[0], 1)
    return 1


def _dot_flops(ins: Instr, comp: Computation) -> float:
    relems, _ = shape_elems_bytes(ins.result_type)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    if not m or not ins.operand_names:
        return 2.0 * relems
    cdims = [int(x) for x in m.group(1).split(",") if x]
    lhs_type = comp.types.get(ins.operand_names[0], "")
    dims = shape_dims(lhs_type)
    k = 1
    for d in cdims:
        if d < len(dims):
            k *= dims[d]
    return 2.0 * relems * k


def _conv_flops(ins: Instr, comp: Computation) -> float:
    relems, _ = shape_elems_bytes(ins.result_type)
    kern = 1
    m = re.search(r"window=\{size=([0-9x]+)", ins.attrs)
    if m:
        for d in m.group(1).split("x"):
            kern *= int(d)
    cin = 1
    if len(ins.operand_names) > 1:
        d = shape_dims(comp.types.get(ins.operand_names[1], ""))
        if len(d) >= 2:
            cin = d[-2]
    mg = re.search(r"feature_group_count=(\d+)", ins.attrs)
    if mg and cin > 1:
        cin = max(cin // int(mg.group(1)), 1)
    return 2.0 * relems * kern * cin


@dataclass
class ModuleCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    coll_counts: Dict[str, float] = field(default_factory=lambda: defaultdict(float))

    @property
    def coll_total(self) -> float:
        return float(sum(self.coll_bytes.values()))

    def scaled(self, factor: float) -> "ModuleCost":
        out = ModuleCost(flops=self.flops * factor, bytes=self.bytes * factor)
        for k, v in self.coll_bytes.items():
            out.coll_bytes[k] = v * factor
        for k, v in self.coll_counts.items():
            out.coll_counts[k] = v * factor
        return out

    def add(self, other: "ModuleCost", factor: float = 1.0) -> None:
        self.flops += other.flops * factor
        self.bytes += other.bytes * factor
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] += v * factor
        for k, v in other.coll_counts.items():
            self.coll_counts[k] += v * factor


def _called(attrs: str, key: str) -> Optional[str]:
    m = re.search(rf"{key}=%?([\w\.\-]+)", attrs)
    return m.group(1) if m else None


_SLICE_KINDS = {"dynamic-slice", "slice", "gather"}


def _fusion_bytes(fcomp: Computation) -> float:
    """HBM bytes of one fusion execution, aware of slice/update semantics:

    * a parameter consumed ONLY by slicing ops contributes the sliced bytes
      (the classic scan pattern: full stacked [L, ...] buffer operand, one
      layer's slice actually read)
    * a parameter that only flows into a root dynamic-update-slice as the
      updated buffer is aliased in place: zero read
    * root DUS writes the update region, not the whole buffer
    """
    params = [ins for ins in fcomp.instrs if ins.kind == "parameter"]
    uses: Dict[str, List[Instr]] = {p.name: [] for p in params}
    for ins in fcomp.instrs:
        if ins.kind == "parameter":
            continue
        for op in ins.operand_names:
            if op in uses:
                uses[op].append(ins)
    root = fcomp.instrs[-1] if fcomp.instrs else None

    read = 0.0
    for p in params:
        _, pb = shape_elems_bytes(p.result_type)
        us = uses[p.name]
        if not us:
            continue
        if all(u.kind in _SLICE_KINDS and u.operand_names and u.operand_names[0] == p.name
               for u in us):
            for u in us:
                _, rb = shape_elems_bytes(u.result_type)
                read += rb
            continue
        if (
            root is not None
            and root.kind == "dynamic-update-slice"
            and all(u is root and u.operand_names and u.operand_names[0] == p.name
                    for u in us)
        ):
            continue  # in-place aliased buffer
        read += pb

    if root is not None and root.kind == "dynamic-update-slice":
        ub = 0.0
        if len(root.operand_names) > 1:
            t = fcomp.types.get(root.operand_names[1], "")
            _, ub = shape_elems_bytes(t)
        write = ub
    else:
        _, write = shape_elems_bytes(root.result_type) if root else (0, 0.0)
    return read + write


def _cost_of(
    comp: Computation,
    comps: Dict[str, Computation],
    memo: Dict[str, ModuleCost],
    in_fusion: bool,
) -> ModuleCost:
    key = comp.name + ("#f" if in_fusion else "")
    if key in memo:
        return memo[key]
    memo[key] = ModuleCost()  # break cycles defensively
    total = ModuleCost()
    for ins in comp.instrs:
        kind = ins.kind
        base = kind[:-6] if kind.endswith("-start") else kind
        if base == "dot":
            total.flops += _dot_flops(ins, comp)
        elif base == "convolution":
            total.flops += _conv_flops(ins, comp)
        if base in COLLECTIVE_KINDS and not kind.endswith("-done"):
            _, rb = shape_elems_bytes(ins.result_type)
            total.coll_bytes[base] += rb
            total.coll_counts[base] += 1
        if base == "while":
            body = _called(ins.attrs, "body")
            trip = _trip_count(ins, comps)
            if body in comps:
                total.add(_cost_of(comps[body], comps, memo, in_fusion), trip)
            continue
        if base == "fusion":
            called = _called(ins.attrs, "calls")
            if called in comps:
                sub = _cost_of(comps[called], comps, memo, True)
                total.flops += sub.flops
                total.add(
                    ModuleCost(coll_bytes=sub.coll_bytes, coll_counts=sub.coll_counts)
                )
                if not in_fusion:
                    total.bytes += _fusion_bytes(comps[called])
            continue
        if base in ("call", "conditional", "async-start"):
            for keyname in ("to_apply", "true_computation", "false_computation",
                            "called_computation"):
                called = _called(ins.attrs, keyname)
                if called in comps:
                    total.add(_cost_of(comps[called], comps, memo, in_fusion))
        if not in_fusion:
            _, rb = shape_elems_bytes(ins.result_type)
            if base in _BYTES_OPS_FULL and base != "fusion":
                ob = 0
                for op in ins.operand_names:
                    _, b = shape_elems_bytes(comp.types.get(op, ""))
                    ob += b
                total.bytes += rb + ob
            elif base in _BYTES_OPS_RESULT_ONLY:
                total.bytes += 2 * rb  # read region + write result
            elif base in _BYTES_OPS_UPDATE:
                ub = 0
                if len(ins.operand_names) > 1:
                    _, ub = shape_elems_bytes(
                        comp.types.get(ins.operand_names[1], "")
                    )
                total.bytes += 2 * ub
    memo[key] = total
    return total


def module_cost(hlo_text: str) -> ModuleCost:
    comps, entry = parse_module(hlo_text)
    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c].instrs)) if comps else None
    if entry is None:
        return ModuleCost()
    return _cost_of(comps[entry], comps, {}, False)
