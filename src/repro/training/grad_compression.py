"""Int8 gradient compression with error feedback (distributed-optimization
trick for bandwidth-bound data parallelism).

Each tensor is quantized to int8 with a per-tensor scale before crossing the
data-parallel reduction; the quantization residual is carried in an error-
feedback buffer and re-added next step (Seide et al. / 1-bit Adam lineage —
convergence-neutral in expectation).

Two integration points:
  * ``compress_decompress`` — pure transform used inside the standard pjit
    train step: grads are quantized/dequantized around XLA's implicit DP
    all-reduce. This halves (bf16) or quarters (fp32) the bytes the reduce
    moves ONLY when the compiler keeps the cast adjacent to the collective;
    the dry-run's collective-bytes parser verifies whether it did.
  * ``shardmap_int8_psum`` — explicit shard_map reduction for the launch
    layer: quantize -> psum(int32) -> dequantize, guaranteeing an int8-width
    wire format regardless of compiler choices.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _quant(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(grads: Any, error_buf: Any) -> Tuple[Any, Any]:
    """Quantize+dequantize each grad leaf with error feedback.

    Returns (decompressed_grads, new_error_buf)."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _quant(gf)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), gf - deq

    out = jax.tree.map(one, grads, error_buf)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return deq, err


def init_error_buf(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def shardmap_int8_psum(mesh, axis_names: Tuple[str, ...]):
    """Returns f(x) performing an int8-wire all-reduce over ``axis_names``.

    Usage (launch layer): reduce = shardmap_int8_psum(mesh, ("data",));
    g = reduce(g)  # g replicated over data axis afterwards.
    """
    from jax.experimental.shard_map import shard_map

    def reduce_fn(x):
        q, scale = _quant(x)
        qs = jax.lax.psum(q.astype(jnp.int32), axis_names)  # int32 accum
        s = jax.lax.pmax(scale, axis_names)  # conservative shared scale
        n = 1
        for a in axis_names:
            n *= mesh.shape[a]
        return qs.astype(jnp.float32) * s / n

    def apply(x):
        return shard_map(
            reduce_fn,
            mesh=mesh,
            in_specs=P(*axis_names),
            out_specs=P(*axis_names),
        )(x)

    return apply
