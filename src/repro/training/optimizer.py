"""AdamW optimizer + schedules, pure JAX (no optax dependency).

Optimizer state mirrors the param pytree (m, v in fp32) and inherits the
params' sharding (FSDP/TP) via the launch layer's param specs, so ZeRO-style
state sharding falls out of pjit for free.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    m: Any  # pytree like params, fp32
    v: Any
    step: jax.Array  # [] int32


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(m=zeros, v=jax.tree.map(jnp.copy, zeros), step=jnp.zeros((), jnp.int32))


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def _decay_mask(path) -> bool:
    """No weight decay on norms / biases / 1-D params (standard)."""
    name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
    return not any(s in name for s in ("norm", "bias", "b_q", "b_k", "b_v", "A_log", "D", "dt_bias"))


def adamw_update(
    cfg: AdamWConfig, params, grads, state: OptState
) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    """One AdamW step with global-norm clipping. Returns (params', state', metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if _decay_mask(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v), params, grads, state.m, state.v
    )
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(m=new_m, v=new_v, step=step), metrics
