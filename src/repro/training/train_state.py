"""TrainState + train_step factory (the function every dry-run cell lowers)."""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import get_model
from repro.training import grad_compression as gc
from repro.training.optimizer import AdamWConfig, OptState, adamw_update, init_opt_state


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    error_buf: Optional[Any] = None  # grad-compression error feedback


def init_train_state(cfg, rng, *, compress_grads: bool = False) -> TrainState:
    api = get_model(cfg)
    params = api.init(rng)
    return TrainState(
        params=params,
        opt=init_opt_state(params),
        error_buf=gc.init_error_buf(params) if compress_grads else None,
    )


def make_train_step(cfg, opt_cfg: AdamWConfig, *, remat: str = "block",
                    compress_grads: bool = False, microbatch: int = 1):
    """Build train_step(state, batch) -> (state, metrics). Pure function —
    jit/pjit/shardings are applied by the caller (launch layer).

    ``microbatch`` > 1 enables gradient accumulation: the batch splits into K
    microbatches scanned sequentially with fp32 grad accumulation and ONE
    optimizer step — activation peak drops ~K× (how over-HBM train cells fit
    on 16 GB chips; see EXPERIMENTS §Capacity)."""
    api = get_model(cfg)

    def _grads(params, batch):
        def loss_fn(p):
            loss, metrics = api.loss(p, batch, remat=remat)
            return loss, metrics

        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if microbatch > 1:
            B = jax.tree.leaves(batch)[0].shape[0]
            assert B % microbatch == 0, (B, microbatch)
            mb = {
                k: v.reshape(microbatch, B // microbatch, *v.shape[1:])
                for k, v in batch.items()
            }

            def acc_fn(carry, mbatch):
                gsum, msum = carry
                (loss, metrics), g = _grads(state.params, mbatch)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g
                )
                metrics = dict(metrics)
                metrics["loss"] = loss
                msum = jax.tree.map(lambda a, b: a + b, msum, metrics)
                return (gsum, msum), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            m0 = {"ce": 0.0, "aux": 0.0, "tokens": 0.0, "loss": 0.0}
            m0 = jax.tree.map(jnp.float32, m0)
            (grads, metrics), _ = jax.lax.scan(acc_fn, (g0, m0), mb)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            metrics = jax.tree.map(lambda m: m / microbatch, metrics)
            metrics["tokens"] = metrics["tokens"] * microbatch
            loss = metrics["loss"]
        else:
            (loss, metrics), grads = _grads(state.params, batch)
            metrics = dict(metrics)
            metrics["loss"] = loss
        error_buf = state.error_buf
        if compress_grads and error_buf is not None:
            grads, error_buf = gc.compress_decompress(grads, error_buf)
        params, opt, opt_metrics = adamw_update(opt_cfg, state.params, grads, state.opt)
        metrics.update(opt_metrics)
        return TrainState(params=params, opt=opt, error_buf=error_buf), metrics

    return train_step


def make_eval_step(cfg, *, remat: str = "none"):
    api = get_model(cfg)

    def eval_step(params, batch):
        loss, metrics = api.loss(params, batch, remat=remat)
        return metrics

    return eval_step
