"""Fault-tolerance runtime: heartbeats, elastic restart, straggler mitigation.

On a real multi-pod deployment these hooks ride on the cluster scheduler
(GKE/Borg preemption signals, jax.distributed heartbeats). The control logic
here is the deployable part; liveness signals are injected (testable with
fake clocks, and wirable to real signals on a cluster).

Recovery contract (exercised by tests + launch/train.py):
  1. HeartbeatTracker declares a host dead after ``timeout`` silence
  2. the coordinator picks the new world (alive hosts), halving the data-
     parallel axis if needed to keep the mesh rectangular
  3. TrainState restores from the last checkpoint with the NEW shardings
     (Checkpointer.restore(shardings=...)) and the data pipeline replays
     from the checkpointed step — bitwise-identical stream (see data/)
  4. training resumes; the step clock never goes backwards more than one
     checkpoint interval
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat: float
    alive: bool = True
    step_times: List[float] = dataclasses.field(default_factory=list)


class HeartbeatTracker:
    def __init__(self, host_ids: Sequence[int], timeout: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.timeout = timeout
        now = clock()
        self.hosts: Dict[int, HostState] = {
            h: HostState(host_id=h, last_beat=now) for h in host_ids
        }

    def beat(self, host_id: int) -> None:
        self.hosts[host_id].last_beat = self.clock()

    def check(self) -> List[int]:
        """Returns newly-dead host ids."""
        now = self.clock()
        dead = []
        for h in self.hosts.values():
            if h.alive and now - h.last_beat > self.timeout:
                h.alive = False
                dead.append(h.host_id)
        return dead

    def alive_hosts(self) -> List[int]:
        return [h.host_id for h in self.hosts.values() if h.alive]


class StragglerDetector:
    """Per-host step-time EWMA; hosts slower than ``ratio`` x median are
    stragglers. Mitigations: re-shard its data (elastic), or issue backup
    steps (speculative execution) — the detector only decides."""

    def __init__(self, host_ids: Sequence[int], ewma: float = 0.3, ratio: float = 1.8):
        self.ewma = ewma
        self.ratio = ratio
        self.times: Dict[int, Optional[float]] = {h: None for h in host_ids}

    def record(self, host_id: int, step_seconds: float) -> None:
        prev = self.times.get(host_id)
        self.times[host_id] = (
            step_seconds if prev is None else self.ewma * step_seconds + (1 - self.ewma) * prev
        )

    def stragglers(self) -> List[int]:
        vals = [t for t in self.times.values() if t is not None]
        if len(vals) < 2:
            return []
        med = sorted(vals)[len(vals) // 2]
        return [h for h, t in self.times.items() if t is not None and t > self.ratio * med]


def plan_elastic_mesh(alive_hosts: int, chips_per_host: int,
                      model_parallel: int) -> Tuple[int, int]:
    """Largest rectangular (data, model) mesh from the surviving hosts.

    model_parallel is fixed (weights are sharded that way); the data axis
    shrinks to the largest power-of-two of full rows that still divides the
    global batch. Returns (data_size, model_size)."""
    total = alive_hosts * chips_per_host
    if total < model_parallel:
        raise RuntimeError("not enough chips for the model-parallel axis")
    rows = total // model_parallel
    # largest power of two <= rows keeps batch divisibility simple
    data = 1 << (rows.bit_length() - 1)
    return data, model_parallel


class ElasticRunner:
    """Drives a step function with checkpoint/restart on injected failures.

    The step callable raises HostFailure to simulate a lost host; the runner
    restores from the checkpointer and continues with the shrunken world.
    """

    def __init__(self, checkpointer, make_step, save_every: int = 10):
        self.ckpt = checkpointer
        self.make_step = make_step  # (world_size) -> (step_fn, state)
        self.save_every = save_every
        self.restarts = 0

    def run(self, state, world_size: int, n_steps: int, fail_at=()):
        step_fn = self.make_step(world_size)
        fail_at = set(fail_at)
        step = 0
        while step < n_steps:
            if step % self.save_every == 0:
                self.ckpt.save(step, state, meta={"world": world_size}, blocking=True)
            if step in fail_at:
                fail_at.discard(step)
                self.restarts += 1
                world_size = max(world_size // 2, 1)
                step_fn = self.make_step(world_size)
                last = self.ckpt.latest_step()
                state, meta = self.ckpt.restore(state, step=last)
                step = last
                continue
            state = step_fn(state, step)
            step += 1
        return state, world_size


class HostFailure(RuntimeError):
    pass


# --------------------------------------------------------------------------
# Fleet sweep fault tolerance (DESIGN.md §7): supervision of the dispatch
# worker and checkpoint/resume for scenario sweeps. The generic pieces above
# (HeartbeatTracker, Checkpointer) are the substrate; these two classes wire
# them to core.fleet.FleetManager / core.scenario.run_sweep.
# --------------------------------------------------------------------------

class DispatchSupervisor:
    """Supervises a fleet's async dispatch worker during a sweep.

    ``join`` bounds every wait on an in-flight chunk by ``timeout`` (None =
    wait forever); a timeout or worker fault surfaces as ``DispatchError``
    and the sweep driver recovers (``FleetManager.recover_dispatch``), calls
    :meth:`note_fallback`, and re-runs the chunk through :meth:`dispatch` —
    which, once degraded, runs every subsequent chunk on the serialized
    inline path (``pipeline=False`` semantics). With a timeout set the
    fleet's ``HeartbeatTracker`` supervision is enabled too (host 0 = the
    worker; it beats at dispatch start and completion)."""

    def __init__(self, fleet, timeout: Optional[float] = None):
        self.fleet = fleet
        self.timeout = timeout
        self.degraded = False  # sticky: once fallen back, stay serialized
        self.fallbacks = 0
        if timeout is not None:
            fleet.enable_supervision(timeout=timeout)

    def dispatch(self, k: int, counts=None, trim_stats: bool = True):
        return self.fleet.run_epochs_async(
            k, counts=counts, trim_stats=trim_stats, inline=self.degraded
        )

    def join(self, handle):
        """Bounded wait on a ``FleetPendingResult``; raises DispatchError on
        timeout or worker fault (the caller recovers + falls back)."""
        return handle.result(self.timeout)

    def note_fallback(self) -> None:
        self.degraded = True
        self.fallbacks += 1


_BIGINT_KEY = "$bigint"
_PARAM_FLOAT_FIELDS = ("ewma_lambda", "hysteresis", "promote_band", "demote_band")


def _sanitize_meta(obj):
    """Make a meta tree msgpack-encodable: numpy scalars -> python, ints
    beyond 64 bits (the PCG64 state words are 128-bit) -> tagged strings."""
    if isinstance(obj, dict):
        return {k: _sanitize_meta(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_sanitize_meta(v) for v in obj]
    if isinstance(obj, (bool, np.bool_)):
        return bool(obj)
    if isinstance(obj, (int, np.integer)):
        v = int(obj)
        if v > 2**63 - 1 or v < -(2**63):
            return {_BIGINT_KEY: str(v)}
        return v
    if isinstance(obj, (float, np.floating)):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return [_sanitize_meta(v) for v in obj.tolist()]
    return obj


def _unsanitize_meta(obj):
    if isinstance(obj, dict):
        if set(obj) == {_BIGINT_KEY}:
            return int(obj[_BIGINT_KEY])
        return {k: _unsanitize_meta(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_unsanitize_meta(v) for v in obj]
    return obj


def _params_to_meta(params) -> dict:
    """PolicyParams -> plain dict. The params can't ride in the leaf pytree:
    ``fair_mode`` is a static python bool, not an array leaf."""
    out = {}
    for f, v in params._asdict().items():
        if f == "fair_mode":
            out[f] = bool(v)
        elif f in _PARAM_FLOAT_FIELDS:
            out[f] = float(v)
        else:
            out[f] = int(v)
    return out


def _params_from_meta(meta: dict):
    import jax.numpy as jnp

    from repro.core.types import PolicyParams

    kw = {}
    for f, v in meta.items():
        if f == "fair_mode":
            kw[f] = bool(v)
        elif f in _PARAM_FLOAT_FIELDS:
            kw[f] = jnp.float32(v)
        else:
            kw[f] = jnp.int32(v)
    return PolicyParams(**kw)


def _sim_to_meta(sim) -> dict:
    from dataclasses import asdict

    tenants = []
    for nm, t in sim.tenants.items():
        ent = {
            "name": nm,
            "spec": asdict(t.spec),
            "page_ids": np.asarray(t.page_ids).tolist(),
            "perm": np.asarray(t._perm).tolist(),
        }
        if hasattr(t, "_pp_perms"):
            ent["pp_perms"] = [np.asarray(p).tolist() for p in t._pp_perms]
            ent["pp_side"] = int(t._pp_side)
        tenants.append(ent)
    return {
        "rng": sim.rng.bit_generator.state,
        "stall_epochs": float(sim._stall_epochs),
        "failed": bool(sim.failed),
        "handles": {nm: int(h) for nm, h in sim.handles.items()},
        "tenants": tenants,
        "history": [asdict(r) for r in sim.history],
    }


def _sim_from_meta(sim, meta: dict) -> None:
    from repro.core.simulator import EpochRecord, TenantSim, WorkloadSpec

    sim.rng.bit_generator.state = meta["rng"]
    sim._stall_epochs = float(meta["stall_epochs"])
    sim.failed = bool(meta["failed"])
    sim.handles = {nm: int(h) for nm, h in meta["handles"].items()}
    sim.tenants = {}
    for ent in meta["tenants"]:
        spec_d = dict(ent["spec"])
        spec_d["sets"] = tuple(tuple(s) for s in spec_d.get("sets", ()))
        spec = WorkloadSpec(**spec_d)
        t = TenantSim.__new__(TenantSim)
        t.spec = spec
        t.page_ids = np.asarray(ent["page_ids"], np.int64)
        t.rng = sim.rng
        t._perm = np.asarray(ent["perm"], np.int64)
        t.probs = TenantSim._build_probs(spec, len(t.page_ids))[t._perm]
        if "pp_perms" in ent:
            t._pp_perms = tuple(np.asarray(p, np.int64) for p in ent["pp_perms"])
            t._pp_side = int(ent["pp_side"])
        sim.tenants[ent["name"]] = t
    sim.history = [EpochRecord(**r) for r in meta["history"]]


class SweepCheckpoint:
    """Checkpoint/resume for fleet scenario sweeps (``scenario.run_sweep``).

    Everything a sweep needs to continue BIT-IDENTICALLY rides in one
    atomic checkpoint step (checkpoint/checkpointer.py: tmp + rename):

      * device pytree ``{"m<i>": PolicyState}`` — every machine's full
        policy state (for a failed machine, the PARKED real state, so the
        saved structure never depends on which machines happen to be down);
      * msgpack meta — per-machine params/epoch clock/queue counters/failed
        flags, and per-sim host state: the numpy PRNG stream (PCG64 state,
        128-bit words as tagged strings), tenant specs + page maps +
        scatter permutations, and the recorded epoch history.

    A sweep killed at any chunk boundary and resumed from the latest step
    replays the remaining epochs to the exact histories of an uninterrupted
    run (locked by tests/test_chaos.py)."""

    def __init__(self, directory: str, keep: int = 3):
        from repro.checkpoint.checkpointer import Checkpointer

        self.ckpt = Checkpointer(directory, keep=keep)

    def latest(self) -> Optional[int]:
        return self.ckpt.latest_step()

    def save(self, cur: int, fleet, sims) -> None:
        device_tree = {}
        machines_meta = []
        for i, m in enumerate(fleet.machines):
            failed = i in fleet._parked
            if failed:
                state = fleet._parked[i]
            else:
                m._ensure_segs()  # checkpoint a self-consistent state
                state = m._state
            device_tree[f"m{i}"] = state
            machines_meta.append({
                "params": _params_to_meta(m.params),
                "epoch_index": int(m.epoch_index),
                "arrival_seq": int(m._arrival_seq),
                "queue": {
                    "enqueued": int(m.queue_enqueued),
                    "drained": int(m.queue_drained),
                    "cancelled": int(m.queue_cancelled),
                    "dropped": int(m.queue_dropped),
                },
                "migration_failures": int(m.migration_failures),
                "failed": failed,
            })
        meta = _sanitize_meta({
            "cur": int(cur),
            "machines": machines_meta,
            "sims": [_sim_to_meta(s) for s in sims],
        })
        self.ckpt.save(int(cur), device_tree, meta=meta, blocking=True)

    def restore(self, fleet, sims, step: Optional[int] = None) -> int:
        """Restore fleet + sims in place; returns the sweep cursor."""
        from repro.core.types import OwnerSegments, PolicyState

        K = len(fleet.machines)
        target = {}
        for i in range(K):
            st = PolicyState.create(
                fleet.num_pages, fleet.max_tenants, seed=0,
                queue_size=fleet.queue_size,
            )
            target[f"m{i}"] = st._replace(segs=OwnerSegments.build(
                np.full(fleet.num_pages, -1, np.int32), fleet.max_tenants
            ))
        tree, meta = self.ckpt.restore(target, step=step)
        meta = _unsanitize_meta(meta)
        # un-fail whatever is failed NOW; the checkpoint's flags re-park below
        for i in list(fleet.failed_machines):
            fleet.recover_machine(i)
        for i, m in enumerate(fleet.machines):
            mm = meta["machines"][i]
            m._state = tree[f"m{i}"]
            m._segs_owner = None  # restored segs are current by construction
            m.params = _params_from_meta(mm["params"])
            m.epoch_index = int(mm["epoch_index"])
            m._arrival_seq = int(mm["arrival_seq"])
            q = mm["queue"]
            m.queue_enqueued = int(q["enqueued"])
            m.queue_drained = int(q["drained"])
            m.queue_cancelled = int(q["cancelled"])
            m.queue_dropped = int(q["dropped"])
            m.migration_failures = int(mm["migration_failures"])
            m._snap = None
        for sim, sm in zip(sims, meta["sims"]):
            _sim_from_meta(sim, sm)
        for i, mm in enumerate(meta["machines"]):
            if mm["failed"]:
                fleet.fail_machine(i)
        return int(meta["cur"])
