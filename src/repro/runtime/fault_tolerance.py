"""Fault-tolerance runtime: heartbeats, elastic restart, straggler mitigation.

On a real multi-pod deployment these hooks ride on the cluster scheduler
(GKE/Borg preemption signals, jax.distributed heartbeats). The control logic
here is the deployable part; liveness signals are injected (testable with
fake clocks, and wirable to real signals on a cluster).

Recovery contract (exercised by tests + launch/train.py):
  1. HeartbeatTracker declares a host dead after ``timeout`` silence
  2. the coordinator picks the new world (alive hosts), halving the data-
     parallel axis if needed to keep the mesh rectangular
  3. TrainState restores from the last checkpoint with the NEW shardings
     (Checkpointer.restore(shardings=...)) and the data pipeline replays
     from the checkpointed step — bitwise-identical stream (see data/)
  4. training resumes; the step clock never goes backwards more than one
     checkpoint interval
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat: float
    alive: bool = True
    step_times: List[float] = dataclasses.field(default_factory=list)


class HeartbeatTracker:
    def __init__(self, host_ids: Sequence[int], timeout: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.timeout = timeout
        now = clock()
        self.hosts: Dict[int, HostState] = {
            h: HostState(host_id=h, last_beat=now) for h in host_ids
        }

    def beat(self, host_id: int) -> None:
        self.hosts[host_id].last_beat = self.clock()

    def check(self) -> List[int]:
        """Returns newly-dead host ids."""
        now = self.clock()
        dead = []
        for h in self.hosts.values():
            if h.alive and now - h.last_beat > self.timeout:
                h.alive = False
                dead.append(h.host_id)
        return dead

    def alive_hosts(self) -> List[int]:
        return [h.host_id for h in self.hosts.values() if h.alive]


class StragglerDetector:
    """Per-host step-time EWMA; hosts slower than ``ratio`` x median are
    stragglers. Mitigations: re-shard its data (elastic), or issue backup
    steps (speculative execution) — the detector only decides."""

    def __init__(self, host_ids: Sequence[int], ewma: float = 0.3, ratio: float = 1.8):
        self.ewma = ewma
        self.ratio = ratio
        self.times: Dict[int, Optional[float]] = {h: None for h in host_ids}

    def record(self, host_id: int, step_seconds: float) -> None:
        prev = self.times.get(host_id)
        self.times[host_id] = (
            step_seconds if prev is None else self.ewma * step_seconds + (1 - self.ewma) * prev
        )

    def stragglers(self) -> List[int]:
        vals = [t for t in self.times.values() if t is not None]
        if len(vals) < 2:
            return []
        med = sorted(vals)[len(vals) // 2]
        return [h for h, t in self.times.items() if t is not None and t > self.ratio * med]


def plan_elastic_mesh(alive_hosts: int, chips_per_host: int,
                      model_parallel: int) -> Tuple[int, int]:
    """Largest rectangular (data, model) mesh from the surviving hosts.

    model_parallel is fixed (weights are sharded that way); the data axis
    shrinks to the largest power-of-two of full rows that still divides the
    global batch. Returns (data_size, model_size)."""
    total = alive_hosts * chips_per_host
    if total < model_parallel:
        raise RuntimeError("not enough chips for the model-parallel axis")
    rows = total // model_parallel
    # largest power of two <= rows keeps batch divisibility simple
    data = 1 << (rows.bit_length() - 1)
    return data, model_parallel


class ElasticRunner:
    """Drives a step function with checkpoint/restart on injected failures.

    The step callable raises HostFailure to simulate a lost host; the runner
    restores from the checkpointer and continues with the shrunken world.
    """

    def __init__(self, checkpointer, make_step, save_every: int = 10):
        self.ckpt = checkpointer
        self.make_step = make_step  # (world_size) -> (step_fn, state)
        self.save_every = save_every
        self.restarts = 0

    def run(self, state, world_size: int, n_steps: int, fail_at=()):
        step_fn = self.make_step(world_size)
        fail_at = set(fail_at)
        step = 0
        while step < n_steps:
            if step % self.save_every == 0:
                self.ckpt.save(step, state, meta={"world": world_size}, blocking=True)
            if step in fail_at:
                fail_at.discard(step)
                self.restarts += 1
                world_size = max(world_size // 2, 1)
                step_fn = self.make_step(world_size)
                last = self.ckpt.latest_step()
                state, meta = self.ckpt.restore(state, step=last)
                step = last
                continue
            state = step_fn(state, step)
            step += 1
        return state, world_size


class HostFailure(RuntimeError):
    pass
