"""Paged decode attention — Pallas TPU kernel (serving hot spot).

One query token per sequence attends over a *paged* KV pool through a block
table (vLLM-style indirection). The block table and sequence lengths are
scalar-prefetched (SMEM) so each grid step's page id feeds the BlockSpec
index_map — the kernel walks physical pages, not virtual positions. This is
the access path MaxMem's tiering manages: the pool rows it reads are exactly
the "pages" whose heat the central manager tracks.

Grid: (B, nkv, n_pages_per_seq); the page dimension is innermost with VMEM
accumulators, online softmax over pages. GQA: q is viewed [B, nkv, g, dh];
each (b, kv-head) cell processes its g query heads as one (g x dh) block
(g x page MXU matmuls).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(
    tables_ref,  # SMEM [B, n_p] int32 (scalar prefetch)
    lens_ref,  # SMEM [B] int32 (scalar prefetch)
    q_ref,  # [1, 1, g, dh]
    k_ref,  # [1, page, 1, dh] — row tables[b, p] of the pool
    v_ref,
    o_ref,  # [1, 1, g, dh]
    acc_ref,  # VMEM [g, dh] f32
    m_ref,  # VMEM [g, 1] f32
    l_ref,  # VMEM [g, 1] f32
    *,
    sm_scale: float,
    page: int,
):
    b = pl.program_id(0)
    p = pl.program_id(2)
    n_p = pl.num_programs(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    seq_len = lens_ref[b]
    page_id = tables_ref[b, p]
    n_valid = jnp.clip(seq_len - p * page, 0, page)
    run = jnp.logical_and(n_valid > 0, page_id >= 0)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [g, dh]
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # [page, dh]
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # [g, page]
        pos = jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        mask = pos < n_valid
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        pr = jnp.exp(s - m_new[:, None])
        pr = jnp.where(mask, pr, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + pr.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            pr.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[:, 0] = m_new

    @pl.when(p == n_p - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(
    q: jax.Array,  # [B, nh, dh]
    k_pages: jax.Array,  # [P, page, nkv, dh]
    v_pages: jax.Array,
    block_tables: jax.Array,  # [B, n_p] int32; -1 entries skipped
    seq_lens: jax.Array,  # [B] int32
    *,
    interpret: bool = True,
) -> jax.Array:
    B, nh, dh = q.shape
    P, page, nkv, _ = k_pages.shape
    n_p = block_tables.shape[1]
    assert nh % nkv == 0
    g = nh // nkv
    qg = q.reshape(B, nkv, g, dh)

    kernel = functools.partial(_paged_kernel, sm_scale=1.0 / math.sqrt(dh), page=page)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nkv, n_p),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda b, h, p, tables, lens: (b, h, 0, 0)),
            pl.BlockSpec(
                (1, page, 1, dh),
                lambda b, h, p, tables, lens: (jnp.maximum(tables[b, p], 0), 0, h, 0),
            ),
            pl.BlockSpec(
                (1, page, 1, dh),
                lambda b, h, p, tables, lens: (jnp.maximum(tables[b, p], 0), 0, h, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), lambda b, h, p, tables, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, dh), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nkv, g, dh), q.dtype),
        interpret=interpret,
    )(block_tables, seq_lens, qg, k_pages, v_pages)
    return out.reshape(B, nh, dh)
