"""Public kernel entry points.

Dispatch policy: on a real TPU backend the Pallas kernels compile natively
(``interpret=False``); everywhere else (this CPU container, unit tests) they
run in interpret mode, which executes the kernel body in Python — bit-level
semantics, no Mosaic. The pure-jnp references in ``ref.py`` remain the
correctness oracles either way.

``use_pallas()`` may be forced via REPRO_FORCE_PALLAS=0/1 for experiments.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.hot_bins import hot_bins as _hot_bins
from repro.kernels.page_copy import page_copy as _page_copy
from repro.kernels.page_copy import page_move as _page_move
from repro.kernels.paged_attention import paged_attention as _paged


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def use_pallas() -> bool:
    env = os.environ.get("REPRO_FORCE_PALLAS")
    if env is not None:
        return env not in ("0", "false", "")
    return True  # interpret mode on CPU, native on TPU


def _interpret() -> bool:
    return not on_tpu()


def flash_attention(q, k, v, *, causal=True, sliding_window=0, q_blk=256, kv_blk=256):
    if not use_pallas():
        return _ref.flash_attention_ref(
            q, k, v, causal=causal, sliding_window=sliding_window
        )
    return _flash(
        q, k, v, causal=causal, sliding_window=sliding_window,
        q_blk=q_blk, kv_blk=kv_blk, interpret=_interpret(),
    )


def paged_attention(q, k_pages, v_pages, block_tables, seq_lens):
    if not use_pallas():
        return _ref.paged_attention_ref(q, k_pages, v_pages, block_tables, seq_lens)
    return _paged(q, k_pages, v_pages, block_tables, seq_lens, interpret=_interpret())


def hot_bins(page_ids, counts_in, *, num_bins=6, tile=512):
    if not use_pallas():
        return _ref.hot_bins_ref(page_ids, counts_in, num_bins)
    return _hot_bins(
        page_ids, counts_in, num_bins=num_bins, tile=tile, interpret=_interpret()
    )


def page_copy(src_pool, dst_pool, src_ids, dst_ids):
    if not use_pallas():
        return _ref.page_copy_ref(src_pool, dst_pool, src_ids, dst_ids)
    return _page_copy(src_pool, dst_pool, src_ids, dst_ids, interpret=_interpret())


def page_move(pool, src_ids, dst_ids):
    """Intra-pool in-place moves (MaxMem migration executor path)."""
    if not use_pallas():
        return _ref.page_move_ref(pool, src_ids, dst_ids)
    return _page_move(pool, src_ids, dst_ids, interpret=_interpret())
