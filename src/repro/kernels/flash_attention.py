"""Causal GQA flash attention — Pallas TPU kernel (train/prefill hot spot).

Canonical 4D-grid online-softmax flash: grid = (B, nh, nq, nk) with the kv
dimension innermost ("arbitrary" semantics); accumulators live in VMEM
scratch and persist across the kv iterations of one (b, h, i) cell.

Block shapes are the VMEM tiling: q (q_blk x dh), k/v (kv_blk x dh) with
dh in {64, 128} — MXU-aligned (128 lanes). GQA maps q head h to kv head
h // (nh // nkv) inside the index_map (no KV expansion in memory).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # [1, 1, q_blk, dh]
    k_ref,  # [1, 1, kv_blk, dh]
    v_ref,
    o_ref,  # [1, 1, q_blk, dh]
    acc_ref,  # VMEM scratch [q_blk, dh] f32
    m_ref,  # [q_blk, 1] f32
    l_ref,  # [q_blk, 1] f32
    *,
    sm_scale: float,
    causal: bool,
    sliding_window: int,
    q_blk: int,
    kv_blk: int,
    kv_len: int,
    q_offset: int,
):
    i = pl.program_id(2)
    j = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # suffix alignment: queries are the last Sq positions of the kv stream
    q_pos = q_offset + i * q_blk + jax.lax.broadcasted_iota(
        jnp.int32, (q_blk, kv_blk), 0
    )
    k_pos = j * kv_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 1)

    run = jnp.asarray(True)
    if causal:
        # skip blocks entirely in the future (saves ~half the FLOPs)
        run = jnp.logical_and(run, j * kv_blk <= q_offset + i * q_blk + q_blk - 1)
    if sliding_window:
        # skip blocks entirely older than the window
        run = jnp.logical_and(
            run, (j + 1) * kv_blk - 1 >= q_offset + i * q_blk - sliding_window + 1
        )

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # [q_blk, kv_blk]
        mask = k_pos < kv_len
        if causal:
            mask &= k_pos <= q_pos
        if sliding_window:
            mask &= k_pos > q_pos - sliding_window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[:, 0] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sliding_window", "q_blk", "kv_blk", "interpret"),
)
def flash_attention(
    q: jax.Array,  # [B, nh, Sq, dh]
    k: jax.Array,  # [B, nkv, Skv, dh]
    v: jax.Array,
    *,
    causal: bool = True,
    sliding_window: int = 0,
    q_blk: int = 256,
    kv_blk: int = 256,
    interpret: bool = True,
) -> jax.Array:
    B, nh, Sq, dh = q.shape
    nkv, Skv = k.shape[1], k.shape[2]
    assert nh % nkv == 0
    g = nh // nkv
    q_blk = min(q_blk, Sq)
    kv_blk = min(kv_blk, Skv)
    pq, pk = (-Sq) % q_blk, (-Skv) % kv_blk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq, nk_blocks = (Sq + pq) // q_blk, (Skv + pk) // kv_blk

    kernel = functools.partial(
        _flash_kernel,
        sm_scale=1.0 / math.sqrt(dh),
        causal=causal,
        sliding_window=sliding_window,
        q_blk=q_blk,
        kv_blk=kv_blk,
        kv_len=Skv,
        q_offset=Skv - Sq,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, nh, nq, nk_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, kv_blk, dh), lambda b, h, i, j, g=g: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, kv_blk, dh), lambda b, h, i, j, g=g: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_blk, dh), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nh, Sq + pq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk, dh), jnp.float32),
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
