"""Page-migration copy kernel — Pallas TPU (the I/OAT DMA-engine analogue).

Gathers pool rows ``src_ids`` and scatters them to rows ``dst_ids`` of the
destination pool in one grid sweep; both id vectors are scalar-prefetched so
the BlockSpec index_maps perform the indirection (each grid step is one
page-sized VMEM round trip — back-to-back DMA, no compute).

Contract: ids must be in-range. Fixed-size plans pad with a reserved trash
row (by convention the LAST row of the destination pool), mirroring how the
MaxMem migration planner emits fixed-size plans.

The destination pool is donated (input_output_aliased): the copy is in-place,
like the DMA engine the paper offloads to.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(src_ids_ref, dst_ids_ref, src_ref, dst_ref, o_ref):
    o_ref[...] = src_ref[...]


def _move_kernel(src_ids_ref, dst_ids_ref, src_ref, o_ref):
    o_ref[...] = src_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def page_move(
    pool: jax.Array,  # [P, E] (donated; in-place moves)
    src_ids: jax.Array,  # [M] int32
    dst_ids: jax.Array,  # [M] int32
    *,
    interpret: bool = True,
) -> jax.Array:
    """Intra-pool page moves: pool[dst_ids[i]] = pool[src_ids[i]].

    One buffer aliased input->output with different index maps (read row
    src_ids[i], write row dst_ids[i]). GATHER semantics: reads must see the
    pre-plan pool, so a plan must never read a row it also writes. The MaxMem
    executor guarantees this (promote sources are owned slow slots; demote
    destinations are unowned slow slots — disjoint sets)."""
    E = pool.shape[1]
    M = src_ids.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(M,),
        in_specs=[
            pl.BlockSpec((1, E), lambda i, src_ids, dst_ids: (src_ids[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, E), lambda i, src_ids, dst_ids: (dst_ids[i], 0)),
    )
    return pl.pallas_call(
        _move_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={2: 0},  # pool (after 2 scalar args) -> out
        interpret=interpret,
    )(src_ids, dst_ids, pool)


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(1,))
def page_copy(
    src_pool: jax.Array,  # [Ps, E]
    dst_pool: jax.Array,  # [Pd, E] (donated)
    src_ids: jax.Array,  # [M] int32
    dst_ids: jax.Array,  # [M] int32
    *,
    interpret: bool = True,
) -> jax.Array:
    M = src_ids.shape[0]
    E = src_pool.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(M,),
        in_specs=[
            pl.BlockSpec((1, E), lambda i, src_ids, dst_ids: (src_ids[i], 0)),
            pl.BlockSpec((1, E), lambda i, src_ids, dst_ids: (dst_ids[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, E), lambda i, src_ids, dst_ids: (dst_ids[i], 0)),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst_pool.shape, dst_pool.dtype),
        input_output_aliases={3: 0},  # dst_pool (arg idx incl. 2 scalar args) -> out
        interpret=interpret,
    )(src_ids, dst_ids, src_pool, dst_pool)
