"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Each ``<name>_ref`` mirrors the corresponding kernel's contract exactly;
tests sweep shapes/dtypes and assert_allclose kernel-vs-ref.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,  # [B, nh, Sq, dh]
    k: jax.Array,  # [B, nkv, Skv, dh]
    v: jax.Array,  # [B, nkv, Skv, dh]
    *,
    causal: bool = True,
    sliding_window: int = 0,
) -> jax.Array:
    B, nh, Sq, dh = q.shape
    nkv, Skv = k.shape[1], k.shape[2]
    g = nh // nkv
    if nkv != nh:
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s / math.sqrt(dh)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos + (Skv - Sq)  # allows Sq<Skv (suffix alignment)
    if sliding_window:
        mask &= kpos > qpos + (Skv - Sq) - sliding_window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhqk,bhkd->bhqd", p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    return out.astype(q.dtype)


def paged_attention_ref(
    q: jax.Array,  # [B, nh, dh] one query token per sequence
    k_pages: jax.Array,  # [P, page, nkv, dh] global page pool
    v_pages: jax.Array,  # [P, page, nkv, dh]
    block_tables: jax.Array,  # [B, pages_per_seq] int32 page ids (-1 pad ok)
    seq_lens: jax.Array,  # [B] int32 valid tokens per sequence
) -> jax.Array:
    B, nh, dh = q.shape
    P, page, nkv, _ = k_pages.shape
    n_p = block_tables.shape[1]
    g = nh // nkv
    tables = jnp.maximum(block_tables, 0)
    k = k_pages[tables]  # [B, n_p, page, nkv, dh]
    v = v_pages[tables]
    k = k.reshape(B, n_p * page, nkv, dh)
    v = v.reshape(B, n_p * page, nkv, dh)
    qg = q.reshape(B, nkv, g, dh)
    s = jnp.einsum("bngd,bknd->bngk", qg, k, preferred_element_type=jnp.float32)
    s = s / math.sqrt(dh)
    pos = jnp.arange(n_p * page)[None, :]
    valid = pos < seq_lens[:, None]
    valid &= (block_tables >= 0)[:, :, None].repeat(page, axis=2).reshape(B, -1)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # rows with zero valid keys
    out = jnp.einsum(
        "bngk,bknd->bngd", p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    return out.reshape(B, nh, dh).astype(q.dtype)


def hot_bins_ref(
    page_ids: jax.Array,  # [N] int32 sampled page ids; <0 entries ignored
    counts_in: jax.Array,  # [P] int32 existing (cooled) counters
    num_bins: int,
) -> tuple[jax.Array, jax.Array]:
    """Returns (counts_out [P] i32, bins [P] i32)."""
    P = counts_in.shape[0]
    valid = page_ids >= 0
    ids = jnp.where(valid, page_ids, P)
    hist = jnp.zeros((P + 1,), jnp.int32).at[ids].add(1)[:P]
    counts = counts_in + hist
    fl = jnp.where(
        counts > 0,
        31 - jax.lax.clz(jnp.maximum(counts, 1)),
        -1,
    )
    bins = jnp.clip(fl + 1, 0, num_bins - 1).astype(jnp.int32)
    return counts, bins


def page_copy_ref(
    src_pool: jax.Array,  # [Ps, page_elems]
    dst_pool: jax.Array,  # [Pd, page_elems]
    src_ids: jax.Array,  # [M] int32 rows of src_pool
    dst_ids: jax.Array,  # [M] int32 rows of dst_pool
) -> jax.Array:
    """dst_pool with dst_pool[dst_ids[i]] = src_pool[src_ids[i]].

    Contract (shared with the kernel): ids are in-range; padding entries must
    point at a reserved trash row, not -1.
    """
    return dst_pool.at[dst_ids].set(src_pool[src_ids])


def page_move_ref(
    pool: jax.Array, src_ids: jax.Array, dst_ids: jax.Array
) -> jax.Array:
    """Gather semantics: every read sees the PRE-plan pool. Plans must not
    read a row the same plan writes (the MaxMem executor guarantees this:
    promote sources are owned slow slots, demote destinations are unowned
    slow slots — disjoint; write-after-read on freed fast slots is safe)."""
    return pool.at[dst_ids].set(pool[src_ids])
