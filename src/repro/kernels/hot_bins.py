"""Hotness accumulate + bin kernel — Pallas TPU (MaxMem §3.2 hot path).

Turns a batch of sampled page ids into per-page counters and heat-bin ids.
Scatter-add is pathological on TPU, so the bincount is computed densely: the
grid tiles the page axis; each tile compares the whole id vector against its
page range (broadcast compare -> one-hot) and row-reduces. The compare+reduce
feeds the VPU/MXU instead of a serial scatter unit — this is the paper's
"binning" mechanism restated as dense linear algebra (DESIGN.md §2).

Fused in the same pass: counts_out = counts_in + bincount(ids) and
bin id = clip(floor(log2(count)) + 1, 0, num_bins-1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _hot_bins_kernel(
    ids_ref,  # [N, 1] int32 (whole sample vector, every tile)
    counts_ref,  # [tile] int32
    out_counts_ref,  # [tile] int32
    out_bins_ref,  # [tile] int32
    *,
    tile: int,
    num_bins: int,
    n_chunk: int,
):
    t = pl.program_id(0)
    base = t * tile
    N = ids_ref.shape[0]
    page_idx = base + jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1)  # [1, tile]

    def body(c, acc):
        ids = ids_ref[pl.ds(c * n_chunk, n_chunk), :]  # [chunk, 1]
        onehot = (ids == page_idx).astype(jnp.int32)  # [chunk, tile]
        return acc + onehot.sum(axis=0)

    nchunks = N // n_chunk
    hist = jax.lax.fori_loop(0, nchunks, body, jnp.zeros((tile,), jnp.int32))
    counts = counts_ref[...] + hist
    out_counts_ref[...] = counts
    # bin = clip(floor(log2(count)) + 1, 0, num_bins-1); count==0 -> 0
    fl = 31 - jax.lax.clz(jnp.maximum(counts, 1))
    bins = jnp.clip(jnp.where(counts > 0, fl + 1, 0), 0, num_bins - 1)
    out_bins_ref[...] = bins.astype(jnp.int32)


def _default_interpret() -> bool:
    """Compiled Pallas on TPU; interpreter everywhere else (CPU/GPU hosts)."""
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("num_bins", "tile", "n_chunk", "interpret"))
def hot_bins(
    page_ids: jax.Array,  # [N] int32; entries < 0 ignored
    counts_in: jax.Array,  # [P] int32
    *,
    num_bins: int = 6,
    tile: int = 512,
    n_chunk: int = 1024,
    interpret: bool = None,
):
    """Returns (counts_out [P] i32, bins [P] i32).

    ``interpret=None`` auto-selects from the JAX backend: the kernel runs
    compiled on TPU and in the Pallas interpreter elsewhere.
    """
    if interpret is None:
        interpret = _default_interpret()
    P = counts_in.shape[0]
    N = page_ids.shape[0]
    pad_p = (-P) % tile
    if pad_p:
        counts_in = jnp.pad(counts_in, (0, pad_p))
    pad_n = (-N) % n_chunk
    ids = jnp.where(page_ids >= 0, page_ids, -1)
    if pad_n:
        ids = jnp.pad(ids, (0, pad_n), constant_values=-1)
    ids2d = ids[:, None]

    kernel = functools.partial(
        _hot_bins_kernel, tile=tile, num_bins=num_bins, n_chunk=min(n_chunk, ids.shape[0])
    )
    counts, bins_arr = pl.pallas_call(
        kernel,
        grid=((P + pad_p) // tile,),
        in_specs=[
            pl.BlockSpec((ids2d.shape[0], 1), lambda t: (0, 0)),  # full ids each tile
            pl.BlockSpec((tile,), lambda t: (t,)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((tile,), lambda t: (t,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P + pad_p,), jnp.int32),
            jax.ShapeDtypeStruct((P + pad_p,), jnp.int32),
        ],
        interpret=interpret,
    )(ids2d, counts_in)
    return counts[:P], bins_arr[:P]
