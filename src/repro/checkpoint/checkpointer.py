"""Sharded, atomic, async checkpointing with reshard-on-restore.

Layout (one directory per step, manifest + one .npy per leaf):

    <dir>/step_000120/
        MANIFEST.msgpack   {step, leaves: {path: {shape, dtype, shard}}, meta}
        <leafpath>.npy

Writes go to ``tmp.<step>`` and are atomically renamed — a crash mid-save
never corrupts the latest checkpoint. Saves run on a background thread
(training continues while the previous step serializes); ``wait()`` joins.

Multihost note: each process saves only its addressable shards (the ``shard``
field records the global offset/extent); this container is single-process so
shards are full arrays, but the manifest format and the restore-time
resharding path (``restore(target_sharding=...)``) are world-size agnostic —
restoring onto a different mesh re-slices per the new sharding (elastic
restart).
"""
from __future__ import annotations

import os
import re
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))))
    return ".".join(parts)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Future] = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, state: Any, meta: Optional[Dict] = None,
             blocking: bool = False) -> None:
        # snapshot to host memory synchronously (cheap), serialize async
        leaves = {}
        flat = jax.tree_util.tree_flatten_with_path(state)[0]
        for path, leaf in flat:
            leaves[_path_str(path)] = np.asarray(leaf)
        self.wait()
        fut = self._pool.submit(self._write, step, leaves, meta or {})
        self._pending = fut
        if blocking:
            self.wait()

    def _write(self, step: int, leaves: Dict[str, np.ndarray], meta: Dict) -> None:
        tmp = os.path.join(self.dir, f"tmp.{step}")
        final = os.path.join(self.dir, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "meta": meta, "leaves": {}}
        for name, arr in leaves.items():
            fn = name.replace("/", "_") + ".npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"][name] = {
                "file": fn,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "shard": {"offset": [0] * arr.ndim, "global_shape": list(arr.shape)},
            }
        with open(os.path.join(tmp, "MANIFEST.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._prune()

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    # -------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", d)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, Dict]:
        """Restore into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs). If ``shardings`` (matching pytree of NamedSharding)
        is given, leaves are device_put with those shardings — restoring onto
        a different mesh than the one that saved (elastic restart)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "MANIFEST.msgpack"), "rb") as f:
            manifest = msgpack.unpackb(f.read())
        flat, treedef = jax.tree_util.tree_flatten_with_path(target)
        shard_flat = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
        )
        leaves = []
        for i, (path, leaf) in enumerate(flat):
            name = _path_str(path)
            ent = manifest["leaves"].get(name)
            if ent is None:
                raise KeyError(f"checkpoint missing leaf {name}")
            arr = np.load(os.path.join(d, ent["file"]))
            want_shape = tuple(leaf.shape)
            if tuple(arr.shape) != want_shape:
                raise ValueError(f"{name}: shape {arr.shape} != target {want_shape}")
            if shard_flat is not None:
                leaves.append(jax.device_put(arr, shard_flat[i]))
            else:
                leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest["meta"]
