"""Deterministic, shard-aware data pipeline with background prefetch.

Synthetic LM token streams (the paper needs no real corpus) generated
deterministically from (seed, shard, step): every host produces exactly its
own shard of the global batch, so the pipeline is elastic — restarting with a
different host count replays the same global stream as long as
(global_batch, seq_len, seed) are unchanged. A background thread keeps a
bounded prefetch queue ahead of the training loop (host-side overlap).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # markov-ish synthetic stream: makes loss curves non-trivial
    structure: float = 0.7  # P(next token derived from current), else uniform


class SyntheticTokens:
    """Deterministic per-(step, shard) batch generator."""

    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        out_tok = np.empty((self.local_batch, cfg.seq_len), np.int32)
        for i in range(self.local_batch):
            global_row = self.shard * self.local_batch + i
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, global_row])
            )
            toks = np.empty(cfg.seq_len + 1, np.uint64)
            toks[0] = rng.integers(0, cfg.vocab_size)
            structured = rng.random(cfg.seq_len) < cfg.structure
            jumps = rng.integers(0, cfg.vocab_size, cfg.seq_len).astype(np.uint64)
            mul = np.uint64(6364136223846793005)
            add = np.uint64(1442695040888963407)
            vocab = np.uint64(cfg.vocab_size)
            with np.errstate(over="ignore"):
                for t in range(cfg.seq_len):
                    if structured[t]:
                        toks[t + 1] = (toks[t] * mul + add) % vocab
                    else:
                        toks[t + 1] = jumps[t]
            out_tok[i] = toks[:-1]
            if i == 0:
                labels_shape = (self.local_batch, cfg.seq_len)
                if not hasattr(self, "_lbl"):
                    self._lbl = np.empty(labels_shape, np.int32)
            self._lbl[i] = toks[1:]
        return {"tokens": out_tok, "labels": self._lbl.copy()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchIterator:
    """Bounded background prefetch (host-side compute/IO overlap)."""

    def __init__(self, source: SyntheticTokens, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        step, batch = self.q.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
