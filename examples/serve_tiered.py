"""Flagship end-to-end example: multi-tenant LLM serving over tiered memory.

A real (smoke-scale) transformer serves two tenants through the paged KV
cache; MaxMem samples the Quest page-access stream, runs its FMMR policy
every few steps, and migrates hot KV pages into the fast (HBM) pool with the
Pallas page-copy kernel. The LS tenant's pages win fast-tier residency.

    PYTHONPATH=src python examples/serve_tiered.py
"""
import subprocess
import sys

# the serving driver IS the example; keep one source of truth
from repro.launch import serve

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--steps", "60", "--fast-pages", "6",
                "--slow-pages", "90", "--quest-pages", "2"]
    serve.main()
