"""Paper Figure 4, reproduced on the scenario engine: 6 GUPS processes
under dynamic colocation.

The timeline is a declarative ``core.scenario.Scenario``: 5 staggered
arrivals, a late 6th, a hot-set growth event, and a live QoS-target change —
watch every latency-sensitive process converge back to its target after each
disturbance, phase by phase. Swap ``CentralManager`` for any baseline in
``repro.core.baselines`` to see the same script punish a static partition.

    PYTHONPATH=src python examples/colocation_demo.py

Finite-bandwidth quickstart: ``--bandwidth N`` runs the same timeline on
the asynchronous migration data plane (DESIGN.md §4) — promotions and
demotions queue up and commit at N pages/epoch, so convergence after each
disturbance is visibly paced by DMA bandwidth:

    PYTHONPATH=src python examples/colocation_demo.py --bandwidth 8
"""
import argparse

from repro.core.manager import CentralManager
from repro.core.scenario import Arrive, ResizeWorkingSet, Retarget, Scenario
from repro.core.simulator import OPTANE, ColocationSim, WorkloadSpec

ap = argparse.ArgumentParser()
ap.add_argument("--bandwidth", type=int, default=None, metavar="PAGES_PER_EPOCH",
                help="bound the migration drain (enables the queue data plane)")
args = ap.parse_args()

mgr = CentralManager(
    num_pages=3584, fast_capacity=512, migration_budget=32,
    max_tenants=8, sample_period=100,
    queue_size=64 if args.bandwidth is not None else 0,
    migration_bandwidth=args.bandwidth,
)
sim = ColocationSim(mgr, OPTANE, seed=2)

events = [Arrive(0, WorkloadSpec("p1", 128, t_miss=1.0, threads=2))]
for j, i in enumerate([2, 3, 4, 5]):
    events.append(Arrive(10 * (j + 1), WorkloadSpec(
        f"p{i}", 128, t_miss=0.1, threads=2, sets=((0.5, 0.9),))))
events += [
    Arrive(110, WorkloadSpec("p6", 128, t_miss=0.1, threads=2, sets=((0.5, 0.9),))),
    ResizeWorkingSet(170, "p5", 0, 0.75),  # hot set +50%
    Retarget(230, "p1", 0.1),  # dynamic QoS change
]
scenario = Scenario(name="fig4_demo", n_epochs=300, events=tuple(events),
                    description="paper Fig. 4 timeline")

result = sim.run_scenario(scenario)

marks = {10: "p2 arrives", 50: "all LS arrived", 110: "p6 arrives",
         170: "p5 hot set +50%", 230: "p1 target 1.0->0.1", 295: "final"}
print(f"{'epoch':>6} {'event':<20} " + " ".join(f"{f'p{i}':>7}" for i in range(1, 7)))
for e, label in sorted(marks.items()):
    r = result.history[e]
    vals = " ".join(
        f"{r.fmmr_true.get(f'p{i}', float('nan')):>7.3f}" for i in range(1, 7)
    )
    print(f"{e:>6} {label:<20} {vals}")

print("\nper-phase mean FMMR (scenario-engine telemetry):")
for p in result.phases:
    vals = " ".join(f"{p.fmmr.get(f'p{i}', float('nan')):>7.3f}" for i in range(1, 7))
    extra = ""
    if args.bandwidth is not None:
        extra = (f"  mig={p.migration_bytes / 2**20:7.0f}MiB"
                 f" queue~{p.mean_queue_depth:5.1f}")
    print(f"[{p.start:3d},{p.end:3d}) {p.label:<16} {vals}{extra}")
print("\n(fmmr per process; LS target = 0.1 — compare paper Fig. 4)")
if args.bandwidth is not None:
    print(f"(migration drain bounded at {args.bandwidth} pages/epoch; "
          f"data-plane counters: {mgr.queue_counters()})")
