"""Quickstart: MaxMem in 40 lines.

Two tenants share a small tiered memory; the latency-sensitive one (target
FMMR 0.1) pulls its hot pages into the fast tier, the best-effort one
(target 1.0) donates. Run:

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import CentralManager, TIER_FAST

mgr = CentralManager(
    num_pages=256,          # total tiered memory (pages)
    fast_capacity=64,       # DRAM/HBM-analogue
    migration_budget=16,    # pages per policy epoch (the paper's 4 GB/s cap)
    sample_period=1,        # exact access accounting for the demo
    exact_sampling=True,
)

ls = mgr.register(t_miss=0.1)   # latency-sensitive tenant
be = mgr.register(t_miss=1.0)   # best-effort tenant

be_pages = mgr.allocate(be, 96)  # arrives first, grabs the fast tier
ls_pages = mgr.allocate(ls, 96)

rng = np.random.default_rng(0)
hot = ls_pages[:32]  # the LS tenant hammers 1/3 of its pages

print(f"{'epoch':>5} {'LS fmmr':>8} {'LS fast pages':>14} {'BE fast pages':>14}")
for epoch in range(25):
    counts = np.zeros(mgr.num_pages, np.int64)
    counts[hot] += 900          # 90% of LS accesses -> hot set
    counts[ls_pages] += 10
    counts[be_pages] += 50      # uniform BE traffic
    mgr.record_access(counts)
    mgr.run_epoch()
    if epoch % 4 == 0 or epoch == 24:
        print(f"{epoch:>5} {mgr.fmmr_of(ls):>8.3f} {mgr.fast_pages_of(ls):>14} "
              f"{mgr.fast_pages_of(be):>14}")

hot_fast = (mgr.tier_of(hot) == TIER_FAST).mean()
print(f"\nLS hot set resident in fast tier: {hot_fast:.0%}")
assert mgr.fmmr_of(ls) <= 0.12, "QoS target missed!"
print("QoS target met: a_miss <= t_miss  ✓")
