"""Train a (reduced) model end to end with checkpoint/restart.

Runs 60 steps of the qwen2.5-3b smoke config on CPU, kills the run at step
30, restores from the async checkpoint, and finishes — demonstrating the
fault-tolerance path. On a TPU slice drop --smoke for the full config and
add --mesh prod.

    PYTHONPATH=src python examples/train_small.py
"""
import subprocess
import sys
import tempfile

if __name__ == "__main__":
    d = tempfile.mkdtemp(prefix="repro_ckpt_")
    base = [sys.executable, "-m", "repro.launch.train", "--arch", "qwen2.5-3b",
            "--smoke", "--batch", "4", "--seq", "32", "--ckpt-dir", d,
            "--ckpt-every", "15", "--log-every", "5"]
    print("=== phase 1: train to step 30 (then 'crash') ===")
    subprocess.run(base + ["--steps", "30"], check=True)
    print("\n=== phase 2: restart from checkpoint, finish to step 60 ===")
    subprocess.run(base + ["--steps", "60", "--resume"], check=True)
    print("\ncheckpoint/restart cycle complete ✓")
