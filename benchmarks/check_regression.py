"""CI perf-regression gate (the ``perf-gate`` job in ci.yml).

Re-measures the policy-engine microbench, the ``--smoke`` scenario suite, a
smoke-scale fleet engine/sweep run and the smoke serving-colocation legs on
the current checkout, then compares against the committed
``BENCH_policy.json`` / ``BENCH_scenarios.json`` / ``BENCH_fleet.json`` /
``BENCH_serving.json``:

  * per-metric slowdown beyond the tolerance band (default 25%, override
    with ``--tolerance`` or ``PERF_GATE_TOL``) fails the gate — the gated
    metrics are the per-epoch policy timings and the smoke-scale fleet
    timings, the hot paths every PR is allowed to touch;
  * a metric or section missing on EITHER side fails loudly with a named
    "missing" row (never a bare KeyError traceback) — a gate that cannot
    find what it gates must not pass vacuously;
  * a broken qualitative policy ordering (MaxMem steady-state aggregate
    throughput below any baseline, fresh run OR committed payload) fails
    the gate, as does a committed fleet payload that no longer claims the
    >= 4x sweep speedup or its recorded speedup floor vs the committed
    PR 4 single-device fleet baseline (the 1.8x multi-core target is
    reported as its own row: "ok" when the measuring host clears it,
    "below_target" when hardware-bound);
  * the finite-bandwidth thrash scenario must complete on all four
    policies, and the smoke fleet sweep must complete on every machine
    with the sharded-executor overlap metadata (devices/pipeline) present;
  * the committed serving payload must carry a PASSING LS-p99 claim row
    (MaxMem <= static AND <= fixed partition, with migrated pages > 0),
    and the fresh smoke serving legs must all complete with the maxmem leg
    migrating and both baselines frozen (see :func:`check_serving`);
  * the committed autotune payload must carry a PASSING tuned-vs-default
    claim (>= 2 scenario families with tuned aggregate throughput >=
    default and LS p99 <= default) and a passing online-recovery claim,
    with every referenced tuned profile still present under
    ``src/repro/configs/tuned/``; the fresh smoke leg re-runs the search
    canary and the smoke-profile replays (see :func:`check_autotune`);
  * the committed adversarial payload (``BENCH_adversarial.json``) must
    carry PASSING storm claims — guarded MaxMem recovering its
    enqueue/drain balance in strictly fewer epochs than default on every
    storm family, steady-state aggregate within tolerance, cancel ratio
    bounded — and the fresh smoke storm grid re-runs all five legs per
    family with invariants checked, re-verifying the same claims plus the
    guards-off <= 3% wall band (see :func:`check_adversarial`);
  * the invariant sentinel with its traced flag OFF must cost within
    ``PERF_GATE_SENTINEL_TOL`` (default 3%) of a program with the sentinel
    compiled out — fresh-only, same-host (see :func:`check_sentinel_band`),
    so the robustness layer can't silently tax the hot path;
  * the scaling payload (``BENCH_scale.json``, re-measured fresh as a
    smoke slope grid plus one 1M x 256 headline epoch) is gated on its
    fitted per-axis log-log SLOPES — absolute limits, no host
    normalization, committed and fresh (see :func:`check_scale`); the
    ~10ms headline bar reports ``below_target`` non-fatally on
    hardware-bound hosts, exactly like the fleet 1.8x row;
  * every size-row in the schema'd BENCH sections must carry its full
    metric key set (see :func:`check_row_schema`) — a row that silently
    dropped a key (the old 256k ``policy_epoch`` row had no
    ``speedup_vs_seed``) fails loudly instead of being skipped.

Every BENCH payload carries a ``platform`` stamp (host, jax backend, cpu
count); the committed numbers rarely come from the machine re-measuring
them, so ratios are host-normalized by their median before judging
(see :func:`compare_metrics`). Writes a machine-readable diff to ``--out``
(uploaded as a CI artifact) and exits non-zero on any violation.

    PYTHONPATH=src:. python benchmarks/check_regression.py
"""
from __future__ import annotations

import argparse
import json
import os
import sys

BENCH_FILES = {
    "policy": "BENCH_policy.json",
    "scenarios": "BENCH_scenarios.json",
    "fleet": "BENCH_fleet.json",
    "serving": "BENCH_serving.json",
    "autotune": "BENCH_autotune.json",
    "scale": "BENCH_scale.json",
    "adversarial": "BENCH_adversarial.json",
}

# Per-axis fitted log-log slope ceilings for the scaling payload
# (benchmarks/scale_bench.py). Slopes are dimensionless and host-robust —
# a uniformly slower gate host moves every point, not the fit — so they
# are gated ABSOLUTELY, with no host normalization, on the committed full
# payload AND the fresh smoke grid. pages: 1.0 is linear; the measured
# engine sits well below (fixed per-tick overheads amortize), so > 1.15
# means a superlinear term crept back in. tenants/machines: the tick is
# P-dominated and the fleet scan batches, so both axes must stay nearly
# flat.
SCALE_SLOPE_LIMITS = {
    "pages": ("fitted", 1.15),
    "pages_scan": ("scan_fitted", 1.15),
    "tenants": ("fitted", 0.55),
    "machines": ("fitted", 0.35),
}

# Every size-row inside these BENCH sections must carry its full metric
# key set on BOTH sides of the gate. A row that silently dropped a key
# (the pre-PR-9 256k policy_epoch row omitted speedup_vs_seed) fails
# loudly here instead of being skipped by whichever check reads it.
REQUIRED_ROW_KEYS = {
    ("policy", "policy_epoch"): ("us", "epochs_per_sec", "speedup_vs_seed"),
    ("policy", "policy_epoch_queue"): ("us", "instant_us", "overhead_vs_instant"),
    ("policy", "run_epochs_k16"): ("scan_per_epoch_us", "singles_per_epoch_us"),
    ("policy", "live_bytes"): ("solo_instant", "solo_queue", "fleet4_stacked"),
    ("scale", "pages_axis"): ("epoch_us", "scan_epoch_us", "state_bytes"),
    ("scale", "tenants_axis"): ("epoch_us",),
    ("scale", "machines_axis"): ("per_machine_epoch_us", "fleet_live_bytes"),
}

# (payload key, json path) -> gated metric; all are lower-is-better
# microseconds re-measured fresh on the gate host
GATED_METRICS = (
    ("policy", ("policy_epoch", "65536", "us")),
    ("policy", ("policy_epoch", "262144", "us")),
    ("policy", ("policy_epoch_queue", "65536", "us")),
    ("policy", ("policy_epoch_queue", "262144", "us")),
    ("policy", ("run_epochs_k16", "65536", "scan_per_epoch_us")),
    ("policy", ("run_epochs_k16", "262144", "scan_per_epoch_us")),
    ("fleet", ("engine_smoke", "fleet", "per_machine_epoch_us")),
    ("fleet", ("engine_smoke", "fleet_sharded", "per_machine_epoch_us")),
    ("fleet", ("engine_smoke", "serial_scan", "per_machine_epoch_us")),
    # real-engine serving decode: mean wall time per step, per placement
    # leg (committed full run vs fresh smoke run — same engine config,
    # only n_steps differs, so per-step cost is comparable and the
    # per-payload host factor absorbs the residual warmup skew)
    ("serving", ("legs", "maxmem", "_engine", "step_us")),
    ("serving", ("legs", "static", "_engine", "step_us")),
    ("serving", ("legs", "fixed", "_engine", "step_us")),
)


def _dig(payload, path):
    for key in path:
        if not isinstance(payload, dict) or key not in payload:
            raise KeyError(key)
        payload = payload[key]
    return payload


def compare_metrics(committed: dict, fresh: dict, tolerance: float) -> list:
    """Per-metric slowdown rows, judged on HOST-NORMALIZED ratios.

    The committed numbers come from a different machine than the CI
    runner (each payload's ``platform`` block records which), so raw
    fresh/committed ratios fold in the host-speed gap. The median ratio
    across the gated metrics estimates that gap (a uniformly slower host
    moves every metric together); dividing it out leaves the per-metric
    regression signal, which is what the tolerance band judges. A genuine
    global regression shows up as a large host factor — reported in the
    artifact and failed beyond 1 + 3*tolerance as a backstop.

    The host factor is estimated PER PAYLOAD FILE: the committed payloads
    are regenerated independently (their ``platform`` stamps may name
    different hosts), so one shared median would split any speed gap
    between the groups and report spurious per-metric regressions.

    A metric absent on either side produces a named ``missing`` row
    (counted as a failure by the caller) instead of raising.
    """
    rows = []
    ratios: dict = {}
    for payload_key, path in GATED_METRICS:
        name = payload_key + ":" + ".".join(path)
        missing = []
        old = new = None
        try:
            old = float(_dig(committed.get(payload_key, {}), path))
        except (KeyError, TypeError, ValueError):
            missing.append("committed")
        try:
            new = float(_dig(fresh.get(payload_key, {}), path))
        except (KeyError, TypeError, ValueError):
            missing.append("fresh")
        if missing:
            rows.append({"metric": name, "status": "missing",
                         "missing_in": missing})
            continue
        ratio = new / old if old > 0 else float("inf")
        ratios.setdefault(payload_key, []).append(ratio)
        rows.append({"metric": name, "payload": payload_key,
                     "committed_us": old, "fresh_us": new,
                     "ratio": round(ratio, 3)})
    hosts = {
        key: sorted(rs)[len(rs) // 2] for key, rs in ratios.items() if rs
    }
    for r in rows:
        if r.get("status") == "missing":
            continue
        host = hosts.get(r["payload"], 1.0)
        norm = r["ratio"] / host if host > 0 else float("inf")
        r["host_factor"] = round(host, 3)
        r["normalized_ratio"] = round(norm, 3)
        r["status"] = "fail" if norm > 1.0 + tolerance else "ok"
    for key, host in hosts.items():
        if host > 1.0 + 3.0 * tolerance:
            rows.append({
                "metric": f"host_factor_backstop:{key}",
                "ratio": round(host, 3),
                "status": "fail",
            })
    return rows


def check_ordering(scenarios: dict, source: str) -> list:
    ok = scenarios.get("maxmem_geq_all_baselines")
    rows = [{
        "check": f"{source}:maxmem_geq_all_baselines",
        "status": ("missing" if ok is None else ("ok" if ok else "fail")),
        "steady_state": scenarios.get("steady_state_agg_throughput"),
    }]
    thrash = scenarios.get("thrash")
    if thrash is not None:
        rows.append({
            "check": f"{source}:thrash_all_policies",
            "status": "ok" if len(thrash.get("completed_policies", ())) == 4 else "fail",
        })
    faults = scenarios.get("faults")
    if faults is not None:
        # the fault-injection contract (DESIGN.md §7): all four policies
        # survive the machine-fail + bandwidth-degrade schedule, the down
        # window records zero throughput, MaxMem recovers to 90% of its
        # pre-fail throughput and ends with conservation invariants intact
        ok = (
            len(faults.get("completed_policies", ())) == 4
            and all(faults.get("down_window_zero_throughput", {}).values())
            and faults.get("recovery_epochs", {}).get("maxmem") is not None
            and bool(faults.get("maxmem_deep_validate_ok"))
        )
        rows.append({
            "check": f"{source}:faults_recovery_contract",
            "status": "ok" if ok else "fail",
            "recovery_epochs": faults.get("recovery_epochs"),
        })
    return rows


def check_fleet(committed_fleet: dict, fresh_fleet: dict) -> list:
    """Fleet smoke-leg checks beyond the tolerance-band metrics: the
    committed full-scale payload must still claim the >= 4x sweep speedup
    AND the >= 1.8x sharded/pipelined speedup over the committed PR 4
    single-device fleet baseline; the fresh smoke sweep must have completed
    on every machine and carry the sharded-executor overlap metadata
    (devices + pipeline) — a smoke run that silently fell back to the
    serialized driver must not pass."""
    rows = []
    sweep = committed_fleet.get("sweep", {})
    meets = sweep.get("meets_4x")
    rows.append({
        "check": "committed:fleet_sweep_meets_4x",
        "status": ("missing" if meets is None else ("ok" if meets else "fail")),
        "speedup": sweep.get("fleet", {}).get("speedup_vs_serial_per_process"),
    })
    # hard floor: the speedup the reference container demonstrates through
    # its noise band (the payload records the floor value it was held to);
    # regressing below it fails. The 1.8x multi-core target is reported as
    # its own row — "ok" when the committed payload was measured on a host
    # that clears it, "below_target" (visible, non-fatal) when the
    # measuring host is hardware-bound below it (fewer physical cores than
    # shard slots, DESIGN.md §6); absent entirely still fails.
    meets_floor = sweep.get("meets_floor_vs_pr4")
    rows.append({
        "check": "committed:fleet_sweep_meets_floor_vs_pr4",
        "status": ("missing" if meets_floor is None
                   else ("ok" if meets_floor else "fail")),
        "floor": sweep.get("speedup_floor"),
        "speedup": sweep.get("fleet", {}).get("speedup_vs_pr4_committed"),
        "devices": sweep.get("fleet", {}).get("devices"),
    })
    meets18 = sweep.get("meets_1_8x_vs_pr4")
    rows.append({
        "check": "committed:fleet_sweep_meets_1_8x_target_vs_pr4",
        "status": (
            "missing" if meets18 is None
            else ("ok" if meets18 else "below_target")
        ),
        "speedup": sweep.get("fleet", {}).get("speedup_vs_pr4_committed"),
        "host_cpu_count": sweep.get("host_cpu_count"),
        "config_autotune": sweep.get("fleet", {}).get("config_autotune"),
    })
    sw = fresh_fleet.get("sweep_smoke", {})
    n = sw.get("n_machines")
    done = sw.get("steady_state_agg_throughput", {}).get("fleet", {})
    rows.append({
        "check": "fresh_smoke:fleet_sweep_completed_machines",
        "status": "ok" if n and len(done) == n else "fail",
        "machines": n,
        "completed": len(done),
    })
    rows.append({
        "check": "fresh_smoke:fleet_sweep_overlap_metadata",
        "status": "ok" if (
            isinstance(sw.get("devices"), int) and sw.get("devices", 0) >= 1
            and sw.get("pipeline") is True
        ) else "missing",
        "devices": sw.get("devices"),
        "pipeline": sw.get("pipeline"),
    })
    return rows


def check_serving(committed_serving: dict, fresh_serving: dict) -> list:
    """Serving colocation claim rows (DESIGN.md §8).

    The committed payload must carry a PASSING claim: MaxMem's LS p99 step
    latency <= the static no-migration baseline AND <= the fixed HeMem-style
    KV partition, with migrated_pages > 0 and both baselines frozen (zero
    migrations) — a payload whose claim row fails or went missing means the
    headline serving result no longer holds and must fail the gate.

    The fresh smoke leg re-runs the three placements on the gate host and
    checks MECHANISM, not margins (latency orderings on a 60-step smoke run
    are noise-prone): every leg completes requests for both tenants, the
    maxmem leg actually migrates KV pages, and the frozen baselines move
    zero — a serving stack that silently stopped migrating (or started
    migrating in the static leg) must not pass."""
    rows = []
    claim = committed_serving.get("claim")
    rows.append({
        "check": "committed:serving_claim_ls_p99",
        "status": ("missing" if claim is None
                   else ("ok" if claim.get("pass") else "fail")),
        "ls_p99_us": (claim or {}).get("ls_p99_us"),
        "migrated_pages": (claim or {}).get("migrated_pages"),
    })
    from benchmarks.serving_colocation import TENANTS

    legs = fresh_serving.get("legs", {})
    completed = {
        m: sum(leg.get(t.name, {}).get("completed", 0) for t in TENANTS)
        for m, leg in legs.items()
    }
    all_legs = set(completed) == {"maxmem", "static", "fixed"}
    rows.append({
        "check": "fresh_smoke:serving_all_legs_complete",
        "status": "ok" if all_legs and all(
            n > 0 for n in completed.values()
        ) else "fail",
        "completed": completed,
    })
    migrated = {
        m: leg.get("_engine", {}).get("migrated_pages") for m, leg in legs.items()
    }
    rows.append({
        "check": "fresh_smoke:serving_maxmem_migrates_baselines_frozen",
        "status": "ok" if (
            (migrated.get("maxmem") or 0) > 0
            and migrated.get("static") == 0
            and migrated.get("fixed") == 0
        ) else "fail",
        "migrated_pages": migrated,
    })
    return rows


def check_autotune(committed_autotune: dict, fresh_autotune: dict) -> list:
    """Autotuner claim rows (DESIGN.md §9).

    Committed payload: the headline claim must PASS — at least two scenario
    families where the committed tuned profile achieves aggregate
    throughput >= default AND LS p99 <= default (the replays are
    deterministic, so equality is a legitimate pass), and the online
    re-tuner must recover the shifted tenant in fewer epochs than default
    params after a SkewChange. Every profile the payload references must
    still exist under ``src/repro/configs/tuned/`` — a bench claiming
    numbers for a profile that was deleted (or renamed) must fail loudly,
    not silently re-tune.

    Fresh smoke: the tiny search canary must have completed every
    generation with a weakly-dominating winner, and the smoke-scale
    family replays must all complete with passing claims (they replay
    committed smoke profiles, so this is deterministic, not noise-bound).
    """
    rows = []
    claim = committed_autotune.get("claim")
    rows.append({
        "check": "committed:autotune_tuned_geq_default",
        "status": ("missing" if claim is None
                   else ("ok" if claim.get("pass") else "fail")),
        "families_passing": (claim or {}).get("families_passing"),
    })
    online = committed_autotune.get("online", {})
    oc = online.get("claim")
    rows.append({
        "check": "committed:autotune_online_recovery",
        "status": ("missing" if oc is None
                   else ("ok" if oc.get("pass") else "fail")),
        "recovery_epochs_default": online.get("recovery_epochs_default"),
        "recovery_epochs_online": online.get("recovery_epochs_online"),
    })
    referenced = committed_autotune.get("profiles_referenced")
    if referenced is None:
        rows.append({"check": "committed:autotune_profiles_exist",
                     "status": "missing"})
    else:
        from repro.configs.tuned import profile_names

        have = set(profile_names())
        gone = sorted(set(referenced) - have)
        rows.append({
            "check": "committed:autotune_profiles_exist",
            "status": "ok" if not gone else "fail",
            "referenced": referenced,
            "missing_profiles": gone,
        })
    search = fresh_autotune.get("search_smoke", {})
    rows.append({
        "check": "fresh_smoke:autotune_search_complete",
        "status": ("ok" if search.get("claim", {}).get("pass") else "fail"),
        "generations": search.get("generations"),
    })
    fams = fresh_autotune.get("families", {})
    bad = sorted(
        f for f, d in fams.items() if not d.get("claim", {}).get("pass")
    )
    rows.append({
        "check": "fresh_smoke:autotune_family_replays",
        "status": "ok" if fams and not bad else "fail",
        "families": sorted(fams),
        "failing": bad,
    })
    return rows


def check_row_schema(committed: dict, fresh: dict) -> list:
    """Metric-key completeness per size-row (see REQUIRED_ROW_KEYS): a
    BENCH section whose rows dropped a key must fail loudly — the old
    behavior was that downstream consumers silently skipped such rows."""
    rows = []
    for (payload_key, section), keys in REQUIRED_ROW_KEYS.items():
        for source, payloads in (("committed", committed), ("fresh", fresh)):
            name = f"{source}:{payload_key}.{section}:row_keys"
            sec = payloads.get(payload_key, {}).get(section)
            if not isinstance(sec, dict) or not sec:
                rows.append({"check": name, "status": "missing"})
                continue
            bad = {
                size: sorted(set(keys) - set(row))
                for size, row in sec.items()
                if not isinstance(row, dict) or set(keys) - set(row)
            }
            row = {"check": name, "status": "ok" if not bad else "fail",
                   "rows": sorted(sec)}
            if bad:
                row["missing_keys"] = bad
            rows.append(row)
    return rows


def check_scale(committed_scale: dict, fresh_scale: dict) -> list:
    """Scaling-curve gate (benchmarks/scale_bench.py, DESIGN.md §10).

    Gates the fitted per-axis log-log SLOPES absolutely (no host
    normalization — see SCALE_SLOPE_LIMITS) on both the committed full
    payload and the fresh smoke grid, so a regression in asymptotic
    behavior fails even when a fast gate host hides it in the point
    estimates. The 1M x 256 headline is handled like the fleet 1.8x
    target: its presence and geometry are required (missing fails), but a
    measuring host below the ~10ms absolute bar reports ``below_target``
    — visible, non-fatal — because the bar is hardware-bound while the
    slopes are not."""
    rows = []
    for source, payload in (("committed", committed_scale),
                            ("fresh_smoke", fresh_scale)):
        slopes = payload.get("slopes")
        if not slopes:
            rows.append({"check": f"{source}:scale_slopes", "status": "missing"})
            continue
        for name, (key, limit) in SCALE_SLOPE_LIMITS.items():
            axis = name.split("_")[0]
            fitted = slopes.get(axis, {}).get(key)
            rows.append({
                "check": f"{source}:scale_slope_{name}",
                "status": ("missing" if fitted is None
                           else ("ok" if fitted <= limit else "fail")),
                "fitted": fitted,
                "limit": limit,
            })
        head = payload.get("headline") or {}
        geom_ok = (head.get("pages") == 1048576 and head.get("tenants") == 256
                   and isinstance(head.get("epoch_us"), (int, float)))
        rows.append({
            "check": f"{source}:scale_headline_1m_x256_recorded",
            "status": "ok" if geom_ok else "missing",
            "epoch_us": head.get("epoch_us"),
        })
        if geom_ok:
            rows.append({
                "check": f"{source}:scale_headline_meets_10ms",
                "status": ("ok" if head.get("meets_target")
                           else "below_target"),
                "epoch_us": head.get("epoch_us"),
                "target_us": head.get("target_us"),
            })
    churn = fresh_scale.get("churn") or {}
    rows.append({
        "check": "fresh_smoke:scale_churn_completed",
        "status": "ok" if churn.get("phases", 0) >= 3 else "fail",
        "scenario": churn.get("scenario"),
        "wall_s": churn.get("wall_s"),
    })
    return rows


def check_adversarial(committed_adv: dict, fresh_adv: dict) -> list:
    """Adversarial storm claim rows (DESIGN.md §11).

    Committed payload: all three storm claims must PASS — guarded MaxMem
    recovers its enqueue/drain balance in strictly fewer epochs than
    default on EVERY storm family (the drop-requeue storm subsides
    instead of saturating), guarded steady-state aggregate within the
    recorded tolerance of default, and the cancelled/drained ratio
    bounded on both legs (no livelock). The guards-off overhead row is
    judged FRESH-only (wall-clock bands don't transfer across hosts; the
    committed value is recorded for provenance, not gated).

    Fresh smoke: the full storm grid re-runs on the gate host — every
    family on all five legs with conservation invariants checked after
    every event — and the same claims are re-verified, deterministic at
    smoke scale, plus the fresh guards-off <= 3% band."""
    rows = []
    claims = committed_adv.get("claims")
    for key in ("recovery_strict_every_family", "steady_state_within_tol",
                "cancel_ratio_bounded"):
        ok = (claims or {}).get(key)
        rows.append({
            "check": f"committed:adversarial_{key}",
            "status": ("missing" if ok is None else ("ok" if ok else "fail")),
        })
    fams = committed_adv.get("families")
    rows.append({
        "check": "committed:adversarial_worst_recovery",
        "status": "ok" if fams else "missing",
        "worst_recovery": {
            f: {
                "default": d["policies"]["maxmem"].get("worst_churn_recovery"),
                "guarded": d["policies"]["maxmem_guarded"].get(
                    "worst_churn_recovery"),
            }
            for f, d in (fams or {}).items()
        } or None,
    })
    fresh_claims = fresh_adv.get("claims", {})
    for key in ("recovery_strict_every_family", "steady_state_within_tol",
                "cancel_ratio_bounded", "guards_off_overhead_ok"):
        ok = fresh_claims.get(key)
        rows.append({
            "check": f"fresh_smoke:adversarial_{key}",
            "status": ("missing" if ok is None else ("ok" if ok else "fail")),
        })
    rows.append({
        "check": "fresh_smoke:adversarial_guards_off_band",
        "status": "ok" if fresh_adv.get("guards_off_overhead", {}).get("ok")
        else "fail",
        "ratio": fresh_adv.get("guards_off_overhead", {}).get("ratio"),
        "band": fresh_adv.get("guards_off_overhead", {}).get("band"),
    })
    return rows


def check_sentinel_band(fresh_policy: dict, tol: float) -> list:
    """Sentinel-off overhead band (DESIGN.md §7), fresh-only: the
    production policy program compiles the invariant sentinel gated by a
    traced flag — with the flag OFF it must cost within ``tol`` of a
    program with the sentinel compiled out entirely. Both legs come from
    the SAME fresh run on THIS host (min-of-reps), so no host
    normalization applies and the committed payloads are not consulted.
    The section missing fails loudly, like every other gated metric."""
    sent = fresh_policy.get("policy_epoch_sentinel", {}).get("65536")
    if not sent:
        return [{"check": "fresh:sentinel_off_band", "status": "missing"}]
    over = float(sent["overhead_off"])
    return [{
        "check": "fresh:sentinel_off_band",
        "status": "ok" if over <= 1.0 + tol else "fail",
        "overhead_off": round(over, 4),
        "overhead_on": round(float(sent["overhead_on"]), 4),
        "tolerance": tol,
        "ref_us": round(float(sent["ref_us"]), 1),
        "off_us": round(float(sent["off_us"]), 1),
        "on_us": round(float(sent["on_us"]), 1),
    }]


def _load_committed() -> dict:
    out = {}
    for key, path in BENCH_FILES.items():
        if not os.path.exists(path):
            out[key] = None
            continue
        with open(path) as f:
            out[key] = json.load(f)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("PERF_GATE_TOL", "0.25")),
                    help="allowed fractional slowdown per metric (default 0.25)")
    ap.add_argument("--sentinel-tolerance", type=float,
                    default=float(os.environ.get("PERF_GATE_SENTINEL_TOL", "0.03")),
                    help="allowed sentinel-off overhead vs the compiled-out "
                         "reference program (default 0.03)")
    ap.add_argument("--out", default="perf_gate_diff.json",
                    help="diff artifact path")
    args = ap.parse_args(argv)

    committed = _load_committed()
    file_rows = [
        {"check": f"committed_file:{BENCH_FILES[k]}",
         "status": "ok" if committed[k] is not None else "missing"}
        for k in BENCH_FILES
    ]
    committed = {k: v or {} for k, v in committed.items()}

    from benchmarks import (
        adversarial_bench,
        autotune_bench,
        dynamic_workload,
        microbench,
        scale_bench,
        serving_colocation,
    )

    fresh = {
        "policy": microbench.policy_bench(),
        "scenarios": dynamic_workload.scenarios_bench(smoke=True),
        "fleet": {
            "engine_smoke": microbench.fleet_bench(
                n_machines=4, n_pages=4096, n_epochs=8
            ),
            # fleet-only: the gate checks completion, not the serial
            # reference legs (those live in BENCH_fleet.json and the
            # scenarios job's --sweep --smoke run)
            "sweep_smoke": dynamic_workload.sweep_fleet_smoke(),
        },
        "serving": serving_colocation.serving_bench(smoke=True),
        "autotune": autotune_bench.autotune_bench(smoke=True),
        # smoke slope grid + ONE fresh 1M x 256 headline epoch on this host
        "scale": scale_bench.scale_bench(smoke=True),
        # the storm grid: all five legs per family, invariants on every
        # event, claims re-verified at smoke scale
        "adversarial": adversarial_bench.adversarial_bench(smoke=True),
    }

    diff = {
        "tolerance": args.tolerance,
        "committed_platforms": {
            k: committed[k].get("platform") for k in BENCH_FILES
        },
        "files": file_rows,
        "metrics": compare_metrics(committed, fresh, args.tolerance),
        "ordering": check_ordering(fresh["scenarios"], "fresh_smoke")
        + check_ordering(committed["scenarios"], "committed")
        + check_fleet(committed["fleet"], fresh["fleet"])
        + check_serving(committed["serving"], fresh["serving"])
        + check_autotune(committed["autotune"], fresh["autotune"])
        + check_sentinel_band(fresh["policy"], args.sentinel_tolerance)
        + check_scale(committed["scale"], fresh["scale"])
        + check_adversarial(committed["adversarial"], fresh["adversarial"])
        + check_row_schema(committed, fresh),
    }
    # a metric or file absent on either side means the gate is no longer
    # measuring what it claims to — that must fail loudly, not pass
    # vacuously
    failures = [r for r in diff["files"] if r["status"] != "ok"]
    failures += [r for r in diff["metrics"] if r["status"] in ("fail", "missing")]
    failures += [r for r in diff["ordering"] if r["status"] in ("fail", "missing")]
    diff["failures"] = len(failures)

    with open(args.out, "w") as f:
        json.dump(diff, f, indent=2)
    print(f"wrote {args.out}")
    for r in diff["files"]:
        print(f"perf_gate_{r['check']},0.000,status={r['status']}")
    for r in diff["metrics"]:
        print(f"perf_gate_{r['metric']},{r.get('fresh_us', 0):.1f},"
              f"ratio={r.get('ratio', 'n/a')};"
              f"normalized={r.get('normalized_ratio', 'n/a')};status={r['status']}")
    for r in diff["ordering"]:
        print(f"perf_gate_{r['check']},0.000,status={r['status']}")
    if failures:
        print(f"PERF GATE FAILED: {len(failures)} violation(s); see {args.out}")
        return 1
    print("perf gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
