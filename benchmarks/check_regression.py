"""CI perf-regression gate (the ``perf-gate`` job in ci.yml).

Re-measures the policy-engine microbench on the current checkout and runs
the ``--smoke`` scenario suite, then compares against the committed
``BENCH_policy.json``/``BENCH_scenarios.json``:

  * per-metric slowdown beyond the tolerance band (default 25%, override
    with ``--tolerance`` or ``PERF_GATE_TOL``) fails the gate — the gated
    metrics are the per-epoch policy timings, which are the hot path every
    PR is allowed to touch;
  * a broken qualitative policy ordering (MaxMem steady-state aggregate
    throughput below any baseline, fresh run OR committed payload) fails
    the gate — perf work must not silently trade away the paper's claim;
  * the finite-bandwidth thrash scenario must complete on all four
    policies.

Writes a machine-readable diff to ``--out`` (uploaded as a CI artifact)
and exits non-zero on any violation.

    PYTHONPATH=src:. python benchmarks/check_regression.py
"""
from __future__ import annotations

import argparse
import json
import os
import sys

POLICY_BENCH = "BENCH_policy.json"
SCENARIO_BENCH = "BENCH_scenarios.json"

# (json path into BENCH_policy.json) -> gated metric; all are
# lower-is-better microseconds from benchmarks.microbench.policy_bench()
GATED_METRICS = (
    ("policy_epoch", "65536", "us"),
    ("policy_epoch", "262144", "us"),
    ("run_epochs_k16", "65536", "scan_per_epoch_us"),
    ("run_epochs_k16", "262144", "scan_per_epoch_us"),
)


def _dig(payload: dict, path):
    for key in path:
        payload = payload[key]
    return payload


def compare_policy(committed: dict, fresh: dict, tolerance: float) -> list:
    """Per-metric slowdown rows, judged on HOST-NORMALIZED ratios.

    The committed numbers come from a different machine than the CI
    runner, so raw fresh/committed ratios fold in the host-speed gap. The
    median ratio across the gated metrics estimates that gap (a uniformly
    slower host moves every metric together); dividing it out leaves the
    per-metric regression signal, which is what the tolerance band judges.
    A genuine global regression shows up as a large host factor — reported
    in the artifact and failed beyond 1 + 3*tolerance as a backstop.
    """
    rows = []
    ratios = []
    for path in GATED_METRICS:
        name = ".".join(path)
        try:
            old = float(_dig(committed, path))
            new = float(_dig(fresh, path))
        except KeyError:
            rows.append({"metric": name, "status": "missing"})
            continue
        ratio = new / old if old > 0 else float("inf")
        ratios.append(ratio)
        rows.append({"metric": name, "committed_us": old, "fresh_us": new,
                     "ratio": round(ratio, 3)})
    host = sorted(ratios)[len(ratios) // 2] if ratios else 1.0
    for r in rows:
        if r.get("status") == "missing":
            continue
        norm = r["ratio"] / host if host > 0 else float("inf")
        r["host_factor"] = round(host, 3)
        r["normalized_ratio"] = round(norm, 3)
        r["status"] = "fail" if norm > 1.0 + tolerance else "ok"
    if ratios and host > 1.0 + 3.0 * tolerance:
        rows.append({
            "metric": "host_factor_backstop",
            "ratio": round(host, 3),
            "status": "fail",
        })
    return rows


def check_ordering(scenarios: dict, source: str) -> list:
    rows = [{
        "check": f"{source}:maxmem_geq_all_baselines",
        "status": "ok" if scenarios.get("maxmem_geq_all_baselines") else "fail",
        "steady_state": scenarios.get("steady_state_agg_throughput"),
    }]
    thrash = scenarios.get("thrash")
    if thrash is not None:
        rows.append({
            "check": f"{source}:thrash_all_policies",
            "status": "ok" if len(thrash.get("completed_policies", ())) == 4 else "fail",
        })
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("PERF_GATE_TOL", "0.25")),
                    help="allowed fractional slowdown per metric (default 0.25)")
    ap.add_argument("--out", default="perf_gate_diff.json",
                    help="diff artifact path")
    args = ap.parse_args(argv)

    with open(POLICY_BENCH) as f:
        committed_policy = json.load(f)
    with open(SCENARIO_BENCH) as f:
        committed_scen = json.load(f)

    from benchmarks import dynamic_workload, microbench

    fresh_policy = microbench.policy_bench()
    fresh_scen = dynamic_workload.scenarios_bench(smoke=True)

    diff = {
        "tolerance": args.tolerance,
        "metrics": compare_policy(committed_policy, fresh_policy, args.tolerance),
        "ordering": check_ordering(fresh_scen, "fresh_smoke")
        + check_ordering(committed_scen, "committed"),
    }
    # a metric absent on either side means the gate is no longer measuring
    # what it claims to — that must fail loudly, not pass vacuously
    failures = [r for r in diff["metrics"] if r["status"] in ("fail", "missing")]
    failures += [r for r in diff["ordering"] if r["status"] == "fail"]
    diff["failures"] = len(failures)

    with open(args.out, "w") as f:
        json.dump(diff, f, indent=2)
    print(f"wrote {args.out}")
    for r in diff["metrics"]:
        print(f"perf_gate_{r['metric']},{r.get('fresh_us', 0):.1f},"
              f"ratio={r.get('ratio', 'n/a')};"
              f"normalized={r.get('normalized_ratio', 'n/a')};status={r['status']}")
    for r in diff["ordering"]:
        print(f"perf_gate_{r['check']},0.000,status={r['status']}")
    if failures:
        print(f"PERF GATE FAILED: {len(failures)} violation(s); see {args.out}")
        return 1
    print("perf gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
