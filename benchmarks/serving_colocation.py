"""Multi-tenant serving colocation on the REAL engine (DESIGN.md §8).

The paper's Fig. 5-7 colocation claim, re-staged on the actual serving
stack instead of the simulator: an LS tenant (tight ``t_miss``) decodes
through the tiered paged KV cache next to a BE co-runner (``t_miss`` ~ 1.0)
that floods the machine, under THREE placements driven by the SAME
open-loop Poisson arrival stream (same seed -> same request sequence;
placement policy is the only difference):

  maxmem — queue-mode bounded-bandwidth FMMR control: epoch selections
           enqueue, drained batches commit KV-block moves
           (commit-on-completion) through the Pallas ``page_move`` kernel
  static — the same traced program with ``migration_bandwidth=0``:
           first-touch placement frozen forever (no-migration baseline)
  fixed  — HeMem-style per-tenant fast partition: each tenant gets a fixed
           fast-page quota at allocation, no migration

All three legs share one ``epoch_step`` trace (identical ``num_pages`` /
``max_tenants`` / ``queue_size`` / ``plan_size``; only traced
``PolicyParams`` differ) — sweeping the legs does not retrace.

Claim row (gated in ``check_regression.py``): MaxMem's LS p99 step latency
is <= the static no-migration baseline AND <= the fixed KV partition,
with ``migrated_pages > 0`` (the win must come from actual migration, not
from a degenerate no-op run).

Writes ``BENCH_serving.json`` via ``benchmarks/run.py``.
"""
from __future__ import annotations

import time
from typing import Dict

import jax

from benchmarks.common import Rows, platform_metadata
from repro.configs import get_config
from repro.kvcache.paged import TieredPagedKV
from repro.models.model import get_model
from repro.serving.baselines import make_serving_manager
from repro.serving.driver import OpenLoopDriver, TenantSpec
from repro.serving.engine import ServingEngine

_STATE: dict = {}

# one smoke-scale machine: 16 fast + 80 slow KV pages (fast tier fits ~1/6
# of the working set, like the paper's 128 GB DRAM under a 896 GB footprint)
FAST_PAGES = 16
SLOW_PAGES = 80
PAGE_TOKENS = 4
MAX_BATCH = 4
PAGES_PER_SEQ = 8
EPOCH_STEPS = 2
QUEUE_SIZE = 32
BANDWIDTH = 8  # drained pages per epoch (bounded-bandwidth data plane)
# TPP-style fast-page reserve (maxmem leg only): the policy stops refilling
# the last ALLOC_HEADROOM fast pages, so an LS burst's first-touch
# allocation lands fast instead of eating a whole slow-resident epoch —
# that epoch is exactly what dominated the LS p99 tail without it
ALLOC_HEADROOM = 6

# the LS tenant's per-request working set (3 prompt + 4 decode pages = 7,
# two lanes often live at once) OVERFLOWS its fixed quota (8 fast pages):
# the partition can neither borrow idle fast pages from the BE co-runner
# nor follow the hot set — exactly the regime where the paper's occupancy
# control wins. The BE flood also churns through static's recycled fast
# pages, so first-touch placement cannot stay lucky for the LS tenant.
TENANTS = (
    TenantSpec("ls", t_miss=0.1, arrival_rate=0.10,
               prompt_tokens=12, max_new_tokens=16),
    TenantSpec("be", t_miss=1.0, arrival_rate=0.15,
               prompt_tokens=16, max_new_tokens=24),
)

MODES = ("maxmem", "static", "fixed")


def _setup():
    if "setup" not in _STATE:
        cfg = get_config("yi-6b").smoke()
        api = get_model(cfg)
        _STATE["setup"] = (cfg, api.init(jax.random.PRNGKey(0)))
    return _STATE["setup"]


def _engine(cfg, params, mode: str) -> ServingEngine:
    manager = make_serving_manager(
        mode,
        num_pages=FAST_PAGES + SLOW_PAGES,
        fast_capacity=FAST_PAGES,
        migration_budget=BANDWIDTH,
        queue_size=QUEUE_SIZE,
        migration_bandwidth=BANDWIDTH,
        # split the fast tier evenly between the tenants (the
        # provisioned-for-peak deployment the paper argues against)
        fast_quota={"ls": FAST_PAGES // 2, "be": FAST_PAGES // 2},
        alloc_headroom=ALLOC_HEADROOM,
        max_tenants=4,
    )
    kv = TieredPagedKV(cfg, FAST_PAGES, SLOW_PAGES, page_tokens=PAGE_TOKENS)
    return ServingEngine(
        cfg, params, manager, kv,
        max_batch=MAX_BATCH, pages_per_seq=PAGES_PER_SEQ,
        quest_pages=2, epoch_steps=EPOCH_STEPS,
    )


# untimed leading steps: long enough to hit every compile path (prefill,
# decode, epoch tick, queue drain + page_move) so ``step_us`` is a
# steady-state number — otherwise smoke (60-step) and full (160-step) runs
# amortize one-off JIT cost differently and the perf gate's committed-vs-
# fresh ratio measures compile time, not the engine
WARMUP_STEPS = 24


def _leg(cfg, params, mode: str, n_steps: int, seed: int) -> Dict[str, dict]:
    eng = _engine(cfg, params, mode)
    driver = OpenLoopDriver(eng, TENANTS, seed=seed)
    driver.run(WARMUP_STEPS)
    t0 = time.time()
    rep = driver.run(n_steps)
    wall = time.time() - t0
    rep["_engine"]["wall_s"] = round(wall, 3)
    rep["_engine"]["step_us"] = round(wall / n_steps * 1e6, 1)
    return rep


def serving_bench(smoke: bool = False, seed: int = 7) -> dict:
    cfg, params = _setup()
    n_steps = 60 if smoke else 160
    legs = {m: _leg(cfg, params, m, n_steps, seed) for m in MODES}

    def _p99(mode: str) -> float:
        return legs[mode]["ls"]["latency"].get("p99", float("inf")) * 1e6

    ls_p99 = {m: round(_p99(m), 2) for m in MODES}
    migrated = legs["maxmem"]["_engine"]["migrated_pages"]
    frozen = all(
        legs[m]["_engine"]["migrated_pages"] == 0 for m in ("static", "fixed")
    )
    claim = {
        "ls_p99_us": ls_p99,
        "maxmem_leq_static": ls_p99["maxmem"] <= ls_p99["static"],
        "maxmem_leq_fixed": ls_p99["maxmem"] <= ls_p99["fixed"],
        "migrated_pages": migrated,
        "baselines_frozen": frozen,
        "pass": (
            ls_p99["maxmem"] <= ls_p99["static"]
            and ls_p99["maxmem"] <= ls_p99["fixed"]
            and migrated > 0
            and frozen
        ),
    }
    return {
        "platform": platform_metadata(),
        "config": {
            "model": cfg.name,
            "fast_pages": FAST_PAGES,
            "slow_pages": SLOW_PAGES,
            "page_tokens": PAGE_TOKENS,
            "max_batch": MAX_BATCH,
            "epoch_steps": EPOCH_STEPS,
            "queue_size": QUEUE_SIZE,
            "migration_bandwidth": BANDWIDTH,
            "alloc_headroom": ALLOC_HEADROOM,
            "n_steps": n_steps,
            "warmup_steps": WARMUP_STEPS,
            "smoke": smoke,
            "seed": seed,
            "tenants": [t.__dict__ for t in TENANTS],
        },
        "legs": legs,
        "claim": claim,
    }


def run() -> Rows:
    """CSV rows for the ``benchmarks/run.py`` harness."""
    rows = Rows()
    payload = serving_bench(smoke=True)
    for mode in MODES:
        leg = payload["legs"][mode]
        ls = leg["ls"]["latency"]
        rows.add(
            f"serving_colo_{mode}_ls",
            ls.get("mean", 0) * 1e6,
            f"p50us={ls.get('p50', 0) * 1e6:.1f};"
            f"p99us={ls.get('p99', 0) * 1e6:.1f};"
            f"migrated={leg['_engine']['migrated_pages']};"
            f"blocked={leg['_engine']['admission_blocked']}",
        )
    c = payload["claim"]
    rows.add(
        "serving_colo_claim_ls_p99", 0.0,
        f"maxmem<=static={c['maxmem_leq_static']};"
        f"maxmem<=fixed={c['maxmem_leq_fixed']};"
        f"migrated={c['migrated_pages']};pass={c['pass']}",
    )
    return rows


if __name__ == "__main__":
    run().print()
