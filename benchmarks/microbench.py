"""Micro-benchmarks: wall time of the hot MaxMem primitives on this host.

(The CPU numbers are not TPU performance claims — they document the
policy-path costs, which are host-side even in deployment: one policy epoch
at production page counts must be << the epoch period.)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Rows
from repro.core import policy
from repro.core.types import PageState, PolicyParams, TenantState, TIER_FAST, TIER_SLOW
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hot_bins import hot_bins
from repro.kernels.page_copy import page_move
from repro.kernels.paged_attention import paged_attention


def _time(fn, n=10, warmup=2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n * 1e6


def run() -> Rows:
    rows = Rows()
    rng = np.random.default_rng(0)

    # policy epoch at production scale: 64k pages (128 GB @ 2 MB), 16 tenants
    P, T, R = 65536, 16, 2048
    pages = PageState.create(P)._replace(
        owner=jnp.asarray(rng.integers(0, T, P), jnp.int32),
        tier=jnp.asarray(np.where(rng.random(P) < 0.25, TIER_FAST, TIER_SLOW), jnp.int8),
    )
    tenants = TenantState.create(T)._replace(
        active=jnp.ones((T,), bool),
        t_miss=jnp.asarray(rng.uniform(0.05, 1.0, T), jnp.float32),
        arrival=jnp.arange(T, dtype=jnp.int32),
    )
    params = PolicyParams(
        fast_capacity=jnp.int32(P // 4), migration_budget=jnp.int32(R),
        sample_period=jnp.int32(100),
    )
    sampled = jnp.asarray(rng.poisson(2, P), jnp.uint32)
    us = _time(lambda: policy.policy_epoch(
        pages, tenants, sampled, params, max_tenants=T, plan_size=R))
    rows.add("micro_policy_epoch_64k_pages", us, f"pages={P};tenants={T};budget={R}")

    # hot_bins kernel (interpret mode)
    ids = jnp.asarray(rng.integers(0, 4096, 2048), jnp.int32)
    cin = jnp.zeros((4096,), jnp.int32)
    us = _time(lambda: hot_bins(ids, cin, tile=512))
    rows.add("micro_hot_bins_4k_pages_2k_samples", us, "tile=512")

    # page_copy kernel: 64 x 0.5 MB pages
    pool = jnp.asarray(rng.normal(size=(256, 131072)), jnp.float32)
    sid = jnp.asarray(rng.choice(256, 64, replace=False), jnp.int32)
    did = jnp.asarray(rng.choice(256, 64, replace=False), jnp.int32)
    us = _time(lambda: page_move(jnp.copy(pool), sid, did), n=5)
    rows.add("micro_page_move_64x512KB", us, "bytes=" + str(64 * 131072 * 4))

    # flash attention kernel (interpret)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 512, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 512, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 512, 64), jnp.float32)
    us = _time(lambda: flash_attention(q, k, v, q_blk=128, kv_blk=128), n=5)
    rows.add("micro_flash_attn_512_interpret", us, "B1_h4_dh64")

    # paged attention kernel (interpret)
    kp = jax.random.normal(ks[1], (64, 16, 2, 64), jnp.float32)
    vp = jax.random.normal(ks[2], (64, 16, 2, 64), jnp.float32)
    qd = jax.random.normal(ks[0], (4, 4, 64), jnp.float32)
    tables = jnp.asarray(rng.integers(0, 64, (4, 8)), jnp.int32)
    lens = jnp.asarray([128, 96, 64, 32], jnp.int32)
    us = _time(lambda: paged_attention(qd, kp, vp, tables, lens), n=5)
    rows.add("micro_paged_attn_interpret", us, "B4_pages8x16")
    return rows


if __name__ == "__main__":
    run().print()
