"""Micro-benchmarks: wall time of the hot MaxMem primitives on this host.

(The CPU numbers are not TPU performance claims — they document the
policy-path costs, which are host-side even in deployment: one policy epoch
at production page counts must be << the epoch period.)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Rows, platform_metadata
from repro.core import policy
from repro.core.manager import CentralManager
from repro.core.types import PageState, PolicyParams, TenantState, TIER_FAST, TIER_SLOW
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hot_bins import hot_bins
from repro.kernels.page_copy import page_move
from repro.kernels.paged_attention import paged_attention

# Seed-commit (c35e7fc, lexsort ranks + W=4096 window) measurement of
# micro_policy_epoch_64k_pages on the reference CI host — the fixed baseline
# BENCH_policy.json tracks the counting-rank engine against across PRs.
SEED_POLICY_EPOCH_64K_US = 78321.0


def seed_policy_epoch_us(n_pages: int) -> float:
    """Seed-engine reference cost extrapolated to ``n_pages``.

    The seed commit was only measured at 64k pages; its lexsort-rank epoch
    was SUPERLINEAR in P (global sort dominated), so a linear-in-pages
    extrapolation is a conservative UNDERESTIMATE of what the seed would
    cost at larger sizes — every ``speedup_vs_seed`` beyond 64k is a floor,
    never inflated by the model.
    """
    return SEED_POLICY_EPOCH_64K_US * (n_pages / 65536.0)

_POLICY_BENCH_CACHE = None
_FLEET_BENCH_CACHE = None


def _time(fn, n=10, warmup=2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n * 1e6


def _time_wall(fn, n=3, warmup=1) -> float:
    """Wall time for host-side loops (already synchronous)."""
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def _time_wall_min(fn, n=3, warmup=1) -> float:
    """Min-of-reps wall time: the gating convention for noisy shared
    hosts (cf. vectorization_bench) — the minimum is the least polluted
    estimate of the code's actual cost, and far more stable than the mean
    for the smoke-scale legs the CI perf gate re-measures."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _time_min(fn, n=15, warmup=3) -> float:
    """Min-of-reps device timing: used where two programs are COMPARED on
    the same fresh run (the sentinel overhead band) — the minimum cancels
    shared-host noise that a mean folds into the ratio."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _policy_state(rng, P, T):
    pages = PageState.create(P)._replace(
        owner=jnp.asarray(rng.integers(0, T, P), jnp.int32),
        tier=jnp.asarray(np.where(rng.random(P) < 0.25, TIER_FAST, TIER_SLOW), jnp.int8),
    )
    tenants = TenantState.create(T)._replace(
        active=jnp.ones((T,), bool),
        t_miss=jnp.asarray(rng.uniform(0.05, 1.0, T), jnp.float32),
        arrival=jnp.arange(T, dtype=jnp.int32),
    )
    return pages, tenants


def _bench_manager(P, T, R, counts, k=16):
    """(singles_total_us, scan_total_us): k policy ticks through the
    CentralManager API — per-epoch record_access + run_epoch versus one
    fused run_epochs scan dispatch."""
    def mk():
        mgr = CentralManager(
            num_pages=P, fast_capacity=P // 4, migration_budget=R,
            max_tenants=T, sample_period=100,
        )
        for _ in range(T):
            h = mgr.register(t_miss=0.5)
            mgr.allocate(h, P // T)
        return mgr

    mgr_a = mk()

    def singles():
        for _ in range(k):
            mgr_a.record_access(counts)
            mgr_a.run_epoch()

    singles_us = _time_wall(singles)

    mgr_b = mk()

    def scan():
        mgr_b.run_epochs(k, counts=counts)

    scan_us = _time_wall(scan)
    return singles_us, scan_us


def policy_bench() -> dict:
    """Policy-engine timings for BENCH_policy.json (cached per process)."""
    global _POLICY_BENCH_CACHE
    if _POLICY_BENCH_CACHE is not None:
        return _POLICY_BENCH_CACHE
    rng = np.random.default_rng(0)
    T, R, k = 16, 2048, 16
    out = {
        "platform": platform_metadata(),
        "seed_reference": {
            "micro_policy_epoch_64k_pages_us": SEED_POLICY_EPOCH_64K_US,
            "commit": "c35e7fc (lexsort ranks, W=4096 victim window)",
            # speedup_vs_seed beyond 64k divides by this linear-in-pages
            # extrapolation (see seed_policy_epoch_us: the seed engine was
            # superlinear, so the reported speedups are floors)
            "extrapolation": "linear_in_pages",
        },
        "policy_epoch": {},
        "policy_epoch_queue": {},
        "policy_epoch_sentinel": {},
        "run_epochs_k16": {},
        "live_bytes": {},
    }
    for P in (65536, 262144):
        pages, tenants = _policy_state(rng, P, T)
        params = PolicyParams(
            fast_capacity=jnp.int32(P // 4), migration_budget=jnp.int32(R),
            sample_period=jnp.int32(100),
        )
        sampled = jnp.asarray(rng.poisson(2, P), jnp.uint32)
        n_rep = 10 if P <= 65536 else 5
        epoch_us = _time(lambda: policy.policy_epoch(
            pages, tenants, sampled, params, max_tenants=T, plan_size=R), n=n_rep)
        # every size carries speedup_vs_seed (the 256k row used to omit it,
        # which the perf gate's schema check now rejects); beyond 64k the
        # seed cost is the conservative linear extrapolation
        out["policy_epoch"][str(P)] = {
            "us": epoch_us,
            "epochs_per_sec": 1e6 / epoch_us,
            "speedup_vs_seed": seed_policy_epoch_us(P) / epoch_us,
        }

        # queue-mode (bounded data plane) overhead over the instant tick at
        # BOTH engine scales, on manager-grade states (owner segments
        # attached — every production queue state goes through
        # CentralManager and carries them), so the ratio isolates the data
        # plane itself
        from repro.core.types import OwnerSegments, PolicyState

        segs = OwnerSegments.build(np.asarray(pages.owner), T)
        pending = jnp.asarray(rng.poisson(200, P), jnp.uint32)
        istate = PolicyState.create(P, T)._replace(
            pages=pages, tenants=tenants, pending=pending, segs=segs,
        )
        qstate = PolicyState.create(P, T, queue_size=2 * R)._replace(
            pages=pages, tenants=tenants, pending=pending, segs=segs,
        )
        qparams = params._replace(migration_bandwidth=jnp.int32(R // 2))

        def instant_epoch():
            st, _plan, _stats = policy.epoch_step(
                istate, params, max_tenants=T, plan_size=R)
            return st.pages.tier

        def queue_epoch():
            st, _plan, _stats = policy.epoch_step(
                qstate, qparams, max_tenants=T, plan_size=R)
            return st.pages.tier

        i_us = _time(instant_epoch, n=n_rep)
        q_us = _time(queue_epoch, n=n_rep)
        out["policy_epoch_queue"][str(P)] = {
            "us": q_us,
            "instant_us": i_us,
            "overhead_vs_instant": q_us / i_us,
            "queue_size": 2 * R,
            "bandwidth": R // 2,
        }

        # live-bytes audit (packed-layout satellite): array bytes of the
        # solo instant/queue states and of a 4-machine stacked fleet state
        # — measured off the real pytrees (types.state_nbytes), so the i16
        # owner / i8 queue-heat packing shows up as data, not assertion
        from repro.core.fleet import FleetManager
        from repro.core.types import state_nbytes

        fleet4 = FleetManager(
            _fleet_managers(4, P, T, R), devices=1)
        out["live_bytes"][str(P)] = {
            "solo_instant": state_nbytes(istate),
            "solo_queue": state_nbytes(qstate),
            "fleet4_stacked": fleet4.live_bytes(),
            "fleet_machines": 4,
            "bytes_per_page_solo": state_nbytes(istate) / P,
        }
        del fleet4

        if P == 65536:
            # Sentinel overhead band (DESIGN.md §7). Three programs on the
            # SAME manager-grade state: the sentinel compiled OUT entirely
            # (the reference), the production program with the traced flag
            # OFF (what every non-chaos run executes — the perf gate bounds
            # this one's overhead vs the reference), and the flag ON (the
            # chaos-run cost, reported for the §7 cost table).
            on_params = params._replace(sentinel=jnp.int32(1))

            def sentinel_ref():
                st, _plan, _stats = policy.epoch_step(
                    istate, params, max_tenants=T, plan_size=R,
                    compile_sentinel=False)
                return st.pages.tier

            def sentinel_off():
                st, _plan, _stats = policy.epoch_step(
                    istate, params, max_tenants=T, plan_size=R)
                return st.pages.tier

            def sentinel_on():
                st, _plan, _stats = policy.epoch_step(
                    istate, on_params, max_tenants=T, plan_size=R)
                return st.pages.tier

            ref_us = _time_min(sentinel_ref)
            off_us = _time_min(sentinel_off)
            on_us = _time_min(sentinel_on)
            out["policy_epoch_sentinel"][str(P)] = {
                "ref_us": ref_us,  # sentinel compiled out
                "off_us": off_us,  # compiled in, traced flag off
                "on_us": on_us,  # compiled in, traced flag on
                "overhead_off": off_us / ref_us,
                "overhead_on": on_us / ref_us,
            }

        counts = rng.poisson(200, P).astype(np.int64)
        singles_us, scan_us = _bench_manager(P, T, R, counts, k=k)
        out["run_epochs_k16"][str(P)] = {
            "singles_total_us": singles_us,
            "scan_total_us": scan_us,
            "singles_per_epoch_us": singles_us / k,
            "scan_per_epoch_us": scan_us / k,
            "scan_epochs_per_sec": k * 1e6 / scan_us,
            "scan_speedup_vs_singles": singles_us / scan_us,
        }
    _POLICY_BENCH_CACHE = out
    return out


def _fleet_managers(n_machines, n_pages, max_tenants, budget):
    mgrs = []
    for seed in range(n_machines):
        m = CentralManager(
            num_pages=n_pages, fast_capacity=n_pages // 4,
            migration_budget=budget, max_tenants=max_tenants,
            sample_period=100, seed=seed,
        )
        for _ in range(max_tenants):
            h = m.register(t_miss=0.5)
            m.allocate(h, n_pages // max_tenants)
        mgrs.append(m)
    return mgrs


def fleet_bench(n_machines: int = 16, n_pages: int = 65536, n_epochs: int = 16) -> dict:
    """Engine-level fleet timings (cached per process per config).

    Four drivers over the SAME per-machine workload:

      * ``serial_singles`` — the pre-fleet sweep driver: for every machine,
        per-epoch ``record_access`` + ``run_epoch`` + a telemetry snapshot
        read (K x E dispatches and host syncs);
      * ``serial_scan``    — per-machine fused ``run_epochs`` (K dispatches,
        K snapshots);
      * ``fleet``          — ``FleetManager.run_epochs`` on ONE device: one
        vmapped scan dispatch and one stacked snapshot for all machines;
      * ``fleet_sharded``  — the same program with the machine axis
        partitioned over every visible XLA device (``devices`` records how
        many; identical to ``fleet`` on single-device hosts), telemetry
        trimmed to the sweep record fields and the stacked placement read
        through ``stacked_placement`` (the sweep pipeline's fetch path).

    Per-machine results of all four are bit-identical (tests/test_fleet.py,
    tests/test_fleet_sharded.py); only the dispatch/host-sync structure
    differs.
    """
    global _FLEET_BENCH_CACHE
    key = (n_machines, n_pages, n_epochs)
    if _FLEET_BENCH_CACHE is None:
        _FLEET_BENCH_CACHE = {}
    if key in _FLEET_BENCH_CACHE:
        return _FLEET_BENCH_CACHE[key]
    import jax

    from repro.core.fleet import FleetManager

    T = 16
    R = max(n_pages // 32, 8)
    rng = np.random.default_rng(0)
    counts = rng.poisson(200, (n_machines, n_pages)).astype(np.int64)

    # One manager set per driver, built OUTSIDE the timed closures: the
    # gated metric must measure the epoch hot path, not control-plane
    # setup. State advances across reps (steady workload) — the same
    # convention _bench_manager uses.
    singles_ms = _fleet_managers(n_machines, n_pages, T, R)
    scans_ms = _fleet_managers(n_machines, n_pages, T, R)
    fleet_f = FleetManager(_fleet_managers(n_machines, n_pages, T, R), devices=1)
    fleet_s = FleetManager(_fleet_managers(n_machines, n_pages, T, R))

    def singles():
        for i, m in enumerate(singles_ms):
            for _ in range(n_epochs):
                m.record_access(counts[i])
                m.run_epoch()
                m.tiers()  # the sweep driver reads placement every epoch

    def scans():
        for i, m in enumerate(scans_ms):
            m.run_epochs(n_epochs, counts=counts[i])
            m.tiers()

    def fleet():
        fleet_f.run_epochs(n_epochs, counts=counts)
        for m in fleet_f.machines:
            m.tiers()

    def fleet_sharded():
        fleet_s.run_epochs(n_epochs, counts=counts, trim_stats=True)
        fleet_s.stacked_placement()

    reps = 5 if n_pages <= 16384 else 2
    me = n_machines * n_epochs
    out = {"n_machines": n_machines, "n_pages": n_pages,
           "n_epochs": n_epochs, "max_tenants": T, "migration_budget": R,
           "devices": jax.local_device_count()}
    for name, fn in (("serial_singles", singles), ("serial_scan", scans),
                     ("fleet", fleet), ("fleet_sharded", fleet_sharded)):
        total = _time_wall_min(fn, n=reps, warmup=1)
        out[name] = {
            "total_us": total,
            "per_machine_epoch_us": total / me,
            "agg_epochs_per_sec": me * 1e6 / total,
        }
    out["fleet"]["speedup_vs_singles"] = (
        out["serial_singles"]["total_us"] / out["fleet"]["total_us"]
    )
    out["fleet"]["speedup_vs_scan"] = (
        out["serial_scan"]["total_us"] / out["fleet"]["total_us"]
    )
    out["fleet_sharded"]["devices"] = jax.local_device_count()
    out["fleet_sharded"]["speedup_vs_fleet"] = (
        out["fleet"]["total_us"] / out["fleet_sharded"]["total_us"]
    )
    _FLEET_BENCH_CACHE[key] = out
    return out


def run() -> Rows:
    rows = Rows()
    rng = np.random.default_rng(0)

    # policy engine at production scale: 64k pages (128 GB @ 2 MB), 16
    # tenants, plus the 256k-page and fused-scan variants
    pb = policy_bench()
    P, T, R = 65536, 16, 2048
    rows.add(
        "micro_policy_epoch_64k_pages", pb["policy_epoch"]["65536"]["us"],
        f"pages=65536;tenants={T};budget={R};"
        f"speedup_vs_seed={pb['policy_epoch']['65536']['speedup_vs_seed']:.2f}",
    )
    rows.add(
        "micro_policy_epoch_256k_pages", pb["policy_epoch"]["262144"]["us"],
        f"pages=262144;tenants={T};budget={R};"
        f"speedup_vs_seed={pb['policy_epoch']['262144']['speedup_vs_seed']:.2f}",
    )
    for p_key, label in (("65536", "64k"), ("262144", "256k")):
        lb = pb["live_bytes"][p_key]
        rows.add(
            f"micro_policy_live_bytes_{label}", 0.0,
            f"solo_instant={lb['solo_instant']};solo_queue={lb['solo_queue']};"
            f"fleet4_stacked={lb['fleet4_stacked']};"
            f"bytes_per_page={lb['bytes_per_page_solo']:.2f}",
        )
    for p_key, label in (("65536", "64k"), ("262144", "256k")):
        q = pb["policy_epoch_queue"][p_key]
        rows.add(
            f"micro_policy_epoch_{label}_queue_mode", q["us"],
            f"queue={q['queue_size']};bw={q['bandwidth']};"
            f"overhead_vs_instant={q['overhead_vs_instant']:.2f}",
        )
    sb = pb["policy_epoch_sentinel"]["65536"]
    rows.add(
        "micro_policy_epoch_64k_sentinel_off", sb["off_us"],
        f"ref_us={sb['ref_us']:.0f};on_us={sb['on_us']:.0f};"
        f"overhead_off={sb['overhead_off']:.3f};"
        f"overhead_on={sb['overhead_on']:.3f}",
    )
    for p_key, label in (("65536", "64k"), ("262144", "256k")):
        d = pb["run_epochs_k16"][p_key]
        rows.add(
            f"micro_policy_multi_epoch_k16_{label}_pages", d["scan_total_us"],
            f"per_epoch_us={d['scan_per_epoch_us']:.0f};"
            f"speedup_vs_singles={d['scan_speedup_vs_singles']:.2f}",
        )
        rows.add(
            f"micro_policy_single_epochs_k16_{label}_pages", d["singles_total_us"],
            f"per_epoch_us={d['singles_per_epoch_us']:.0f}",
        )

    # fleet engine: 16 machines x 64k pages, one vmapped scan dispatch
    fb = fleet_bench()
    rows.add(
        "micro_fleet_16x64k_per_machine_epoch", fb["fleet"]["per_machine_epoch_us"],
        f"agg_eps={fb['fleet']['agg_epochs_per_sec']:.1f};"
        f"speedup_vs_singles={fb['fleet']['speedup_vs_singles']:.2f};"
        f"speedup_vs_scan={fb['fleet']['speedup_vs_scan']:.2f}",
    )
    fs = fb["fleet_sharded"]
    rows.add(
        "micro_fleet_sharded_16x64k_per_machine_epoch",
        fs["per_machine_epoch_us"],
        f"devices={fs['devices']};agg_eps={fs['agg_epochs_per_sec']:.1f};"
        f"speedup_vs_fleet={fs['speedup_vs_fleet']:.2f}",
    )

    # hot_bins kernel (interpret mode)
    ids = jnp.asarray(rng.integers(0, 4096, 2048), jnp.int32)
    cin = jnp.zeros((4096,), jnp.int32)
    us = _time(lambda: hot_bins(ids, cin, tile=512))
    rows.add("micro_hot_bins_4k_pages_2k_samples", us, "tile=512")

    # page_copy kernel: 64 x 0.5 MB pages
    pool = jnp.asarray(rng.normal(size=(256, 131072)), jnp.float32)
    sid = jnp.asarray(rng.choice(256, 64, replace=False), jnp.int32)
    did = jnp.asarray(rng.choice(256, 64, replace=False), jnp.int32)
    us = _time(lambda: page_move(jnp.copy(pool), sid, did), n=5)
    rows.add("micro_page_move_64x512KB", us, "bytes=" + str(64 * 131072 * 4))

    # flash attention kernel (interpret)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 512, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 512, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 512, 64), jnp.float32)
    us = _time(lambda: flash_attention(q, k, v, q_blk=128, kv_blk=128), n=5)
    rows.add("micro_flash_attn_512_interpret", us, "B1_h4_dh64")

    # paged attention kernel (interpret)
    kp = jax.random.normal(ks[1], (64, 16, 2, 64), jnp.float32)
    vp = jax.random.normal(ks[2], (64, 16, 2, 64), jnp.float32)
    qd = jax.random.normal(ks[0], (4, 4, 64), jnp.float32)
    tables = jnp.asarray(rng.integers(0, 64, (4, 8)), jnp.int32)
    lens = jnp.asarray([128, 96, 64, 32], jnp.int32)
    us = _time(lambda: paged_attention(qd, kp, vp, tables, lens), n=5)
    rows.add("micro_paged_attn_interpret", us, "B4_pages8x16")
    return rows


if __name__ == "__main__":
    run().print()
